"""AOT lowering tests: every artifact kind lowers to parseable HLO text."""

import json
import subprocess
import sys

import pytest

from compile.model import PRESETS
from compile.aot import lower_layer_fwd, lower_layer_fwd_bin, lower_lm_head, lower_gemm


def _check_hlo(text, min_params):
    assert text.startswith("HloModule"), text[:80]
    assert "ROOT" in text
    assert text.count("parameter(") >= min_params


def test_lower_layer_fwd_llama():
    cfg = PRESETS["llama1-7b"]
    _check_hlo(lower_layer_fwd(cfg), 3 + len(cfg.layer_weight_names()))


def test_lower_layer_fwd_opt():
    cfg = PRESETS["opt-1.3b"]
    _check_hlo(lower_layer_fwd(cfg), 3 + len(cfg.layer_weight_names()))


def test_lower_layer_fwd_mistral_sliding_window():
    cfg = PRESETS["mistral-7b"]
    _check_hlo(lower_layer_fwd(cfg), 10)


def test_lower_layer_fwd_bin_contains_kernel_body():
    cfg = PRESETS["llama1-7b"]
    text = lower_layer_fwd_bin(cfg)
    _check_hlo(text, 3 + 2 * len(cfg.layer_weight_names()))


def test_lower_lm_head():
    _check_hlo(lower_lm_head(PRESETS["llama1-7b"]), 3)


def test_lower_gemm_shapes():
    text = lower_gemm(16, 32, 24)
    _check_hlo(text, 3)
    assert "f32[16,32]" in text and "f32[24,32]" in text


def test_hlo_text_has_32bit_friendly_header():
    # the text parser reassigns ids; just ensure we did NOT emit a proto blob
    text = lower_gemm(8, 8, 8)
    assert "\x00" not in text
