"""Cross-language determinism lock for RNG + corpora.

The known-answer vectors below are ALSO asserted by rust/src/util/rng.rs and
rust/src/model/corpus.rs unit tests. If either side drifts, both test suites
fail — guaranteeing the Python trainer and Rust evaluator share one data
distribution (bit-identical streams for equal seeds).
"""

import numpy as np

from compile.rngcorpus import Pcg32, Corpus, SPECS, corpus_tokens

KAT_PCG_42_54 = [2707161783, 2068313097, 3122475824, 2211639955, 3215226955, 3421331566]
KAT_BOUNDED_7_3 = [51, 8, 72, 30, 99, 67, 36, 35]
KAT_WIKI = [17, 47, 15, 33, 62, 63, 36, 2, 32, 59, 49, 17]
KAT_C4 = [55, 20, 82, 30, 37, 29, 31, 18, 38, 49, 95, 32]
KAT_PTB = [8, 25, 27, 8, 29, 15, 23, 8, 20, 24, 2, 17]


def test_pcg32_known_answers():
    r = Pcg32(42, stream=54)
    assert [r.next_u32() for _ in range(6)] == KAT_PCG_42_54


def test_pcg32_bounded_known_answers():
    r = Pcg32(7, stream=3)
    assert [r.bounded(100) for _ in range(8)] == KAT_BOUNDED_7_3


def test_corpus_known_answers():
    assert corpus_tokens("wikitext2s", 12, 5) == KAT_WIKI
    assert corpus_tokens("c4s", 12, 5) == KAT_C4
    assert corpus_tokens("ptbs", 12, 5) == KAT_PTB


def test_corpus_alphabet_bounds():
    for name, spec in SPECS.items():
        toks = corpus_tokens(name, 2000, 9)
        assert min(toks) >= 0 and max(toks) < spec.alphabet, name


def test_corpus_determinism_and_seed_sensitivity():
    a = corpus_tokens("c4s", 256, 1)
    b = corpus_tokens("c4s", 256, 1)
    c = corpus_tokens("c4s", 256, 2)
    assert a == b
    assert a != c


def test_corpora_have_distinct_distributions():
    """Unigram histograms must differ enough that in/out-of-domain ppl gaps
    exist (Tables 7/11 depend on this)."""
    h = {}
    for name in SPECS:
        toks = corpus_tokens(name, 8000, 3)
        hist = np.bincount(toks, minlength=256).astype(np.float64)
        h[name] = hist / hist.sum()
    def tv(a, b):
        return 0.5 * np.abs(a - b).sum()
    assert tv(h["wikitext2s"], h["c4s"]) > 0.2
    assert tv(h["wikitext2s"], h["ptbs"]) > 0.2


def test_ptbs_has_reset_symbol():
    toks = corpus_tokens("ptbs", 4000, 4)
    frac0 = toks.count(0) / len(toks)
    assert frac0 > 0.02  # terminator appears regularly


def test_pcg_float_range():
    r = Pcg32(9, stream=1)
    vals = [r.next_f32() for _ in range(1000)]
    assert all(0.0 <= v < 1.0 for v in vals)
    assert 0.4 < float(np.mean(vals)) < 0.6
