"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes, N:M patterns and block sizes; every case asserts
allclose against ``ref.py``.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.binary_gemm import (
    nm_binary_gemm,
    nm_binary_gemm_residual,
    vmem_footprint_bytes,
)
from compile.kernels.residual import residual_binarize
from compile.kernels import ref

RTOL, ATOL = 1e-5, 1e-4


def make_nm_sb(rng, n, k, nn, mm):
    """Random ±1 signs with an exact N:M mask per row-group of mm."""
    signs = rng.choice([-1.0, 1.0], size=(n, k)).astype(np.float32)
    mask = np.zeros((n, k), np.float32)
    for i in range(n):
        for g in range(0, k, mm):
            width = min(mm, k - g)
            keep = rng.choice(width, size=min(nn, width), replace=False)
            mask[i, g + keep] = 1.0
    return signs * mask


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 8, 33, 64]),
    k=st.sampled_from([8, 32, 96, 256]),
    n=st.sampled_from([8, 24, 64]),
    nm=st.sampled_from([(2, 4), (4, 8), (6, 8), (5, 8)]),
    seed=st.integers(0, 2**16),
)
def test_gemm_matches_ref_hypothesis(m, k, n, nm, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    sb = make_nm_sb(rng, n, k, *nm)
    alpha = np.abs(rng.normal(size=(n,))).astype(np.float32)
    got = nm_binary_gemm(jnp.asarray(x), jnp.asarray(sb), jnp.asarray(alpha))
    want = ref.nm_binary_gemm_ref(x, sb, alpha)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 16), (16, 32, 32), (128, 128, 64), (64, 64, 256)])
def test_gemm_block_sizes_agree(bm, bn, bk):
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 128)).astype(np.float32)
    sb = make_nm_sb(rng, 96, 128, 4, 8)
    alpha = np.abs(rng.normal(size=(96,))).astype(np.float32)
    got = nm_binary_gemm(jnp.asarray(x), jnp.asarray(sb), jnp.asarray(alpha), bm=bm, bn=bn, bk=bk)
    want = ref.nm_binary_gemm_ref(x, sb, alpha)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_gemm_ktiled_and_smallk_paths_agree():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(32, 512)).astype(np.float32)
    sb = make_nm_sb(rng, 64, 512, 2, 4)
    alpha = np.abs(rng.normal(size=(64,))).astype(np.float32)
    kt = nm_binary_gemm(jnp.asarray(x), jnp.asarray(sb), jnp.asarray(alpha), bk=128)
    sk = nm_binary_gemm(jnp.asarray(x), jnp.asarray(sb), jnp.asarray(alpha), bk=1024)
    np.testing.assert_allclose(kt, sk, rtol=RTOL, atol=ATOL)


def test_gemm_zero_alpha_zeroes_channel():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(16, 32)).astype(np.float32)
    sb = make_nm_sb(rng, 8, 32, 4, 8)
    alpha = np.ones((8,), np.float32)
    alpha[3] = 0.0
    y = np.asarray(nm_binary_gemm(jnp.asarray(x), jnp.asarray(sb), jnp.asarray(alpha)))
    assert np.all(y[:, 3] == 0.0)
    assert np.any(y[:, 0] != 0.0)


def test_gemm_fully_pruned_rows_are_zero():
    x = np.ones((4, 16), np.float32)
    sb = np.zeros((6, 16), np.float32)  # 0:M "mask"
    alpha = np.ones((6,), np.float32)
    y = np.asarray(nm_binary_gemm(jnp.asarray(x), jnp.asarray(sb), jnp.asarray(alpha)))
    assert np.all(y == 0.0)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([4, 16, 48]),
    k=st.sampled_from([16, 64, 160]),
    n=st.sampled_from([8, 32]),
    seed=st.integers(0, 2**16),
)
def test_residual_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    sb_o = make_nm_sb(rng, n, k, 4, 8)
    sb_r = make_nm_sb(rng, n, k, 4, 8)
    a_o = np.abs(rng.normal(size=(n,))).astype(np.float32)
    a_r = np.abs(rng.normal(size=(n,))).astype(np.float32)
    got = nm_binary_gemm_residual(
        jnp.asarray(x), jnp.asarray(sb_o), jnp.asarray(a_o),
        jnp.asarray(sb_r), jnp.asarray(a_r),
    )
    want = ref.nm_binary_gemm_residual_ref(x, sb_o, a_o, sb_r, a_r)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([1, 7, 32, 128]),
    k=st.sampled_from([8, 64, 352]),
    seed=st.integers(0, 2**16),
)
def test_residual_binarize_matches_ref(m, k, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, k)).astype(np.float32)
    got = residual_binarize(jnp.asarray(w))
    want = ref.residual_binarize_ref(w)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_residual_binarize_reduces_error_vs_plain_sign():
    rng = np.random.default_rng(3)
    w = rng.normal(size=(64, 128)).astype(np.float32)
    recon = np.asarray(residual_binarize(jnp.asarray(w)))
    a = np.mean(np.abs(w), axis=1, keepdims=True)
    plain = a * np.where(w >= 0, 1.0, -1.0)
    assert np.linalg.norm(w - recon) < np.linalg.norm(w - plain)


def test_residual_binarize_sign_zero_is_positive():
    w = np.zeros((2, 8), np.float32)
    recon = np.asarray(residual_binarize(jnp.asarray(w)))
    np.testing.assert_allclose(recon, 0.0)  # alpha = 0 ⇒ reconstruction 0


def test_vmem_footprint_monotone():
    assert vmem_footprint_bytes(128, 128, 256) > vmem_footprint_bytes(64, 64, 128)
    # production tile must fit a 16 MiB VMEM with room for double-buffering
    assert vmem_footprint_bytes(128, 128, 256) * 2 < 16 * 1024 * 1024
