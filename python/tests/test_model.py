"""L2 model tests: shapes, family variants, dense-vs-binary-path parity,
training signal, loss behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.model import (
    PRESETS, ModelConfig, init_params, layer_fwd, binary_layer_fwd, lm_head,
    model_fwd, next_token_loss, rope_tables, apply_rope, causal_mask,
    config_manifest, HEAD_DIM,
)

SMALL = {"llama": PRESETS["llama1-7b"], "opt": PRESETS["opt-1.3b"], "mistral": PRESETS["mistral-7b"]}


@pytest.mark.parametrize("family", ["llama", "opt", "mistral"])
def test_model_fwd_shapes(family):
    cfg = SMALL[family]
    params = init_params(cfg)
    toks = jnp.arange(cfg.seq_len, dtype=jnp.int32) % cfg.vocab
    logits = model_fwd(cfg, params, toks)
    assert logits.shape == (cfg.seq_len, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("family", ["llama", "opt", "mistral"])
def test_layer_fwd_shapes_and_finite(family):
    cfg = SMALL[family]
    params = init_params(cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(cfg.seq_len, cfg.dim)).astype(np.float32))
    y = layer_fwd(cfg, x, params["layers"][0])
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_binary_layer_matches_dense_when_sb_is_weight():
    """With sb := W and alpha := 1 the Pallas path must reproduce the dense
    layer exactly — locks kernel wiring (transposes, epilogue) in place."""
    cfg = SMALL["llama"]
    params = init_params(cfg)
    layer = params["layers"][0]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(cfg.seq_len, cfg.dim)).astype(np.float32))
    sbs = {n: layer[n] for n in cfg.layer_weight_names()}
    alphas = {n: jnp.ones((layer[n].shape[0],), jnp.float32) for n in cfg.layer_weight_names()}
    dense = layer_fwd(cfg, x, layer)
    binary = binary_layer_fwd(cfg, x, sbs, alphas, {"ln1": layer["ln1"], "ln2": layer["ln2"]})
    np.testing.assert_allclose(np.asarray(binary), np.asarray(dense), rtol=1e-4, atol=1e-4)


def test_causality():
    """Changing a future token must not change past logits."""
    cfg = SMALL["llama"]
    params = init_params(cfg)
    toks = jnp.arange(cfg.seq_len, dtype=jnp.int32) % cfg.vocab
    l1 = model_fwd(cfg, params, toks)
    toks2 = toks.at[-1].set((toks[-1] + 7) % cfg.vocab)
    l2 = model_fwd(cfg, params, toks2)
    np.testing.assert_allclose(np.asarray(l1[:-1]), np.asarray(l2[:-1]), rtol=1e-5, atol=1e-5)


def test_sliding_window_differs_from_full_causal():
    cfg = SMALL["mistral"]
    assert cfg.window > 0
    full = ModelConfig(**{**cfg.__dict__, "name": "tmp", "window": 0})
    params = init_params(cfg)
    toks = jnp.arange(cfg.seq_len, dtype=jnp.int32) % cfg.vocab
    a = np.asarray(model_fwd(cfg, params, toks))
    b = np.asarray(model_fwd(full, params, toks))
    # early positions identical (window covers whole history), late differ
    np.testing.assert_allclose(a[: cfg.window - 1], b[: cfg.window - 1], rtol=1e-5, atol=1e-5)
    assert np.max(np.abs(a[-1] - b[-1])) > 1e-6


def test_rope_preserves_norm_and_relative_phase():
    cos, sin = rope_tables(16)
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(16, 2, HEAD_DIM)).astype(np.float32))
    r = apply_rope(q, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(r), axis=-1),
        np.linalg.norm(np.asarray(q), axis=-1),
        rtol=1e-5,
    )
    # position 0 is identity
    np.testing.assert_allclose(np.asarray(r[0]), np.asarray(q[0]), rtol=1e-6, atol=1e-6)


def test_causal_mask_window():
    m = np.asarray(causal_mask(8, 3))
    assert m[5, 5] == 0.0 and m[5, 4] == 0.0 and m[5, 3] == 0.0
    assert m[5, 2] < -1e8 and m[5, 6] < -1e8


def test_loss_decreases_with_training():
    from compile import train as T
    cfg = SMALL["llama"]
    _, curve = T.train_model(cfg, steps=25, log_every=5)
    assert curve[-1][1] < curve[0][1] - 0.3, curve


def test_weight_save_load_roundtrip(tmp_path):
    from compile import train as T
    cfg = SMALL["opt"]
    params = init_params(cfg)
    p = str(tmp_path / "w.bin")
    T.save_weights(cfg, params, p)
    named = T.load_weights(p)
    back = T.params_from_named(cfg, named)
    np.testing.assert_array_equal(np.asarray(back["embed"]), np.asarray(params["embed"]))
    np.testing.assert_array_equal(
        np.asarray(back["layers"][1]["w1"]), np.asarray(params["layers"][1]["w1"])
    )
    toks = jnp.arange(cfg.seq_len, dtype=jnp.int32) % cfg.vocab
    np.testing.assert_allclose(
        np.asarray(model_fwd(cfg, back, toks)), np.asarray(model_fwd(cfg, params, toks)),
        rtol=1e-6,
    )


def test_manifest_fields():
    m = config_manifest(PRESETS["llama1-30b"])
    assert m["dim"] == 256 and m["n_heads"] == 8 and m["head_dim"] == HEAD_DIM
    assert m["layer_weights"]["w1"] == [704, 256]
    assert m["n_params"] > 0


def test_all_presets_consistent():
    for cfg in PRESETS.values():
        assert cfg.dim % HEAD_DIM == 0, cfg.name
        for n in cfg.layer_weight_names():
            o, i = cfg.layer_weight_shape(n)
            assert o % 8 == 0 and i % 8 == 0, (cfg.name, n)  # N:M group alignment
