"""Deterministic PCG32 RNG + synthetic corpus generators.

This module is the Python mirror of ``rust/src/util/rng.rs`` and
``rust/src/model/corpus.rs``. The two implementations are *bit-identical*:
all corpus construction uses only integer arithmetic on the PCG32 stream, so
the Python build-time trainer and the Rust run-time evaluator see token
streams drawn from exactly the same distribution (and, for equal seeds, the
exact same bytes). This is what makes a perplexity measured in Rust
commensurable with a loss curve trained in Python.

Corpora (stand-ins for the paper's eval sets, see DESIGN.md §2):
  * ``wikitext2s`` — order-2 Markov chain, 64-symbol alphabet, 4 successor
    candidates per context with Zipf-ish integer weights. Clean, low-entropy
    prose-like stream.
  * ``c4s``       — order-1 Markov chain, 96 symbols, 8 candidates. Noisier
    web-like stream with higher entropy.
  * ``ptbs``      — order-2 Markov chain, 32 symbols, 3 candidates, with a
    frequent sentence-terminator reset symbol. Short-sentence newswire-like.
"""

from __future__ import annotations

from dataclasses import dataclass

MASK64 = (1 << 64) - 1
MASK32 = (1 << 32) - 1
PCG_MULT = 6364136223846793005


class Pcg32:
    """Minimal PCG32 (XSH-RR). Mirrors rust/src/util/rng.rs exactly."""

    def __init__(self, seed: int, stream: int = 54):
        self.state = 0
        self.inc = ((stream << 1) | 1) & MASK64
        self.next_u32()
        self.state = (self.state + (seed & MASK64)) & MASK64
        self.next_u32()

    def next_u32(self) -> int:
        old = self.state
        self.state = (old * PCG_MULT + self.inc) & MASK64
        xorshifted = (((old >> 18) ^ old) >> 27) & MASK32
        rot = old >> 59
        return ((xorshifted >> rot) | (xorshifted << ((32 - rot) & 31))) & MASK32

    def bounded(self, n: int) -> int:
        """Uniform-ish integer in [0, n). Modulo bias is acceptable here."""
        return self.next_u32() % n

    def next_f32(self) -> float:
        """Uniform float in [0, 1) with 24 bits of entropy."""
        return (self.next_u32() >> 8) * (1.0 / float(1 << 24))

    def normal(self) -> float:
        """Approximate standard normal via sum of 12 uniforms (Irwin-Hall).

        Matches the Rust implementation; used only for weight init styles
        that never need cross-language determinism beyond distribution.
        """
        s = 0.0
        for _ in range(12):
            s += self.next_f32()
        return s - 6.0


@dataclass(frozen=True)
class CorpusSpec:
    name: str
    seed: int
    alphabet: int
    order: int  # 1 or 2
    candidates: int
    reset_every: int  # 0 = never; else ~geometric sentence resets


SPECS = {
    "wikitext2s": CorpusSpec("wikitext2s", 11, 64, 2, 4, 0),
    "c4s": CorpusSpec("c4s", 22, 96, 1, 8, 0),
    "ptbs": CorpusSpec("ptbs", 33, 32, 2, 3, 24),
}


class Corpus:
    """Markov-chain token stream over byte symbols [0, alphabet).

    Transition tables and sampling are all-integer so the Rust port emits an
    identical stream for the same spec.
    """

    def __init__(self, spec: CorpusSpec):
        self.spec = spec
        rng = Pcg32(spec.seed, stream=7)
        a, k = spec.alphabet, spec.candidates
        n_ctx = a * a if spec.order == 2 else a
        # For each context: k candidate successors + integer Zipf weights
        # w_i = 1000 // (i + 1); total = sum(w).
        self.succ = []
        self.weights = [1000 // (i + 1) for i in range(k)]
        self.total_w = sum(self.weights)
        for _ in range(n_ctx):
            self.succ.append([rng.bounded(a) for _ in range(k)])

    def generate(self, n: int, seed: int) -> list[int]:
        """Generate ``n`` tokens with a sampling RNG independent of the table RNG."""
        spec = self.spec
        rng = Pcg32(seed, stream=13)
        a = spec.alphabet
        prev1 = rng.bounded(a)
        prev2 = rng.bounded(a)
        out = []
        for step in range(n):
            if spec.reset_every and rng.bounded(spec.reset_every) == 0:
                # sentence reset: emit terminator symbol 0 and resample state
                out.append(0)
                prev1 = rng.bounded(a)
                prev2 = rng.bounded(a)
                continue
            ctx = prev1 * a + prev2 if spec.order == 2 else prev2
            r = rng.bounded(self.total_w)
            acc = 0
            nxt = self.succ[ctx][-1]
            for cand, w in zip(self.succ[ctx], self.weights):
                acc += w
                if r < acc:
                    nxt = cand
                    break
            out.append(nxt)
            prev1, prev2 = prev2, nxt
        return out


def corpus_tokens(name: str, n: int, seed: int) -> list[int]:
    """Convenience: build the named corpus and generate ``n`` tokens."""
    return Corpus(SPECS[name]).generate(n, seed)
