"""Build-time pre-training of the tiny model zoo.

The paper applies PTQ to *trained* checkpoints; quantization behaviour
(salient columns, heavy-tailed weights, activation outliers) only emerges on
trained weights, so we briefly train every preset on the synthetic corpus
mix before quantizing. This runs once under ``make artifacts`` and the
resulting weights are stored in ``artifacts/weights/`` for the Rust side.

Hand-rolled Adam (optax is not available in this environment).

Training data is prose-like wikitext2s plus a little ptbs; c4s stays fully
out-of-domain. This mirrors the paper's in/out-of-domain spread (their PTB
evals are far-OOD for LLaMA) and is what Tables 7/11 rely on.
"""

from __future__ import annotations

import os
import struct
import time

import numpy as np
import jax
import jax.numpy as jnp

from compile import rngcorpus
from compile.model import ModelConfig, init_params, next_token_loss

TRAIN_MIX = [("wikitext2s", 0.92), ("ptbs", 0.08)]
# fixed training corpus size; batches resample it randomly each step (the
# repetition is what lets tiny models escape the unigram plateau quickly)
CORPUS_TOKENS = 100_000


def _mixed_tokens(seed: int) -> np.ndarray:
    parts = []
    for name, frac in TRAIN_MIX:
        parts.append(
            np.array(rngcorpus.corpus_tokens(name, int(CORPUS_TOKENS * frac), seed), np.int32)
        )
    return np.concatenate(parts)


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree_util.tree_map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(lambda p, m, v: p - lr * m / (jnp.sqrt(v) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


def train_model(cfg: ModelConfig, steps: int, batch: int = 8, lr: float = 2e-3,
                log_every: int = 50) -> tuple[dict, list[tuple[int, float]]]:
    """Train ``cfg`` for ``steps``; returns (params, loss_curve).

    Constant lr after a 20-step warmup: tiny byte-level models spend ~200
    steps on a unigram plateau before context learning kicks in, and cosine
    decay starves exactly that phase (measured — see EXPERIMENTS.md).
    Batches are sampled with replacement from a fixed mixed corpus.
    """
    seq = cfg.seq_len
    toks = _mixed_tokens(seed=cfg.seed)
    params = init_params(cfg)

    @jax.jit
    def step_fn(params, opt, batch_toks, lr):
        loss, grads = jax.value_and_grad(lambda p: next_token_loss(cfg, p, batch_toks))(params)
        params, opt = adam_update(params, grads, opt, lr)
        return params, opt, loss

    opt = adam_init(params)
    curve = []
    t0 = time.time()
    rng = np.random.default_rng(cfg.seed)
    for s in range(steps):
        idx = rng.integers(0, len(toks) - seq - 1, batch)
        bt = jnp.asarray(np.stack([toks[i : i + seq + 1] for i in idx]))
        cur_lr = lr * min(1.0, (s + 1) / 20)
        params, opt, loss = step_fn(params, opt, bt, cur_lr)
        if s % log_every == 0 or s == steps - 1:
            curve.append((s, float(loss)))
    dt = time.time() - t0
    print(f"  [{cfg.name}] {steps} steps, final loss {curve[-1][1]:.4f}, {dt:.1f}s")
    return params, curve


# ---------------------------------------------------------------------------
# Weight serialization: simple tagged binary format read by rust/src/model/io.rs
#   magic "STBW" | u32 n_tensors | per tensor:
#   u32 name_len | name bytes | u32 ndim | u32 dims... | f32 LE data
# ---------------------------------------------------------------------------

def _flatten_named(cfg: ModelConfig, params: dict) -> list[tuple[str, np.ndarray]]:
    out = [("embed", params["embed"]), ("ln_f", params["ln_f"])]
    if cfg.family == "opt":
        out.append(("pos", params["pos"]))
    for i, layer in enumerate(params["layers"]):
        out.append((f"layers.{i}.ln1", layer["ln1"]))
        out.append((f"layers.{i}.ln2", layer["ln2"]))
        for n in cfg.layer_weight_names():
            out.append((f"layers.{i}.{n}", layer[n]))
    return [(n, np.asarray(t, np.float32)) for n, t in out]


def save_weights(cfg: ModelConfig, params: dict, path: str) -> None:
    tensors = _flatten_named(cfg, params)
    with open(path, "wb") as f:
        f.write(b"STBW")
        f.write(struct.pack("<I", len(tensors)))
        for name, t in tensors:
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", t.ndim))
            for d in t.shape:
                f.write(struct.pack("<I", d))
            f.write(t.astype("<f4").tobytes())


def load_weights(path: str) -> dict[str, np.ndarray]:
    tensors = {}
    with open(path, "rb") as f:
        assert f.read(4) == b"STBW", "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            (nd,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd))
            cnt = int(np.prod(dims)) if nd else 1
            data = np.frombuffer(f.read(4 * cnt), "<f4").reshape(dims)
            tensors[name] = data
    return tensors


def params_from_named(cfg: ModelConfig, named: dict[str, np.ndarray]) -> dict:
    params = {
        "embed": jnp.asarray(named["embed"]),
        "ln_f": jnp.asarray(named["ln_f"]),
        "layers": [],
    }
    if cfg.family == "opt":
        params["pos"] = jnp.asarray(named["pos"])
    for i in range(cfg.n_layers):
        layer = {
            "ln1": jnp.asarray(named[f"layers.{i}.ln1"]),
            "ln2": jnp.asarray(named[f"layers.{i}.ln2"]),
        }
        for n in cfg.layer_weight_names():
            layer[n] = jnp.asarray(named[f"layers.{i}.{n}"])
        params["layers"].append(layer)
    return params
