"""Pallas kernel: row-wise residual binarization (STBLLM Eq. 4).

Used on the salient-weight path. Each grid step owns a block of full rows
(the alpha reductions are row-wise, so rows never split across tiles); the
whole row fits VMEM for every config in this repo (K <= 1024 f32 = 4 KiB/row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _residual_kernel(w_ref, o_ref):
    w = w_ref[...]
    sgn = lambda t: jnp.where(t >= 0, 1.0, -1.0)
    a_o = jnp.mean(jnp.abs(w), axis=1, keepdims=True)
    b_o = sgn(w)
    r = w - a_o * b_o
    a_r = jnp.mean(jnp.abs(r), axis=1, keepdims=True)
    o_ref[...] = a_o * b_o + a_r * sgn(r)


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm",))
def residual_binarize(w, *, bm: int = 128):
    """Reconstruction alpha_o*sign(w) + alpha_r*sign(residual), row-wise."""
    m, k = w.shape
    bm = _pick_block(m, bm)
    return pl.pallas_call(
        _residual_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.float32),
        interpret=True,
    )(w)
