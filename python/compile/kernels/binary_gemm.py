"""Pallas kernel: N:M structured-binary GEMM (the paper's compute hot-spot).

TPU re-think of the paper's CUDA 2:4 sparse-tensor-core kernel (Appendix C):
on Ampere the win is *skipped MACs*; on TPU there is no sparse MXU, so the
win is *bytes moved* — the structured-binary weights live in HBM at <1 bit
per weight and are expanded to dense ±alpha tiles **in VMEM** right before
hitting the MXU. The BlockSpec below expresses exactly that HBM→VMEM
schedule: activations and weight tiles are streamed block-by-block; the
per-channel scale is fused into the epilogue so no dequantized weight tensor
ever exists in HBM.

Two variants:
  * ``nm_binary_gemm``          — y = x @ (alpha ⊙ sb)^T, K-tiled with a VMEM
                                  accumulator (the production schedule).
  * ``nm_binary_gemm_smallk``   — whole-K blocks, no accumulator; used when K
                                  fits VMEM alongside the tiles (our configs).

``interpret=True`` always: the CPU PJRT client cannot execute Mosaic
custom-calls. Real-TPU performance is estimated analytically in
EXPERIMENTS.md §Perf from the VMEM footprint and MXU utilization of these
block shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel_smallk(x_ref, sb_ref, alpha_ref, o_ref):
    """Whole-K tile: o[bm, bn] = x[bm, K] @ sb[bn, K]^T * alpha[bn]."""
    acc = jnp.dot(x_ref[...], sb_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = acc * alpha_ref[...][None, :]


def _gemm_kernel_ktiled(x_ref, sb_ref, alpha_ref, o_ref, *, nk: int):
    """K-tiled accumulation. Grid = (M/bm, N/bn, K/bk); o_ref is revisited
    across the K dimension (innermost), so it doubles as the accumulator —
    the standard Pallas matmul reduction schedule."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], sb_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = o_ref[...] * alpha_ref[...][None, :]


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (VMEM-friendly tiles)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def nm_binary_gemm(x, sb, alpha, *, bm: int = 128, bn: int = 128, bk: int = 256):
    """y = x @ (alpha ⊙ sb)^T with sb ∈ {-1,0,+1}^(N,K), alpha ∈ R^N.

    Block sizes are clamped to divisors of the problem dims; K is tiled when
    it exceeds ``bk`` (VMEM budget), otherwise the small-K schedule is used.
    """
    m, k = x.shape
    n, k2 = sb.shape
    assert k == k2, f"K mismatch {k} vs {k2}"
    assert alpha.shape == (n,)
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    if k <= bk:
        grid = (m // bm, n // bn)
        return pl.pallas_call(
            _gemm_kernel_smallk,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                pl.BlockSpec((bn, k), lambda i, j: (j, 0)),
                pl.BlockSpec((bn,), lambda i, j: (j,)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
            interpret=True,
        )(x, sb, alpha)
    bk = _pick_block(k, bk)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_gemm_kernel_ktiled, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, sb, alpha)


def nm_binary_gemm_residual(x, sb_o, alpha_o, sb_r, alpha_r, **kw):
    """Residual-approximated GEMM: two structured-binary passes summed.

    The salient-column path of STBLLM (Eq. 4): W ≈ α_o B_o + α_r B_r. Each
    pass reuses the same VMEM schedule; on real hardware the second pass hits
    activations already resident in VMEM.
    """
    return nm_binary_gemm(x, sb_o, alpha_o, **kw) + nm_binary_gemm(
        x, sb_r, alpha_r, **kw
    )


def vmem_footprint_bytes(bm: int, bn: int, bk: int) -> int:
    """Analytic VMEM bytes for one grid step of the K-tiled schedule:
    x tile + sb tile + alpha + output/accumulator tile (all f32 in interpret;
    bf16 x + int8 sb on real TPU would halve/quarter this)."""
    return 4 * (bm * bk + bn * bk + bn + bm * bn)
