"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness references: every Pallas kernel in this
package is asserted allclose against the function of the same name here
(``python/tests/test_kernel.py``), and the Rust-side packed GEMM asserts
against the same semantics (``rust/src/packed/gemm.rs`` unit tests mirror
these formulas).
"""

from __future__ import annotations

import jax.numpy as jnp


def nm_binary_gemm_ref(x, sb, alpha):
    """y = x @ (alpha ⊙ sb)^T.

    Args:
      x:     (B, K) f32 activations.
      sb:    (N, K) f32 structured-binary weights: entries in {-1, 0, +1}
             (sign ⊙ N:M mask — zeros are the pruned positions).
      alpha: (N,) f32 per-output-channel scale.
    Returns:
      (B, N) f32.
    """
    return (x @ sb.T) * alpha[None, :]


def nm_binary_gemm_residual_ref(x, sb_o, alpha_o, sb_r, alpha_r):
    """Residual-approximated binary GEMM (Eq. 4 applied inside the matmul):
    y = x @ (alpha_o ⊙ sb_o + alpha_r ⊙ sb_r)^T.
    """
    w = alpha_o[:, None] * sb_o + alpha_r[:, None] * sb_r
    return x @ w.T


def residual_binarize_ref(w):
    """Two-stage residual binarization of a weight tile (Eq. 4).

    Row-wise: alpha_o = mean(|w|) per row, B_o = sign(w);
    residual r = w - alpha_o B_o; alpha_r = mean(|r|), B_r = sign(r).
    Returns the reconstruction alpha_o*B_o + alpha_r*B_r.

    sign(0) := +1 to match the paper's Eq. 2 and the Rust implementation.
    """
    sgn = lambda t: jnp.where(t >= 0, 1.0, -1.0)
    a_o = jnp.mean(jnp.abs(w), axis=1, keepdims=True)
    b_o = sgn(w)
    r = w - a_o * b_o
    a_r = jnp.mean(jnp.abs(r), axis=1, keepdims=True)
    b_r = sgn(r)
    return a_o * b_o + a_r * b_r
