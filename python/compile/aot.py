"""AOT driver: train the tiny model zoo, lower the compute graphs, emit artifacts.

Runs ONCE at build time (``make artifacts``); Python is never on the request
path. Everything the Rust runtime needs lands in ``artifacts/``:

  artifacts/manifest.json            model configs + artifact index + loss curves
  artifacts/weights/<preset>.bin     trained FP32 weights (custom STBW format)
  artifacts/<entry>.hlo.txt          HLO *text* modules for the PJRT runtime

HLO text — NOT ``.serialize()`` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which xla_extension 0.5.1 (the
version the Rust ``xla`` crate binds) rejects. The text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Lowered entry points:
  layer_fwd_<preset>      one transformer block, dense FP weights as params.
                          Rust loops this over layers for PPL eval; the same
                          artifact serves *every* quantization method because
                          a quantized layer is fed as its dense reconstruction.
  layer_fwd_bin_<preset>  the structured-binary block: every projection runs
                          through the L1 Pallas kernel (llama presets only;
                          demonstrates the full three-layer composition).
  lm_head_<preset>        final RMSNorm + tied-embedding logits.
  nm_binary_gemm_MxKxN    standalone Pallas kernel at benchmark shapes.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import (
    PRESETS, ModelConfig, layer_fwd, binary_layer_fwd, lm_head, config_manifest,
)
from compile import train as trainlib

GEMM_SHAPES = [(128, 128, 128), (128, 256, 704), (256, 320, 864)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(True)  # True: print large constants (RoPE tables); default elides them as {...}


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_layer_fwd(cfg: ModelConfig) -> str:
    d, s = cfg.dim, cfg.seq_len
    names = cfg.layer_weight_names()

    def fn(x, ln1, ln2, *weights):
        layer = {"ln1": ln1, "ln2": ln2, **dict(zip(names, weights))}
        return (layer_fwd(cfg, x, layer),)

    specs = [_spec(s, d), _spec(d), _spec(d)] + [
        _spec(*cfg.layer_weight_shape(n)) for n in names
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_layer_fwd_bin(cfg: ModelConfig) -> str:
    assert cfg.family in ("llama", "mistral")
    d, s = cfg.dim, cfg.seq_len
    names = cfg.layer_weight_names()

    def fn(x, ln1, ln2, *packed):
        sbs = dict(zip(names, packed[: len(names)]))
        alphas = dict(zip(names, packed[len(names):]))
        return (binary_layer_fwd(cfg, x, sbs, alphas, {"ln1": ln1, "ln2": ln2}),)

    specs = [_spec(s, d), _spec(d), _spec(d)]
    specs += [_spec(*cfg.layer_weight_shape(n)) for n in names]
    specs += [_spec(cfg.layer_weight_shape(n)[0]) for n in names]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_lm_head(cfg: ModelConfig) -> str:
    def fn(x, ln_f, embed):
        return (lm_head(cfg, x, ln_f, embed),)

    specs = [_spec(cfg.seq_len, cfg.dim), _spec(cfg.dim), _spec(cfg.vocab, cfg.dim)]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_gemm(m: int, k: int, n: int) -> str:
    from compile.kernels.binary_gemm import nm_binary_gemm

    def fn(x, sb, alpha):
        return (nm_binary_gemm(x, sb, alpha),)

    return to_hlo_text(jax.jit(fn).lower(_spec(m, k), _spec(n, k), _spec(n)))


def main() -> None:
    ap = argparse.ArgumentParser(description="STBLLM AOT artifact builder")
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("STBLLM_STEPS", "450")))
    ap.add_argument("--models", default="all", help="comma list of presets or 'all'")
    ap.add_argument("--force", action="store_true", help="retrain even if weights exist")
    args = ap.parse_args()

    out = os.path.abspath(args.out_dir)
    os.makedirs(os.path.join(out, "weights"), exist_ok=True)
    wanted = list(PRESETS) if args.models == "all" else args.models.split(",")

    manifest = {"models": {}, "kernels": [], "head_dim": 32, "steps": args.steps}
    mpath = os.path.join(out, "manifest.json")
    if os.path.exists(mpath):
        with open(mpath) as f:
            try:
                manifest.update(json.load(f))
            except json.JSONDecodeError:
                pass

    for name in wanted:
        cfg = PRESETS[name]
        wfile = os.path.join(out, "weights", f"{name}.bin")
        entry = manifest["models"].get(name, {})
        if args.force or not os.path.exists(wfile):
            print(f"training {name} ({cfg.n_params():,} params)")
            params, curve = trainlib.train_model(cfg, steps=args.steps)
            trainlib.save_weights(cfg, params, wfile)
            entry["loss_curve"] = curve
        entry.update(config_manifest(cfg))
        entry["weights"] = f"weights/{name}.bin"

        hfile = os.path.join(out, f"layer_fwd_{name}.hlo.txt")
        if args.force or not os.path.exists(hfile):
            print(f"lowering layer_fwd_{name}")
            with open(hfile, "w") as f:
                f.write(lower_layer_fwd(cfg))
        entry["layer_fwd"] = f"layer_fwd_{name}.hlo.txt"

        hfile = os.path.join(out, f"lm_head_{name}.hlo.txt")
        if args.force or not os.path.exists(hfile):
            print(f"lowering lm_head_{name}")
            with open(hfile, "w") as f:
                f.write(lower_lm_head(cfg))
        entry["lm_head"] = f"lm_head_{name}.hlo.txt"

        if cfg.family in ("llama", "mistral") and name in ("llama1-7b", "llama1-30b"):
            hfile = os.path.join(out, f"layer_fwd_bin_{name}.hlo.txt")
            if args.force or not os.path.exists(hfile):
                print(f"lowering layer_fwd_bin_{name} (Pallas kernel path)")
                with open(hfile, "w") as f:
                    f.write(lower_layer_fwd_bin(cfg))
            entry["layer_fwd_bin"] = f"layer_fwd_bin_{name}.hlo.txt"

        manifest["models"][name] = entry

    manifest["kernels"] = []
    for (m, k, n) in GEMM_SHAPES:
        kname = f"nm_binary_gemm_{m}x{k}x{n}"
        hfile = os.path.join(out, f"{kname}.hlo.txt")
        if args.force or not os.path.exists(hfile):
            print(f"lowering {kname}")
            with open(hfile, "w") as f:
                f.write(lower_gemm(m, k, n))
        manifest["kernels"].append({"name": kname, "m": m, "k": k, "n": n,
                                    "file": f"{kname}.hlo.txt"})

    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {mpath} ({len(manifest['models'])} models)")


if __name__ == "__main__":
    main()
