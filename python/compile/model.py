"""L2: JAX model zoo — tiny LLaMA / OPT / Mistral-style transformers.

This is the build-time model definition. It is the single source of truth
for model configurations (the Rust side reads ``artifacts/manifest.json``
emitted by ``aot.py``), the training forward pass (``train.py``), and the
AOT-lowered per-layer forward / LM-head computations executed by the Rust
runtime through PJRT.

Architecture families (scaled-down analogues of the paper's model zoo):
  * ``llama``   — RMSNorm, RoPE, SwiGLU FFN, no biases  (LLaMA-1/2/3 stand-in)
  * ``opt``     — learned positional embeddings, GELU FFN (OPT stand-in)
  * ``mistral`` — llama arch + sliding-window causal attention (Mistral stand-in)

All linear weights are stored (out, in); y = x @ W^T. Only these 2-D
matrices are quantized by STBLLM (norms/embeddings stay FP, as in the paper,
which binarizes the FFN + MHSA projection weights).

The *binary* layer forward routes every projection through the Pallas
``nm_binary_gemm`` kernel so that the lowered HLO contains the L1 kernel —
the three-layer composition the Rust integration tests verify.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import numpy as np
import jax
import jax.numpy as jnp

from compile.kernels.binary_gemm import nm_binary_gemm

HEAD_DIM = 32
ROPE_THETA = 10000.0


@dataclass(frozen=True)
class ModelConfig:
    name: str        # preset name, e.g. "llama1-7b" (paper-scale label)
    family: str      # llama | opt | mistral
    dim: int
    n_layers: int
    ffn_hidden: int
    vocab: int = 256
    seq_len: int = 128
    window: int = 0      # sliding-window size (mistral); 0 = full causal
    norm_eps: float = 1e-5
    seed: int = 0

    @property
    def n_heads(self) -> int:
        return self.dim // HEAD_DIM

    def layer_weight_names(self) -> list[str]:
        """2-D quantizable matrices, in canonical order."""
        if self.family == "opt":
            return ["wq", "wk", "wv", "wo", "w1", "w2"]
        return ["wq", "wk", "wv", "wo", "w1", "w2", "w3"]

    def layer_weight_shape(self, name: str) -> tuple[int, int]:
        d, h = self.dim, self.ffn_hidden
        return {
            "wq": (d, d), "wk": (d, d), "wv": (d, d), "wo": (d, d),
            "w1": (h, d), "w2": (d, h), "w3": (h, d),
        }[name]

    def n_params(self) -> int:
        per_layer = sum(
            a * b for a, b in (self.layer_weight_shape(n) for n in self.layer_weight_names())
        ) + 2 * self.dim
        extra = self.vocab * self.dim + self.dim  # embedding + final norm
        if self.family == "opt":
            extra += self.seq_len * self.dim  # learned positions
        return per_layer * self.n_layers + extra


# Paper model zoo → tiny analogues. Larger paper models map to wider/deeper
# tiny models so size-dependent trends (Tables 2-4) are exercised.
PRESETS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        ModelConfig("llama1-7b", "llama", 128, 4, 352, seed=101),
        ModelConfig("llama1-13b", "llama", 192, 6, 512, seed=102),
        ModelConfig("llama1-30b", "llama", 256, 8, 704, seed=103),
        ModelConfig("llama1-65b", "llama", 320, 10, 864, seed=104),
        ModelConfig("llama2-7b", "llama", 128, 4, 384, seed=201),
        ModelConfig("llama2-13b", "llama", 192, 6, 544, seed=202),
        ModelConfig("llama3-8b", "llama", 160, 5, 448, seed=301),
        ModelConfig("opt-1.3b", "opt", 128, 4, 512, seed=401),
        ModelConfig("opt-2.7b", "opt", 160, 5, 640, seed=402),
        ModelConfig("opt-6.7b", "opt", 192, 6, 768, seed=403),
        ModelConfig("opt-30b", "opt", 256, 8, 1024, seed=404),
        ModelConfig("mistral-7b", "mistral", 192, 6, 512, window=64, seed=501),
    ]
}


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig) -> dict:
    """Scaled-normal init (GPT-2 style 1/sqrt(dim) with depth scaling)."""
    rng = np.random.default_rng(cfg.seed)
    d = cfg.dim

    def mat(shape, scale):
        return jnp.asarray(rng.normal(0.0, scale, size=shape).astype(np.float32))

    params: dict = {
        "embed": mat((cfg.vocab, d), 0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    if cfg.family == "opt":
        params["pos"] = mat((cfg.seq_len, d), 0.02)
    proj_scale = 1.0 / np.sqrt(d)
    out_scale = proj_scale / np.sqrt(2.0 * cfg.n_layers)
    for _ in range(cfg.n_layers):
        layer = {"ln1": jnp.ones((d,), jnp.float32), "ln2": jnp.ones((d,), jnp.float32)}
        for nme in cfg.layer_weight_names():
            shape = cfg.layer_weight_shape(nme)
            scale = out_scale if nme in ("wo", "w2") else proj_scale
            layer[nme] = mat(shape, scale)
        params["layers"].append(layer)
    return params


# ---------------------------------------------------------------------------
# Forward pass building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * w


def rope_tables(seq_len: int):
    """cos/sin tables, shape (seq, HEAD_DIM/2).

    Computed in numpy at trace time so they lower as CONSTANTS. This is both
    the right schedule (no per-call trig) and a necessary workaround: the
    xla_extension 0.5.1 runtime behind the Rust `xla` crate miscompiles the
    power(theta, iota) frequency chain (all frequencies collapse to the
    first — verified by probe, see EXPERIMENTS.md §Perf L2).
    """
    pos = np.arange(seq_len, dtype=np.float32)[:, None]
    inv = 1.0 / (ROPE_THETA ** (np.arange(0, HEAD_DIM, 2, dtype=np.float32) / HEAD_DIM))
    ang = pos * inv[None, :]
    return jnp.asarray(np.cos(ang)), jnp.asarray(np.sin(ang))


def apply_rope(q, cos, sin):
    """q: (S, H, HEAD_DIM); split-half rotation (matches Rust model/rope.rs)."""
    h = HEAD_DIM // 2
    q1, q2 = q[..., :h], q[..., h:]
    c, s = cos[:, None, :], sin[:, None, :]
    return jnp.concatenate([q1 * c - q2 * s, q1 * s + q2 * c], axis=-1)


def causal_mask(seq: int, window: int):
    i = jnp.arange(seq)[:, None]
    j = jnp.arange(seq)[None, :]
    m = j <= i
    if window > 0:
        m = m & (j > i - window)
    return jnp.where(m, 0.0, -1e9).astype(jnp.float32)


def _attention(cfg: ModelConfig, x, q_w, k_w, v_w, o_w, matmul):
    """matmul(x, w) computes x @ w^T — swapped for the binary path."""
    s, d = x.shape
    nh = cfg.n_heads
    q = matmul(x, "wq", q_w).reshape(s, nh, HEAD_DIM)
    k = matmul(x, "wk", k_w).reshape(s, nh, HEAD_DIM)
    v = matmul(x, "wv", v_w).reshape(s, nh, HEAD_DIM)
    if cfg.family != "opt":
        cos, sin = rope_tables(s)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    att = jnp.einsum("shd,thd->hst", q, k) / np.sqrt(HEAD_DIM)
    att = att + causal_mask(s, cfg.window)[None, :, :]
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("hst,thd->shd", att, v).reshape(s, d)
    return matmul(out, "wo", o_w)


def _ffn(cfg: ModelConfig, x, layer, matmul):
    if cfg.family == "opt":
        h = jax.nn.gelu(matmul(x, "w1", layer["w1"]))
        return matmul(h, "w2", layer["w2"])
    g = jax.nn.silu(matmul(x, "w1", layer["w1"]))
    u = matmul(x, "w3", layer["w3"])
    return matmul(g * u, "w2", layer["w2"])


def layer_fwd(cfg: ModelConfig, x, layer, matmul=None):
    """One pre-norm transformer block over x: (S, dim)."""
    if matmul is None:
        matmul = lambda t, _n, w: t @ w.T
    h = x + _attention(
        cfg, rmsnorm(x, layer["ln1"], cfg.norm_eps),
        layer["wq"], layer["wk"], layer["wv"], layer["wo"], matmul,
    )
    return h + _ffn(cfg, rmsnorm(h, layer["ln2"], cfg.norm_eps), layer, matmul)


def binary_layer_fwd(cfg: ModelConfig, x, layer_sb, layer_alpha, norms):
    """Layer forward with every projection running through the Pallas
    structured-binary GEMM. ``layer_sb[name]`` ∈ {-1,0,+1}^(out,in),
    ``layer_alpha[name]`` ∈ R^out, ``norms`` = {"ln1", "ln2"}."""
    matmul = lambda t, n, _w: nm_binary_gemm(t, layer_sb[n], layer_alpha[n])
    layer = dict(layer_sb)  # names only; values routed via matmul closure
    layer["ln1"], layer["ln2"] = norms["ln1"], norms["ln2"]
    return layer_fwd(cfg, x, layer, matmul)


def lm_head(cfg: ModelConfig, x, ln_f, embed):
    """Final norm + tied-embedding projection to logits."""
    return rmsnorm(x, ln_f, cfg.norm_eps) @ embed.T


def model_fwd(cfg: ModelConfig, params: dict, tokens):
    """tokens: (S,) int32 → logits (S, vocab)."""
    x = params["embed"][tokens]
    if cfg.family == "opt":
        x = x + params["pos"][: tokens.shape[0]]
    for layer in params["layers"]:
        x = layer_fwd(cfg, x, layer)
    return lm_head(cfg, x, params["ln_f"], params["embed"])


def next_token_loss(cfg: ModelConfig, params: dict, tokens):
    """Mean cross-entropy of next-token prediction over a (B, S) batch."""
    def one(seq):
        logits = model_fwd(cfg, params, seq[:-1])
        lp = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.mean(jnp.take_along_axis(lp, seq[1:, None], axis=-1))
    return jnp.mean(jax.vmap(one)(tokens))


def config_manifest(cfg: ModelConfig) -> dict:
    d = asdict(cfg)
    d["n_heads"] = cfg.n_heads
    d["head_dim"] = HEAD_DIM
    d["rope_theta"] = ROPE_THETA
    d["layer_weights"] = {
        n: list(cfg.layer_weight_shape(n)) for n in cfg.layer_weight_names()
    }
    d["n_params"] = cfg.n_params()
    return d
