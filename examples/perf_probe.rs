//! Perf probe — the §Perf measurement harness (EXPERIMENTS.md).
//!
//! Times the L3 hot-path kernels against their reference implementations in
//! the same process/run, so machine contention cancels out of the ratios:
//!   * matmul_bt (4-way unrolled) vs matmul_bt_naive (row-dot)
//!   * packed 2:4 1-bit GEMM vs dense 2-bit GEMM vs f32
//!   * end-to-end decode step (serving hot path)
//!
//! Run: `cargo run --release --example perf_probe`

use stbllm::engine::{Backend, NativeBackend, PackedBackend};
use stbllm::model::config::ModelConfig;
use stbllm::model::ModelWeights;
use stbllm::packed::{
    enforce_24, gemm_2bit, gemm_f32, packed_gemm, packed_gemm_onthefly, packed_gemv, Dense2Bit,
    Packed24,
};
use stbllm::tensor::{matmul_bt, matmul_bt_naive, Mat};
use stbllm::util::rng::Pcg32;
use stbllm::util::timer::BenchStats;

fn main() {
    let mut rng = Pcg32::seeded(1);
    println!("== perf probe (ratios are contention-invariant) ==");

    // --- matmul_bt: the native-forward hot loop -------------------------
    println!("\n[matmul_bt] C = A(BxK) @ W(NxK)^T");
    for (m, k, n) in [(128usize, 256usize, 704usize), (128, 704, 256), (1, 256, 704)] {
        let a = Mat::random(m, k, 1.0, &mut rng);
        let b = Mat::random(n, k, 1.0, &mut rng);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let t_opt = BenchStats::measure(1, 7, || {
            std::hint::black_box(matmul_bt(&a, &b));
        });
        let t_ref = BenchStats::measure(1, 7, || {
            std::hint::black_box(matmul_bt_naive(&a, &b));
        });
        println!(
            "  {m}x{k}x{n}: opt {:.2} GFLOP/s vs naive {:.2} GFLOP/s — {:.2}x",
            flops / t_opt.min_s() / 1e9,
            flops / t_ref.min_s() / 1e9,
            t_ref.min_s() / t_opt.min_s()
        );
    }

    // --- packed GEMM family ---------------------------------------------
    println!("\n[packed gemm] y = x(SxK) @ W(NxK)^T, N=864 K=320");
    let (n, k) = (864usize, 320usize);
    let w = Mat::random(n, k, 0.05, &mut rng);
    let (sb, alpha) = enforce_24(&w);
    let packed = Packed24::pack(&sb, &alpha).unwrap();
    let two = Dense2Bit::quantize(&w);
    for s in [8usize, 128, 1024] {
        let x = Mat::random(s, k, 1.0, &mut rng);
        let flops = 2.0 * s as f64 * n as f64 * k as f64;
        let t_f = BenchStats::measure(1, 5, || {
            std::hint::black_box(gemm_f32(&x, &w));
        });
        let t_2 = BenchStats::measure(1, 5, || {
            std::hint::black_box(gemm_2bit(&x, &two));
        });
        let t_p = BenchStats::measure(1, 5, || {
            std::hint::black_box(packed_gemm(&x, &packed));
        });
        let t_v1 = BenchStats::measure(1, 5, || {
            std::hint::black_box(packed_gemm_onthefly(&x, &packed));
        });
        println!(
            "  seq {s}: ours {:.2} GFLOP/s-eq | vs v1 {:.2}x | vs 2bit {:.2}x | vs f32 {:.2}x",
            flops / t_p.min_s() / 1e9,
            t_v1.min_s() / t_p.min_s(),
            t_2.min_s() / t_p.min_s(),
            t_f.min_s() / t_p.min_s()
        );
    }

    // --- packed gemv (decode-path kernel) --------------------------------
    println!("\n[packed gemv] y = W(NxK) @ x, N=864 K=320 (single token)");
    {
        let xv: Vec<f32> = (0..k).map(|i| (i as f32 * 0.37).sin()).collect();
        let flops = 2.0 * n as f64 * k as f64;
        let t_gv = BenchStats::measure(4, 9, || {
            std::hint::black_box(packed_gemv(&packed, &xv));
        });
        let xm = Mat::from_vec(1, k, xv.clone());
        let t_gm = BenchStats::measure(4, 9, || {
            std::hint::black_box(packed_gemm(&xm, &packed));
        });
        println!(
            "  gemv {:.2} GFLOP/s-eq | vs 1-row gemm {:.2}x",
            flops / t_gv.min_s() / 1e9,
            t_gm.min_s() / t_gv.min_s()
        );
    }

    // --- decode step (serving hot path) through the Backend seam ----------
    println!("\n[decode] single-token step, llama1-7b synthetic weights");
    let cfg = ModelConfig::preset("llama1-7b").unwrap();
    let weights = ModelWeights::synthetic(&cfg, 2);
    let native = NativeBackend::borrowed(&cfg, &weights);
    let packed_be = PackedBackend::from_weights(&cfg, &weights).expect("packable");
    for (name, be) in [("native", &native as &dyn Backend), ("packed", &packed_be as &dyn Backend)] {
        let t = BenchStats::measure(2, 5, || {
            let mut sess = be.begin_decode(64).expect("decode session");
            for i in 0..32u8 {
                std::hint::black_box(sess.step(i % 7).expect("step"));
            }
        });
        println!(
            "  32-token decode [{name}]: {:.1} ms ({:.1} tok/s single-stream)",
            t.min_s() * 1e3,
            32.0 / t.min_s()
        );
    }
}
