//! Perf probe — the §Perf measurement harness (EXPERIMENTS.md).
//!
//! Times the L3 hot-path kernels against their reference implementations in
//! the same process/run, so machine contention cancels out of the ratios:
//!   * matmul_bt (4-way unrolled) vs matmul_bt_naive (row-dot)
//!   * packed 2:4 kernel lineage: v3 LUT vs v2 scratch vs v1 on-the-fly,
//!     vs dense 2-bit and f32
//!   * end-to-end decode step (serving hot path), per-session vs the fused
//!     cross-session `decode_batch` tick
//!
//! Run: `cargo run --release --example perf_probe`
//! (the full suite with `BENCH_kernels.json` output is
//!  `cargo run --release -- bench-kernels`)

use stbllm::engine::{Backend, DecodeSession, NativeBackend, PackedBackend};
use stbllm::model::config::ModelConfig;
use stbllm::model::ModelWeights;
use stbllm::packed::{
    enforce_24, gemm_2bit, gemm_f32, packed_gemm, packed_gemm_onthefly, packed_gemm_scratch,
    packed_gemv, packed_gemv_onthefly, Dense2Bit, Packed24,
};
use stbllm::tensor::{matmul_bt, matmul_bt_naive, Mat};
use stbllm::util::rng::Pcg32;
use stbllm::util::timer::BenchStats;

fn main() {
    let mut rng = Pcg32::seeded(1);
    println!("== perf probe (ratios are contention-invariant) ==");

    // --- matmul_bt: the native-forward hot loop -------------------------
    println!("\n[matmul_bt] C = A(BxK) @ W(NxK)^T");
    for (m, k, n) in [(128usize, 256usize, 704usize), (128, 704, 256), (1, 256, 704)] {
        let a = Mat::random(m, k, 1.0, &mut rng);
        let b = Mat::random(n, k, 1.0, &mut rng);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let t_opt = BenchStats::measure(1, 7, || {
            std::hint::black_box(matmul_bt(&a, &b));
        });
        let t_ref = BenchStats::measure(1, 7, || {
            std::hint::black_box(matmul_bt_naive(&a, &b));
        });
        println!(
            "  {m}x{k}x{n}: opt {:.2} GFLOP/s vs naive {:.2} GFLOP/s — {:.2}x",
            flops / t_opt.min_s() / 1e9,
            flops / t_ref.min_s() / 1e9,
            t_ref.min_s() / t_opt.min_s()
        );
    }

    // --- packed GEMM lineage (v3 LUT vs v2 scratch vs v1) ----------------
    println!("\n[packed gemm] y = x(SxK) @ W(NxK)^T, N=864 K=320");
    let (n, k) = (864usize, 320usize);
    let w = Mat::random(n, k, 0.05, &mut rng);
    let (sb, alpha) = enforce_24(&w);
    let packed = Packed24::pack(&sb, &alpha).unwrap();
    let two = Dense2Bit::quantize(&w);
    for s in [8usize, 128, 1024] {
        let x = Mat::random(s, k, 1.0, &mut rng);
        let flops = 2.0 * s as f64 * n as f64 * k as f64;
        let t_f = BenchStats::measure(1, 5, || {
            std::hint::black_box(gemm_f32(&x, &w));
        });
        let t_2 = BenchStats::measure(1, 5, || {
            std::hint::black_box(gemm_2bit(&x, &two));
        });
        let t_v3 = BenchStats::measure(1, 5, || {
            std::hint::black_box(packed_gemm(&x, &packed));
        });
        let t_v2 = BenchStats::measure(1, 5, || {
            std::hint::black_box(packed_gemm_scratch(&x, &packed));
        });
        let t_v1 = BenchStats::measure(1, 5, || {
            std::hint::black_box(packed_gemm_onthefly(&x, &packed));
        });
        println!(
            "  seq {s}: v3 {:.2} GFLOP/s-eq | vs v2 {:.2}x | vs v1 {:.2}x | vs 2bit {:.2}x | vs f32 {:.2}x",
            flops / t_v3.min_s() / 1e9,
            t_v2.min_s() / t_v3.min_s(),
            t_v1.min_s() / t_v3.min_s(),
            t_2.min_s() / t_v3.min_s(),
            t_f.min_s() / t_v3.min_s()
        );
    }

    // --- packed gemv (decode-path kernel): v2 LUT vs v1 ------------------
    println!("\n[packed gemv] y = W(NxK) @ x, N=864 K=320 (single token)");
    {
        let xv: Vec<f32> = (0..k).map(|i| (i as f32 * 0.37).sin()).collect();
        let flops = 2.0 * n as f64 * k as f64;
        let t_v2 = BenchStats::measure(4, 9, || {
            std::hint::black_box(packed_gemv(&packed, &xv));
        });
        let t_v1 = BenchStats::measure(4, 9, || {
            std::hint::black_box(packed_gemv_onthefly(&packed, &xv));
        });
        println!(
            "  gemv v2 {:.2} GFLOP/s-eq | vs v1 {:.2}x",
            flops / t_v2.min_s() / 1e9,
            t_v1.min_s() / t_v2.min_s()
        );
    }

    // --- decode step (serving hot path) through the Backend seam ----------
    println!("\n[decode] single-token step, llama1-7b synthetic weights");
    let cfg = ModelConfig::preset("llama1-7b").unwrap();
    let weights = ModelWeights::synthetic(&cfg, 2);
    let native = NativeBackend::borrowed(&cfg, &weights);
    let packed_be = PackedBackend::from_weights(&cfg, &weights).expect("packable");
    for (name, be) in [("native", &native as &dyn Backend), ("packed", &packed_be as &dyn Backend)]
    {
        let t = BenchStats::measure(2, 5, || {
            let mut sess = be.begin_decode(64).expect("decode session");
            for i in 0..32u8 {
                std::hint::black_box(sess.step(i % 7).expect("step"));
            }
        });
        println!(
            "  32-token decode [{name}]: {:.1} ms ({:.1} tok/s single-stream)",
            t.min_s() * 1e3,
            32.0 / t.min_s()
        );
    }

    // --- fused cross-session tick vs per-session stepping ------------------
    println!("\n[fused decode] 4 sessions x 32 ticks, packed backend");
    let batch = 4usize;
    let ticks = 32usize;
    let t_solo = BenchStats::measure(1, 5, || {
        let mut sessions: Vec<_> =
            (0..batch).map(|_| packed_be.begin_decode(ticks + 1).expect("session")).collect();
        for t in 0..ticks {
            for sess in &mut sessions {
                std::hint::black_box(sess.step((t % 7) as u8).expect("step"));
            }
        }
    });
    let t_fused = BenchStats::measure(1, 5, || {
        let mut sessions: Vec<_> =
            (0..batch).map(|_| packed_be.begin_decode(ticks + 1).expect("session")).collect();
        for t in 0..ticks {
            let toks = vec![(t % 7) as u8; batch];
            let mut refs: Vec<&mut (dyn DecodeSession + '_)> =
                sessions.iter_mut().map(|sess| sess.as_mut()).collect();
            std::hint::black_box(packed_be.decode_batch(&mut refs, &toks).expect("fused tick"));
        }
    });
    let toks_total = (batch * ticks) as f64;
    println!(
        "  per-session {:.1} tok/s | fused {:.1} tok/s — {:.2}x",
        toks_total / t_solo.min_s(),
        toks_total / t_fused.min_s(),
        t_solo.min_s() / t_fused.min_s()
    );
}
