//! Three-layer composition demo: the SAME structured-binary GEMM computed by
//!   (a) the L1 Pallas kernel, AOT-lowered to HLO and executed via PJRT,
//!   (b) the L3 packed-bit CPU simulator (`packed::packed_gemm`),
//!   (c) the dense f32 reference,
//! asserting all agree — the cross-layer correctness triangle. When the
//! PJRT runtime is unavailable (crate built without the `pjrt` feature),
//! the demo degrades to the (b) ⇄ (c) pair with a notice.
//!
//! Run: `cargo run --release --example pallas_kernel_demo`

use stbllm::packed::{enforce_24, gemm_f32, packed_gemm, Packed24};
use stbllm::runtime::client::MatArg;
use stbllm::runtime::{Artifacts, Runtime};
use stbllm::tensor::Mat;
use stbllm::util::rng::Pcg32;
use stbllm::util::timer::Timer;

fn main() -> anyhow::Result<()> {
    let arts = Artifacts::load_default()?;
    let rt = match Runtime::cpu(&arts.root) {
        Ok(rt) => {
            println!("== pallas_kernel_demo (platform: {}) ==", rt.platform());
            Some(rt)
        }
        Err(e) => {
            println!("== pallas_kernel_demo (PJRT unavailable: {e}) ==");
            println!("   comparing packed simulator vs f32 reference only");
            None
        }
    };

    for ka in &arts.kernels {
        let (m, k, n) = (ka.m, ka.k, ka.n);
        let mut rng = Pcg32::seeded(11);
        let x = Mat::random(m, k, 1.0, &mut rng);
        // a 2:4 structured-binary weight (valid for all three paths)
        let dense = Mat::random(n, k, 0.5, &mut rng);
        let (sb, alpha) = enforce_24(&dense);
        let packed = Packed24::pack(&sb, &alpha).map_err(anyhow::Error::msg)?;

        // (c) dense reference
        let w_eff = packed.unpack();
        let y_ref = gemm_f32(&x, &w_eff);

        let diff = |a: &Mat, b: &Mat| -> f32 {
            a.data.iter().zip(&b.data).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
        };

        // (b) packed-bit simulator
        let t = Timer::start();
        let y_packed = packed_gemm(&x, &packed);
        let t_packed = t.elapsed_ms();
        let d_packed = diff(&y_packed, &y_ref);

        // (a) Pallas kernel through PJRT, when the runtime is up
        if let Some(rt) = &rt {
            let exe = rt.load(&ka.file)?;
            let t = Timer::start();
            let y_pallas = exe.run(&[MatArg::M(&x), MatArg::M(&sb), MatArg::V(&alpha)])?;
            let t_pallas = t.elapsed_ms();
            let d_pallas = diff(&y_pallas, &y_ref);
            println!(
                "{}: pallas(PJRT) {:.2}ms maxerr {:.1e} | packed(rust) {:.2}ms maxerr {:.1e}",
                ka.name, t_pallas, d_pallas, t_packed, d_packed
            );
            assert!(d_pallas < 1e-2, "pallas vs ref diverged");
        } else {
            println!(
                "{}: packed(rust) {:.2}ms maxerr {:.1e} (pallas skipped)",
                ka.name, t_packed, d_packed
            );
        }
        assert!(d_packed < 1e-2, "packed vs ref diverged");
    }
    println!("\nall kernel shapes agree across the available layers ✓");
    Ok(())
}
