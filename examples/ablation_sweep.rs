//! Ablation sweep example: one command that reproduces the paper's §4.4
//! ablation axes on a single model — pruning metric (Table 5), allocation
//! strategy (Table 6), non-salient quantizer (Table 8) and N:M ratio — and
//! prints a combined summary.
//!
//! Run: `cargo run --release --example ablation_sweep [model]`

use stbllm::coordinator::quantizer::{
    stbllm_with_allocation, stbllm_with_metric, stbllm_with_nonsalient, stbllm_with_rearrange,
};
use stbllm::coordinator::{calibrate, quantize_model, Method};
use stbllm::engine::NativeBackend;
use stbllm::eval::perplexity::perplexity;
use stbllm::model::corpus;
use stbllm::quant::{Allocation, Metric, NmRatio, NonSalientMode};
use stbllm::report::{fmt_ppl, Report};
use stbllm::runtime::Artifacts;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "llama1-7b".to_string());
    let arts = Artifacts::load_default()?;
    let cfg = arts.models[&model].config.clone();
    let weights = arts.load_weights(&model)?;
    // calibrate ONCE and reuse across every ablation variant (an Engine per
    // variant would recalibrate; the sweep only varies the method)
    let calib = calibrate(&cfg, &weights, "c4s", 512, 1234);
    let toks = corpus::corpus_tokens("wikitext2s", 1161, 999);
    let mut eval = |method: &Method| -> (f64, f64) {
        let q = quantize_model(&cfg, &weights, method, Some(&calib), 1);
        let be = NativeBackend::borrowed(&cfg, &q.weights);
        (perplexity(&be, &toks).expect("native eval"), q.avg_bits)
    };

    let nm = NmRatio::new(4, 8);
    let mut rep = Report::new(
        &format!("Ablation sweep — {model} (wikitext2s ppl)"),
        &["Axis", "Variant", "bits", "ppl"],
    );

    for metric in [Metric::Magnitude, Metric::Wanda, Metric::SparseGpt, Metric::Si] {
        let (ppl, bits) = eval(&stbllm_with_metric(nm, metric));
        rep.row(vec!["metric".into(), metric.name().into(), format!("{bits:.2}"), fmt_ppl(ppl)]);
    }
    for alloc in [Allocation::Uniform, Allocation::SinShape, Allocation::Ours] {
        let (ppl, bits) = eval(&stbllm_with_allocation(nm, alloc));
        rep.row(vec!["allocation".into(), alloc.name().into(), format!("{bits:.2}"), fmt_ppl(ppl)]);
    }
    for (name, mode) in [
        ("Bell-shaped", NonSalientMode::BellShaped),
        ("Trisection", NonSalientMode::Trisection),
        ("Plain", NonSalientMode::Plain),
    ] {
        let (ppl, bits) = eval(&stbllm_with_nonsalient(nm, mode));
        rep.row(vec!["non-salient".into(), name.into(), format!("{bits:.2}"), fmt_ppl(ppl)]);
    }
    {
        let (ppl, bits) = eval(&stbllm_with_rearrange(nm));
        rep.row(vec!["rearrange".into(), "on".into(), format!("{bits:.2}"), fmt_ppl(ppl)]);
        let (ppl, bits) = eval(&Method::stbllm(nm));
        rep.row(vec!["rearrange".into(), "off".into(), format!("{bits:.2}"), fmt_ppl(ppl)]);
    }
    for n in [2usize, 4, 5, 6] {
        let r = if n == 2 { NmRatio::new(2, 4) } else { NmRatio::new(n, 8) };
        let (ppl, bits) = eval(&Method::stbllm(r));
        rep.row(vec!["N:M".into(), r.label(), format!("{bits:.2}"), fmt_ppl(ppl)]);
    }
    rep.print();
    rep.save(&format!("ablation_sweep_{model}"));
    Ok(())
}
