//! Serving example: quantize a model to 2:4 structured-binary form through
//! the `Engine` facade with the **packed** backend — the decode hot path
//! runs `packed::gemm` kernels directly on the 6-bit/group store, never
//! expanding weights to dense f32 — then serve a batched workload with
//! continuous batching over a **paged KV pool** (admission control, prefix
//! caching, copy-on-write), reporting throughput, latency, TTFT, KV-pool
//! occupancy and the weight-memory footprint (FP32 vs 2:4 packed). Also
//! round-trips the `.stbp` deployment container and serves from the
//! reloaded store.
//!
//! Run: `cargo run --release --example serve_binary [model] [requests]`

use stbllm::coordinator::{BatchServer, Method};
use stbllm::engine::{Backend, BackendKind, Engine, PackedBackend};
use stbllm::packed::PackedModel;
use stbllm::quant::NmRatio;
use stbllm::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "llama1-7b".to_string());
    let n_req: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(6);
    println!("== serve_binary: {model}, {n_req} requests ==");

    // PTQ to the hardware-friendly 2:4 setting, served by the packed backend
    let engine = Engine::builder()
        .model(&model)
        .method(Method::stbllm(NmRatio::new(2, 4)))
        .backend(BackendKind::Packed)
        .calib_corpus("c4s")
        .build()?;
    println!(
        "quantized to 2:4 structured binary ({:.2} bits/weight)",
        engine.quantize().avg_bits
    );

    // pack into the 6-bit/group deployment container, save + reload (.stbp)
    let cfg = engine.cfg().clone();
    let pm = PackedModel::from_weights(&cfg, engine.weights())?;
    let stbp = std::env::temp_dir().join(format!("{model}.stbp"));
    pm.save(&stbp)?;
    let on_disk = std::fs::metadata(&stbp)?.len();
    let fp_bytes: usize = engine
        .weights()
        .layers
        .iter()
        .flat_map(|l| l.mats.values())
        .map(|m| m.data.len() * 4)
        .sum();
    let packed_proj: usize = pm.packed.values().map(|p| p.bytes()).sum();
    println!(
        "packed store: {} on disk ({} projections at {:.2} bits/w; fp32 projections were {} — {:.1}x smaller)",
        fmt_bytes(on_disk),
        fmt_bytes(packed_proj as u64),
        packed_proj as f64 * 8.0 / (fp_bytes as f64 / 4.0),
        fmt_bytes(fp_bytes as u64),
        fp_bytes as f64 / packed_proj as f64
    );
    // the serving process loads the deployment artifact, not FP weights:
    // a PackedBackend built straight from the reloaded .stbp store
    let store = PackedModel::load(&stbp)?;
    std::fs::remove_file(&stbp).ok();
    let backend = PackedBackend::from_store(&cfg, &store)?;
    println!(
        "serving backend: {} ({:.2} bits/weight resident)",
        backend.label(),
        backend.bits_per_weight()
    );

    // batched serving over a paged KV pool: sessions borrow fixed-size
    // pages (16 token slots here) instead of owning flat worst-case
    // buffers, so KV memory — the real capacity limit once weights are
    // sub-1-bit — is admission-controlled and shared
    let prompt_len = 16;
    let max_new = 24;
    let reqs = engine.synthetic_workload(n_req, prompt_len, max_new);
    for batch in [1usize, 4] {
        let server = BatchServer::new(&backend, batch).with_kv_pool(0, 16);
        let (resps, stats) = server.run(reqs.clone())?;
        println!("\nbatch={batch}:");
        println!("  completed    : {}", stats.completed);
        println!("  throughput   : {:.1} tok/s", stats.tokens_per_s());
        println!("  mean latency : {:.1} ms", stats.mean_latency_s * 1e3);
        println!("  p50 latency  : {:.1} ms", stats.p50_latency_s * 1e3);
        println!("  p95 latency  : {:.1} ms", stats.p95_latency_s * 1e3);
        println!("  mean TTFT    : {:.1} ms", stats.mean_ttft_s * 1e3);
        if let Some(kv) = &stats.kv {
            println!(
                "  kv pool      : peak {} / {} pages ({} slots each), {} prefix page hits",
                kv.peak_pages, kv.total_pages, kv.page_size, kv.prefix_hits
            );
        }
        if batch == 4 {
            let sample: String = resps[0].tokens.iter().map(|t| format!("{t} ")).collect();
            println!("  sample generation (token ids): {sample}");
        }
    }
    Ok(())
}
