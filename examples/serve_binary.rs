//! Serving example: quantize a model to 2:4 structured-binary form, pack the
//! weights into the paper's 6-bit/group format, then serve a batched
//! workload with continuous batching — reporting throughput, latency, TTFT
//! and the weight-memory footprint (FP32 vs 2:4 packed).
//!
//! Run: `cargo run --release --example serve_binary [model] [requests]`

use stbllm::coordinator::{calibrate, quantize_model, BatchServer, Method, Request};
use stbllm::model::corpus;
use stbllm::packed::PackedModel;
use stbllm::quant::NmRatio;
use stbllm::runtime::Artifacts;
use stbllm::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "llama1-7b".to_string());
    let n_req: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(6);
    let arts = Artifacts::load_default()?;
    let cfg = arts.models[&model].config.clone();
    let weights = arts.load_weights(&model)?;
    println!("== serve_binary: {model}, {n_req} requests ==");

    // PTQ to the hardware-friendly 2:4 setting
    let calib = calibrate(&cfg, &weights, "c4s", 512, 1234);
    let q = quantize_model(&cfg, &weights, &Method::stbllm(NmRatio::new(2, 4)), Some(&calib), 1);
    println!("quantized to 2:4 structured binary ({:.2} bits/weight)", q.avg_bits);

    // pack into the 6-bit/group deployment container, save + reload (.stbp)
    let pm = PackedModel::from_weights(&cfg, &q.weights)?;
    let stbp = std::env::temp_dir().join(format!("{model}.stbp"));
    pm.save(&stbp)?;
    let on_disk = std::fs::metadata(&stbp)?.len();
    let fp_bytes: usize = q
        .weights
        .layers
        .iter()
        .flat_map(|l| l.mats.values())
        .map(|m| m.data.len() * 4)
        .sum();
    let packed_proj: usize = pm.packed.values().map(|p| p.bytes()).sum();
    println!(
        "packed store: {} on disk ({} projections at {:.2} bits/w; fp32 projections were {} — {:.1}x smaller)",
        fmt_bytes(on_disk),
        fmt_bytes(packed_proj as u64),
        packed_proj as f64 * 8.0 / (fp_bytes as f64 / 4.0),
        fmt_bytes(fp_bytes as u64),
        fp_bytes as f64 / packed_proj as f64
    );
    // the serving process loads the deployment artifact, not FP weights
    let served = PackedModel::load(&stbp)?.to_weights(&cfg)?;
    std::fs::remove_file(&stbp).ok();
    let q = stbllm::coordinator::QuantizedModel {
        weights: served,
        avg_bits: q.avg_bits,
        r_salient: q.r_salient,
        seconds: q.seconds,
        layer_ratios: q.layer_ratios,
    };

    // batched serving: synthetic prompts from the prose corpus
    let prompt_len = 16;
    let max_new = 24;
    let toks = corpus::corpus_tokens("wikitext2s", n_req * prompt_len, 5);
    let reqs: Vec<Request> = (0..n_req)
        .map(|i| Request {
            id: i as u64,
            prompt: toks[i * prompt_len..(i + 1) * prompt_len].to_vec(),
            max_new,
        })
        .collect();

    for batch in [1usize, 4] {
        let server = BatchServer::new(&cfg, &q.weights, batch);
        let (resps, stats) = server.run(reqs.clone());
        println!("\nbatch={batch}:");
        println!("  completed    : {}", stats.completed);
        println!("  throughput   : {:.1} tok/s", stats.tokens_per_s());
        println!("  mean latency : {:.1} ms", stats.mean_latency_s * 1e3);
        println!("  p95 latency  : {:.1} ms", stats.p95_latency_s * 1e3);
        println!("  mean TTFT    : {:.1} ms", stats.mean_ttft_s * 1e3);
        if batch == 4 {
            let sample: String = resps[0].tokens.iter().map(|t| format!("{t} ")).collect();
            println!("  sample generation (token ids): {sample}");
        }
    }
    Ok(())
}
