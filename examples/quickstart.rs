//! Quickstart — the end-to-end driver (DESIGN.md §"End-to-end validation"),
//! now a ~20-line walk through the `Engine` facade:
//!
//!   1. print the build-time training loss curve,
//!   2. build an Engine per method (calibrates on c4s + quantizes at build),
//!      preferring the PJRT AOT backend and falling back to native,
//!   3. compare STBLLM 4:8 (≈0.55 bits) against the BiLLM 4:8 baseline and
//!      full precision on wikitext2s perplexity — the paper's Table 2 shape.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use stbllm::coordinator::Method;
use stbllm::engine::{BackendKind, Engine};
use stbllm::quant::NmRatio;
use stbllm::report::fmt_ppl;
use stbllm::runtime::Artifacts;

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "llama1-7b".to_string());
    let arts = Artifacts::load_default()?;
    let ma = &arts.models[&model];
    println!("== STBLLM quickstart: {model} ({} params) ==", ma.config.n_params());

    // 1. the training loss curve recorded at build time
    if !ma.loss_curve.is_empty() {
        println!("\ntraining loss curve (build-time, python/compile/train.py):");
        for (step, loss) in &ma.loss_curve {
            println!("  step {:>4}: {:.4}", step, loss);
        }
    }

    // 2. one Engine per method; build() = load + calibrate + quantize +
    //    stand the backend up. PJRT preferred; backend_fallback drops to
    //    native (with a warning) WITHOUT repeating the quantize work.
    let mk = |method: Method| -> anyhow::Result<Engine> {
        Ok(Engine::builder()
            .model(&model)
            .method(method)
            .calib_corpus("c4s")
            .backend(BackendKind::Pjrt)
            .backend_fallback(true)
            .build()?)
    };
    let nm = NmRatio::new(4, 8);
    let fp = mk(Method::FullPrecision)?;
    let stb = mk(Method::stbllm(nm))?;
    println!(
        "\nSTBLLM(4:8): {:.3} bits/weight, r_salient {:.3}, {:.1}s",
        stb.quantize().avg_bits,
        stb.quantize().r_salient,
        stb.quantize().seconds
    );
    let billm = mk(Method::BiLlm { nm: Some(nm) })?;
    println!(
        "BiLLM(4:8) : {:.3} bits/weight, {:.1}s",
        billm.quantize().avg_bits,
        billm.quantize().seconds
    );

    // 3. the headline comparison
    let p_fp = fp.perplexity("wikitext2s")?;
    let p_stb = stb.perplexity("wikitext2s")?;
    let p_billm = billm.perplexity("wikitext2s")?;
    println!("\nwikitext2s perplexity ({} backend):", stb.backend().label());
    println!("  FullPrecision (32 bits): {}", fmt_ppl(p_fp));
    println!("  STBLLM 4:8  ({:.2} bits): {}", stb.quantize().avg_bits, fmt_ppl(p_stb));
    println!("  BiLLM  4:8  ({:.2} bits): {}", billm.quantize().avg_bits, fmt_ppl(p_billm));
    println!(
        "\npaper shape check: STBLLM < BiLLM at 0.55 bits — {} ({} vs {})",
        if p_stb < p_billm { "REPRODUCED" } else { "NOT reproduced" },
        fmt_ppl(p_stb),
        fmt_ppl(p_billm),
    );
    Ok(())
}
