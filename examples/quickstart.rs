//! Quickstart — the end-to-end driver (DESIGN.md §"End-to-end validation").
//!
//! On a real (in-repo-trained) tiny LLaMA:
//!   1. print the build-time training loss curve,
//!   2. calibrate on c4s,
//!   3. quantize with STBLLM 4:8 (≈0.55 bits) and the BiLLM 4:8 baseline,
//!   4. evaluate perplexity through the PJRT AOT path (Pallas/JAX HLO
//!      executed from Rust), falling back to the native forward if needed,
//!   5. report the bits/ppl trade-off the paper's Table 2 row shows.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use stbllm::coordinator::{calibrate, quantize_model, Method};
use stbllm::eval::perplexity::{ppl_native, ppl_pjrt};
use stbllm::model::corpus;
use stbllm::quant::NmRatio;
use stbllm::report::fmt_ppl;
use stbllm::runtime::{Artifacts, Runtime};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "llama1-7b".to_string());
    let arts = Artifacts::load_default()?;
    let ma = &arts.models[&model];
    let cfg = ma.config.clone();
    println!("== STBLLM quickstart: {model} ({} params) ==", cfg.n_params());

    // 1. the training loss curve recorded at build time
    if !ma.loss_curve.is_empty() {
        println!("\ntraining loss curve (build-time, python/compile/train.py):");
        for (step, loss) in &ma.loss_curve {
            println!("  step {:>4}: {:.4}", step, loss);
        }
    }

    let weights = arts.load_weights(&model)?;

    // 2. calibration
    println!("\ncalibrating on c4s (512 tokens)...");
    let calib = calibrate(&cfg, &weights, "c4s", 512, 1234);

    // 3. quantize: STBLLM vs BiLLM at the same 4:8 sub-1-bit setting
    let nm = NmRatio::new(4, 8);
    let stb = quantize_model(&cfg, &weights, &Method::stbllm(nm), Some(&calib), 1);
    println!(
        "STBLLM(4:8): {:.3} bits/weight, r_salient {:.3}, {:.1}s",
        stb.avg_bits, stb.r_salient, stb.seconds
    );
    let billm = quantize_model(&cfg, &weights, &Method::BiLlm { nm: Some(nm) }, Some(&calib), 1);
    println!("BiLLM(4:8) : {:.3} bits/weight, {:.1}s", billm.avg_bits, billm.seconds);

    // 4. evaluate through the AOT PJRT path
    let toks = corpus::corpus_tokens("wikitext2s", 1161, 999);
    let rt = Runtime::cpu(&arts.root).ok();
    let ppl = |w: &stbllm::model::ModelWeights| -> f64 {
        if let Some(rt) = &rt {
            if let Ok(p) = ppl_pjrt(rt, &arts, &model, w, &toks) {
                return p;
            }
        }
        ppl_native(&cfg, w, &toks)
    };
    let p_fp = ppl(&weights);
    let p_stb = ppl(&stb.weights);
    let p_billm = ppl(&billm.weights);

    // 5. the headline comparison
    println!("\nwikitext2s perplexity ({}):", if rt.is_some() { "PJRT AOT path" } else { "native path" });
    println!("  FullPrecision (32 bits): {}", fmt_ppl(p_fp));
    println!("  STBLLM 4:8  ({:.2} bits): {}", stb.avg_bits, fmt_ppl(p_stb));
    println!("  BiLLM  4:8  ({:.2} bits): {}", billm.avg_bits, fmt_ppl(p_billm));
    println!(
        "\npaper shape check: STBLLM < BiLLM at 0.55 bits — {} ({})",
        if p_stb < p_billm { "REPRODUCED" } else { "NOT reproduced" },
        format!("{} vs {}", fmt_ppl(p_stb), fmt_ppl(p_billm)),
    );
    Ok(())
}
