//! Offline-vendored minimal subset of the `anyhow` 1.x API.
//!
//! The build environment has no network access, so this shim provides the
//! surface the `stbllm` crate actually uses — `Result`, `Error`, the
//! `Context` extension trait (on both `Result` and `Option`), and the
//! `anyhow!` / `bail!` macros — with anyhow-compatible formatting:
//! `{}` shows the outermost context, `{:#}` the full `outer: ...: root`
//! chain. Drop-in replaceable by crates.io `anyhow = "1"`.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-chained error: the root cause plus the contexts wrapped around
/// it (innermost first), mirroring anyhow's rendering.
pub struct Error {
    root: String,
    /// contexts, innermost first (last pushed = outermost)
    contexts: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message (anyhow::Error::msg).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { root: message.to_string(), contexts: Vec::new() }
    }

    /// Wrap with an outer context (anyhow::Error::context).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.contexts.push(context.to_string());
        self
    }

    /// The root cause message (innermost in the chain).
    pub fn root_cause(&self) -> &str {
        &self.root
    }

    /// The chain outermost-first, ending at the root cause — like
    /// `anyhow::Error::chain`.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.contexts.iter().rev().map(|s| s.as_str()).chain(std::iter::once(self.root.as_str()))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first
            let mut first = true;
            for part in self.chain() {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{part}")?;
                first = false;
            }
            Ok(())
        } else {
            // `{}`: the outermost message only
            write!(f, "{}", self.contexts.last().unwrap_or(&self.root))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.contexts.last().unwrap_or(&self.root))?;
        if !self.contexts.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for part in self.chain().skip(1) {
                write!(f, "\n    {part}")?;
            }
        }
        Ok(())
    }
}

// NOTE: like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes the blanket `From` below coherent
// alongside core's reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // fold `source()` links into the context chain so `{:#}` shows them
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        let root = msgs.pop().unwrap();
        msgs.reverse(); // innermost first
        Error { root, contexts: msgs }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option` (anyhow::Context).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...")` — format an Error.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an Err(anyhow!(...)).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_alternate_shows_chain() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: file missing");

        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(3u8).context("never").unwrap(), 3);
    }

    #[test]
    fn macros_work() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad value {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(format!("{}", f(true).unwrap_err()), "bad value 7");
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.root_cause(), "x = 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert!(parse("12").is_ok());
        assert!(parse("nope").is_err());
    }
}
