//! # STBLLM — Structured Binary LLMs below 1 bit
//!
//! Rust + JAX + Pallas reproduction of *"STBLLM: Breaking the 1-Bit Barrier
//! with Structured Binary LLMs"* (ICLR 2025). See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for paper-vs-measured results.
//!
//! Layer map:
//! * [`engine`] — the `Engine` facade + pluggable `Backend` trait (native /
//!   PJRT / packed): the one seam quantize, eval and serve plug into.
//! * [`quant`] — the paper's PTQ algorithms (SI metric, N:M structured
//!   binarization, trisection, OBC compensation) + every baseline.
//! * [`packed`] — sub-1-bit storage format and the 2:4 sparse-binary GEMM
//!   "sparse tensor core" simulator (paper Appendix C).
//! * [`model`] — from-scratch tiny LLaMA/OPT/Mistral zoo + corpora.
//! * [`runtime`] — PJRT client executing AOT-lowered JAX/Pallas artifacts.
//! * [`coordinator`] — calibration, layer scheduling, the full-model PTQ
//!   driver and the batched inference server.
//! * [`net`] — the HTTP/1.1 streaming gateway (`stbllm serve --http`):
//!   chunked/SSE token streaming, deadlines, drain, live stats.
//! * [`obs`] — the observability substrate: lock-free metrics registry
//!   (`GET /metrics` Prometheus exposition), per-request trace spans,
//!   the shared percentile, and the schema-2 stats envelope.
//! * [`faults`] — the chaos harness (`stbllm chaos`): seeded fault plans
//!   injected against the artifact loaders and the live gateway.
//! * [`eval`] — perplexity, zero-shot harness, sign-flip study.
//! * [`report`] — table/figure rendering for the bench harness.

pub mod coordinator;
pub mod engine;
pub mod eval;
pub mod faults;
pub mod model;
// The gateway faces untrusted input: a stray `.unwrap()` on a parse or a
// lock is a remote panic, so unwrap is denied throughout net/ non-test
// code (tests opt back in per-module).
#[deny(clippy::unwrap_used)]
pub mod net;
pub mod obs;
pub mod packed;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod util;

// The facade, re-exported at crate root: `stbllm::Engine` is the intended
// entry point for downstream users.
pub use engine::{
    Backend, BackendKind, Capabilities, DecodeSession, Engine, EngineBuilder, EngineError,
    NativeBackend, PackedBackend, PjrtBackend, SessionOpts,
};
