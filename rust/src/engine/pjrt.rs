//! `PjrtBackend` — executes the AOT-lowered JAX/Pallas artifacts through
//! the PJRT client: embedding in Rust, one shape-specialized executable per
//! transformer layer, and the LM-head executable for logits. This is the
//! path that proves L1 (Pallas) ∘ L2 (JAX) ∘ L3 (Rust) compose.
//!
//! The layer executables are lowered for exactly `cfg.seq_len` tokens, so
//! `capabilities().fixed_seq_len == Some(seq_len)` and decode is
//! unsupported — the Engine routes serving to a decode-capable backend and
//! windows perplexity at `seq_len`, which is exactly what the old
//! `ppl_pjrt` hand-rolled.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::engine::backend::{Backend, Capabilities, DecodeSession, WeightsRef};
use crate::model::config::ModelConfig;
use crate::model::transformer;
use crate::model::ModelWeights;
use crate::runtime::client::MatArg;
use crate::runtime::{Artifacts, Runtime};
use crate::tensor::Mat;

enum RtRef<'a> {
    Owned(Box<Runtime>),
    Borrowed(&'a Runtime),
}

impl RtRef<'_> {
    fn get(&self) -> &Runtime {
        match self {
            RtRef::Owned(rt) => rt,
            RtRef::Borrowed(rt) => rt,
        }
    }
}

/// AOT-artifact backend. Executables are compiled once (eagerly, so that
/// `EngineBuilder::build` fails fast) and cached inside the runtime.
pub struct PjrtBackend<'a> {
    cfg: ModelConfig,
    weights: WeightsRef<'a>,
    rt: RtRef<'a>,
    layer_fwd: String,
    lm_head: String,
}

impl PjrtBackend<'static> {
    /// Owning constructor: creates a CPU PJRT runtime rooted at the
    /// artifacts directory and compiles the model's executables. Weights
    /// are shared, not cloned.
    pub fn new(
        arts: &Artifacts,
        model: &str,
        weights: Arc<ModelWeights>,
    ) -> Result<PjrtBackend<'static>> {
        let rt = Runtime::cpu(&arts.root)?;
        Self::build(RtRef::Owned(Box::new(rt)), arts, model, WeightsRef::Shared(weights))
    }
}

impl<'a> PjrtBackend<'a> {
    /// Borrowing constructor: reuses an existing runtime (and its compiled
    /// executable cache) — what the bench harness uses across cells.
    pub fn borrowed(
        rt: &'a Runtime,
        arts: &Artifacts,
        model: &str,
        weights: &'a ModelWeights,
    ) -> Result<PjrtBackend<'a>> {
        Self::build(RtRef::Borrowed(rt), arts, model, WeightsRef::Borrowed(weights))
    }

    fn build(
        rt: RtRef<'a>,
        arts: &Artifacts,
        model: &str,
        weights: WeightsRef<'a>,
    ) -> Result<PjrtBackend<'a>> {
        let ma = arts.models.get(model).with_context(|| format!("unknown model {model}"))?;
        // compile eagerly so misconfiguration surfaces at build time
        rt.get().load(&ma.layer_fwd)?;
        rt.get().load(&ma.lm_head)?;
        Ok(PjrtBackend {
            cfg: ma.config.clone(),
            weights,
            rt,
            layer_fwd: ma.layer_fwd.clone(),
            lm_head: ma.lm_head.clone(),
        })
    }

    pub fn platform(&self) -> String {
        self.rt.get().platform()
    }
}

impl Backend for PjrtBackend<'_> {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            full_forward: true,
            decode: false,
            fixed_seq_len: Some(self.cfg.seq_len),
            sub_1bit_storage: false,
            fused_decode: false,
            // no decode path at all, so no chunked prefill or paged-KV
            // sessions either
            chunked_prefill: false,
            paged_kv: false,
        }
    }

    fn forward(&self, tokens: &[u8]) -> Result<Mat> {
        if tokens.len() != self.cfg.seq_len {
            bail!(
                "pjrt backend executes fixed {}-token windows, got {}",
                self.cfg.seq_len,
                tokens.len()
            );
        }
        let rt = self.rt.get();
        let layer_exe = rt.load(&self.layer_fwd)?;
        let head_exe = rt.load(&self.lm_head)?;
        let names = self.cfg.layer_weight_names();
        let w = self.weights.get();
        let mut x = transformer::embed(&self.cfg, w, tokens);
        for lw in &w.layers {
            let mut args: Vec<MatArg> = vec![MatArg::M(&x), MatArg::V(&lw.ln1), MatArg::V(&lw.ln2)];
            for n in &names {
                args.push(MatArg::M(&lw.mats[*n]));
            }
            x = layer_exe.run(&args)?;
        }
        head_exe.run(&[MatArg::M(&x), MatArg::V(&w.ln_f), MatArg::M(&w.embed)])
    }

    fn begin_decode(&self, _capacity: usize) -> Result<Box<dyn DecodeSession + '_>> {
        bail!("pjrt backend has no incremental decode path (AOT artifacts are full-window)");
    }
}
