//! The `Engine` facade — one builder-style entry point for everything the
//! CLI, examples and benches used to hand-wire: artifact loading,
//! calibration, `Method` construction, backend selection, and the
//! quantize / perplexity / zero-shot / serve / flip workflows.
//!
//! ```no_run
//! use stbllm::engine::{BackendKind, Engine};
//! use stbllm::coordinator::Method;
//! use stbllm::quant::NmRatio;
//!
//! # fn main() -> anyhow::Result<()> {
//! let engine = Engine::builder()
//!     .model("llama1-7b")
//!     .method(Method::stbllm(NmRatio::new(4, 8)))
//!     .backend(BackendKind::Packed)
//!     .calib_corpus("c4s")
//!     .build()?;
//! println!("{:.3} bits/weight", engine.quantize().avg_bits);
//! let ppl = engine.perplexity("wikitext2s")?;
//! println!("wikitext2s ppl = {ppl:.2}");
//! # Ok(())
//! # }
//! ```
//!
//! Every future scaling PR (sharding, batching, caching, multi-backend)
//! plugs in at the [`Backend`] seam instead of touching five call sites.

pub mod backend;
pub mod native;
pub mod packed;
pub mod pjrt;

use std::fmt;
use std::sync::Arc;

use anyhow::Result;

pub use backend::{Backend, Capabilities, DecodeSession, SessionOpts};
pub use native::NativeBackend;
pub use packed::PackedBackend;
pub use pjrt::PjrtBackend;

use crate::coordinator::{calibrate, quantize_model, BatchServer, Method, Request, ServerStats};
use crate::eval;
use crate::model::config::ModelConfig;
use crate::model::{corpus, ModelWeights};
use crate::quant::{Allocation, Metric, NmRatio, NonSalientMode, StbOpts};
use crate::runtime::Artifacts;
use crate::util::cli::{defaults, Args};

/// Which execution backend an [`Engine`] drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Native Rust forward on dense f32 weights (full forward + decode).
    Native,
    /// AOT JAX/Pallas HLO via PJRT (fixed-window full forward only).
    Pjrt,
    /// Sub-1-bit 2:4 packed kernels on the deployment store.
    Packed,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind, EngineError> {
        match s {
            "native" | "rust" => Ok(BackendKind::Native),
            "pjrt" | "aot" | "xla" => Ok(BackendKind::Pjrt),
            "packed" | "stbp" => Ok(BackendKind::Packed),
            other => Err(EngineError::UnknownBackend(other.to_string())),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Packed => "packed",
        }
    }
}

/// Typed configuration/validation errors from [`EngineBuilder::build`] —
/// misconfiguration reports what was wrong (and what would be accepted)
/// instead of panicking.
#[derive(Clone, Debug)]
pub enum EngineError {
    /// The artifacts directory is missing/unreadable (run `make artifacts`).
    Artifacts(String),
    UnknownModel { model: String, known: Vec<String> },
    UnknownBackend(String),
    UnknownMethod(String),
    UnknownCorpus(String),
    /// A method option failed to parse (bad `--nm`, `--metric`, ...).
    InvalidOption { option: &'static str, value: String },
    /// The chosen backend cannot run the requested workflow.
    Unsupported { backend: &'static str, what: String },
    /// The backend failed to initialize (e.g. PJRT client unavailable).
    Backend(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Artifacts(e) => {
                write!(f, "artifacts unavailable ({e}) — run `make artifacts` or enable .synthetic_fallback(true)")
            }
            EngineError::UnknownModel { model, known } => {
                write!(f, "unknown model {model:?}; known: {}", known.join(", "))
            }
            EngineError::UnknownBackend(b) => {
                write!(f, "unknown backend {b:?}; expected native | pjrt | packed")
            }
            EngineError::UnknownMethod(m) => {
                write!(f, "unknown method {m:?}; expected fp | rtn | gptq | awq | pbllm | billm | stbllm")
            }
            EngineError::UnknownCorpus(c) => {
                write!(f, "unknown corpus {c:?}; expected wikitext2s | c4s | ptbs")
            }
            EngineError::InvalidOption { option, value } => {
                write!(f, "invalid value {value:?} for --{option}")
            }
            EngineError::Unsupported { backend, what } => {
                write!(f, "{backend} backend does not support {what}")
            }
            EngineError::Backend(e) => write!(f, "backend initialization failed: {e}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Per-model quantization summary captured at build time.
#[derive(Clone, Debug)]
pub struct QuantReport {
    pub model: String,
    /// method label as the paper's tables name it ("STBLLM(4:8)", ...)
    pub method: String,
    /// mean value-bits per weight across quantized matrices
    pub avg_bits: f64,
    /// mean salient fraction
    pub r_salient: f64,
    /// relative Frobenius reconstruction error vs the FP weights
    pub rel_recon_err: f64,
    /// wall-clock seconds spent quantizing
    pub seconds: f64,
    /// per-layer assigned N:M (empty for non-N:M methods)
    pub layer_ratios: Vec<NmRatio>,
}

/// Outcome of [`Engine::flip_study`].
#[derive(Clone, Copy, Debug)]
pub struct FlipReport {
    pub ratio: f64,
    pub ppl_before: f64,
    pub ppl_after: f64,
}

/// Builder for [`Engine`]; validates the whole configuration up front so
/// `build()` is the only fallible step.
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    model: String,
    method: Method,
    backend: BackendKind,
    calib_corpus: String,
    calib_tokens: usize,
    eval_tokens: usize,
    max_batch: usize,
    workers: usize,
    kv_pages: usize,
    page_size: usize,
    flat_kv: bool,
    prefill_chunk: usize,
    synthetic_fallback: bool,
    backend_fallback: bool,
}

impl Default for EngineBuilder {
    fn default() -> EngineBuilder {
        EngineBuilder {
            model: defaults::MODEL.to_string(),
            method: Method::stbllm(NmRatio::parse(defaults::NM).expect("default N:M")),
            backend: BackendKind::Native,
            calib_corpus: defaults::CALIB_CORPUS.to_string(),
            calib_tokens: defaults::CALIB_TOKENS,
            eval_tokens: defaults::EVAL_TOKENS,
            max_batch: defaults::MAX_BATCH,
            workers: defaults::WORKERS,
            kv_pages: defaults::KV_PAGES,
            page_size: defaults::PAGE_SIZE,
            flat_kv: false,
            prefill_chunk: defaults::PREFILL_CHUNK,
            synthetic_fallback: false,
            backend_fallback: false,
        }
    }
}

impl EngineBuilder {
    pub fn model(mut self, model: &str) -> Self {
        self.model = model.to_string();
        self
    }

    pub fn method(mut self, method: Method) -> Self {
        self.method = method;
        self
    }

    pub fn backend(mut self, backend: BackendKind) -> Self {
        self.backend = backend;
        self
    }

    pub fn calib_corpus(mut self, corpus: &str) -> Self {
        self.calib_corpus = corpus.to_string();
        self
    }

    pub fn calib_tokens(mut self, n: usize) -> Self {
        self.calib_tokens = n;
        self
    }

    pub fn eval_tokens(mut self, n: usize) -> Self {
        self.eval_tokens = n;
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n.max(1);
        self
    }

    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// KV pool size in pages for paged serving; `0` (the default)
    /// auto-sizes to `max_batch` concurrent worst-case sessions.
    pub fn kv_pages(mut self, n: usize) -> Self {
        self.kv_pages = n;
        self
    }

    /// KV page size in token slots (must be a power of two).
    pub fn page_size(mut self, n: usize) -> Self {
        self.page_size = n;
        self
    }

    /// Opt out of the paged KV pool: serve with flat per-session KV
    /// buffers (the legacy path; results are bit-identical either way).
    pub fn flat_kv(mut self, yes: bool) -> Self {
        self.flat_kv = yes;
        self
    }

    /// Per-tick prefill-token budget per session (`--prefill-chunk`): the
    /// batch server consumes up to `n` prompt tokens per scheduler tick as
    /// one multi-token chunk through the decode path's batched packed
    /// GEMM. `1` restores the legacy one-token-per-tick prefill; generated
    /// streams are bit-identical at any setting.
    pub fn prefill_chunk(mut self, n: usize) -> Self {
        self.prefill_chunk = n.max(1);
        self
    }

    /// When artifacts are missing, fall back to the preset config +
    /// synthetic weights instead of failing — lets the whole facade run in
    /// artifact-free environments (unit tests, CI).
    pub fn synthetic_fallback(mut self, yes: bool) -> Self {
        self.synthetic_fallback = yes;
        self
    }

    /// When the requested backend cannot be stood up (e.g. PJRT without the
    /// `pjrt` feature / `xla` runtime), fall back to the native backend
    /// with a warning instead of failing. Backend stand-up is the LAST step
    /// of `build()`, so the fallback never repeats calibration or
    /// quantization.
    pub fn backend_fallback(mut self, yes: bool) -> Self {
        self.backend_fallback = yes;
        self
    }

    /// Validate the configuration, quantize, and stand the backend up.
    pub fn build(self) -> Result<Engine, EngineError> {
        // 1. resolve model: artifacts first, preset+synthetic as opt-in fallback
        let arts = match Artifacts::load_default() {
            Ok(a) => Some(a),
            Err(e) if self.synthetic_fallback => {
                let _ = e;
                None
            }
            Err(e) => return Err(EngineError::Artifacts(format!("{e:#}"))),
        };
        let (cfg, fp_weights) = match &arts {
            Some(arts) => match arts.models.get(&self.model) {
                Some(ma) => {
                    let w = arts
                        .load_weights(&self.model)
                        .map_err(|e| EngineError::Artifacts(format!("{e:#}")))?;
                    (ma.config.clone(), w)
                }
                None if self.synthetic_fallback => synthetic_model(&self.model)?,
                None => {
                    return Err(EngineError::UnknownModel {
                        model: self.model.clone(),
                        known: arts.models.keys().cloned().collect(),
                    })
                }
            },
            None => synthetic_model(&self.model)?,
        };

        // 2. validate the calibration corpus / serving knobs before
        //    spending quantize time
        if corpus::spec_by_name(&self.calib_corpus).is_none() {
            return Err(EngineError::UnknownCorpus(self.calib_corpus.clone()));
        }
        if !self.page_size.is_power_of_two() {
            return Err(EngineError::InvalidOption {
                option: "page-size",
                value: self.page_size.to_string(),
            });
        }

        // 3. calibrate + quantize
        let needs_calib = !matches!(self.method, Method::FullPrecision | Method::Rtn { .. });
        let calib = needs_calib.then(|| {
            calibrate(&cfg, &fp_weights, &self.calib_corpus, self.calib_tokens, CALIB_SEED)
        });
        let q = quantize_model(&cfg, &fp_weights, &self.method, calib.as_ref(), self.workers);
        let report = QuantReport {
            model: self.model.clone(),
            method: self.method.label(),
            avg_bits: q.avg_bits,
            r_salient: q.r_salient,
            rel_recon_err: rel_recon_err(&fp_weights, &q.weights),
            seconds: q.seconds,
            layer_ratios: q.layer_ratios,
        };

        // 4. stand the backend up (LAST step: a backend_fallback never
        //    repeats the calibrate/quantize work above). Weights are shared
        //    via Arc so the Engine's retained reconstruction and the
        //    backend alias one allocation.
        let qweights = Arc::new(q.weights);
        let backend: Box<dyn Backend> = match self.backend {
            BackendKind::Native => {
                Box::new(NativeBackend::shared(cfg.clone(), qweights.clone()))
            }
            BackendKind::Packed => Box::new(
                PackedBackend::from_weights(&cfg, &qweights)
                    .map_err(|e| EngineError::Backend(format!("{e:#}")))?
                    .with_workers(self.workers),
            ),
            BackendKind::Pjrt => {
                let built: Result<Box<dyn Backend>, EngineError> = match arts.as_ref() {
                    None => Err(EngineError::Unsupported {
                        backend: "pjrt",
                        what: "synthetic (artifact-free) models".to_string(),
                    }),
                    Some(arts) => PjrtBackend::new(arts, &self.model, qweights.clone())
                        .map(|b| Box::new(b) as Box<dyn Backend>)
                        .map_err(|e| EngineError::Backend(format!("{e:#}"))),
                };
                match built {
                    Ok(b) => b,
                    Err(e) if self.backend_fallback => {
                        eprintln!("[engine] pjrt backend unavailable ({e}); falling back to native");
                        Box::new(NativeBackend::shared(cfg.clone(), qweights.clone()))
                    }
                    Err(e) => return Err(e),
                }
            }
        };

        Ok(Engine {
            model: self.model,
            cfg,
            backend,
            qweights,
            report,
            max_batch: self.max_batch,
            eval_tokens: self.eval_tokens,
            workers: self.workers,
            kv_pages: self.kv_pages,
            page_size: self.page_size,
            flat_kv: self.flat_kv,
            prefill_chunk: self.prefill_chunk,
        })
    }
}

fn synthetic_model(model: &str) -> Result<(ModelConfig, ModelWeights), EngineError> {
    match ModelConfig::preset(model) {
        Some(cfg) => {
            let w = ModelWeights::synthetic(&cfg, cfg.seed);
            Ok((cfg, w))
        }
        None => Err(EngineError::UnknownModel {
            model: model.to_string(),
            known: ModelConfig::preset_names().iter().map(|s| s.to_string()).collect(),
        }),
    }
}

fn rel_recon_err(fp: &ModelWeights, q: &ModelWeights) -> f64 {
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (l0, l1) in fp.layers.iter().zip(&q.layers) {
        for (n, m0) in &l0.mats {
            let d = m0.sub(&l1.mats[n]).frob_norm() as f64;
            num += d * d;
            den += (m0.frob_norm() as f64).powi(2);
        }
    }
    (num / den.max(1e-12)).sqrt()
}

const CALIB_SEED: u64 = 1234;
const EVAL_SEED: u64 = 999;
const WORKLOAD_SEED: u64 = 5;

/// The unified quantize/eval/serve facade. Construction (via
/// [`Engine::builder`]) loads the model, calibrates, quantizes, and stands
/// the chosen [`Backend`] up; the methods below are the workflows the CLI
/// subcommands, examples and benches share.
pub struct Engine {
    model: String,
    cfg: ModelConfig,
    backend: Box<dyn Backend>,
    /// Dense reconstruction of the quantized weights (flip study, PJRT
    /// zero-shot fallback, `weights()` accessor). Shared with the native /
    /// PJRT backend via `Arc` — no duplicate resident copy; the packed
    /// backend's serving hot path never touches it (its working set is the
    /// sub-1-bit store).
    qweights: Arc<ModelWeights>,
    report: QuantReport,
    max_batch: usize,
    eval_tokens: usize,
    /// thread budget shared by quantization, the packed kernels and the
    /// window-parallel evaluation (`--workers`)
    workers: usize,
    /// paged serving: KV pool size in pages (0 = auto)
    kv_pages: usize,
    /// paged serving: token slots per page (power of two)
    page_size: usize,
    /// serve with flat per-session KV buffers instead of the pool
    flat_kv: bool,
    /// per-tick prefill-token budget per session (1 = legacy)
    prefill_chunk: usize,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Dense reconstruction of the quantized weights.
    pub fn weights(&self) -> &ModelWeights {
        &self.qweights
    }

    /// The quantization summary captured at build time.
    pub fn quantize(&self) -> &QuantReport {
        &self.report
    }

    /// Perplexity on `eval_tokens` tokens of the named corpus, through this
    /// engine's backend (the one generic implementation — no more
    /// native/PJRT copy-paste). Windows are evaluated in parallel when the
    /// engine was built with `.workers(n > 1)`; the reduction is
    /// order-preserving, so the result is identical for any worker count.
    pub fn perplexity(&self, corpus_name: &str) -> Result<f64> {
        if corpus::spec_by_name(corpus_name).is_none() {
            return Err(EngineError::UnknownCorpus(corpus_name.to_string()).into());
        }
        let toks = corpus::corpus_tokens(corpus_name, self.eval_tokens, EVAL_SEED);
        eval::perplexity::perplexity_par(self.backend.as_ref(), &toks, self.workers)
    }

    /// The 7-task zero-shot suite. Runs through the backend when it accepts
    /// variable-length sequences; otherwise (PJRT's fixed windows) falls
    /// back to the native forward on the dense reconstruction.
    pub fn zeroshot(&self) -> Result<(Vec<(&'static str, f64)>, f64)> {
        let caps = self.backend.capabilities();
        if caps.full_forward && caps.fixed_seq_len.is_none() {
            eval::zeroshot::run_suite(self.backend.as_ref())
        } else {
            let native = NativeBackend::borrowed(&self.cfg, &self.qweights);
            eval::zeroshot::run_suite(&native)
        }
    }

    /// Serve a workload with continuous batching through the backend's
    /// decode path; returns responses + aggregate [`ServerStats`].
    ///
    /// By default KV memory is managed as a paged pool (admission control,
    /// prefix caching, copy-on-write — see `coordinator::kvpool`) whenever
    /// the backend supports it; `.flat_kv(true)` on the builder restores
    /// flat per-session buffers. Generated tokens are bit-identical either
    /// way.
    pub fn serve(&self, requests: Vec<Request>) -> Result<(Vec<crate::coordinator::Response>, ServerStats)> {
        self.serve_with_registry(requests, None)
    }

    /// [`Engine::serve`] with an explicit observability seam: when
    /// `registry` is `Some`, the batch server (and its KV pool) mint
    /// their counters and per-stage histograms in that registry, so an
    /// embedding caller can scrape one process-wide exposition across
    /// engine runs. `None` keeps a private per-run registry.
    pub fn serve_with_registry(
        &self,
        requests: Vec<Request>,
        registry: Option<std::sync::Arc<crate::obs::Registry>>,
    ) -> Result<(Vec<crate::coordinator::Response>, ServerStats)> {
        if !self.backend.capabilities().decode {
            return Err(EngineError::Unsupported {
                backend: self.backend.label(),
                what: "incremental decode (serving)".to_string(),
            }
            .into());
        }
        let mut server = BatchServer::new(self.backend.as_ref(), self.max_batch);
        server.prefill_chunk = self.prefill_chunk;
        if let Some(reg) = registry {
            server = server.with_registry(reg);
        }
        if !self.flat_kv {
            server = server.with_kv_pool(self.kv_pages, self.page_size);
        }
        server.run(requests)
    }

    /// The serving configuration for this engine, bound to `addr`: the
    /// engine's serving knobs (`max_batch`, `kv_pages`, `page_size`,
    /// `flat_kv`) pre-filled, everything else at its default. Callers (the
    /// CLI, tests, embedders) adjust the returned [`ServeConfig`] and hand
    /// it to [`Engine::serve_http`] — ONE struct end to end, instead of
    /// the builder → gateway field-by-field copying this replaced.
    pub fn serve_config(&self, addr: &str) -> crate::net::ServeConfig {
        let mut cfg = crate::net::ServeConfig::new(addr);
        cfg.max_batch = self.max_batch;
        cfg.kv_pages = self.kv_pages;
        cfg.page_size = self.page_size;
        cfg.flat_kv = self.flat_kv;
        cfg.prefill_chunk = self.prefill_chunk;
        cfg
    }

    /// Serve over HTTP (`stbllm serve --http ADDR`): stream tokens to
    /// network clients through the same continuous-batching scheduler
    /// [`Engine::serve`] uses, so HTTP output is byte-identical to a
    /// direct batch run — at any `opts.replicas` count, since every
    /// replica borrows this engine's ONE resident weight set. Start from
    /// [`Engine::serve_config`]; blocks until `ctl` drains and returns
    /// the final gateway report (check `leaked_pages == 0`).
    pub fn serve_http(
        &self,
        opts: &crate::net::ServeConfig,
        ctl: &crate::net::GatewayCtl,
    ) -> Result<crate::net::GatewayReport> {
        if !self.backend.capabilities().decode {
            return Err(EngineError::Unsupported {
                backend: self.backend.label(),
                what: "incremental decode (serving)".to_string(),
            }
            .into());
        }
        crate::net::serve_http(self.backend.as_ref(), opts, ctl)
    }

    /// Synthetic serving workload: `n_req` prompts sliced from the prose
    /// corpus (the smoke workload `stbllm serve` and the examples use).
    pub fn synthetic_workload(
        &self,
        n_req: usize,
        prompt_len: usize,
        max_new: usize,
    ) -> Vec<Request> {
        let toks =
            corpus::corpus_tokens(defaults::EVAL_CORPUS, n_req * prompt_len, WORKLOAD_SEED);
        (0..n_req)
            .map(|i| Request {
                id: i as u64,
                prompt: toks[i * prompt_len..(i + 1) * prompt_len].to_vec(),
                max_new,
            })
            .collect()
    }

    /// Sign-flip redundancy study (Fig. 1): flip `ratio` of the quantized
    /// signs and measure perplexity before/after on the named corpus.
    pub fn flip_study(
        &self,
        corpus_name: &str,
        ratio: f64,
        salient_aware: bool,
    ) -> Result<FlipReport> {
        if corpus::spec_by_name(corpus_name).is_none() {
            return Err(EngineError::UnknownCorpus(corpus_name.to_string()).into());
        }
        let toks = corpus::corpus_tokens(corpus_name, self.eval_tokens, EVAL_SEED);
        let before = {
            let native = NativeBackend::borrowed(&self.cfg, &self.qweights);
            eval::perplexity::perplexity(&native, &toks)?
        };
        let flipped = eval::flip::flip_model(&self.qweights, ratio, salient_aware, FLIP_SEED);
        let after = {
            let native = NativeBackend::borrowed(&self.cfg, &flipped);
            eval::perplexity::perplexity(&native, &toks)?
        };
        Ok(FlipReport { ratio, ppl_before: before, ppl_after: after })
    }
}

const FLIP_SEED: u64 = 42;

/// Build a [`Method`] from parsed CLI options (`--method`, `--bits`,
/// `--nm`, `--metric`, `--alloc`, `--block`, `--frac`) — shared by
/// `main.rs` and anything else that accepts the paper's method names.
pub fn method_from_args(args: &Args) -> Result<Method, EngineError> {
    let nm_str = args.get_or("nm", defaults::NM);
    let nm = NmRatio::parse(nm_str)
        .ok_or_else(|| EngineError::InvalidOption { option: "nm", value: nm_str.to_string() })?;
    let bits = args.get_usize("bits", defaults::BITS) as u32;
    Ok(match args.get_or("method", defaults::METHOD) {
        "fp" | "fullprecision" => Method::FullPrecision,
        "rtn" => Method::Rtn { bits },
        "gptq" => Method::Gptq { bits, block: defaults::BLOCK_SIZE },
        "awq" => Method::Awq { bits },
        "pbllm" => Method::PbLlm {
            frac_salient: args.get_f64("frac", defaults::FRAC_SALIENT),
            hi_bits: 8,
        },
        "billm" => Method::BiLlm { nm: args.get("nm").map(|_| nm) },
        "stbllm" => {
            let mut opts = StbOpts::stbllm(nm);
            if let Some(m) = args.get("metric") {
                opts.metric = Metric::parse(m).ok_or_else(|| EngineError::InvalidOption {
                    option: "metric",
                    value: m.to_string(),
                })?;
            }
            opts.block_size = args.get_usize("block", defaults::BLOCK_SIZE);
            let alloc_str = args.get_or("alloc", defaults::ALLOC);
            let allocation =
                Allocation::parse(alloc_str).ok_or_else(|| EngineError::InvalidOption {
                    option: "alloc",
                    value: alloc_str.to_string(),
                })?;
            if let Some(ns) = args.get("nonsalient") {
                opts.non_salient = match ns {
                    "bell" => NonSalientMode::BellShaped,
                    "trisection" => NonSalientMode::Trisection,
                    "plain" => NonSalientMode::Plain,
                    other => {
                        return Err(EngineError::InvalidOption {
                            option: "nonsalient",
                            value: other.to_string(),
                        })
                    }
                };
            }
            Method::Stbllm { opts, allocation }
        }
        other => return Err(EngineError::UnknownMethod(other.to_string())),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_with_flags(args.iter().map(|s| s.to_string()), &Args::COMMON_FLAGS)
    }

    #[test]
    fn backend_kind_parses_and_rejects() {
        assert_eq!(BackendKind::parse("native").unwrap(), BackendKind::Native);
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("packed").unwrap(), BackendKind::Packed);
        match BackendKind::parse("cuda") {
            Err(EngineError::UnknownBackend(b)) => assert_eq!(b, "cuda"),
            other => panic!("expected UnknownBackend, got {other:?}"),
        }
    }

    #[test]
    fn method_from_args_defaults_to_stbllm() {
        let m = method_from_args(&parse(&[])).unwrap();
        assert_eq!(m.label(), format!("STBLLM({})", defaults::NM));
    }

    #[test]
    fn method_from_args_rejects_unknowns_typed() {
        match method_from_args(&parse(&["--method", "int8"])) {
            Err(EngineError::UnknownMethod(m)) => assert_eq!(m, "int8"),
            other => panic!("expected UnknownMethod, got {other:?}"),
        }
        match method_from_args(&parse(&["--nm", "9"])) {
            Err(EngineError::InvalidOption { option: "nm", .. }) => {}
            other => panic!("expected InvalidOption(nm), got {other:?}"),
        }
        match method_from_args(&parse(&["--metric", "psnr"])) {
            Err(EngineError::InvalidOption { option: "metric", .. }) => {}
            other => panic!("expected InvalidOption(metric), got {other:?}"),
        }
    }

    #[test]
    fn builder_rejects_unknown_model_with_candidates() {
        let err = Engine::builder()
            .model("gpt-17")
            .synthetic_fallback(true)
            .build()
            .err()
            .expect("unknown model must not build");
        match err {
            EngineError::UnknownModel { model, known } => {
                assert_eq!(model, "gpt-17");
                assert!(!known.is_empty());
            }
            other => panic!("expected UnknownModel, got {other:?}"),
        }
    }

    #[test]
    fn builder_rejects_non_power_of_two_page_size() {
        let r = Engine::builder()
            .model("llama1-7b")
            .page_size(12)
            .synthetic_fallback(true)
            .build();
        match r.err().expect("must not build") {
            EngineError::InvalidOption { option: "page-size", value } => assert_eq!(value, "12"),
            other => panic!("expected InvalidOption(page-size), got {other:?}"),
        }
    }

    #[test]
    fn builder_errors_are_typed_not_panics_without_artifacts() {
        // without synthetic_fallback and without artifacts this must be a
        // clean typed error, never a panic
        let r = Engine::builder().model("llama1-7b").build();
        if let Err(e) = r {
            let msg = e.to_string();
            assert!(!msg.is_empty());
        }
    }
}
