//! `PackedBackend` — the deployment backend: every projection of the
//! forward and of the KV-cache decode runs through the sub-1-bit 2:4 packed
//! kernels (`packed::gemm::packed_gemm4` / `packed_gemv`) directly on
//! [`Packed24`] weights from the `.stbp` store. Weights are never expanded
//! to dense f32, so the resident projection footprint is the paper's ~0.55
//! bit/weight artifact (§4.3, Appendix C) — this wires the packed path into
//! serving for the first time.
//!
//! Only the FP sidecar tensors (embeddings, norms, OPT positions) stay
//! dense; they are exactly the tensors the PTQ pipeline never quantizes.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::engine::backend::{Backend, Capabilities, DecodeSession, SessionOpts};
use crate::model::config::ModelConfig;
use crate::model::transformer::{self, DecodeState, ModelOps};
use crate::model::ModelWeights;
use crate::packed::format::Packed24;
use crate::packed::gemm::{
    packed_gemm4_par, packed_gemm4_par_into, packed_gemv_par, packed_gemv_par_into,
};
use crate::packed::store::PackedModel;
use crate::tensor::Mat;

struct PackedLayer {
    ln1: Vec<f32>,
    ln2: Vec<f32>,
    mats: BTreeMap<String, Packed24>,
}

/// Sub-1-bit packed execution backend.
pub struct PackedBackend {
    cfg: ModelConfig,
    embed: Mat,
    pos: Option<Mat>,
    ln_f: Vec<f32>,
    layers: Vec<PackedLayer>,
    /// kernel thread budget for the `_par` GEMM/GEMV entry points (1 =
    /// serial; parallel results are bit-identical to serial either way)
    workers: usize,
}

impl PackedBackend {
    /// Collapse (already-quantized) dense weights onto the exact 2:4 packed
    /// form and build the backend. Note this applies the §4.3 deployment
    /// collapse (`enforce_24` + single per-row α), identical to what
    /// `PackedModel::from_weights` writes into a `.stbp` container.
    pub fn from_weights(cfg: &ModelConfig, w: &ModelWeights) -> Result<PackedBackend> {
        let pm = PackedModel::from_weights(cfg, w)?;
        Self::from_store(cfg, &pm)
    }

    /// Build from a deployment container (what `stbllm serve --backend
    /// packed` loads instead of FP32 weights).
    pub fn from_store(cfg: &ModelConfig, pm: &PackedModel) -> Result<PackedBackend> {
        let fp_mat = |name: &str| -> Result<Mat> {
            let (dims, data) =
                pm.fp.get(name).with_context(|| format!("missing fp tensor {name}"))?;
            if dims.len() != 2 {
                anyhow::bail!("{name}: expected 2-D, got {dims:?}");
            }
            Ok(Mat::from_vec(dims[0], dims[1], data.clone()))
        };
        let fp_vec = |name: &str| -> Result<Vec<f32>> {
            Ok(pm.fp.get(name).with_context(|| format!("missing fp tensor {name}"))?.1.clone())
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let mut mats = BTreeMap::new();
            for n in cfg.layer_weight_names() {
                let p = pm
                    .packed
                    .get(&format!("layers.{i}.{n}"))
                    .with_context(|| format!("missing packed layers.{i}.{n}"))?;
                mats.insert(n.to_string(), p.clone());
            }
            layers.push(PackedLayer {
                ln1: fp_vec(&format!("layers.{i}.ln1"))?,
                ln2: fp_vec(&format!("layers.{i}.ln2"))?,
                mats,
            });
        }
        Ok(PackedBackend {
            cfg: cfg.clone(),
            embed: fp_mat("embed")?,
            pos: if pm.fp.contains_key("pos") { Some(fp_mat("pos")?) } else { None },
            ln_f: fp_vec("ln_f")?,
            layers,
            workers: 1,
        })
    }

    /// Set the kernel thread budget: projections above the
    /// `packed::gemm::PAR_MIN_MACS` cutoff run over the scheduler pool.
    pub fn with_workers(mut self, workers: usize) -> PackedBackend {
        self.workers = workers.max(1);
        self
    }

    /// Resident bytes of the packed projections (the Fig. 9 number).
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().flat_map(|l| l.mats.values()).map(|p| p.bytes()).sum()
    }

    /// Mean effective bits/weight across the packed projections.
    pub fn bits_per_weight(&self) -> f64 {
        let (mut bits, mut n) = (0.0f64, 0usize);
        for p in self.layers.iter().flat_map(|l| l.mats.values()) {
            bits += p.bytes() as f64 * 8.0;
            n += p.rows * p.cols;
        }
        bits / n.max(1) as f64
    }
}

impl ModelOps for PackedBackend {
    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn ln1(&self, layer: usize) -> &[f32] {
        &self.layers[layer].ln1
    }

    fn ln2(&self, layer: usize) -> &[f32] {
        &self.layers[layer].ln2
    }

    fn proj(&self, layer: usize, name: &str, x: &Mat) -> Mat {
        // v4 multi-column tile: each meta word decoded once per 4 batch
        // rows; bit-identical to the v3 GEMM (and to per-row GEMV)
        packed_gemm4_par(x, &self.layers[layer].mats[name], self.workers)
    }

    fn proj_vec(&self, layer: usize, name: &str, x: &[f32]) -> Vec<f32> {
        packed_gemv_par(&self.layers[layer].mats[name], x, self.workers)
    }

    fn proj_vec_into(&self, layer: usize, name: &str, x: &[f32], out: &mut [f32]) {
        packed_gemv_par_into(&self.layers[layer].mats[name], x, out, self.workers);
    }

    fn proj_chunk_into(&self, layer: usize, name: &str, x: &Mat, out: &mut Mat) {
        // the chunked-prefill hot path: amortize each 6-bit meta-word
        // decode over all chunk columns while staying bit-identical to the
        // per-token GEMV (shared row kernel)
        packed_gemm4_par_into(x, &self.layers[layer].mats[name], out, self.workers);
    }

    fn embed_mat(&self) -> &Mat {
        &self.embed
    }

    fn pos_mat(&self) -> Option<&Mat> {
        self.pos.as_ref()
    }

    fn ln_f(&self) -> &[f32] {
        &self.ln_f
    }
}

impl Backend for PackedBackend {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn label(&self) -> &'static str {
        "packed"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            full_forward: true,
            decode: true,
            fixed_seq_len: None,
            sub_1bit_storage: true,
            fused_decode: true,
            chunked_prefill: true,
            paged_kv: true,
        }
    }

    fn forward(&self, tokens: &[u8]) -> Result<Mat> {
        Ok(transformer::model_fwd_ops(self, &self.cfg, tokens))
    }

    fn begin_decode(&self, capacity: usize) -> Result<Box<dyn DecodeSession + '_>> {
        Ok(Box::new(PackedSession { be: self, st: DecodeState::new(&self.cfg, capacity) }))
    }

    fn begin_decode_with(&self, opts: &SessionOpts<'_>) -> Result<Box<dyn DecodeSession + '_>> {
        let st = match &opts.pool {
            Some(pool) => DecodeState::new_paged(&self.cfg, opts.capacity, pool, opts.prompt)?,
            None => DecodeState::new(&self.cfg, opts.capacity),
        };
        Ok(Box::new(PackedSession { be: self, st }))
    }

    /// Fused cross-session tick: one packed GEMM per projection over the
    /// stacked activations, so the sub-1-bit weight stream is read once per
    /// token-tick instead of once per session — the §4.3 batching win in
    /// the memory-bound decode regime. Bit-identical to per-session
    /// stepping (the packed kernels share one row kernel).
    fn decode_batch(
        &self,
        sessions: &mut [&mut (dyn DecodeSession + '_)],
        tokens: &[u8],
    ) -> Result<Vec<Vec<f32>>> {
        if sessions.len() != tokens.len() {
            anyhow::bail!("decode_batch: {} sessions vs {} tokens", sessions.len(), tokens.len());
        }
        let mut states: Vec<&mut DecodeState> = Vec::with_capacity(sessions.len());
        for s in sessions.iter_mut() {
            match s.state_mut() {
                Some(st) => states.push(st),
                None => anyhow::bail!("packed decode_batch requires KV-cache sessions"),
            }
        }
        Ok(transformer::step_ops_batch(&self.cfg, self, &mut states, tokens))
    }
}

struct PackedSession<'a> {
    be: &'a PackedBackend,
    st: DecodeState,
}

impl DecodeSession for PackedSession<'_> {
    fn step(&mut self, token: u8) -> Result<Vec<f32>> {
        Ok(self.st.step_ops(&self.be.cfg, self.be, token))
    }

    fn prefill(&mut self, tokens: &[u8], all_logits: bool) -> Result<Mat> {
        Ok(self.st.prefill_chunk(&self.be.cfg, self.be, tokens, all_logits))
    }

    fn pos(&self) -> usize {
        self.st.pos
    }

    fn state_mut(&mut self) -> Option<&mut DecodeState> {
        Some(&mut self.st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeBackend;

    /// Dense weights that are already exactly representable in 2:4 packed
    /// form: collapse synthetic weights through the store and re-expand.
    fn exact_24(cfg: &ModelConfig, seed: u64) -> (ModelWeights, PackedModel) {
        let w = ModelWeights::synthetic(cfg, seed);
        let pm = PackedModel::from_weights(cfg, &w).unwrap();
        let dense = pm.to_weights(cfg).unwrap();
        (dense, pm)
    }

    #[test]
    fn packed_forward_matches_native_on_exact_24_weights() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let (dense, pm) = exact_24(&cfg, 21);
        let packed = PackedBackend::from_store(&cfg, &pm).unwrap();
        let native = NativeBackend::borrowed(&cfg, &dense);
        let toks: Vec<u8> = (0..24u8).collect();
        let a = packed.forward(&toks).unwrap();
        let b = native.forward(&toks).unwrap();
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn packed_decode_matches_packed_forward() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let (_, pm) = exact_24(&cfg, 22);
        let be = PackedBackend::from_store(&cfg, &pm).unwrap();
        let toks: Vec<u8> = vec![4, 9, 1, 7, 3];
        let full = be.forward(&toks).unwrap();
        let mut sess = be.begin_decode(16).unwrap();
        let mut last = Vec::new();
        for &t in &toks {
            last = sess.step(t).unwrap();
        }
        for (a, b) in last.iter().zip(full.row(toks.len() - 1)) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    /// Fused `decode_batch` must reproduce per-session decode
    /// token-for-token — here even bit-for-bit: the packed GEMM and GEMV
    /// share one row kernel and the batch step mirrors the per-session
    /// operation order exactly.
    #[test]
    fn fused_decode_batch_bitmatches_per_session_decode() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let (_, pm) = exact_24(&cfg, 24);
        let be = PackedBackend::from_store(&cfg, &pm).unwrap();
        assert!(be.capabilities().fused_decode);

        let prompts: [&[u8]; 3] = [&[4, 9, 1], &[7, 7], &[2, 5, 6, 3]];
        // reference: independent sessions
        let mut want: Vec<Vec<Vec<f32>>> = Vec::new();
        for p in prompts {
            let mut sess = be.begin_decode(16).unwrap();
            want.push(p.iter().map(|&t| sess.step(t).unwrap()).collect());
        }
        // fused: one decode_batch per tick; sessions join/leave mid-stream
        // (different prompt lengths), mirroring continuous batching
        let mut sessions: Vec<_> = prompts.iter().map(|_| be.begin_decode(16).unwrap()).collect();
        let max_len = prompts.iter().map(|p| p.len()).max().unwrap();
        let mut got: Vec<Vec<Vec<f32>>> = prompts.iter().map(|_| Vec::new()).collect();
        for t in 0..max_len {
            let mut idx = Vec::new();
            let mut toks = Vec::new();
            for (i, p) in prompts.iter().enumerate() {
                if t < p.len() {
                    idx.push(i);
                    toks.push(p[t]);
                }
            }
            let logits = {
                let mut refs: Vec<&mut (dyn DecodeSession + '_)> = Vec::new();
                for (i, s) in sessions.iter_mut().enumerate() {
                    if idx.contains(&i) {
                        refs.push(s.as_mut());
                    }
                }
                be.decode_batch(&mut refs, &toks).unwrap()
            };
            for (&i, lg) in idx.iter().zip(logits) {
                got[i].push(lg);
            }
        }
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.len(), w.len(), "session {i}");
            for (a, b) in g.iter().zip(w) {
                assert_eq!(a, b, "session {i}: fused logits must bit-match per-session");
            }
        }
    }

    /// Chunked prefill through the v4 multi-column GEMM must bit-match
    /// per-token stepping — across chunk sizes, incl. a word-unaligned
    /// prompt length, with the parallel kernel path engaged.
    #[test]
    fn session_prefill_bitmatches_per_token_stepping() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let (_, pm) = exact_24(&cfg, 26);
        let be = PackedBackend::from_store(&cfg, &pm).unwrap().with_workers(2);
        assert!(be.capabilities().chunked_prefill);
        let toks: Vec<u8> = (0..13).map(|i| (i * 5 % 32) as u8).collect();
        let mut stepper = be.begin_decode(32).unwrap();
        let want: Vec<Vec<f32>> = toks.iter().map(|&t| stepper.step(t).unwrap()).collect();
        for cs in [3usize, 8, 32] {
            let mut sess = be.begin_decode(32).unwrap();
            let mut got: Vec<Vec<f32>> = Vec::new();
            for chunk in toks.chunks(cs) {
                let lg = sess.prefill(chunk, true).unwrap();
                got.extend((0..lg.rows).map(|r| lg.row(r).to_vec()));
            }
            assert_eq!(sess.pos(), toks.len());
            assert_eq!(got, want, "cs={cs}: chunked prefill must bit-match stepping");
        }
    }

    #[test]
    fn parallel_workers_bitmatch_serial_backend() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let (_, pm) = exact_24(&cfg, 25);
        let serial = PackedBackend::from_store(&cfg, &pm).unwrap();
        let par = PackedBackend::from_store(&cfg, &pm).unwrap().with_workers(4);
        let toks: Vec<u8> = (0..16u8).collect();
        let a = serial.forward(&toks).unwrap();
        let b = par.forward(&toks).unwrap();
        assert_eq!(a.data, b.data, "worker count must not change results");
    }

    #[test]
    fn packed_backend_is_sub_2bit_resident() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 23);
        let be = PackedBackend::from_weights(&cfg, &w).unwrap();
        assert!(be.packed_bytes() > 0);
        assert!(be.bits_per_weight() < 2.0, "{}", be.bits_per_weight());
        assert!(be.capabilities().sub_1bit_storage);
    }
}
