//! The `Backend` trait — the single seam between the [`crate::engine::Engine`]
//! facade (quantize / eval / serve / flip) and an execution strategy.
//!
//! Three implementations ship with the crate:
//!  * [`crate::engine::NativeBackend`] — the Rust transformer forward on
//!    dense f32 weights (full-sequence + KV-cache decode);
//!  * [`crate::engine::PjrtBackend`]  — AOT-lowered JAX/Pallas HLO executed
//!    through the PJRT client (fixed `seq_len` windows, no decode);
//!  * [`crate::engine::PackedBackend`] — every projection routed through the
//!    sub-1-bit 2:4 packed kernels (`packed::gemm`), full forward + decode.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::kvpool::KvPool;
use crate::model::config::ModelConfig;
use crate::model::transformer::DecodeState;
use crate::model::ModelWeights;
use crate::tensor::Mat;

/// Internal: shared-owned or borrowed dense weights. Backends hold this so
/// the Engine's retained reconstruction and the backend's copy are the SAME
/// allocation (no doubled resident weights).
pub(crate) enum WeightsRef<'a> {
    Shared(std::sync::Arc<ModelWeights>),
    Borrowed(&'a ModelWeights),
}

impl WeightsRef<'_> {
    pub(crate) fn get(&self) -> &ModelWeights {
        match self {
            WeightsRef::Shared(w) => w,
            WeightsRef::Borrowed(w) => w,
        }
    }
}

/// What a backend can do; `Engine` and `BatchServer` route work accordingly
/// instead of hard-coding per-backend branches.
#[derive(Clone, Copy, Debug)]
pub struct Capabilities {
    /// Can compute full-sequence logits (perplexity / zero-shot).
    pub full_forward: bool,
    /// Can run incremental KV-cache decode (the serving path).
    pub decode: bool,
    /// `forward` only accepts sequences of exactly this length (AOT
    /// executables are shape-specialized); `None` = any length.
    pub fixed_seq_len: Option<usize>,
    /// Weights are held in the sub-1-bit packed store, not dense f32.
    pub sub_1bit_storage: bool,
    /// [`Backend::decode_batch`] fuses the projection GEMMs across
    /// sessions (the weight stream is read once per token-tick instead of
    /// once per session). Backends without it still serve batches — the
    /// default `decode_batch` steps each session independently.
    pub fused_decode: bool,
    /// [`DecodeSession::prefill`] consumes multi-token chunks through one
    /// batched forward per layer ([`DecodeState::prefill_chunk`]): each
    /// packed weight word is decoded once per chunk instead of once per
    /// token, with output bit-identical to token-by-token stepping.
    /// Backends without it still accept `prefill` — the default steps one
    /// token at a time.
    pub chunked_prefill: bool,
    /// [`Backend::begin_decode_with`] accepts a shared
    /// [`KvPool`] — sessions borrow fixed-size KV pages (with prefix
    /// reuse + copy-on-write) instead of owning flat buffers. The server
    /// only attaches a pool when this is set.
    pub paged_kv: bool,
}

/// How a decode session's KV cache should be provisioned — the argument of
/// [`Backend::begin_decode_with`].
pub struct SessionOpts<'p> {
    /// Worst-case tokens this session may consume (prompt + generation).
    pub capacity: usize,
    /// When set, the session borrows pages from this pool instead of
    /// allocating flat per-session KV buffers.
    pub pool: Option<Arc<KvPool>>,
    /// The upcoming token stream, used for prefix-cache lookup in paged
    /// sessions (empty disables matching; ignored by flat sessions).
    pub prompt: &'p [u8],
}

impl SessionOpts<'_> {
    /// Flat per-session KV storage of `capacity` tokens (the legacy path).
    pub fn flat(capacity: usize) -> SessionOpts<'static> {
        SessionOpts { capacity, pool: None, prompt: &[] }
    }
}

/// An in-flight decode sequence (one KV cache) created by a backend.
pub trait DecodeSession {
    /// Feed one token; returns logits over the vocabulary.
    fn step(&mut self, token: u8) -> Result<Vec<f32>>;
    /// Feed a chunk of prompt tokens; returns logits as a Mat — all rows
    /// when `all_logits` is set (the eval path), else only the final row
    /// (serving). The chunk may start anywhere (prefix-cache resume lands
    /// mid-prompt), and the result is bit-identical to feeding the tokens
    /// through [`DecodeSession::step`] one at a time. This default does
    /// exactly that; backends reporting [`Capabilities::chunked_prefill`]
    /// override it with the batched chunk forward.
    fn prefill(&mut self, tokens: &[u8], all_logits: bool) -> Result<Mat> {
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for (i, &t) in tokens.iter().enumerate() {
            let lg = self.step(t)?;
            if all_logits || i + 1 == tokens.len() {
                rows.push(lg);
            }
        }
        let cols = rows.first().map_or(0, |r| r.len());
        let mut out = Mat::zeros(rows.len(), cols);
        for (i, r) in rows.iter().enumerate() {
            out.row_mut(i).copy_from_slice(r);
        }
        Ok(out)
    }
    /// Number of tokens consumed so far.
    fn pos(&self) -> usize;
    /// The underlying KV-cache [`DecodeState`] when this session is backed
    /// by the shared native decode loop — what fused cross-session
    /// `decode_batch` implementations reach through. `None` for sessions
    /// with a foreign state representation.
    fn state_mut(&mut self) -> Option<&mut DecodeState> {
        None
    }
}

/// A model execution backend.
///
/// Backends own their weight representation; sessions returned by
/// [`Backend::begin_decode`] borrow the backend (`+ '_`), so a server holds
/// one backend reference and any number of concurrent sessions. Backends
/// are `Sync`: evaluation fan-out (`eval::perplexity::perplexity_par`) and
/// the parallel kernels share one backend across scheduler threads.
pub trait Backend: Sync {
    /// The model configuration this backend executes.
    fn cfg(&self) -> &ModelConfig;
    /// Short human label ("native", "pjrt", "packed").
    fn label(&self) -> &'static str;
    fn capabilities(&self) -> Capabilities;
    /// Full-sequence forward: tokens → logits (S, vocab).
    fn forward(&self, tokens: &[u8]) -> Result<Mat>;
    /// Start an incremental decode with the given KV capacity (flat
    /// per-session KV storage).
    fn begin_decode(&self, capacity: usize) -> Result<Box<dyn DecodeSession + '_>>;
    /// Start an incremental decode from full session options — in
    /// particular against a shared paged [`KvPool`]. Backends reporting
    /// [`Capabilities::paged_kv`] override this; the default only accepts
    /// flat options. Paged sessions may come back with `pos() > 0` when
    /// the pool's prefix cache already covers the head of `opts.prompt` —
    /// the caller resumes feeding at `prompt[pos()..]`.
    fn begin_decode_with(&self, opts: &SessionOpts<'_>) -> Result<Box<dyn DecodeSession + '_>> {
        if opts.pool.is_some() {
            anyhow::bail!("{} backend does not support paged KV sessions", self.label());
        }
        self.begin_decode(opts.capacity)
    }
    /// Step several sessions one token each (`sessions[i]` consumes
    /// `tokens[i]`); returns per-session logits. The default steps each
    /// session independently; backends reporting
    /// [`Capabilities::fused_decode`] override it to run one fused GEMM per
    /// projection across the whole tick ([`crate::coordinator::BatchServer`]
    /// calls this once per scheduling round).
    fn decode_batch(
        &self,
        sessions: &mut [&mut (dyn DecodeSession + '_)],
        tokens: &[u8],
    ) -> Result<Vec<Vec<f32>>> {
        if sessions.len() != tokens.len() {
            anyhow::bail!("decode_batch: {} sessions vs {} tokens", sessions.len(), tokens.len());
        }
        sessions.iter_mut().zip(tokens).map(|(s, &t)| s.step(t)).collect()
    }
}
