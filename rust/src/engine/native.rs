//! `NativeBackend` — the pure-Rust transformer forward on dense f32
//! weights. The reference implementation every other backend is checked
//! against, and the default serving backend.

use std::borrow::Cow;
use std::sync::Arc;

use anyhow::Result;

use crate::engine::backend::{Backend, Capabilities, DecodeSession, SessionOpts, WeightsRef};
use crate::model::config::ModelConfig;
use crate::model::transformer::{self, DecodeState};
use crate::model::ModelWeights;
use crate::tensor::Mat;

/// Dense-weight backend over the native Rust forward.
///
/// Weights are held as either a shared `Arc` (what the Engine hands out, so
/// its retained reconstruction and this backend alias one allocation) or a
/// plain borrow (`NativeBackend::borrowed`) for transient evaluations.
pub struct NativeBackend<'a> {
    cfg: Cow<'a, ModelConfig>,
    weights: WeightsRef<'a>,
}

impl NativeBackend<'static> {
    /// Owning constructor.
    pub fn new(cfg: ModelConfig, weights: ModelWeights) -> NativeBackend<'static> {
        Self::shared(cfg, Arc::new(weights))
    }

    /// Shared-ownership constructor (what `EngineBuilder::build` uses).
    pub fn shared(cfg: ModelConfig, weights: Arc<ModelWeights>) -> NativeBackend<'static> {
        NativeBackend { cfg: Cow::Owned(cfg), weights: WeightsRef::Shared(weights) }
    }
}

impl<'a> NativeBackend<'a> {
    /// Borrowing constructor for transient evaluations.
    pub fn borrowed(cfg: &'a ModelConfig, weights: &'a ModelWeights) -> NativeBackend<'a> {
        NativeBackend { cfg: Cow::Borrowed(cfg), weights: WeightsRef::Borrowed(weights) }
    }

    pub fn weights(&self) -> &ModelWeights {
        self.weights.get()
    }
}

impl Backend for NativeBackend<'_> {
    fn cfg(&self) -> &ModelConfig {
        &self.cfg
    }

    fn label(&self) -> &'static str {
        "native"
    }

    fn capabilities(&self) -> Capabilities {
        Capabilities {
            full_forward: true,
            decode: true,
            fixed_seq_len: None,
            sub_1bit_storage: false,
            // dense `proj` (matmul_bt) is not row-wise bit-consistent with
            // `proj_vec` (matvec), so native keeps per-session stepping
            fused_decode: false,
            // chunked prefill stays bit-exact here: the default
            // `proj_chunk_into` seam routes every chunk row through the
            // same `matvec` the decode step uses
            chunked_prefill: true,
            paged_kv: true,
        }
    }

    fn forward(&self, tokens: &[u8]) -> Result<Mat> {
        Ok(transformer::model_fwd(&self.cfg, self.weights.get(), tokens))
    }

    fn begin_decode(&self, capacity: usize) -> Result<Box<dyn DecodeSession + '_>> {
        Ok(Box::new(NativeSession { be: self, st: DecodeState::new(&self.cfg, capacity) }))
    }

    fn begin_decode_with(&self, opts: &SessionOpts<'_>) -> Result<Box<dyn DecodeSession + '_>> {
        let st = match &opts.pool {
            Some(pool) => DecodeState::new_paged(&self.cfg, opts.capacity, pool, opts.prompt)?,
            None => DecodeState::new(&self.cfg, opts.capacity),
        };
        Ok(Box::new(NativeSession { be: self, st }))
    }
}

struct NativeSession<'a, 'w> {
    be: &'a NativeBackend<'w>,
    st: DecodeState,
}

impl DecodeSession for NativeSession<'_, '_> {
    fn step(&mut self, token: u8) -> Result<Vec<f32>> {
        Ok(self.st.step(&self.be.cfg, self.be.weights.get(), token))
    }

    fn prefill(&mut self, tokens: &[u8], all_logits: bool) -> Result<Mat> {
        Ok(self.st.prefill_chunk(&self.be.cfg, self.be.weights.get(), tokens, all_logits))
    }

    fn pos(&self) -> usize {
        self.st.pos
    }

    fn state_mut(&mut self) -> Option<&mut DecodeState> {
        Some(&mut self.st)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_matches_model_fwd_and_decode_agrees() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 11);
        let be = NativeBackend::borrowed(&cfg, &w);
        let toks: Vec<u8> = vec![5, 3, 8, 1, 9, 2];
        let full = be.forward(&toks).unwrap();
        assert_eq!((full.rows, full.cols), (toks.len(), cfg.vocab));

        let mut sess = be.begin_decode(16).unwrap();
        let mut last = Vec::new();
        for &t in &toks {
            last = sess.step(t).unwrap();
        }
        assert_eq!(sess.pos(), toks.len());
        for (a, b) in last.iter().zip(full.row(toks.len() - 1)) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    /// Native chunked prefill must bit-match per-token stepping — the
    /// default `proj_chunk_into` seam reuses the decode row kernel.
    #[test]
    fn session_prefill_bitmatches_stepping() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 14);
        let be = NativeBackend::borrowed(&cfg, &w);
        assert!(be.capabilities().chunked_prefill);
        let toks: Vec<u8> = vec![5, 3, 8, 1, 9, 2, 7];
        let mut stepper = be.begin_decode(16).unwrap();
        let want: Vec<Vec<f32>> = toks.iter().map(|&t| stepper.step(t).unwrap()).collect();
        let mut chunked = be.begin_decode(16).unwrap();
        let lg = chunked.prefill(&toks, true).unwrap();
        assert_eq!(lg.rows, toks.len());
        for (r, wrow) in want.iter().enumerate() {
            assert_eq!(lg.row(r), &wrow[..], "row {r} must bit-match stepping");
        }
        assert_eq!(chunked.pos(), toks.len());
    }

    #[test]
    fn shared_weights_alias_one_allocation() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = Arc::new(ModelWeights::synthetic(&cfg, 12));
        let be = NativeBackend::shared(cfg, w.clone());
        assert_eq!(Arc::strong_count(&w), 2);
        assert!(std::ptr::eq(be.weights(), w.as_ref()));
    }
}
