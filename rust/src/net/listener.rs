//! Connection acceptor with a bounded worker pool.
//!
//! The accept loop runs nonblocking (polling the drain flag between
//! accepts) and hands each connection to one of `threads` scoped workers
//! through a bounded queue — a connection flood blocks in the kernel
//! backlog instead of spawning unbounded threads. Draining
//! ([`crate::net::gateway::GatewayCtl::drain`]) stops the accept loop; the
//! workers finish the connections already handed to them and exit when the
//! queue closes.

use std::io;
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::Mutex;
use std::time::Duration;

use anyhow::Result;

use crate::net::gateway::GatewayCtl;

/// How often the accept loop re-checks the drain flag while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// Accept connections on `listener` and run `handler` on each, using a
/// pool of `threads` scoped workers. Returns once [`GatewayCtl::drain`]
/// fires and every worker has finished its in-flight connections.
pub fn serve_connections<H>(
    listener: TcpListener,
    ctl: &GatewayCtl,
    threads: usize,
    handler: H,
) -> Result<()>
where
    H: Fn(TcpStream) + Sync,
{
    listener.set_nonblocking(true)?;
    let threads = threads.max(1);
    let (tx, rx) = mpsc::sync_channel::<TcpStream>(threads * 2);
    let rx = Mutex::new(rx);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| worker(&rx, ctl, &handler));
        }
        let r = accept_loop(&listener, ctl, &tx);
        // closing the queue is what lets the workers exit; it must happen
        // on the error path too, or the scope would join forever
        drop(tx);
        r
    })
}

fn accept_loop(
    listener: &TcpListener,
    ctl: &GatewayCtl,
    tx: &mpsc::SyncSender<TcpStream>,
) -> Result<()> {
    while !ctl.is_draining() {
        match listener.accept() {
            Ok((stream, _peer)) => {
                ctl.stats().connections.inc();
                if tx.send(stream).is_err() {
                    break; // workers gone — nothing left to hand off to
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

fn worker<H: Fn(TcpStream)>(rx: &Mutex<mpsc::Receiver<TcpStream>>, ctl: &GatewayCtl, handler: &H) {
    loop {
        // hold the lock only while waiting for a connection, never while
        // handling one — otherwise the pool serializes. The lock is never
        // held across `handler`, so a poisoned mutex means another worker
        // panicked BETWEEN recv and drop — count it rather than silently
        // shrinking the pool.
        let stream = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(poisoned) => poisoned.into_inner().recv(),
        };
        match stream {
            Ok(s) => {
                // a panicking handler must not take the worker (or, through
                // the scope, the whole gateway) down with it — catch it,
                // count it, keep serving
                if catch_unwind(AssertUnwindSafe(|| handler(s))).is_err() {
                    ctl.note_handler_panic();
                }
            }
            Err(_) => return, // queue closed: drain complete
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use std::io::{Read, Write};
    use std::net::TcpStream;

    /// Real-socket smoke: connections are served concurrently by the pool
    /// and `drain` shuts the acceptor down cleanly.
    #[test]
    fn serves_connections_then_drains() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let ctl = GatewayCtl::new();
        let ctl2 = ctl.clone();
        std::thread::scope(|s| {
            let server = s.spawn(move || {
                serve_connections(listener, &ctl2, 2, |mut stream| {
                    let mut byte = [0u8; 1];
                    stream.read_exact(&mut byte).unwrap();
                    stream.write_all(&[byte[0] + 1]).unwrap();
                })
                .unwrap();
            });
            for i in 0..5u8 {
                let mut c = TcpStream::connect(addr).unwrap();
                c.write_all(&[i]).unwrap();
                let mut reply = [0u8; 1];
                c.read_exact(&mut reply).unwrap();
                assert_eq!(reply[0], i + 1);
            }
            ctl.drain();
            server.join().unwrap();
        });
        assert_eq!(ctl.stats().connections.get(), 5);
    }

    /// A handler panic must not kill the worker pool: the panic is counted
    /// in `handler_panics` and the NEXT connection is still served.
    #[test]
    fn handler_panic_is_counted_and_pool_survives() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let ctl = GatewayCtl::new();
        let ctl2 = ctl.clone();
        std::thread::scope(|s| {
            let server = s.spawn(move || {
                serve_connections(listener, &ctl2, 1, |mut stream| {
                    let mut byte = [0u8; 1];
                    stream.read_exact(&mut byte).unwrap();
                    if byte[0] == 0xFF {
                        panic!("injected handler panic");
                    }
                    stream.write_all(&[byte[0] + 1]).unwrap();
                })
                .unwrap();
            });
            // first connection panics the (single) worker's handler
            let mut bad = TcpStream::connect(addr).unwrap();
            bad.write_all(&[0xFF]).unwrap();
            let mut sink = Vec::new();
            bad.read_to_end(&mut sink).ok(); // server closes without reply
            // the same worker must still serve the next connection
            let mut good = TcpStream::connect(addr).unwrap();
            good.write_all(&[7]).unwrap();
            let mut reply = [0u8; 1];
            good.read_exact(&mut reply).unwrap();
            assert_eq!(reply[0], 8);
            ctl.drain();
            server.join().unwrap();
        });
        assert_eq!(ctl.stats().handler_panics.get(), 1);
    }
}
