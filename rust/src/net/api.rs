//! The versioned `/generate` wire schema: one typed parse/serialize pair
//! shared by the gateway (server side), the load generator, the chaos
//! harness and the integration tests (client side).
//!
//! Before this module, the request body and the stream-event JSON were
//! hand-rolled at every call site; a field change had to be replayed in
//! five places. Now [`GenerateRequest`] and [`GenerateEvent`] are the only
//! encode/decode path.
//!
//! Versioning contract:
//!
//! * Requests MAY carry `"schema": 3` (the current version). A missing
//!   `schema` field is accepted for back-compatibility with pre-redesign
//!   clients; any other value is refused with a typed 400
//!   ([`ApiError::UnsupportedSchema`]).
//! * Unknown fields are ignored on both requests and events, so additive
//!   evolution never breaks an older peer.
//! * Parse failures are typed ([`ApiError`]) and render to the exact 400
//!   message the gateway returns — clients can match on text they can
//!   also produce locally.

use std::time::Duration;

use crate::util::json::{num, obj, s, Json};

/// The `/generate` wire-schema version this build speaks.
pub const API_SCHEMA_VERSION: usize = 3;

/// Upper bound on `max_new` accepted over HTTP.
pub const MAX_MAX_NEW: usize = 4096;
/// `max_new` when the request omits it.
pub const DEFAULT_MAX_NEW: usize = 16;

/// Why a request body (or a stream event) failed to parse. Rendering via
/// `Display` gives the exact 400 body the gateway answers with.
#[derive(Clone, Debug, PartialEq)]
pub enum ApiError {
    /// The body is not valid UTF-8.
    NotUtf8,
    /// The body is not valid JSON.
    BadJson(String),
    /// The request names a schema version this build does not speak.
    UnsupportedSchema(f64),
    /// No `"prompt"` field (string or token array).
    MissingPrompt,
    /// The prompt is present but empty.
    EmptyPrompt,
    /// A prompt-array entry is not an integer in `0..=255`.
    BadPromptToken(f64),
    /// `max_new` is not an integer in `1..=MAX_MAX_NEW`.
    BadMaxNew,
    /// `deadline_ms` is not a non-negative number.
    BadDeadline,
    /// A stream line is not a recognizable [`GenerateEvent`].
    BadEvent(String),
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ApiError::NotUtf8 => write!(f, "body is not utf-8"),
            ApiError::BadJson(e) => write!(f, "bad json: {e}"),
            ApiError::UnsupportedSchema(v) => {
                write!(f, "unsupported schema {v} (this server speaks schema {API_SCHEMA_VERSION})")
            }
            ApiError::MissingPrompt => write!(f, "missing \"prompt\" (string or token array)"),
            ApiError::EmptyPrompt => write!(f, "empty prompt"),
            ApiError::BadPromptToken(n) => write!(f, "prompt token {n} out of range 0..=255"),
            ApiError::BadMaxNew => {
                write!(f, "max_new must be an integer in 1..={MAX_MAX_NEW}")
            }
            ApiError::BadDeadline => write!(f, "deadline_ms must be a non-negative number"),
            ApiError::BadEvent(line) => write!(f, "unrecognized stream event: {line}"),
        }
    }
}

impl std::error::Error for ApiError {}

/// A `/generate` prompt: either free text (byte-tokenized server-side) or
/// explicit token ids.
#[derive(Clone, Debug, PartialEq)]
pub enum Prompt {
    /// Byte-tokenized server-side, wrapped into the model vocabulary.
    Text(String),
    /// Explicit token ids, each `0..=255` on the wire.
    Tokens(Vec<u8>),
}

/// A typed, versioned `/generate` request — the only request body shape
/// the gateway parses and the only one in-tree clients produce.
#[derive(Clone, Debug, PartialEq)]
pub struct GenerateRequest {
    /// What to prefill.
    pub prompt: Prompt,
    /// Tokens to generate; `None` = server default ([`DEFAULT_MAX_NEW`]).
    pub max_new: Option<usize>,
    /// Per-request deadline in milliseconds from admission.
    pub deadline_ms: Option<u64>,
}

impl GenerateRequest {
    /// A text-prompt request.
    pub fn text(prompt: &str, max_new: usize) -> GenerateRequest {
        GenerateRequest {
            prompt: Prompt::Text(prompt.to_string()),
            max_new: Some(max_new),
            deadline_ms: None,
        }
    }

    /// A token-prompt request.
    pub fn tokens(toks: Vec<u8>, max_new: usize) -> GenerateRequest {
        GenerateRequest { prompt: Prompt::Tokens(toks), max_new: Some(max_new), deadline_ms: None }
    }

    /// Attach a deadline.
    pub fn with_deadline_ms(mut self, ms: u64) -> GenerateRequest {
        self.deadline_ms = Some(ms);
        self
    }

    /// Serialize to the schema-3 request body.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("schema", num(API_SCHEMA_VERSION as f64))];
        match &self.prompt {
            Prompt::Text(t) => fields.push(("prompt", s(t))),
            Prompt::Tokens(toks) => fields.push((
                "prompt",
                Json::Arr(toks.iter().map(|&t| num(t as f64)).collect()),
            )),
        }
        if let Some(n) = self.max_new {
            fields.push(("max_new", num(n as f64)));
        }
        if let Some(ms) = self.deadline_ms {
            fields.push(("deadline_ms", num(ms as f64)));
        }
        obj(fields)
    }

    /// The request body bytes (what goes on the wire).
    pub fn to_body(&self) -> String {
        self.to_json().dump()
    }

    /// Parse and validate a request body. Unknown fields are ignored; a
    /// missing `schema` is accepted (pre-versioning clients), any value
    /// other than [`API_SCHEMA_VERSION`] is a typed refusal.
    pub fn parse(body: &[u8]) -> Result<GenerateRequest, ApiError> {
        let text = std::str::from_utf8(body).map_err(|_| ApiError::NotUtf8)?;
        let doc = Json::parse(text).map_err(ApiError::BadJson)?;
        if let Some(v) = doc.get("schema") {
            match v.as_f64() {
                Some(n) if n == API_SCHEMA_VERSION as f64 => {}
                Some(n) => return Err(ApiError::UnsupportedSchema(n)),
                None => return Err(ApiError::UnsupportedSchema(f64::NAN)),
            }
        }
        let prompt = match doc.get("prompt") {
            Some(Json::Str(t)) if !t.is_empty() => Prompt::Text(t.clone()),
            Some(Json::Arr(items)) if !items.is_empty() => {
                let mut toks = Vec::with_capacity(items.len());
                for item in items {
                    let n = item.as_f64().ok_or(ApiError::BadPromptToken(f64::NAN))?;
                    if !(0.0..=255.0).contains(&n) || n.fract() != 0.0 {
                        return Err(ApiError::BadPromptToken(n));
                    }
                    toks.push(n as u8);
                }
                Prompt::Tokens(toks)
            }
            Some(Json::Str(_)) | Some(Json::Arr(_)) => return Err(ApiError::EmptyPrompt),
            _ => return Err(ApiError::MissingPrompt),
        };
        let max_new = match doc.get("max_new") {
            None => None,
            Some(v) => match v.as_f64() {
                Some(n) if (1.0..=MAX_MAX_NEW as f64).contains(&n) && n.fract() == 0.0 => {
                    Some(n as usize)
                }
                _ => return Err(ApiError::BadMaxNew),
            },
        };
        let deadline_ms = match doc.get("deadline_ms") {
            None => None,
            Some(v) => match v.as_f64() {
                Some(ms) if ms >= 0.0 => Some(ms as u64),
                _ => return Err(ApiError::BadDeadline),
            },
        };
        Ok(GenerateRequest { prompt, max_new, deadline_ms })
    }

    /// The prompt as model tokens, wrapped into a vocabulary of `vocab`.
    pub fn prompt_tokens(&self, vocab: usize) -> Vec<u8> {
        let vocab = vocab.max(1) as u32;
        match &self.prompt {
            Prompt::Text(t) => t.bytes().map(|b| (b as u32 % vocab) as u8).collect(),
            Prompt::Tokens(toks) => toks.iter().map(|&t| (t as u32 % vocab) as u8).collect(),
        }
    }

    /// `max_new` with the server default applied.
    pub fn effective_max_new(&self) -> usize {
        self.max_new.unwrap_or(DEFAULT_MAX_NEW)
    }

    /// The deadline as a `Duration` from admission, if any.
    pub fn deadline(&self) -> Option<Duration> {
        self.deadline_ms.map(Duration::from_millis)
    }
}

/// The terminal accounting of a finished stream, as it appears on the
/// wire (the `{"done":true,...}` line).
#[derive(Clone, Debug, PartialEq)]
pub struct DoneEvent {
    /// Tokens generated (may be short of `max_new` on a deadline stop).
    pub generated: usize,
    /// Seconds from admission to first token.
    pub ttft_s: f64,
    /// Seconds from admission to the end of the stream.
    pub latency_s: f64,
    /// Stop-reason label: `"completed"` or `"deadline"`.
    pub stopped: String,
    /// Per-request trace summary (absent only if the server elides it).
    pub trace: Option<Json>,
}

/// One line of a `/generate` stream: zero or more `Token`s, then exactly
/// one `Done` (or `Error` on a mid-stream fault).
#[derive(Clone, Debug, PartialEq)]
pub enum GenerateEvent {
    /// One generated token: `{"t":N}`.
    Token(u8),
    /// The stream ended: `{"done":true,...}`.
    Done(DoneEvent),
    /// A terminal error document: `{"error":"..."}`.
    Error(String),
}

impl GenerateEvent {
    /// Serialize to the exact wire line (no trailing newline — the
    /// framing layer owns that).
    pub fn to_line(&self) -> String {
        match self {
            // token lines are the hot path: formatted directly
            GenerateEvent::Token(t) => format!("{{\"t\":{t}}}"),
            GenerateEvent::Done(d) => {
                let mut fields = vec![
                    ("done", Json::Bool(true)),
                    ("generated", num(d.generated as f64)),
                    ("ttft_s", num(d.ttft_s)),
                    ("latency_s", num(d.latency_s)),
                    ("stopped", s(&d.stopped)),
                ];
                if let Some(trace) = &d.trace {
                    fields.push(("trace", trace.clone()));
                }
                obj(fields).dump()
            }
            GenerateEvent::Error(msg) => obj(vec![("error", s(msg))]).dump(),
        }
    }

    /// Parse one stream line. Tolerant of unknown fields; a line that is
    /// neither a token, a done document nor an error is a typed failure.
    pub fn parse(line: &str) -> Result<GenerateEvent, ApiError> {
        let doc = Json::parse(line.trim()).map_err(ApiError::BadJson)?;
        if let Some(t) = doc.get("t") {
            let n = t.as_f64().ok_or_else(|| ApiError::BadEvent(line.to_string()))?;
            if !(0.0..=255.0).contains(&n) || n.fract() != 0.0 {
                return Err(ApiError::BadEvent(line.to_string()));
            }
            return Ok(GenerateEvent::Token(n as u8));
        }
        if doc.get("done").is_some() {
            let f = |k: &str| doc.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            return Ok(GenerateEvent::Done(DoneEvent {
                generated: f("generated") as usize,
                ttft_s: f("ttft_s"),
                latency_s: f("latency_s"),
                stopped: doc
                    .get("stopped")
                    .and_then(Json::as_str)
                    .unwrap_or("completed")
                    .to_string(),
                trace: doc.get("trace").cloned(),
            }));
        }
        if let Some(e) = doc.get("error") {
            return Ok(GenerateEvent::Error(
                e.as_str().map(str::to_string).unwrap_or_else(|| e.dump()),
            ));
        }
        Err(ApiError::BadEvent(line.to_string()))
    }
}

/// Split a streamed body buffer into complete JSON lines, returning the
/// unconsumed tail. Chunked transfer can split a line across reads; the
/// client keeps the tail and re-feeds it with the next chunk.
pub fn split_lines(buf: &str) -> (Vec<&str>, &str) {
    match buf.rfind('\n') {
        Some(last) => {
            let lines = buf[..last].lines().filter(|l| !l.trim().is_empty()).collect();
            (lines, &buf[last + 1..])
        }
        None => (Vec::new(), buf),
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn request_roundtrips_through_the_wire_body() {
        for req in [
            GenerateRequest::text("once upon a time", 32),
            GenerateRequest::tokens(vec![1, 2, 255], 8).with_deadline_ms(250),
            GenerateRequest { prompt: Prompt::Text("x".into()), max_new: None, deadline_ms: None },
        ] {
            let body = req.to_body();
            assert!(body.contains("\"schema\":3"), "{body}");
            let back = GenerateRequest::parse(body.as_bytes()).unwrap();
            assert_eq!(back, req);
        }
    }

    #[test]
    fn missing_schema_is_accepted_other_versions_refused() {
        assert!(GenerateRequest::parse(br#"{"prompt": "hi"}"#).is_ok());
        assert!(GenerateRequest::parse(br#"{"prompt": "hi", "schema": 3}"#).is_ok());
        for bad in [br#"{"prompt": "hi", "schema": 2}"#.as_slice(),
            br#"{"prompt": "hi", "schema": 4}"#,
            br#"{"prompt": "hi", "schema": "3"}"#]
        {
            match GenerateRequest::parse(bad) {
                Err(ApiError::UnsupportedSchema(_)) => {}
                other => panic!("expected UnsupportedSchema, got {other:?}"),
            }
        }
    }

    #[test]
    fn unknown_fields_are_ignored() {
        let req = GenerateRequest::parse(
            br#"{"prompt": [7], "max_new": 2, "stream_style": "fancy", "client": {"v": 9}}"#,
        )
        .unwrap();
        assert_eq!(req.prompt, Prompt::Tokens(vec![7]));
        assert_eq!(req.max_new, Some(2));
    }

    #[test]
    fn typed_request_errors() {
        for (body, want) in [
            (&b"\xff\xfe"[..], ApiError::NotUtf8),
            (b"not json", ApiError::BadJson(String::new())),
            (br#"{}"#, ApiError::MissingPrompt),
            (br#"{"prompt": ""}"#, ApiError::EmptyPrompt),
            (br#"{"prompt": []}"#, ApiError::EmptyPrompt),
            (br#"{"prompt": [300]}"#, ApiError::BadPromptToken(300.0)),
            (br#"{"prompt": "a", "max_new": 0}"#, ApiError::BadMaxNew),
            (br#"{"prompt": "a", "max_new": 99999}"#, ApiError::BadMaxNew),
            (br#"{"prompt": "a", "deadline_ms": -5}"#, ApiError::BadDeadline),
        ] {
            let got = GenerateRequest::parse(body).unwrap_err();
            assert_eq!(
                std::mem::discriminant(&got),
                std::mem::discriminant(&want),
                "body {body:?}: got {got:?}"
            );
            assert!(!got.to_string().is_empty());
        }
    }

    #[test]
    fn prompt_tokens_wrap_into_the_vocab() {
        assert_eq!(GenerateRequest::text("hi", 1).prompt_tokens(32), vec![b'h' % 32, b'i' % 32]);
        assert_eq!(GenerateRequest::tokens(vec![1, 40], 1).prompt_tokens(32), vec![1, 8]);
        assert_eq!(GenerateRequest::text("a", 1).effective_max_new(), 1);
        let dflt = GenerateRequest { prompt: Prompt::Text("a".into()), max_new: None, deadline_ms: None };
        assert_eq!(dflt.effective_max_new(), DEFAULT_MAX_NEW);
    }

    #[test]
    fn events_roundtrip_and_tolerate_unknown_fields() {
        let tok = GenerateEvent::Token(42);
        assert_eq!(tok.to_line(), r#"{"t":42}"#);
        assert_eq!(GenerateEvent::parse(&tok.to_line()).unwrap(), tok);

        let done = GenerateEvent::Done(DoneEvent {
            generated: 8,
            ttft_s: 0.25,
            latency_s: 0.5,
            stopped: "completed".into(),
            trace: Some(obj(vec![("total_ms", num(3.0))])),
        });
        assert_eq!(GenerateEvent::parse(&done.to_line()).unwrap(), done);

        let err = GenerateEvent::Error("kv pool exhausted, retry".into());
        assert_eq!(GenerateEvent::parse(&err.to_line()).unwrap(), err);

        // additive fields on a future server must not break this client
        let future = r#"{"t": 7, "replica": 3}"#;
        assert_eq!(GenerateEvent::parse(future).unwrap(), GenerateEvent::Token(7));
        match GenerateEvent::parse(r#"{"mystery": true}"#) {
            Err(ApiError::BadEvent(_)) => {}
            other => panic!("expected BadEvent, got {other:?}"),
        }
    }

    #[test]
    fn split_lines_keeps_partial_tail() {
        let (lines, tail) = split_lines("{\"t\":1}\n{\"t\":2}\n{\"do");
        assert_eq!(lines, vec![r#"{"t":1}"#, r#"{"t":2}"#]);
        assert_eq!(tail, "{\"do");
        let (lines, tail) = split_lines("no newline yet");
        assert!(lines.is_empty());
        assert_eq!(tail, "no newline yet");
    }
}
