//! The bridge between HTTP handlers and the continuous batch decode loop.
//!
//! One bridge worker thread owns the decode side: it ingests
//! [`StreamRequest`]s from a bounded channel, admits them through the SAME
//! [`BatchServer`] admission path (`top_up`: KV reservation, head-of-line
//! aging) and steps the SAME scheduling kernel (`tick`: one fused
//! `decode_batch` per round) that [`BatchServer::run`] uses — which is why
//! tokens streamed over the network are byte-identical to a direct batch
//! run of the same workload.
//!
//! Per-request extras the batch path does not have:
//!
//! * **Streaming** — every generated token is forwarded on the request's
//!   [`StreamEvent`] channel the tick it retires from the decode loop.
//! * **Cancellation** — when the receiving side hangs up (HTTP client
//!   disconnected), the next token send fails, the session is dropped on
//!   the spot and its KV pages return to the pool.
//! * **Deadlines** — a request past its deadline is finished early with
//!   [`StopReason::Deadline`]; queued requests past their deadline never
//!   start.
//! * **Drain** — once every [`StreamRequest`] sender is gone, the worker
//!   finishes all in-flight sequences and exits; with a paged pool, zero
//!   reserved pages remain (asserted by the gateway's drain report).

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::kvpool::KvPool;
use crate::coordinator::server::{BatchServer, Queued, Request, ServeError};
use crate::engine::Backend;
use crate::net::gateway::GatewayCtl;
use crate::net::router::{Router, Seat};
use crate::net::stats::StopReason;
use crate::obs::TraceSummary;

/// Default panic restarts per bridge worker before its supervisor gives
/// up (see `net::gateway::supervise_bridge`).
pub const MAX_BRIDGE_RESTARTS: usize = 8;

/// A generation request entering the bridge, with its event channel.
pub struct StreamRequest {
    /// Prompt tokens to prefill.
    pub prompt: Vec<u8>,
    /// Tokens to generate after the prompt.
    pub max_new: usize,
    /// Absolute deadline; `None` = no limit.
    pub deadline: Option<Instant>,
    /// Where the bridge delivers [`StreamEvent`]s. Dropping the receiver
    /// cancels the stream (the session's KV pages are released).
    pub tx: mpsc::Sender<StreamEvent>,
}

/// Events delivered on a stream's channel, in order: zero or more
/// `Token`s, then exactly one `Done` or `Rejected`.
#[derive(Clone, Debug)]
pub enum StreamEvent {
    /// One generated token.
    Token(u8),
    /// The stream ended (completed or deadline-stopped).
    Done(DoneInfo),
    /// Admission refused the request (it can never fit the KV budget).
    Rejected(String),
}

/// Terminal accounting for one stream.
#[derive(Clone, Copy, Debug)]
pub struct DoneInfo {
    /// Tokens generated (may be short of `max_new` on deadline stop).
    pub generated: usize,
    /// Seconds from admission to first generated token.
    pub ttft_s: f64,
    /// Seconds from admission to the end of the stream.
    pub latency_s: f64,
    /// Why the stream stopped.
    pub stopped: StopReason,
    /// Per-stage breakdown of the request's life (enqueue → retirement).
    pub trace: TraceSummary,
}

/// Decode-side configuration of the bridge worker.
#[derive(Clone)]
pub struct BridgeOpts {
    /// Max concurrently decoding sequences (continuous batching width).
    pub max_batch: usize,
    /// Shared paged KV pool; `None` = flat per-session buffers.
    pub pool: Option<Arc<KvPool>>,
    /// Head-of-line age boost threshold (see
    /// [`BatchServer::hol_boost_deferrals`]).
    pub hol_boost_deferrals: u32,
    /// Per-tick prefill-token budget per session (see
    /// [`BatchServer::prefill_chunk`]; 1 = legacy one-token-per-tick).
    pub prefill_chunk: usize,
    /// Panic restarts before the supervisor gives up on this worker.
    pub max_restarts: usize,
}

impl BridgeOpts {
    /// Flat-KV bridge with the default aging threshold.
    pub fn new(max_batch: usize) -> BridgeOpts {
        BridgeOpts {
            max_batch,
            pool: None,
            hol_boost_deferrals: crate::coordinator::server::DEFAULT_HOL_BOOST_DEFERRALS,
            prefill_chunk: crate::coordinator::server::DEFAULT_PREFILL_CHUNK,
            max_restarts: MAX_BRIDGE_RESTARTS,
        }
    }

    /// Attach a shared KV pool.
    pub fn with_pool(mut self, pool: Arc<KvPool>) -> BridgeOpts {
        self.pool = Some(pool);
        self
    }
}

struct Meta {
    tx: mpsc::Sender<StreamEvent>,
    deadline: Option<Instant>,
}

/// How long the worker sleeps on the request channel when fully idle.
const IDLE_POLL: Duration = Duration::from_millis(20);

/// Run the bridge worker until every request sender is dropped and all
/// admitted work has finished (graceful drain). Normally called on a
/// dedicated thread — by the gateway (`net::gateway::serve_http`) or via
/// [`serve_stream`] — under the gateway's panic supervisor, which is why
/// the receiver is borrowed: the channel (and any requests still queued on
/// it) survives a panic-unwind of this function, so a restarted bridge
/// picks up where the crashed one left off.
pub fn run_bridge(
    backend: &dyn Backend,
    opts: &BridgeOpts,
    rx: &mpsc::Receiver<StreamRequest>,
    ctl: &GatewayCtl,
    seat: &Seat,
) -> Result<()> {
    // the gateway's registry backs the server's stage histograms and the
    // pool's counter mirror, so `GET /metrics` sees all three layers
    let mut server =
        BatchServer::new(backend, opts.max_batch.max(1)).with_registry(ctl.registry());
    server.hol_boost_deferrals = opts.hol_boost_deferrals;
    server.prefill_chunk = opts.prefill_chunk.max(1);
    if let Some(pool) = &opts.pool {
        server = server.with_pool(pool.clone());
    }

    let mut queue: VecDeque<Queued> = VecDeque::new();
    let mut active = Vec::new();
    let mut meta: HashMap<u64, Meta> = HashMap::new();
    let mut next_id = 0u64;
    let mut senders_gone = false;
    let mut tick_no = 0u64;

    loop {
        // 1. ingest: drain everything queued on the channel; block briefly
        //    only when there is no decode work at all
        if !senders_gone && active.is_empty() && queue.is_empty() {
            match rx.recv_timeout(IDLE_POLL) {
                Ok(sr) => enqueue(sr, &mut next_id, &mut queue, &mut meta, ctl, seat),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => senders_gone = true,
            }
        }
        if !senders_gone {
            loop {
                match rx.try_recv() {
                    Ok(sr) => enqueue(sr, &mut next_id, &mut queue, &mut meta, ctl, seat),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => {
                        senders_gone = true;
                        break;
                    }
                }
            }
        }

        let now = Instant::now();

        // 2. queued requests whose deadline already passed never start;
        //    their spans close with pure queue-wait traces
        let any_expired = queue.iter().any(|q| {
            meta.get(&q.req.id).and_then(|m| m.deadline).is_some_and(|d| now >= d)
        });
        if any_expired {
            for q in std::mem::take(&mut queue) {
                let expired =
                    meta.get(&q.req.id).and_then(|m| m.deadline).is_some_and(|d| now >= d);
                if !expired {
                    queue.push_back(q);
                    continue;
                }
                if let Some(m) = meta.remove(&q.req.id) {
                    let _ = m.tx.send(StreamEvent::Done(DoneInfo {
                        generated: 0,
                        ttft_s: 0.0,
                        latency_s: 0.0,
                        stopped: StopReason::Deadline,
                        trace: q.span.finish(now),
                    }));
                }
                ctl.stats().deadline_expired.inc();
            }
        }

        // 3. admission (shared with BatchServer::run — reservation +
        //    head-of-line aging)
        let up = server.top_up(&mut queue, &mut active)?;
        if up.deferred_events > 0 || !up.rejected.is_empty() {
            ctl.stats().deferred.add(up.deferred_events as u64);
            ctl.stats().rejected.add(up.rejected.len() as u64);
        }
        for e in up.rejected {
            let ServeError::RequestTooLarge { id, .. } = &e;
            if let Some(m) = meta.remove(id) {
                let _ = m.tx.send(StreamEvent::Rejected(e.to_string()));
            }
        }

        seat.set_load(active.len(), queue.len());
        ctl.republish_gauges();

        if active.is_empty() {
            if senders_gone && queue.is_empty() {
                break; // drained: nothing in flight, nothing can arrive
            }
            if !queue.is_empty() {
                // deferred head waiting on another server of a shared pool
                std::thread::yield_now();
            }
            continue;
        }

        // 4. active requests past their deadline finish early with
        //    whatever they produced; their sessions (and KV pages) drop now
        let expired: Vec<usize> = active
            .iter()
            .enumerate()
            .filter(|(_, a)| {
                meta.get(&a.req.id)
                    .and_then(|m| m.deadline)
                    .is_some_and(|d| now >= d)
            })
            .map(|(i, _)| i)
            .collect();
        for &slot in expired.iter().rev() {
            let a = active.swap_remove(slot);
            let lat = a.submitted.elapsed().as_secs_f64();
            if let Some(m) = meta.remove(&a.req.id) {
                let _ = m.tx.send(StreamEvent::Done(DoneInfo {
                    generated: a.produced.len(),
                    ttft_s: a.first_token.unwrap_or(lat),
                    latency_s: lat,
                    stopped: StopReason::Deadline,
                    trace: a.finish_span(Instant::now()),
                }));
            }
            ctl.stats().deadline_expired.inc();
        }
        if active.is_empty() {
            continue;
        }

        // 5. ONE scheduling tick (the shared kernel) + forward each token
        //    as it retires; a failed send = client hung up = cancel.
        //    The tick hook fires first — the chaos harness injects bridge
        //    panics here, and an unwind at this point drops every in-flight
        //    session (KV pages return to the pool, stream senders vanish).
        ctl.fire_tick_hook(seat.id() as u64, tick_no);
        tick_no += 1;
        let t = server.tick(&mut active)?;
        if !t.emitted.is_empty() {
            ctl.stats().generated_tokens.add(t.emitted.len() as u64);
        }
        let mut removals: BTreeMap<usize, bool> = BTreeMap::new(); // slot -> deliver Done
        for &f in &t.finished {
            removals.insert(f, true);
        }
        for &(slot, tok) in &t.emitted {
            let id = active[slot].req.id;
            let gone = match meta.get(&id) {
                Some(m) => m.tx.send(StreamEvent::Token(tok)).is_err(),
                None => true,
            };
            if gone {
                removals.insert(slot, false); // cancellation wins over Done
            }
        }

        // 6. retire (descending slot order so swap_remove is stable);
        //    dropping the Active drops its session, returning KV pages
        for (&slot, &deliver) in removals.iter().rev() {
            let a = active.swap_remove(slot);
            let m = meta.remove(&a.req.id);
            if deliver {
                let now2 = Instant::now();
                let lat = now2.duration_since(a.submitted).as_secs_f64();
                let ttft = a.first_token.unwrap_or(lat);
                if let Some(m) = m {
                    let _ = m.tx.send(StreamEvent::Done(DoneInfo {
                        generated: a.produced.len(),
                        ttft_s: ttft,
                        latency_s: lat,
                        stopped: StopReason::Completed,
                        trace: a.finish_span(now2),
                    }));
                }
                ctl.stats().completed.inc();
                seat.note_completed();
                ctl.stats().record_finished(ttft, lat);
            } else {
                ctl.stats().cancelled.inc();
            }
        }
        seat.set_load(active.len(), queue.len());
        ctl.republish_gauges();
    }
    seat.set_load(0, 0);
    ctl.republish_gauges();
    Ok(())
}

fn enqueue(
    sr: StreamRequest,
    next_id: &mut u64,
    queue: &mut VecDeque<Queued>,
    meta: &mut HashMap<u64, Meta>,
    ctl: &GatewayCtl,
    seat: &Seat,
) {
    let id = *next_id;
    *next_id += 1;
    meta.insert(id, Meta { tx: sr.tx, deadline: sr.deadline });
    queue.push_back(Queued::new(Request { id, prompt: sr.prompt, max_new: sr.max_new.max(1) }));
    ctl.stats().streams_started.inc();
    ctl.stats().queued_g.add(1);
    seat.note_enqueued();
}

/// Channel facade: spawn a bridge worker thread owning `backend`; returns
/// the request sender. Dropping every sender clone drains the worker. This
/// is the in-process streaming API (the HTTP gateway is a network skin
/// over the same worker). The worker runs under the same panic supervisor
/// as the gateway's bridge: a panicking decode loop retires its in-flight
/// sessions and restarts instead of killing the thread.
pub fn serve_stream(
    backend: Box<dyn Backend + Send>,
    opts: BridgeOpts,
    ctl: GatewayCtl,
) -> (mpsc::SyncSender<StreamRequest>, std::thread::JoinHandle<Result<()>>) {
    let (tx, rx) = mpsc::sync_channel::<StreamRequest>(1024);
    // a single anonymous seat behind a one-replica router; the CALLER owns
    // the only request sender (the seat keeps none), so dropping the
    // returned sender remains the drain signal
    let seat = Arc::new(Seat::new(0, opts.pool.clone(), None, None));
    let router = Arc::new(Router::new(vec![seat], 0, &ctl.registry()));
    ctl.set_router(Some(router.clone()));
    let handle = std::thread::spawn(move || {
        crate::net::gateway::supervise_bridge(&*backend, &opts, &rx, &ctl, &router, 0)
    });
    (tx, handle)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use std::sync::atomic::Ordering;

    use crate::coordinator::server::{BatchServer, Request};
    use crate::engine::NativeBackend;
    use crate::model::config::ModelConfig;
    use crate::model::ModelWeights;

    fn tiny() -> (ModelConfig, ModelWeights) {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        (cfg.clone(), ModelWeights::synthetic(&cfg, 1))
    }

    fn drain_stream(rx: &mpsc::Receiver<StreamEvent>) -> (Vec<u8>, Option<DoneInfo>) {
        let mut toks = Vec::new();
        let mut done = None;
        while let Ok(ev) = rx.recv_timeout(Duration::from_secs(60)) {
            match ev {
                StreamEvent::Token(t) => toks.push(t),
                StreamEvent::Done(d) => {
                    done = Some(d);
                    break;
                }
                StreamEvent::Rejected(e) => panic!("unexpected rejection: {e}"),
            }
        }
        (toks, done)
    }

    /// Streamed tokens must be byte-identical to a direct batch run of the
    /// same workload — both paths run the same top_up/tick kernel.
    #[test]
    fn streamed_tokens_match_batch_run() {
        let (cfg, w) = tiny();
        let reqs: Vec<Request> = (0..3)
            .map(|id| Request { id, prompt: vec![1, 2, 3 + id as u8], max_new: 4 })
            .collect();
        let be = NativeBackend::borrowed(&cfg, &w);
        let (mut direct, _) = BatchServer::new(&be, 2).run(reqs.clone()).unwrap();
        direct.sort_by_key(|r| r.id);

        let ctl = GatewayCtl::new();
        let (tx, handle) = serve_stream(
            Box::new(NativeBackend::new(cfg, w)),
            BridgeOpts::new(2),
            ctl.clone(),
        );
        let mut rxs = Vec::new();
        for r in &reqs {
            let (etx, erx) = mpsc::channel();
            tx.send(StreamRequest {
                prompt: r.prompt.clone(),
                max_new: r.max_new,
                deadline: None,
                tx: etx,
            })
            .unwrap();
            rxs.push(erx);
        }
        for (r, erx) in reqs.iter().zip(&rxs) {
            let (toks, done) = drain_stream(erx);
            let want = &direct.iter().find(|d| d.id == r.id).unwrap().tokens;
            assert_eq!(&toks, want, "stream for req {} diverged from batch run", r.id);
            let d = done.expect("stream must end with Done");
            assert_eq!(d.stopped, StopReason::Completed);
            assert_eq!(d.generated, toks.len());
            assert!(d.latency_s >= d.ttft_s);
            // every done-event carries a closed span obeying the
            // conservative stage-accounting invariant
            assert!(d.trace.stages_within_total(0.5), "bad trace: {:?}", d.trace);
            assert!(d.trace.decode_ms > 0.0, "decode stage empty: {:?}", d.trace);
            assert!(d.trace.ticks >= 1);
        }
        drop(tx);
        handle.join().unwrap().unwrap();
        assert_eq!(ctl.stats().completed.get(), 3);
        assert_eq!(ctl.stats().generated_tokens.get(), 12);
        // the bridge's batch server shares the gateway registry: the
        // per-stage histograms must be populated in the exposition
        let text = ctl.registry().render_prometheus();
        for h in ["queue", "prefill", "decode", "kernel"] {
            let needle = format!("stbllm_server_{h}_seconds_count");
            let line = text
                .lines()
                .find(|l| l.starts_with(&needle))
                .unwrap_or_else(|| panic!("missing {needle} in:\n{text}"));
            let n: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n > 0.0, "empty stage histogram: {line}");
        }
    }

    /// Dropping a stream's receiver mid-generation must retire the session
    /// and return its KV pages to the pool (the serve-channel cancellation
    /// contract): the pool's unreserved page count fully recovers while
    /// other streams keep running.
    #[test]
    fn dropping_receiver_mid_stream_releases_kv_pages() {
        let (cfg, w) = tiny();
        let pool = Arc::new(KvPool::new(&cfg, 16, 4));
        let ctl = GatewayCtl::new();
        let (tx, handle) = serve_stream(
            Box::new(NativeBackend::new(cfg, w)),
            BridgeOpts::new(2).with_pool(pool.clone()),
            ctl.clone(),
        );
        // a long stream we will abandon mid-flight
        let (etx, erx) = mpsc::channel();
        tx.send(StreamRequest { prompt: vec![3, 1, 4, 1], max_new: 40, deadline: None, tx: etx })
            .unwrap();
        for _ in 0..3 {
            match erx.recv_timeout(Duration::from_secs(60)).unwrap() {
                StreamEvent::Token(_) => {}
                other => panic!("expected tokens first, got {other:?}"),
            }
        }
        assert!(pool.stats().pages_reserved > 0, "stream must hold a reservation");
        drop(erx); // client hangs up mid-stream
        // a short follow-up stream keeps the worker ticking and proves the
        // pool still serves after the cancellation
        let (etx2, erx2) = mpsc::channel();
        tx.send(StreamRequest { prompt: vec![5, 6], max_new: 2, deadline: None, tx: etx2 })
            .unwrap();
        let (toks, done) = drain_stream(&erx2);
        assert_eq!(toks.len(), 2);
        assert_eq!(done.unwrap().stopped, StopReason::Completed);
        // the cancelled session's reservation must come back
        let t0 = Instant::now();
        loop {
            if pool.stats().pages_reserved == 0 {
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "cancelled stream leaked its KV reservation: {:?}",
                pool.stats()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        drop(tx);
        handle.join().unwrap().unwrap();
        assert_eq!(ctl.stats().cancelled.get(), 1);
        assert_eq!(pool.stats().pages_reserved, 0, "drain must leave zero reserved pages");
    }

    /// An already-expired deadline stops the stream with partial (here
    /// zero) output and releases everything.
    #[test]
    fn expired_deadline_stops_stream() {
        let (cfg, w) = tiny();
        let pool = Arc::new(KvPool::new(&cfg, 16, 4));
        let ctl = GatewayCtl::new();
        let (tx, handle) = serve_stream(
            Box::new(NativeBackend::new(cfg, w)),
            BridgeOpts::new(2).with_pool(pool.clone()),
            ctl.clone(),
        );
        let (etx, erx) = mpsc::channel();
        tx.send(StreamRequest {
            prompt: vec![1, 2, 3],
            max_new: 8,
            deadline: Some(Instant::now() - Duration::from_millis(1)),
            tx: etx,
        })
        .unwrap();
        let (toks, done) = drain_stream(&erx);
        let d = done.expect("deadline stream must still end with Done");
        assert_eq!(d.stopped, StopReason::Deadline);
        assert!(toks.len() < 8, "an expired deadline cannot deliver the full request");
        drop(tx);
        handle.join().unwrap().unwrap();
        assert_eq!(ctl.stats().deadline_expired.get(), 1);
        assert_eq!(pool.stats().pages_reserved, 0);
    }

    /// A panic inside the decode loop must not kill the worker thread: the
    /// supervisor retires the in-flight sessions (pages back to the pool),
    /// restarts the bridge on the same channel, and later requests complete.
    #[test]
    fn bridge_panic_is_supervised_and_pages_recover() {
        let (cfg, w) = tiny();
        let pool = Arc::new(KvPool::new(&cfg, 16, 4));
        let ctl = GatewayCtl::new();
        // one-shot injected panic: fires on the first scheduler tick only
        let armed = Arc::new(std::sync::atomic::AtomicBool::new(true));
        let a2 = armed.clone();
        ctl.set_tick_hook(Some(Arc::new(move |_replica, _tick| {
            if a2.swap(false, Ordering::SeqCst) {
                panic!("injected bridge panic");
            }
        })));
        let (tx, handle) = serve_stream(
            Box::new(NativeBackend::new(cfg, w)),
            BridgeOpts::new(2).with_pool(pool.clone()),
            ctl.clone(),
        );
        // the victim stream dies with the crashed bridge: its sender is
        // dropped in the unwind, so the receiver disconnects without Done
        let (etx, erx) = mpsc::channel();
        tx.send(StreamRequest { prompt: vec![1, 2, 3], max_new: 8, deadline: None, tx: etx })
            .unwrap();
        let (_, done) = drain_stream(&erx);
        assert!(done.is_none(), "victim stream must end by disconnect, not Done");
        // the supervisor must have counted and restarted
        let t0 = Instant::now();
        while ctl.stats().bridge_restarts.get() == 0 {
            assert!(t0.elapsed() < Duration::from_secs(30), "bridge was not restarted");
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(ctl.stats().bridge_panics.get(), 1);
        // the restarted bridge serves new work on the SAME channel
        let (etx2, erx2) = mpsc::channel();
        tx.send(StreamRequest { prompt: vec![4, 5], max_new: 3, deadline: None, tx: etx2 })
            .unwrap();
        let (toks, done) = drain_stream(&erx2);
        assert_eq!(toks.len(), 3);
        assert_eq!(done.unwrap().stopped, StopReason::Completed);
        drop(tx);
        handle.join().unwrap().unwrap();
        assert_eq!(pool.stats().pages_reserved, 0, "crashed sessions leaked KV pages");
    }

    /// An impossible request is rejected with a typed message, not hung.
    #[test]
    fn oversized_request_rejected_on_stream() {
        let (cfg, w) = tiny();
        let pool = Arc::new(KvPool::new(&cfg, 2, 4)); // 8 token slots total
        let ctl = GatewayCtl::new();
        let (tx, handle) = serve_stream(
            Box::new(NativeBackend::new(cfg, w)),
            BridgeOpts::new(2).with_pool(pool.clone()),
            ctl.clone(),
        );
        let (etx, erx) = mpsc::channel();
        tx.send(StreamRequest { prompt: vec![1; 30], max_new: 10, deadline: None, tx: etx })
            .unwrap();
        match erx.recv_timeout(Duration::from_secs(60)).unwrap() {
            StreamEvent::Rejected(msg) => assert!(msg.contains("KV"), "got: {msg}"),
            other => panic!("expected Rejected, got {other:?}"),
        }
        drop(tx);
        handle.join().unwrap().unwrap();
        assert_eq!(ctl.stats().rejected.get(), 1);
    }
}
