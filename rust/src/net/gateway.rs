//! The HTTP gateway: endpoints, per-connection protocol handling, and the
//! lifecycle that ties the [listener](crate::net::listener) to the
//! [bridge](crate::net::bridge).
//!
//! Endpoints:
//!
//! * `POST /generate` — body `{"prompt": "..." | [tokens], "max_new": N,
//!   "deadline_ms": M}`. Streams one JSON line per token
//!   (`{"t":N}`) over chunked transfer encoding, ending with a
//!   `{"done":true, ...}` line; with `Accept: text/event-stream` the same
//!   documents arrive as SSE `data:` events. Impossible requests get `413`
//!   before any stream bytes; closing the connection mid-stream cancels
//!   the request and releases its KV pages.
//! * `GET /healthz` — liveness probe.
//! * `GET /stats` — the schema-2 stats envelope:
//!   `{"schema": 2, "gateway": {... counters, percentiles, "kv": {...}}}`.
//! * `GET /metrics` — Prometheus text exposition of the gateway's
//!   [`Registry`]: gateway counters, the bridge server's per-stage
//!   latency histograms, and the KV pool mirror.
//! * `POST /admin/drain` — stop accepting connections, finish in-flight
//!   streams, then [`serve_http`] returns a [`GatewayReport`] whose
//!   `leaked_pages` must be 0.
//!
//! Every `/generate` response carries a per-request trace: a `"trace"`
//! object on the final done-event and an `x-stbllm-trace` chunked
//! trailer with the same JSON (queue/prefill/decode/kernel breakdown).
//!
//! The gateway holds no decode state of its own: every generation request
//! funnels into the single bridge worker, which runs the same
//! `BatchServer` scheduling kernel as offline serving — HTTP-streamed
//! tokens are byte-identical to a direct batch run.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::kvpool::{KvPool, KvPoolStats};
use crate::coordinator::server::DEFAULT_HOL_BOOST_DEFERRALS;
use crate::engine::Backend;
use crate::net::bridge::{run_bridge, BridgeOpts, StreamEvent, StreamRequest};
use crate::net::http::{
    write_response, write_response_with, ChunkedWriter, HttpError, HttpRequest,
};
use crate::net::listener::serve_connections;
use crate::net::stats::GatewayStats;
use crate::obs::{envelope, Registry};
use crate::util::cli::defaults;
use crate::util::json::{num, obj, s, Json};

/// Per-tick callback the bridge fires before each scheduler tick — the
/// chaos harness's fault-injection point.
pub type TickHook = Arc<dyn Fn(u64) + Send + Sync>;

/// Shared control handle for a running gateway: drain flag, live stats,
/// bound address, and the KV pool (for `/stats` and leak checks). Clone
/// freely — all clones share one state.
#[derive(Clone, Default)]
pub struct GatewayCtl {
    inner: Arc<CtlInner>,
}

#[derive(Default)]
struct CtlInner {
    draining: AtomicBool,
    stats: GatewayStats,
    bound: Mutex<Option<SocketAddr>>,
    bound_cv: Condvar,
    active: AtomicUsize,
    queued: AtomicUsize,
    pool: Mutex<Option<Arc<KvPool>>>,
    tick_hook: Mutex<Option<TickHook>>,
    panic_logged: AtomicBool,
}

impl GatewayCtl {
    /// Fresh control handle (pass the same one to [`serve_http`] and to
    /// whatever needs to drain or observe it).
    pub fn new() -> GatewayCtl {
        GatewayCtl::default()
    }

    /// Control handle whose metrics live in `registry` — pass
    /// `Registry::disabled()` to measure recording overhead (`serve
    /// --no-obs`), or a shared registry to aggregate several gateways.
    pub fn with_registry(registry: Arc<Registry>) -> GatewayCtl {
        GatewayCtl {
            inner: Arc::new(CtlInner {
                stats: GatewayStats::new(registry),
                ..CtlInner::default()
            }),
        }
    }

    /// Begin graceful shutdown: the acceptor stops taking connections,
    /// in-flight streams run to completion, then [`serve_http`] returns.
    pub fn drain(&self) {
        self.inner.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a drain has been requested.
    pub fn is_draining(&self) -> bool {
        self.inner.draining.load(Ordering::SeqCst)
    }

    /// The live stats handles (lock-free: bump or read counters directly).
    pub fn stats(&self) -> &GatewayStats {
        &self.inner.stats
    }

    /// The metrics registry backing this gateway (rendered by `/metrics`;
    /// also wired into the bridge's batch server and the KV pool).
    pub fn registry(&self) -> Arc<Registry> {
        self.inner.stats.registry().clone()
    }

    /// Publish the in-flight gauges (bridge-internal).
    pub(crate) fn set_gauges(&self, active: usize, queued: usize) {
        self.inner.active.store(active, Ordering::Relaxed);
        self.inner.queued.store(queued, Ordering::Relaxed);
        self.inner.stats.active_g.set(active as i64);
        self.inner.stats.queued_g.set(queued as i64);
    }

    /// The queued-streams gauge (bridge-internal; bumped at enqueue so
    /// `/stats` sees requests the scheduler has not looked at yet).
    pub(crate) fn queued_gauge(&self) -> &AtomicUsize {
        &self.inner.queued
    }

    /// Current `(active, queued)` stream gauges.
    pub fn gauges(&self) -> (usize, usize) {
        (self.inner.active.load(Ordering::Relaxed), self.inner.queued.load(Ordering::Relaxed))
    }

    fn set_bound(&self, addr: SocketAddr) {
        *self.inner.bound.lock().expect("bound poisoned") = Some(addr);
        self.inner.bound_cv.notify_all();
    }

    /// Block until the gateway has bound its socket (e.g. after handing
    /// `addr` `:0`) and return the actual address; `None` on timeout.
    pub fn wait_bound(&self, timeout: Duration) -> Option<SocketAddr> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.inner.bound.lock().expect("bound poisoned");
        loop {
            if let Some(addr) = *guard {
                return Some(addr);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (next, _) = self
                .inner
                .bound_cv
                .wait_timeout(guard, deadline - now)
                .expect("bound poisoned");
            guard = next;
        }
    }

    fn set_pool(&self, pool: Option<Arc<KvPool>>) {
        *self.inner.pool.lock().expect("pool slot poisoned") = pool;
    }

    /// The gateway's KV pool, once serving has started (None on flat KV).
    pub fn pool(&self) -> Option<Arc<KvPool>> {
        self.inner.pool.lock().expect("pool slot poisoned").clone()
    }

    /// Install (or clear) the per-tick callback the bridge fires right
    /// before each scheduler tick. The chaos harness uses this to inject a
    /// bridge panic at a chosen tick.
    pub fn set_tick_hook(&self, hook: Option<TickHook>) {
        *self.inner.tick_hook.lock().expect("tick hook poisoned") = hook;
    }

    /// Fire the tick hook (bridge-internal). The hook is cloned out of the
    /// lock BEFORE the call, so a panicking hook unwinds the bridge without
    /// poisoning the hook slot — the supervisor can restart cleanly.
    pub(crate) fn fire_tick_hook(&self, tick: u64) {
        let hook = self.inner.tick_hook.lock().expect("tick hook poisoned").clone();
        if let Some(h) = hook {
            h(tick);
        }
    }

    /// Count a panicking connection handler; logged once per gateway so a
    /// panic loop cannot flood stderr.
    pub(crate) fn note_handler_panic(&self) {
        self.inner.stats.handler_panics.inc();
        if !self.inner.panic_logged.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[gateway] a connection handler panicked; connection answered 500/closed \
                 (further panics counted in handler_panics, not logged)"
            );
        }
    }

    /// The `/stats` document: the schema-2 envelope with the gateway
    /// snapshot (counters + gauges + a live KV section) under `"gateway"`.
    pub fn stats_json(&self) -> Json {
        let kv = self.pool().map(|p| p.stats());
        let (active, queued) = self.gauges();
        let snap = self.inner.stats.snapshot(kv, active, queued);
        envelope(&[&snap])
    }
}

/// Configuration for [`serve_http`].
#[derive(Clone, Debug)]
pub struct HttpServeOpts {
    /// Bind address, e.g. `127.0.0.1:8090` (`:0` picks a free port —
    /// recover it via [`GatewayCtl::wait_bound`] or `addr_file`).
    pub addr: String,
    /// HTTP worker threads (concurrent connections being handled).
    pub threads: usize,
    /// Max concurrently decoding streams (continuous batching width).
    pub max_batch: usize,
    /// KV pool size in pages; `0` auto-sizes to `max_batch` worst-case
    /// sessions.
    pub kv_pages: usize,
    /// KV page size in token slots.
    pub page_size: usize,
    /// Serve with flat per-session KV buffers instead of the paged pool.
    pub flat_kv: bool,
    /// Deadline applied to requests that do not send `deadline_ms`.
    pub default_deadline_ms: Option<u64>,
    /// Idle keep-alive read timeout per connection (also bounds how long a
    /// drain waits on idle connections).
    pub keepalive_ms: u64,
    /// If set, the bound address is written to this file once listening
    /// (how CI discovers a `:0` port).
    pub addr_file: Option<String>,
    /// Head-of-line age boost threshold for the admission queue.
    pub hol_boost_deferrals: u32,
    /// Load-shed watermark in free KV pages: when `total - reserved` drops
    /// below this, new `/generate` admits get `503 + Retry-After` instead
    /// of queueing indefinitely. `0` auto-sizes to an eighth of the pool
    /// (min 1). Ignored on flat (unpaged) serving.
    pub shed_watermark: usize,
}

impl HttpServeOpts {
    /// Defaults: 8 HTTP threads, the CLI's serving batch width, auto-sized
    /// paged KV, 1s keep-alive polls, no default deadline.
    pub fn new(addr: &str) -> HttpServeOpts {
        HttpServeOpts {
            addr: addr.to_string(),
            threads: defaults::HTTP_THREADS,
            max_batch: defaults::MAX_BATCH,
            kv_pages: defaults::KV_PAGES,
            page_size: defaults::PAGE_SIZE,
            flat_kv: false,
            default_deadline_ms: None,
            keepalive_ms: defaults::HTTP_KEEPALIVE_MS,
            addr_file: None,
            hol_boost_deferrals: DEFAULT_HOL_BOOST_DEFERRALS,
            shed_watermark: 0,
        }
    }
}

/// What a drained gateway hands back — the CLI prints it and exits
/// non-zero if `leaked_pages > 0`.
#[derive(Clone, Debug)]
pub struct GatewayReport {
    /// Streams that ran to completion.
    pub completed: usize,
    /// Streams cancelled by client disconnect.
    pub cancelled: usize,
    /// Streams stopped by their deadline.
    pub deadline_expired: usize,
    /// Requests refused at admission.
    pub rejected: usize,
    /// Total tokens generated.
    pub generated_tokens: usize,
    /// Final KV pool counters (`None` on flat serving).
    pub kv: Option<KvPoolStats>,
    /// Pages still reserved after the drain — MUST be 0; anything else
    /// means a session leaked its reservation.
    pub leaked_pages: usize,
}

impl GatewayReport {
    /// JSON form of the drain report.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("completed", num(self.completed as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("deadline_expired", num(self.deadline_expired as f64)),
            ("rejected", num(self.rejected as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("leaked_pages", num(self.leaked_pages as f64)),
        ];
        if let Some(kv) = &self.kv {
            fields.push(("kv", crate::net::stats::kv_json(kv)));
        }
        obj(fields)
    }
}

/// Serve HTTP on `opts.addr` until `ctl` drains; returns the final
/// [`GatewayReport`]. Spawns one bridge worker (the decode loop) plus
/// `opts.threads` connection workers, all scoped to this call — nothing
/// outlives it.
pub fn serve_http(
    backend: &dyn Backend,
    opts: &HttpServeOpts,
    ctl: &GatewayCtl,
) -> Result<GatewayReport> {
    let cfg = backend.cfg();
    let pool = if !opts.flat_kv && backend.capabilities().paged_kv {
        let page_size = opts.page_size.max(1);
        let pages = if opts.kv_pages == 0 {
            // mirror BatchServer::with_kv_pool's auto-size: max_batch
            // worst-case flat sessions
            opts.max_batch.max(1) * (4 * cfg.seq_len).div_ceil(page_size)
        } else {
            opts.kv_pages
        };
        Some(Arc::new(KvPool::new(cfg, pages, page_size)))
    } else {
        None
    };
    ctl.set_pool(pool.clone());

    let listener = TcpListener::bind(&opts.addr)?;
    let local = listener.local_addr()?;
    if let Some(path) = &opts.addr_file {
        std::fs::write(path, local.to_string())?;
    }
    ctl.set_bound(local);
    eprintln!("[gateway] listening on http://{local}");

    let bopts = BridgeOpts {
        max_batch: opts.max_batch.max(1),
        pool: pool.clone(),
        hol_boost_deferrals: opts.hol_boost_deferrals,
    };
    let (tx, rx) = mpsc::sync_channel::<StreamRequest>(1024);

    let shed_watermark = match (&pool, opts.shed_watermark) {
        (None, _) => 0,
        (Some(p), 0) => (p.total_pages() / 8).max(1),
        (Some(_), w) => w,
    };

    std::thread::scope(|scope| -> Result<()> {
        let bridge = scope.spawn(|| supervise_bridge(backend, &bopts, &rx, ctl));
        let hc = HandlerCtx {
            tx,
            default_deadline: opts.default_deadline_ms.map(Duration::from_millis),
            keepalive: Duration::from_millis(opts.keepalive_ms.max(10)),
            vocab: cfg.vocab,
            pool: pool.clone(),
            shed_watermark,
        };
        let listened = serve_connections(listener, ctl, opts.threads.max(1), |stream| {
            handle_connection(stream, ctl, &hc);
        });
        // dropping the request sender is the bridge's drain signal: it
        // finishes everything in flight, then exits
        drop(hc);
        let bridged = match bridge.join() {
            Ok(r) => r,
            Err(_) => Err(anyhow::anyhow!("bridge supervisor panicked")),
        };
        listened?;
        bridged
    })?;

    let kv = pool.as_ref().map(|p| p.stats());
    let leaked_pages = kv.as_ref().map_or(0, |k| k.pages_reserved);
    let st = ctl.stats();
    Ok(GatewayReport {
        completed: st.completed.get() as usize,
        cancelled: st.cancelled.get() as usize,
        deadline_expired: st.deadline_expired.get() as usize,
        rejected: st.rejected.get() as usize,
        generated_tokens: st.generated_tokens.get() as usize,
        kv,
        leaked_pages,
    })
}

/// Max automatic bridge restarts before the gateway gives up and errors
/// out — a backstop against a deterministic crash loop.
const MAX_BRIDGE_RESTARTS: usize = 8;

/// Run the bridge under a supervisor: a panic inside the decode loop
/// unwinds the bridge (dropping every in-flight session, which releases
/// its KV pages back to the pool and disconnects its stream senders, so
/// each waiting handler answers 500 / terminates its chunk stream) and the
/// bridge is restarted on the same request channel — queued requests that
/// had not been ingested yet survive the crash.
pub(crate) fn supervise_bridge(
    backend: &dyn Backend,
    opts: &BridgeOpts,
    rx: &mpsc::Receiver<StreamRequest>,
    ctl: &GatewayCtl,
) -> Result<()> {
    let mut restarts = 0usize;
    loop {
        match catch_unwind(AssertUnwindSafe(|| run_bridge(backend, opts, rx, ctl))) {
            Ok(r) => return r,
            Err(_) => {
                ctl.set_gauges(0, 0);
                ctl.stats().bridge_panics.inc();
                if restarts >= MAX_BRIDGE_RESTARTS {
                    bail!("bridge worker panicked; {restarts} restarts exhausted");
                }
                restarts += 1;
                ctl.stats().bridge_restarts.inc();
                eprintln!(
                    "[gateway] bridge worker panicked; in-flight sessions retired, \
                     restarting ({restarts}/{MAX_BRIDGE_RESTARTS})"
                );
            }
        }
    }
}

/// Everything one connection handler needs; owns a clone-free handle on
/// the bridge's request sender (dropping the ctx after the listener exits
/// is what drains the bridge).
struct HandlerCtx {
    tx: mpsc::SyncSender<StreamRequest>,
    default_deadline: Option<Duration>,
    keepalive: Duration,
    vocab: usize,
    /// The paged KV pool, for the load-shed free-page check.
    pool: Option<Arc<KvPool>>,
    /// Shed new admits when free pages drop below this (0 disables).
    shed_watermark: usize,
}

/// Keep-alive connection loop: parse requests until the peer closes, a
/// protocol error occurs, or a drain is requested.
fn handle_connection(mut stream: TcpStream, ctl: &GatewayCtl, hc: &HandlerCtx) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(hc.keepalive));
    loop {
        match HttpRequest::read_from(&mut stream) {
            Ok(None) => break, // peer closed between requests
            Ok(Some(req)) => {
                ctl.stats().http_requests.inc();
                let keep = req.keep_alive() && !ctl.is_draining();
                // a panic while serving one request must not take the
                // worker down: answer 500, count it, close this connection
                let served =
                    catch_unwind(AssertUnwindSafe(|| dispatch(&mut stream, &req, keep, ctl, hc)));
                match served {
                    Ok(r) => {
                        if r.is_err() || !keep {
                            break;
                        }
                    }
                    Err(_) => {
                        ctl.note_handler_panic();
                        let _ = write_response(
                            &mut stream,
                            500,
                            "text/plain",
                            b"internal server error",
                            false,
                        );
                        break;
                    }
                }
            }
            Err(HttpError::IdleTimeout) => {
                // idle keep-alive poll: stay open unless draining
                if ctl.is_draining() {
                    break;
                }
            }
            Err(HttpError::BadRequest(msg)) => {
                let _ = write_response(&mut stream, 400, "text/plain", msg.as_bytes(), false);
                break;
            }
            Err(HttpError::TooLarge(what)) => {
                let status = if what.contains("head") { 431 } else { 413 };
                let _ = write_response(&mut stream, status, "text/plain", what.as_bytes(), false);
                break;
            }
            Err(HttpError::Io(_)) => break,
        }
    }
}

fn dispatch(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep: bool,
    ctl: &GatewayCtl,
    hc: &HandlerCtx,
) -> std::io::Result<()> {
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => {
            write_response(stream, 200, "application/json", b"{\"ok\":true}", keep)
        }
        ("GET", "/stats") => {
            let doc = ctl.stats_json().dump();
            write_response(stream, 200, "application/json", doc.as_bytes(), keep)
        }
        ("GET", "/metrics") => {
            let body = ctl.registry().render_prometheus();
            write_response(
                stream,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                body.as_bytes(),
                keep,
            )
        }
        ("POST", "/admin/drain") => {
            ctl.drain();
            write_response(stream, 200, "application/json", b"{\"draining\":true}", false)
        }
        ("POST", "/generate") if ctl.is_draining() => {
            write_response(stream, 503, "text/plain", b"draining", false)
        }
        ("POST", "/generate") => {
            // load shedding: when the pool is nearly exhausted, refuse the
            // admit NOW with a retry hint instead of deferring indefinitely
            if let Some(pool) = &hc.pool {
                let kv = pool.stats();
                if hc.shed_watermark > 0 && kv.free_pages() < hc.shed_watermark {
                    ctl.stats().shed.inc();
                    return write_response_with(
                        stream,
                        503,
                        "application/json",
                        &[("retry-after", "1")],
                        b"{\"error\":\"kv pool exhausted, retry\"}",
                        keep,
                    );
                }
            }
            handle_generate(stream, req, keep, hc)
        }
        (_, "/healthz" | "/stats" | "/metrics" | "/admin/drain" | "/generate") => {
            write_response(stream, 405, "text/plain", b"method not allowed", keep)
        }
        _ => write_response(stream, 404, "text/plain", b"not found", keep),
    }
}

/// Upper bound on `max_new` accepted over HTTP.
const MAX_MAX_NEW: usize = 4096;
/// `max_new` when the request omits it.
const DEFAULT_MAX_NEW: usize = 16;

struct GenSpec {
    prompt: Vec<u8>,
    max_new: usize,
    deadline_ms: Option<u64>,
}

fn parse_generate(body: &[u8], vocab: usize) -> Result<GenSpec, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not utf-8".to_string())?;
    let doc = Json::parse(text).map_err(|e| format!("bad json: {e}"))?;
    let vocab = vocab.max(1) as u32;
    let prompt: Vec<u8> = match doc.get("prompt") {
        // string prompts are byte-tokenized, wrapped into the model vocab
        Some(Json::Str(st)) if !st.is_empty() => {
            st.bytes().map(|b| (b as u32 % vocab) as u8).collect()
        }
        Some(Json::Arr(items)) if !items.is_empty() => {
            let mut toks = Vec::with_capacity(items.len());
            for item in items {
                let n = item
                    .as_f64()
                    .ok_or_else(|| "prompt array entries must be numbers".to_string())?;
                if !(0.0..=255.0).contains(&n) || n.fract() != 0.0 {
                    return Err(format!("prompt token {n} out of range 0..=255"));
                }
                toks.push((n as u32 % vocab) as u8);
            }
            toks
        }
        Some(Json::Str(_)) | Some(Json::Arr(_)) => return Err("empty prompt".to_string()),
        _ => return Err("missing \"prompt\" (string or token array)".to_string()),
    };
    let max_new = match doc.get("max_new") {
        None => DEFAULT_MAX_NEW,
        Some(v) => match v.as_f64() {
            Some(n) if (1.0..=MAX_MAX_NEW as f64).contains(&n) && n.fract() == 0.0 => {
                n as usize
            }
            _ => return Err(format!("max_new must be an integer in 1..={MAX_MAX_NEW}")),
        },
    };
    let deadline_ms = match doc.get("deadline_ms") {
        None => None,
        Some(v) => match v.as_f64() {
            Some(ms) if ms >= 0.0 => Some(ms as u64),
            _ => return Err("deadline_ms must be a non-negative number".to_string()),
        },
    };
    Ok(GenSpec { prompt, max_new, deadline_ms })
}

/// `POST /generate`: admit the request into the bridge and stream its
/// tokens back. The status line is withheld until the FIRST stream event,
/// so a rejection is a clean `413` rather than a broken 200-stream.
fn handle_generate(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep: bool,
    hc: &HandlerCtx,
) -> std::io::Result<()> {
    let spec = match parse_generate(&req.body, hc.vocab) {
        Ok(spec) => spec,
        Err(msg) => return write_response(stream, 400, "text/plain", msg.as_bytes(), keep),
    };
    let deadline = spec
        .deadline_ms
        .map(Duration::from_millis)
        .or(hc.default_deadline)
        .map(|d| Instant::now() + d);
    let (etx, erx) = mpsc::channel::<StreamEvent>();
    let sr =
        StreamRequest { prompt: spec.prompt, max_new: spec.max_new, deadline, tx: etx };
    if hc.tx.send(sr).is_err() {
        return write_response(stream, 503, "text/plain", b"server shutting down", false);
    }
    let first = match erx.recv() {
        Ok(ev) => ev,
        Err(_) => {
            return write_response(stream, 500, "text/plain", b"stream worker gone", false)
        }
    };
    if let StreamEvent::Rejected(msg) = first {
        let doc = obj(vec![("error", s(&msg))]).dump();
        return write_response(stream, 413, "application/json", doc.as_bytes(), keep);
    }
    let sse = req.wants_sse();
    let content_type = if sse { "text/event-stream" } else { "application/json" };
    let mut cw = ChunkedWriter::start(stream, 200, content_type, keep)?;
    let mut ev = first;
    let mut trace: Option<String> = None;
    loop {
        let line = match &ev {
            StreamEvent::Token(t) => format!("{{\"t\":{t}}}"),
            StreamEvent::Done(d) => {
                trace = Some(d.trace.header_value());
                obj(vec![
                    ("done", Json::Bool(true)),
                    ("generated", num(d.generated as f64)),
                    ("ttft_s", num(d.ttft_s)),
                    ("latency_s", num(d.latency_s)),
                    ("stopped", s(d.stopped.label())),
                    ("trace", d.trace.to_json()),
                ])
                .dump()
            }
            // a rejection is always the first event; unreachable here, but
            // surface it rather than hang if that invariant ever breaks
            StreamEvent::Rejected(msg) => obj(vec![("error", s(msg))]).dump(),
        };
        if sse {
            cw.sse_event(&line)?;
        } else {
            cw.chunk(format!("{line}\n").as_bytes())?;
        }
        if !matches!(ev, StreamEvent::Token(_)) {
            break;
        }
        ev = match erx.recv() {
            Ok(next) => next,
            Err(_) => break, // bridge died mid-stream; terminate the chunks
        };
    }
    // the per-request trace rides again as a chunked trailer, so clients
    // that skip the body (HEAD-ish probes, loadgen) still get the span
    match &trace {
        Some(t) => cw.finish_with_trailers(&[("x-stbllm-trace", t)]),
        None => cw.finish(),
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn parse_generate_accepts_string_and_array_prompts() {
        let spec =
            parse_generate(br#"{"prompt": "hi", "max_new": 3}"#, 32).expect("string prompt");
        assert_eq!(spec.prompt, vec![b'h' % 32, b'i' % 32]);
        assert_eq!(spec.max_new, 3);
        assert_eq!(spec.deadline_ms, None);

        let spec = parse_generate(br#"{"prompt": [1, 2, 40], "deadline_ms": 250}"#, 32)
            .expect("array prompt");
        assert_eq!(spec.prompt, vec![1, 2, 40 % 32]);
        assert_eq!(spec.max_new, DEFAULT_MAX_NEW);
        assert_eq!(spec.deadline_ms, Some(250));
    }

    #[test]
    fn parse_generate_rejects_bad_bodies() {
        for (body, why) in [
            (&b"not json"[..], "garbage"),
            (br#"{}"#, "missing prompt"),
            (br#"{"prompt": ""}"#, "empty string prompt"),
            (br#"{"prompt": []}"#, "empty array prompt"),
            (br#"{"prompt": [1, "x"]}"#, "non-numeric token"),
            (br#"{"prompt": [300]}"#, "token out of range"),
            (br#"{"prompt": "a", "max_new": 0}"#, "zero max_new"),
            (br#"{"prompt": "a", "max_new": 99999}"#, "huge max_new"),
            (br#"{"prompt": "a", "deadline_ms": -5}"#, "negative deadline"),
        ] {
            assert!(parse_generate(body, 32).is_err(), "should reject: {why}");
        }
    }

    #[test]
    fn ctl_drain_flag_and_gauges() {
        let ctl = GatewayCtl::new();
        assert!(!ctl.is_draining());
        ctl.drain();
        assert!(ctl.is_draining());
        ctl.set_gauges(3, 7);
        assert_eq!(ctl.gauges(), (3, 7));
        // stats JSON is the schema-2 envelope; the gauges ride under
        // "gateway" and mirror into the registry exposition
        let doc = Json::parse(&ctl.stats_json().dump()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_usize().unwrap(), 2);
        assert_eq!(doc.path(&["gateway", "active"]).unwrap().as_usize().unwrap(), 3);
        assert_eq!(doc.path(&["gateway", "queued"]).unwrap().as_usize().unwrap(), 7);
        let text = ctl.registry().render_prometheus();
        assert!(text.contains("stbllm_gateway_active 3"), "{text}");
        assert!(text.contains("stbllm_gateway_queued 7"), "{text}");
    }

    #[test]
    fn ctl_wait_bound_times_out_then_resolves() {
        let ctl = GatewayCtl::new();
        assert!(ctl.wait_bound(Duration::from_millis(20)).is_none());
        let addr: SocketAddr = "127.0.0.1:4242".parse().unwrap();
        ctl.set_bound(addr);
        assert_eq!(ctl.wait_bound(Duration::from_secs(1)), Some(addr));
    }

    #[test]
    fn tick_hook_fires_and_a_panicking_hook_does_not_poison_the_slot() {
        let ctl = GatewayCtl::new();
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        ctl.set_tick_hook(Some(Arc::new(move |t| {
            c2.fetch_add(t as usize + 1, Ordering::SeqCst);
        })));
        ctl.fire_tick_hook(0);
        ctl.fire_tick_hook(1);
        assert_eq!(count.load(Ordering::SeqCst), 3);
        // the hook is called OUTSIDE the slot lock: a panicking hook
        // unwinds the caller but the slot stays usable
        ctl.set_tick_hook(Some(Arc::new(|_| panic!("injected hook panic"))));
        assert!(catch_unwind(AssertUnwindSafe(|| ctl.fire_tick_hook(2))).is_err());
        ctl.set_tick_hook(None);
        ctl.fire_tick_hook(3); // must not panic on a poisoned lock
    }

    #[test]
    fn report_json_includes_leak_count() {
        let report = GatewayReport {
            completed: 4,
            cancelled: 1,
            deadline_expired: 0,
            rejected: 2,
            generated_tokens: 40,
            kv: None,
            leaked_pages: 0,
        };
        let doc = Json::parse(&report.to_json().dump()).unwrap();
        assert_eq!(doc.get("completed").unwrap().as_usize().unwrap(), 4);
        assert_eq!(doc.get("leaked_pages").unwrap().as_usize().unwrap(), 0);
        assert!(doc.get("kv").is_none());
    }
}
