//! The HTTP gateway: endpoints, per-connection protocol handling, and the
//! lifecycle that ties the [listener](crate::net::listener) to the
//! per-replica [bridge](crate::net::bridge) workers through the
//! [router](crate::net::router).
//!
//! Endpoints:
//!
//! * `POST /generate` — body is a schema-3 [`GenerateRequest`]
//!   (`{"prompt": "..." | [tokens], "max_new": N, "deadline_ms": M}`;
//!   an explicit `"schema": 3` is accepted, other versions get a typed
//!   `400`). Streams one JSON line per token (`{"t":N}`) over chunked
//!   transfer encoding, ending with a `{"done":true, ...}` line; with
//!   `Accept: text/event-stream` the same documents arrive as SSE `data:`
//!   events. Impossible requests get `413` before any stream bytes;
//!   closing the connection mid-stream cancels the request and releases
//!   its KV pages.
//! * `GET /healthz` — liveness probe.
//! * `GET /stats` — the schema-2 stats envelope:
//!   `{"schema": 2, "gateway": {...}, "replicas": [...]}` (the flat
//!   `"gateway"` section is unchanged from single-replica serving; the
//!   `"replicas"` array adds per-replica id/load/fault/kv rows).
//! * `GET /metrics` — Prometheus text exposition of the gateway's
//!   [`Registry`]: gateway counters, router decisions, the bridge
//!   servers' per-stage latency histograms, and the KV pool mirrors —
//!   with `replica="N"`-labeled series when serving more than one
//!   replica.
//! * `POST /admin/drain` — stop accepting connections, finish in-flight
//!   streams, then [`serve_http`] returns a [`GatewayReport`] whose
//!   `leaked_pages` (summed across every replica's pool) must be 0.
//!
//! Every `/generate` response carries a per-request trace: a `"trace"`
//! object on the final done-event and an `x-stbllm-trace` chunked
//! trailer with the same JSON (queue/prefill/decode/kernel breakdown).
//!
//! With `--replicas R` the gateway runs R decode workers over ONE
//! resident model (each replica borrows the same backend; only KV state
//! is per-replica). The [`Router`] assigns each stream by prompt-prefix
//! affinity with least-loaded fallback; a replica that exhausts its
//! panic restarts has its queued requests migrated to survivors. Every
//! replica runs the same `BatchServer` scheduling kernel as offline
//! serving, and greedy decode makes each stream a pure function of its
//! prompt — so streamed tokens are byte-identical to a direct batch run
//! at ANY replica count.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::coordinator::kvpool::{KvPool, KvPoolStats};
use crate::coordinator::server::{DEFAULT_HOL_BOOST_DEFERRALS, DEFAULT_PREFILL_CHUNK};
use crate::engine::Backend;
use crate::net::api::{DoneEvent, GenerateEvent, GenerateRequest};
use crate::net::bridge::{
    run_bridge, BridgeOpts, StreamEvent, StreamRequest, MAX_BRIDGE_RESTARTS,
};
use crate::net::http::{
    write_response, write_response_with, ChunkedWriter, HttpError, HttpRequest,
};
use crate::net::listener::serve_connections;
use crate::net::router::{Admission, DispatchError, Router, Seat};
use crate::net::stats::GatewayStats;
use crate::obs::{envelope, Registry};
use crate::util::cli::defaults;
use crate::util::json::{num, obj, Json};

/// Per-tick callback each bridge fires before a scheduler tick, with its
/// `(replica, tick)` — the chaos harness's fault-injection point.
pub type TickHook = Arc<dyn Fn(u64, u64) + Send + Sync>;

/// Shared control handle for a running gateway: drain flag, live stats,
/// bound address, and the replica router (for `/stats` and leak checks).
/// Clone freely — all clones share one state.
#[derive(Clone, Default)]
pub struct GatewayCtl {
    inner: Arc<CtlInner>,
}

#[derive(Default)]
struct CtlInner {
    draining: AtomicBool,
    stats: GatewayStats,
    bound: Mutex<Option<SocketAddr>>,
    bound_cv: Condvar,
    router: Mutex<Option<Arc<Router>>>,
    tick_hook: Mutex<Option<TickHook>>,
    panic_logged: AtomicBool,
}

impl GatewayCtl {
    /// Fresh control handle (pass the same one to [`serve_http`] and to
    /// whatever needs to drain or observe it).
    pub fn new() -> GatewayCtl {
        GatewayCtl::default()
    }

    /// Control handle whose metrics live in `registry` — pass
    /// `Registry::disabled()` to measure recording overhead (`serve
    /// --no-obs`), or a shared registry to aggregate several gateways.
    pub fn with_registry(registry: Arc<Registry>) -> GatewayCtl {
        GatewayCtl {
            inner: Arc::new(CtlInner {
                stats: GatewayStats::new(registry),
                ..CtlInner::default()
            }),
        }
    }

    /// Begin graceful shutdown: the acceptor stops taking connections,
    /// in-flight streams run to completion, then [`serve_http`] returns.
    pub fn drain(&self) {
        self.inner.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a drain has been requested.
    pub fn is_draining(&self) -> bool {
        self.inner.draining.load(Ordering::SeqCst)
    }

    /// The live stats handles (lock-free: bump or read counters directly).
    pub fn stats(&self) -> &GatewayStats {
        &self.inner.stats
    }

    /// The metrics registry backing this gateway (rendered by `/metrics`;
    /// also wired into the bridge's batch servers and the KV pools).
    pub fn registry(&self) -> Arc<Registry> {
        self.inner.stats.registry().clone()
    }

    /// Install the replica router once serving starts.
    pub(crate) fn set_router(&self, router: Option<Arc<Router>>) {
        *self.inner.router.lock().expect("router slot poisoned") = router;
    }

    /// The replica router, once serving has started.
    pub(crate) fn router(&self) -> Option<Arc<Router>> {
        self.inner.router.lock().expect("router slot poisoned").clone()
    }

    /// Current `(active, queued)` stream gauges, summed across replicas.
    pub fn gauges(&self) -> (usize, usize) {
        self.router().map_or((0, 0), |r| r.loads())
    }

    /// Refresh the aggregate gauges from the per-replica seat loads
    /// (bridge-internal, after a seat's load changes).
    pub(crate) fn republish_gauges(&self) {
        let (active, queued) = self.gauges();
        self.inner.stats.active_g.set(active as i64);
        self.inner.stats.queued_g.set(queued as i64);
    }

    /// The first replica's KV pool, once serving has started (`None` on
    /// flat KV). Per-replica pools hang off the router's seats.
    pub fn pool(&self) -> Option<Arc<KvPool>> {
        self.router().and_then(|r| r.seats().first().and_then(|s| s.pool().cloned()))
    }

    fn set_bound(&self, addr: SocketAddr) {
        *self.inner.bound.lock().expect("bound poisoned") = Some(addr);
        self.inner.bound_cv.notify_all();
    }

    /// Block until the gateway has bound its socket (e.g. after handing
    /// `addr` `:0`) and return the actual address; `None` on timeout.
    pub fn wait_bound(&self, timeout: Duration) -> Option<SocketAddr> {
        let deadline = Instant::now() + timeout;
        let mut guard = self.inner.bound.lock().expect("bound poisoned");
        loop {
            if let Some(addr) = *guard {
                return Some(addr);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (next, _) = self
                .inner
                .bound_cv
                .wait_timeout(guard, deadline - now)
                .expect("bound poisoned");
            guard = next;
        }
    }

    /// Install (or clear) the per-tick callback the bridges fire right
    /// before each scheduler tick, as `hook(replica, tick)`. The chaos
    /// harness uses this to inject a bridge panic at a chosen tick on a
    /// chosen replica.
    pub fn set_tick_hook(&self, hook: Option<TickHook>) {
        *self.inner.tick_hook.lock().expect("tick hook poisoned") = hook;
    }

    /// Fire the tick hook (bridge-internal). The hook is cloned out of the
    /// lock BEFORE the call, so a panicking hook unwinds the bridge without
    /// poisoning the hook slot — the supervisor can restart cleanly.
    pub(crate) fn fire_tick_hook(&self, replica: u64, tick: u64) {
        let hook = self.inner.tick_hook.lock().expect("tick hook poisoned").clone();
        if let Some(h) = hook {
            h(replica, tick);
        }
    }

    /// Count a panicking connection handler; logged once per gateway so a
    /// panic loop cannot flood stderr.
    pub(crate) fn note_handler_panic(&self) {
        self.inner.stats.handler_panics.inc();
        if !self.inner.panic_logged.swap(true, Ordering::Relaxed) {
            eprintln!(
                "[gateway] a connection handler panicked; connection answered 500/closed \
                 (further panics counted in handler_panics, not logged)"
            );
        }
    }

    /// The `/stats` document: the schema-2 envelope with the aggregate
    /// gateway snapshot under `"gateway"` (byte-compatible with
    /// single-replica serving — the KV section is the merged counters of
    /// every replica's pool) plus a `"replicas"` array with one
    /// id/load/fault/kv row per replica.
    pub fn stats_json(&self) -> Json {
        match self.router() {
            Some(r) => {
                let (active, queued) = r.loads();
                let snap = self.inner.stats.snapshot(r.kv_stats(), active, queued);
                let reps = r.snapshot();
                envelope(&[&snap, &reps])
            }
            None => envelope(&[&self.inner.stats.snapshot(None, 0, 0)]),
        }
    }
}

/// Serving configuration — the ONE struct consumed by the CLI, the engine
/// builder and [`serve_http`] alike (it replaced the field-by-field
/// `EngineBuilder` → gateway option copying, so a new serving knob cannot
/// silently miss one of those paths).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `127.0.0.1:8090` (`:0` picks a free port —
    /// recover it via [`GatewayCtl::wait_bound`] or `addr_file`).
    pub addr: String,
    /// HTTP worker threads (concurrent connections being handled).
    pub threads: usize,
    /// Max concurrently decoding streams PER REPLICA (continuous batching
    /// width of each replica's scheduler).
    pub max_batch: usize,
    /// Total KV pool budget in pages, split evenly across replicas; `0`
    /// auto-sizes each replica to `max_batch` worst-case sessions.
    pub kv_pages: usize,
    /// KV page size in token slots.
    pub page_size: usize,
    /// Serve with flat per-session KV buffers instead of the paged pool.
    pub flat_kv: bool,
    /// Deadline applied to requests that do not send `deadline_ms`.
    pub default_deadline_ms: Option<u64>,
    /// Idle keep-alive read timeout per connection (also bounds how long a
    /// drain waits on idle connections).
    pub keepalive_ms: u64,
    /// If set, the bound address is written to this file once listening
    /// (how CI discovers a `:0` port).
    pub addr_file: Option<String>,
    /// Head-of-line age boost threshold for the admission queue.
    pub hol_boost_deferrals: u32,
    /// Per-tick prefill-token budget per session (`--prefill-chunk`): a
    /// prefilling stream consumes up to this many prompt tokens per
    /// scheduler tick, multi-token chunks running as one batched packed
    /// GEMM. `1` = legacy one-token-per-tick; streams are byte-identical
    /// either way.
    pub prefill_chunk: usize,
    /// Load-shed watermark in free KV pages, applied per replica: when a
    /// replica's `total - reserved` drops below this it is not routable,
    /// and when NO replica is, new `/generate` admits get `503 +
    /// Retry-After` instead of queueing indefinitely. `0` auto-sizes to an
    /// eighth of one replica's pool (min 1). Ignored on flat serving.
    pub shed_watermark: usize,
    /// Decode replicas over the shared resident weights — each gets its
    /// own `BatchServer`, bridge thread and KV pool slice, behind the
    /// prefix-affinity [`Router`].
    pub replicas: usize,
    /// Panic restarts per replica before its supervisor gives up; a dead
    /// replica's queued requests migrate to survivors (with one replica,
    /// exhaustion fails the gateway, as before).
    pub max_bridge_restarts: usize,
}

impl ServeConfig {
    /// Defaults: 8 HTTP threads, the CLI's serving batch width, auto-sized
    /// paged KV, 1s keep-alive polls, no default deadline, one replica.
    pub fn new(addr: &str) -> ServeConfig {
        ServeConfig {
            addr: addr.to_string(),
            threads: defaults::HTTP_THREADS,
            max_batch: defaults::MAX_BATCH,
            kv_pages: defaults::KV_PAGES,
            page_size: defaults::PAGE_SIZE,
            flat_kv: false,
            default_deadline_ms: None,
            keepalive_ms: defaults::HTTP_KEEPALIVE_MS,
            addr_file: None,
            hol_boost_deferrals: DEFAULT_HOL_BOOST_DEFERRALS,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            shed_watermark: 0,
            replicas: defaults::REPLICAS,
            max_bridge_restarts: MAX_BRIDGE_RESTARTS,
        }
    }
}

/// What a drained gateway hands back — the CLI prints it and exits
/// non-zero if `leaked_pages > 0`.
#[derive(Clone, Debug)]
pub struct GatewayReport {
    /// Streams that ran to completion.
    pub completed: usize,
    /// Streams cancelled by client disconnect.
    pub cancelled: usize,
    /// Streams stopped by their deadline.
    pub deadline_expired: usize,
    /// Requests refused at admission.
    pub rejected: usize,
    /// Total tokens generated.
    pub generated_tokens: usize,
    /// Final KV pool counters, merged across replicas (`None` on flat
    /// serving).
    pub kv: Option<KvPoolStats>,
    /// Pages still reserved after the drain, summed over every replica's
    /// pool — MUST be 0; anything else means a session leaked its
    /// reservation.
    pub leaked_pages: usize,
}

impl GatewayReport {
    /// JSON form of the drain report.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("completed", num(self.completed as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("deadline_expired", num(self.deadline_expired as f64)),
            ("rejected", num(self.rejected as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("leaked_pages", num(self.leaked_pages as f64)),
        ];
        if let Some(kv) = &self.kv {
            fields.push(("kv", crate::net::stats::kv_json(kv)));
        }
        obj(fields)
    }
}

/// Serve HTTP on `opts.addr` until `ctl` drains; returns the final
/// [`GatewayReport`]. Spawns `opts.replicas` supervised bridge workers
/// (the decode loops, all borrowing ONE backend) plus `opts.threads`
/// connection workers, all scoped to this call — nothing outlives it.
pub fn serve_http(
    backend: &dyn Backend,
    opts: &ServeConfig,
    ctl: &GatewayCtl,
) -> Result<GatewayReport> {
    let cfg = backend.cfg();
    let replicas = opts.replicas.max(1);
    let paged = !opts.flat_kv && backend.capabilities().paged_kv;
    let registry = ctl.registry();

    let mut seats: Vec<Arc<Seat>> = Vec::with_capacity(replicas);
    let mut channels = Vec::with_capacity(replicas);
    for id in 0..replicas {
        let pool = if paged {
            let page_size = opts.page_size.max(1);
            let pages = if opts.kv_pages == 0 {
                // mirror BatchServer::with_kv_pool's auto-size, per
                // replica: max_batch worst-case flat sessions
                opts.max_batch.max(1) * (4 * cfg.seq_len).div_ceil(page_size)
            } else {
                (opts.kv_pages / replicas).max(1)
            };
            let pool = Arc::new(KvPool::new(cfg, pages, page_size));
            if replicas > 1 {
                // label this slice's stbllm_kv_* series before the bridge's
                // unlabeled attach (which then no-ops, being same-registry)
                pool.attach_registry_with(&registry, &format!("replica=\"{id}\""));
            }
            Some(pool)
        } else {
            None
        };
        let (tx, rx) = mpsc::sync_channel::<StreamRequest>(1024);
        let labeled = if replicas > 1 { Some(registry.as_ref()) } else { None };
        seats.push(Arc::new(Seat::new(id, pool, Some(tx), labeled)));
        channels.push(rx);
    }

    let shed_watermark = if !paged {
        0
    } else if opts.shed_watermark == 0 {
        seats[0].pool().map_or(0, |p| (p.total_pages() / 8).max(1))
    } else {
        opts.shed_watermark
    };
    let router = Arc::new(Router::new(seats, shed_watermark, &registry));
    ctl.set_router(Some(router.clone()));

    let listener = TcpListener::bind(&opts.addr)?;
    let local = listener.local_addr()?;
    if let Some(path) = &opts.addr_file {
        std::fs::write(path, local.to_string())?;
    }
    ctl.set_bound(local);
    eprintln!("[gateway] listening on http://{local}");
    if replicas > 1 {
        eprintln!("[gateway] {replicas} decode replicas over shared weights");
    }

    std::thread::scope(|scope| -> Result<()> {
        let mut bridges = Vec::with_capacity(replicas);
        for (idx, rx) in channels.into_iter().enumerate() {
            let bopts = BridgeOpts {
                max_batch: opts.max_batch.max(1),
                pool: router.seats()[idx].pool().cloned(),
                hol_boost_deferrals: opts.hol_boost_deferrals,
                prefill_chunk: opts.prefill_chunk,
                max_restarts: opts.max_bridge_restarts,
            };
            let router = Arc::clone(&router);
            bridges
                .push(scope.spawn(move || supervise_bridge(backend, &bopts, &rx, ctl, &router, idx)));
        }
        let hc = HandlerCtx {
            router: router.clone(),
            default_deadline: opts.default_deadline_ms.map(Duration::from_millis),
            keepalive: Duration::from_millis(opts.keepalive_ms.max(10)),
            vocab: cfg.vocab,
        };
        let listened = serve_connections(listener, ctl, opts.threads.max(1), |stream| {
            handle_connection(stream, ctl, &hc);
        });
        // dropping every seat's request sender is the drain signal: each
        // bridge finishes everything in flight, then exits
        router.close();
        let mut bridged: Result<()> = Ok(());
        for b in bridges {
            match b.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => bridged = Err(e),
                Err(_) => bridged = Err(anyhow::anyhow!("bridge supervisor panicked")),
            }
        }
        listened?;
        bridged
    })?;

    let kv = router.kv_stats();
    let leaked_pages = kv.as_ref().map_or(0, |k| k.pages_reserved);
    let st = ctl.stats();
    Ok(GatewayReport {
        completed: st.completed.get() as usize,
        cancelled: st.cancelled.get() as usize,
        deadline_expired: st.deadline_expired.get() as usize,
        rejected: st.rejected.get() as usize,
        generated_tokens: st.generated_tokens.get() as usize,
        kv,
        leaked_pages,
    })
}

/// Run one replica's bridge under a supervisor. A panic inside the decode
/// loop unwinds the bridge (dropping every in-flight session, which
/// releases its KV pages back to the pool and disconnects its stream
/// senders, so each waiting handler answers 500 / terminates its chunk
/// stream) and the bridge is restarted on the same request channel —
/// queued requests that had not been ingested yet survive the crash.
///
/// When `opts.max_restarts` is exhausted the replica dies for good: its
/// seat is marked dead (the router stops picking it) and, if other
/// replicas survive, this supervisor becomes a forwarder pump that
/// migrates everything still queued on the dead replica's channel to the
/// survivors via [`Router::redispatch`]. Only when NO replica survives
/// does the gateway fail, as single-replica serving always did.
pub(crate) fn supervise_bridge(
    backend: &dyn Backend,
    opts: &BridgeOpts,
    rx: &mpsc::Receiver<StreamRequest>,
    ctl: &GatewayCtl,
    router: &Router,
    idx: usize,
) -> Result<()> {
    let seat = &router.seats()[idx];
    let mut restarts = 0usize;
    loop {
        match catch_unwind(AssertUnwindSafe(|| run_bridge(backend, opts, rx, ctl, seat))) {
            Ok(r) => return r,
            Err(_) => {
                seat.set_load(0, 0);
                ctl.republish_gauges();
                ctl.stats().bridge_panics.inc();
                seat.note_panic();
                if restarts >= opts.max_restarts {
                    seat.mark_dead();
                    seat.close();
                    if router.alive() == 0 {
                        bail!("bridge worker panicked; {restarts} restarts exhausted");
                    }
                    eprintln!(
                        "[gateway] replica {idx} gave up after {restarts} restarts; \
                         migrating its queued requests to surviving replicas"
                    );
                    // forwarder pump: requests still queued on the dead
                    // replica's channel migrate instead of dying with it
                    loop {
                        match rx.recv() {
                            Ok(sr) => {
                                if !router.redispatch(sr, idx) {
                                    ctl.stats().rejected.inc();
                                }
                            }
                            Err(_) => return Ok(()),
                        }
                    }
                }
                restarts += 1;
                ctl.stats().bridge_restarts.inc();
                seat.note_restart();
                eprintln!(
                    "[gateway] bridge worker panicked; in-flight sessions retired, \
                     restarting ({restarts}/{})",
                    opts.max_restarts
                );
            }
        }
    }
}

/// Everything one connection handler needs: the router (which owns each
/// replica's request sender) and the per-request defaults.
struct HandlerCtx {
    router: Arc<Router>,
    default_deadline: Option<Duration>,
    keepalive: Duration,
    vocab: usize,
}

/// Keep-alive connection loop: parse requests until the peer closes, a
/// protocol error occurs, or a drain is requested.
fn handle_connection(mut stream: TcpStream, ctl: &GatewayCtl, hc: &HandlerCtx) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(hc.keepalive));
    loop {
        match HttpRequest::read_from(&mut stream) {
            Ok(None) => break, // peer closed between requests
            Ok(Some(req)) => {
                ctl.stats().http_requests.inc();
                let keep = req.keep_alive() && !ctl.is_draining();
                // a panic while serving one request must not take the
                // worker down: answer 500, count it, close this connection
                let served =
                    catch_unwind(AssertUnwindSafe(|| dispatch(&mut stream, &req, keep, ctl, hc)));
                match served {
                    Ok(r) => {
                        if r.is_err() || !keep {
                            break;
                        }
                    }
                    Err(_) => {
                        ctl.note_handler_panic();
                        let _ = write_response(
                            &mut stream,
                            500,
                            "text/plain",
                            b"internal server error",
                            false,
                        );
                        break;
                    }
                }
            }
            Err(HttpError::IdleTimeout) => {
                // idle keep-alive poll: stay open unless draining
                if ctl.is_draining() {
                    break;
                }
            }
            Err(HttpError::BadRequest(msg)) => {
                let _ = write_response(&mut stream, 400, "text/plain", msg.as_bytes(), false);
                break;
            }
            Err(HttpError::TooLarge(what)) => {
                let status = if what.contains("head") { 431 } else { 413 };
                let _ = write_response(&mut stream, status, "text/plain", what.as_bytes(), false);
                break;
            }
            Err(HttpError::Io(_)) => break,
        }
    }
}

fn dispatch(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep: bool,
    ctl: &GatewayCtl,
    hc: &HandlerCtx,
) -> std::io::Result<()> {
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => {
            write_response(stream, 200, "application/json", b"{\"ok\":true}", keep)
        }
        ("GET", "/stats") => {
            let doc = ctl.stats_json().dump();
            write_response(stream, 200, "application/json", doc.as_bytes(), keep)
        }
        ("GET", "/metrics") => {
            let body = ctl.registry().render_prometheus();
            write_response(
                stream,
                200,
                "text/plain; version=0.0.4; charset=utf-8",
                body.as_bytes(),
                keep,
            )
        }
        ("POST", "/admin/drain") => {
            ctl.drain();
            write_response(stream, 200, "application/json", b"{\"draining\":true}", false)
        }
        ("POST", "/generate") if ctl.is_draining() => {
            write_response(stream, 503, "text/plain", b"draining", false)
        }
        // load shedding: when every routable replica is at its free-page
        // watermark, refuse the admit NOW with a retry hint instead of
        // deferring indefinitely
        ("POST", "/generate") => match hc.router.admission() {
            Admission::Open => handle_generate(stream, req, keep, ctl, hc),
            Admission::Shed => shed_response(stream, ctl, keep),
            Admission::Closed => {
                write_response(stream, 503, "text/plain", b"server shutting down", false)
            }
        },
        (_, "/healthz" | "/stats" | "/metrics" | "/admin/drain" | "/generate") => {
            write_response(stream, 405, "text/plain", b"method not allowed", keep)
        }
        _ => write_response(stream, 404, "text/plain", b"not found", keep),
    }
}

fn shed_response(stream: &mut TcpStream, ctl: &GatewayCtl, keep: bool) -> std::io::Result<()> {
    ctl.stats().shed.inc();
    write_response_with(
        stream,
        503,
        "application/json",
        &[("retry-after", "1")],
        b"{\"error\":\"kv pool exhausted, retry\"}",
        keep,
    )
}

/// `POST /generate`: parse the schema-3 request, route it to a replica,
/// and stream its tokens back. The status line is withheld until the
/// FIRST stream event, so a rejection is a clean `413` rather than a
/// broken 200-stream.
fn handle_generate(
    stream: &mut TcpStream,
    req: &HttpRequest,
    keep: bool,
    ctl: &GatewayCtl,
    hc: &HandlerCtx,
) -> std::io::Result<()> {
    let greq = match GenerateRequest::parse(&req.body) {
        Ok(r) => r,
        Err(e) => {
            return write_response(stream, 400, "text/plain", e.to_string().as_bytes(), keep)
        }
    };
    let deadline = greq.deadline().or(hc.default_deadline).map(|d| Instant::now() + d);
    let (etx, erx) = mpsc::channel::<StreamEvent>();
    let sr = StreamRequest {
        prompt: greq.prompt_tokens(hc.vocab),
        max_new: greq.effective_max_new(),
        deadline,
        tx: etx,
    };
    match hc.router.dispatch(sr) {
        Ok(_replica) => {}
        Err(DispatchError::Shed(_)) => return shed_response(stream, ctl, keep),
        Err(DispatchError::Unavailable(_)) => {
            return write_response(stream, 503, "text/plain", b"server shutting down", false)
        }
    }
    let first = match erx.recv() {
        Ok(ev) => ev,
        Err(_) => {
            return write_response(stream, 500, "text/plain", b"stream worker gone", false)
        }
    };
    if let StreamEvent::Rejected(msg) = first {
        let doc = GenerateEvent::Error(msg).to_line();
        return write_response(stream, 413, "application/json", doc.as_bytes(), keep);
    }
    let sse = req.wants_sse();
    let content_type = if sse { "text/event-stream" } else { "application/json" };
    let mut cw = ChunkedWriter::start(stream, 200, content_type, keep)?;
    let mut ev = first;
    let mut trace: Option<String> = None;
    loop {
        let line = match &ev {
            StreamEvent::Token(t) => GenerateEvent::Token(*t).to_line(),
            StreamEvent::Done(d) => {
                trace = Some(d.trace.header_value());
                GenerateEvent::Done(DoneEvent {
                    generated: d.generated,
                    ttft_s: d.ttft_s,
                    latency_s: d.latency_s,
                    stopped: d.stopped.label().to_string(),
                    trace: Some(d.trace.to_json()),
                })
                .to_line()
            }
            // a rejection is always the first event; unreachable here, but
            // surface it rather than hang if that invariant ever breaks
            StreamEvent::Rejected(msg) => GenerateEvent::Error(msg.clone()).to_line(),
        };
        if sse {
            cw.sse_event(&line)?;
        } else {
            cw.chunk(format!("{line}\n").as_bytes())?;
        }
        if !matches!(ev, StreamEvent::Token(_)) {
            break;
        }
        ev = match erx.recv() {
            Ok(next) => next,
            Err(_) => break, // bridge died mid-stream; terminate the chunks
        };
    }
    // the per-request trace rides again as a chunked trailer, so clients
    // that skip the body (HEAD-ish probes, loadgen) still get the span
    match &trace {
        Some(t) => cw.finish_with_trailers(&[("x-stbllm-trace", t)]),
        None => cw.finish(),
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn ctl_with_seats(n: usize) -> (GatewayCtl, Arc<Router>) {
        let ctl = GatewayCtl::new();
        let reg = ctl.registry();
        let seats = (0..n)
            .map(|id| {
                let labeled = if n > 1 { Some(reg.as_ref()) } else { None };
                Arc::new(Seat::new(id, None, None, labeled))
            })
            .collect();
        let router = Arc::new(Router::new(seats, 0, &reg));
        ctl.set_router(Some(router.clone()));
        (ctl, router)
    }

    #[test]
    fn ctl_drain_flag_and_gauges() {
        let (ctl, router) = ctl_with_seats(1);
        assert!(!ctl.is_draining());
        ctl.drain();
        assert!(ctl.is_draining());
        router.seats()[0].set_load(3, 7);
        ctl.republish_gauges();
        assert_eq!(ctl.gauges(), (3, 7));
        // stats JSON is the schema-2 envelope; the gauges ride under
        // "gateway" and mirror into the registry exposition
        let doc = Json::parse(&ctl.stats_json().dump()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_usize().unwrap(), 2);
        assert_eq!(doc.path(&["gateway", "active"]).unwrap().as_usize().unwrap(), 3);
        assert_eq!(doc.path(&["gateway", "queued"]).unwrap().as_usize().unwrap(), 7);
        let text = ctl.registry().render_prometheus();
        assert!(text.contains("stbllm_gateway_active 3"), "{text}");
        assert!(text.contains("stbllm_gateway_queued 7"), "{text}");
    }

    #[test]
    fn stats_json_carries_a_replicas_section() {
        let (ctl, router) = ctl_with_seats(2);
        router.seats()[1].set_load(1, 2);
        router.seats()[1].note_completed();
        ctl.republish_gauges();
        let doc = Json::parse(&ctl.stats_json().dump()).unwrap();
        let rows = doc.get("replicas").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("id").and_then(Json::as_usize), Some(0));
        assert_eq!(rows[1].get("active").and_then(Json::as_usize), Some(1));
        assert_eq!(rows[1].get("completed").and_then(Json::as_usize), Some(1));
        // the flat gateway section stays: aggregate gauges sum the seats
        assert_eq!(doc.path(&["gateway", "active"]).and_then(Json::as_usize), Some(1));
        assert_eq!(doc.path(&["gateway", "queued"]).and_then(Json::as_usize), Some(2));
        // and per-replica labeled series land in the exposition
        let text = ctl.registry().render_prometheus();
        assert!(text.contains("stbllm_gateway_active{replica=\"1\"} 1"), "{text}");
        assert!(text.contains("stbllm_gateway_completed_total{replica=\"1\"} 1"), "{text}");
    }

    #[test]
    fn ctl_wait_bound_times_out_then_resolves() {
        let ctl = GatewayCtl::new();
        assert!(ctl.wait_bound(Duration::from_millis(20)).is_none());
        let addr: SocketAddr = "127.0.0.1:4242".parse().unwrap();
        ctl.set_bound(addr);
        assert_eq!(ctl.wait_bound(Duration::from_secs(1)), Some(addr));
    }

    #[test]
    fn tick_hook_fires_and_a_panicking_hook_does_not_poison_the_slot() {
        let ctl = GatewayCtl::new();
        let count = Arc::new(AtomicUsize::new(0));
        let c2 = count.clone();
        ctl.set_tick_hook(Some(Arc::new(move |replica, t| {
            assert_eq!(replica, 3, "hook must see the firing replica");
            c2.fetch_add(t as usize + 1, Ordering::SeqCst);
        })));
        ctl.fire_tick_hook(3, 0);
        ctl.fire_tick_hook(3, 1);
        assert_eq!(count.load(Ordering::SeqCst), 3);
        // the hook is called OUTSIDE the slot lock: a panicking hook
        // unwinds the caller but the slot stays usable
        ctl.set_tick_hook(Some(Arc::new(|_, _| panic!("injected hook panic"))));
        assert!(catch_unwind(AssertUnwindSafe(|| ctl.fire_tick_hook(0, 2))).is_err());
        ctl.set_tick_hook(None);
        ctl.fire_tick_hook(0, 3); // must not panic on a poisoned lock
    }

    #[test]
    fn report_json_includes_leak_count() {
        let report = GatewayReport {
            completed: 4,
            cancelled: 1,
            deadline_expired: 0,
            rejected: 2,
            generated_tokens: 40,
            kv: None,
            leaked_pages: 0,
        };
        let doc = Json::parse(&report.to_json().dump()).unwrap();
        assert_eq!(doc.get("completed").unwrap().as_usize().unwrap(), 4);
        assert_eq!(doc.get("leaked_pages").unwrap().as_usize().unwrap(), 0);
        assert!(doc.get("kv").is_none());
    }
}
