//! Minimal HTTP/1.1 over `std::net` — request parsing, response writing,
//! chunked transfer encoding and server-sent events.
//!
//! No external crates: the gateway only needs the sliver of HTTP/1.1 that
//! `curl`, browsers and the `stbllm loadgen` client speak — request line +
//! headers + `Content-Length` bodies in, fixed or chunked responses out.
//! The client-side helpers ([`read_response_head`], [`BodyReader`]) exist
//! so the load generator and the integration tests exercise the gateway
//! over real sockets instead of mocks.
//!
//! Headers are parsed with lowercased names; bodies are bounded by
//! [`MAX_BODY_BYTES`] and heads by [`MAX_HEAD_BYTES`] so a misbehaving
//! client cannot balloon server memory.

use std::io::{Read, Write};

/// Upper bound on the request line + headers of one request.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Upper bound on a request body (`Content-Length`).
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Errors surfaced while reading or parsing HTTP traffic.
#[derive(Debug)]
pub enum HttpError {
    /// The underlying socket failed mid-message.
    Io(std::io::Error),
    /// The peer sent something that is not HTTP/1.x (maps to `400`).
    BadRequest(String),
    /// Head or body exceeded its size bound (maps to `431`/`413`).
    TooLarge(&'static str),
    /// The read timed out while the connection was idle between requests —
    /// a keep-alive poll, not a protocol error.
    IdleTimeout,
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Io(e) => write!(f, "http i/o error: {e}"),
            HttpError::BadRequest(m) => write!(f, "bad request: {m}"),
            HttpError::TooLarge(what) => write!(f, "{what} too large"),
            HttpError::IdleTimeout => write!(f, "idle keep-alive timeout"),
        }
    }
}

impl std::error::Error for HttpError {}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// One parsed HTTP request.
#[derive(Debug)]
pub struct HttpRequest {
    /// Request method, uppercased by the client ("GET", "POST", ...).
    pub method: String,
    /// Raw request target, e.g. `/generate?mode=sse`.
    pub target: String,
    /// Protocol version string, e.g. `HTTP/1.1`.
    pub version: String,
    /// Header `(name, value)` pairs; names are lowercased at parse time.
    pub headers: Vec<(String, String)>,
    /// Request body (empty unless `Content-Length` was sent).
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First header value for `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// The target path with any query string stripped.
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// Whether the connection should stay open after the response
    /// (HTTP/1.1 defaults to keep-alive unless `Connection: close`).
    pub fn keep_alive(&self) -> bool {
        match self.header("connection").map(|v| v.to_ascii_lowercase()) {
            Some(v) if v.contains("close") => false,
            Some(v) if v.contains("keep-alive") => true,
            _ => self.version == "HTTP/1.1",
        }
    }

    /// Whether the client asked for a server-sent-events stream.
    pub fn wants_sse(&self) -> bool {
        self.header("accept").is_some_and(|a| a.contains("text/event-stream"))
    }

    /// Read and parse one request from `r`.
    ///
    /// Returns `Ok(None)` on clean EOF before any byte arrives (the peer
    /// closed an idle keep-alive connection); [`HttpError::IdleTimeout`]
    /// when the socket's read timeout fires while idle, so the caller can
    /// poll a drain flag and keep waiting.
    pub fn read_from<R: Read>(r: &mut R) -> Result<Option<HttpRequest>, HttpError> {
        // Byte-at-a-time head read: never consumes past the blank line, so
        // sequential requests on a keep-alive connection stay framed.
        let mut head: Vec<u8> = Vec::with_capacity(256);
        let mut byte = [0u8; 1];
        loop {
            match r.read(&mut byte) {
                Ok(0) => {
                    if head.is_empty() {
                        return Ok(None); // clean close between requests
                    }
                    return Err(HttpError::BadRequest("eof mid-header".into()));
                }
                Ok(_) => {
                    head.push(byte[0]);
                    if head.len() > MAX_HEAD_BYTES {
                        return Err(HttpError::TooLarge("request head"));
                    }
                    if head.ends_with(b"\r\n\r\n") {
                        break;
                    }
                }
                Err(e) if is_timeout(&e) && head.is_empty() => {
                    return Err(HttpError::IdleTimeout)
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(HttpError::Io(e)),
            }
        }
        let text = std::str::from_utf8(&head)
            .map_err(|_| HttpError::BadRequest("non-utf8 header block".into()))?;
        let mut lines = text.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split_whitespace();
        let (method, target, version) = match (parts.next(), parts.next(), parts.next()) {
            (Some(m), Some(t), Some(v)) if v.starts_with("HTTP/1") => {
                (m.to_string(), t.to_string(), v.to_string())
            }
            _ => {
                return Err(HttpError::BadRequest(format!("bad request line {request_line:?}")))
            }
        };
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let Some((name, value)) = line.split_once(':') else {
                return Err(HttpError::BadRequest(format!("bad header line {line:?}")));
            };
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
        let req = HttpRequest { method, target, version, headers, body: Vec::new() };
        let len = match req.header("content-length") {
            None => 0,
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| HttpError::BadRequest(format!("bad content-length {v:?}")))?,
        };
        if len > MAX_BODY_BYTES {
            return Err(HttpError::TooLarge("request body"));
        }
        let mut req = req;
        if len > 0 {
            let mut body = vec![0u8; len];
            r.read_exact(&mut body).map_err(HttpError::Io)?;
            req.body = body;
        }
        Ok(Some(req))
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "",
    }
}

/// Write a complete fixed-length response.
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_with(w, status, content_type, &[], body, keep_alive)
}

/// Write a complete fixed-length response with extra headers (e.g.
/// `Retry-After` on a load-shed 503).
pub fn write_response_with<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n",
        status,
        reason(status),
        content_type,
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    )?;
    for (name, value) in extra_headers {
        write!(w, "{name}: {value}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// A streaming response using chunked transfer encoding. Each
/// [`ChunkedWriter::chunk`] is flushed immediately so the peer observes
/// tokens as they are generated; [`ChunkedWriter::finish`] writes the
/// zero-length terminator.
pub struct ChunkedWriter<'w, W: Write> {
    w: &'w mut W,
}

impl<'w, W: Write> ChunkedWriter<'w, W> {
    /// Write the response head and switch the connection to chunked
    /// transfer encoding.
    pub fn start(
        w: &'w mut W,
        status: u16,
        content_type: &str,
        keep_alive: bool,
    ) -> std::io::Result<ChunkedWriter<'w, W>> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ntransfer-encoding: chunked\r\nconnection: {}\r\n\r\n",
            status,
            reason(status),
            content_type,
            if keep_alive { "keep-alive" } else { "close" },
        )?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// Emit one chunk (flushed).
    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        if data.is_empty() {
            return Ok(()); // an empty chunk would terminate the stream
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Emit one server-sent event carrying `data` (flushed).
    pub fn sse_event(&mut self, data: &str) -> std::io::Result<()> {
        self.chunk(format!("data: {data}\n\n").as_bytes())
    }

    /// Terminate the stream with the zero-length chunk.
    pub fn finish(self) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }

    /// Terminate the stream with the zero-length chunk followed by
    /// trailer headers (e.g. the `x-stbllm-trace` per-request span).
    pub fn finish_with_trailers(self, trailers: &[(&str, &str)]) -> std::io::Result<()> {
        self.w.write_all(b"0\r\n")?;
        for (name, value) in trailers {
            write!(self.w, "{name}: {value}\r\n")?;
        }
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }
}

/// Status line + headers of a response, as read by the client helpers.
#[derive(Debug)]
pub struct ResponseHead {
    /// HTTP status code.
    pub status: u16,
    /// Header `(name, value)` pairs, names lowercased.
    pub headers: Vec<(String, String)>,
}

impl ResponseHead {
    /// First header value for `name` (case-insensitive).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// Whether the body uses chunked transfer encoding.
    pub fn chunked(&self) -> bool {
        self.header("transfer-encoding").is_some_and(|v| v.eq_ignore_ascii_case("chunked"))
    }

    /// The `Content-Length`, if declared.
    pub fn content_length(&self) -> Option<usize> {
        self.header("content-length").and_then(|v| v.parse().ok())
    }
}

/// Client side: read a response's status line + headers from `r`.
pub fn read_response_head<R: Read>(r: &mut R) -> Result<ResponseHead, HttpError> {
    let mut head: Vec<u8> = Vec::with_capacity(256);
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => return Err(HttpError::BadRequest("eof before response head".into())),
            Ok(_) => {
                head.push(byte[0]);
                if head.len() > MAX_HEAD_BYTES {
                    return Err(HttpError::TooLarge("response head"));
                }
                if head.ends_with(b"\r\n\r\n") {
                    break;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
    let text = std::str::from_utf8(&head)
        .map_err(|_| HttpError::BadRequest("non-utf8 response head".into()))?;
    let mut lines = text.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| HttpError::BadRequest(format!("bad status line {status_line:?}")))?;
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
    }
    Ok(ResponseHead { status, headers })
}

/// Client side: incremental body reader for a [`ResponseHead`] — yields
/// chunk payloads one at a time for chunked bodies (what the streaming
/// endpoints emit per token), or the whole body once for fixed-length
/// responses.
pub struct BodyReader {
    chunked: bool,
    remaining_fixed: usize,
    done: bool,
    trailers: Vec<(String, String)>,
}

impl BodyReader {
    /// Build a reader matching `head`'s framing.
    pub fn new(head: &ResponseHead) -> BodyReader {
        BodyReader {
            chunked: head.chunked(),
            remaining_fixed: head.content_length().unwrap_or(0),
            done: false,
            trailers: Vec::new(),
        }
    }

    /// Trailer headers read after the terminating chunk (empty until the
    /// body has been fully consumed; names lowercased).
    pub fn trailers(&self) -> &[(String, String)] {
        &self.trailers
    }

    /// First trailer value for `name` (case-insensitive).
    pub fn trailer(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.trailers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }

    /// Next piece of the body: one chunk payload (chunked) or the whole
    /// remaining body (fixed length). `Ok(None)` once the body ends.
    pub fn next_piece<R: Read>(&mut self, r: &mut R) -> Result<Option<Vec<u8>>, HttpError> {
        if self.done {
            return Ok(None);
        }
        if !self.chunked {
            self.done = true;
            if self.remaining_fixed == 0 {
                return Ok(None);
            }
            let mut body = vec![0u8; self.remaining_fixed];
            r.read_exact(&mut body).map_err(HttpError::Io)?;
            return Ok(Some(body));
        }
        // chunk-size line (hex) \r\n payload \r\n
        let mut line = Vec::with_capacity(8);
        let mut byte = [0u8; 1];
        loop {
            match r.read(&mut byte) {
                Ok(0) => return Err(HttpError::BadRequest("eof in chunk size".into())),
                Ok(_) => {
                    line.push(byte[0]);
                    if line.len() > 32 {
                        return Err(HttpError::BadRequest("chunk size line too long".into()));
                    }
                    if line.ends_with(b"\r\n") {
                        break;
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(HttpError::Io(e)),
            }
        }
        let size_txt = std::str::from_utf8(&line[..line.len() - 2])
            .map_err(|_| HttpError::BadRequest("non-utf8 chunk size".into()))?
            .trim();
        let size = usize::from_str_radix(size_txt.split(';').next().unwrap_or(""), 16)
            .map_err(|_| HttpError::BadRequest(format!("bad chunk size {size_txt:?}")))?;
        if size == 0 {
            // terminator: zero or more trailer lines, then an empty line
            let mut budget = MAX_HEAD_BYTES;
            loop {
                let line = read_crlf_line(r, &mut budget)?;
                if line.is_empty() {
                    break;
                }
                if let Some((name, value)) = line.split_once(':') {
                    self.trailers
                        .push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
                }
            }
            self.done = true;
            return Ok(None);
        }
        if size > MAX_BODY_BYTES {
            return Err(HttpError::TooLarge("response chunk"));
        }
        let mut payload = vec![0u8; size + 2];
        r.read_exact(&mut payload).map_err(HttpError::Io)?;
        payload.truncate(size); // drop the trailing CRLF
        Ok(Some(payload))
    }

    /// Drain the rest of the body into one buffer.
    pub fn read_all<R: Read>(&mut self, r: &mut R) -> Result<Vec<u8>, HttpError> {
        let mut out = Vec::new();
        while let Some(piece) = self.next_piece(r)? {
            out.extend_from_slice(&piece);
        }
        Ok(out)
    }
}

/// Read one CRLF-terminated line (returned without the CRLF), debiting
/// `budget` per byte so a malicious trailer section cannot balloon memory.
fn read_crlf_line<R: Read>(r: &mut R, budget: &mut usize) -> Result<String, HttpError> {
    let mut line: Vec<u8> = Vec::with_capacity(32);
    let mut byte = [0u8; 1];
    loop {
        match r.read(&mut byte) {
            Ok(0) => return Err(HttpError::BadRequest("eof in chunk trailers".into())),
            Ok(_) => {
                if *budget == 0 {
                    return Err(HttpError::TooLarge("chunk trailers"));
                }
                *budget -= 1;
                line.push(byte[0]);
                if line.ends_with(b"\r\n") {
                    line.truncate(line.len() - 2);
                    let text = std::str::from_utf8(&line)
                        .map_err(|_| HttpError::BadRequest("non-utf8 trailer line".into()))?;
                    return Ok(text.to_string());
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(HttpError::Io(e)),
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use std::io::Cursor;

    #[test]
    fn extra_headers_are_emitted() {
        let mut wire = Vec::new();
        write_response_with(&mut wire, 503, "application/json", &[("retry-after", "1")], b"{}", false)
            .unwrap();
        let mut cur = Cursor::new(&wire[..]);
        let head = read_response_head(&mut cur).unwrap();
        assert_eq!(head.status, 503);
        assert_eq!(head.header("retry-after"), Some("1"));
        assert_eq!(BodyReader::new(&head).read_all(&mut cur).unwrap(), b"{}");
    }

    #[test]
    fn parses_request_with_body_and_headers() {
        let raw = b"POST /generate?x=1 HTTP/1.1\r\nHost: a\r\nContent-Length: 5\r\nAccept: text/event-stream\r\n\r\nhello";
        let req = HttpRequest::read_from(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path(), "/generate");
        assert_eq!(req.target, "/generate?x=1");
        assert_eq!(req.header("host"), Some("a"));
        assert_eq!(req.header("HOST"), Some("a"));
        assert_eq!(req.body, b"hello");
        assert!(req.keep_alive(), "HTTP/1.1 defaults to keep-alive");
        assert!(req.wants_sse());
    }

    #[test]
    fn keep_alive_semantics() {
        let raw = b"GET / HTTP/1.1\r\nConnection: close\r\n\r\n";
        let req = HttpRequest::read_from(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert!(!req.keep_alive());
        let raw = b"GET / HTTP/1.0\r\n\r\n";
        let req = HttpRequest::read_from(&mut Cursor::new(&raw[..])).unwrap().unwrap();
        assert!(!req.keep_alive(), "HTTP/1.0 defaults to close");
    }

    #[test]
    fn clean_eof_is_none_and_garbage_is_bad_request() {
        assert!(HttpRequest::read_from(&mut Cursor::new(&b""[..])).unwrap().is_none());
        match HttpRequest::read_from(&mut Cursor::new(&b"NOT HTTP\r\n\r\n"[..])) {
            Err(HttpError::BadRequest(_)) => {}
            other => panic!("expected BadRequest, got {other:?}"),
        }
        match HttpRequest::read_from(&mut Cursor::new(&b"GET /x HTTP/1.1\r\ntrunc"[..])) {
            Err(HttpError::BadRequest(_)) => {}
            other => panic!("expected BadRequest on eof mid-header, got {other:?}"),
        }
    }

    #[test]
    fn oversized_head_rejected() {
        let mut raw = b"GET / HTTP/1.1\r\nx: ".to_vec();
        raw.resize(raw.len() + MAX_HEAD_BYTES + 10, b'a');
        match HttpRequest::read_from(&mut Cursor::new(&raw[..])) {
            Err(HttpError::TooLarge(_)) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
    }

    #[test]
    fn two_requests_on_one_connection_stay_framed() {
        let raw =
            b"POST /a HTTP/1.1\r\nContent-Length: 3\r\n\r\nabcGET /b HTTP/1.1\r\n\r\n".to_vec();
        let mut cur = Cursor::new(&raw[..]);
        let a = HttpRequest::read_from(&mut cur).unwrap().unwrap();
        assert_eq!((a.path(), &a.body[..]), ("/a", &b"abc"[..]));
        let b = HttpRequest::read_from(&mut cur).unwrap().unwrap();
        assert_eq!(b.path(), "/b");
        assert!(HttpRequest::read_from(&mut cur).unwrap().is_none());
    }

    #[test]
    fn fixed_response_roundtrip() {
        let mut wire = Vec::new();
        write_response(&mut wire, 200, "application/json", b"{\"ok\":true}", true).unwrap();
        let mut cur = Cursor::new(&wire[..]);
        let head = read_response_head(&mut cur).unwrap();
        assert_eq!(head.status, 200);
        assert_eq!(head.content_length(), Some(11));
        assert!(!head.chunked());
        let body = BodyReader::new(&head).read_all(&mut cur).unwrap();
        assert_eq!(body, b"{\"ok\":true}");
    }

    #[test]
    fn chunked_roundtrip_streams_piecewise() {
        let mut wire = Vec::new();
        {
            let mut cw = ChunkedWriter::start(&mut wire, 200, "application/json", false).unwrap();
            cw.chunk(b"{\"t\":1}\n").unwrap();
            cw.chunk(b"{\"t\":2}\n").unwrap();
            cw.chunk(b"{\"done\":true}\n").unwrap();
            cw.finish().unwrap();
        }
        let mut cur = Cursor::new(&wire[..]);
        let head = read_response_head(&mut cur).unwrap();
        assert!(head.chunked());
        let mut br = BodyReader::new(&head);
        assert_eq!(br.next_piece(&mut cur).unwrap().unwrap(), b"{\"t\":1}\n");
        assert_eq!(br.next_piece(&mut cur).unwrap().unwrap(), b"{\"t\":2}\n");
        assert_eq!(br.next_piece(&mut cur).unwrap().unwrap(), b"{\"done\":true}\n");
        assert!(br.next_piece(&mut cur).unwrap().is_none());
        assert!(br.next_piece(&mut cur).unwrap().is_none(), "stays done");
        assert!(br.trailers().is_empty(), "plain finish has no trailers");
    }

    #[test]
    fn chunked_trailers_roundtrip() {
        let mut wire = Vec::new();
        {
            let mut cw = ChunkedWriter::start(&mut wire, 200, "application/json", true).unwrap();
            cw.chunk(b"{\"t\":1}\n").unwrap();
            cw.finish_with_trailers(&[
                ("x-stbllm-trace", "{\"total_ms\":1.5}"),
                ("X-Other", "v"),
            ])
            .unwrap();
        }
        let mut cur = Cursor::new(&wire[..]);
        let head = read_response_head(&mut cur).unwrap();
        let mut br = BodyReader::new(&head);
        assert_eq!(br.read_all(&mut cur).unwrap(), b"{\"t\":1}\n");
        assert_eq!(br.trailer("x-stbllm-trace"), Some("{\"total_ms\":1.5}"));
        assert_eq!(br.trailer("X-STBLLM-TRACE"), Some("{\"total_ms\":1.5}"));
        assert_eq!(br.trailer("x-other"), Some("v"));
        assert_eq!(br.trailers().len(), 2);
        // the connection stays framed: nothing left to read
        assert_eq!(cur.position() as usize, wire.len());
    }

    #[test]
    fn sse_event_formatting() {
        let mut wire = Vec::new();
        {
            let mut cw = ChunkedWriter::start(&mut wire, 200, "text/event-stream", false).unwrap();
            cw.sse_event("{\"t\":7}").unwrap();
            cw.finish().unwrap();
        }
        let mut cur = Cursor::new(&wire[..]);
        let head = read_response_head(&mut cur).unwrap();
        assert_eq!(head.header("content-type"), Some("text/event-stream"));
        let body = BodyReader::new(&head).read_all(&mut cur).unwrap();
        assert_eq!(body, b"data: {\"t\":7}\n\n");
    }
}
