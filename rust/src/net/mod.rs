//! `net/` — the HTTP streaming gateway over the batch server.
//!
//! Dependency-free (`std::net` only) HTTP/1.1 serving for the packed
//! sub-1-bit model: many concurrent clients share ONE resident model
//! through the same continuous-batching scheduler offline serving uses —
//! optionally as several decode replicas over the shared weights, behind
//! a prefix-affinity router.
//!
//! Module map:
//! * [`api`] — the versioned wire schema: typed [`GenerateRequest`] /
//!   [`GenerateEvent`] with one parse/serialize pair shared by the
//!   gateway, the load generator, the chaos harness and the tests.
//! * [`http`] — request parsing, fixed/chunked/SSE response writing, and
//!   the client-side helpers the load generator uses.
//! * [`listener`] — nonblocking acceptor + bounded worker pool.
//! * [`bridge`] — the decode-side worker: feeds requests into the shared
//!   `BatchServer` scheduling kernel and streams tokens back per tick,
//!   with deadlines, disconnect cancellation, and graceful drain.
//! * [`router`] — replica seats and the [`Router`]: prompt-prefix
//!   affinity, least-loaded fallback, per-replica shed watermarks, and
//!   dead-replica request migration.
//! * [`gateway`] — endpoints (`/generate`, `/healthz`, `/stats`,
//!   `/metrics`, `/admin/drain`), connection handling, load shedding
//!   (503 + `Retry-After` when every replica's KV pool nears
//!   exhaustion), the bridge panic supervisor, and [`serve_http`] tying
//!   it all together.
//! * [`stats`] — registry-backed [`GatewayStats`] handles (including the
//!   fault counters: `shed`, `handler_panics`, `bridge_panics`,
//!   `bridge_restarts`) and the schema-2 `/stats` snapshot. The same
//!   registry renders the `GET /metrics` Prometheus exposition, and every
//!   `/generate` response carries a per-request trace (done-event
//!   `"trace"` + `x-stbllm-trace` trailer).
//!
//! Entry points: `stbllm serve --http ADDR [--replicas R]` (CLI),
//! [`serve_http`] (library), [`bridge::serve_stream`] (in-process
//! streaming without sockets).

pub mod api;
pub mod bridge;
pub mod gateway;
pub mod http;
pub mod listener;
pub mod router;
pub mod stats;

pub use api::{
    split_lines, ApiError, DoneEvent, GenerateEvent, GenerateRequest, Prompt, API_SCHEMA_VERSION,
};
pub use bridge::{serve_stream, BridgeOpts, DoneInfo, StreamEvent, StreamRequest};
pub use gateway::{serve_http, GatewayCtl, GatewayReport, ServeConfig, TickHook};
pub use router::{
    Admission, DispatchError, ReplicaSnapshot, ReplicasSnapshot, Router, Seat,
    AFFINITY_PREFIX_TOKENS,
};
pub use stats::{GatewaySnapshot, GatewayStats, StopReason};
