//! `net/` — the HTTP streaming gateway over the batch server.
//!
//! Dependency-free (`std::net` only) HTTP/1.1 serving for the packed
//! sub-1-bit model: many concurrent clients share ONE resident model
//! through the same continuous-batching scheduler offline serving uses.
//!
//! Module map:
//! * [`http`] — request parsing, fixed/chunked/SSE response writing, and
//!   the client-side helpers the load generator uses.
//! * [`listener`] — nonblocking acceptor + bounded worker pool.
//! * [`bridge`] — the decode-side worker: feeds requests into the shared
//!   `BatchServer` scheduling kernel and streams tokens back per tick,
//!   with deadlines, disconnect cancellation, and graceful drain.
//! * [`gateway`] — endpoints (`/generate`, `/healthz`, `/stats`,
//!   `/metrics`, `/admin/drain`), connection handling, load shedding
//!   (503 + `Retry-After` when the KV pool nears exhaustion), the bridge
//!   panic supervisor, and [`serve_http`] tying it all together.
//! * [`stats`] — registry-backed [`GatewayStats`] handles (including the
//!   fault counters: `shed`, `handler_panics`, `bridge_panics`,
//!   `bridge_restarts`) and the schema-2 `/stats` snapshot. The same
//!   registry renders the `GET /metrics` Prometheus exposition, and every
//!   `/generate` response carries a per-request trace (done-event
//!   `"trace"` + `x-stbllm-trace` trailer).
//!
//! Entry points: `stbllm serve --http ADDR` (CLI), [`serve_http`]
//! (library), [`bridge::serve_stream`] (in-process streaming without
//! sockets).

pub mod bridge;
pub mod gateway;
pub mod http;
pub mod listener;
pub mod stats;

pub use bridge::{serve_stream, BridgeOpts, DoneInfo, StreamEvent, StreamRequest};
pub use gateway::{serve_http, GatewayCtl, GatewayReport, HttpServeOpts, TickHook};
pub use stats::{GatewaySnapshot, GatewayStats, StopReason};
