//! Live gateway counters — what `GET /stats` serializes and what the
//! final drain report aggregates.
//!
//! One [`GatewayStats`] lives behind a mutex shared by the HTTP workers
//! (request/connection counters), the bridge worker (stream lifecycle,
//! token counters, latency samples) and the `/stats` endpoint (snapshot).
//! KV pool counters are NOT stored here — the endpoint snapshots the live
//! [`KvPoolStats`] straight from the pool so the numbers are current, not
//! end-of-run.

use std::time::Instant;

use crate::coordinator::kvpool::KvPoolStats;
use crate::coordinator::server::percentile;
use crate::util::json::{num, obj, Json};

/// Why a stream ended — reported in the final event of every stream and
/// tallied in [`GatewayStats`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The request generated its full `max_new` tokens.
    Completed,
    /// The per-request deadline expired; the stream carries the tokens
    /// generated up to that point.
    Deadline,
}

impl StopReason {
    /// Wire label used in the final stream event and the stats JSON.
    pub fn label(&self) -> &'static str {
        match self {
            StopReason::Completed => "completed",
            StopReason::Deadline => "deadline",
        }
    }
}

/// Counters for the HTTP gateway, accumulated across connections and
/// streams. All derived rates are finite by construction (empty runs
/// report zeros).
#[derive(Debug)]
pub struct GatewayStats {
    /// Connections accepted by the listener.
    pub connections: usize,
    /// HTTP requests parsed (all endpoints).
    pub http_requests: usize,
    /// Generation streams admitted into the batch loop.
    pub streams_started: usize,
    /// Streams that ran to completion.
    pub completed: usize,
    /// Streams cancelled because the client disconnected mid-stream
    /// (their KV pages were released back to the pool).
    pub cancelled: usize,
    /// Streams stopped by their deadline (partial output delivered).
    pub deadline_expired: usize,
    /// Requests refused at admission (can never fit the KV budget).
    pub rejected: usize,
    /// Admission backpressure events (deferred, later admitted).
    pub deferred: usize,
    /// New admits shed with `503 + Retry-After` because free KV pages were
    /// below the load-shed watermark.
    pub shed: usize,
    /// Connection handlers that panicked (the connection got a 500 or was
    /// dropped; the gateway kept serving).
    pub handler_panics: usize,
    /// Bridge decode-worker panics caught by the supervisor (each one
    /// retired all in-flight sessions and released their KV pages).
    pub bridge_panics: usize,
    /// Bridge restarts performed by the supervisor after a panic.
    pub bridge_restarts: usize,
    /// Tokens generated across all streams.
    pub generated_tokens: usize,
    /// Seconds-to-first-token samples of completed streams.
    ttfts: Vec<f64>,
    /// End-to-end latency samples of completed streams.
    latencies: Vec<f64>,
    started: Instant,
}

impl Default for GatewayStats {
    fn default() -> GatewayStats {
        GatewayStats {
            connections: 0,
            http_requests: 0,
            streams_started: 0,
            completed: 0,
            cancelled: 0,
            deadline_expired: 0,
            rejected: 0,
            deferred: 0,
            shed: 0,
            handler_panics: 0,
            bridge_panics: 0,
            bridge_restarts: 0,
            generated_tokens: 0,
            ttfts: Vec::new(),
            latencies: Vec::new(),
            started: Instant::now(),
        }
    }
}

impl GatewayStats {
    /// Record a finished stream's latency samples.
    pub fn record_finished(&mut self, ttft_s: f64, latency_s: f64) {
        self.ttfts.push(ttft_s);
        self.latencies.push(latency_s);
    }

    /// Wall-clock seconds since the gateway started.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Aggregate decode throughput over the gateway's uptime; `0.0` when
    /// nothing was generated (always finite).
    pub fn tokens_per_s(&self) -> f64 {
        let up = self.uptime_s();
        if self.generated_tokens == 0 || up <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / up
    }

    /// Serialize the counters (+ a live [`KvPoolStats`] snapshot and the
    /// current in-flight gauges) into the `/stats` JSON document.
    pub fn to_json(&self, kv: Option<&KvPoolStats>, active: usize, queued: usize) -> Json {
        let mut ttfts = self.ttfts.clone();
        let mut lats = self.latencies.clone();
        ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        let mut fields: Vec<(&str, Json)> = vec![
            ("uptime_s", num(self.uptime_s())),
            ("connections", num(self.connections as f64)),
            ("http_requests", num(self.http_requests as f64)),
            ("streams_started", num(self.streams_started as f64)),
            ("completed", num(self.completed as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("deadline_expired", num(self.deadline_expired as f64)),
            ("rejected", num(self.rejected as f64)),
            ("deferred", num(self.deferred as f64)),
            ("shed", num(self.shed as f64)),
            ("handler_panics", num(self.handler_panics as f64)),
            ("bridge_panics", num(self.bridge_panics as f64)),
            ("bridge_restarts", num(self.bridge_restarts as f64)),
            ("active", num(active as f64)),
            ("queued", num(queued as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("tokens_per_s", num(self.tokens_per_s())),
            ("ttft_p50_s", num(percentile(&ttfts, 50.0))),
            ("ttft_p95_s", num(percentile(&ttfts, 95.0))),
            ("latency_p50_s", num(percentile(&lats, 50.0))),
            ("latency_p95_s", num(percentile(&lats, 95.0))),
        ];
        if let Some(kv) = kv {
            fields.push(("kv", kv_json(kv)));
        }
        obj(fields)
    }
}

/// Serialize a [`KvPoolStats`] snapshot (shared by `/stats` and the CLI's
/// drain report).
pub fn kv_json(kv: &KvPoolStats) -> Json {
    obj(vec![
        ("total_pages", num(kv.total_pages as f64)),
        ("page_size", num(kv.page_size as f64)),
        ("pages_in_use", num(kv.pages_in_use as f64)),
        ("pages_reserved", num(kv.pages_reserved as f64)),
        ("peak_pages", num(kv.peak_pages as f64)),
        ("allocated_total", num(kv.allocated_total as f64)),
        ("cow_copies", num(kv.cow_copies as f64)),
        ("prefix_hits", num(kv.prefix_hits as f64)),
        ("prefix_hit_tokens", num(kv.prefix_hit_tokens as f64)),
        ("evictions", num(kv.evictions as f64)),
    ])
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;

    #[test]
    fn empty_stats_serialize_finite() {
        let s = GatewayStats::default();
        assert_eq!(s.tokens_per_s(), 0.0);
        let j = s.to_json(None, 0, 0);
        let parsed = Json::parse(&j.dump()).unwrap();
        assert_eq!(parsed.get("completed").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(parsed.get("ttft_p95_s").unwrap().as_f64().unwrap(), 0.0);
        assert!(parsed.get("kv").is_none());
    }

    #[test]
    fn fault_counters_serialize() {
        let mut s = GatewayStats::default();
        s.shed = 3;
        s.handler_panics = 1;
        s.bridge_panics = 2;
        s.bridge_restarts = 2;
        let j = s.to_json(None, 0, 0);
        assert_eq!(j.get("shed").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(j.get("handler_panics").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(j.get("bridge_panics").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("bridge_restarts").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn latency_percentiles_appear_in_json() {
        let mut s = GatewayStats::default();
        for i in 1..=20 {
            s.record_finished(i as f64 / 100.0, i as f64 / 10.0);
        }
        s.completed = 20;
        s.generated_tokens = 100;
        let j = s.to_json(None, 2, 3);
        assert_eq!(j.get("ttft_p50_s").unwrap().as_f64().unwrap(), 0.10);
        assert_eq!(j.get("latency_p95_s").unwrap().as_f64().unwrap(), 1.9);
        assert_eq!(j.get("active").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(j.get("queued").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn stop_reason_labels() {
        assert_eq!(StopReason::Completed.label(), "completed");
        assert_eq!(StopReason::Deadline.label(), "deadline");
    }
}
