//! Gateway-side observability: registry-backed counters/gauges/histograms
//! plus the schema-2 `/stats` snapshot.
//!
//! [`GatewayStats`] used to be a mutex-guarded struct of plain `usize`
//! fields; it is now a bundle of lock-free [`obs`](crate::obs) handles
//! minted from the gateway's [`Registry`], so every bump is visible both
//! to `GET /stats` (exact values via [`GatewayStats::snapshot`]) and to
//! `GET /metrics` (Prometheus exposition via the shared registry). The
//! ttft/latency sample vectors stay under a small mutex so `/stats` can
//! report exact nearest-rank percentiles; the registry histograms carry
//! the same samples at bucket granularity for Prometheus. KV pool counters
//! are NOT stored here — the endpoint snapshots the live [`KvPoolStats`]
//! straight from the pool so the numbers are current, not end-of-run.

use std::sync::{Arc, Mutex, PoisonError};
use std::time::Instant;

use crate::coordinator::kvpool::KvPoolStats;
use crate::obs::{percentile, Counter, Gauge, Histogram, Registry, Snapshot};
use crate::util::json::{num, obj, Json};

/// Why a stream ended — reported in the final event of every stream and
/// tallied in [`GatewayStats`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The request generated its full `max_new` tokens.
    Completed,
    /// The per-request deadline expired; the stream carries the tokens
    /// generated up to that point.
    Deadline,
}

impl StopReason {
    /// Wire label used in the final stream event and the stats JSON.
    pub fn label(&self) -> &'static str {
        match self {
            StopReason::Completed => "completed",
            StopReason::Deadline => "deadline",
        }
    }
}

/// Latency samples kept for exact `/stats` percentiles.
#[derive(Default)]
struct Samples {
    ttfts: Vec<f64>,
    latencies: Vec<f64>,
}

/// Live gateway counters, registry-backed. Every field is a lock-free
/// handle minted from the gateway's [`Registry`]; bumps are visible to
/// clones of the handle and to the registry's `/metrics` exposition alike,
/// with no lock on any hot path.
pub struct GatewayStats {
    registry: Arc<Registry>,
    /// TCP connections accepted.
    pub connections: Arc<Counter>,
    /// HTTP requests parsed (all endpoints).
    pub http_requests: Arc<Counter>,
    /// Generation streams enqueued into the bridge.
    pub streams_started: Arc<Counter>,
    /// Streams that ran to completion.
    pub completed: Arc<Counter>,
    /// Streams cancelled by client disconnect.
    pub cancelled: Arc<Counter>,
    /// Streams stopped by their deadline.
    pub deadline_expired: Arc<Counter>,
    /// Requests refused at admission (can never fit).
    pub rejected: Arc<Counter>,
    /// Admission deferral events.
    pub deferred: Arc<Counter>,
    /// Requests shed at the KV free-page watermark.
    pub shed: Arc<Counter>,
    /// Connection handler panics answered with 500.
    pub handler_panics: Arc<Counter>,
    /// Bridge worker panics caught by the supervisor.
    pub bridge_panics: Arc<Counter>,
    /// Bridge worker restarts after a panic.
    pub bridge_restarts: Arc<Counter>,
    /// Tokens streamed to clients.
    pub generated_tokens: Arc<Counter>,
    /// Streams currently decoding.
    pub active_g: Arc<Gauge>,
    /// Streams waiting for admission.
    pub queued_g: Arc<Gauge>,
    /// Enqueue → first token, per finished stream.
    pub ttft_h: Arc<Histogram>,
    /// Enqueue → stream end, per finished stream.
    pub latency_h: Arc<Histogram>,
    samples: Mutex<Samples>,
    started: Instant,
}

impl Default for GatewayStats {
    fn default() -> GatewayStats {
        GatewayStats::new(Arc::new(Registry::new()))
    }
}

impl GatewayStats {
    /// Mint the gateway's metric handles from `registry`.
    pub fn new(registry: Arc<Registry>) -> GatewayStats {
        let r = &registry;
        GatewayStats {
            connections: r.counter("stbllm_gateway_connections", "TCP connections accepted"),
            http_requests: r.counter("stbllm_gateway_http_requests", "HTTP requests parsed"),
            streams_started: r
                .counter("stbllm_gateway_streams_started", "generation streams enqueued"),
            completed: r.counter("stbllm_gateway_completed", "streams run to completion"),
            cancelled: r
                .counter("stbllm_gateway_cancelled", "streams cancelled by client disconnect"),
            deadline_expired: r
                .counter("stbllm_gateway_deadline_expired", "streams stopped by their deadline"),
            rejected: r.counter("stbllm_gateway_rejected", "requests refused at admission"),
            deferred: r.counter("stbllm_gateway_deferred", "admission deferral events"),
            shed: r.counter("stbllm_gateway_shed", "requests shed at the KV free-page watermark"),
            handler_panics: r
                .counter("stbllm_gateway_handler_panics", "connection handler panics"),
            bridge_panics: r.counter("stbllm_gateway_bridge_panics", "bridge worker panics"),
            bridge_restarts: r
                .counter("stbllm_gateway_bridge_restarts", "bridge restarts after a panic"),
            generated_tokens: r
                .counter("stbllm_gateway_generated_tokens", "tokens streamed to clients"),
            active_g: r.gauge("stbllm_gateway_active", "streams currently decoding"),
            queued_g: r.gauge("stbllm_gateway_queued", "streams waiting for admission"),
            ttft_h: r.histogram("stbllm_gateway_ttft_seconds", "enqueue to first token"),
            latency_h: r.histogram("stbllm_gateway_latency_seconds", "enqueue to stream end"),
            samples: Mutex::new(Samples::default()),
            started: Instant::now(),
            registry,
        }
    }

    /// The registry all handles were minted from (shared with the bridge's
    /// batch server and the KV pool mirror; rendered by `GET /metrics`).
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Record a finished stream's first-token and total latency, both in
    /// the exact sample vectors (for `/stats` percentiles) and in the
    /// registry histograms (for `/metrics`).
    pub fn record_finished(&self, ttft_s: f64, latency_s: f64) {
        self.ttft_h.record_secs(ttft_s);
        self.latency_h.record_secs(latency_s);
        let mut guard = self.samples.lock().unwrap_or_else(PoisonError::into_inner);
        guard.ttfts.push(ttft_s);
        guard.latencies.push(latency_s);
    }

    /// Seconds since the gateway started.
    pub fn uptime_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Generated-token throughput over the gateway's lifetime.
    pub fn tokens_per_s(&self) -> f64 {
        let up = self.uptime_s();
        if up > 0.0 {
            self.generated_tokens.get() as f64 / up
        } else {
            0.0
        }
    }

    /// Freeze the live handles into a [`GatewaySnapshot`] (the `"gateway"`
    /// section of the `/stats` envelope). `kv`, `active` and `queued` come
    /// from the caller because they live outside this struct (the pool and
    /// the bridge gauges).
    pub fn snapshot(
        &self,
        kv: Option<KvPoolStats>,
        active: usize,
        queued: usize,
    ) -> GatewaySnapshot {
        let (ttft_p50, ttft_p95, lat_p50, lat_p95) = {
            let mut guard = self.samples.lock().unwrap_or_else(PoisonError::into_inner);
            guard.ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            guard
                .latencies
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            (
                percentile(&guard.ttfts, 50.0),
                percentile(&guard.ttfts, 95.0),
                percentile(&guard.latencies, 50.0),
                percentile(&guard.latencies, 95.0),
            )
        };
        GatewaySnapshot {
            uptime_s: self.uptime_s(),
            connections: self.connections.get(),
            http_requests: self.http_requests.get(),
            streams_started: self.streams_started.get(),
            completed: self.completed.get(),
            cancelled: self.cancelled.get(),
            deadline_expired: self.deadline_expired.get(),
            rejected: self.rejected.get(),
            deferred: self.deferred.get(),
            shed: self.shed.get(),
            handler_panics: self.handler_panics.get(),
            bridge_panics: self.bridge_panics.get(),
            bridge_restarts: self.bridge_restarts.get(),
            active,
            queued,
            generated_tokens: self.generated_tokens.get(),
            tokens_per_s: self.tokens_per_s(),
            ttft_p50_s: ttft_p50,
            ttft_p95_s: ttft_p95,
            latency_p50_s: lat_p50,
            latency_p95_s: lat_p95,
            kv,
        }
    }
}

/// A frozen view of the gateway counters — the `"gateway"` section of the
/// schema-2 `/stats` envelope. Field set and JSON key names match the
/// pre-redesign flat document exactly (now nested one level down).
#[derive(Clone, Debug)]
pub struct GatewaySnapshot {
    /// Seconds since the gateway started.
    pub uptime_s: f64,
    /// TCP connections accepted.
    pub connections: u64,
    /// HTTP requests parsed.
    pub http_requests: u64,
    /// Generation streams enqueued.
    pub streams_started: u64,
    /// Streams run to completion.
    pub completed: u64,
    /// Streams cancelled by disconnect.
    pub cancelled: u64,
    /// Streams stopped by deadline.
    pub deadline_expired: u64,
    /// Requests refused at admission.
    pub rejected: u64,
    /// Admission deferral events.
    pub deferred: u64,
    /// Requests load-shed at the watermark.
    pub shed: u64,
    /// Handler panics answered with 500.
    pub handler_panics: u64,
    /// Bridge panics caught by the supervisor.
    pub bridge_panics: u64,
    /// Bridge restarts after panics.
    pub bridge_restarts: u64,
    /// Streams currently decoding.
    pub active: usize,
    /// Streams waiting for admission.
    pub queued: usize,
    /// Tokens streamed to clients.
    pub generated_tokens: u64,
    /// Lifetime token throughput.
    pub tokens_per_s: f64,
    /// Exact nearest-rank p50 of first-token latency.
    pub ttft_p50_s: f64,
    /// Exact nearest-rank p95 of first-token latency.
    pub ttft_p95_s: f64,
    /// Exact nearest-rank p50 of stream latency.
    pub latency_p50_s: f64,
    /// Exact nearest-rank p95 of stream latency.
    pub latency_p95_s: f64,
    /// Live KV pool snapshot (`None` on flat serving).
    pub kv: Option<KvPoolStats>,
}

impl Snapshot for GatewaySnapshot {
    fn name(&self) -> &'static str {
        "gateway"
    }

    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("uptime_s", num(self.uptime_s)),
            ("connections", num(self.connections as f64)),
            ("http_requests", num(self.http_requests as f64)),
            ("streams_started", num(self.streams_started as f64)),
            ("completed", num(self.completed as f64)),
            ("cancelled", num(self.cancelled as f64)),
            ("deadline_expired", num(self.deadline_expired as f64)),
            ("rejected", num(self.rejected as f64)),
            ("deferred", num(self.deferred as f64)),
            ("shed", num(self.shed as f64)),
            ("handler_panics", num(self.handler_panics as f64)),
            ("bridge_panics", num(self.bridge_panics as f64)),
            ("bridge_restarts", num(self.bridge_restarts as f64)),
            ("active", num(self.active as f64)),
            ("queued", num(self.queued as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("tokens_per_s", num(self.tokens_per_s)),
            ("ttft_p50_s", num(self.ttft_p50_s)),
            ("ttft_p95_s", num(self.ttft_p95_s)),
            ("latency_p50_s", num(self.latency_p50_s)),
            ("latency_p95_s", num(self.latency_p95_s)),
        ];
        if let Some(kv) = &self.kv {
            fields.push(("kv", kv.to_json()));
        }
        obj(fields)
    }
}

/// JSON form of a KV pool snapshot (used by the drain report as well as
/// the `/stats` envelope) — delegates to the pool's [`Snapshot`] impl.
pub fn kv_json(kv: &KvPoolStats) -> Json {
    kv.to_json()
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use crate::obs::envelope;

    fn finite(v: &Json, key: &str) -> f64 {
        let f = v.get(key).and_then(Json::as_f64).unwrap_or_else(|| panic!("missing {key}"));
        assert!(f.is_finite(), "{key} not finite: {f}");
        f
    }

    #[test]
    fn empty_stats_serialize_finite() {
        let st = GatewayStats::default();
        let doc = Json::parse(&st.snapshot(None, 0, 0).to_json().dump()).unwrap();
        for key in [
            "uptime_s",
            "connections",
            "completed",
            "generated_tokens",
            "tokens_per_s",
            "ttft_p50_s",
            "latency_p95_s",
        ] {
            finite(&doc, key);
        }
        assert!(doc.get("kv").is_none());
    }

    #[test]
    fn fault_counters_serialize() {
        let st = GatewayStats::default();
        st.shed.add(3);
        st.handler_panics.add(2);
        st.bridge_panics.inc();
        st.bridge_restarts.inc();
        let doc = st.snapshot(None, 0, 0).to_json();
        assert_eq!(finite(&doc, "shed"), 3.0);
        assert_eq!(finite(&doc, "handler_panics"), 2.0);
        assert_eq!(finite(&doc, "bridge_panics"), 1.0);
        assert_eq!(finite(&doc, "bridge_restarts"), 1.0);
    }

    #[test]
    fn latency_percentiles_appear_in_json() {
        let st = GatewayStats::default();
        for i in 1..=20 {
            st.record_finished(i as f64 / 10.0, i as f64 / 10.0 + 0.05);
        }
        let doc = st.snapshot(None, 2, 1).to_json();
        assert_eq!(finite(&doc, "ttft_p50_s"), 1.0);
        assert_eq!(finite(&doc, "ttft_p95_s"), 1.9);
        assert!((finite(&doc, "latency_p50_s") - 1.05).abs() < 1e-9);
        assert!((finite(&doc, "latency_p95_s") - 1.95).abs() < 1e-9);
        assert_eq!(finite(&doc, "active"), 2.0);
        assert_eq!(finite(&doc, "queued"), 1.0);
        // the same samples land in the registry histograms for /metrics
        assert_eq!(st.ttft_h.count(), 20);
        assert_eq!(st.latency_h.count(), 20);
    }

    #[test]
    fn snapshot_rides_in_the_schema2_envelope() {
        let st = GatewayStats::default();
        st.completed.add(4);
        let snap = st.snapshot(None, 0, 0);
        let doc = envelope(&[&snap]);
        assert_eq!(doc.get("schema").and_then(Json::as_usize), Some(2));
        assert_eq!(doc.path(&["gateway", "completed"]).and_then(Json::as_usize), Some(4));
    }

    #[test]
    fn counters_mirror_into_the_prometheus_exposition() {
        let st = GatewayStats::default();
        st.connections.add(5);
        st.generated_tokens.add(17);
        st.active_g.set(2);
        st.record_finished(0.1, 0.2);
        let text = st.registry().render_prometheus();
        assert!(text.contains("stbllm_gateway_connections_total 5"), "{text}");
        assert!(text.contains("stbllm_gateway_generated_tokens_total 17"), "{text}");
        assert!(text.contains("stbllm_gateway_active 2"), "{text}");
        assert!(text.contains("stbllm_gateway_latency_seconds_count 1"), "{text}");
    }

    #[test]
    fn stop_reason_labels() {
        assert_eq!(StopReason::Completed.label(), "completed");
        assert_eq!(StopReason::Deadline.label(), "deadline");
    }
}
