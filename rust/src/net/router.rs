//! Prefix-affinity routing across shared-weight engine replicas.
//!
//! `serve_http --replicas R` runs R independent decode workers — each its
//! own `BatchServer`, bridge thread and KV pool slice — over ONE resident
//! set of packed weights (every replica borrows the same `&dyn Backend`;
//! sub-1-bit packing is what makes R decode loops per host affordable).
//! The [`Router`] is the admission seam between the HTTP handlers and
//! those workers:
//!
//! * **Prefix affinity** — a request is routed by a hash of its prompt
//!   prefix ([`Router::affine_replica`]), so repeated prompts land on the
//!   replica whose KV pool already holds their prefix pages and the
//!   prefix cache keeps hitting across replicas.
//! * **Least-loaded fallback** — if the affine replica is dead or below
//!   its free-page watermark, the stream goes to the alive replica with
//!   the fewest in-flight streams instead.
//! * **Shed** — if no replica can take the stream, admission refuses it
//!   (`503 + Retry-After` at the gateway) rather than queueing forever.
//! * **Migration on replica death** — when a replica exhausts its panic
//!   restarts, its supervisor turns into a forwarder pump: requests still
//!   queued on the dead replica's channel are re-dispatched through
//!   [`Router::redispatch`] to surviving replicas instead of dying with
//!   the worker.
//!
//! Every decision is counted (`stbllm_router_affinity`,
//! `stbllm_router_fallback`, `stbllm_router_migrated`) and the pick +
//! channel handoff is timed (`stbllm_router_dispatch_seconds`). With more
//! than one replica, each [`Seat`] additionally publishes the existing
//! gateway gauges and fault counters under a `replica="N"` label.
//!
//! Greedy decode makes a stream's bytes a pure function of its prompt, so
//! routing — whichever replica wins — can never change what a client
//! receives; the `--replicas 2` parity test pins that.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, PoisonError};
use std::time::Instant;

use crate::coordinator::kvpool::{KvPool, KvPoolStats};
use crate::net::bridge::{StreamEvent, StreamRequest};
use crate::obs::{Counter, Gauge, Histogram, Registry, Snapshot};
use crate::util::json::{num, obj, Json};

/// How many leading prompt tokens feed the affinity hash. Matches the
/// scale of a few KV pages, so prompts sharing a cacheable prefix share a
/// replica even when their tails differ.
pub const AFFINITY_PREFIX_TOKENS: usize = 16;

/// Labeled per-replica handles, minted only when `replicas > 1` — with a
/// single seat the unlabeled aggregate series already tell the whole
/// story, and minting both would double-publish.
struct SeatMetrics {
    active_g: Arc<Gauge>,
    queued_g: Arc<Gauge>,
    completed: Arc<Counter>,
    panics: Arc<Counter>,
    restarts: Arc<Counter>,
    routed: Arc<Counter>,
}

/// One replica as the router sees it: its request channel, KV pool slice,
/// live load, and fault history. The plain atomics are authoritative (the
/// `/stats` replicas section reads them); the optional labeled registry
/// handles mirror them into `/metrics`.
pub struct Seat {
    id: usize,
    pool: Option<Arc<KvPool>>,
    tx: Mutex<Option<mpsc::SyncSender<StreamRequest>>>,
    active: AtomicUsize,
    queued: AtomicUsize,
    dead: AtomicBool,
    completed: AtomicU64,
    panics: AtomicU64,
    restarts: AtomicU64,
    metrics: Option<SeatMetrics>,
}

impl Seat {
    /// Build a seat. `labeled` is the registry to mint `replica="id"`
    /// series from — pass `Some` only when serving more than one replica.
    pub(crate) fn new(
        id: usize,
        pool: Option<Arc<KvPool>>,
        tx: Option<mpsc::SyncSender<StreamRequest>>,
        labeled: Option<&Registry>,
    ) -> Seat {
        let metrics = labeled.map(|r| {
            let l = format!("replica=\"{id}\"");
            SeatMetrics {
                active_g: r.gauge_with("stbllm_gateway_active", &l, "streams currently decoding"),
                queued_g: r.gauge_with(
                    "stbllm_gateway_queued",
                    &l,
                    "streams waiting for admission",
                ),
                completed: r.counter_with(
                    "stbllm_gateway_completed",
                    &l,
                    "streams run to completion",
                ),
                panics: r.counter_with("stbllm_gateway_bridge_panics", &l, "bridge worker panics"),
                restarts: r.counter_with(
                    "stbllm_gateway_bridge_restarts",
                    &l,
                    "bridge restarts after a panic",
                ),
                routed: r.counter_with(
                    "stbllm_router_routed",
                    &l,
                    "streams handed to this replica",
                ),
            }
        });
        Seat {
            id,
            pool,
            tx: Mutex::new(tx),
            active: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            dead: AtomicBool::new(false),
            completed: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            metrics,
        }
    }

    /// This replica's index (also its `replica="N"` label value).
    pub fn id(&self) -> usize {
        self.id
    }

    /// The replica's KV pool slice (`None` on flat serving).
    pub fn pool(&self) -> Option<&Arc<KvPool>> {
        self.pool.as_ref()
    }

    /// Live KV counters for this replica's pool slice.
    pub fn kv_stats(&self) -> Option<KvPoolStats> {
        self.pool.as_ref().map(|p| p.stats())
    }

    /// Pages not promised to a live session; `usize::MAX` on flat serving
    /// (an unpaged replica never sheds on pool pressure).
    pub fn free_pages(&self) -> usize {
        self.pool.as_ref().map_or(usize::MAX, |p| p.stats().free_pages())
    }

    /// Whether the replica can still take work (its supervisor has not
    /// given up).
    pub fn is_alive(&self) -> bool {
        !self.dead.load(Ordering::SeqCst)
    }

    /// In-flight load (decoding + waiting) — the least-loaded sort key.
    pub fn load(&self) -> usize {
        self.active.load(Ordering::Relaxed) + self.queued.load(Ordering::Relaxed)
    }

    /// Current `(active, queued)` for this replica.
    pub fn gauges(&self) -> (usize, usize) {
        (self.active.load(Ordering::Relaxed), self.queued.load(Ordering::Relaxed))
    }

    fn tx_clone(&self) -> Option<mpsc::SyncSender<StreamRequest>> {
        self.tx.lock().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Hand a request to this replica's bridge. The sender is cloned out
    /// of the lock first — the channel is bounded and a send may block.
    pub(crate) fn send(&self, req: StreamRequest) -> Result<(), StreamRequest> {
        match self.tx_clone() {
            Some(tx) => tx.send(req).map_err(|e| e.0),
            None => Err(req),
        }
    }

    /// Drop this seat's request sender. The seat holds the only long-lived
    /// sender for its replica, so this is the replica's drain signal.
    pub(crate) fn close(&self) {
        self.tx.lock().unwrap_or_else(PoisonError::into_inner).take();
    }

    /// Mark the replica unroutable (supervisor gave up restarting it).
    pub(crate) fn mark_dead(&self) {
        self.dead.store(true, Ordering::SeqCst);
    }

    /// Publish this replica's scheduler gauges (bridge-internal, once per
    /// tick).
    pub(crate) fn set_load(&self, active: usize, queued: usize) {
        self.active.store(active, Ordering::Relaxed);
        self.queued.store(queued, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.active_g.set(active as i64);
            m.queued_g.set(queued as i64);
        }
    }

    /// Count a request entering this replica's admission queue.
    pub(crate) fn note_enqueued(&self) {
        self.queued.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.queued_g.add(1);
        }
    }

    /// Count a stream this replica ran to completion.
    pub(crate) fn note_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.completed.inc();
        }
    }

    /// Count a decode-loop panic caught by this replica's supervisor.
    pub(crate) fn note_panic(&self) {
        self.panics.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.panics.inc();
        }
    }

    /// Count a post-panic restart of this replica's bridge.
    pub(crate) fn note_restart(&self) {
        self.restarts.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = &self.metrics {
            m.restarts.inc();
        }
    }

    fn note_routed(&self) {
        if let Some(m) = &self.metrics {
            m.routed.inc();
        }
    }

    /// Freeze this replica's row of the `/stats` `"replicas"` section.
    pub fn snapshot(&self) -> ReplicaSnapshot {
        let (active, queued) = self.gauges();
        ReplicaSnapshot {
            id: self.id,
            active,
            queued,
            completed: self.completed.load(Ordering::Relaxed),
            panics: self.panics.load(Ordering::Relaxed),
            restarts: self.restarts.load(Ordering::Relaxed),
            dead: !self.is_alive(),
            kv: self.kv_stats(),
        }
    }
}

/// Why [`Router::dispatch`] refused a request (the request comes back so
/// the caller can answer its stream).
pub enum DispatchError {
    /// Every alive replica is below its free-page watermark — shed with a
    /// retry hint.
    Shed(StreamRequest),
    /// No replica can ever take it (all dead or draining).
    Unavailable(StreamRequest),
}

/// What `/generate` admission would do right now (checked before the body
/// is even parsed, mirroring the single-replica pre-admit shed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// At least one replica is routable.
    Open,
    /// Alive replicas exist but all are at their watermark.
    Shed,
    /// No alive, un-drained replica remains.
    Closed,
}

/// The replica router: owns the seats and every routing decision. Shared
/// (`Arc`) between the HTTP handlers, the per-replica supervisors, and
/// the control handle's `/stats` path.
pub struct Router {
    seats: Vec<Arc<Seat>>,
    /// Per-replica free-page shed watermark (0 disables shedding).
    watermark: usize,
    affinity_c: Arc<Counter>,
    fallback_c: Arc<Counter>,
    migrated_c: Arc<Counter>,
    dispatch_h: Arc<Histogram>,
}

impl Router {
    /// Build a router over `seats` with a per-replica free-page shed
    /// `watermark`, minting the routing metrics from `registry`.
    pub(crate) fn new(seats: Vec<Arc<Seat>>, watermark: usize, registry: &Registry) -> Router {
        assert!(!seats.is_empty(), "router needs at least one replica seat");
        Router {
            seats,
            watermark,
            affinity_c: registry
                .counter("stbllm_router_affinity", "streams routed to their affine replica"),
            fallback_c: registry.counter(
                "stbllm_router_fallback",
                "streams routed least-loaded off their affine replica",
            ),
            migrated_c: registry
                .counter("stbllm_router_migrated", "streams migrated off a dead replica"),
            dispatch_h: registry
                .histogram("stbllm_router_dispatch_seconds", "routing pick + channel handoff"),
        }
    }

    /// The replica a prompt is affine to: an FNV-1a hash of its first
    /// [`AFFINITY_PREFIX_TOKENS`] tokens, mod the replica count. Pure and
    /// public so tests (and operators) can predict placement.
    pub fn affine_replica(prompt: &[u8], replicas: usize) -> usize {
        if replicas <= 1 {
            return 0;
        }
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for &b in prompt.iter().take(AFFINITY_PREFIX_TOKENS) {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        (h % replicas as u64) as usize
    }

    /// The seats, indexed by replica id.
    pub fn seats(&self) -> &[Arc<Seat>] {
        &self.seats
    }

    /// Alive replica count.
    pub fn alive(&self) -> usize {
        self.seats.iter().filter(|s| s.is_alive()).count()
    }

    /// Summed `(active, queued)` across replicas — the aggregate gauges.
    pub fn loads(&self) -> (usize, usize) {
        self.seats.iter().fold((0, 0), |(a, q), s| {
            let (sa, sq) = s.gauges();
            (a + sa, q + sq)
        })
    }

    /// Merged KV counters across every replica's pool slice (`None` on
    /// flat serving). For one replica this is exactly that pool's stats,
    /// which keeps the single-replica `/stats` document byte-compatible.
    pub fn kv_stats(&self) -> Option<KvPoolStats> {
        let mut merged: Option<KvPoolStats> = None;
        for s in &self.seats {
            if let Some(kv) = s.kv_stats() {
                match &mut merged {
                    None => merged = Some(kv),
                    Some(m) => m.merge(&kv),
                }
            }
        }
        merged
    }

    fn routable(&self, seat: &Seat) -> bool {
        seat.is_alive() && (self.watermark == 0 || seat.free_pages() >= self.watermark)
    }

    /// What admission would decide right now.
    pub fn admission(&self) -> Admission {
        let mut any_alive = false;
        for s in &self.seats {
            if !s.is_alive() || s.tx_clone().is_none() {
                continue;
            }
            any_alive = true;
            if self.routable(s) {
                return Admission::Open;
            }
        }
        if any_alive {
            Admission::Shed
        } else {
            Admission::Closed
        }
    }

    /// Candidate order for a request: the affine replica first, then the
    /// rest least-loaded (ties broken by id, so the order — and therefore
    /// single-replica behavior — is deterministic).
    fn candidate_order(&self, affine: usize, exclude: Option<usize>) -> Vec<usize> {
        let mut order: Vec<usize> =
            (0..self.seats.len()).filter(|&i| Some(i) != exclude).collect();
        order.sort_by_key(|&i| (self.seats[i].load(), i));
        if let Some(pos) = order.iter().position(|&i| i == affine) {
            let a = order.remove(pos);
            order.insert(0, a);
        }
        order
    }

    /// Route one stream: affine replica if routable, else least-loaded
    /// alive replica above the watermark, else a typed refusal. A send
    /// that fails because a replica's channel vanished marks that seat
    /// dead and falls through to the next candidate.
    pub(crate) fn dispatch(&self, req: StreamRequest) -> Result<usize, DispatchError> {
        let t0 = Instant::now();
        let affine = Router::affine_replica(&req.prompt, self.seats.len());
        let mut req = req;
        for i in self.candidate_order(affine, None) {
            let seat = &self.seats[i];
            if !self.routable(seat) {
                continue;
            }
            match seat.send(req) {
                Ok(()) => {
                    if i == affine {
                        self.affinity_c.inc();
                    } else {
                        self.fallback_c.inc();
                    }
                    seat.note_routed();
                    self.dispatch_h.record_secs(t0.elapsed().as_secs_f64());
                    return Ok(i);
                }
                Err(r) => {
                    // disconnected channel: the replica's supervisor is
                    // gone for good (a drained seat is skipped above by
                    // its taken sender)
                    if seat.tx_clone().is_some() {
                        seat.mark_dead();
                    }
                    req = r;
                }
            }
        }
        match self.admission() {
            Admission::Shed => Err(DispatchError::Shed(req)),
            _ => Err(DispatchError::Unavailable(req)),
        }
    }

    /// Migrate a request off dead replica `from` to the least-loaded
    /// survivor, ignoring the watermark (migrating beats dying). Returns
    /// `true` on success; on total failure the stream is answered with a
    /// terminal `Rejected` event.
    pub(crate) fn redispatch(&self, req: StreamRequest, from: usize) -> bool {
        let mut req = req;
        for i in self.candidate_order(from, Some(from)) {
            let seat = &self.seats[i];
            if !seat.is_alive() {
                continue;
            }
            match seat.send(req) {
                Ok(()) => {
                    self.migrated_c.inc();
                    seat.note_routed();
                    return true;
                }
                Err(r) => req = r,
            }
        }
        let _ = req.tx.send(StreamEvent::Rejected("no replicas available".to_string()));
        false
    }

    /// Drop every seat's request sender — the gateway-wide drain signal:
    /// each bridge finishes its in-flight work and exits.
    pub(crate) fn close(&self) {
        for s in &self.seats {
            s.close();
        }
    }

    /// Freeze the `/stats` `"replicas"` section.
    pub fn snapshot(&self) -> ReplicasSnapshot {
        ReplicasSnapshot { replicas: self.seats.iter().map(|s| s.snapshot()).collect() }
    }
}

/// One replica's row in the `/stats` `"replicas"` section.
#[derive(Clone, Debug)]
pub struct ReplicaSnapshot {
    /// Replica id (the `replica="N"` label value).
    pub id: usize,
    /// Streams decoding on this replica.
    pub active: usize,
    /// Streams waiting in its admission queue.
    pub queued: usize,
    /// Streams it ran to completion.
    pub completed: u64,
    /// Decode-loop panics its supervisor caught.
    pub panics: u64,
    /// Post-panic bridge restarts.
    pub restarts: u64,
    /// Whether its supervisor has given up (requests migrate away).
    pub dead: bool,
    /// Its KV pool slice counters (`None` on flat serving).
    pub kv: Option<KvPoolStats>,
}

impl ReplicaSnapshot {
    /// JSON row for the `"replicas"` array.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", num(self.id as f64)),
            ("active", num(self.active as f64)),
            ("queued", num(self.queued as f64)),
            ("completed", num(self.completed as f64)),
            ("panics", num(self.panics as f64)),
            ("restarts", num(self.restarts as f64)),
            ("dead", Json::Bool(self.dead)),
        ];
        if let Some(kv) = &self.kv {
            fields.push(("kv", kv.to_json()));
        }
        obj(fields)
    }
}

/// The `"replicas"` section of the schema-2 `/stats` envelope: one row
/// per replica.
#[derive(Clone, Debug)]
pub struct ReplicasSnapshot {
    /// Per-replica rows, indexed by id.
    pub replicas: Vec<ReplicaSnapshot>,
}

impl Snapshot for ReplicasSnapshot {
    fn name(&self) -> &'static str {
        "replicas"
    }

    fn to_json(&self) -> Json {
        Json::Arr(self.replicas.iter().map(ReplicaSnapshot::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used)]
    use super::*;
    use std::time::Duration;

    fn seat_with_chan(
        id: usize,
        reg: Option<&Registry>,
    ) -> (Arc<Seat>, mpsc::Receiver<StreamRequest>) {
        let (tx, rx) = mpsc::sync_channel(64);
        (Arc::new(Seat::new(id, None, Some(tx), reg)), rx)
    }

    fn req(prompt: Vec<u8>) -> (StreamRequest, mpsc::Receiver<StreamEvent>) {
        let (tx, rx) = mpsc::channel();
        (StreamRequest { prompt, max_new: 1, deadline: None, tx }, rx)
    }

    #[test]
    fn affinity_is_deterministic_and_prefix_based() {
        let a = Router::affine_replica(&[1, 2, 3, 4], 4);
        assert_eq!(a, Router::affine_replica(&[1, 2, 3, 4], 4), "must be stable");
        // only the first AFFINITY_PREFIX_TOKENS tokens matter
        let mut long = vec![7u8; AFFINITY_PREFIX_TOKENS];
        let base = Router::affine_replica(&long, 4);
        long.push(99);
        long.push(123);
        assert_eq!(base, Router::affine_replica(&long, 4), "tail must not change affinity");
        assert_eq!(Router::affine_replica(&[9, 9], 1), 0);
        // the hash actually spreads: some pair of small prompts differs
        let spread: std::collections::BTreeSet<usize> =
            (0u8..32).map(|b| Router::affine_replica(&[b], 4)).collect();
        assert!(spread.len() > 1, "all prompts hashed to one replica");
    }

    #[test]
    fn dispatch_prefers_the_affine_seat() {
        let reg = Registry::new();
        let (s0, rx0) = seat_with_chan(0, None);
        let (s1, rx1) = seat_with_chan(1, None);
        let router = Router::new(vec![s0, s1], 0, &reg);
        // find prompts affine to each replica
        let p0 = (0u8..64).find(|&b| Router::affine_replica(&[b], 2) == 0).unwrap();
        let p1 = (0u8..64).find(|&b| Router::affine_replica(&[b], 2) == 1).unwrap();
        let (r, _e0) = req(vec![p0]);
        assert_eq!(router.dispatch(r).ok(), Some(0));
        let (r, _e1) = req(vec![p1]);
        assert_eq!(router.dispatch(r).ok(), Some(1));
        assert!(rx0.try_recv().is_ok());
        assert!(rx1.try_recv().is_ok());
        assert_eq!(router.affinity_c.get(), 2);
        assert_eq!(router.fallback_c.get(), 0);
        assert_eq!(router.dispatch_h.count(), 2);
    }

    #[test]
    fn dead_affine_seat_falls_back_least_loaded() {
        let reg = Registry::new();
        let (s0, _rx0) = seat_with_chan(0, None);
        let (s1, rx1) = seat_with_chan(1, None);
        let (s2, rx2) = seat_with_chan(2, None);
        s2.set_load(5, 2); // busier than s1
        let router = Router::new(vec![s0.clone(), s1, s2], 0, &reg);
        s0.mark_dead();
        let p0 = (0u8..255).find(|&b| Router::affine_replica(&[b], 3) == 0).unwrap();
        let (r, _e) = req(vec![p0]);
        assert_eq!(router.dispatch(r).ok(), Some(1), "least-loaded survivor must win");
        assert!(rx1.try_recv().is_ok());
        assert!(rx2.try_recv().is_err());
        assert_eq!(router.fallback_c.get(), 1);
    }

    #[test]
    fn admission_shed_and_closed_states() {
        let reg = Registry::new();
        let (s0, _rx0) = seat_with_chan(0, None);
        let (s1, _rx1) = seat_with_chan(1, None);
        // watermark > 0 with no pool: free_pages() is usize::MAX => open
        let router = Router::new(vec![s0.clone(), s1.clone()], 4, &reg);
        assert_eq!(router.admission(), Admission::Open);
        s0.mark_dead();
        assert_eq!(router.admission(), Admission::Open);
        s1.close(); // drained
        assert_eq!(router.admission(), Admission::Closed);
        let (r, erx) = req(vec![1]);
        assert!(matches!(router.dispatch(r), Err(DispatchError::Unavailable(_))));
        drop(erx);
    }

    #[test]
    fn redispatch_migrates_and_rejects_when_no_survivor() {
        let reg = Registry::new();
        let (s0, _rx0) = seat_with_chan(0, None);
        let (s1, rx1) = seat_with_chan(1, None);
        let router = Router::new(vec![s0.clone(), s1.clone()], 0, &reg);
        s0.mark_dead();
        let (r, erx) = req(vec![42]);
        assert!(router.redispatch(r, 0), "must migrate to the survivor");
        assert!(rx1.try_recv().is_ok());
        assert_eq!(router.migrated_c.get(), 1);
        drop(erx);
        // no survivor left: the stream gets a terminal Rejected event
        s1.mark_dead();
        let (r, erx) = req(vec![42]);
        assert!(!router.redispatch(r, 0));
        match erx.recv_timeout(Duration::from_secs(5)).unwrap() {
            StreamEvent::Rejected(msg) => assert!(msg.contains("no replicas"), "{msg}"),
            other => panic!("expected Rejected, got {other:?}"),
        }
    }

    #[test]
    fn labeled_seats_publish_per_replica_series() {
        let reg = Registry::new();
        let (s0, _rx0) = seat_with_chan(0, Some(&reg));
        let (s1, _rx1) = seat_with_chan(1, Some(&reg));
        s0.set_load(2, 1);
        s1.note_completed();
        s1.note_panic();
        s1.note_restart();
        let router = Router::new(vec![s0, s1], 0, &reg);
        assert_eq!(router.loads(), (2, 1));
        let text = reg.render_prometheus();
        assert!(text.contains("stbllm_gateway_active{replica=\"0\"} 2"), "{text}");
        assert!(text.contains("stbllm_gateway_queued{replica=\"0\"} 1"), "{text}");
        assert!(text.contains("stbllm_gateway_completed_total{replica=\"1\"} 1"), "{text}");
        assert!(text.contains("stbllm_gateway_bridge_panics_total{replica=\"1\"} 1"), "{text}");
        let snap = router.snapshot();
        assert_eq!(snap.replicas.len(), 2);
        assert_eq!(snap.replicas[1].panics, 1);
        assert_eq!(snap.replicas[1].restarts, 1);
        let doc = Json::parse(&snap.to_json().dump()).unwrap();
        let rows = doc.as_arr().unwrap();
        assert_eq!(rows[0].get("id").and_then(Json::as_usize), Some(0));
        assert_eq!(rows[0].get("active").and_then(Json::as_usize), Some(2));
        assert_eq!(rows[1].get("completed").and_then(Json::as_usize), Some(1));
        assert_eq!(rows[1].get("dead"), Some(&Json::Bool(false)));
    }
}
