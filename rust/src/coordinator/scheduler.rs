//! Work scheduler: runs per-matrix quantization jobs across a small worker
//! pool. Layer-parallel PTQ is safe because each job touches one weight
//! matrix + read-only calibration. On the single-core CI machine this
//! degrades gracefully to sequential execution; the structure is what a
//! multi-socket deployment would use.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

thread_local! {
    /// Set on pool worker threads so a nested `run_parallel` (e.g. the
    /// packed `_par` kernels inside a window-parallel eval) degrades to the
    /// sequential path instead of multiplying the thread budget to
    /// workers² — the outer fan-out already saturates the cores, and the
    /// result is identical either way (the sequential path preserves
    /// order).
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Run `f` over `jobs` with `workers` threads, preserving input order in the
/// result vector. Calls from inside a pool worker run sequentially (no
/// nested spawning).
pub fn run_parallel<J, R, F>(jobs: Vec<J>, workers: usize, f: F) -> Vec<R>
where
    J: Send,
    R: Send,
    F: Fn(J) -> R + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 || IN_POOL.with(|flag| flag.get()) {
        return jobs.into_iter().map(f).collect();
    }
    let jobs: Vec<Mutex<Option<J>>> = jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                IN_POOL.with(|flag| flag.set(true));
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let job = jobs[i].lock().unwrap().take().unwrap();
                    let r = f(job);
                    *results[i].lock().unwrap() = Some(r);
                }
            });
        }
    });
    results.into_iter().map(|m| m.into_inner().unwrap().unwrap()).collect()
}

/// Short-name re-export: the kernel (`packed::gemm::*_par`) and eval
/// (`eval::perplexity::perplexity_par`) fan-out call the pool as
/// `scheduler::run`.
pub use self::run_parallel as run;

/// Default worker count: leave one core for the coordinator itself.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|p| p.get().saturating_sub(1).max(1)).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let jobs: Vec<usize> = (0..37).collect();
        let out = run_parallel(jobs, 4, |j| j * 2);
        assert_eq!(out, (0..37).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_path() {
        let out = run_parallel(vec![1, 2, 3], 1, |j| j + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_jobs() {
        let out: Vec<i32> = run_parallel(Vec::<i32>::new(), 4, |j| j);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = run_parallel(vec![5], 16, |j| j);
        assert_eq!(out, vec![5]);
    }

    /// A `run_parallel` issued from inside a pool worker must complete
    /// correctly (sequentially — no thread explosion) with order preserved.
    #[test]
    fn nested_run_degrades_to_sequential() {
        let jobs: Vec<usize> = (0..8).collect();
        let out = run_parallel(jobs, 4, |j| {
            let inner: Vec<usize> = run_parallel((0..5).collect(), 4, |i| i * 10);
            assert_eq!(inner, vec![0, 10, 20, 30, 40]);
            j * 2
        });
        assert_eq!(out, (0..8).map(|j| j * 2).collect::<Vec<_>>());
    }
}
