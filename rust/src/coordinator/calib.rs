//! Calibration manager: streams calibration windows through the FP model,
//! captures the four per-layer activation tap points, and accumulates the
//! OBC Hessians (`H = 2 XᵀX`) + per-column activation L2 norms for every
//! quantizable projection.
//!
//! Tap → projection mapping (see `model::transformer::LayerTaps`):
//!   attn_in → wq, wk, wv;  wo_in → wo;  ffn_in → w1 (+w3);  w2_in → w2.

use std::collections::BTreeMap;

use crate::model::config::{Family, ModelConfig};
use crate::model::corpus;
use crate::model::transformer::model_fwd_with_taps;
use crate::model::ModelWeights;
use crate::quant::LayerCalib;
use crate::tensor::{gram, Mat};

/// Accumulated calibration for one projection input.
struct Accum {
    hessian: Mat,
    sq_col_sums: Vec<f32>,
}

impl Accum {
    fn new(k: usize) -> Accum {
        Accum { hessian: Mat::zeros(k, k), sq_col_sums: vec![0.0; k] }
    }

    fn add(&mut self, x: &Mat) {
        let mut g = gram(x);
        g.scale(2.0);
        self.hessian.add_assign(&g);
        for t in 0..x.rows {
            for (a, &v) in self.sq_col_sums.iter_mut().zip(x.row(t)) {
                *a += v * v;
            }
        }
    }

    fn finish(self) -> LayerCalib {
        LayerCalib {
            hessian: Some(self.hessian),
            x_col_norms: Some(self.sq_col_sums.iter().map(|s| s.sqrt()).collect()),
        }
    }
}

/// Calibration output: per layer, per weight-name `LayerCalib`.
pub struct ModelCalib {
    pub per_layer: Vec<BTreeMap<String, LayerCalib>>,
    pub n_tokens: usize,
    pub corpus: String,
}

/// Run calibration on `n_tokens` tokens of the named corpus.
pub fn calibrate(
    cfg: &ModelConfig,
    weights: &ModelWeights,
    corpus_name: &str,
    n_tokens: usize,
    seed: u64,
) -> ModelCalib {
    let win = cfg.seq_len;
    let toks = corpus::corpus_tokens(corpus_name, n_tokens.max(win), seed);

    // one accumulator per (layer, tap)
    let mut attn_in = Vec::new();
    let mut wo_in = Vec::new();
    let mut ffn_in = Vec::new();
    let mut w2_in = Vec::new();
    for _ in 0..cfg.n_layers {
        attn_in.push(Accum::new(cfg.dim));
        wo_in.push(Accum::new(cfg.dim));
        ffn_in.push(Accum::new(cfg.dim));
        w2_in.push(Accum::new(cfg.ffn_hidden));
    }

    let mut i = 0usize;
    let mut used = 0usize;
    while i + win <= toks.len() {
        let (_, taps) = model_fwd_with_taps(cfg, weights, &toks[i..i + win]);
        for (l, t) in taps.into_iter().enumerate() {
            attn_in[l].add(t.attn_in.as_ref().unwrap());
            wo_in[l].add(t.wo_in.as_ref().unwrap());
            ffn_in[l].add(t.ffn_in.as_ref().unwrap());
            w2_in[l].add(t.w2_in.as_ref().unwrap());
        }
        used += win;
        i += win;
    }

    let mut per_layer = Vec::with_capacity(cfg.n_layers);
    for (((a, o), f), w2) in attn_in
        .into_iter()
        .zip(wo_in)
        .zip(ffn_in)
        .zip(w2_in)
    {
        let a = a.finish();
        let o = o.finish();
        let f = f.finish();
        let w2 = w2.finish();
        let mut map = BTreeMap::new();
        for n in ["wq", "wk", "wv"] {
            map.insert(n.to_string(), a.clone());
        }
        map.insert("wo".to_string(), o);
        map.insert("w1".to_string(), f.clone());
        if cfg.family != Family::Opt {
            map.insert("w3".to_string(), f.clone());
        }
        map.insert("w2".to_string(), w2);
        per_layer.push(map);
    }
    ModelCalib { per_layer, n_tokens: used, corpus: corpus_name.to_string() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    #[test]
    fn calibration_shapes_and_positive_diag() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 1);
        let calib = calibrate(&cfg, &w, "wikitext2s", 256, 3);
        assert_eq!(calib.per_layer.len(), cfg.n_layers);
        assert_eq!(calib.n_tokens, 256);
        let l0 = &calib.per_layer[0];
        for n in cfg.layer_weight_names() {
            let c = &l0[n];
            let h = c.hessian.as_ref().unwrap();
            let want = cfg.layer_weight_shape(n).1;
            assert_eq!(h.rows, want, "{n}");
            for j in 0..h.rows {
                assert!(h[(j, j)] >= 0.0);
            }
            assert_eq!(c.x_col_norms.as_ref().unwrap().len(), want);
        }
    }

    #[test]
    fn more_tokens_larger_hessian_trace() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 2);
        let c1 = calibrate(&cfg, &w, "c4s", 128, 4);
        let c2 = calibrate(&cfg, &w, "c4s", 384, 4);
        let tr = |c: &ModelCalib| -> f32 {
            let h = c.per_layer[0]["wq"].hessian.as_ref().unwrap();
            (0..h.rows).map(|i| h[(i, i)]).sum()
        };
        assert!(tr(&c2) > tr(&c1));
    }
}
