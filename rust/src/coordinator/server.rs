//! Batched inference server — the L3 request path.
//!
//! vLLM-router-shaped: a request queue feeds a dynamic batcher; the decode
//! worker admits up to `max_batch` sequences, interleaves their decode steps
//! (each with its own KV cache), retires finished sequences and admits new
//! ones mid-flight (continuous batching). Latency and throughput counters
//! feed the serving example + EXPERIMENTS.md.
//!
//! The server is generic over the [`Backend`] seam: it holds a
//! `&dyn Backend` and opens one [`DecodeSession`] (KV cache) per admitted
//! request. `stbllm serve --backend packed` therefore drives the sub-1-bit
//! packed GEMM end-to-end; `--backend native` uses the dense Rust forward.
//! The usual construction path is `Engine::serve`.
//!
//! ## KV admission control
//!
//! With a [`KvPool`] attached ([`BatchServer::with_kv_pool`]), KV memory is
//! a managed budget: a request is admitted only when the pool can reserve
//! its worst-case pages (`ceil((prompt + max_new) / page_size)`). A request
//! that cannot be covered *right now* waits at the head of the queue
//! (backpressure) until running sequences retire; a request that could
//! never fit is refused with a typed [`ServeError`] instead of panicking
//! mid-decode. Sessions admitted against the pool also reuse prefix-cached
//! pages from earlier sequences — their prefill skips straight past the
//! reused tokens.
//!
//! ## Head-of-line aging
//!
//! A deferred request does not hard-block the queue: smaller requests
//! behind it may be admitted in its place (bypass) so free pages are never
//! left idle. To keep a steady stream of small admits from starving a
//! large request forever, every tick the head waits adds one deferral to
//! its age; once the age reaches [`BatchServer::hol_boost_deferrals`] the
//! bypass is switched off and admission holds until the aged head fits.
//!
//! ## Chunked prefill
//!
//! Prompt consumption is budgeted per tick: each prefilling session
//! consumes up to [`BatchServer::prefill_chunk`] prompt tokens per tick
//! (default [`DEFAULT_PREFILL_CHUNK`]; 1 reproduces the legacy
//! one-token-per-tick scheduler exactly). A multi-token chunk runs as ONE
//! batched forward through [`DecodeSession::prefill`] — the packed backend
//! decodes each 6-bit weight word once per chunk instead of once per
//! token — while sessions with one prompt token left and all decoding
//! sessions still share the fused [`Backend::decode_batch`] tick. The
//! budget is the fairness knob: a P-token prompt spreads over
//! `ceil(P / prefill_chunk)` ticks instead of monopolizing one giant
//! forward, so co-scheduled decode streams keep emitting a token every
//! tick. Chunking is orthogonal to admission — the KV budget and
//! head-of-line aging operate on whole requests *before* chunking begins —
//! and prefix-cache hits simply shrink the prompt remainder the budget
//! applies to (resume lands mid-prompt at any offset). Chunked and
//! single-token prefill produce bit-identical logits, so the budget never
//! changes the emitted streams, only their timing; per-tick prefill tokens
//! are stamped into trace spans and the
//! `stbllm_server_prefill_tokens_total` counter.
//!
//! The per-tick scheduling itself (`top_up` + `tick`) is shared verbatim
//! with the streaming HTTP bridge (`crate::net::bridge`), so tokens
//! streamed over the network are byte-identical to a direct
//! [`BatchServer::run`] of the same workload.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::kvpool::{KvPool, KvPoolStats};
use crate::engine::backend::{Backend, DecodeSession, SessionOpts};
use crate::obs::{Counter, Gauge, Histogram, Registry, Snapshot, TraceSpan, TraceSummary};
use crate::util::json::{arr, num, obj, s as jstr, Json};

// The one percentile implementation (nearest-rank), re-exported here for
// the pre-obs call sites that imported it from this module.
pub use crate::obs::percentile;

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u8>,
    /// seconds from submission to completion
    pub latency_s: f64,
    /// seconds from submission to first generated token
    pub ttft_s: f64,
    /// per-stage breakdown of where this request's time went
    pub trace: TraceSummary,
}

/// Typed admission refusal — returned in [`ServerStats::rejections`]
/// instead of panicking mid-decode (the pre-pool server asserted
/// `"KV cache capacity exceeded"` deep in the step loop).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// The request's worst case (prompt + max_new tokens) can never fit
    /// the server's KV capacity, even with nothing else running.
    RequestTooLarge { id: u64, need_tokens: usize, capacity_tokens: usize },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::RequestTooLarge { id, need_tokens, capacity_tokens } => write!(
                f,
                "request {id} needs {need_tokens} KV tokens but capacity is {capacity_tokens}"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub completed: usize,
    pub generated_tokens: usize,
    pub wall_s: f64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub mean_ttft_s: f64,
    /// requests refused at admission, each with its typed reason
    pub rejections: Vec<ServeError>,
    /// rejections issued while capacity was actually available — a bug
    /// canary the `serve-smoke` CI gate asserts stays 0
    pub rejected_with_capacity_free: usize,
    /// admission attempts pushed back for lack of free KV pages
    /// (backpressure events, not failures)
    pub deferred: usize,
    /// KV pool counters at end of run (`None` on flat serving)
    pub kv: Option<KvPoolStats>,
}

impl ServerStats {
    /// Aggregate decode throughput. Always finite: an empty or
    /// zero-duration run reports `0.0` rather than `NaN`/`inf` (pinned by
    /// unit test — the JSON stats sinks require finite numbers).
    pub fn tokens_per_s(&self) -> f64 {
        if self.generated_tokens == 0 || !self.wall_s.is_finite() || self.wall_s <= 0.0 {
            return 0.0;
        }
        self.generated_tokens as f64 / self.wall_s
    }
}

impl Snapshot for ServerStats {
    fn name(&self) -> &'static str {
        "server"
    }

    /// The batch server's section of the schema-2 stats envelope — the
    /// pre-redesign `--stats-json` fields, preserved verbatim.
    fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("completed", num(self.completed as f64)),
            ("generated_tokens", num(self.generated_tokens as f64)),
            ("tokens_per_s", num(self.tokens_per_s())),
            ("wall_s", num(self.wall_s)),
            ("mean_latency_s", num(self.mean_latency_s)),
            ("p50_latency_s", num(self.p50_latency_s)),
            ("p95_latency_s", num(self.p95_latency_s)),
            ("mean_ttft_s", num(self.mean_ttft_s)),
            ("rejected", num(self.rejections.len() as f64)),
            ("rejections", arr(self.rejections.iter().map(|e| jstr(&e.to_string())).collect())),
            ("rejected_with_capacity_free", num(self.rejected_with_capacity_free as f64)),
            ("deferred", num(self.deferred as f64)),
        ];
        if let Some(kv) = &self.kv {
            fields.push(("kv", kv.to_json()));
        }
        obj(fields)
    }
}

/// A queued request plus its head-of-line age (deferral count) — the
/// starvation-avoidance bookkeeping of the admission loop — and its
/// trace span, opened at enqueue so queue wait is never lost.
pub(crate) struct Queued {
    pub(crate) req: Request,
    /// times this request was deferred while at the head of the queue
    pub(crate) deferrals: u32,
    /// per-request span; follows the request into `Active` at admission
    pub(crate) span: TraceSpan,
}

impl Queued {
    pub(crate) fn new(req: Request) -> Queued {
        Queued { req, deferrals: 0, span: TraceSpan::begin(Instant::now()) }
    }
}

pub(crate) struct Active<'a> {
    pub(crate) req: Request,
    session: Box<dyn DecodeSession + 'a>,
    pub(crate) produced: Vec<u8>,
    pub(crate) submitted: Instant,
    pub(crate) first_token: Option<f64>,
    /// position in the prompt during prefill
    prefill_pos: usize,
    last_logits: Vec<f32>,
    /// per-request span, accumulating stage times tick by tick
    pub(crate) span: TraceSpan,
}

impl Active<'_> {
    /// Close this request's span (used at retirement, both here and in
    /// the streaming bridge).
    pub(crate) fn finish_span(&self, now: Instant) -> TraceSummary {
        self.span.finish(now)
    }
}

/// Outcome of one [`BatchServer::top_up`] round.
#[derive(Default)]
pub(crate) struct TopUp {
    /// request ids admitted this round, in admission order
    pub(crate) admitted: Vec<u64>,
    /// typed refusals (request can never fit)
    pub(crate) rejected: Vec<ServeError>,
    /// of which issued while capacity was free (bug canary)
    pub(crate) rejected_free: usize,
    /// backpressure events (deferred admissions) this round
    pub(crate) deferred_events: usize,
}

/// Outcome of one [`BatchServer::tick`].
pub(crate) struct TickResult {
    /// `(slot in active, token)` for every token generated this tick, in
    /// slot order — what a streaming frontend forwards to its clients
    pub(crate) emitted: Vec<(usize, u8)>,
    /// slots whose sequences finished, ascending (retire with
    /// `swap_remove` in REVERSE order)
    pub(crate) finished: Vec<usize>,
}

/// Outcome of one admission attempt.
enum Admission<'a> {
    Admitted(Active<'a>),
    /// Not enough free KV pages right now — the request goes back to the
    /// head of the queue (span intact, still accruing queue wait) and
    /// waits for running sequences to retire.
    Deferred(Queued),
    /// The request can never be served by this server's KV capacity.
    Rejected(ServeError),
}

/// The batch server's registered metric handles — one mint per server,
/// recorded lock-free on the scheduling hot path (`top_up`/`tick`).
pub(crate) struct ServerMetrics {
    pub(crate) admitted: Arc<Counter>,
    pub(crate) rejected: Arc<Counter>,
    pub(crate) deferred: Arc<Counter>,
    pub(crate) completed: Arc<Counter>,
    pub(crate) tokens: Arc<Counter>,
    pub(crate) prefill_tokens: Arc<Counter>,
    pub(crate) queue_h: Arc<Histogram>,
    pub(crate) prefill_h: Arc<Histogram>,
    pub(crate) decode_h: Arc<Histogram>,
    pub(crate) kernel_h: Arc<Histogram>,
    pub(crate) ttft_h: Arc<Histogram>,
    pub(crate) latency_h: Arc<Histogram>,
    pub(crate) active_g: Arc<Gauge>,
    pub(crate) queued_g: Arc<Gauge>,
}

impl ServerMetrics {
    pub(crate) fn new(reg: &Registry) -> Self {
        ServerMetrics {
            admitted: reg.counter("stbllm_server_admitted", "requests admitted to the batch"),
            rejected: reg.counter("stbllm_server_rejected", "requests refused at admission"),
            deferred: reg.counter("stbllm_server_deferred", "admission backpressure events"),
            completed: reg.counter("stbllm_server_completed", "requests retired complete"),
            tokens: reg.counter("stbllm_server_generated_tokens", "tokens generated"),
            prefill_tokens: reg
                .counter("stbllm_server_prefill_tokens", "prompt tokens prefilled"),
            queue_h: reg.histogram("stbllm_server_queue_seconds", "enqueue to admission wait"),
            prefill_h: reg
                .histogram("stbllm_server_prefill_seconds", "per-tick prefill wall time"),
            decode_h: reg.histogram("stbllm_server_decode_seconds", "per-tick decode wall time"),
            kernel_h: reg
                .histogram("stbllm_server_kernel_seconds", "per-tick batched kernel time"),
            ttft_h: reg.histogram("stbllm_server_ttft_seconds", "admission to first token"),
            latency_h: reg.histogram("stbllm_server_latency_seconds", "admission to retirement"),
            active_g: reg.gauge("stbllm_server_active", "sequences decoding right now"),
            queued_g: reg.gauge("stbllm_server_queued", "requests waiting for admission"),
        }
    }
}

/// Synchronous batch server: processes a workload of requests with
/// continuous batching and returns responses + stats. (The async façade
/// `serve_channel` wraps this for streaming use.)
pub struct BatchServer<'a> {
    pub backend: &'a dyn Backend,
    pub max_batch: usize,
    /// per-session KV token capacity of the flat (pool-less) path
    pub kv_capacity: usize,
    /// Deferral age at which a head-of-line request stops being bypassed
    /// by smaller admits: once the head has been deferred this many times,
    /// admission holds (no bypass) until it fits, so a large request
    /// cannot be starved forever by a stream of small ones.
    pub hol_boost_deferrals: u32,
    /// Per-tick prefill-token budget per session: a prefilling sequence
    /// consumes up to this many prompt tokens per tick, multi-token chunks
    /// running as one batched [`DecodeSession::prefill`] forward. `1`
    /// reproduces the legacy one-token-per-tick scheduler exactly; any
    /// value yields bit-identical streams (see the module docs).
    pub prefill_chunk: usize,
    pool: Option<Arc<KvPool>>,
    registry: Arc<Registry>,
    metrics: ServerMetrics,
}

/// Default [`BatchServer::hol_boost_deferrals`]: a deferred head tolerates
/// this many bypass rounds before it locks the admission queue.
pub const DEFAULT_HOL_BOOST_DEFERRALS: u32 = 8;

/// Default [`BatchServer::prefill_chunk`]: enough tokens per tick that the
/// packed GEMM amortizes each weight-word decode well past the memory-bound
/// knee, small enough that a long prompt cannot stall co-scheduled decode
/// streams for more than one chunk's worth of work per tick.
pub const DEFAULT_PREFILL_CHUNK: usize = 32;

impl<'a> BatchServer<'a> {
    pub fn new(backend: &'a dyn Backend, max_batch: usize) -> Self {
        let kv_capacity = 4 * backend.cfg().seq_len;
        // each server gets its own registry by default (test isolation);
        // serving stacks share one via `with_registry`
        let registry = Arc::new(Registry::new());
        let metrics = ServerMetrics::new(&registry);
        BatchServer {
            backend,
            max_batch,
            kv_capacity,
            hol_boost_deferrals: DEFAULT_HOL_BOOST_DEFERRALS,
            prefill_chunk: DEFAULT_PREFILL_CHUNK,
            pool: None,
            registry,
            metrics,
        }
    }

    /// Attach an existing shared KV pool; it mirrors its page counters
    /// into this server's registry.
    pub fn with_pool(mut self, pool: Arc<KvPool>) -> Self {
        pool.attach_registry(&self.registry);
        self.pool = Some(pool);
        self
    }

    /// Record into `registry` instead of the server's private one — the
    /// serving stacks (gateway, `Engine::serve`) pass theirs so
    /// `GET /metrics` exposes scheduler histograms. The KV pool (attached
    /// before or after) mirrors into the same registry.
    pub fn with_registry(mut self, registry: Arc<Registry>) -> Self {
        self.metrics = ServerMetrics::new(&registry);
        self.registry = registry;
        if let Some(pool) = &self.pool {
            pool.attach_registry(&self.registry);
        }
        self
    }

    /// The registry this server records into.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// Attach a paged KV pool of `pages` pages of `page_size` token slots;
    /// `pages == 0` auto-sizes to `max_batch` concurrent sessions at the
    /// flat path's per-session capacity. No-op (flat serving, `stats.kv ==
    /// None`) when the backend does not support paged sessions.
    pub fn with_kv_pool(mut self, pages: usize, page_size: usize) -> Self {
        if !self.backend.capabilities().paged_kv {
            return self;
        }
        let pages = if pages == 0 {
            self.max_batch.max(1) * self.kv_capacity.div_ceil(page_size)
        } else {
            pages
        };
        let pool = Arc::new(KvPool::new(self.backend.cfg(), pages, page_size));
        pool.attach_registry(&self.registry);
        self.pool = Some(pool);
        self
    }

    /// The attached KV pool, if any.
    pub fn pool(&self) -> Option<&Arc<KvPool>> {
        self.pool.as_ref()
    }

    /// Try to admit the queued request: open its decode session (paged
    /// against the pool when one is attached, flat otherwise) or report
    /// why it cannot run. Admission closes the span's queue stage and
    /// stamps the request's KV page footprint.
    fn admit(&self, q: Queued) -> Result<Admission<'a>> {
        let Queued { req, mut span, deferrals } = q;
        let need_tokens = req.prompt.len() + req.max_new;
        let mut pages = 0usize;
        let session = match &self.pool {
            Some(pool) => {
                let need_pages = pool.pages_for(need_tokens);
                if need_pages > pool.total_pages() {
                    return Ok(Admission::Rejected(ServeError::RequestTooLarge {
                        id: req.id,
                        need_tokens,
                        capacity_tokens: pool.total_pages() * pool.page_size(),
                    }));
                }
                if !pool.can_reserve(need_pages) {
                    return Ok(Admission::Deferred(Queued { req, span, deferrals }));
                }
                let opts = SessionOpts {
                    capacity: need_tokens,
                    pool: Some(pool.clone()),
                    prompt: &req.prompt,
                };
                match self.backend.begin_decode_with(&opts) {
                    Ok(session) => {
                        pages = need_pages;
                        session
                    }
                    // another server on a shared pool can win the
                    // reservation between our can_reserve peek and the
                    // session's atomic reserve — a now-exhausted pool is
                    // backpressure, not a failure; genuine backend errors
                    // (pool still reservable) propagate
                    Err(_) if !pool.can_reserve(need_pages) => {
                        return Ok(Admission::Deferred(Queued { req, span, deferrals }))
                    }
                    Err(e) => return Err(e),
                }
            }
            None => {
                if need_tokens > self.kv_capacity {
                    return Ok(Admission::Rejected(ServeError::RequestTooLarge {
                        id: req.id,
                        need_tokens,
                        capacity_tokens: self.kv_capacity,
                    }));
                }
                self.backend.begin_decode(self.kv_capacity)?
            }
        };
        let t0 = Instant::now();
        let queue_s = span.admitted(t0);
        self.metrics.queue_h.record_secs(queue_s);
        span.set_pages(pages);
        // prefix-cache hits come back with pos() > 0: prefill resumes
        // right after the reused tokens
        let prefill_pos = session.pos();
        span.add_prefix_hit_tokens(prefill_pos);
        Ok(Admission::Admitted(Active {
            session,
            produced: Vec::with_capacity(req.max_new),
            submitted: t0,
            first_token: None,
            prefill_pos,
            last_logits: Vec::new(),
            span,
            req,
        }))
    }

    /// Would this rejection have fit after all? (Always false by
    /// construction — kept as a live canary for the CI serving gate.)
    fn capacity_was_free(&self, e: &ServeError) -> bool {
        let ServeError::RequestTooLarge { need_tokens, .. } = e;
        match &self.pool {
            Some(pool) => pool.can_reserve(pool.pages_for(*need_tokens)),
            None => *need_tokens <= self.kv_capacity,
        }
    }

    /// One admission round: move queued requests into `active` until the
    /// batch is full or nothing else is admissible. A deferred head is
    /// bypassed by later (smaller) requests until its age reaches
    /// `hol_boost_deferrals`, after which admission holds for it (the
    /// starvation guard — see the module docs). Shared verbatim between
    /// [`BatchServer::run`] and the streaming HTTP bridge.
    pub(crate) fn top_up(
        &self,
        queue: &mut VecDeque<Queued>,
        active: &mut Vec<Active<'a>>,
    ) -> Result<TopUp> {
        let mut out = TopUp::default();
        let mut idx = 0usize;
        while active.len() < self.max_batch && idx < queue.len() {
            let q = queue.remove(idx).expect("idx < queue.len()");
            let age = q.deferrals;
            match self.admit(q)? {
                Admission::Admitted(a) => {
                    self.metrics.admitted.inc();
                    out.admitted.push(a.req.id);
                    active.push(a);
                    // idx now points at the next not-yet-tried entry
                }
                Admission::Deferred(mut q) => {
                    out.deferred_events += 1;
                    self.metrics.deferred.inc();
                    // only the true head accrues starvation age; bypassed
                    // followers just wait their turn
                    let age = if idx == 0 { age + 1 } else { age };
                    q.deferrals = age;
                    queue.insert(idx, q);
                    if idx == 0 && age >= self.hol_boost_deferrals {
                        // aged head: stop bypassing so retiring sessions
                        // can only free pages INTO this request
                        break;
                    }
                    idx += 1;
                }
                Admission::Rejected(e) => {
                    self.metrics.rejected.inc();
                    if self.capacity_was_free(&e) {
                        out.rejected_free += 1;
                    }
                    out.rejected.push(e);
                }
            }
        }
        self.metrics.active_g.set(active.len() as i64);
        self.metrics.queued_g.set(queue.len() as i64);
        Ok(out)
    }

    /// One decode tick over `active`: pick each sequence's input (prefill
    /// consumes up to [`BatchServer::prefill_chunk`] prompt tokens, decode
    /// feeds the greedy argmax), run each multi-token chunk as one batched
    /// prefill forward and ONE [`Backend::decode_batch`] across every
    /// single-token-stepping sequence, and report the tokens generated
    /// plus which slots finished. The caller retires `finished` in
    /// descending index order (`swap_remove`).
    ///
    /// This is THE scheduling kernel: `run` and the HTTP streaming bridge
    /// both call it, which is what makes network-streamed tokens
    /// byte-identical to a direct batch run.
    pub(crate) fn tick(&self, active: &mut Vec<Active<'a>>) -> Result<TickResult> {
        let tick0 = Instant::now();
        // Phase 1: pick inputs; sequences that just produced their last
        // token finish without another step.
        let mut stepping: Vec<usize> = Vec::with_capacity(active.len());
        // parallel to `stepping`: was this step prompt prefill (true) or
        // token decode (false)? Drives per-stage span/histogram credit.
        let mut prefilling: Vec<bool> = Vec::with_capacity(active.len());
        let mut tokens: Vec<u8> = Vec::with_capacity(active.len());
        let mut emitted: Vec<(usize, u8)> = Vec::new();
        let mut finished: Vec<usize> = Vec::new();
        // `(slot, tokens to consume)` for sessions taking a multi-token
        // prefill chunk this tick — they run their own batched prefill
        // forward (phase 2a) instead of joining the fused decode_batch
        let budget = self.prefill_chunk.max(1);
        let mut chunked: Vec<(usize, usize)> = Vec::new();
        for (i, a) in active.iter_mut().enumerate() {
            if a.prefill_pos < a.req.prompt.len() {
                // prefill up to `prefill_chunk` prompt tokens this tick
                let take = (a.req.prompt.len() - a.prefill_pos).min(budget);
                if take >= 2 {
                    chunked.push((i, take));
                } else {
                    // a single remaining token rides the fused
                    // decode_batch tick with the decoding sessions
                    tokens.push(a.req.prompt[a.prefill_pos]);
                    a.prefill_pos += 1;
                    stepping.push(i);
                    prefilling.push(true);
                }
            } else {
                // greedy decode
                let next = argmax(&a.last_logits);
                if a.first_token.is_none() {
                    a.first_token = Some(a.submitted.elapsed().as_secs_f64());
                    a.span.first_token(Instant::now());
                }
                a.produced.push(next);
                emitted.push((i, next));
                if a.produced.len() >= a.req.max_new {
                    finished.push(i);
                } else {
                    tokens.push(next);
                    stepping.push(i);
                    prefilling.push(false);
                }
            }
        }
        self.metrics.tokens.add(emitted.len() as u64);
        // Phase 2a: chunked prefill — one batched multi-token forward per
        // chunked session ([`DecodeSession::prefill`]): the packed backend
        // decodes each 6-bit weight word once per chunk instead of once
        // per token. Logits are bit-identical to single-token prefill, so
        // the budget never changes the emitted streams.
        for &(i, take) in &chunked {
            let a = &mut active[i];
            let chunk0 = Instant::now();
            let from = a.prefill_pos;
            let logits = a.session.prefill(&a.req.prompt[from..from + take], false)?;
            a.prefill_pos += take;
            a.last_logits = logits.data;
            let dt = chunk0.elapsed().as_secs_f64();
            a.span.add_prefill(dt);
            a.span.add_kernel(dt);
            a.span.add_prefill_tokens(take);
            self.metrics.prefill_h.record_secs(dt);
            self.metrics.kernel_h.record_secs(dt);
            self.metrics.prefill_tokens.add(take as u64);
        }
        // Phase 2: ONE decode_batch per tick — a fused backend runs a
        // single packed GEMM per projection across every stepping
        // sequence (the weight stream is read once per tick, not once
        // per session); other backends step per-session inside the
        // default implementation.
        if !stepping.is_empty() {
            let kernel0 = Instant::now();
            let logits = {
                let mut sessions: Vec<&mut (dyn DecodeSession + 'a)> =
                    Vec::with_capacity(stepping.len());
                let mut k = 0usize;
                for (i, a) in active.iter_mut().enumerate() {
                    if k < stepping.len() && stepping[k] == i {
                        sessions.push(a.session.as_mut());
                        k += 1;
                    }
                }
                self.backend.decode_batch(&mut sessions, &tokens)?
            };
            let kernel_s = kernel0.elapsed().as_secs_f64();
            for (&i, lg) in stepping.iter().zip(logits) {
                active[i].last_logits = lg;
            }
            // Stage attribution: the tick's wall time is credited to each
            // stepping sequence's current stage, the decode_batch share to
            // its kernel time. Tick windows are disjoint intervals inside
            // each request's admit→retire lifetime, so per-request stage
            // sums can never exceed the span total (the trace invariant
            // the metrics-smoke gate asserts).
            let tick_s = tick0.elapsed().as_secs_f64();
            self.metrics.kernel_h.record_secs(kernel_s);
            for (&i, &pf) in stepping.iter().zip(prefilling.iter()) {
                let a = &mut active[i];
                if pf {
                    a.span.add_prefill(tick_s);
                    a.span.add_prefill_tokens(1);
                    self.metrics.prefill_h.record_secs(tick_s);
                    self.metrics.prefill_tokens.add(1);
                } else {
                    a.span.add_decode(tick_s);
                    self.metrics.decode_h.record_secs(tick_s);
                }
                a.span.add_kernel(kernel_s);
            }
        }
        Ok(TickResult { emitted, finished })
    }

    /// Run the whole workload; returns responses in completion order.
    /// Requests that can never fit the KV capacity are refused with a
    /// typed entry in [`ServerStats::rejections`]; the rest are served.
    pub fn run(&self, workload: Vec<Request>) -> Result<(Vec<Response>, ServerStats)> {
        let wall0 = Instant::now();
        let mut queue: VecDeque<Queued> = workload.into_iter().map(Queued::new).collect();
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<Response> = Vec::new();
        let mut latencies = Vec::new();
        let mut ttfts = Vec::new();
        let mut generated = 0usize;
        let mut rejections: Vec<ServeError> = Vec::new();
        let mut rejected_with_capacity_free = 0usize;
        let mut deferred = 0usize;

        while !queue.is_empty() || !active.is_empty() {
            // continuous batching: top up the active set, respecting the
            // KV pool's admission budget + head-of-line aging
            let up = self.top_up(&mut queue, &mut active)?;
            deferred += up.deferred_events;
            rejected_with_capacity_free += up.rejected_free;
            rejections.extend(up.rejected);
            if active.is_empty() {
                if queue.is_empty() {
                    break;
                }
                // a deferred head with nothing running can only unblock via
                // another server on a shared pool — yield instead of
                // spinning hot
                std::thread::yield_now();
                continue;
            }
            let t = self.tick(&mut active)?;
            generated += t.emitted.len();
            // retire finished sequences (descending index order so
            // swap_remove never disturbs a pending index)
            for &i in t.finished.iter().rev() {
                let a = active.swap_remove(i);
                let now = Instant::now();
                let lat = now.duration_since(a.submitted).as_secs_f64();
                let ttft = a.first_token.unwrap_or(lat);
                latencies.push(lat);
                ttfts.push(ttft);
                self.metrics.completed.inc();
                self.metrics.latency_h.record_secs(lat);
                self.metrics.ttft_h.record_secs(ttft);
                let trace = a.finish_span(now);
                done.push(Response {
                    id: a.req.id,
                    tokens: a.produced,
                    latency_s: lat,
                    ttft_s: ttft,
                    trace,
                });
            }
        }

        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = ServerStats {
            completed: done.len(),
            generated_tokens: generated,
            wall_s: wall0.elapsed().as_secs_f64(),
            mean_latency_s: mean(&latencies),
            p50_latency_s: percentile(&latencies, 50.0),
            p95_latency_s: percentile(&latencies, 95.0),
            mean_ttft_s: mean(&ttfts),
            rejections,
            rejected_with_capacity_free,
            deferred,
            kv: self.pool.as_ref().map(|p| p.stats()),
        };
        Ok((done, stats))
    }
}

/// Channel-based façade: spawn a worker thread owning the backend; send
/// requests, receive responses as they complete. Returns (request sender,
/// response receiver).
pub fn serve_channel(
    backend: Box<dyn Backend + Send>,
    max_batch: usize,
) -> (mpsc::Sender<Request>, mpsc::Receiver<Response>) {
    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    std::thread::spawn(move || {
        let server = BatchServer::new(&*backend, max_batch);
        // micro-batching loop: drain whatever is queued, run it, repeat
        while let Ok(first) = req_rx.recv() {
            let mut batch = vec![first];
            while let Ok(r) = req_rx.try_recv() {
                batch.push(r);
            }
            let responses = match server.run(batch) {
                Ok((responses, _)) => responses,
                Err(e) => {
                    eprintln!("serve worker failed: {e:#}");
                    return;
                }
            };
            for r in responses {
                if resp_tx.send(r).is_err() {
                    return;
                }
            }
        }
    });
    (req_tx, resp_rx)
}

fn argmax(v: &[f32]) -> u8 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as u8
}

fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeBackend;
    use crate::model::config::ModelConfig;
    use crate::model::transformer::model_fwd;
    use crate::model::ModelWeights;

    fn tiny() -> (ModelConfig, ModelWeights) {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        (cfg.clone(), ModelWeights::synthetic(&cfg, 1))
    }

    #[test]
    fn serves_batch_and_matches_sequential_greedy() {
        let (cfg, w) = tiny();
        let prompt: Vec<u8> = vec![1, 2, 3, 4, 5];
        let reqs: Vec<Request> =
            (0..3).map(|id| Request { id, prompt: prompt.clone(), max_new: 4 }).collect();
        let be = NativeBackend::borrowed(&cfg, &w);
        let server = BatchServer::new(&be, 2);
        let (resps, stats) = server.run(reqs).unwrap();
        assert_eq!(resps.len(), 3);
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.generated_tokens, 12);
        // greedy reference via full forward
        let mut seq = prompt.clone();
        let mut want = Vec::new();
        for _ in 0..4 {
            let logits = model_fwd(&cfg, &w, &seq);
            let last = logits.row(logits.rows - 1);
            let next = argmax(last);
            want.push(next);
            seq.push(next);
        }
        for r in &resps {
            assert_eq!(r.tokens, want, "req {}", r.id);
            assert!(r.latency_s >= r.ttft_s);
        }
    }

    #[test]
    fn continuous_batching_admits_beyond_max_batch() {
        let (cfg, w) = tiny();
        let reqs: Vec<Request> =
            (0..5).map(|id| Request { id, prompt: vec![7, 8], max_new: 2 }).collect();
        let be = NativeBackend::borrowed(&cfg, &w);
        let server = BatchServer::new(&be, 2);
        let (resps, stats) = server.run(reqs).unwrap();
        assert_eq!(resps.len(), 5);
        assert!(stats.tokens_per_s() > 0.0);
    }

    #[test]
    fn channel_facade_round_trips() {
        let (cfg, w) = tiny();
        let (tx, rx) = serve_channel(Box::new(NativeBackend::new(cfg, w)), 2);
        tx.send(Request { id: 42, prompt: vec![1, 2, 3], max_new: 3 }).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.id, 42);
        assert_eq!(resp.tokens.len(), 3);
    }

    /// The fused tick (packed backend, `decode_batch` with B > 1) must
    /// produce the same greedy tokens as solo serving (B = 1 per tick).
    #[test]
    fn fused_packed_serving_matches_solo_serving() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 13);
        let be = crate::engine::PackedBackend::from_weights(&cfg, &w).unwrap();
        let reqs: Vec<Request> = (0..4)
            .map(|id| Request { id, prompt: vec![2, 4, 6, (id % 3) as u8], max_new: 3 })
            .collect();
        let (mut fused, _) = BatchServer::new(&be, 4).run(reqs.clone()).unwrap();
        let (mut solo, _) = BatchServer::new(&be, 1).run(reqs).unwrap();
        fused.sort_by_key(|r| r.id);
        solo.sort_by_key(|r| r.id);
        assert_eq!(fused.len(), 4);
        for (a, b) in fused.iter().zip(&solo) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "req {}: fused tick must match solo decode", a.id);
        }
    }

    /// Chunked prefill (any budget) must produce exactly the streams the
    /// one-token-per-tick scheduler produces. Staggered prompt lengths
    /// force ticks that mix a chunked prefill with ongoing decode streams;
    /// shared prompt prefixes on the paged pool force mid-prompt
    /// prefix-cache resumes into a chunk. Exercised on the fused packed
    /// backend (paged pool) and native (flat).
    #[test]
    fn chunked_prefill_serving_matches_single_token_serving() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 13);
        let packed = crate::engine::PackedBackend::from_weights(&cfg, &w).unwrap();
        let native = NativeBackend::borrowed(&cfg, &w);
        let backends: [(&dyn Backend, bool); 2] = [(&packed, true), (&native, false)];
        let reqs: Vec<Request> = (0..4u64)
            .map(|id| Request {
                id,
                prompt: (0..3 + 5 * id as usize).map(|i| (i * 7 % 32) as u8).collect(),
                max_new: 3,
            })
            .collect();
        for (be, paged) in backends {
            let mk = |chunk: usize| {
                let mut s = BatchServer::new(be, 2);
                s.prefill_chunk = chunk;
                if paged {
                    s = s.with_kv_pool(0, 4);
                }
                s
            };
            let (mut want, _) = mk(1).run(reqs.clone()).unwrap();
            want.sort_by_key(|r| r.id);
            for chunk in [3usize, 8, 32] {
                let (mut got, _) = mk(chunk).run(reqs.clone()).unwrap();
                got.sort_by_key(|r| r.id);
                assert_eq!(got.len(), want.len());
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.id, b.id);
                    assert_eq!(
                        a.tokens, b.tokens,
                        "chunk={chunk} paged={paged} req {}: streams must match chunk=1",
                        a.id
                    );
                }
            }
        }
    }

    /// A prompt that alone exceeds the KV capacity must surface as a typed
    /// rejection, not a mid-decode panic (the old path asserted
    /// `"KV cache capacity exceeded"` inside the step loop).
    #[test]
    fn oversized_request_rejected_typed_not_panicking() {
        let (cfg, w) = tiny();
        let be = NativeBackend::borrowed(&cfg, &w);
        let mut server = BatchServer::new(&be, 2);
        server.kv_capacity = 8;
        let reqs = vec![
            Request { id: 0, prompt: vec![1; 20], max_new: 4 }, // 24 > 8
            Request { id: 1, prompt: vec![1, 2, 3], max_new: 2 },
        ];
        let (resps, stats) = server.run(reqs).unwrap();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].id, 1);
        assert_eq!(
            stats.rejections,
            vec![ServeError::RequestTooLarge { id: 0, need_tokens: 24, capacity_tokens: 8 }]
        );
        assert_eq!(stats.rejected_with_capacity_free, 0);
        assert!(stats.kv.is_none(), "flat serving reports no pool stats");
    }

    /// Paged serving (shared KV pool) must produce exactly the tokens flat
    /// serving produces — same requests, same greedy continuations.
    #[test]
    fn paged_serving_matches_flat_serving() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 13);
        let be = crate::engine::PackedBackend::from_weights(&cfg, &w).unwrap();
        let reqs: Vec<Request> = (0..4)
            .map(|id| Request { id, prompt: vec![2, 4, 6, (id % 3) as u8], max_new: 3 })
            .collect();
        let (mut flat, flat_stats) = BatchServer::new(&be, 2).run(reqs.clone()).unwrap();
        let (mut paged, paged_stats) =
            BatchServer::new(&be, 2).with_kv_pool(0, 8).run(reqs).unwrap();
        assert!(flat_stats.kv.is_none());
        let kv = paged_stats.kv.expect("paged serving must report pool stats");
        assert!(kv.pages_in_use == 0 || kv.pages_in_use <= kv.total_pages);
        assert!(kv.peak_pages > 0);
        flat.sort_by_key(|r| r.id);
        paged.sort_by_key(|r| r.id);
        for (a, b) in flat.iter().zip(&paged) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "req {}: paged must match flat", a.id);
        }
    }

    /// A pool that only covers one request at a time forces sequential
    /// admission (backpressure) — everything still completes.
    #[test]
    fn pool_backpressure_defers_but_serves_all() {
        let (cfg, w) = tiny();
        let be = NativeBackend::borrowed(&cfg, &w);
        // each request: 4 prompt + 2 new = 6 tokens → 2 pages of 4; pool
        // of 2 pages admits exactly one at a time
        let pool = Arc::new(KvPool::new(&cfg, 2, 4));
        let reqs: Vec<Request> =
            (0..3).map(|id| Request { id, prompt: vec![5, 6, 7, 8], max_new: 2 }).collect();
        let server = BatchServer::new(&be, 3).with_pool(pool);
        let (resps, stats) = server.run(reqs).unwrap();
        assert_eq!(resps.len(), 3);
        assert!(stats.deferred > 0, "expected admission backpressure");
        assert!(stats.rejections.is_empty());
        let kv = stats.kv.unwrap();
        assert!(kv.peak_pages <= 2, "peak {} exceeds the pool", kv.peak_pages);
    }

    /// With a pool attached, an impossible request is rejected up front
    /// and the rest of the workload is unaffected.
    #[test]
    fn pool_rejects_never_fitting_request() {
        let (cfg, w) = tiny();
        let be = NativeBackend::borrowed(&cfg, &w);
        let pool = Arc::new(KvPool::new(&cfg, 2, 4));
        let reqs = vec![
            Request { id: 7, prompt: vec![1; 30], max_new: 10 }, // 10 pages > 2
            Request { id: 8, prompt: vec![1, 2], max_new: 2 },
        ];
        let (resps, stats) = BatchServer::new(&be, 2).with_pool(pool).run(reqs).unwrap();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].id, 8);
        assert_eq!(stats.rejections.len(), 1);
        assert!(matches!(stats.rejections[0], ServeError::RequestTooLarge { id: 7, .. }));
        assert_eq!(stats.rejected_with_capacity_free, 0);
    }

    /// Shared-prompt workload: later waves map the earlier waves' prefix
    /// pages instead of recomputing them, so total page allocations stay
    /// well under sessions × pages-per-request and the generated tokens
    /// are untouched. (This is the `serve-smoke` CI gate's assertion,
    /// pinned as a unit test.)
    #[test]
    fn shared_prompt_workload_reuses_prefix_pages() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 17);
        let be = crate::engine::PackedBackend::from_weights(&cfg, &w).unwrap();
        let prompt: Vec<u8> = (0..10).map(|i| (i * 5 % 32) as u8).collect();
        let n_req = 4usize;
        let max_new = 4usize;
        let reqs: Vec<Request> = (0..n_req as u64)
            .map(|id| Request { id, prompt: prompt.clone(), max_new })
            .collect();
        // max_batch 2 < n_req so the second wave sees the first wave's
        // cached pages; page_size 4 so the 10-token prompt spans 2 full
        // pages + a partial one
        let server = BatchServer::new(&be, 2).with_kv_pool(0, 4);
        let pages_per_req = server.pool().unwrap().pages_for(prompt.len() + max_new);
        let (mut resps, stats) = server.run(reqs.clone()).unwrap();
        assert_eq!(resps.len(), n_req);
        let kv = stats.kv.unwrap();
        assert!(kv.prefix_hits > 0, "second wave must hit the prefix cache");
        assert!(
            kv.allocated_total < n_req * pages_per_req,
            "prefix caching saved nothing: {} allocs vs naive {}",
            kv.allocated_total,
            n_req * pages_per_req
        );
        // identical prompts under greedy decode → identical continuations,
        // and they must match a pool-less reference run
        let (flat, _) = BatchServer::new(&be, 2).run(reqs).unwrap();
        resps.sort_by_key(|r| r.id);
        for r in &resps {
            assert_eq!(r.tokens, flat[0].tokens, "req {}", r.id);
        }
    }

    /// The percentile used by this module is THE shared nearest-rank
    /// implementation — its semantics are pinned once, in
    /// `crate::obs::percentile` (`percentile_nearest_rank_pinned`).
    #[test]
    fn percentile_is_the_shared_obs_implementation() {
        // spot-check the re-export resolves to nearest-rank behavior
        assert_eq!(percentile(&[1.0, 2.0], 50.0), 1.0);
    }

    /// Starvation regression: a request needing the WHOLE pool, followed by
    /// a stream of staggered small requests that keeps at least one page
    /// reserved at all times. Pure bypass admission (no aging) would only
    /// admit the big request once every small one has drained — it finishes
    /// dead last. The head-of-line age boost locks admission after a few
    /// deferrals, so the big request completes well before the small-stream
    /// tail.
    #[test]
    fn aged_head_of_line_request_is_not_starved_by_small_stream() {
        let (cfg, w) = tiny();
        let be = NativeBackend::borrowed(&cfg, &w);
        let pool = Arc::new(KvPool::new(&cfg, 4, 4));
        // big: 8 prompt + 6 new = 14 tokens -> 4 pages (the whole pool);
        // smalls: <= 4 tokens -> 1 page, alternating max_new so their
        // retirements interleave and the pool is never all-free by luck.
        // Two smalls go FIRST so they already hold pages when the big one
        // is tried — otherwise it would be admitted into the empty pool
        // and the starvation scenario never arises.
        let small = |i: u64| Request {
            id: 1 + i,
            prompt: vec![1, 2],
            max_new: if i % 2 == 0 { 1 } else { 2 },
        };
        let mut reqs = vec![small(0), small(1)];
        reqs.push(Request { id: 0, prompt: vec![9; 8], max_new: 6 });
        reqs.extend((2..20u64).map(small));
        let mut server = BatchServer::new(&be, 2).with_pool(pool);
        server.hol_boost_deferrals = 3;
        let (resps, stats) = server.run(reqs).unwrap();
        assert_eq!(resps.len(), 21, "everything must complete");
        assert!(stats.deferred > 0, "the big request must have been deferred");
        let big_rank = resps.iter().position(|r| r.id == 0).unwrap();
        assert!(
            big_rank < 12,
            "big request finished {}th of 21 — starved past the age boost",
            big_rank + 1
        );
        // smalls DID bypass the deferred head before it aged out
        // (otherwise the boost test proves nothing about bypass admission)
        assert!(
            resps.iter().take(2).all(|r| r.id != 0),
            "small requests should have been served while the big one waited"
        );
    }

    /// Empty / degenerate runs must report finite stats — the JSON sinks
    /// (`--stats-json`, `/stats`, BENCH_http.json) reject NaN/inf.
    #[test]
    fn stats_are_finite_on_empty_runs() {
        let empty = ServerStats::default();
        assert_eq!(empty.tokens_per_s(), 0.0);
        let weird = ServerStats { generated_tokens: 5, wall_s: f64::NAN, ..Default::default() };
        assert_eq!(weird.tokens_per_s(), 0.0);
        let zero_wall = ServerStats { generated_tokens: 5, wall_s: 0.0, ..Default::default() };
        assert_eq!(zero_wall.tokens_per_s(), 0.0);
        let ok = ServerStats { generated_tokens: 10, wall_s: 2.0, ..Default::default() };
        assert_eq!(ok.tokens_per_s(), 5.0);
        // percentile of nothing is 0.0, never an index panic or NaN
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[], 95.0), 0.0);
        assert!(ServerStats::default().mean_latency_s.is_finite());
    }

    /// An empty workload through the full server must also come out finite.
    #[test]
    fn empty_workload_serves_to_finite_stats() {
        let (cfg, w) = tiny();
        let be = NativeBackend::borrowed(&cfg, &w);
        let (resps, stats) = BatchServer::new(&be, 2).run(Vec::new()).unwrap();
        assert!(resps.is_empty());
        assert_eq!(stats.completed, 0);
        assert!(stats.tokens_per_s().is_finite());
        assert!(stats.p50_latency_s.is_finite() && stats.p95_latency_s.is_finite());
        assert!(stats.mean_ttft_s.is_finite());
    }

    #[test]
    fn server_stats_expose_p50_and_p95() {
        let (cfg, w) = tiny();
        let reqs: Vec<Request> =
            (0..4).map(|id| Request { id, prompt: vec![1, 2], max_new: 2 }).collect();
        let be = NativeBackend::borrowed(&cfg, &w);
        let (_, stats) = BatchServer::new(&be, 2).run(reqs).unwrap();
        assert!(stats.p50_latency_s > 0.0);
        assert!(stats.p95_latency_s >= stats.p50_latency_s);
    }

    /// Every retired response carries a per-stage trace whose accounting
    /// is conservative (`queue+prefill+decode ≤ total`), and the server's
    /// registry fills its stage histograms while serving.
    #[test]
    fn responses_carry_consistent_traces_and_metrics() {
        let (cfg, w) = tiny();
        let be = NativeBackend::borrowed(&cfg, &w);
        let server = BatchServer::new(&be, 2);
        let reqs: Vec<Request> =
            (0..3).map(|id| Request { id, prompt: vec![1, 2, 3], max_new: 4 }).collect();
        let (resps, _) = server.run(reqs).unwrap();
        assert_eq!(resps.len(), 3);
        for r in &resps {
            assert!(r.trace.stages_within_total(0.5), "stage overshoot: {:?}", r.trace);
            assert!(r.trace.ttft_ms <= r.trace.total_ms + 0.5);
            assert!(r.trace.prefill_ms > 0.0, "prefill ticks untraced");
            assert!(r.trace.decode_ms > 0.0, "decode ticks untraced");
            assert!(r.trace.ticks >= 1);
            assert_eq!(r.trace.prefill_tokens, 3, "whole prompt must be stamped as prefilled");
        }
        let text = server.registry().render_prometheus();
        assert!(text.contains("stbllm_server_completed_total 3"));
        assert!(text.contains("stbllm_server_generated_tokens_total 12"));
        assert!(
            text.contains("stbllm_server_prefill_tokens_total 9"),
            "3 requests x 3 prompt tokens must be counted"
        );
        for h in ["queue", "prefill", "decode", "kernel", "ttft", "latency"] {
            let needle = format!("stbllm_server_{h}_seconds_count");
            let line = text
                .lines()
                .find(|l| l.starts_with(&needle))
                .unwrap_or_else(|| panic!("{needle} missing from exposition"));
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n > 0, "{needle} is empty");
        }
    }

    /// `ServerStats` is a [`Snapshot`]: it serializes under `"server"`
    /// inside the schema-2 envelope with the old flat fields intact.
    #[test]
    fn server_stats_snapshot_in_schema2_envelope() {
        let stats =
            ServerStats { completed: 2, generated_tokens: 8, wall_s: 2.0, ..Default::default() };
        let doc = crate::obs::envelope(&[&stats]);
        assert_eq!(doc.get("schema").and_then(Json::as_usize), Some(2));
        assert_eq!(doc.path(&["server", "completed"]).and_then(Json::as_usize), Some(2));
        assert_eq!(doc.path(&["server", "tokens_per_s"]).and_then(Json::as_f64), Some(4.0));
        assert_eq!(doc.path(&["server", "deferred"]).and_then(Json::as_usize), Some(0));
    }
}
