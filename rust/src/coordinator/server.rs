//! Batched inference server — the L3 request path.
//!
//! vLLM-router-shaped: a request queue feeds a dynamic batcher; the decode
//! worker admits up to `max_batch` sequences, interleaves their decode steps
//! (each with its own KV cache), retires finished sequences and admits new
//! ones mid-flight (continuous batching). Latency and throughput counters
//! feed the serving example + EXPERIMENTS.md.
//!
//! The server is generic over the [`Backend`] seam: it holds a
//! `&dyn Backend` and opens one [`DecodeSession`] (KV cache) per admitted
//! request. `stbllm serve --backend packed` therefore drives the sub-1-bit
//! packed GEMM end-to-end; `--backend native` uses the dense Rust forward.
//! The usual construction path is `Engine::serve`.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::engine::backend::{Backend, DecodeSession};

/// A generation request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u8>,
    pub max_new: usize,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<u8>,
    /// seconds from submission to completion
    pub latency_s: f64,
    /// seconds from submission to first generated token
    pub ttft_s: f64,
}

/// Aggregate serving metrics.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub completed: usize,
    pub generated_tokens: usize,
    pub wall_s: f64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p95_latency_s: f64,
    pub mean_ttft_s: f64,
}

impl ServerStats {
    pub fn tokens_per_s(&self) -> f64 {
        self.generated_tokens as f64 / self.wall_s.max(1e-9)
    }
}

struct Active<'a> {
    req: Request,
    session: Box<dyn DecodeSession + 'a>,
    produced: Vec<u8>,
    submitted: Instant,
    first_token: Option<f64>,
    /// position in the prompt during prefill
    prefill_pos: usize,
    last_logits: Vec<f32>,
}

/// Synchronous batch server: processes a workload of requests with
/// continuous batching and returns responses + stats. (The async façade
/// `serve_channel` wraps this for streaming use.)
pub struct BatchServer<'a> {
    pub backend: &'a dyn Backend,
    pub max_batch: usize,
    pub kv_capacity: usize,
}

impl<'a> BatchServer<'a> {
    pub fn new(backend: &'a dyn Backend, max_batch: usize) -> Self {
        let kv_capacity = 4 * backend.cfg().seq_len;
        BatchServer { backend, max_batch, kv_capacity }
    }

    fn admit(&self, req: Request, t0: Instant) -> Result<Active<'a>> {
        Ok(Active {
            session: self.backend.begin_decode(self.kv_capacity)?,
            produced: Vec::with_capacity(req.max_new),
            submitted: t0,
            first_token: None,
            prefill_pos: 0,
            last_logits: Vec::new(),
            req,
        })
    }

    /// Run the whole workload; returns responses in completion order.
    pub fn run(&self, workload: Vec<Request>) -> Result<(Vec<Response>, ServerStats)> {
        let wall0 = Instant::now();
        let mut queue: VecDeque<Request> = workload.into();
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<Response> = Vec::new();
        let mut latencies = Vec::new();
        let mut ttfts = Vec::new();
        let mut generated = 0usize;

        while !queue.is_empty() || !active.is_empty() {
            // continuous batching: top up the active set
            while active.len() < self.max_batch {
                match queue.pop_front() {
                    Some(r) => active.push(self.admit(r, Instant::now())?),
                    None => break,
                }
            }
            // Phase 1: pick each active sequence's input token for this tick
            // (prefill consumes the prompt, decode feeds the greedy argmax);
            // sequences that just produced their last token finish without
            // another step.
            let mut stepping: Vec<usize> = Vec::with_capacity(active.len());
            let mut tokens: Vec<u8> = Vec::with_capacity(active.len());
            let mut finished: Vec<usize> = Vec::new();
            for (i, a) in active.iter_mut().enumerate() {
                if a.prefill_pos < a.req.prompt.len() {
                    // prefill one token per tick (chunked prefill)
                    tokens.push(a.req.prompt[a.prefill_pos]);
                    a.prefill_pos += 1;
                    stepping.push(i);
                } else {
                    // greedy decode
                    let next = argmax(&a.last_logits);
                    if a.first_token.is_none() {
                        a.first_token = Some(a.submitted.elapsed().as_secs_f64());
                    }
                    a.produced.push(next);
                    generated += 1;
                    if a.produced.len() >= a.req.max_new {
                        finished.push(i);
                    } else {
                        tokens.push(next);
                        stepping.push(i);
                    }
                }
            }
            // Phase 2: ONE decode_batch per tick — a fused backend runs a
            // single packed GEMM per projection across every stepping
            // sequence (the weight stream is read once per tick, not once
            // per session); other backends step per-session inside the
            // default implementation.
            if !stepping.is_empty() {
                let logits = {
                    let mut sessions: Vec<&mut (dyn DecodeSession + 'a)> =
                        Vec::with_capacity(stepping.len());
                    let mut k = 0usize;
                    for (i, a) in active.iter_mut().enumerate() {
                        if k < stepping.len() && stepping[k] == i {
                            sessions.push(a.session.as_mut());
                            k += 1;
                        }
                    }
                    self.backend.decode_batch(&mut sessions, &tokens)?
                };
                for (&i, lg) in stepping.iter().zip(logits) {
                    active[i].last_logits = lg;
                }
            }
            // Phase 3: retire finished sequences (descending index order so
            // swap_remove never disturbs a pending index)
            for &i in finished.iter().rev() {
                let a = active.swap_remove(i);
                let lat = a.submitted.elapsed().as_secs_f64();
                latencies.push(lat);
                ttfts.push(a.first_token.unwrap_or(lat));
                done.push(Response {
                    id: a.req.id,
                    tokens: a.produced,
                    latency_s: lat,
                    ttft_s: a.first_token.unwrap_or(lat),
                });
            }
        }

        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = ServerStats {
            completed: done.len(),
            generated_tokens: generated,
            wall_s: wall0.elapsed().as_secs_f64(),
            mean_latency_s: mean(&latencies),
            p50_latency_s: percentile(&latencies, 50.0),
            p95_latency_s: percentile(&latencies, 95.0),
            mean_ttft_s: mean(&ttfts),
        };
        Ok((done, stats))
    }
}

/// Channel-based façade: spawn a worker thread owning the backend; send
/// requests, receive responses as they complete. Returns (request sender,
/// response receiver).
pub fn serve_channel(
    backend: Box<dyn Backend + Send>,
    max_batch: usize,
) -> (mpsc::Sender<Request>, mpsc::Receiver<Response>) {
    let (req_tx, req_rx) = mpsc::channel::<Request>();
    let (resp_tx, resp_rx) = mpsc::channel::<Response>();
    std::thread::spawn(move || {
        let server = BatchServer::new(&*backend, max_batch);
        // micro-batching loop: drain whatever is queued, run it, repeat
        while let Ok(first) = req_rx.recv() {
            let mut batch = vec![first];
            while let Ok(r) = req_rx.try_recv() {
                batch.push(r);
            }
            let responses = match server.run(batch) {
                Ok((responses, _)) => responses,
                Err(e) => {
                    eprintln!("serve worker failed: {e:#}");
                    return;
                }
            };
            for r in responses {
                if resp_tx.send(r).is_err() {
                    return;
                }
            }
        }
    });
    (req_tx, resp_rx)
}

fn argmax(v: &[f32]) -> u8 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as u8
}

fn mean(v: &[f64]) -> f64 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f64>() / v.len() as f64
    }
}

/// Nearest-rank percentile of an ascending-sorted slice: the smallest value
/// such that at least `p`% of the samples are ≤ it (rank = ⌈p/100 · n⌉,
/// 1-based). The previous `round((p/100)·(n-1))` interpolation over-read
/// e.g. p50 of a 2-sample vector as the max.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeBackend;
    use crate::model::config::ModelConfig;
    use crate::model::transformer::model_fwd;
    use crate::model::ModelWeights;

    fn tiny() -> (ModelConfig, ModelWeights) {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        (cfg.clone(), ModelWeights::synthetic(&cfg, 1))
    }

    #[test]
    fn serves_batch_and_matches_sequential_greedy() {
        let (cfg, w) = tiny();
        let prompt: Vec<u8> = vec![1, 2, 3, 4, 5];
        let reqs: Vec<Request> =
            (0..3).map(|id| Request { id, prompt: prompt.clone(), max_new: 4 }).collect();
        let be = NativeBackend::borrowed(&cfg, &w);
        let server = BatchServer::new(&be, 2);
        let (resps, stats) = server.run(reqs).unwrap();
        assert_eq!(resps.len(), 3);
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.generated_tokens, 12);
        // greedy reference via full forward
        let mut seq = prompt.clone();
        let mut want = Vec::new();
        for _ in 0..4 {
            let logits = model_fwd(&cfg, &w, &seq);
            let last = logits.row(logits.rows - 1);
            let next = argmax(last);
            want.push(next);
            seq.push(next);
        }
        for r in &resps {
            assert_eq!(r.tokens, want, "req {}", r.id);
            assert!(r.latency_s >= r.ttft_s);
        }
    }

    #[test]
    fn continuous_batching_admits_beyond_max_batch() {
        let (cfg, w) = tiny();
        let reqs: Vec<Request> =
            (0..5).map(|id| Request { id, prompt: vec![7, 8], max_new: 2 }).collect();
        let be = NativeBackend::borrowed(&cfg, &w);
        let server = BatchServer::new(&be, 2);
        let (resps, stats) = server.run(reqs).unwrap();
        assert_eq!(resps.len(), 5);
        assert!(stats.tokens_per_s() > 0.0);
    }

    #[test]
    fn channel_facade_round_trips() {
        let (cfg, w) = tiny();
        let (tx, rx) = serve_channel(Box::new(NativeBackend::new(cfg, w)), 2);
        tx.send(Request { id: 42, prompt: vec![1, 2, 3], max_new: 3 }).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert_eq!(resp.id, 42);
        assert_eq!(resp.tokens.len(), 3);
    }

    /// The fused tick (packed backend, `decode_batch` with B > 1) must
    /// produce the same greedy tokens as solo serving (B = 1 per tick).
    #[test]
    fn fused_packed_serving_matches_solo_serving() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 13);
        let be = crate::engine::PackedBackend::from_weights(&cfg, &w).unwrap();
        let reqs: Vec<Request> = (0..4)
            .map(|id| Request { id, prompt: vec![2, 4, 6, (id % 3) as u8], max_new: 3 })
            .collect();
        let (mut fused, _) = BatchServer::new(&be, 4).run(reqs.clone()).unwrap();
        let (mut solo, _) = BatchServer::new(&be, 1).run(reqs).unwrap();
        fused.sort_by_key(|r| r.id);
        solo.sort_by_key(|r| r.id);
        assert_eq!(fused.len(), 4);
        for (a, b) in fused.iter().zip(&solo) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "req {}: fused tick must match solo decode", a.id);
        }
    }

    #[test]
    fn percentile_nearest_rank_pinned() {
        // known vector 1..=20: p50 = 10 (rank ⌈0.5·20⌉ = 10), p95 = 19,
        // p100 = 20, tiny p → min
        let v: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 10.0);
        assert_eq!(percentile(&v, 95.0), 19.0);
        assert_eq!(percentile(&v, 100.0), 20.0);
        assert_eq!(percentile(&v, 1.0), 1.0);
        // two samples: the median by nearest-rank is the FIRST, not the max
        assert_eq!(percentile(&[1.0, 2.0], 50.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 95.0), 2.0);
        // degenerate inputs
        assert_eq!(percentile(&[], 95.0), 0.0);
        assert_eq!(percentile(&[3.5], 95.0), 3.5);
    }

    #[test]
    fn server_stats_expose_p50_and_p95() {
        let (cfg, w) = tiny();
        let reqs: Vec<Request> =
            (0..4).map(|id| Request { id, prompt: vec![1, 2], max_new: 2 }).collect();
        let be = NativeBackend::borrowed(&cfg, &w);
        let (_, stats) = BatchServer::new(&be, 2).run(reqs).unwrap();
        assert!(stats.p50_latency_s > 0.0);
        assert!(stats.p95_latency_s >= stats.p50_latency_s);
    }
}
