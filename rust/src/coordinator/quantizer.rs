//! Full-model PTQ driver: applies a quantization `Method` to every
//! quantizable matrix of a model, with adaptive layer-wise N:M allocation
//! (§3.3) and the per-matrix calibration gathered by `coordinator::calib`.

use crate::coordinator::calib::ModelCalib;
use crate::coordinator::scheduler;
use crate::model::config::ModelConfig;
use crate::model::ModelWeights;
use crate::quant::allocate::{assign_layer_ratios, Allocation};
use crate::quant::baselines::{awq, billm_opts, gptq, pbllm, rtn};
use crate::quant::pipeline::{structured_binarize, StbOpts};
use crate::quant::{LayerCalib, Metric, NmRatio, NonSalientMode};

/// A quantization method, as named in the paper's tables.
#[derive(Clone, Debug)]
pub enum Method {
    FullPrecision,
    Rtn { bits: u32 },
    Gptq { bits: u32, block: usize },
    PbLlm { frac_salient: f64, hi_bits: u32 },
    /// AWQ-style activation-aware scaling + grouped RTN (Fig. 4b baseline)
    Awq { bits: u32 },
    /// BiLLM; `nm = None` → vanilla ~1.09 bit, `Some` → sub-1-bit N:M variant
    BiLlm { nm: Option<NmRatio> },
    /// STBLLM with explicit options (the default via `Method::stbllm`)
    Stbllm { opts: StbOpts, allocation: Allocation },
}

impl Method {
    pub fn stbllm(nm: NmRatio) -> Method {
        Method::Stbllm { opts: StbOpts::stbllm(nm), allocation: Allocation::Ours }
    }

    pub fn label(&self) -> String {
        match self {
            Method::FullPrecision => "FullPrecision".into(),
            Method::Rtn { bits } => format!("RTN-{bits}bit"),
            Method::Gptq { bits, .. } => format!("GPTQ-{bits}bit"),
            Method::PbLlm { .. } => "PB-LLM".into(),
            Method::Awq { bits } => format!("AWQ-{bits}bit"),
            Method::BiLlm { nm: None } => "BiLLM".into(),
            Method::BiLlm { nm: Some(r) } => format!("BiLLM({})", r.label()),
            Method::Stbllm { opts, .. } => format!("STBLLM({})", opts.nm.label()),
        }
    }
}

/// Per-model quantization outcome.
pub struct QuantizedModel {
    pub weights: ModelWeights,
    /// mean value-bits per weight across quantized matrices
    pub avg_bits: f64,
    /// mean salient fraction
    pub r_salient: f64,
    /// wall-clock seconds spent quantizing
    pub seconds: f64,
    /// per-layer assigned N:M (empty for non-N:M methods)
    pub layer_ratios: Vec<NmRatio>,
}

/// Layer importance for allocation: L2 norm of the layer's weight matrices.
pub fn layer_importance(w: &ModelWeights) -> Vec<f32> {
    w.layers
        .iter()
        .map(|l| l.mats.values().map(|m| m.frob_norm().powi(2)).sum::<f32>().sqrt())
        .collect()
}

/// Quantize a whole model. `calib = None` runs calibration-free (RTN etc.).
pub fn quantize_model(
    cfg: &ModelConfig,
    weights: &ModelWeights,
    method: &Method,
    calib: Option<&ModelCalib>,
    workers: usize,
) -> QuantizedModel {
    let t0 = std::time::Instant::now();
    if matches!(method, Method::FullPrecision) {
        return QuantizedModel {
            weights: weights.clone(),
            avg_bits: 32.0,
            r_salient: 0.0,
            seconds: 0.0,
            layer_ratios: Vec::new(),
        };
    }

    // layer-wise N:M allocation for STBLLM (other methods use uniform masks)
    let layer_ratios: Vec<NmRatio> = match method {
        Method::Stbllm { opts, allocation } => {
            assign_layer_ratios(*allocation, opts.nm, &layer_importance(weights))
        }
        Method::BiLlm { nm: Some(r) } => vec![*r; cfg.n_layers],
        _ => Vec::new(),
    };

    // flatten jobs: (layer, name, matrix, calib)
    struct Job<'a> {
        layer: usize,
        name: String,
        w: &'a crate::tensor::Mat,
        calib: Option<&'a LayerCalib>,
    }
    let names = cfg.layer_weight_names();
    let mut jobs = Vec::new();
    for (li, lw) in weights.layers.iter().enumerate() {
        for n in &names {
            jobs.push(Job {
                layer: li,
                name: n.to_string(),
                w: &lw.mats[*n],
                calib: calib.map(|c| &c.per_layer[li][*n]),
            });
        }
    }

    let empty_calib = LayerCalib::none();
    let results = scheduler::run_parallel(jobs, workers, |job| {
        let lc = job.calib.unwrap_or(&empty_calib);
        let (recon, bits, r_sal) = match method {
            Method::FullPrecision => unreachable!(),
            Method::Rtn { bits } => (rtn::rtn(job.w, *bits), *bits as f64, 0.0),
            Method::Gptq { bits, block } => (
                gptq::gptq(job.w, lc.hessian.as_ref(), *bits, *block, 0.01),
                *bits as f64,
                0.0,
            ),
            Method::PbLlm { frac_salient, hi_bits } => {
                let (r, b) = pbllm::pbllm(job.w, *frac_salient, *hi_bits);
                (r, b, *frac_salient)
            }
            Method::Awq { bits } => {
                let ones = vec![1.0f32; job.w.cols];
                let norms = lc.x_col_norms.as_deref().unwrap_or(&ones);
                (awq::awq(job.w, norms, *bits, 0.5, 128), *bits as f64, 0.0)
            }
            Method::BiLlm { nm } => {
                let mut opts = billm_opts(*nm);
                if nm.is_some() {
                    opts.nm = layer_ratios[job.layer];
                }
                let res = structured_binarize(job.w, lc, &opts);
                (res.recon, res.avg_bits, res.r_salient)
            }
            Method::Stbllm { opts, .. } => {
                let mut o = opts.clone();
                o.nm = layer_ratios[job.layer];
                let res = structured_binarize(job.w, lc, &o);
                (res.recon, res.avg_bits, res.r_salient)
            }
        };
        (job.layer, job.name, recon, bits, r_sal)
    });

    let mut out = weights.clone();
    let mut bits_sum = 0.0;
    let mut sal_sum = 0.0;
    let n_results = results.len().max(1);
    for (layer, name, recon, bits, r_sal) in results {
        out.layers[layer].mats.insert(name, recon);
        bits_sum += bits;
        sal_sum += r_sal;
    }
    QuantizedModel {
        weights: out,
        avg_bits: bits_sum / n_results as f64,
        r_salient: sal_sum / n_results as f64,
        seconds: t0.elapsed().as_secs_f64(),
        layer_ratios,
    }
}

/// Convenience: the ablation variants of Table 5/6/8/10 as Method builders.
pub fn stbllm_with_rearrange(nm: NmRatio) -> Method {
    let mut opts = StbOpts::stbllm(nm);
    opts.rearrange = true;
    Method::Stbllm { opts, allocation: Allocation::Ours }
}

pub fn stbllm_with_metric(nm: NmRatio, metric: Metric) -> Method {
    let mut opts = StbOpts::stbllm(nm);
    opts.metric = metric;
    Method::Stbllm { opts, allocation: Allocation::Ours }
}

pub fn stbllm_with_allocation(nm: NmRatio, allocation: Allocation) -> Method {
    Method::Stbllm { opts: StbOpts::stbllm(nm), allocation }
}

pub fn stbllm_with_nonsalient(nm: NmRatio, mode: NonSalientMode) -> Method {
    let mut opts = StbOpts::stbllm(nm);
    opts.non_salient = mode;
    Method::Stbllm { opts, allocation: Allocation::Ours }
}

pub fn stbllm_with_block(nm: NmRatio, block: usize) -> Method {
    let mut opts = StbOpts::stbllm(nm);
    opts.block_size = block;
    Method::Stbllm { opts, allocation: Allocation::Ours }
}

/// Table 10 variants: quant-only (no N:M) and structure-only (no binarize).
pub fn quant_only(nm: NmRatio) -> Method {
    let mut opts = StbOpts::stbllm(nm);
    opts.structure = false;
    Method::Stbllm { opts, allocation: Allocation::Ours }
}

pub fn structure_only(nm: NmRatio) -> Method {
    let mut opts = StbOpts::stbllm(nm);
    opts.quantize = false;
    Method::Stbllm { opts, allocation: Allocation::Ours }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::calib::calibrate;

    fn setup() -> (ModelConfig, ModelWeights, ModelCalib) {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 1);
        let calib = calibrate(&cfg, &w, "c4s", 256, 2);
        (cfg, w, calib)
    }

    #[test]
    fn stbllm_quantizes_all_matrices() {
        let (cfg, w, calib) = setup();
        let q = quantize_model(&cfg, &w, &Method::stbllm(NmRatio::new(4, 8)), Some(&calib), 1);
        assert!(q.avg_bits < 0.65 && q.avg_bits > 0.4, "bits={}", q.avg_bits);
        assert!(q.r_salient > 0.0 && q.r_salient < 0.2);
        assert_eq!(q.layer_ratios.len(), cfg.n_layers);
        // every matrix now has ~half zeros
        for l in &q.weights.layers {
            for m in l.mats.values() {
                let zeros = m.data.iter().filter(|&&v| v == 0.0).count();
                let frac = zeros as f64 / m.data.len() as f64;
                assert!(frac > 0.3, "zeros frac {frac}");
            }
        }
        // embeddings untouched
        assert_eq!(q.weights.embed.data, w.embed.data);
    }

    #[test]
    fn labels() {
        assert_eq!(Method::stbllm(NmRatio::new(4, 8)).label(), "STBLLM(4:8)");
        assert_eq!(Method::BiLlm { nm: None }.label(), "BiLLM");
        assert_eq!(Method::Rtn { bits: 1 }.label(), "RTN-1bit");
    }

    #[test]
    fn fp_is_identity() {
        let (cfg, w, _) = setup();
        let q = quantize_model(&cfg, &w, &Method::FullPrecision, None, 1);
        assert_eq!(q.weights.layers[0].mats["wq"].data, w.layers[0].mats["wq"].data);
    }

    #[test]
    fn rtn_works_without_calibration() {
        let (cfg, w, _) = setup();
        let q = quantize_model(&cfg, &w, &Method::Rtn { bits: 2 }, None, 1);
        assert!((q.avg_bits - 2.0).abs() < 1e-9);
    }

    #[test]
    fn stbllm_recon_better_than_billm_same_nm() {
        let (cfg, w, calib) = setup();
        let nm = NmRatio::new(4, 8);
        let qs = quantize_model(&cfg, &w, &Method::stbllm(nm), Some(&calib), 1);
        let qb = quantize_model(&cfg, &w, &Method::BiLlm { nm: Some(nm) }, Some(&calib), 1);
        let err = |q: &QuantizedModel| -> f32 {
            let a = &w.layers[0].mats["wq"];
            let b = &q.weights.layers[0].mats["wq"];
            a.sub(b).frob_norm()
        };
        assert!(err(&qs) <= err(&qb) * 1.1, "stb={} billm={}", err(&qs), err(&qb));
    }
}
