//! The L3 coordinator: calibration manager, work scheduler, full-model PTQ
//! driver, and the batched inference server. This module is the system glue
//! that turns the per-matrix algorithms in [`crate::quant`] into a
//! deployable compression + serving pipeline.
//!
//! The server runs against the [`crate::engine::Backend`] seam (native,
//! packed, ...) rather than raw weights; the `Engine` facade
//! (`crate::engine`) is the canonical way to drive quantize → eval → serve.

pub mod calib;
pub mod kvpool;
pub mod quantizer;
pub mod scheduler;
pub mod server;

pub use calib::{calibrate, ModelCalib};
pub use kvpool::{KvPool, KvPoolError, KvPoolStats, PagedKv};
pub use quantizer::{quantize_model, Method, QuantizedModel};
pub use server::{serve_channel, BatchServer, Request, Response, ServeError, ServerStats};
