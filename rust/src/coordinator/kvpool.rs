//! Paged KV-cache pool — KV activation memory as a managed, shared resource.
//!
//! The flat serving path gives every decode session a private
//! `(capacity, dim)` K and V matrix per layer, sized for the worst case; at
//! sub-1-bit weight storage the KV cache, not the packed weights, is what
//! caps how many sequences a node can admit. This module replaces the flat
//! buffers with a vLLM-style block arena:
//!
//! * [`KvPool`] — a fixed budget of physical **pages** (each page holds
//!   `page_size` token slots × `dim` floats of K and V for every layer),
//!   with a free-list of recycled page buffers, reservation accounting for
//!   admission control, and a prefix index for cross-session reuse.
//! * [`PagedKv`] — one sequence's **page table**: an ordered list of
//!   `Arc<KvPage>` handles the decode loop reads/writes through. Pages are
//!   appended as the sequence grows and returned to the pool on drop.
//! * **Prefix caching** — completed pages are registered under the exact
//!   token history they encode; a new session whose prompt shares that
//!   history maps the same physical pages read-only (K/V rows depend only
//!   on the tokens at and before them, so reuse is exact). A session that
//!   shares a page and then needs to write into it (divergence inside a
//!   partially-reused page) gets a private copy first — copy-on-write.
//!
//! Accounting invariant: a page table never holds more pages than its
//! reservation, and every physical page is either owned by a live table,
//! shared between tables, or held only by the prefix index (and therefore
//! evictable). Hence, once a reservation is granted, page allocation cannot
//! fail — the pool evicts cached-only pages on demand and the residual
//! physical count is bounded by the sum of live reservations.
//!
//! The decode hot paths (`DecodeState::step_ops`, `step_ops_batch`) access
//! KV through this table with the same f32 values as the flat path, so
//! paged decode is bit-identical to flat decode (pinned by
//! `rust/tests/kv_paging.rs`).

use std::fmt;
use std::sync::{Arc, Mutex};

use crate::model::config::ModelConfig;
use crate::obs::{Counter, Gauge, Registry, Snapshot};
use crate::util::json::{num, obj, Json};

/// One physical KV page: `page_size` token slots of K and V rows for every
/// layer, laid out `[layer][k=0|v=1][slot][dim]`. Deliberately NOT `Clone`:
/// every physical page must be minted by `KvPool::alloc_page` so the
/// reserved/physical accounting stays truthful.
pub struct KvPage {
    data: Vec<f32>,
}

/// Typed allocation/admission errors from the pool. `Exhausted` is
/// transient (pages free up as sequences retire — back off and retry);
/// `TooLarge` and `GeometryMismatch` are permanent for the request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KvPoolError {
    /// The reservation cannot be granted right now; retry after sequences
    /// retire.
    Exhausted { need_pages: usize, free_pages: usize, total_pages: usize },
    /// The request can never fit, even in an empty pool.
    TooLarge { need_pages: usize, total_pages: usize },
    /// The pool was built for a different model shape.
    GeometryMismatch { pool_dim: usize, model_dim: usize, pool_layers: usize, model_layers: usize },
}

impl fmt::Display for KvPoolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KvPoolError::Exhausted { need_pages, free_pages, total_pages } => write!(
                f,
                "kv pool exhausted: need {need_pages} pages, {free_pages}/{total_pages} unreserved"
            ),
            KvPoolError::TooLarge { need_pages, total_pages } => write!(
                f,
                "request needs {need_pages} kv pages but the pool only has {total_pages}"
            ),
            KvPoolError::GeometryMismatch { pool_dim, model_dim, pool_layers, model_layers } => {
                write!(
                    f,
                    "kv pool built for dim={pool_dim}/{pool_layers} layers, model has dim={model_dim}/{model_layers} layers"
                )
            }
        }
    }
}

impl std::error::Error for KvPoolError {}

/// Pool counters, snapshot via [`KvPool::stats`] (also embedded in
/// `ServerStats::kv` at the end of a serving run).
#[derive(Clone, Debug, Default)]
pub struct KvPoolStats {
    pub total_pages: usize,
    pub page_size: usize,
    /// physical pages live right now (session-owned + shared + cached)
    pub pages_in_use: usize,
    /// pages promised to live sessions (admission-control budget)
    pub pages_reserved: usize,
    /// high-water mark of `pages_in_use`
    pub peak_pages: usize,
    /// fresh physical allocations over the pool's lifetime (incl. COW)
    pub allocated_total: usize,
    /// copy-on-write page duplications (divergence inside a shared page)
    pub cow_copies: usize,
    /// pages mapped from the prefix index into new sessions
    pub prefix_hits: usize,
    /// of which partially-valid tail pages (COW candidates)
    pub prefix_hit_partial: usize,
    /// tokens of KV recomputation skipped thanks to prefix hits
    pub prefix_hit_tokens: usize,
    /// completed pages registered in the prefix index
    pub registered: usize,
    /// cached-only pages dropped to make room for new allocations
    pub evictions: usize,
}

impl KvPoolStats {
    /// Pages not promised to any live session — what the gateway's
    /// load-shed watermark compares against.
    pub fn free_pages(&self) -> usize {
        self.total_pages.saturating_sub(self.pages_reserved)
    }

    /// Fold another pool's counters into this one — how the gateway
    /// aggregates per-replica pools into ONE `"kv"` stats section. Every
    /// counter sums; `page_size` keeps `self`'s value (replica slices are
    /// built identically), so merging a single snapshot is the identity.
    pub fn merge(&mut self, other: &KvPoolStats) {
        self.total_pages += other.total_pages;
        self.pages_in_use += other.pages_in_use;
        self.pages_reserved += other.pages_reserved;
        self.peak_pages += other.peak_pages;
        self.allocated_total += other.allocated_total;
        self.cow_copies += other.cow_copies;
        self.prefix_hits += other.prefix_hits;
        self.prefix_hit_partial += other.prefix_hit_partial;
        self.prefix_hit_tokens += other.prefix_hit_tokens;
        self.registered += other.registered;
        self.evictions += other.evictions;
    }
}

impl Snapshot for KvPoolStats {
    fn name(&self) -> &'static str {
        "kv"
    }

    /// The pool's section of the schema-2 stats envelope (nested under
    /// `"kv"` in the server/gateway sections) — the pre-redesign fields
    /// preserved, plus the counters that previously had no JSON surface.
    fn to_json(&self) -> Json {
        obj(vec![
            ("total_pages", num(self.total_pages as f64)),
            ("page_size", num(self.page_size as f64)),
            ("pages_in_use", num(self.pages_in_use as f64)),
            ("pages_reserved", num(self.pages_reserved as f64)),
            ("free_pages", num(self.free_pages() as f64)),
            ("peak_pages", num(self.peak_pages as f64)),
            ("allocated_total", num(self.allocated_total as f64)),
            ("cow_copies", num(self.cow_copies as f64)),
            ("prefix_hits", num(self.prefix_hits as f64)),
            ("prefix_hit_partial", num(self.prefix_hit_partial as f64)),
            ("prefix_hit_tokens", num(self.prefix_hit_tokens as f64)),
            ("registered", num(self.registered as f64)),
            ("evictions", num(self.evictions as f64)),
        ])
    }
}

/// The pool's registered metric handles — mirrored from the authoritative
/// `PoolInner` counters at each mutation point, under the pool lock.
struct KvMetrics {
    allocated: Arc<Counter>,
    cow: Arc<Counter>,
    evictions: Arc<Counter>,
    prefix_hits: Arc<Counter>,
    prefix_hit_tokens: Arc<Counter>,
    registered: Arc<Counter>,
    in_use: Arc<Gauge>,
    reserved: Arc<Gauge>,
}

impl KvMetrics {
    fn new(reg: &Registry, labels: &str) -> KvMetrics {
        KvMetrics {
            allocated: reg.counter_with(
                "stbllm_kv_pages_allocated",
                labels,
                "physical page allocations",
            ),
            cow: reg.counter_with(
                "stbllm_kv_cow_copies",
                labels,
                "copy-on-write page duplications",
            ),
            evictions: reg.counter_with(
                "stbllm_kv_evictions",
                labels,
                "cached pages evicted under pressure",
            ),
            prefix_hits: reg.counter_with(
                "stbllm_kv_prefix_hits",
                labels,
                "pages mapped from the prefix cache",
            ),
            prefix_hit_tokens: reg.counter_with(
                "stbllm_kv_prefix_hit_tokens",
                labels,
                "prompt tokens served from cache",
            ),
            registered: reg.counter_with(
                "stbllm_kv_prefix_registered",
                labels,
                "pages registered for reuse",
            ),
            in_use: reg.gauge_with(
                "stbllm_kv_pages_in_use",
                labels,
                "physical pages live right now",
            ),
            reserved: reg.gauge_with(
                "stbllm_kv_pages_reserved",
                labels,
                "pages promised to live sessions",
            ),
        }
    }
}

struct PrefixEntry {
    /// the exact token history `[0, (k+1)·page_size)` this page encodes
    key: Vec<u8>,
    page: Arc<KvPage>,
    last_used: u64,
}

struct PoolInner {
    reserved: usize,
    physical: usize,
    /// recycled page buffers (the free-list half of the arena)
    free: Vec<Vec<f32>>,
    index: Vec<PrefixEntry>,
    /// logical clock for LRU bookkeeping
    clock: u64,
    stats: KvPoolStats,
    /// registry mirror, attached by the serving stack (`None` until then)
    metrics: Option<KvMetrics>,
    /// address of the attached registry — makes `attach_registry`
    /// idempotent (re-attaching the same one must not re-seed counters)
    metrics_reg: usize,
}

impl PoolInner {
    /// Refresh the level gauges from the authoritative counters. Called
    /// under the pool lock after any mutation of `physical`/`reserved`.
    fn sync_gauges(&self) {
        if let Some(m) = &self.metrics {
            m.in_use.set(self.physical as i64);
            m.reserved.set(self.reserved as i64);
        }
    }
}

/// A shared, fixed-budget arena of KV pages (see the module docs).
///
/// All methods take `&self`; the pool is `Sync` and intended to be shared
/// as an `Arc<KvPool>` between a `BatchServer` and its decode sessions.
pub struct KvPool {
    dim: usize,
    n_layers: usize,
    page_size: usize,
    total_pages: usize,
    /// floats per page: `n_layers * 2 * page_size * dim`
    page_floats: usize,
    /// prefix-index entry cap (entries beyond it are LRU-dropped)
    index_cap: usize,
    inner: Mutex<PoolInner>,
}

impl KvPool {
    /// Build a pool of `total_pages` pages of `page_size` token slots for
    /// the given model shape. `page_size` must be a power of two (the row
    /// lookup in the decode hot path is a shift + mask).
    pub fn new(cfg: &ModelConfig, total_pages: usize, page_size: usize) -> KvPool {
        assert!(page_size.is_power_of_two(), "page_size must be a power of two, got {page_size}");
        assert!(total_pages > 0, "kv pool needs at least one page");
        KvPool {
            dim: cfg.dim,
            n_layers: cfg.n_layers,
            page_size,
            total_pages,
            page_floats: cfg.n_layers * 2 * page_size * cfg.dim,
            index_cap: (2 * total_pages).max(8),
            inner: Mutex::new(PoolInner {
                reserved: 0,
                physical: 0,
                free: Vec::new(),
                index: Vec::new(),
                clock: 0,
                stats: KvPoolStats::default(),
                metrics: None,
                metrics_reg: 0,
            }),
        }
    }

    /// Mirror this pool's counters into `registry` (`stbllm_kv_*`).
    /// Counters are seeded with the pool's lifetime totals so a
    /// late-attached registry still reads monotonic, truthful values;
    /// re-attaching to the same registry re-uses the same handles.
    pub fn attach_registry(&self, registry: &Registry) {
        self.attach_registry_with(registry, "");
    }

    /// [`attach_registry`](KvPool::attach_registry) with a fixed label set
    /// on every series (e.g. `replica="0"`) — how multi-replica serving
    /// keeps each pool slice's `stbllm_kv_*` series apart in one registry.
    /// Attach-same-registry idempotence still applies, so a later
    /// unlabeled attach (the bridge's default) is a no-op.
    pub fn attach_registry_with(&self, registry: &Registry, labels: &str) {
        let reg_id = std::ptr::from_ref(registry) as usize;
        {
            let g = self.inner.lock().unwrap();
            if g.metrics_reg == reg_id {
                return; // already mirroring into this registry
            }
        }
        // mint outside the pool lock; a benign double-attach race just
        // re-uses the same registry handles
        let m = KvMetrics::new(registry, labels);
        let mut g = self.inner.lock().unwrap();
        if g.metrics_reg == reg_id {
            return;
        }
        g.metrics_reg = reg_id;
        m.allocated.add(g.stats.allocated_total as u64);
        m.cow.add(g.stats.cow_copies as u64);
        m.evictions.add(g.stats.evictions as u64);
        m.prefix_hits.add(g.stats.prefix_hits as u64);
        m.prefix_hit_tokens.add(g.stats.prefix_hit_tokens as u64);
        m.registered.add(g.stats.registered as u64);
        g.metrics = Some(m);
        g.sync_gauges();
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    /// Worst-case pages for a sequence of `tokens` tokens — the
    /// pages-per-request formula: `ceil(tokens / page_size)`.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.max(1).div_ceil(self.page_size)
    }

    /// Would a reservation of `pages` be granted right now? (Admission
    /// control peek; the authoritative check is [`PagedKv::new`], which
    /// reserves atomically.)
    pub fn can_reserve(&self, pages: usize) -> bool {
        pages <= self.total_pages
            && self.inner.lock().unwrap().reserved + pages <= self.total_pages
    }

    /// Counter snapshot.
    pub fn stats(&self) -> KvPoolStats {
        let g = self.inner.lock().unwrap();
        let mut s = g.stats.clone();
        s.total_pages = self.total_pages;
        s.page_size = self.page_size;
        s.pages_in_use = g.physical;
        s.pages_reserved = g.reserved;
        s
    }

    fn check_geometry(&self, cfg: &ModelConfig) -> Result<(), KvPoolError> {
        if cfg.dim != self.dim || cfg.n_layers != self.n_layers {
            return Err(KvPoolError::GeometryMismatch {
                pool_dim: self.dim,
                model_dim: cfg.dim,
                pool_layers: self.n_layers,
                model_layers: cfg.n_layers,
            });
        }
        Ok(())
    }

    fn try_reserve(&self, pages: usize) -> Result<(), KvPoolError> {
        if pages > self.total_pages {
            return Err(KvPoolError::TooLarge {
                need_pages: pages,
                total_pages: self.total_pages,
            });
        }
        let mut g = self.inner.lock().unwrap();
        if g.reserved + pages > self.total_pages {
            return Err(KvPoolError::Exhausted {
                need_pages: pages,
                free_pages: self.total_pages - g.reserved,
                total_pages: self.total_pages,
            });
        }
        g.reserved += pages;
        g.sync_gauges();
        Ok(())
    }

    /// Allocate one physical page, evicting cached-only pages if the arena
    /// is full. Panics if nothing is evictable — unreachable while every
    /// caller allocates within a granted reservation (see module docs).
    fn alloc_page(&self, cow: bool) -> KvPage {
        let mut g = self.inner.lock().unwrap();
        if g.physical >= self.total_pages {
            let need = g.physical + 1 - self.total_pages;
            Self::evict_locked(&mut g, need);
        }
        assert!(
            g.physical < self.total_pages,
            "kv pool over-committed: {}/{} physical pages live and none evictable \
             (page allocated outside a reservation?)",
            g.physical,
            self.total_pages
        );
        g.physical += 1;
        g.stats.allocated_total += 1;
        if cow {
            g.stats.cow_copies += 1;
        }
        if g.physical > g.stats.peak_pages {
            g.stats.peak_pages = g.physical;
        }
        if let Some(m) = &g.metrics {
            m.allocated.inc();
            if cow {
                m.cow.inc();
            }
        }
        g.sync_gauges();
        let data = g.free.pop().unwrap_or_else(|| vec![0.0f32; self.page_floats]);
        KvPage { data }
    }

    /// Drop the least-recently-used cached-only index entries until `need`
    /// physical pages have been freed (or nothing evictable remains).
    fn evict_locked(g: &mut PoolInner, need: usize) {
        let mut freed = 0usize;
        while freed < need {
            let mut lru: Option<usize> = None;
            for (i, e) in g.index.iter().enumerate() {
                // strong_count == 1 ⇒ only the index holds it ⇒ dropping
                // the entry frees the physical page
                if Arc::strong_count(&e.page) == 1
                    && lru.is_none_or(|l| e.last_used < g.index[l].last_used)
                {
                    lru = Some(i);
                }
            }
            let Some(i) = lru else { break };
            let e = g.index.swap_remove(i);
            if let Ok(pg) = Arc::try_unwrap(e.page) {
                g.physical -= 1;
                g.free.push(pg.data);
                g.stats.evictions += 1;
                if let Some(m) = &g.metrics {
                    m.evictions.inc();
                }
                freed += 1;
            }
        }
        g.sync_gauges();
    }

    /// Return one page reference to the pool (the COW path replacing a
    /// shared page). Frees the physical page iff this was the last holder.
    fn release_one(&self, page: Arc<KvPage>) {
        let mut g = self.inner.lock().unwrap();
        Self::drop_ref_locked(&mut g, page);
        g.sync_gauges();
    }

    /// Return a whole page table + its reservation (session teardown).
    fn release(&self, pages: Vec<Arc<KvPage>>, reserved: usize) {
        let mut g = self.inner.lock().unwrap();
        g.reserved -= reserved.min(g.reserved);
        for p in pages {
            Self::drop_ref_locked(&mut g, p);
        }
        g.sync_gauges();
    }

    fn drop_ref_locked(g: &mut PoolInner, page: Arc<KvPage>) {
        if Arc::strong_count(&page) == 1 {
            if let Ok(pg) = Arc::try_unwrap(page) {
                // empty pages are CoW placeholders that were never
                // pool-accounted — dropping one must not skew `physical`
                if !pg.data.is_empty() {
                    g.physical -= 1;
                    g.free.push(pg.data);
                }
            }
        }
        // count > 1: dropping `page` here just decrements; the page stays
        // live in another table or the prefix index, and whoever drops the
        // final reference routes through this accounting too
    }

    /// Register a completed page under the exact token `history` it
    /// encodes. Same-key re-registrations (identical prompts computed
    /// concurrently) keep a single cached copy.
    fn register_prefix(&self, history: &[u8], page: &Arc<KvPage>) {
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        if let Some(i) = g.index.iter().position(|e| e.key == history) {
            let old = std::mem::replace(&mut g.index[i].page, page.clone());
            g.index[i].last_used = clock;
            Self::drop_ref_locked(&mut g, old);
            return;
        }
        if g.index.len() >= self.index_cap {
            let lru = g.index.iter().enumerate().min_by_key(|(_, e)| e.last_used).map(|(i, _)| i);
            if let Some(i) = lru {
                let e = g.index.swap_remove(i);
                Self::drop_ref_locked(&mut g, e.page);
            }
        }
        g.index.push(PrefixEntry { key: history.to_vec(), page: page.clone(), last_used: clock });
        g.stats.registered += 1;
        if let Some(m) = &g.metrics {
            m.registered.inc();
        }
    }

    /// Map as many cached pages as match `prompt`, up to `max_tokens`
    /// tokens: full pages via exact-key chain lookups at page boundaries,
    /// then at most one partially-valid tail page from an entry whose
    /// history extends ours (shared until the session writes into it —
    /// that write copies, see [`PagedKv`]). Returns the mapped pages and
    /// the number of tokens whose KV they already hold.
    fn lookup_prefix(&self, prompt: &[u8], max_tokens: usize) -> (Vec<Arc<KvPage>>, usize) {
        let ps = self.page_size;
        let limit = max_tokens.min(prompt.len());
        let mut g = self.inner.lock().unwrap();
        g.clock += 1;
        let clock = g.clock;
        let mut pages: Vec<Arc<KvPage>> = Vec::new();
        let mut matched = 0usize;
        while matched + ps <= limit {
            let key = &prompt[..matched + ps];
            let Some(i) = g.index.iter().position(|e| e.key == key) else { break };
            g.index[i].last_used = clock;
            pages.push(g.index[i].page.clone());
            matched += ps;
        }
        if matched < limit {
            // partial tail: the best entry covering [matched, matched+ps)
            // whose history agrees with our prompt past `matched`
            let mut best: Option<(usize, usize)> = None;
            for (i, e) in g.index.iter().enumerate() {
                if e.key.len() != matched + ps || e.key[..matched] != prompt[..matched] {
                    continue;
                }
                let common = e.key[matched..]
                    .iter()
                    .zip(&prompt[matched..limit])
                    .take_while(|(a, b)| a == b)
                    .count();
                if common > 0 && best.is_none_or(|(_, c)| common > c) {
                    best = Some((i, common));
                }
            }
            if let Some((i, common)) = best {
                g.index[i].last_used = clock;
                pages.push(g.index[i].page.clone());
                matched += common;
                g.stats.prefix_hit_partial += 1;
            }
        }
        g.stats.prefix_hits += pages.len();
        g.stats.prefix_hit_tokens += matched;
        if let Some(m) = &g.metrics {
            m.prefix_hits.add(pages.len() as u64);
            m.prefix_hit_tokens.add(matched as u64);
        }
        (pages, matched)
    }
}

/// One sequence's page table over a shared [`KvPool`] — what a paged
/// `DecodeState` reads and writes KV through.
pub struct PagedKv {
    pool: Arc<KvPool>,
    table: Vec<Arc<KvPage>>,
    /// pages reserved at creation (returned on drop)
    reserved: usize,
    /// tokens whose KV was mapped from the prefix cache at creation
    matched: usize,
    /// full token history (prompt prefix + every token stepped) — the
    /// prefix-index key material
    history: Vec<u8>,
    // geometry copies so the hot row lookup never touches the pool lock
    page_size: usize,
    shift: u32,
    mask: usize,
    dim: usize,
}

impl PagedKv {
    /// Reserve worst-case pages for `capacity_tokens` and map any cached
    /// prefix of `prompt`. At most `prompt.len() - 1` tokens are reused so
    /// the session always recomputes the last prompt token (the serving
    /// loop needs its logits).
    pub fn new(
        pool: &Arc<KvPool>,
        cfg: &ModelConfig,
        capacity_tokens: usize,
        prompt: &[u8],
    ) -> Result<PagedKv, KvPoolError> {
        pool.check_geometry(cfg)?;
        let capacity = capacity_tokens.max(1);
        let reserved = pool.pages_for(capacity);
        pool.try_reserve(reserved)?;
        let max_reuse = prompt.len().saturating_sub(1).min(capacity - 1);
        let (table, matched) = pool.lookup_prefix(prompt, max_reuse);
        Ok(PagedKv {
            table,
            reserved,
            matched,
            history: prompt[..matched].to_vec(),
            page_size: pool.page_size,
            shift: pool.page_size.trailing_zeros(),
            mask: pool.page_size - 1,
            dim: pool.dim,
            pool: pool.clone(),
        })
    }

    /// Tokens already covered by prefix-cache pages; the caller starts
    /// decoding at this position.
    pub fn matched(&self) -> usize {
        self.matched
    }

    /// Pages currently mapped by this sequence.
    pub fn pages_mapped(&self) -> usize {
        self.table.len()
    }

    #[inline]
    fn row_off(&self, li: usize, which: usize, slot: usize) -> usize {
        ((li * 2 + which) * self.page_size + slot) * self.dim
    }

    /// K row for layer `li`, position `j` (must have been written).
    #[inline]
    pub fn k_row(&self, li: usize, j: usize) -> &[f32] {
        let off = self.row_off(li, 0, j & self.mask);
        &self.table[j >> self.shift].data[off..off + self.dim]
    }

    /// V row for layer `li`, position `j` (must have been written).
    #[inline]
    pub fn v_row(&self, li: usize, j: usize) -> &[f32] {
        let off = self.row_off(li, 1, j & self.mask);
        &self.table[j >> self.shift].data[off..off + self.dim]
    }

    /// Make page `pi` privately writable: append a fresh page when the
    /// table ends at `pi`, or copy-on-write when the page is shared with
    /// another table / the prefix index.
    fn ensure_writable(&mut self, pi: usize) {
        if pi == self.table.len() {
            self.table.push(Arc::new(self.pool.alloc_page(false)));
        } else if Arc::strong_count(&self.table[pi]) > 1 {
            // Copy-on-write. Order matters: snapshot the shared rows and
            // release OUR reference FIRST, so that when this session's own
            // prefix mapping pins every cached page (full pool, all pages
            // strong_count 2 via index + this table), the released page
            // becomes cached-only and therefore evictable by the
            // allocation below — otherwise the "infallible within a
            // reservation" invariant would break and alloc_page would
            // panic on a shared-prompt workload.
            let src = self.table[pi].data.clone();
            let old =
                std::mem::replace(&mut self.table[pi], Arc::new(KvPage { data: Vec::new() }));
            self.pool.release_one(old);
            let mut fresh = self.pool.alloc_page(true);
            fresh.data.copy_from_slice(&src);
            self.table[pi] = Arc::new(fresh);
        }
        debug_assert!(pi < self.table.len(), "kv page table gap at page {pi}");
    }

    /// Write the K and V rows for position `p` of layer `li`.
    pub(crate) fn write(&mut self, li: usize, p: usize, k: &[f32], v: &[f32]) {
        let pi = p >> self.shift;
        self.ensure_writable(pi);
        let slot = p & self.mask;
        let ko = self.row_off(li, 0, slot);
        let vo = self.row_off(li, 1, slot);
        let page = Arc::get_mut(&mut self.table[pi]).expect("page unique after ensure_writable");
        page.data[ko..ko + self.dim].copy_from_slice(k);
        page.data[vo..vo + self.dim].copy_from_slice(v);
    }

    /// Record that `tok`'s step completed (all layers written). When this
    /// fills a page, the page is published to the pool's prefix index
    /// under the exact token history it encodes.
    pub(crate) fn on_token(&mut self, tok: u8) {
        self.history.push(tok);
        let n = self.history.len();
        if n % self.page_size == 0 {
            let pi = n / self.page_size - 1;
            if let Some(page) = self.table.get(pi) {
                self.pool.register_prefix(&self.history, page);
            }
        }
    }
}

impl Drop for PagedKv {
    fn drop(&mut self) {
        self.pool.release(std::mem::take(&mut self.table), self.reserved);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::Family;

    /// Tiny geometry so page buffers stay small.
    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".to_string(),
            family: Family::Llama,
            dim: 8,
            n_layers: 2,
            ffn_hidden: 16,
            vocab: 32,
            seq_len: 64,
            window: 0,
            norm_eps: 1e-5,
            seed: 1,
        }
    }

    fn krow(li: usize, p: usize) -> Vec<f32> {
        (0..8usize).map(|d| (li * 1000 + p * 10 + d) as f32).collect()
    }

    fn vrow(li: usize, p: usize) -> Vec<f32> {
        (0..8usize).map(|d| -((li * 1000 + p * 10 + d) as f32)).collect()
    }

    /// Step a PagedKv through `tokens`, writing deterministic rows.
    fn run_seq(pool: &Arc<KvPool>, cfg: &ModelConfig, cap: usize, tokens: &[u8]) -> PagedKv {
        let mut kv = PagedKv::new(pool, cfg, cap, tokens).unwrap();
        for (p, &t) in tokens.iter().enumerate().skip(kv.matched()) {
            for li in 0..cfg.n_layers {
                kv.write(li, p, &krow(li, p), &vrow(li, p));
            }
            kv.on_token(t);
        }
        kv
    }

    /// The registry mirror (`stbllm_kv_*`) must agree with the pool's own
    /// stats snapshot, survive a redundant re-attach without double
    /// counting, and drop the level gauges back to zero at release.
    #[test]
    fn registry_mirror_tracks_pool_counters() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 8, 4));
        let reg = Registry::new();
        pool.attach_registry(&reg);
        pool.attach_registry(&reg); // idempotent: must not re-seed
        let toks: Vec<u8> = (0..10).collect();
        let kv = run_seq(&pool, &cfg, 16, &toks);
        let mid = reg.render_prometheus();
        assert!(mid.contains("stbllm_kv_pages_reserved 4"));
        assert!(mid.contains("stbllm_kv_pages_in_use 3"));
        drop(kv);
        let s = pool.stats();
        let text = reg.render_prometheus();
        assert!(
            text.contains(&format!("stbllm_kv_pages_allocated_total {}\n", s.allocated_total)),
            "mirror drifted from stats: {text}"
        );
        assert!(text.contains(&format!("stbllm_kv_prefix_registered_total {}\n", s.registered)));
        assert!(text.contains("stbllm_kv_pages_reserved 0"));
        assert!(text.contains(&format!("stbllm_kv_pages_in_use {}\n", s.pages_in_use)));
    }

    /// `KvPoolStats` serializes under `"kv"` with the old field names.
    #[test]
    fn kv_stats_snapshot_json_shape() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 8, 4));
        let toks: Vec<u8> = (0..10).collect();
        let kv = run_seq(&pool, &cfg, 16, &toks);
        drop(kv);
        let s = pool.stats();
        let j = s.to_json();
        assert_eq!(j.get("total_pages").and_then(Json::as_usize), Some(8));
        assert_eq!(j.get("page_size").and_then(Json::as_usize), Some(4));
        assert_eq!(j.get("registered").and_then(Json::as_usize), Some(s.registered));
        assert_eq!(j.get("free_pages").and_then(Json::as_usize), Some(8));
        assert_eq!(s.name(), "kv");
    }

    #[test]
    fn free_pages_tracks_reservations() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 8, 4));
        assert_eq!(pool.stats().free_pages(), 8);
        let toks: Vec<u8> = (0..10).collect();
        let kv = run_seq(&pool, &cfg, 16, &toks);
        assert_eq!(pool.stats().free_pages(), 8 - 4); // ceil(16/4) reserved
        drop(kv);
        assert_eq!(pool.stats().free_pages(), 8);
    }

    #[test]
    fn alloc_write_read_roundtrip_and_release() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 8, 4));
        let toks: Vec<u8> = (0..10).collect();
        let kv = run_seq(&pool, &cfg, 16, &toks);
        assert_eq!(kv.pages_mapped(), 3); // 10 tokens / 4-slot pages
        for p in 0..10 {
            for li in 0..cfg.n_layers {
                assert_eq!(kv.k_row(li, p), &krow(li, p)[..]);
                assert_eq!(kv.v_row(li, p), &vrow(li, p)[..]);
            }
        }
        let s = pool.stats();
        assert_eq!(s.pages_in_use, 3);
        assert_eq!(s.pages_reserved, 4); // ceil(16/4)
        drop(kv);
        let s = pool.stats();
        // pages 0 and 1 completed → cached in the prefix index; page 2 died
        assert_eq!(s.pages_reserved, 0);
        assert_eq!(s.pages_in_use, 2);
        assert_eq!(s.registered, 2);
    }

    #[test]
    fn reservation_rejects_typed() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 4, 4));
        // too large even for an empty pool
        match PagedKv::new(&pool, &cfg, 100, &[]) {
            Err(KvPoolError::TooLarge { need_pages: 25, total_pages: 4 }) => {}
            other => panic!("expected TooLarge, got {other:?}"),
        }
        // exhausted after a live reservation takes the budget
        let _a = PagedKv::new(&pool, &cfg, 12, &[]).unwrap(); // 3 pages
        match PagedKv::new(&pool, &cfg, 8, &[]) {
            Err(KvPoolError::Exhausted { need_pages: 2, free_pages: 1, total_pages: 4 }) => {}
            other => panic!("expected Exhausted, got {other:?}"),
        }
        // and the error formats usefully
        let e = KvPoolError::Exhausted { need_pages: 2, free_pages: 1, total_pages: 4 };
        assert!(e.to_string().contains("1/4"));
    }

    #[test]
    fn geometry_mismatch_is_typed() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 4, 4));
        let mut other = tiny_cfg();
        other.dim = 16;
        match PagedKv::new(&pool, &other, 4, &[]) {
            Err(KvPoolError::GeometryMismatch { pool_dim: 8, model_dim: 16, .. }) => {}
            o => panic!("expected GeometryMismatch, got {o:?}"),
        }
    }

    #[test]
    fn prefix_reuse_shares_physical_pages() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 16, 4));
        let toks: Vec<u8> = (10..22).collect(); // 12 tokens = 3 full pages
        let a = run_seq(&pool, &cfg, 16, &toks);
        let before = pool.stats().allocated_total;
        // same prompt: reuse caps at prompt.len()-1 = 11 → pages 0,1 full
        // plus a partial share of a's page 2 (rows 8..11)
        let b = run_seq(&pool, &cfg, 16, &toks);
        assert_eq!(b.matched(), 11);
        let s = pool.stats();
        assert!(s.prefix_hits >= 3, "prefix hits: {}", s.prefix_hits);
        // b's only allocation is the copy-on-write of the shared tail page
        // (it re-writes position 11 there)
        assert_eq!(s.allocated_total - before, 1);
        assert_eq!(s.cow_copies, 1);
        // shared rows read back identically through both tables
        for p in 0..8 {
            assert_eq!(a.k_row(0, p), b.k_row(0, p));
            assert_eq!(a.v_row(1, p), b.v_row(1, p));
        }
    }

    #[test]
    fn divergence_in_shared_page_copies_on_write() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 16, 4));
        let toks_a: Vec<u8> = (0..12).collect();
        let a = run_seq(&pool, &cfg, 16, &toks_a);
        // b shares tokens 0..10 then diverges: full pages 0,1 + partial
        // reuse of a's page 2 (rows 8,9 valid)
        let mut toks_b: Vec<u8> = (0..12).collect();
        toks_b[10] = 99;
        let b = run_seq(&pool, &cfg, 16, &toks_b);
        assert_eq!(b.matched(), 10);
        let s = pool.stats();
        assert_eq!(s.cow_copies, 1, "writing into the shared partial page must copy");
        assert_eq!(s.prefix_hit_partial, 1);
        // a's page 2 is untouched by b's divergent writes
        for p in 8..12 {
            assert_eq!(a.k_row(0, p), &krow(0, p)[..]);
        }
        // and b re-wrote its own rows 10.. in its private copy
        assert_eq!(b.k_row(0, 11), &krow(0, 11)[..]);
    }

    /// Regression: a full pool whose every cached page is pinned by the
    /// NEW session's own prefix mapping must still CoW without panicking —
    /// releasing the session's reference first makes the cached copy
    /// evictable, so the allocation stays within the reservation.
    #[test]
    fn cow_succeeds_when_own_prefix_mapping_pins_the_whole_pool() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 3, 4));
        let toks: Vec<u8> = (0..12).collect();
        drop(run_seq(&pool, &cfg, 12, &toks)); // 3 pages, all left cached
        assert_eq!(pool.stats().pages_in_use, 3);
        // identical sequence: maps all 3 cached pages (2 full + 1 partial,
        // matched 11), then its first write CoWs the partial page while
        // the pool is physically full
        let b = run_seq(&pool, &cfg, 12, &toks);
        assert_eq!(b.matched(), 11);
        let s = pool.stats();
        assert_eq!(s.cow_copies, 1);
        assert!(s.evictions >= 1, "the released shared page must have been evicted");
        assert!(s.pages_in_use <= 3);
        assert_eq!(b.k_row(0, 11), &krow(0, 11)[..]);
        assert_eq!(b.k_row(1, 9), &krow(1, 9)[..]); // shared rows intact
    }

    #[test]
    fn eviction_reclaims_cached_pages_under_pressure() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 4, 4));
        // fill the pool with cached pages from retired sequences
        for seed in 0..2u8 {
            let toks: Vec<u8> = (0..8).map(|t| t + seed * 50).collect();
            drop(run_seq(&pool, &cfg, 8, &toks));
        }
        assert_eq!(pool.stats().pages_in_use, 4); // all cached
        // a new sequence needs 3 fresh pages → evictions must make room
        let toks: Vec<u8> = (100..110).collect();
        let kv = run_seq(&pool, &cfg, 12, &toks);
        assert_eq!(kv.pages_mapped(), 3);
        let s = pool.stats();
        assert!(s.evictions >= 2, "evictions: {}", s.evictions);
        assert!(s.pages_in_use <= 4);
    }

    #[test]
    fn free_list_recycles_buffers() {
        let cfg = tiny_cfg();
        let pool = Arc::new(KvPool::new(&cfg, 2, 4));
        // sequences of < one full page never register prefixes, so their
        // pages die on drop and the buffers go back to the free list
        for _ in 0..5 {
            let kv = run_seq(&pool, &cfg, 4, &[1, 2, 3]);
            assert_eq!(kv.pages_mapped(), 1);
        }
        let s = pool.stats();
        assert_eq!(s.pages_in_use, 0);
        assert_eq!(s.allocated_total, 5);
        assert_eq!(s.peak_pages, 1);
    }

    #[test]
    fn pages_for_formula() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, 4, 16);
        assert_eq!(pool.pages_for(1), 1);
        assert_eq!(pool.pages_for(16), 1);
        assert_eq!(pool.pages_for(17), 2);
        assert_eq!(pool.pages_for(0), 1); // degenerate: still one page
    }
}
