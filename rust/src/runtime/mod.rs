//! PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the XLA CPU client.
//!
//! This is the only boundary between Rust and the AOT-compiled JAX/Pallas
//! world. HLO **text** is the interchange format (xla_extension 0.5.1
//! rejects jax ≥ 0.5 serialized protos — 64-bit instruction ids), and every
//! lowered function returns a 1-tuple (`return_tuple=True`), unwrapped here.

pub mod artifacts;
pub mod client;

pub use artifacts::Artifacts;
pub use client::{Executable, Runtime};
