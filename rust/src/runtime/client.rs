//! PJRT CPU client wrapper + executable cache.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. Executables are
//! cached by artifact name; compilation happens once per process.
//!
//! The real client needs the vendored `xla` crate and is gated behind the
//! `pjrt` cargo feature. Without it an API-compatible fallback is built
//! whose `Runtime::cpu` fails cleanly — every caller (Engine, benches,
//! tests) already degrades to the native execution path on that error, so
//! the crate builds and runs fully offline.

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use anyhow::{Context, Result};

    use crate::tensor::Mat;

    /// A compiled PJRT executable for one lowered jax function.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with f32 matrix inputs; returns the single (tupled) output.
        pub fn run(&self, inputs: &[MatArg]) -> Result<Mat> {
            let mut lits = Vec::with_capacity(inputs.len());
            for a in inputs {
                lits.push(a.to_literal()?);
            }
            self.run_literals(&lits)
        }

        /// Execute with pre-built literals (any ranks); unwraps the 1-tuple
        /// output into a Mat (rank-1/2 outputs only).
        pub fn run_literals(&self, lits: &[xla::Literal]) -> Result<Mat> {
            let result = self.exe.execute::<xla::Literal>(lits)?[0][0].to_literal_sync()?;
            let out = result.to_tuple1().context("unwrap 1-tuple output")?;
            let shape = out.array_shape()?;
            let dims = shape.dims();
            let data = out.to_vec::<f32>()?;
            let (rows, cols) = match dims.len() {
                2 => (dims[0] as usize, dims[1] as usize),
                1 => (1usize, dims[0] as usize),
                d => anyhow::bail!("unexpected output rank {d}"),
            };
            Ok(Mat::from_vec(rows, cols, data))
        }
    }

    /// An input argument: a matrix (2-D) or vector (1-D).
    pub enum MatArg<'a> {
        M(&'a Mat),
        V(&'a [f32]),
    }

    impl<'a> MatArg<'a> {
        fn to_literal(&self) -> Result<xla::Literal> {
            match self {
                MatArg::M(m) => {
                    Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
                }
                MatArg::V(v) => Ok(xla::Literal::vec1(v)),
            }
        }
    }

    /// The process-wide PJRT runtime: one CPU client + an executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
        root: PathBuf,
    }

    impl Runtime {
        /// Create a CPU PJRT runtime rooted at the artifacts directory.
        pub fn cpu(artifacts_root: &Path) -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Runtime {
                client,
                cache: Mutex::new(HashMap::new()),
                root: artifacts_root.to_path_buf(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact (cached by relative file name).
        pub fn load(&self, rel_file: &str) -> Result<std::sync::Arc<Executable>> {
            if let Some(e) = self.cache.lock().unwrap().get(rel_file) {
                return Ok(e.clone());
            }
            let path = self.root.join(rel_file);
            let proto = xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compile {rel_file}"))?;
            let arc = std::sync::Arc::new(Executable { exe, name: rel_file.to_string() });
            self.cache.lock().unwrap().insert(rel_file.to_string(), arc.clone());
            Ok(arc)
        }

        pub fn cached_count(&self) -> usize {
            self.cache.lock().unwrap().len()
        }
    }

    // NOTE: integration tests for this module live in rust/tests/pjrt_parity.rs
    // (they need built artifacts). Unit tests here cover the literal plumbing
    // only, via a computation built directly with XlaBuilder.
    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn literal_roundtrip_via_builder() {
            let client = xla::PjRtClient::cpu().unwrap();
            let builder = xla::XlaBuilder::new("t");
            let shape = xla::Shape::array::<f32>(vec![2, 3]);
            let p = builder.parameter_s(0, &shape, "p").unwrap();
            let comp = (p.clone() + p).unwrap().build().unwrap();
            let exe = client.compile(&comp).unwrap();
            let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
            let lit = MatArg::M(&m).to_literal().unwrap();
            let out =
                exe.execute::<xla::Literal>(&[lit]).unwrap()[0][0].to_literal_sync().unwrap();
            let v = out.to_vec::<f32>().unwrap();
            assert_eq!(v, vec![2., 4., 6., 8., 10., 12.]);
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    //! Fallback built when the `xla` crate is unavailable: same public API,
    //! but `Runtime::cpu` (and any executable run) fails with a clear error.

    use std::path::Path;
    use std::sync::Arc;

    use anyhow::{bail, Result};

    use crate::tensor::Mat;

    const UNAVAILABLE: &str =
        "PJRT runtime unavailable: built without the `pjrt` feature (vendored `xla` crate)";

    /// Fallback stand-in for a compiled PJRT executable.
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[MatArg]) -> Result<Mat> {
            bail!("{UNAVAILABLE}");
        }
    }

    /// An input argument: a matrix (2-D) or vector (1-D).
    pub enum MatArg<'a> {
        M(&'a Mat),
        V(&'a [f32]),
    }

    /// Fallback runtime: creation always fails, so callers take their
    /// native execution path.
    pub struct Runtime {
        _priv: (),
    }

    impl Runtime {
        pub fn cpu(_artifacts_root: &Path) -> Result<Runtime> {
            bail!("{UNAVAILABLE}");
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load(&self, rel_file: &str) -> Result<Arc<Executable>> {
            bail!("{UNAVAILABLE} (artifact {rel_file})");
        }

        pub fn cached_count(&self) -> usize {
            0
        }
    }
}

pub use imp::{Executable, MatArg, Runtime};
