//! Artifact registry: parses `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) into model configs + artifact file names, and
//! locates the artifacts directory for tests/benches/examples.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::model::config::ModelConfig;
use crate::util::json::Json;

/// One model's artifact set.
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    pub config: ModelConfig,
    pub weights: String,
    pub layer_fwd: String,
    pub lm_head: String,
    pub layer_fwd_bin: Option<String>,
    /// training loss curve (step, loss) recorded by the build
    pub loss_curve: Vec<(usize, f64)>,
}

/// Parsed manifest.
pub struct Artifacts {
    pub root: PathBuf,
    pub models: BTreeMap<String, ModelArtifacts>,
    pub kernels: Vec<KernelArtifact>,
}

#[derive(Clone, Debug)]
pub struct KernelArtifact {
    pub name: String,
    pub file: String,
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl Artifacts {
    /// Load from a directory containing `manifest.json`.
    pub fn load(root: &Path) -> Result<Artifacts> {
        let text = std::fs::read_to_string(root.join("manifest.json"))
            .with_context(|| format!("read {}/manifest.json — run `make artifacts`", root.display()))?;
        let j = Json::parse(&text).map_err(anyhow::Error::msg)?;
        let mut models = BTreeMap::new();
        for (name, entry) in j.get("models").and_then(|m| m.as_obj()).context("manifest: models")? {
            let config = ModelConfig::from_manifest(name, entry).map_err(anyhow::Error::msg)?;
            let get_s = |k: &str| -> Result<String> {
                Ok(entry.get(k).and_then(|v| v.as_str()).context(format!("{name}: {k}"))?.to_string())
            };
            let loss_curve = entry
                .get("loss_curve")
                .and_then(|v| v.as_arr())
                .map(|arr| {
                    arr.iter()
                        .filter_map(|p| {
                            let pair = p.as_arr()?;
                            Some((pair.first()?.as_usize()?, pair.get(1)?.as_f64()?))
                        })
                        .collect()
                })
                .unwrap_or_default();
            models.insert(
                name.clone(),
                ModelArtifacts {
                    config,
                    weights: get_s("weights")?,
                    layer_fwd: get_s("layer_fwd")?,
                    lm_head: get_s("lm_head")?,
                    layer_fwd_bin: entry
                        .get("layer_fwd_bin")
                        .and_then(|v| v.as_str())
                        .map(|s| s.to_string()),
                    loss_curve,
                },
            );
        }
        let mut kernels = Vec::new();
        if let Some(arr) = j.get("kernels").and_then(|k| k.as_arr()) {
            for k in arr {
                kernels.push(KernelArtifact {
                    name: k.get("name").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                    file: k.get("file").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                    m: k.get("m").and_then(|v| v.as_usize()).unwrap_or(0),
                    k: k.get("k").and_then(|v| v.as_usize()).unwrap_or(0),
                    n: k.get("n").and_then(|v| v.as_usize()).unwrap_or(0),
                });
            }
        }
        Ok(Artifacts { root: root.to_path_buf(), models, kernels })
    }

    /// Standard location: `$STBLLM_ARTIFACTS` or `<repo>/artifacts`.
    pub fn default_root() -> PathBuf {
        if let Ok(p) = std::env::var("STBLLM_ARTIFACTS") {
            return PathBuf::from(p);
        }
        // CARGO_MANIFEST_DIR works for tests/benches/examples; fall back to cwd
        let base = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
        Path::new(&base).join("artifacts")
    }

    pub fn load_default() -> Result<Artifacts> {
        Self::load(&Self::default_root())
    }

    /// Load a model's trained weights.
    pub fn load_weights(&self, name: &str) -> Result<crate::model::ModelWeights> {
        let ma = self.models.get(name).with_context(|| format!("unknown model {name}"))?;
        crate::model::ModelWeights::load(&ma.config, &self.root.join(&ma.weights))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("stbllm_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"models": {"llama1-7b": {"family": "llama", "dim": 128, "n_layers": 4,
                "ffn_hidden": 352, "vocab": 256, "seq_len": 128, "window": 0,
                "norm_eps": 1e-5, "seed": 101, "weights": "weights/llama1-7b.bin",
                "layer_fwd": "layer_fwd_llama1-7b.hlo.txt",
                "lm_head": "lm_head_llama1-7b.hlo.txt",
                "loss_curve": [[0, 5.5], [100, 3.2]]}},
              "kernels": [{"name": "g", "file": "g.hlo.txt", "m": 8, "k": 16, "n": 24}]}"#,
        )
        .unwrap();
        let a = Artifacts::load(&dir).unwrap();
        let m = &a.models["llama1-7b"];
        assert_eq!(m.config.dim, 128);
        assert_eq!(m.loss_curve, vec![(0, 5.5), (100, 3.2)]);
        assert_eq!(a.kernels[0].n, 24);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_is_helpful_error() {
        let err = match Artifacts::load(Path::new("/nonexistent")) {
            Ok(_) => panic!("expected error"),
            Err(e) => e,
        };
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
