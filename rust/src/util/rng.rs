//! PCG32 (XSH-RR) — bit-identical to `python/compile/rngcorpus.py`.
//!
//! The cross-language determinism is load-bearing: the Python build-time
//! trainer and the Rust run-time evaluator draw corpora from the *same*
//! stream (see `model::corpus`). The known-answer tests below are mirrored
//! in `python/tests/test_corpus.py`; if either side drifts, both suites fail.

const PCG_MULT: u64 = 6364136223846793005;

/// Minimal PCG32 generator (seed, stream) → u32 stream.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Construct from a seed and a stream id (must match the Python side).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Default stream (54) — convenience for non-corpus uses.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform-ish integer in `[0, n)`. Modulo bias accepted (matches Python).
    #[inline]
    pub fn bounded(&mut self, n: u32) -> u32 {
        self.next_u32() % n
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of entropy.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Approximate standard normal (Irwin–Hall sum of 12 uniforms).
    pub fn normal(&mut self) -> f32 {
        let mut s = 0.0f32;
        for _ in 0..12 {
            s += self.next_f32();
        }
        s - 6.0
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.bounded((i + 1) as u32) as usize;
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices out of `[0, n)` (partial Fisher–Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.bounded((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Mirrored in python/tests/test_corpus.py — DO NOT change one side only.
    #[test]
    fn pcg32_known_answers() {
        let mut r = Pcg32::new(42, 54);
        let got: Vec<u32> = (0..6).map(|_| r.next_u32()).collect();
        assert_eq!(
            got,
            vec![2707161783, 2068313097, 3122475824, 2211639955, 3215226955, 3421331566]
        );
    }

    #[test]
    fn pcg32_bounded_known_answers() {
        let mut r = Pcg32::new(7, 3);
        let got: Vec<u32> = (0..8).map(|_| r.bounded(100)).collect();
        assert_eq!(got, vec![51, 8, 72, 30, 99, 67, 36, 35]);
    }

    #[test]
    fn float_range_and_mean() {
        let mut r = Pcg32::seeded(9);
        let vals: Vec<f32> = (0..1000).map(|_| r.next_f32()).collect();
        assert!(vals.iter().all(|&v| (0.0..1.0).contains(&v)));
        let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
        assert!((0.4..0.6).contains(&mean));
    }

    #[test]
    fn normal_roughly_standard() {
        let mut r = Pcg32::seeded(11);
        let vals: Vec<f32> = (0..4000).map(|_| r.normal()).collect();
        let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
        let var: f32 = vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / vals.len() as f32;
        assert!(mean.abs() < 0.06, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn choose_k_distinct_in_range() {
        let mut r = Pcg32::seeded(5);
        let ks = r.choose_k(20, 8);
        assert_eq!(ks.len(), 8);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
        assert!(ks.iter().all(|&i| i < 20));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut s = xs.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<u32>>());
    }
}
