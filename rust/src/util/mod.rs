//! Offline-friendly utility substrate: RNG, JSON, CLI, timing, property tests.
//!
//! Nothing here depends on crates beyond std — the environment only vendors
//! `xla` + `anyhow`, so the conveniences usually pulled from clap / serde /
//! criterion / proptest live in this module instead.

pub mod artifact;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod timer;

/// Human-readable byte count.
pub fn fmt_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Render an aligned text table (used by the bench harness to print the
/// paper's tables).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        line.trim_end().to_string()
    };
    out.push_str(&fmt_row(headers.iter().map(|s| s.to_string()).collect(), &widths));
    out.push('\n');
    let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
    out.push_str(&fmt_row(sep, &widths));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row.clone(), &widths));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
    }

    #[test]
    fn table_alignment() {
        let t = render_table(
            &["Method", "PPL"],
            &[vec!["STBLLM".into(), "31.72".into()], vec!["BiLLM".into(), "688.73".into()]],
        );
        assert!(t.contains("| Method | PPL"));
        assert!(t.lines().count() == 4);
        let lens: Vec<usize> = t.lines().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{t}");
    }
}
