//! In-tree property-testing harness (proptest is unavailable offline).
//!
//! Seeded-random generation + N-case loops with failure reporting that
//! prints the case seed so a failure reproduces deterministically:
//!
//! ```ignore
//! prop_check("nm mask keeps exactly N per group", 200, |rng| {
//!     let n = 1 + rng.bounded(6) as usize;
//!     ...
//!     prop_assert!(cond, "context {n}");
//!     Ok(())
//! });
//! ```

use super::rng::Pcg32;

/// Outcome of a single property case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `f`, each seeded deterministically. Panics
/// with the failing seed + message on first failure.
pub fn prop_check<F: FnMut(&mut Pcg32) -> PropResult>(name: &str, cases: u64, mut f: F) {
    for case in 0..cases {
        // stable per-case seed so failures replay
        let mut rng = Pcg32::new(0x5781_0000 + case, 17);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {}): {msg}", 0x5781_0000u64 + case);
        }
    }
}

/// Assert inside a property; formats into the failure report.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!("assertion failed: {} [{}]", stringify!($cond), format!($($fmt)+)));
        }
    };
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> PropResult {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Random f32 vector in [-r, r].
pub fn gen_vec(rng: &mut Pcg32, n: usize, r: f32) -> Vec<f32> {
    (0..n).map(|_| rng.range_f32(-r, r)).collect()
}

/// Random normal-ish f32 vector.
pub fn gen_normal_vec(rng: &mut Pcg32, n: usize, std: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * std).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_check_passes_trivially() {
        prop_check("tautology", 50, |rng| {
            let x = rng.next_f32();
            prop_assert!((0.0..1.0).contains(&x), "x={x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn prop_check_reports_failure() {
        prop_check("always fails", 3, |_rng| Err("boom".to_string()));
    }

    #[test]
    fn close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0001], 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
