//! Wall-clock timing + summary statistics for the in-tree bench harness
//! (criterion is unavailable offline). `BenchStats` implements the usual
//! warmup → N samples → median/mean/p95 protocol.

use std::time::Instant;

/// Scoped stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Timing summary over repeated runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub samples_s: Vec<f64>,
}

impl BenchStats {
    /// Run `f` with `warmup` discarded iterations then `samples` timed ones.
    pub fn measure<F: FnMut()>(warmup: usize, samples: usize, mut f: F) -> BenchStats {
        for _ in 0..warmup {
            f();
        }
        let mut out = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t = Timer::start();
            f();
            out.push(t.elapsed_s());
        }
        BenchStats { samples_s: out }
    }

    pub fn mean_s(&self) -> f64 {
        self.samples_s.iter().sum::<f64>() / self.samples_s.len().max(1) as f64
    }

    pub fn median_s(&self) -> f64 {
        self.percentile_s(50.0)
    }

    pub fn p95_s(&self) -> f64 {
        self.percentile_s(95.0)
    }

    pub fn min_s(&self) -> f64 {
        self.samples_s.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Nearest-rank percentile via the shared `obs::percentile`
    /// implementation (`NaN` on an empty sample set, matching the old
    /// bench behavior; the shared function itself returns `0.0`).
    pub fn percentile_s(&self, p: f64) -> f64 {
        if self.samples_s.is_empty() {
            return f64::NAN;
        }
        let xs = crate::obs::percentile::sorted(self.samples_s.clone());
        crate::obs::percentile(&xs, p)
    }

    /// Throughput in ops/sec given `work` per run.
    pub fn throughput(&self, work: f64) -> f64 {
        work / self.median_s()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_moves_forward() {
        let t = Timer::start();
        let mut acc = 0u64;
        for i in 0..100_000u64 {
            acc = acc.wrapping_add(i * i);
        }
        std::hint::black_box(acc);
        assert!(t.elapsed_s() >= 0.0);
        assert!(t.elapsed_ms() >= t.elapsed_s());
    }

    #[test]
    fn stats_basics() {
        let s = BenchStats { samples_s: vec![1.0, 2.0, 3.0, 4.0, 100.0] };
        assert!((s.median_s() - 3.0).abs() < 1e-12);
        assert!((s.mean_s() - 22.0).abs() < 1e-12);
        assert_eq!(s.min_s(), 1.0);
        assert!(s.p95_s() >= s.median_s());
    }

    #[test]
    fn measure_runs() {
        let mut count = 0;
        let s = BenchStats::measure(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(s.samples_s.len(), 5);
        assert!(s.throughput(10.0) > 0.0);
    }
}
