//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Subcommand dispatch lives in `main.rs`; this module only tokenizes.

use std::collections::BTreeMap;

/// Single source of truth for CLI defaults — consumed both by the option
/// parsing in `main.rs` / `EngineBuilder` and by the generated help text,
/// so documentation and behavior cannot drift.
pub mod defaults {
    pub const MODEL: &str = "llama1-7b";
    pub const METHOD: &str = "stbllm";
    pub const BITS: usize = 1;
    pub const NM: &str = "4:8";
    pub const METRIC: &str = "si";
    pub const ALLOC: &str = "ours";
    pub const BLOCK_SIZE: usize = 128;
    pub const FRAC_SALIENT: f64 = 0.10;
    pub const CALIB_CORPUS: &str = "c4s";
    pub const EVAL_CORPUS: &str = "wikitext2s";
    pub const CALIB_TOKENS: usize = 512;
    pub const EVAL_TOKENS: usize = 1161;
    pub const SERVE_REQUESTS: usize = 8;
    pub const MAX_BATCH: usize = 4;
    pub const PROMPT_LEN: usize = 16;
    pub const MAX_NEW: usize = 16;
    pub const FLIP_RATIO: f64 = 0.05;
    pub const WORKERS: usize = 1;
    pub const SERVE_BACKEND: &str = "native";
    pub const EVAL_BACKEND: &str = "pjrt";
    /// KV pool size in pages for paged serving (0 = auto-size to
    /// `max_batch` worst-case sessions).
    pub const KV_PAGES: usize = 0;
    /// Token slots per KV page (must be a power of two).
    pub const PAGE_SIZE: usize = 16;
    /// Connection-handler threads for `serve --http`.
    pub const HTTP_THREADS: usize = 8;
    /// Keep-alive idle read timeout (ms) for `serve --http`.
    pub const HTTP_KEEPALIVE_MS: u64 = 1000;
    /// Decode replicas over the shared weights for `serve --http`.
    pub const REPLICAS: usize = 1;
    /// Concurrent connections for `stbllm loadgen`.
    pub const LOADGEN_CONNECTIONS: usize = 4;
    /// Total requests for `stbllm loadgen`.
    pub const LOADGEN_REQUESTS: usize = 16;
    /// Per-tick prefill-token budget per session for `serve`
    /// (`--prefill-chunk`; 1 = legacy one-token-per-tick).
    pub const PREFILL_CHUNK: usize = 32;
}

/// Parsed command-line arguments: options + positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `known_flags` disambiguates `--flag positional` from `--key value`.
    pub fn parse_with_flags<I: IntoIterator<Item = String>>(argv: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    out.flags.push(body.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse with no known boolean flags (`--key value` always pairs).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        Self::parse_with_flags(argv, &[])
    }

    /// Boolean flags used across the stbllm CLI / examples / benches.
    pub const COMMON_FLAGS: [&'static str; 14] = [
        "verbose",
        "fast",
        "full",
        "force",
        "help",
        "quiet",
        "native",
        "synthetic",
        "salient-aware",
        "smoke",
        "flat-kv",
        "drain",
        "metrics-check",
        "no-obs",
    ];

    pub fn from_env() -> Args {
        Self::parse_with_flags(std::env::args().skip(1), &Self::COMMON_FLAGS)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key) || self.get(key) == Some("true")
    }

    /// Comma-separated list option.
    pub fn get_list(&self, key: &str) -> Option<Vec<String>> {
        self.get(key).map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_with_flags(args.iter().map(|s| s.to_string()), &Args::COMMON_FLAGS)
    }

    #[test]
    fn parses_mixed() {
        let a = parse(&["quantize", "--model", "llama1-7b", "--nm=4:8", "--verbose", "out.bin"]);
        assert_eq!(a.positional, vec!["quantize", "out.bin"]);
        assert_eq!(a.get("model"), Some("llama1-7b"));
        assert_eq!(a.get("nm"), Some("4:8"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["--n", "42", "--ratio", "0.55"]);
        assert_eq!(a.get_usize("n", 0), 42);
        assert!((a.get_f64("ratio", 0.0) - 0.55).abs() < 1e-12);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn list_option() {
        let a = parse(&["--models", "a, b,c"]);
        assert_eq!(a.get_list("models").unwrap(), vec!["a", "b", "c"]);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
    }
}
