//! Artifact integrity substrate shared by the `.stbp` and `.stbw` binary
//! containers: CRC32 checksums, a bounds-checked byte reader, typed
//! corruption errors, and atomic (temp + fsync + rename) file writes.
//!
//! The loaders in [`crate::packed::store`] and [`crate::model::weights`]
//! parse untrusted bytes: every length field is validated against the
//! remaining file size BEFORE any allocation, so a corrupt header yields a
//! typed [`ArtifactError`] naming the entry and byte offset instead of an
//! OOM abort, and a flipped payload bit fails its entry checksum instead
//! of silently decoding to wrong weights.

use std::io::Write;
use std::path::Path;

/// CRC-32 (IEEE 802.3, polynomial `0xEDB88320`) lookup table, built at
/// compile time — no crates, no lazy init.
const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 of `data` (the common IEEE variant: init `!0`, final xor `!0`).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Typed corruption error for the binary artifact containers. Every
/// variant carries the byte offset where parsing failed and, when known,
/// the entry being parsed — the contract the chaos harness gates on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactError {
    /// The file does not start with the expected magic bytes.
    BadMagic {
        /// What the first bytes actually were.
        found: Vec<u8>,
        /// The magic this loader accepts.
        expected: &'static str,
    },
    /// The version field names a format this build cannot parse.
    UnsupportedVersion {
        /// Version read from the header.
        version: u32,
    },
    /// The file ended before a read completed.
    Truncated {
        /// Entry being parsed, when known.
        entry: Option<String>,
        /// Byte offset of the failed read.
        offset: usize,
        /// Bytes the read needed.
        needed: usize,
        /// Bytes actually remaining.
        have: usize,
    },
    /// An untrusted length field implies more bytes than the file holds —
    /// rejected before any allocation.
    BoundExceeded {
        /// Entry being parsed, when known.
        entry: Option<String>,
        /// Which length field lied.
        field: &'static str,
        /// The value it claimed.
        value: u64,
        /// Bytes remaining in the file at that point.
        remaining: usize,
        /// Byte offset of the field.
        offset: usize,
    },
    /// An entry's stored CRC32 does not match its bytes.
    EntryChecksum {
        /// Name of the corrupt entry.
        entry: String,
        /// Byte offset where the entry starts.
        offset: usize,
        /// CRC stored in the file.
        stored: u32,
        /// CRC computed over the entry bytes.
        computed: u32,
    },
    /// The whole-file checksum trailer does not match the file bytes.
    FileChecksum {
        /// CRC stored in the trailer.
        stored: u32,
        /// CRC computed over the file body.
        computed: u32,
    },
    /// A field parsed but its value is structurally invalid.
    Invalid {
        /// Entry being parsed, when known.
        entry: Option<String>,
        /// Byte offset of the bad field.
        offset: usize,
        /// What was wrong.
        what: String,
    },
    /// Bytes remain after the container's declared end.
    TrailingBytes {
        /// Offset where the container ended.
        offset: usize,
        /// Unclaimed bytes after it.
        extra: usize,
    },
}

impl std::fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        fn ent(e: &Option<String>) -> String {
            e.as_deref().map(|n| format!(" in entry {n:?}")).unwrap_or_default()
        }
        match self {
            ArtifactError::BadMagic { found, expected } => {
                write!(f, "bad magic {found:?} (expected {expected})")
            }
            ArtifactError::UnsupportedVersion { version } => {
                write!(f, "unsupported container version {version}")
            }
            ArtifactError::Truncated { entry, offset, needed, have } => write!(
                f,
                "truncated{} at offset {offset}: need {needed} bytes, {have} remain",
                ent(entry)
            ),
            ArtifactError::BoundExceeded { entry, field, value, remaining, offset } => write!(
                f,
                "corrupt {field}{} at offset {offset}: claims {value}, only {remaining} bytes remain",
                ent(entry)
            ),
            ArtifactError::EntryChecksum { entry, offset, stored, computed } => write!(
                f,
                "checksum mismatch in entry {entry:?} at offset {offset}: stored {stored:#010x}, computed {computed:#010x}",
            ),
            ArtifactError::FileChecksum { stored, computed } => write!(
                f,
                "whole-file checksum mismatch: stored {stored:#010x}, computed {computed:#010x}"
            ),
            ArtifactError::Invalid { entry, offset, what } => {
                write!(f, "invalid field{} at offset {offset}: {what}", ent(entry))
            }
            ArtifactError::TrailingBytes { offset, extra } => {
                write!(f, "{extra} trailing bytes after container end at offset {offset}")
            }
        }
    }
}

impl std::error::Error for ArtifactError {}

impl ArtifactError {
    /// The entry name the error points at, when it names one.
    pub fn entry(&self) -> Option<&str> {
        match self {
            ArtifactError::Truncated { entry, .. }
            | ArtifactError::BoundExceeded { entry, .. }
            | ArtifactError::Invalid { entry, .. } => entry.as_deref(),
            ArtifactError::EntryChecksum { entry, .. } => Some(entry.as_str()),
            _ => None,
        }
    }
}

/// Bounds-checked cursor over an untrusted byte buffer. Every read is
/// validated against the remaining length first; length fields go through
/// [`ByteReader::bounded_count`] so a lying header can never trigger a
/// huge allocation.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
    /// Entry currently being parsed — carried into every error.
    pub entry: Option<String>,
}

impl<'a> ByteReader<'a> {
    /// Cursor at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0, entry: None }
    }

    /// Current byte offset.
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// The slice already consumed (for checksumming parsed regions).
    pub fn consumed_since(&self, start: usize) -> &'a [u8] {
        &self.buf[start..self.pos]
    }

    /// Read `n` bytes or fail with a typed truncation error.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], ArtifactError> {
        if n > self.remaining() {
            return Err(ArtifactError::Truncated {
                entry: self.entry.clone(),
                offset: self.pos,
                needed: n,
                have: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8, ArtifactError> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian `u32`.
    pub fn u32(&mut self) -> Result<u32, ArtifactError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Validate an untrusted element count BEFORE allocating: `count`
    /// elements of `elem_bytes` each must fit in the remaining buffer.
    /// Returns the count as `usize` on success.
    pub fn bounded_count(
        &self,
        count: u64,
        elem_bytes: usize,
        field: &'static str,
    ) -> Result<usize, ArtifactError> {
        let need = count.saturating_mul(elem_bytes as u64);
        if need > self.remaining() as u64 {
            return Err(ArtifactError::BoundExceeded {
                entry: self.entry.clone(),
                field,
                value: count,
                remaining: self.remaining(),
                offset: self.pos,
            });
        }
        Ok(count as usize)
    }

    /// A typed `Invalid` error at the current offset.
    pub fn invalid(&self, what: impl Into<String>) -> ArtifactError {
        ArtifactError::Invalid { entry: self.entry.clone(), offset: self.pos, what: what.into() }
    }

    /// Fail unless the buffer is fully consumed.
    pub fn expect_end(&self) -> Result<(), ArtifactError> {
        if self.remaining() != 0 {
            return Err(ArtifactError::TrailingBytes { offset: self.pos, extra: self.remaining() });
        }
        Ok(())
    }
}

/// Crash-safe file write: the bytes land in a sibling temp file, are
/// fsynced, then renamed over `path` — a crash mid-save leaves either the
/// old artifact or the new one, never a torn half-write.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    let mut tmp = path.as_os_str().to_os_string();
    tmp.push(&format!(".tmp.{}", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp);
    let write = (|| {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(bytes)?;
        f.sync_all()?;
        std::fs::rename(&tmp, path)
    })();
    if write.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    write
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // standard IEEE CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_ne!(crc32(b"abc"), crc32(b"abd"));
    }

    #[test]
    fn reader_truncation_is_typed_with_offset() {
        let mut r = ByteReader::new(&[1, 2, 3]);
        r.entry = Some("wq".into());
        assert_eq!(r.u8().unwrap(), 1);
        match r.u32() {
            Err(ArtifactError::Truncated { entry, offset, needed, have }) => {
                assert_eq!(entry.as_deref(), Some("wq"));
                assert_eq!((offset, needed, have), (1, 4, 2));
            }
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn bounded_count_rejects_lying_lengths_without_allocating() {
        let buf = vec![0u8; 16];
        let r = ByteReader::new(&buf);
        // a corrupt header claiming u32::MAX elements must be rejected
        match r.bounded_count(u32::MAX as u64, 4, "name_len") {
            Err(ArtifactError::BoundExceeded { field, value, remaining, .. }) => {
                assert_eq!(field, "name_len");
                assert_eq!(value, u32::MAX as u64);
                assert_eq!(remaining, 16);
            }
            other => panic!("expected BoundExceeded, got {other:?}"),
        }
        // saturating_mul: count * elem_bytes overflowing u64 still rejects
        assert!(r.bounded_count(u64::MAX, 8, "dims").is_err());
        assert_eq!(r.bounded_count(4, 4, "alpha").unwrap(), 4);
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut r = ByteReader::new(&[9, 9]);
        r.u8().unwrap();
        match r.expect_end() {
            Err(ArtifactError::TrailingBytes { offset, extra }) => {
                assert_eq!((offset, extra), (1, 1));
            }
            other => panic!("expected TrailingBytes, got {other:?}"),
        }
    }

    #[test]
    fn atomic_write_replaces_and_leaves_no_temp() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("stb_atomic_{}.bin", std::process::id()));
        atomic_write(&path, b"first").unwrap();
        atomic_write(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        let stale: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().starts_with(&format!(
                    "stb_atomic_{}.bin.tmp",
                    std::process::id()
                ))
            })
            .collect();
        assert!(stale.is_empty(), "temp file left behind");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn errors_render_entry_and_offset() {
        let e = ArtifactError::EntryChecksum {
            entry: "layers.0.wq".into(),
            offset: 1234,
            stored: 1,
            computed: 2,
        };
        let msg = e.to_string();
        assert!(msg.contains("layers.0.wq"), "{msg}");
        assert!(msg.contains("1234"), "{msg}");
        assert_eq!(e.entry(), Some("layers.0.wq"));
    }
}
