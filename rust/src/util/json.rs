//! Minimal JSON parser + writer (serde is unavailable in this offline env).
//!
//! Supports the full JSON grammar the project needs: objects, arrays,
//! strings with escapes, numbers, bools, null. Used to read
//! `artifacts/manifest.json` (emitted by `python/compile/aot.py`) and to
//! write run reports / CSV-adjacent dumps.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- accessors -------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// Path accessor: `j.path(&["models", "llama1-7b", "dim"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // ---- writer ----------------------------------------------------------
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience builders.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek().ok_or("unexpected EOF")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or("EOF in string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or("EOF in escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("EOF in \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|e| e.to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                c => {
                    // copy raw utf-8 byte sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        txt.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{txt}': {e}"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n", "d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path(&["b", "c"]).unwrap().as_str().unwrap(), "hi\n");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -300.0);
        let dumped = v.dump();
        assert_eq!(Json::parse(&dumped).unwrap(), v);
    }

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"models": {"llama1-7b": {"dim": 128, "layer_weights": {"wq": [128, 128]}}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.path(&["models", "llama1-7b", "dim"]).unwrap().as_usize().unwrap(), 128);
        let sh = v.path(&["models", "llama1-7b", "layer_weights", "wq"]).unwrap().as_arr().unwrap();
        assert_eq!(sh.len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn builders_dump() {
        let v = obj(vec![("x", num(1.0)), ("y", arr(vec![s("a"), Json::Bool(false)]))]);
        assert_eq!(v.dump(), r#"{"x":1,"y":["a",false]}"#);
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#"{"k": "héllo ✓"}"#).unwrap();
        assert_eq!(v.get("k").unwrap().as_str().unwrap(), "héllo ✓");
    }
}
