//! Model configuration, parsed from `artifacts/manifest.json` (single source
//! of truth is `python/compile/model.py`). Presets are also mirrored here so
//! pure-Rust paths (unit tests, synthetic benches) can run without artifacts.

use crate::util::json::Json;

pub const HEAD_DIM: usize = 32;
pub const ROPE_THETA: f32 = 10000.0;

/// Architecture family — scaled-down analogues of the paper's model zoo.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Llama,
    Opt,
    Mistral,
}

impl Family {
    pub fn parse(s: &str) -> Option<Family> {
        match s {
            "llama" => Some(Family::Llama),
            "opt" => Some(Family::Opt),
            "mistral" => Some(Family::Mistral),
            _ => None,
        }
    }
    pub fn as_str(&self) -> &'static str {
        match self {
            Family::Llama => "llama",
            Family::Opt => "opt",
            Family::Mistral => "mistral",
        }
    }
}

/// Static model hyperparameters (mirrors `python/compile/model.py::ModelConfig`).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub family: Family,
    pub dim: usize,
    pub n_layers: usize,
    pub ffn_hidden: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub window: usize,
    pub norm_eps: f32,
    pub seed: u64,
}

impl ModelConfig {
    pub fn n_heads(&self) -> usize {
        self.dim / HEAD_DIM
    }

    /// Canonical names of the 2-D quantizable matrices (order matters: it is
    /// the artifact parameter order).
    pub fn layer_weight_names(&self) -> Vec<&'static str> {
        match self.family {
            Family::Opt => vec!["wq", "wk", "wv", "wo", "w1", "w2"],
            _ => vec!["wq", "wk", "wv", "wo", "w1", "w2", "w3"],
        }
    }

    /// (out, in) shape of a named layer weight.
    pub fn layer_weight_shape(&self, name: &str) -> (usize, usize) {
        let (d, h) = (self.dim, self.ffn_hidden);
        match name {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "w1" | "w3" => (h, d),
            "w2" => (d, h),
            _ => panic!("unknown layer weight {name}"),
        }
    }

    pub fn n_params(&self) -> usize {
        let per_layer: usize = self
            .layer_weight_names()
            .iter()
            .map(|n| {
                let (o, i) = self.layer_weight_shape(n);
                o * i
            })
            .sum::<usize>()
            + 2 * self.dim;
        let mut extra = self.vocab * self.dim + self.dim;
        if self.family == Family::Opt {
            extra += self.seq_len * self.dim;
        }
        per_layer * self.n_layers + extra
    }

    /// Parse one entry of `manifest.json["models"]`.
    pub fn from_manifest(name: &str, j: &Json) -> Result<ModelConfig, String> {
        let family = Family::parse(
            j.get("family").and_then(|v| v.as_str()).ok_or("missing family")?,
        )
        .ok_or("bad family")?;
        let get = |k: &str| -> Result<usize, String> {
            j.get(k).and_then(|v| v.as_usize()).ok_or(format!("missing {k}"))
        };
        Ok(ModelConfig {
            name: name.to_string(),
            family,
            dim: get("dim")?,
            n_layers: get("n_layers")?,
            ffn_hidden: get("ffn_hidden")?,
            vocab: get("vocab")?,
            seq_len: get("seq_len")?,
            window: get("window")?,
            norm_eps: j.get("norm_eps").and_then(|v| v.as_f64()).unwrap_or(1e-5) as f32,
            seed: get("seed")? as u64,
        })
    }

    /// Built-in presets (mirror of the Python PRESETS table) for paths that
    /// must run without artifacts.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        let mk = |name: &str, family: Family, dim, n_layers, ffn, window, seed| ModelConfig {
            name: name.to_string(),
            family,
            dim,
            n_layers,
            ffn_hidden: ffn,
            vocab: 256,
            seq_len: 128,
            window,
            norm_eps: 1e-5,
            seed,
        };
        Some(match name {
            "llama1-7b" => mk(name, Family::Llama, 128, 4, 352, 0, 101),
            "llama1-13b" => mk(name, Family::Llama, 192, 6, 512, 0, 102),
            "llama1-30b" => mk(name, Family::Llama, 256, 8, 704, 0, 103),
            "llama1-65b" => mk(name, Family::Llama, 320, 10, 864, 0, 104),
            "llama2-7b" => mk(name, Family::Llama, 128, 4, 384, 0, 201),
            "llama2-13b" => mk(name, Family::Llama, 192, 6, 544, 0, 202),
            "llama3-8b" => mk(name, Family::Llama, 160, 5, 448, 0, 301),
            "opt-1.3b" => mk(name, Family::Opt, 128, 4, 512, 0, 401),
            "opt-2.7b" => mk(name, Family::Opt, 160, 5, 640, 0, 402),
            "opt-6.7b" => mk(name, Family::Opt, 192, 6, 768, 0, 403),
            "opt-30b" => mk(name, Family::Opt, 256, 8, 1024, 0, 404),
            "mistral-7b" => mk(name, Family::Mistral, 192, 6, 512, 64, 501),
            _ => return None,
        })
    }

    pub fn preset_names() -> Vec<&'static str> {
        vec![
            "llama1-7b", "llama1-13b", "llama1-30b", "llama1-65b", "llama2-7b",
            "llama2-13b", "llama3-8b", "opt-1.3b", "opt-2.7b", "opt-6.7b", "opt-30b",
            "mistral-7b",
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_internally_consistent() {
        for name in ModelConfig::preset_names() {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.dim % HEAD_DIM, 0, "{name}");
            for w in c.layer_weight_names() {
                let (o, i) = c.layer_weight_shape(w);
                assert_eq!(o % 8, 0, "{name}.{w}");
                assert_eq!(i % 8, 0, "{name}.{w}");
            }
            assert!(c.n_params() > 0);
        }
    }

    #[test]
    fn manifest_parse() {
        let j = Json::parse(
            r#"{"family": "llama", "dim": 128, "n_layers": 4, "ffn_hidden": 352,
                "vocab": 256, "seq_len": 128, "window": 0, "norm_eps": 1e-5, "seed": 101}"#,
        )
        .unwrap();
        let c = ModelConfig::from_manifest("llama1-7b", &j).unwrap();
        assert_eq!(c.dim, 128);
        assert_eq!(c.n_heads(), 4);
        assert_eq!(c.layer_weight_shape("w1"), (352, 128));
        // matches the preset mirror
        let p = ModelConfig::preset("llama1-7b").unwrap();
        assert_eq!(p.n_params(), c.n_params());
    }

    #[test]
    fn opt_has_six_weights_llama_seven() {
        assert_eq!(ModelConfig::preset("opt-1.3b").unwrap().layer_weight_names().len(), 6);
        assert_eq!(ModelConfig::preset("llama1-7b").unwrap().layer_weight_names().len(), 7);
    }
}
