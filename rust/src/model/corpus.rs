//! Synthetic corpora — bit-identical port of `python/compile/rngcorpus.py`.
//!
//! Three Markov-chain corpora stand in for the paper's eval sets (see
//! DESIGN.md §2): `wikitext2s` (clean prose-like), `c4s` (noisy web-like),
//! `ptbs` (short-sentence newswire-like). All-integer construction keeps
//! the Rust and Python streams identical for equal seeds; known-answer
//! tests are mirrored in `python/tests/test_corpus.py`.

use crate::util::rng::Pcg32;

/// Static description of a corpus distribution.
#[derive(Clone, Copy, Debug)]
pub struct CorpusSpec {
    pub name: &'static str,
    pub seed: u64,
    pub alphabet: u32,
    pub order: u32,
    pub candidates: usize,
    pub reset_every: u32,
}

pub const WIKITEXT2S: CorpusSpec =
    CorpusSpec { name: "wikitext2s", seed: 11, alphabet: 64, order: 2, candidates: 4, reset_every: 0 };
pub const C4S: CorpusSpec =
    CorpusSpec { name: "c4s", seed: 22, alphabet: 96, order: 1, candidates: 8, reset_every: 0 };
pub const PTBS: CorpusSpec =
    CorpusSpec { name: "ptbs", seed: 33, alphabet: 32, order: 2, candidates: 3, reset_every: 24 };

pub const ALL: [CorpusSpec; 3] = [WIKITEXT2S, C4S, PTBS];

pub fn spec_by_name(name: &str) -> Option<CorpusSpec> {
    ALL.iter().copied().find(|s| s.name == name)
}

/// Instantiated Markov chain with integer transition tables.
pub struct Corpus {
    spec: CorpusSpec,
    succ: Vec<Vec<u32>>,
    weights: Vec<u32>,
    total_w: u32,
}

impl Corpus {
    pub fn new(spec: CorpusSpec) -> Corpus {
        let mut rng = Pcg32::new(spec.seed, 7);
        let a = spec.alphabet;
        let k = spec.candidates;
        let n_ctx = if spec.order == 2 { (a * a) as usize } else { a as usize };
        let weights: Vec<u32> = (0..k).map(|i| 1000 / (i as u32 + 1)).collect();
        let total_w = weights.iter().sum();
        let mut succ = Vec::with_capacity(n_ctx);
        for _ in 0..n_ctx {
            succ.push((0..k).map(|_| rng.bounded(a)).collect());
        }
        Corpus { spec, succ, weights, total_w }
    }

    /// Generate `n` tokens; sampling RNG is independent of the table RNG.
    pub fn generate(&self, n: usize, seed: u64) -> Vec<u8> {
        let spec = self.spec;
        let mut rng = Pcg32::new(seed, 13);
        let a = spec.alphabet;
        let mut prev1 = rng.bounded(a);
        let mut prev2 = rng.bounded(a);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if spec.reset_every != 0 && rng.bounded(spec.reset_every) == 0 {
                out.push(0u8);
                prev1 = rng.bounded(a);
                prev2 = rng.bounded(a);
                continue;
            }
            let ctx = if spec.order == 2 { (prev1 * a + prev2) as usize } else { prev2 as usize };
            let r = rng.bounded(self.total_w);
            let mut acc = 0u32;
            let cands = &self.succ[ctx];
            let mut nxt = *cands.last().unwrap();
            for (cand, w) in cands.iter().zip(&self.weights) {
                acc += w;
                if r < acc {
                    nxt = *cand;
                    break;
                }
            }
            out.push(nxt as u8);
            prev1 = prev2;
            prev2 = nxt;
        }
        out
    }
}

/// Convenience: build the named corpus and generate `n` tokens.
pub fn corpus_tokens(name: &str, n: usize, seed: u64) -> Vec<u8> {
    Corpus::new(spec_by_name(name).unwrap_or_else(|| panic!("unknown corpus {name}"))).generate(n, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Mirrored in python/tests/test_corpus.py — cross-language lock.
    #[test]
    fn corpus_known_answers() {
        assert_eq!(corpus_tokens("wikitext2s", 12, 5), vec![17, 47, 15, 33, 62, 63, 36, 2, 32, 59, 49, 17]);
        assert_eq!(corpus_tokens("c4s", 12, 5), vec![55, 20, 82, 30, 37, 29, 31, 18, 38, 49, 95, 32]);
        assert_eq!(corpus_tokens("ptbs", 12, 5), vec![8, 25, 27, 8, 29, 15, 23, 8, 20, 24, 2, 17]);
    }

    #[test]
    fn alphabet_bounds() {
        for spec in ALL {
            let toks = corpus_tokens(spec.name, 2000, 9);
            assert!(toks.iter().all(|&t| (t as u32) < spec.alphabet), "{}", spec.name);
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = corpus_tokens("c4s", 256, 1);
        let b = corpus_tokens("c4s", 256, 1);
        let c = corpus_tokens("c4s", 256, 2);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn ptbs_resets() {
        let toks = corpus_tokens("ptbs", 4000, 4);
        let zeros = toks.iter().filter(|&&t| t == 0).count();
        assert!(zeros as f64 / toks.len() as f64 > 0.02);
    }

    #[test]
    fn distributions_distinct() {
        let hist = |name: &str| -> Vec<f64> {
            let toks = corpus_tokens(name, 8000, 3);
            let mut h = vec![0f64; 256];
            for t in toks {
                h[t as usize] += 1.0;
            }
            let s: f64 = h.iter().sum();
            h.iter().map(|x| x / s).collect()
        };
        let tv = |a: &[f64], b: &[f64]| -> f64 {
            0.5 * a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>()
        };
        let (w, c, p) = (hist("wikitext2s"), hist("c4s"), hist("ptbs"));
        assert!(tv(&w, &c) > 0.2);
        assert!(tv(&w, &p) > 0.2);
    }
}
