//! Model weights: STBW binary loader (format written by
//! `python/compile/train.py::save_weights`), in-memory layout, and synthetic
//! initialization for artifact-free paths (unit tests, pure benches).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use crate::model::config::{Family, ModelConfig};
use crate::tensor::Mat;
use crate::util::rng::Pcg32;

/// One transformer block's parameters.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    /// 2-D quantizable matrices by canonical name (wq..w3), each (out, in).
    pub mats: BTreeMap<String, Mat>,
}

/// Full model parameters.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub embed: Mat,        // (vocab, dim)
    pub ln_f: Vec<f32>,    // (dim,)
    pub pos: Option<Mat>,  // (seq_len, dim), OPT family only
    pub layers: Vec<LayerWeights>,
}

impl ModelWeights {
    /// Parse the STBW container:
    /// magic "STBW" | u32 n | per tensor: u32 name_len | name | u32 ndim |
    /// u32 dims... | f32 LE data.
    pub fn load(cfg: &ModelConfig, path: &Path) -> anyhow::Result<ModelWeights> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        let named = parse_stbw(&buf).map_err(anyhow::Error::msg)?;
        Self::from_named(cfg, &named).map_err(anyhow::Error::msg)
    }

    pub fn from_named(
        cfg: &ModelConfig,
        named: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    ) -> Result<ModelWeights, String> {
        let get = |name: &str| -> Result<&(Vec<usize>, Vec<f32>), String> {
            named.get(name).ok_or(format!("missing tensor {name}"))
        };
        let mat = |name: &str| -> Result<Mat, String> {
            let (dims, data) = get(name)?;
            if dims.len() != 2 {
                return Err(format!("{name}: expected 2-D, got {dims:?}"));
            }
            Ok(Mat::from_vec(dims[0], dims[1], data.clone()))
        };
        let vec1 = |name: &str| -> Result<Vec<f32>, String> { Ok(get(name)?.1.clone()) };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let mut mats = BTreeMap::new();
            for n in cfg.layer_weight_names() {
                let m = mat(&format!("layers.{i}.{n}"))?;
                let want = cfg.layer_weight_shape(n);
                if (m.rows, m.cols) != want {
                    return Err(format!("layers.{i}.{n}: shape {:?} != {:?}", (m.rows, m.cols), want));
                }
                mats.insert(n.to_string(), m);
            }
            layers.push(LayerWeights {
                ln1: vec1(&format!("layers.{i}.ln1"))?,
                ln2: vec1(&format!("layers.{i}.ln2"))?,
                mats,
            });
        }
        Ok(ModelWeights {
            embed: mat("embed")?,
            ln_f: vec1("ln_f")?,
            pos: if cfg.family == Family::Opt { Some(mat("pos")?) } else { None },
            layers,
        })
    }

    /// Synthetic weights with the same init distribution as the Python side
    /// (matching *distribution*, not bits — used by artifact-free tests).
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Pcg32::seeded(seed);
        let d = cfg.dim;
        let proj = 1.0 / (d as f32).sqrt();
        let out_s = proj / (2.0 * cfg.n_layers as f32).sqrt();
        let mut layers = Vec::new();
        for _ in 0..cfg.n_layers {
            let mut mats = BTreeMap::new();
            for n in cfg.layer_weight_names() {
                let (o, i) = cfg.layer_weight_shape(n);
                let s = if n == "wo" || n == "w2" { out_s } else { proj };
                mats.insert(n.to_string(), Mat::random(o, i, s, &mut rng));
            }
            layers.push(LayerWeights { ln1: vec![1.0; d], ln2: vec![1.0; d], mats });
        }
        ModelWeights {
            embed: Mat::random(cfg.vocab, d, 0.02, &mut rng),
            ln_f: vec![1.0; d],
            pos: (cfg.family == Family::Opt).then(|| Mat::random(cfg.seq_len, d, 0.02, &mut rng)),
            layers,
        }
    }

    /// Total parameter count (must agree with `ModelConfig::n_params`).
    pub fn n_params(&self) -> usize {
        let mut n = self.embed.data.len() + self.ln_f.len();
        if let Some(p) = &self.pos {
            n += p.data.len();
        }
        for l in &self.layers {
            n += l.ln1.len() + l.ln2.len();
            n += l.mats.values().map(|m| m.data.len()).sum::<usize>();
        }
        n
    }
}

fn parse_stbw(buf: &[u8]) -> Result<BTreeMap<String, (Vec<usize>, Vec<f32>)>, String> {
    let mut p = 0usize;
    let take = |p: &mut usize, n: usize| -> Result<&[u8], String> {
        if *p + n > buf.len() {
            return Err("truncated STBW file".into());
        }
        let s = &buf[*p..*p + n];
        *p += n;
        Ok(s)
    };
    let read_u32 = |p: &mut usize| -> Result<u32, String> {
        let b = take(p, 4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    };
    if take(&mut p, 4)? != b"STBW" {
        return Err("bad magic (expected STBW)".into());
    }
    let n = read_u32(&mut p)? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let name_len = read_u32(&mut p)? as usize;
        let name = String::from_utf8(take(&mut p, name_len)?.to_vec()).map_err(|e| e.to_string())?;
        let ndim = read_u32(&mut p)? as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(read_u32(&mut p)? as usize);
        }
        let count: usize = dims.iter().product::<usize>().max(1);
        let raw = take(&mut p, 4 * count)?;
        let data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, (dims, data));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_stbw(tensors: &[(&str, Vec<usize>, Vec<f32>)]) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"STBW");
        buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in dims {
                buf.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        buf
    }

    #[test]
    fn stbw_roundtrip() {
        let buf = write_stbw(&[
            ("a", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            ("b.c", vec![2], vec![-1.5, 0.25]),
        ]);
        let named = parse_stbw(&buf).unwrap();
        assert_eq!(named["a"].0, vec![2, 3]);
        assert_eq!(named["a"].1[4], 5.0);
        assert_eq!(named["b.c"].1, vec![-1.5, 0.25]);
    }

    #[test]
    fn stbw_rejects_bad_magic_and_truncation() {
        assert!(parse_stbw(b"NOPE").is_err());
        let mut buf = write_stbw(&[("a", vec![4], vec![1., 2., 3., 4.])]);
        buf.truncate(buf.len() - 3);
        assert!(parse_stbw(&buf).is_err());
    }

    #[test]
    fn synthetic_matches_config_param_count() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let cfg = ModelConfig::preset(name).unwrap();
            let w = ModelWeights::synthetic(&cfg, 1);
            assert_eq!(w.n_params(), cfg.n_params(), "{name}");
        }
    }

    #[test]
    fn from_named_validates_shapes() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 2);
        // serialize by hand into the named map with a WRONG shape for wq
        let mut named: BTreeMap<String, (Vec<usize>, Vec<f32>)> = BTreeMap::new();
        named.insert("embed".into(), (vec![cfg.vocab, cfg.dim], w.embed.data.clone()));
        named.insert("ln_f".into(), (vec![cfg.dim], w.ln_f.clone()));
        for i in 0..cfg.n_layers {
            named.insert(format!("layers.{i}.ln1"), (vec![cfg.dim], w.layers[i].ln1.clone()));
            named.insert(format!("layers.{i}.ln2"), (vec![cfg.dim], w.layers[i].ln2.clone()));
            for n in cfg.layer_weight_names() {
                let m = &w.layers[i].mats[n];
                named.insert(format!("layers.{i}.{n}"), (vec![m.rows, m.cols], m.data.clone()));
            }
        }
        assert!(ModelWeights::from_named(&cfg, &named).is_ok());
        let bad = (vec![7usize, 7], vec![0.0f32; 49]);
        named.insert("layers.0.wq".into(), bad);
        assert!(ModelWeights::from_named(&cfg, &named).is_err());
    }
}
