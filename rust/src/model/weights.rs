//! Model weights: STBW binary loader (format written by
//! `python/compile/train.py::save_weights`), in-memory layout, and synthetic
//! initialization for artifact-free paths (unit tests, pure benches).
//!
//! Two container flavors parse here: legacy `"STBW"` (what the Python side
//! writes — no checksums) and `"SBW2"` (what [`ModelWeights::save`] writes —
//! per-tensor CRC32 plus a whole-file trailer, saved atomically). Both paths
//! bound every untrusted length field against the remaining file size before
//! allocating, so a corrupt header is a typed [`ArtifactError`], not an OOM.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use crate::model::config::{Family, ModelConfig};
use crate::tensor::Mat;
use crate::util::artifact::{atomic_write, crc32, ArtifactError, ByteReader};
use crate::util::rng::Pcg32;

/// One transformer block's parameters.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
    /// 2-D quantizable matrices by canonical name (wq..w3), each (out, in).
    pub mats: BTreeMap<String, Mat>,
}

/// Full model parameters.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub embed: Mat,        // (vocab, dim)
    pub ln_f: Vec<f32>,    // (dim,)
    pub pos: Option<Mat>,  // (seq_len, dim), OPT family only
    pub layers: Vec<LayerWeights>,
}

impl ModelWeights {
    /// Parse the STBW container:
    /// magic "STBW" | u32 n | per tensor: u32 name_len | name | u32 ndim |
    /// u32 dims... | f32 LE data. The checksummed "SBW2" flavor is accepted
    /// too (see [`parse_stbw`]).
    pub fn load(cfg: &ModelConfig, path: &Path) -> anyhow::Result<ModelWeights> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        let named = parse_stbw(&buf)?;
        Self::from_named(cfg, &named).map_err(anyhow::Error::msg)
    }

    /// Flatten into the named-tensor map the containers serialize.
    pub fn to_named(&self) -> BTreeMap<String, (Vec<usize>, Vec<f32>)> {
        let mut named: BTreeMap<String, (Vec<usize>, Vec<f32>)> = BTreeMap::new();
        named.insert("embed".into(), (vec![self.embed.rows, self.embed.cols], self.embed.data.clone()));
        named.insert("ln_f".into(), (vec![self.ln_f.len()], self.ln_f.clone()));
        if let Some(p) = &self.pos {
            named.insert("pos".into(), (vec![p.rows, p.cols], p.data.clone()));
        }
        for (i, l) in self.layers.iter().enumerate() {
            named.insert(format!("layers.{i}.ln1"), (vec![l.ln1.len()], l.ln1.clone()));
            named.insert(format!("layers.{i}.ln2"), (vec![l.ln2.len()], l.ln2.clone()));
            for (n, m) in &l.mats {
                named.insert(format!("layers.{i}.{n}"), (vec![m.rows, m.cols], m.data.clone()));
            }
        }
        named
    }

    /// Write the checksummed "SBW2" container atomically (temp + fsync +
    /// rename): per-tensor CRC32 after each entry, whole-file CRC32 trailer.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let bytes = encode_sbw2(&self.to_named());
        atomic_write(path, &bytes)
            .map_err(|e| anyhow::Error::msg(format!("save {}: {e}", path.display())))?;
        Ok(())
    }

    pub fn from_named(
        cfg: &ModelConfig,
        named: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    ) -> Result<ModelWeights, String> {
        let get = |name: &str| -> Result<&(Vec<usize>, Vec<f32>), String> {
            named.get(name).ok_or(format!("missing tensor {name}"))
        };
        let mat = |name: &str| -> Result<Mat, String> {
            let (dims, data) = get(name)?;
            if dims.len() != 2 {
                return Err(format!("{name}: expected 2-D, got {dims:?}"));
            }
            Ok(Mat::from_vec(dims[0], dims[1], data.clone()))
        };
        let vec1 = |name: &str| -> Result<Vec<f32>, String> { Ok(get(name)?.1.clone()) };

        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let mut mats = BTreeMap::new();
            for n in cfg.layer_weight_names() {
                let m = mat(&format!("layers.{i}.{n}"))?;
                let want = cfg.layer_weight_shape(n);
                if (m.rows, m.cols) != want {
                    return Err(format!("layers.{i}.{n}: shape {:?} != {:?}", (m.rows, m.cols), want));
                }
                mats.insert(n.to_string(), m);
            }
            layers.push(LayerWeights {
                ln1: vec1(&format!("layers.{i}.ln1"))?,
                ln2: vec1(&format!("layers.{i}.ln2"))?,
                mats,
            });
        }
        Ok(ModelWeights {
            embed: mat("embed")?,
            ln_f: vec1("ln_f")?,
            pos: if cfg.family == Family::Opt { Some(mat("pos")?) } else { None },
            layers,
        })
    }

    /// Synthetic weights with the same init distribution as the Python side
    /// (matching *distribution*, not bits — used by artifact-free tests).
    pub fn synthetic(cfg: &ModelConfig, seed: u64) -> ModelWeights {
        let mut rng = Pcg32::seeded(seed);
        let d = cfg.dim;
        let proj = 1.0 / (d as f32).sqrt();
        let out_s = proj / (2.0 * cfg.n_layers as f32).sqrt();
        let mut layers = Vec::new();
        for _ in 0..cfg.n_layers {
            let mut mats = BTreeMap::new();
            for n in cfg.layer_weight_names() {
                let (o, i) = cfg.layer_weight_shape(n);
                let s = if n == "wo" || n == "w2" { out_s } else { proj };
                mats.insert(n.to_string(), Mat::random(o, i, s, &mut rng));
            }
            layers.push(LayerWeights { ln1: vec![1.0; d], ln2: vec![1.0; d], mats });
        }
        ModelWeights {
            embed: Mat::random(cfg.vocab, d, 0.02, &mut rng),
            ln_f: vec![1.0; d],
            pos: (cfg.family == Family::Opt).then(|| Mat::random(cfg.seq_len, d, 0.02, &mut rng)),
            layers,
        }
    }

    /// Total parameter count (must agree with `ModelConfig::n_params`).
    pub fn n_params(&self) -> usize {
        let mut n = self.embed.data.len() + self.ln_f.len();
        if let Some(p) = &self.pos {
            n += p.data.len();
        }
        for l in &self.layers {
            n += l.ln1.len() + l.ln2.len();
            n += l.mats.values().map(|m| m.data.len()).sum::<usize>();
        }
        n
    }
}

/// Serialize a named-tensor map as the checksummed "SBW2" container:
/// magic "SBW2" | u32 n | per tensor: entry bytes (u32 name_len | name |
/// u32 ndim | dims | f32 data) followed by u32 crc32(entry bytes) | final
/// u32 crc32 over everything before it.
pub fn encode_sbw2(named: &BTreeMap<String, (Vec<usize>, Vec<f32>)>) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"SBW2");
    out.extend_from_slice(&(named.len() as u32).to_le_bytes());
    let mut entry = Vec::new();
    for (name, (dims, data)) in named {
        entry.clear();
        entry.extend_from_slice(&(name.len() as u32).to_le_bytes());
        entry.extend_from_slice(name.as_bytes());
        entry.extend_from_slice(&(dims.len() as u32).to_le_bytes());
        for d in dims {
            entry.extend_from_slice(&(*d as u32).to_le_bytes());
        }
        for v in data {
            entry.extend_from_slice(&v.to_le_bytes());
        }
        let crc = crc32(&entry);
        out.extend_from_slice(&entry);
        out.extend_from_slice(&crc.to_le_bytes());
    }
    let file_crc = crc32(&out);
    out.extend_from_slice(&file_crc.to_le_bytes());
    out
}

/// Parse a weights container from untrusted bytes. Dispatches on magic:
/// legacy `"STBW"` (no checksums, what the Python exporter writes) or
/// `"SBW2"` (per-entry + whole-file CRC32). Every length field is bounded
/// against the remaining file size before allocation; corruption yields a
/// typed [`ArtifactError`] naming the tensor and byte offset.
pub fn parse_stbw(buf: &[u8]) -> Result<BTreeMap<String, (Vec<usize>, Vec<f32>)>, ArtifactError> {
    let mut r = ByteReader::new(buf);
    let magic = r.take(4)?;
    let checksummed = match magic {
        b"STBW" => false,
        b"SBW2" => true,
        other => {
            return Err(ArtifactError::BadMagic { found: other.to_vec(), expected: "STBW|SBW2" })
        }
    };
    let raw_n = r.u32()?;
    let n = r.bounded_count(raw_n as u64, 8, "tensor count")?; // name_len + ndim floor
    let mut out = BTreeMap::new();
    for _ in 0..n {
        let entry_start = r.pos();
        let raw_nl = r.u32()?;
        let name_len = r.bounded_count(raw_nl as u64, 1, "name_len")?;
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .map_err(|_| r.invalid("tensor name is not utf-8"))?;
        r.entry = Some(name.clone());
        let raw_ndim = r.u32()?;
        let ndim = r.bounded_count(raw_ndim as u64, 4, "ndim")?;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(r.u32()? as usize);
        }
        let count: u64 = dims.iter().map(|&d| d as u64).fold(1u64, u64::saturating_mul).max(1);
        let n_vals = r.bounded_count(count, 4, "tensor data")?;
        let data: Vec<f32> = r
            .take(4 * n_vals)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        if checksummed {
            let computed = crc32(r.consumed_since(entry_start));
            let stored = r.u32()?;
            if stored != computed {
                return Err(ArtifactError::EntryChecksum {
                    entry: name.clone(),
                    offset: entry_start,
                    stored,
                    computed,
                });
            }
        }
        r.entry = None;
        out.insert(name, (dims, data));
    }
    if checksummed {
        let computed = crc32(r.consumed_since(0));
        let stored = r.u32()?;
        if stored != computed {
            return Err(ArtifactError::FileChecksum { stored, computed });
        }
    }
    r.expect_end()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_stbw(tensors: &[(&str, Vec<usize>, Vec<f32>)]) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"STBW");
        buf.extend_from_slice(&(tensors.len() as u32).to_le_bytes());
        for (name, dims, data) in tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(dims.len() as u32).to_le_bytes());
            for d in dims {
                buf.extend_from_slice(&(*d as u32).to_le_bytes());
            }
            for v in data {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        buf
    }

    #[test]
    fn stbw_roundtrip() {
        let buf = write_stbw(&[
            ("a", vec![2, 3], vec![1., 2., 3., 4., 5., 6.]),
            ("b.c", vec![2], vec![-1.5, 0.25]),
        ]);
        let named = parse_stbw(&buf).unwrap();
        assert_eq!(named["a"].0, vec![2, 3]);
        assert_eq!(named["a"].1[4], 5.0);
        assert_eq!(named["b.c"].1, vec![-1.5, 0.25]);
    }

    #[test]
    fn stbw_rejects_bad_magic_and_truncation() {
        match parse_stbw(b"NOPE") {
            Err(ArtifactError::BadMagic { .. }) => {}
            other => panic!("expected BadMagic, got {other:?}"),
        }
        let mut buf = write_stbw(&[("a", vec![4], vec![1., 2., 3., 4.])]);
        buf.truncate(buf.len() - 3);
        match parse_stbw(&buf) {
            Err(ArtifactError::Truncated { entry, .. }) => {
                assert_eq!(entry.as_deref(), Some("a"));
            }
            other => panic!("expected Truncated naming the tensor, got {other:?}"),
        }
    }

    #[test]
    fn stbw_bounds_lying_lengths_without_alloc() {
        // legacy header claiming u32::MAX dims: typed BoundExceeded, no OOM
        let mut buf = Vec::new();
        buf.extend_from_slice(b"STBW");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes()); // name_len 1
        buf.push(b'a');
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // ndim lie
        match parse_stbw(&buf) {
            Err(ArtifactError::BoundExceeded { field, entry, .. }) => {
                assert_eq!(field, "ndim");
                assert_eq!(entry.as_deref(), Some("a"));
            }
            other => panic!("expected BoundExceeded, got {other:?}"),
        }
        // dims whose product saturates u64 must also be rejected
        let mut buf = Vec::new();
        buf.extend_from_slice(b"STBW");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.push(b'b');
        buf.extend_from_slice(&4u32.to_le_bytes()); // ndim 4
        for _ in 0..4 {
            buf.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        match parse_stbw(&buf) {
            Err(ArtifactError::BoundExceeded { field, .. }) => assert_eq!(field, "tensor data"),
            other => panic!("expected BoundExceeded, got {other:?}"),
        }
    }

    #[test]
    fn sbw2_roundtrips_and_catches_flipped_bits() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 5);
        let path = std::env::temp_dir().join(format!("stbw2_{}.stbw", std::process::id()));
        w.save(&path).unwrap();
        let back = ModelWeights::load(&cfg, &path).unwrap();
        assert_eq!(back.embed.data, w.embed.data);
        assert_eq!(back.layers[0].mats["wq"].data, w.layers[0].mats["wq"].data);

        // flip one payload bit: the corrupt tensor must be named
        let mut bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let named = w.to_named();
        let first = named.keys().next().unwrap().clone();
        // first entry payload starts after magic(4)+n(4)+name_len(4)+name+ndim(4)+dims
        let ndims = named[&first].0.len();
        let flip_at = 8 + 4 + first.len() + 4 + 4 * ndims + 1;
        bytes[flip_at] ^= 0x40;
        match parse_stbw(&bytes) {
            Err(ArtifactError::EntryChecksum { entry, offset, .. }) => {
                assert_eq!(entry, first);
                assert_eq!(offset, 8);
            }
            other => panic!("expected EntryChecksum naming {first}, got {other:?}"),
        }
    }

    #[test]
    fn synthetic_matches_config_param_count() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let cfg = ModelConfig::preset(name).unwrap();
            let w = ModelWeights::synthetic(&cfg, 1);
            assert_eq!(w.n_params(), cfg.n_params(), "{name}");
        }
    }

    #[test]
    fn from_named_validates_shapes() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 2);
        // serialize by hand into the named map with a WRONG shape for wq
        let mut named: BTreeMap<String, (Vec<usize>, Vec<f32>)> = BTreeMap::new();
        named.insert("embed".into(), (vec![cfg.vocab, cfg.dim], w.embed.data.clone()));
        named.insert("ln_f".into(), (vec![cfg.dim], w.ln_f.clone()));
        for i in 0..cfg.n_layers {
            named.insert(format!("layers.{i}.ln1"), (vec![cfg.dim], w.layers[i].ln1.clone()));
            named.insert(format!("layers.{i}.ln2"), (vec![cfg.dim], w.layers[i].ln2.clone()));
            for n in cfg.layer_weight_names() {
                let m = &w.layers[i].mats[n];
                named.insert(format!("layers.{i}.{n}"), (vec![m.rows, m.cols], m.data.clone()));
            }
        }
        assert!(ModelWeights::from_named(&cfg, &named).is_ok());
        let bad = (vec![7usize, 7], vec![0.0f32; 49]);
        named.insert("layers.0.wq".into(), bad);
        assert!(ModelWeights::from_named(&cfg, &named).is_err());
    }
}
