//! Model substrate: configs (manifest-driven), synthetic corpora, weight
//! containers and the native transformer forward (full-sequence + KV-cache
//! decode). The quantization pipeline treats a model as "a config + a set of
//! named 2-D matrices"; everything else here exists to *evaluate* the result.

pub mod config;
pub mod corpus;
pub mod transformer;
pub mod weights;

pub use config::{Family, ModelConfig, HEAD_DIM};
pub use weights::{LayerWeights, ModelWeights};
