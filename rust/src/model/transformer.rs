//! Native Rust transformer forward — semantically identical to the JAX
//! `python/compile/model.py` forward (the integration test
//! `rust/tests/pjrt_parity.rs` asserts the two paths agree to ~1e-3).
//!
//! Two modes:
//!   * full-sequence forward (perplexity eval, calibration capture);
//!   * incremental decode with a KV cache (the serving hot path).
//!
//! The math is written once against the [`ModelOps`] seam: everything the
//! forward needs from a weight container, with the projection GEMMs behind
//! trait methods. Dense `ModelWeights` implement it with `matmul_bt` /
//! `matvec`; the packed sub-1-bit store implements it with `packed::gemm`
//! (see `engine::packed`), so quantized deployment artifacts run the exact
//! same attention/FFN code as full-precision weights.

use crate::model::config::{Family, ModelConfig, HEAD_DIM, ROPE_THETA};
use crate::model::weights::{LayerWeights, ModelWeights};
use crate::tensor::{matmul_bt, Mat};

/// The weight-application seam shared by every model representation.
///
/// `proj` / `proj_vec` compute `x @ W^T` for the named per-layer projection
/// (`wq`..`w3`); the embedding / norm tensors stay dense f32 in all
/// representations (they are never quantized).
pub trait ModelOps {
    fn n_layers(&self) -> usize;
    fn ln1(&self, layer: usize) -> &[f32];
    fn ln2(&self, layer: usize) -> &[f32];
    /// Full-sequence projection: `y = x @ W[layer][name]^T` — (S, out).
    fn proj(&self, layer: usize, name: &str, x: &Mat) -> Mat;
    /// Single-vector projection: `y = W[layer][name] @ x` (decode path).
    fn proj_vec(&self, layer: usize, name: &str, x: &[f32]) -> Vec<f32>;
    /// Tied embedding matrix — (vocab, dim).
    fn embed_mat(&self) -> &Mat;
    /// Learned positional embeddings (OPT family only).
    fn pos_mat(&self) -> Option<&Mat>;
    fn ln_f(&self) -> &[f32];
}

impl ModelOps for ModelWeights {
    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn ln1(&self, layer: usize) -> &[f32] {
        &self.layers[layer].ln1
    }

    fn ln2(&self, layer: usize) -> &[f32] {
        &self.layers[layer].ln2
    }

    fn proj(&self, layer: usize, name: &str, x: &Mat) -> Mat {
        matmul_bt(x, &self.layers[layer].mats[name])
    }

    fn proj_vec(&self, layer: usize, name: &str, x: &[f32]) -> Vec<f32> {
        crate::tensor::matvec(&self.layers[layer].mats[name], x)
    }

    fn embed_mat(&self) -> &Mat {
        &self.embed
    }

    fn pos_mat(&self) -> Option<&Mat> {
        self.pos.as_ref()
    }

    fn ln_f(&self) -> &[f32] {
        &self.ln_f
    }
}

/// x * rsqrt(mean(x²) + eps) * w, row-wise over (S, D).
pub fn rmsnorm(x: &Mat, w: &[f32], eps: f32) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let r = x.row(i);
        let ms = r.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (o, (v, g)) in out.row_mut(i).iter_mut().zip(r.iter().zip(w)) {
            *o = v * inv * g;
        }
    }
    out
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// tanh-approximate GELU (matches `jax.nn.gelu` default).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// RoPE tables for positions `[0, seq)`: (cos, sin), each seq × HEAD_DIM/2.
pub fn rope_tables(seq: usize) -> (Mat, Mat) {
    let h = HEAD_DIM / 2;
    let mut cos = Mat::zeros(seq, h);
    let mut sin = Mat::zeros(seq, h);
    for p in 0..seq {
        for i in 0..h {
            let inv = 1.0 / ROPE_THETA.powf(2.0 * i as f32 / HEAD_DIM as f32);
            let ang = p as f32 * inv;
            cos[(p, i)] = ang.cos();
            sin[(p, i)] = ang.sin();
        }
    }
    (cos, sin)
}

/// Split-half rotation applied in place to one head vector at position `p`.
fn apply_rope_vec(v: &mut [f32], cos: &Mat, sin: &Mat, p: usize) {
    let h = HEAD_DIM / 2;
    for i in 0..h {
        let (c, s) = (cos[(p, i)], sin[(p, i)]);
        let (a, b) = (v[i], v[i + h]);
        v[i] = a * c - b * s;
        v[i + h] = a * s + b * c;
    }
}

fn softmax_inplace(row: &mut [f32]) {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    row.iter_mut().for_each(|v| *v *= inv);
}

/// Per-layer activation taps captured during calibration — the inputs of
/// each quantizable projection group (see `coordinator::calib`).
#[derive(Clone, Debug, Default)]
pub struct LayerTaps {
    /// input to wq/wk/wv: rmsnorm(x, ln1) — (S, dim)
    pub attn_in: Option<Mat>,
    /// input to wo: concatenated attention output — (S, dim)
    pub wo_in: Option<Mat>,
    /// input to w1/w3: rmsnorm(h, ln2) — (S, dim)
    pub ffn_in: Option<Mat>,
    /// input to w2: the FFN hidden activation — (S, ffn_hidden)
    pub w2_in: Option<Mat>,
}

/// One transformer block over a full sequence, with the projections behind a
/// closure — the single implementation shared by dense and packed weights.
/// When `taps` is Some, the four projection inputs are recorded (cloned)
/// for Hessian accumulation.
pub fn layer_fwd_with(
    cfg: &ModelConfig,
    x: &Mat,
    ln1: &[f32],
    ln2: &[f32],
    proj: &mut dyn FnMut(&str, &Mat) -> Mat,
    taps: Option<&mut LayerTaps>,
) -> Mat {
    let s = x.rows;
    let d = cfg.dim;
    let nh = cfg.n_heads();
    let mut taps = taps;

    // ---- attention -------------------------------------------------------
    let xn = rmsnorm(x, ln1, cfg.norm_eps);
    if let Some(t) = taps.as_deref_mut() {
        t.attn_in = Some(xn.clone());
    }
    let mut q = proj("wq", &xn);
    let mut k = proj("wk", &xn);
    let v = proj("wv", &xn);
    if cfg.family != Family::Opt {
        let (cos, sin) = rope_tables(s);
        for p in 0..s {
            for h in 0..nh {
                apply_rope_vec(&mut q.row_mut(p)[h * HEAD_DIM..(h + 1) * HEAD_DIM], &cos, &sin, p);
                apply_rope_vec(&mut k.row_mut(p)[h * HEAD_DIM..(h + 1) * HEAD_DIM], &cos, &sin, p);
            }
        }
    }
    let scale = 1.0 / (HEAD_DIM as f32).sqrt();
    let mut attn_out = Mat::zeros(s, d);
    let mut att = vec![0.0f32; s];
    for h in 0..nh {
        let hoff = h * HEAD_DIM;
        for i in 0..s {
            let lo = if cfg.window > 0 { (i + 1).saturating_sub(cfg.window) } else { 0 };
            let qi = &q.row(i)[hoff..hoff + HEAD_DIM];
            for j in lo..=i {
                let kj = &k.row(j)[hoff..hoff + HEAD_DIM];
                att[j] = crate::tensor::dot(qi, kj) * scale;
            }
            softmax_inplace(&mut att[lo..=i]);
            let orow = &mut attn_out.row_mut(i)[hoff..hoff + HEAD_DIM];
            for j in lo..=i {
                let w = att[j];
                let vj = &v.row(j)[hoff..hoff + HEAD_DIM];
                for (o, vv) in orow.iter_mut().zip(vj) {
                    *o += w * vv;
                }
            }
        }
    }
    if let Some(t) = taps.as_deref_mut() {
        t.wo_in = Some(attn_out.clone());
    }
    let proj_out = proj("wo", &attn_out);
    let mut hidden = x.clone();
    hidden.add_assign(&proj_out);

    // ---- FFN ---------------------------------------------------------------
    let hn = rmsnorm(&hidden, ln2, cfg.norm_eps);
    if let Some(t) = taps.as_deref_mut() {
        t.ffn_in = Some(hn.clone());
    }
    let ffn = if cfg.family == Family::Opt {
        let mut a = proj("w1", &hn);
        a.data.iter_mut().for_each(|x| *x = gelu(*x));
        if let Some(t) = taps.as_deref_mut() {
            t.w2_in = Some(a.clone());
        }
        proj("w2", &a)
    } else {
        let mut g = proj("w1", &hn);
        let u = proj("w3", &hn);
        for (gi, ui) in g.data.iter_mut().zip(&u.data) {
            *gi = silu(*gi) * ui;
        }
        if let Some(t) = taps.as_deref_mut() {
            t.w2_in = Some(g.clone());
        }
        proj("w2", &g)
    };
    hidden.add_assign(&ffn);
    hidden
}

/// One transformer block over dense layer weights (the historical entry
/// point — now a thin shim over [`layer_fwd_with`]).
pub fn layer_fwd(
    cfg: &ModelConfig,
    x: &Mat,
    lw: &LayerWeights,
    taps: Option<&mut LayerTaps>,
) -> Mat {
    layer_fwd_with(cfg, x, &lw.ln1, &lw.ln2, &mut |name, xin| matmul_bt(xin, &lw.mats[name]), taps)
}

/// Embedding lookup (+ learned positions for OPT) over any representation.
pub fn embed_ops(ops: &dyn ModelOps, cfg: &ModelConfig, tokens: &[u8]) -> Mat {
    let mut x = Mat::zeros(tokens.len(), cfg.dim);
    let emb = ops.embed_mat();
    for (i, &t) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(emb.row(t as usize));
    }
    if let Some(pos) = ops.pos_mat() {
        for i in 0..tokens.len() {
            let p = pos.row(i % pos.rows);
            for (a, b) in x.row_mut(i).iter_mut().zip(p) {
                *a += b;
            }
        }
    }
    x
}

/// Embedding lookup for dense weights (shim over [`embed_ops`]).
pub fn embed(cfg: &ModelConfig, w: &ModelWeights, tokens: &[u8]) -> Mat {
    embed_ops(w, cfg, tokens)
}

/// Final norm + tied-embedding logits over any representation.
pub fn lm_head_ops(ops: &dyn ModelOps, cfg: &ModelConfig, x: &Mat) -> Mat {
    matmul_bt(&rmsnorm(x, ops.ln_f(), cfg.norm_eps), ops.embed_mat())
}

/// Final norm + tied-embedding logits (dense shim).
pub fn lm_head(cfg: &ModelConfig, w: &ModelWeights, x: &Mat) -> Mat {
    lm_head_ops(w, cfg, x)
}

/// Full-model forward over any representation: tokens → logits (S, vocab).
pub fn model_fwd_ops(ops: &dyn ModelOps, cfg: &ModelConfig, tokens: &[u8]) -> Mat {
    let mut x = embed_ops(ops, cfg, tokens);
    for l in 0..ops.n_layers() {
        x = layer_fwd_with(
            cfg,
            &x,
            ops.ln1(l),
            ops.ln2(l),
            &mut |name, xin| ops.proj(l, name, xin),
            None,
        );
    }
    lm_head_ops(ops, cfg, &x)
}

/// Full-model forward over dense weights: tokens → logits (S, vocab).
pub fn model_fwd(cfg: &ModelConfig, w: &ModelWeights, tokens: &[u8]) -> Mat {
    model_fwd_ops(w, cfg, tokens)
}

/// Forward capturing per-layer calibration taps (dense weights only — the
/// calibration pass always runs on the full-precision model).
pub fn model_fwd_with_taps(
    cfg: &ModelConfig,
    w: &ModelWeights,
    tokens: &[u8],
) -> (Mat, Vec<LayerTaps>) {
    let mut x = embed(cfg, w, tokens);
    let mut taps = Vec::with_capacity(w.layers.len());
    for lw in &w.layers {
        let mut t = LayerTaps::default();
        x = layer_fwd(cfg, &x, lw, Some(&mut t));
        taps.push(t);
    }
    (lm_head(cfg, w, &x), taps)
}

// ---------------------------------------------------------------------------
// Incremental decoding (serving hot path)
// ---------------------------------------------------------------------------

/// Per-layer KV cache for one sequence.
pub struct KvCache {
    pub k: Mat, // (capacity, dim)
    pub v: Mat,
    pub len: usize,
}

/// Decode state: caches for all layers + current position.
pub struct DecodeState {
    pub caches: Vec<KvCache>,
    pub pos: usize,
    capacity: usize,
    /// RoPE tables precomputed to capacity (§Perf L3: recomputing per step
    /// made decode quadratic in position)
    rope: (Mat, Mat),
}

impl DecodeState {
    pub fn new(cfg: &ModelConfig, capacity: usize) -> DecodeState {
        DecodeState {
            caches: (0..cfg.n_layers)
                .map(|_| KvCache {
                    k: Mat::zeros(capacity, cfg.dim),
                    v: Mat::zeros(capacity, cfg.dim),
                    len: 0,
                })
                .collect(),
            pos: 0,
            capacity,
            rope: rope_tables(capacity),
        }
    }

    /// Process one token through dense weights; returns logits over the
    /// vocab (shim over [`DecodeState::step_ops`]).
    pub fn step(&mut self, cfg: &ModelConfig, w: &ModelWeights, token: u8) -> Vec<f32> {
        self.step_ops(cfg, w, token)
    }

    /// Process one token over any representation; returns logits over the
    /// vocab. This is the serving hot path — packed backends route every
    /// projection through the sub-1-bit gather kernels here.
    pub fn step_ops(&mut self, cfg: &ModelConfig, ops: &dyn ModelOps, token: u8) -> Vec<f32> {
        assert!(self.pos < self.capacity, "KV cache capacity exceeded");
        let d = cfg.dim;
        let nh = cfg.n_heads();
        let p = self.pos;
        let (cos, sin) = (&self.rope.0, &self.rope.1);

        // embedding
        let mut x: Vec<f32> = ops.embed_mat().row(token as usize).to_vec();
        if let Some(pos_emb) = ops.pos_mat() {
            for (a, b) in x.iter_mut().zip(pos_emb.row(p % pos_emb.rows)) {
                *a += b;
            }
        }

        for li in 0..ops.n_layers() {
            let xn = rmsnorm_vec(&x, ops.ln1(li), cfg.norm_eps);
            let mut q = ops.proj_vec(li, "wq", &xn);
            let mut k = ops.proj_vec(li, "wk", &xn);
            let v = ops.proj_vec(li, "wv", &xn);
            if cfg.family != Family::Opt {
                for h in 0..nh {
                    apply_rope_vec(&mut q[h * HEAD_DIM..(h + 1) * HEAD_DIM], cos, sin, p);
                    apply_rope_vec(&mut k[h * HEAD_DIM..(h + 1) * HEAD_DIM], cos, sin, p);
                }
            }
            let cache = &mut self.caches[li];
            cache.k.row_mut(p).copy_from_slice(&k);
            cache.v.row_mut(p).copy_from_slice(&v);
            cache.len = p + 1;

            let lo = if cfg.window > 0 { (p + 1).saturating_sub(cfg.window) } else { 0 };
            let scale = 1.0 / (HEAD_DIM as f32).sqrt();
            let mut attn_out = vec![0.0f32; d];
            let mut att = vec![0.0f32; p + 1];
            for h in 0..nh {
                let hoff = h * HEAD_DIM;
                let qh = &q[hoff..hoff + HEAD_DIM];
                for j in lo..=p {
                    att[j] =
                        crate::tensor::dot(qh, &cache.k.row(j)[hoff..hoff + HEAD_DIM]) * scale;
                }
                softmax_inplace(&mut att[lo..=p]);
                for j in lo..=p {
                    let wgt = att[j];
                    let vj = &cache.v.row(j)[hoff..hoff + HEAD_DIM];
                    for (o, vv) in attn_out[hoff..hoff + HEAD_DIM].iter_mut().zip(vj) {
                        *o += wgt * vv;
                    }
                }
            }
            let proj = ops.proj_vec(li, "wo", &attn_out);
            for (a, b) in x.iter_mut().zip(&proj) {
                *a += b;
            }

            let hn = rmsnorm_vec(&x, ops.ln2(li), cfg.norm_eps);
            let ffn = if cfg.family == Family::Opt {
                let mut a = ops.proj_vec(li, "w1", &hn);
                a.iter_mut().for_each(|t| *t = gelu(*t));
                ops.proj_vec(li, "w2", &a)
            } else {
                let mut g = ops.proj_vec(li, "w1", &hn);
                let u = ops.proj_vec(li, "w3", &hn);
                for (gi, ui) in g.iter_mut().zip(&u) {
                    *gi = silu(*gi) * ui;
                }
                ops.proj_vec(li, "w2", &g)
            };
            for (a, b) in x.iter_mut().zip(&ffn) {
                *a += b;
            }
        }
        self.pos += 1;
        let xn = rmsnorm_vec(&x, ops.ln_f(), cfg.norm_eps);
        crate::tensor::matvec(ops.embed_mat(), &xn)
    }
}

fn rmsnorm_vec(x: &[f32], w: &[f32], eps: f32) -> Vec<f32> {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    x.iter().zip(w).map(|(v, g)| v * inv * g).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(name: &str) -> (ModelConfig, ModelWeights) {
        let cfg = ModelConfig::preset(name).unwrap();
        let w = ModelWeights::synthetic(&cfg, 7);
        (cfg, w)
    }

    #[test]
    fn fwd_shapes_all_families() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let (cfg, w) = tiny(name);
            let toks: Vec<u8> = (0..32u8).collect();
            let logits = model_fwd(&cfg, &w, &toks);
            assert_eq!((logits.rows, logits.cols), (32, cfg.vocab), "{name}");
            assert!(logits.data.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn causality_holds() {
        let (cfg, w) = tiny("llama1-7b");
        let mut toks: Vec<u8> = (0..24u8).collect();
        let l1 = model_fwd(&cfg, &w, &toks);
        toks[23] = 99;
        let l2 = model_fwd(&cfg, &w, &toks);
        for i in 0..23 {
            for j in 0..cfg.vocab {
                assert!((l1[(i, j)] - l2[(i, j)]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn decode_matches_full_forward() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let (cfg, w) = tiny(name);
            let toks: Vec<u8> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8];
            let full = model_fwd(&cfg, &w, &toks);
            let mut st = DecodeState::new(&cfg, 32);
            let mut last = Vec::new();
            for &t in &toks {
                last = st.step(&cfg, &w, t);
            }
            let want = full.row(toks.len() - 1);
            for (a, b) in last.iter().zip(want) {
                assert!((a - b).abs() < 1e-3, "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn taps_captured_with_right_shapes() {
        let (cfg, w) = tiny("llama1-7b");
        let toks: Vec<u8> = (0..16u8).collect();
        let (_, taps) = model_fwd_with_taps(&cfg, &w, &toks);
        assert_eq!(taps.len(), cfg.n_layers);
        let t = &taps[0];
        assert_eq!(t.attn_in.as_ref().unwrap().cols, cfg.dim);
        assert_eq!(t.wo_in.as_ref().unwrap().cols, cfg.dim);
        assert_eq!(t.ffn_in.as_ref().unwrap().cols, cfg.dim);
        assert_eq!(t.w2_in.as_ref().unwrap().cols, cfg.ffn_hidden);
        assert_eq!(t.w2_in.as_ref().unwrap().rows, 16);
    }

    #[test]
    fn sliding_window_changes_late_logits_only() {
        let cfg_w = ModelConfig::preset("mistral-7b").unwrap();
        let mut cfg_full = cfg_w.clone();
        cfg_full.window = 0;
        let w = ModelWeights::synthetic(&cfg_w, 9);
        let toks: Vec<u8> = (0..100).map(|i| (i * 7 % 32) as u8).collect();
        let a = model_fwd(&cfg_w, &w, &toks);
        let b = model_fwd(&cfg_full, &w, &toks);
        // within the window everything matches
        for j in 0..cfg_w.vocab {
            assert!((a[(10, j)] - b[(10, j)]).abs() < 1e-4);
        }
        // beyond it, logits differ
        let diff: f32 = (0..cfg_w.vocab).map(|j| (a[(99, j)] - b[(99, j)]).abs()).sum();
        assert!(diff > 1e-4);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = Mat::from_vec(1, 4, vec![2.0, -2.0, 2.0, -2.0]);
        let out = rmsnorm(&x, &[1.0; 4], 0.0);
        for v in out.data {
            assert!((v.abs() - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn ops_forward_matches_dense_entry_point() {
        // model_fwd_ops over the ModelWeights impl IS model_fwd; pin it.
        let (cfg, w) = tiny("opt-1.3b");
        let toks: Vec<u8> = (0..12u8).collect();
        let a = model_fwd(&cfg, &w, &toks);
        let b = model_fwd_ops(&w, &cfg, &toks);
        assert_eq!(a.data, b.data);
    }
}
