//! Native Rust transformer forward — semantically identical to the JAX
//! `python/compile/model.py` forward (the integration test
//! `rust/tests/pjrt_parity.rs` asserts the two paths agree to ~1e-3).
//!
//! Two modes:
//!   * full-sequence forward (perplexity eval, calibration capture);
//!   * incremental decode with a KV cache (the serving hot path).
//!
//! The math is written once against the [`ModelOps`] seam: everything the
//! forward needs from a weight container, with the projection GEMMs behind
//! trait methods. Dense `ModelWeights` implement it with `matmul_bt` /
//! `matvec`; the packed sub-1-bit store implements it with `packed::gemm`
//! (see `engine::packed`), so quantized deployment artifacts run the exact
//! same attention/FFN code as full-precision weights.

use std::sync::Arc;

use crate::coordinator::kvpool::{KvPool, KvPoolError, PagedKv};
use crate::model::config::{Family, ModelConfig, HEAD_DIM, ROPE_THETA};
use crate::model::weights::{LayerWeights, ModelWeights};
use crate::tensor::{matmul_bt, Mat};

/// The weight-application seam shared by every model representation.
///
/// `proj` / `proj_vec` compute `x @ W^T` for the named per-layer projection
/// (`wq`..`w3`); the embedding / norm tensors stay dense f32 in all
/// representations (they are never quantized).
pub trait ModelOps {
    fn n_layers(&self) -> usize;
    fn ln1(&self, layer: usize) -> &[f32];
    fn ln2(&self, layer: usize) -> &[f32];
    /// Full-sequence projection: `y = x @ W[layer][name]^T` — (S, out).
    fn proj(&self, layer: usize, name: &str, x: &Mat) -> Mat;
    /// Single-vector projection: `y = W[layer][name] @ x` (decode path).
    fn proj_vec(&self, layer: usize, name: &str, x: &[f32]) -> Vec<f32>;
    /// Single-vector projection into caller-owned storage — the
    /// zero-allocation decode hot path ([`DecodeState`] owns the buffers).
    /// `out.len()` must equal the projection's output rows. The default
    /// routes through [`ModelOps::proj_vec`] (one allocation); dense and
    /// packed representations override it to be allocation-free.
    fn proj_vec_into(&self, layer: usize, name: &str, x: &[f32], out: &mut [f32]) {
        out.copy_from_slice(&self.proj_vec(layer, name, x));
    }
    /// Chunk projection into caller-owned storage: `out = x @ W^T` for a
    /// (C, in) block of activation rows — the chunked-prefill seam
    /// ([`DecodeState::prefill_chunk`]). `out` must be (C, out_rows).
    ///
    /// The default routes every row through [`ModelOps::proj_vec_into`],
    /// which makes chunked prefill bit-identical to token-by-token decode
    /// *by construction*. Representations whose batched GEMM shares the
    /// decode row kernel (the packed LUT kernels: `packed_gemm4` funnels
    /// through the same per-word accumulation as `packed_gemv`) override
    /// this to amortize each weight read across all C columns while
    /// preserving that bit identity.
    fn proj_chunk_into(&self, layer: usize, name: &str, x: &Mat, out: &mut Mat) {
        debug_assert_eq!(x.rows, out.rows);
        for b in 0..x.rows {
            self.proj_vec_into(layer, name, x.row(b), out.row_mut(b));
        }
    }
    /// Tied embedding matrix — (vocab, dim).
    fn embed_mat(&self) -> &Mat;
    /// Learned positional embeddings (OPT family only).
    fn pos_mat(&self) -> Option<&Mat>;
    fn ln_f(&self) -> &[f32];
}

impl ModelOps for ModelWeights {
    fn n_layers(&self) -> usize {
        self.layers.len()
    }

    fn ln1(&self, layer: usize) -> &[f32] {
        &self.layers[layer].ln1
    }

    fn ln2(&self, layer: usize) -> &[f32] {
        &self.layers[layer].ln2
    }

    fn proj(&self, layer: usize, name: &str, x: &Mat) -> Mat {
        matmul_bt(x, &self.layers[layer].mats[name])
    }

    fn proj_vec(&self, layer: usize, name: &str, x: &[f32]) -> Vec<f32> {
        crate::tensor::matvec(&self.layers[layer].mats[name], x)
    }

    fn proj_vec_into(&self, layer: usize, name: &str, x: &[f32], out: &mut [f32]) {
        crate::tensor::matvec_into(&self.layers[layer].mats[name], x, out);
    }

    fn embed_mat(&self) -> &Mat {
        &self.embed
    }

    fn pos_mat(&self) -> Option<&Mat> {
        self.pos.as_ref()
    }

    fn ln_f(&self) -> &[f32] {
        &self.ln_f
    }
}

/// x * rsqrt(mean(x²) + eps) * w, row-wise over (S, D).
pub fn rmsnorm(x: &Mat, w: &[f32], eps: f32) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    for i in 0..x.rows {
        let r = x.row(i);
        let ms = r.iter().map(|v| v * v).sum::<f32>() / x.cols as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        for (o, (v, g)) in out.row_mut(i).iter_mut().zip(r.iter().zip(w)) {
            *o = v * inv * g;
        }
    }
    out
}

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// tanh-approximate GELU (matches `jax.nn.gelu` default).
#[inline]
fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608028654; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// RoPE tables for positions `[0, seq)`: (cos, sin), each seq × HEAD_DIM/2.
pub fn rope_tables(seq: usize) -> (Mat, Mat) {
    let h = HEAD_DIM / 2;
    let mut cos = Mat::zeros(seq, h);
    let mut sin = Mat::zeros(seq, h);
    for p in 0..seq {
        for i in 0..h {
            let inv = 1.0 / ROPE_THETA.powf(2.0 * i as f32 / HEAD_DIM as f32);
            let ang = p as f32 * inv;
            cos[(p, i)] = ang.cos();
            sin[(p, i)] = ang.sin();
        }
    }
    (cos, sin)
}

/// Split-half rotation applied in place to one head vector at position `p`.
fn apply_rope_vec(v: &mut [f32], cos: &Mat, sin: &Mat, p: usize) {
    let h = HEAD_DIM / 2;
    for i in 0..h {
        let (c, s) = (cos[(p, i)], sin[(p, i)]);
        let (a, b) = (v[i], v[i + h]);
        v[i] = a * c - b * s;
        v[i + h] = a * s + b * c;
    }
}

fn softmax_inplace(row: &mut [f32]) {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - m).exp();
        z += *v;
    }
    let inv = 1.0 / z;
    row.iter_mut().for_each(|v| *v *= inv);
}

/// Per-layer activation taps captured during calibration — the inputs of
/// each quantizable projection group (see `coordinator::calib`).
#[derive(Clone, Debug, Default)]
pub struct LayerTaps {
    /// input to wq/wk/wv: rmsnorm(x, ln1) — (S, dim)
    pub attn_in: Option<Mat>,
    /// input to wo: concatenated attention output — (S, dim)
    pub wo_in: Option<Mat>,
    /// input to w1/w3: rmsnorm(h, ln2) — (S, dim)
    pub ffn_in: Option<Mat>,
    /// input to w2: the FFN hidden activation — (S, ffn_hidden)
    pub w2_in: Option<Mat>,
}

/// One transformer block over a full sequence, with the projections behind a
/// closure — the single implementation shared by dense and packed weights.
/// When `taps` is Some, the four projection inputs are recorded (cloned)
/// for Hessian accumulation.
pub fn layer_fwd_with(
    cfg: &ModelConfig,
    x: &Mat,
    ln1: &[f32],
    ln2: &[f32],
    proj: &mut dyn FnMut(&str, &Mat) -> Mat,
    taps: Option<&mut LayerTaps>,
) -> Mat {
    let s = x.rows;
    let d = cfg.dim;
    let nh = cfg.n_heads();
    let mut taps = taps;

    // ---- attention -------------------------------------------------------
    let xn = rmsnorm(x, ln1, cfg.norm_eps);
    if let Some(t) = taps.as_deref_mut() {
        t.attn_in = Some(xn.clone());
    }
    let mut q = proj("wq", &xn);
    let mut k = proj("wk", &xn);
    let v = proj("wv", &xn);
    if cfg.family != Family::Opt {
        let (cos, sin) = rope_tables(s);
        for p in 0..s {
            for h in 0..nh {
                apply_rope_vec(&mut q.row_mut(p)[h * HEAD_DIM..(h + 1) * HEAD_DIM], &cos, &sin, p);
                apply_rope_vec(&mut k.row_mut(p)[h * HEAD_DIM..(h + 1) * HEAD_DIM], &cos, &sin, p);
            }
        }
    }
    let scale = 1.0 / (HEAD_DIM as f32).sqrt();
    let mut attn_out = Mat::zeros(s, d);
    let mut att = vec![0.0f32; s];
    for h in 0..nh {
        let hoff = h * HEAD_DIM;
        for i in 0..s {
            let lo = if cfg.window > 0 { (i + 1).saturating_sub(cfg.window) } else { 0 };
            let qi = &q.row(i)[hoff..hoff + HEAD_DIM];
            for j in lo..=i {
                let kj = &k.row(j)[hoff..hoff + HEAD_DIM];
                att[j] = crate::tensor::dot(qi, kj) * scale;
            }
            softmax_inplace(&mut att[lo..=i]);
            let orow = &mut attn_out.row_mut(i)[hoff..hoff + HEAD_DIM];
            for j in lo..=i {
                let w = att[j];
                let vj = &v.row(j)[hoff..hoff + HEAD_DIM];
                for (o, vv) in orow.iter_mut().zip(vj) {
                    *o += w * vv;
                }
            }
        }
    }
    if let Some(t) = taps.as_deref_mut() {
        t.wo_in = Some(attn_out.clone());
    }
    let proj_out = proj("wo", &attn_out);
    let mut hidden = x.clone();
    hidden.add_assign(&proj_out);

    // ---- FFN ---------------------------------------------------------------
    let hn = rmsnorm(&hidden, ln2, cfg.norm_eps);
    if let Some(t) = taps.as_deref_mut() {
        t.ffn_in = Some(hn.clone());
    }
    let ffn = if cfg.family == Family::Opt {
        let mut a = proj("w1", &hn);
        a.data.iter_mut().for_each(|x| *x = gelu(*x));
        if let Some(t) = taps.as_deref_mut() {
            t.w2_in = Some(a.clone());
        }
        proj("w2", &a)
    } else {
        let mut g = proj("w1", &hn);
        let u = proj("w3", &hn);
        for (gi, ui) in g.data.iter_mut().zip(&u.data) {
            *gi = silu(*gi) * ui;
        }
        if let Some(t) = taps.as_deref_mut() {
            t.w2_in = Some(g.clone());
        }
        proj("w2", &g)
    };
    hidden.add_assign(&ffn);
    hidden
}

/// One transformer block over dense layer weights (the historical entry
/// point — now a thin shim over [`layer_fwd_with`]).
pub fn layer_fwd(
    cfg: &ModelConfig,
    x: &Mat,
    lw: &LayerWeights,
    taps: Option<&mut LayerTaps>,
) -> Mat {
    layer_fwd_with(cfg, x, &lw.ln1, &lw.ln2, &mut |name, xin| matmul_bt(xin, &lw.mats[name]), taps)
}

/// Embedding lookup (+ learned positions for OPT) over any representation.
pub fn embed_ops(ops: &dyn ModelOps, cfg: &ModelConfig, tokens: &[u8]) -> Mat {
    let mut x = Mat::zeros(tokens.len(), cfg.dim);
    let emb = ops.embed_mat();
    for (i, &t) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(emb.row(t as usize));
    }
    if let Some(pos) = ops.pos_mat() {
        for i in 0..tokens.len() {
            let p = pos.row(i % pos.rows);
            for (a, b) in x.row_mut(i).iter_mut().zip(p) {
                *a += b;
            }
        }
    }
    x
}

/// Embedding lookup for dense weights (shim over [`embed_ops`]).
pub fn embed(cfg: &ModelConfig, w: &ModelWeights, tokens: &[u8]) -> Mat {
    embed_ops(w, cfg, tokens)
}

/// Final norm + tied-embedding logits over any representation.
pub fn lm_head_ops(ops: &dyn ModelOps, cfg: &ModelConfig, x: &Mat) -> Mat {
    matmul_bt(&rmsnorm(x, ops.ln_f(), cfg.norm_eps), ops.embed_mat())
}

/// Final norm + tied-embedding logits (dense shim).
pub fn lm_head(cfg: &ModelConfig, w: &ModelWeights, x: &Mat) -> Mat {
    lm_head_ops(w, cfg, x)
}

/// Full-model forward over any representation: tokens → logits (S, vocab).
pub fn model_fwd_ops(ops: &dyn ModelOps, cfg: &ModelConfig, tokens: &[u8]) -> Mat {
    let mut x = embed_ops(ops, cfg, tokens);
    for l in 0..ops.n_layers() {
        x = layer_fwd_with(
            cfg,
            &x,
            ops.ln1(l),
            ops.ln2(l),
            &mut |name, xin| ops.proj(l, name, xin),
            None,
        );
    }
    lm_head_ops(ops, cfg, &x)
}

/// Full-model forward over dense weights: tokens → logits (S, vocab).
pub fn model_fwd(cfg: &ModelConfig, w: &ModelWeights, tokens: &[u8]) -> Mat {
    model_fwd_ops(w, cfg, tokens)
}

/// Forward capturing per-layer calibration taps (dense weights only — the
/// calibration pass always runs on the full-precision model).
pub fn model_fwd_with_taps(
    cfg: &ModelConfig,
    w: &ModelWeights,
    tokens: &[u8],
) -> (Mat, Vec<LayerTaps>) {
    let mut x = embed(cfg, w, tokens);
    let mut taps = Vec::with_capacity(w.layers.len());
    for lw in &w.layers {
        let mut t = LayerTaps::default();
        x = layer_fwd(cfg, &x, lw, Some(&mut t));
        taps.push(t);
    }
    (lm_head(cfg, w, &x), taps)
}

// ---------------------------------------------------------------------------
// Incremental decoding (serving hot path)
// ---------------------------------------------------------------------------

/// Per-layer KV cache for one sequence (the flat, session-private layout).
pub struct KvCache {
    pub k: Mat, // (capacity, dim)
    pub v: Mat,
    pub len: usize,
}

/// Where a sequence's KV rows live: session-private flat matrices, or a
/// page table borrowing fixed-size pages from a shared
/// [`crate::coordinator::KvPool`] (with prefix reuse + copy-on-write).
/// Both variants store identical f32 rows, so the decode math below is
/// bit-identical across them.
pub enum KvStore {
    Flat(Vec<KvCache>),
    Paged(PagedKv),
}

impl KvStore {
    /// K row for layer `li`, position `j` (must already be written).
    #[inline]
    pub fn k_row(&self, li: usize, j: usize) -> &[f32] {
        match self {
            KvStore::Flat(c) => c[li].k.row(j),
            KvStore::Paged(p) => p.k_row(li, j),
        }
    }

    /// V row for layer `li`, position `j` (must already be written).
    #[inline]
    pub fn v_row(&self, li: usize, j: usize) -> &[f32] {
        match self {
            KvStore::Flat(c) => c[li].v.row(j),
            KvStore::Paged(p) => p.v_row(li, j),
        }
    }

    /// Store the K and V rows for position `p` of layer `li`.
    #[inline]
    pub fn write(&mut self, li: usize, p: usize, k: &[f32], v: &[f32]) {
        match self {
            KvStore::Flat(c) => {
                let cache = &mut c[li];
                cache.k.row_mut(p).copy_from_slice(k);
                cache.v.row_mut(p).copy_from_slice(v);
                cache.len = p + 1;
            }
            KvStore::Paged(pg) => pg.write(li, p, k, v),
        }
    }

    /// Hook run after a full token step (all layers written): paged stores
    /// publish completed pages to the prefix cache.
    #[inline]
    fn on_token(&mut self, tok: u8) {
        if let KvStore::Paged(p) = self {
            p.on_token(tok);
        }
    }
}

/// Reusable per-session buffers for the decode step — one allocation at
/// session start, zero allocations per token (§Perf L3: the old step
/// allocated ~12 vectors per token; profiles showed the allocator competing
/// with the packed gather for the hot path).
pub struct DecodeScratch {
    /// residual stream (dim)
    x: Vec<f32>,
    /// rmsnorm output feeding the attention projections (dim)
    xn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    /// attention weights (capacity)
    att: Vec<f32>,
    /// wo output (dim)
    proj: Vec<f32>,
    /// rmsnorm output feeding the FFN (dim)
    hn: Vec<f32>,
    /// FFN gate/hidden activation (ffn_hidden)
    g: Vec<f32>,
    /// FFN up activation, LLaMA/Mistral only (ffn_hidden)
    u: Vec<f32>,
    /// w2 output (dim)
    ffn: Vec<f32>,
}

impl DecodeScratch {
    fn new(cfg: &ModelConfig, capacity: usize) -> DecodeScratch {
        let d = cfg.dim;
        let h = cfg.ffn_hidden;
        DecodeScratch {
            x: vec![0.0; d],
            xn: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn_out: vec![0.0; d],
            att: vec![0.0; capacity.max(1)],
            proj: vec![0.0; d],
            hn: vec![0.0; d],
            g: vec![0.0; h],
            u: vec![0.0; h],
            ffn: vec![0.0; d],
        }
    }
}

/// Decode state: KV storage for all layers + current position.
pub struct DecodeState {
    pub kv: KvStore,
    pub pos: usize,
    capacity: usize,
    /// RoPE tables precomputed to capacity (§Perf L3: recomputing per step
    /// made decode quadratic in position)
    rope: (Mat, Mat),
    /// reusable step buffers (§Perf L3: no `vec!` in the token loop)
    scratch: DecodeScratch,
}

impl DecodeState {
    /// Flat (session-private) KV storage, zero-initialized to `capacity`.
    pub fn new(cfg: &ModelConfig, capacity: usize) -> DecodeState {
        DecodeState {
            kv: KvStore::Flat(
                (0..cfg.n_layers)
                    .map(|_| KvCache {
                        k: Mat::zeros(capacity, cfg.dim),
                        v: Mat::zeros(capacity, cfg.dim),
                        len: 0,
                    })
                    .collect(),
            ),
            pos: 0,
            capacity,
            rope: rope_tables(capacity),
            scratch: DecodeScratch::new(cfg, capacity),
        }
    }

    /// Paged KV storage borrowing pages from a shared pool. Reserves
    /// worst-case pages for `capacity` tokens up front (typed error when
    /// the pool cannot cover them) and maps any prefix of `prompt` already
    /// cached by earlier sessions — the returned state then starts at
    /// `pos == matched`, and the caller feeds `prompt[matched..]` onward.
    /// Logits are bit-identical to the flat path for the same token
    /// stream.
    pub fn new_paged(
        cfg: &ModelConfig,
        capacity: usize,
        pool: &Arc<KvPool>,
        prompt: &[u8],
    ) -> Result<DecodeState, KvPoolError> {
        let paged = PagedKv::new(pool, cfg, capacity, prompt)?;
        let pos = paged.matched();
        Ok(DecodeState {
            kv: KvStore::Paged(paged),
            pos,
            capacity,
            rope: rope_tables(capacity),
            scratch: DecodeScratch::new(cfg, capacity),
        })
    }

    /// Process one token through dense weights; returns logits over the
    /// vocab (shim over [`DecodeState::step_ops`]).
    pub fn step(&mut self, cfg: &ModelConfig, w: &ModelWeights, token: u8) -> Vec<f32> {
        self.step_ops(cfg, w, token)
    }

    /// Process one token over any representation; returns logits over the
    /// vocab. This is the serving hot path — packed backends route every
    /// projection through the sub-1-bit LUT kernels here, and every
    /// intermediate lives in the reusable [`DecodeScratch`] (the returned
    /// logits vector is the only per-token allocation).
    pub fn step_ops(&mut self, cfg: &ModelConfig, ops: &dyn ModelOps, token: u8) -> Vec<f32> {
        assert!(self.pos < self.capacity, "KV cache capacity exceeded");
        let nh = cfg.n_heads();
        let p = self.pos;
        let (cos, sin) = (&self.rope.0, &self.rope.1);
        let sc = &mut self.scratch;

        // embedding, copied into the reusable residual buffer
        sc.x.copy_from_slice(ops.embed_mat().row(token as usize));
        if let Some(pos_emb) = ops.pos_mat() {
            for (a, b) in sc.x.iter_mut().zip(pos_emb.row(p % pos_emb.rows)) {
                *a += b;
            }
        }

        for li in 0..ops.n_layers() {
            rmsnorm_vec_into(&sc.x, ops.ln1(li), cfg.norm_eps, &mut sc.xn);
            ops.proj_vec_into(li, "wq", &sc.xn, &mut sc.q);
            ops.proj_vec_into(li, "wk", &sc.xn, &mut sc.k);
            ops.proj_vec_into(li, "wv", &sc.xn, &mut sc.v);
            if cfg.family != Family::Opt {
                for h in 0..nh {
                    apply_rope_vec(&mut sc.q[h * HEAD_DIM..(h + 1) * HEAD_DIM], cos, sin, p);
                    apply_rope_vec(&mut sc.k[h * HEAD_DIM..(h + 1) * HEAD_DIM], cos, sin, p);
                }
            }
            self.kv.write(li, p, &sc.k, &sc.v);

            let lo = if cfg.window > 0 { (p + 1).saturating_sub(cfg.window) } else { 0 };
            let scale = 1.0 / (HEAD_DIM as f32).sqrt();
            sc.attn_out.fill(0.0);
            let att = &mut sc.att[..p + 1];
            for h in 0..nh {
                let hoff = h * HEAD_DIM;
                let qh = &sc.q[hoff..hoff + HEAD_DIM];
                for j in lo..=p {
                    let kj = &self.kv.k_row(li, j)[hoff..hoff + HEAD_DIM];
                    att[j] = crate::tensor::dot(qh, kj) * scale;
                }
                softmax_inplace(&mut att[lo..=p]);
                for j in lo..=p {
                    let wgt = att[j];
                    let vj = &self.kv.v_row(li, j)[hoff..hoff + HEAD_DIM];
                    for (o, vv) in sc.attn_out[hoff..hoff + HEAD_DIM].iter_mut().zip(vj) {
                        *o += wgt * vv;
                    }
                }
            }
            ops.proj_vec_into(li, "wo", &sc.attn_out, &mut sc.proj);
            for (a, b) in sc.x.iter_mut().zip(&sc.proj) {
                *a += b;
            }

            rmsnorm_vec_into(&sc.x, ops.ln2(li), cfg.norm_eps, &mut sc.hn);
            if cfg.family == Family::Opt {
                ops.proj_vec_into(li, "w1", &sc.hn, &mut sc.g);
                sc.g.iter_mut().for_each(|t| *t = gelu(*t));
                ops.proj_vec_into(li, "w2", &sc.g, &mut sc.ffn);
            } else {
                ops.proj_vec_into(li, "w1", &sc.hn, &mut sc.g);
                ops.proj_vec_into(li, "w3", &sc.hn, &mut sc.u);
                for (gi, ui) in sc.g.iter_mut().zip(&sc.u) {
                    *gi = silu(*gi) * ui;
                }
                ops.proj_vec_into(li, "w2", &sc.g, &mut sc.ffn);
            }
            for (a, b) in sc.x.iter_mut().zip(&sc.ffn) {
                *a += b;
            }
        }
        self.pos += 1;
        self.kv.on_token(token);
        rmsnorm_vec_into(&sc.x, ops.ln_f(), cfg.norm_eps, &mut sc.xn);
        crate::tensor::matvec(ops.embed_mat(), &sc.xn)
    }

    /// Process a chunk of C prompt tokens in one pass — the chunked-prefill
    /// fast path. Projections run once per layer over the stacked (C, ·)
    /// activation block via [`ModelOps::proj_chunk_into`], so a packed
    /// representation decodes each 6-bit meta word once per chunk instead of
    /// once per token; attention is causal within the chunk and reads
    /// earlier context from the KV store exactly like
    /// [`DecodeState::step_ops`].
    ///
    /// Returns logits as a Mat: all C rows when `all_logits` is true (the
    /// perplexity path), else just the final row (serving, where only the
    /// next-token distribution matters). The chunk may start at any
    /// position — prefix-cache resume lands mid-prompt at arbitrary,
    /// page-aligned-but-chunk-unaligned offsets — and the output is
    /// bit-identical to feeding the same tokens through `step_ops` one at a
    /// time, on flat and paged KV alike: per-position math only couples
    /// positions through the KV rows, every KV row written here is the same
    /// f32s `step_ops` would write, and the projections either reuse the
    /// decode row kernel verbatim (default seam) or share its per-word
    /// accumulation (packed v4 GEMM).
    pub fn prefill_chunk(
        &mut self,
        cfg: &ModelConfig,
        ops: &dyn ModelOps,
        tokens: &[u8],
        all_logits: bool,
    ) -> Mat {
        let c = tokens.len();
        if c == 0 {
            return Mat::zeros(0, ops.embed_mat().rows);
        }
        if c == 1 {
            // single-token chunks take the scalar hot path untouched
            let lg = self.step_ops(cfg, ops, tokens[0]);
            let n = lg.len();
            return Mat::from_vec(1, n, lg);
        }
        assert!(self.pos + c <= self.capacity, "KV cache capacity exceeded");
        let d = cfg.dim;
        let nh = cfg.n_heads();
        let p0 = self.pos;
        let scale = 1.0 / (HEAD_DIM as f32).sqrt();
        let (cos, sin) = (&self.rope.0, &self.rope.1);

        // stacked embeddings for the chunk
        let mut x = Mat::zeros(c, d);
        let emb = ops.embed_mat();
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(emb.row(t as usize));
            if let Some(pos_emb) = ops.pos_mat() {
                for (a, b) in x.row_mut(i).iter_mut().zip(pos_emb.row((p0 + i) % pos_emb.rows)) {
                    *a += b;
                }
            }
        }

        let mut xn = Mat::zeros(c, d);
        let mut q = Mat::zeros(c, d);
        let mut k = Mat::zeros(c, d);
        let mut v = Mat::zeros(c, d);
        let mut attn_out = Mat::zeros(c, d);
        let mut proj = Mat::zeros(c, d);
        let mut g = Mat::zeros(c, cfg.ffn_hidden);
        let mut u = Mat::zeros(c, cfg.ffn_hidden);
        let mut ffn = Mat::zeros(c, d);

        for li in 0..ops.n_layers() {
            for i in 0..c {
                rmsnorm_vec_into(x.row(i), ops.ln1(li), cfg.norm_eps, xn.row_mut(i));
            }
            ops.proj_chunk_into(li, "wq", &xn, &mut q);
            ops.proj_chunk_into(li, "wk", &xn, &mut k);
            ops.proj_chunk_into(li, "wv", &xn, &mut v);
            // rotate + append the whole chunk's KV rows before attending:
            // position p0+i only ever reads rows ≤ p0+i, so writing the
            // later rows early cannot leak acausal context
            for i in 0..c {
                let p = p0 + i;
                if cfg.family != Family::Opt {
                    for h in 0..nh {
                        let hd = h * HEAD_DIM..(h + 1) * HEAD_DIM;
                        apply_rope_vec(&mut q.row_mut(i)[hd.clone()], cos, sin, p);
                        apply_rope_vec(&mut k.row_mut(i)[hd], cos, sin, p);
                    }
                }
                self.kv.write(li, p, k.row(i), v.row(i));
            }
            attn_out.data.fill(0.0);
            for i in 0..c {
                let p = p0 + i;
                let lo = if cfg.window > 0 { (p + 1).saturating_sub(cfg.window) } else { 0 };
                let att = &mut self.scratch.att[..p + 1];
                for h in 0..nh {
                    let hoff = h * HEAD_DIM;
                    let qh = &q.row(i)[hoff..hoff + HEAD_DIM];
                    for j in lo..=p {
                        let kj = &self.kv.k_row(li, j)[hoff..hoff + HEAD_DIM];
                        att[j] = crate::tensor::dot(qh, kj) * scale;
                    }
                    softmax_inplace(&mut att[lo..=p]);
                    for j in lo..=p {
                        let wgt = att[j];
                        let vj = &self.kv.v_row(li, j)[hoff..hoff + HEAD_DIM];
                        for (o, vv) in
                            attn_out.row_mut(i)[hoff..hoff + HEAD_DIM].iter_mut().zip(vj)
                        {
                            *o += wgt * vv;
                        }
                    }
                }
            }
            ops.proj_chunk_into(li, "wo", &attn_out, &mut proj);
            x.add_assign(&proj);

            for i in 0..c {
                rmsnorm_vec_into(x.row(i), ops.ln2(li), cfg.norm_eps, xn.row_mut(i));
            }
            if cfg.family == Family::Opt {
                ops.proj_chunk_into(li, "w1", &xn, &mut g);
                g.data.iter_mut().for_each(|t| *t = gelu(*t));
                ops.proj_chunk_into(li, "w2", &g, &mut ffn);
            } else {
                ops.proj_chunk_into(li, "w1", &xn, &mut g);
                ops.proj_chunk_into(li, "w3", &xn, &mut u);
                for (gi, ui) in g.data.iter_mut().zip(&u.data) {
                    *gi = silu(*gi) * ui;
                }
                ops.proj_chunk_into(li, "w2", &g, &mut ffn);
            }
            x.add_assign(&ffn);
        }
        self.pos += c;
        // deferred page-publication hooks, in token order: by now every
        // layer's rows for the chunk are written, so each completed page is
        // whole when its boundary token publishes it — same page/hash
        // sequence the token-by-token path produces
        for &t in tokens {
            self.kv.on_token(t);
        }

        let first = if all_logits { 0 } else { c - 1 };
        let mut out = Mat::zeros(c - first, emb.rows);
        for (r, i) in (first..c).enumerate() {
            rmsnorm_vec_into(x.row(i), ops.ln_f(), cfg.norm_eps, &mut self.scratch.xn);
            crate::tensor::matvec_into(emb, &self.scratch.xn, out.row_mut(r));
        }
        out
    }
}

/// One fused decode tick over any representation: step each session one
/// token, computing every projection ONCE over the stacked (B, ·)
/// activation matrix so the weight stream is shared across sessions — for
/// the packed backend this is the §4.3 batching win: the sub-1-bit store is
/// read once per token-tick instead of once per session. Attention, norms
/// and the LM head run per-session in exactly the operation order of
/// [`DecodeState::step_ops`]; with a representation whose `proj` is
/// row-wise bit-consistent with `proj_vec` (true for the packed LUT
/// kernels, which share one row kernel) the fused tick reproduces
/// per-session decode bit-for-bit.
pub fn step_ops_batch(
    cfg: &ModelConfig,
    ops: &dyn ModelOps,
    states: &mut [&mut DecodeState],
    tokens: &[u8],
) -> Vec<Vec<f32>> {
    assert_eq!(states.len(), tokens.len());
    let bsz = states.len();
    if bsz == 0 {
        return Vec::new();
    }
    let d = cfg.dim;
    let nh = cfg.n_heads();
    let scale = 1.0 / (HEAD_DIM as f32).sqrt();

    // stacked embeddings (each session may sit at a different position)
    let mut x = Mat::zeros(bsz, d);
    for (i, (st, &tok)) in states.iter().zip(tokens).enumerate() {
        assert!(st.pos < st.capacity, "KV cache capacity exceeded");
        x.row_mut(i).copy_from_slice(ops.embed_mat().row(tok as usize));
        if let Some(pos_emb) = ops.pos_mat() {
            for (a, b) in x.row_mut(i).iter_mut().zip(pos_emb.row(st.pos % pos_emb.rows)) {
                *a += b;
            }
        }
    }

    for li in 0..ops.n_layers() {
        let xn = rmsnorm(&x, ops.ln1(li), cfg.norm_eps);
        let mut q = ops.proj(li, "wq", &xn);
        let mut k = ops.proj(li, "wk", &xn);
        let v = ops.proj(li, "wv", &xn);
        let mut attn_out = Mat::zeros(bsz, d);
        for (i, st) in states.iter_mut().enumerate() {
            let p = st.pos;
            if cfg.family != Family::Opt {
                let (cos, sin) = (&st.rope.0, &st.rope.1);
                for h in 0..nh {
                    let hd = h * HEAD_DIM..(h + 1) * HEAD_DIM;
                    apply_rope_vec(&mut q.row_mut(i)[hd.clone()], cos, sin, p);
                    apply_rope_vec(&mut k.row_mut(i)[hd], cos, sin, p);
                }
            }
            st.kv.write(li, p, k.row(i), v.row(i));

            let lo = if cfg.window > 0 { (p + 1).saturating_sub(cfg.window) } else { 0 };
            let att = &mut st.scratch.att[..p + 1];
            for h in 0..nh {
                let hoff = h * HEAD_DIM;
                let qh = &q.row(i)[hoff..hoff + HEAD_DIM];
                for j in lo..=p {
                    let kj = &st.kv.k_row(li, j)[hoff..hoff + HEAD_DIM];
                    att[j] = crate::tensor::dot(qh, kj) * scale;
                }
                softmax_inplace(&mut att[lo..=p]);
                for j in lo..=p {
                    let wgt = att[j];
                    let vj = &st.kv.v_row(li, j)[hoff..hoff + HEAD_DIM];
                    for (o, vv) in attn_out.row_mut(i)[hoff..hoff + HEAD_DIM].iter_mut().zip(vj) {
                        *o += wgt * vv;
                    }
                }
            }
        }
        let proj = ops.proj(li, "wo", &attn_out);
        x.add_assign(&proj);

        let hn = rmsnorm(&x, ops.ln2(li), cfg.norm_eps);
        let ffn = if cfg.family == Family::Opt {
            let mut a = ops.proj(li, "w1", &hn);
            a.data.iter_mut().for_each(|t| *t = gelu(*t));
            ops.proj(li, "w2", &a)
        } else {
            let mut g = ops.proj(li, "w1", &hn);
            let u = ops.proj(li, "w3", &hn);
            for (gi, ui) in g.data.iter_mut().zip(&u.data) {
                *gi = silu(*gi) * ui;
            }
            ops.proj(li, "w2", &g)
        };
        x.add_assign(&ffn);
    }
    for (st, &tok) in states.iter_mut().zip(tokens) {
        st.pos += 1;
        st.kv.on_token(tok);
    }
    let xn = rmsnorm(&x, ops.ln_f(), cfg.norm_eps);
    // per-row matvec (not matmul_bt) so the head bit-matches the
    // per-session step
    (0..bsz).map(|i| crate::tensor::matvec(ops.embed_mat(), xn.row(i))).collect()
}

/// Vector rmsnorm into caller-owned storage; the math is the row loop of
/// [`rmsnorm`] verbatim, so the decode path bit-matches the full forward
/// (and the fused batch step bit-matches the per-session step).
fn rmsnorm_vec_into(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ms + eps).sqrt();
    for (o, (v, g)) in out.iter_mut().zip(x.iter().zip(w)) {
        *o = v * inv * g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(name: &str) -> (ModelConfig, ModelWeights) {
        let cfg = ModelConfig::preset(name).unwrap();
        let w = ModelWeights::synthetic(&cfg, 7);
        (cfg, w)
    }

    #[test]
    fn fwd_shapes_all_families() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let (cfg, w) = tiny(name);
            let toks: Vec<u8> = (0..32u8).collect();
            let logits = model_fwd(&cfg, &w, &toks);
            assert_eq!((logits.rows, logits.cols), (32, cfg.vocab), "{name}");
            assert!(logits.data.iter().all(|v| v.is_finite()), "{name}");
        }
    }

    #[test]
    fn causality_holds() {
        let (cfg, w) = tiny("llama1-7b");
        let mut toks: Vec<u8> = (0..24u8).collect();
        let l1 = model_fwd(&cfg, &w, &toks);
        toks[23] = 99;
        let l2 = model_fwd(&cfg, &w, &toks);
        for i in 0..23 {
            for j in 0..cfg.vocab {
                assert!((l1[(i, j)] - l2[(i, j)]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn decode_matches_full_forward() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let (cfg, w) = tiny(name);
            let toks: Vec<u8> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8];
            let full = model_fwd(&cfg, &w, &toks);
            let mut st = DecodeState::new(&cfg, 32);
            let mut last = Vec::new();
            for &t in &toks {
                last = st.step(&cfg, &w, t);
            }
            let want = full.row(toks.len() - 1);
            for (a, b) in last.iter().zip(want) {
                assert!((a - b).abs() < 1e-3, "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn taps_captured_with_right_shapes() {
        let (cfg, w) = tiny("llama1-7b");
        let toks: Vec<u8> = (0..16u8).collect();
        let (_, taps) = model_fwd_with_taps(&cfg, &w, &toks);
        assert_eq!(taps.len(), cfg.n_layers);
        let t = &taps[0];
        assert_eq!(t.attn_in.as_ref().unwrap().cols, cfg.dim);
        assert_eq!(t.wo_in.as_ref().unwrap().cols, cfg.dim);
        assert_eq!(t.ffn_in.as_ref().unwrap().cols, cfg.dim);
        assert_eq!(t.w2_in.as_ref().unwrap().cols, cfg.ffn_hidden);
        assert_eq!(t.w2_in.as_ref().unwrap().rows, 16);
    }

    #[test]
    fn sliding_window_changes_late_logits_only() {
        let cfg_w = ModelConfig::preset("mistral-7b").unwrap();
        let mut cfg_full = cfg_w.clone();
        cfg_full.window = 0;
        let w = ModelWeights::synthetic(&cfg_w, 9);
        let toks: Vec<u8> = (0..100).map(|i| (i * 7 % 32) as u8).collect();
        let a = model_fwd(&cfg_w, &w, &toks);
        let b = model_fwd(&cfg_full, &w, &toks);
        // within the window everything matches
        for j in 0..cfg_w.vocab {
            assert!((a[(10, j)] - b[(10, j)]).abs() < 1e-4);
        }
        // beyond it, logits differ
        let diff: f32 = (0..cfg_w.vocab).map(|j| (a[(99, j)] - b[(99, j)]).abs()).sum();
        assert!(diff > 1e-4);
    }

    /// Fused batch stepping must agree with independent per-session steps —
    /// including sessions at DIFFERENT positions (continuous batching).
    #[test]
    fn batch_step_matches_per_session_steps() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let (cfg, w) = tiny(name);
            // session 0 starts 3 tokens ahead of session 1
            let mut solo0 = DecodeState::new(&cfg, 32);
            let mut solo1 = DecodeState::new(&cfg, 32);
            let mut fused0 = DecodeState::new(&cfg, 32);
            let mut fused1 = DecodeState::new(&cfg, 32);
            for &t in &[7u8, 2, 9] {
                solo0.step_ops(&cfg, &w, t);
                fused0.step_ops(&cfg, &w, t);
            }
            let ticks: Vec<(u8, u8)> = vec![(1, 4), (6, 3), (2, 2), (8, 5)];
            for &(t0, t1) in &ticks {
                let want0 = solo0.step_ops(&cfg, &w, t0);
                let want1 = solo1.step_ops(&cfg, &w, t1);
                let got = {
                    let mut states = [&mut fused0, &mut fused1];
                    step_ops_batch(&cfg, &w, &mut states, &[t0, t1])
                };
                assert_eq!(got.len(), 2);
                for (a, b) in got[0].iter().zip(&want0) {
                    assert!((a - b).abs() < 1e-3, "{name} s0: {a} vs {b}");
                }
                for (a, b) in got[1].iter().zip(&want1) {
                    assert!((a - b).abs() < 1e-3, "{name} s1: {a} vs {b}");
                }
            }
            assert_eq!(fused0.pos, solo0.pos);
            assert_eq!(fused1.pos, solo1.pos);
        }
    }

    #[test]
    fn batch_step_empty_is_noop() {
        let (cfg, w) = tiny("llama1-7b");
        let out = step_ops_batch(&cfg, &w, &mut [], &[]);
        assert!(out.is_empty());
    }

    /// Paged KV storage must reproduce the flat path bit-for-bit — same
    /// f32 rows, different residency.
    #[test]
    fn paged_decode_bitmatches_flat_decode() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let (cfg, w) = tiny(name);
            let toks: Vec<u8> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 8, 9, 7];
            for page_size in [4usize, 16] {
                let pool = Arc::new(KvPool::new(&cfg, 16, page_size));
                let mut flat = DecodeState::new(&cfg, 32);
                let mut paged = DecodeState::new_paged(&cfg, 32, &pool, &toks).unwrap();
                assert_eq!(paged.pos, 0, "fresh pool must not prefix-match");
                for &t in &toks {
                    let a = flat.step_ops(&cfg, &w, t);
                    let b = paged.step_ops(&cfg, &w, t);
                    assert_eq!(a, b, "{name} ps={page_size}: paged must bit-match flat");
                }
            }
        }
    }

    /// A second paged session sharing the first's prompt starts at
    /// `pos == matched` and still produces bit-identical logits.
    #[test]
    fn prefix_matched_session_bitmatches_fresh_session() {
        let (cfg, w) = tiny("llama1-7b");
        let toks: Vec<u8> = (0..20).map(|i| (i * 3 % 32) as u8).collect();
        let pool = Arc::new(KvPool::new(&cfg, 32, 4));
        let mut first = DecodeState::new_paged(&cfg, 32, &pool, &toks).unwrap();
        let mut want = Vec::new();
        for &t in &toks {
            want.push(first.step_ops(&cfg, &w, t));
        }
        let mut second = DecodeState::new_paged(&cfg, 32, &pool, &toks).unwrap();
        let matched = second.pos;
        assert!(matched >= 16, "expected ≥4 reused pages, matched {matched}");
        for (p, &t) in toks.iter().enumerate().skip(matched) {
            let got = second.step_ops(&cfg, &w, t);
            assert_eq!(got, want[p], "prefix-matched logits must bit-match");
        }
    }

    /// Chunked prefill must reproduce token-by-token stepping bit-for-bit:
    /// every chunk size {1, 3, 8, 32}, a word-unaligned prompt length, all
    /// model families (incl. the sliding-window one), flat KV.
    #[test]
    fn prefill_chunk_bitmatches_step_ops_flat() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let (cfg, w) = tiny(name);
            let toks: Vec<u8> = (0..13).map(|i| (i * 5 % 32) as u8).collect();
            let mut base = DecodeState::new(&cfg, 64);
            let want: Vec<Vec<f32>> = toks.iter().map(|&t| base.step_ops(&cfg, &w, t)).collect();
            for cs in [1usize, 3, 8, 32] {
                let mut st = DecodeState::new(&cfg, 64);
                let mut got: Vec<Vec<f32>> = Vec::new();
                for chunk in toks.chunks(cs) {
                    let lg = st.prefill_chunk(&cfg, &w, chunk, true);
                    assert_eq!((lg.rows, lg.cols), (chunk.len(), cfg.vocab));
                    got.extend((0..lg.rows).map(|r| lg.row(r).to_vec()));
                }
                assert_eq!(st.pos, toks.len());
                for (p, (a, b)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(a, b, "{name} cs={cs} pos={p}");
                }
            }
        }
    }

    /// `all_logits: false` keeps only the final row; the empty chunk is a
    /// position-preserving no-op.
    #[test]
    fn prefill_chunk_last_row_and_empty_chunk() {
        let (cfg, w) = tiny("llama1-7b");
        let toks: Vec<u8> = vec![3, 1, 4, 1, 5, 9, 2];
        let mut a = DecodeState::new(&cfg, 32);
        let full = a.prefill_chunk(&cfg, &w, &toks, true);
        let mut b = DecodeState::new(&cfg, 32);
        let last = b.prefill_chunk(&cfg, &w, &toks, false);
        assert_eq!((last.rows, last.cols), (1, cfg.vocab));
        assert_eq!(last.row(0), full.row(full.rows - 1));
        let e = b.prefill_chunk(&cfg, &w, &[], true);
        assert_eq!((e.rows, e.cols), (0, cfg.vocab));
        assert_eq!(b.pos, toks.len());
    }

    /// Chunked prefill over paged KV bit-matches flat, and per-token decode
    /// continues seamlessly from the chunked state.
    #[test]
    fn prefill_chunk_paged_bitmatches_flat() {
        for name in ["llama1-7b", "opt-1.3b", "mistral-7b"] {
            let (cfg, w) = tiny(name);
            let toks: Vec<u8> = (0..14).map(|i| (i * 3 % 32) as u8).collect();
            let mut flat = DecodeState::new(&cfg, 32);
            let want = flat.prefill_chunk(&cfg, &w, &toks, true);
            let pool = Arc::new(KvPool::new(&cfg, 16, 4));
            let mut paged = DecodeState::new_paged(&cfg, 32, &pool, &toks).unwrap();
            assert_eq!(paged.pos, 0, "fresh pool must not prefix-match");
            let got = paged.prefill_chunk(&cfg, &w, &toks, true);
            assert_eq!(got.data, want.data, "{name}: paged chunk must bit-match flat");
            let a = flat.step_ops(&cfg, &w, 9);
            let b = paged.step_ops(&cfg, &w, 9);
            assert_eq!(a, b, "{name}: decode after chunked prefill must bit-match");
        }
    }

    /// Prefix-cache resume lands at page-aligned but chunk-unaligned
    /// positions; `prefill_chunk` must continue bit-exactly from there.
    #[test]
    fn prefill_chunk_resumes_mid_prompt_after_prefix_hit() {
        let (cfg, w) = tiny("llama1-7b");
        let toks: Vec<u8> = (0..19).map(|i| (i * 3 % 32) as u8).collect();
        let pool = Arc::new(KvPool::new(&cfg, 32, 4));
        let mut first = DecodeState::new_paged(&cfg, 32, &pool, &toks).unwrap();
        let mut want = Vec::new();
        for &t in &toks {
            want.push(first.step_ops(&cfg, &w, t));
        }
        let mut second = DecodeState::new_paged(&cfg, 32, &pool, &toks).unwrap();
        let matched = second.pos;
        assert!(matched >= 16, "expected ≥4 reused pages, matched {matched}");
        let got = second.prefill_chunk(&cfg, &w, &toks[matched..], true);
        assert_eq!(got.rows, toks.len() - matched);
        for (r, p) in (matched..toks.len()).enumerate() {
            assert_eq!(got.row(r), &want[p][..], "resume pos {p} must bit-match");
        }
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = Mat::from_vec(1, 4, vec![2.0, -2.0, 2.0, -2.0]);
        let out = rmsnorm(&x, &[1.0; 4], 0.0);
        for v in out.data {
            assert!((v.abs() - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn ops_forward_matches_dense_entry_point() {
        // model_fwd_ops over the ModelWeights impl IS model_fwd; pin it.
        let (cfg, w) = tiny("opt-1.3b");
        let toks: Vec<u8> = (0..12u8).collect();
        let a = model_fwd(&cfg, &w, &toks);
        let b = model_fwd_ops(&w, &cfg, &toks);
        assert_eq!(a.data, b.data);
    }
}
