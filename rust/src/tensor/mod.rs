//! Dense f32 matrix substrate.
//!
//! Row-major `Mat` with the operations the PTQ pipeline and the native
//! transformer forward need: blocked matmuls (`matmul`, `matmul_bt`,
//! `gram`), norms/statistics, Cholesky factorization + inverse (for the OBC
//! Hessian), and elementwise helpers. Hot loops are written so rustc
//! auto-vectorizes them (contiguous row dots with multiple accumulators) —
//! see EXPERIMENTS.md §Perf for measured GFLOP/s.

pub mod linalg;

use crate::util::rng::Pcg32;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len(), "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn random(rows: usize, cols: usize, std: f32, rng: &mut Pcg32) -> Mat {
        let data = (0..rows * cols).map(|_| rng.normal() * std).collect();
        Mat::from_vec(rows, cols, data)
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols;
        &mut self.data[i * c..(i + 1) * c]
    }

    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Copy of columns `[c0, c1)`.
    pub fn slice_cols(&self, c0: usize, c1: usize) -> Mat {
        let w = c1 - c0;
        let mut out = Mat::zeros(self.rows, w);
        for i in 0..self.rows {
            out.row_mut(i).copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Write `src` into columns `[c0, c0+src.cols)`.
    pub fn set_cols(&mut self, c0: usize, src: &Mat) {
        assert_eq!(self.rows, src.rows);
        for i in 0..self.rows {
            let c = self.cols;
            self.data[i * c + c0..i * c + c0 + src.cols].copy_from_slice(src.row(i));
        }
    }

    // ---- reductions ------------------------------------------------------

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn l1_norm(&self) -> f32 {
        self.data.iter().map(|x| x.abs()).sum()
    }

    pub fn mean(&self) -> f32 {
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn std(&self) -> f32 {
        let m = self.mean();
        (self.data.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / self.data.len() as f32).sqrt()
    }

    /// L2 norm of each column (Wanda / SI input-feature norms).
    pub fn col_l2_norms(&self) -> Vec<f32> {
        let mut acc = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            let r = self.row(i);
            for (a, &x) in acc.iter_mut().zip(r) {
                *a += x * x;
            }
        }
        acc.iter_mut().for_each(|a| *a = a.sqrt());
        acc
    }

    /// Sum of |x| per row.
    pub fn row_l1_sums(&self) -> Vec<f32> {
        (0..self.rows).map(|i| self.row(i).iter().map(|x| x.abs()).sum()).collect()
    }

    /// Sum of |x| per column.
    pub fn col_l1_sums(&self) -> Vec<f32> {
        let mut acc = vec![0.0f32; self.cols];
        for i in 0..self.rows {
            for (a, &x) in acc.iter_mut().zip(self.row(i)) {
                *a += x.abs();
            }
        }
        acc
    }

    // ---- elementwise -----------------------------------------------------

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    pub fn scale(&mut self, s: f32) {
        self.data.iter_mut().for_each(|x| *x *= s);
    }

    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Mat {
        Mat::from_vec(self.rows, self.cols, self.data.iter().map(|&x| f(x)).collect())
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        let c = self.cols;
        &mut self.data[i * c + j]
    }
}

// ---------------------------------------------------------------------------
// Matmuls
// ---------------------------------------------------------------------------

/// Contiguous dot product with 4 accumulators — autovectorizes well.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let chunks = a.len() / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 8;
        s0 += a[i] * b[i] + a[i + 4] * b[i + 4];
        s1 += a[i + 1] * b[i + 1] + a[i + 5] * b[i + 5];
        s2 += a[i + 2] * b[i + 2] + a[i + 6] * b[i + 6];
        s3 += a[i + 3] * b[i + 3] + a[i + 7] * b[i + 7];
    }
    let mut tail = 0.0f32;
    for i in chunks * 8..a.len() {
        tail += a[i] * b[i];
    }
    s0 + s1 + s2 + s3 + tail
}

/// `axpy`: y += s * x over contiguous slices.
#[inline]
pub fn axpy(y: &mut [f32], s: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += s * xi;
    }
}

/// C = A @ B. ikj loop: each A[i][k] broadcasts over B's row k (contiguous).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul shape mismatch");
    let mut c = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (k, &aik) in arow.iter().enumerate() {
            if aik != 0.0 {
                axpy(crow, aik, b.row(k));
            }
        }
    }
    c
}

/// C = A @ B^T, reference row-dot form (kept for perf comparisons; the
/// optimized `matmul_bt` below is asserted equal in tests).
pub fn matmul_bt_naive(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_bt shape mismatch");
    let mut c = Mat::zeros(a.rows, b.rows);
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for j in 0..b.rows {
            crow[j] = dot(arow, b.row(j));
        }
    }
    c
}

/// C = A @ B^T. 4-way unroll over B's rows: each pass over A's row computes
/// four outputs, quartering the A-row traffic (the native-forward hot loop —
/// see EXPERIMENTS.md §Perf L3).
pub fn matmul_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_bt shape mismatch");
    let k = a.cols;
    let mut c = Mat::zeros(a.rows, b.rows);
    let j4 = b.rows / 4 * 4;
    for i in 0..a.rows {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        let mut j = 0;
        while j < j4 {
            let b0 = b.row(j);
            let b1 = b.row(j + 1);
            let b2 = b.row(j + 2);
            let b3 = b.row(j + 3);
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for t in 0..k {
                let x = arow[t];
                s0 += x * b0[t];
                s1 += x * b1[t];
                s2 += x * b2[t];
                s3 += x * b3[t];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        while j < b.rows {
            crow[j] = dot(arow, b.row(j));
            j += 1;
        }
    }
    c
}

/// Gram matrix `X^T X` (symmetric; computes the upper triangle and mirrors).
/// This is the Hessian accumulation hot spot (`H = 2 X X^T` in the paper's
/// row-vector convention; our X is (tokens, K) so H = 2 X^T X).
pub fn gram(x: &Mat) -> Mat {
    let k = x.cols;
    let mut g = Mat::zeros(k, k);
    // accumulate rank-1 updates row by row: upper triangle only
    for t in 0..x.rows {
        let r = x.row(t);
        for i in 0..k {
            let xi = r[i];
            if xi != 0.0 {
                let gi = &mut g.data[i * k..i * k + k];
                // j >= i only
                for j in i..k {
                    gi[j] += xi * r[j];
                }
            }
        }
    }
    for i in 0..k {
        for j in 0..i {
            g.data[i * k + j] = g.data[j * k + i];
        }
    }
    g
}

/// y = A @ x for a vector x.
pub fn matvec(a: &Mat, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; a.rows];
    matvec_into(a, x, &mut y);
    y
}

/// y = A @ x into caller-owned storage (the zero-allocation decode path);
/// bit-identical to [`matvec`] — same `dot` per output row.
pub fn matvec_into(a: &Mat, x: &[f32], y: &mut [f32]) {
    assert_eq!(a.cols, x.len());
    assert_eq!(a.rows, y.len());
    for (i, out) in y.iter_mut().enumerate() {
        *out = dot(a.row(i), x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a[(i, k)] * b[(k, j)];
                }
                c[(i, j)] = s;
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg32::seeded(1);
        let a = Mat::random(13, 29, 1.0, &mut rng);
        let b = Mat::random(29, 17, 1.0, &mut rng);
        let c1 = matmul(&a, &b);
        let c2 = naive_matmul(&a, &b);
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_bt_matches_matmul_with_transpose() {
        let mut rng = Pcg32::seeded(2);
        let a = Mat::random(9, 33, 1.0, &mut rng);
        let b = Mat::random(21, 33, 1.0, &mut rng);
        let c1 = matmul_bt(&a, &b);
        let c2 = matmul(&a, &b.transpose());
        for (x, y) in c1.data.iter().zip(&c2.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_bt_optimized_matches_naive() {
        let mut rng = Pcg32::seeded(7);
        // sizes that exercise the 4-way unroll remainder paths
        for (m, k, n) in [(3usize, 17usize, 5usize), (8, 64, 12), (5, 31, 7), (1, 8, 4)] {
            let a = Mat::random(m, k, 1.0, &mut rng);
            let b = Mat::random(n, k, 1.0, &mut rng);
            let c1 = matmul_bt(&a, &b);
            let c2 = matmul_bt_naive(&a, &b);
            for (x, y) in c1.data.iter().zip(&c2.data) {
                assert!((x - y).abs() < 1e-4, "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn gram_is_xtx() {
        let mut rng = Pcg32::seeded(3);
        let x = Mat::random(40, 15, 1.0, &mut rng);
        let g1 = gram(&x);
        let g2 = matmul(&x.transpose(), &x);
        for (a, b) in g1.data.iter().zip(&g2.data) {
            assert!((a - b).abs() < 1e-3);
        }
        // symmetry
        for i in 0..15 {
            for j in 0..15 {
                assert!((g1[(i, j)] - g1[(j, i)]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg32::seeded(4);
        let a = Mat::random(37, 53, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn slice_set_cols_roundtrip() {
        let mut rng = Pcg32::seeded(5);
        let a = Mat::random(8, 12, 1.0, &mut rng);
        let s = a.slice_cols(3, 9);
        assert_eq!(s.cols, 6);
        let mut b = Mat::zeros(8, 12);
        b.set_cols(3, &s);
        for i in 0..8 {
            for j in 3..9 {
                assert_eq!(b[(i, j)], a[(i, j)]);
            }
        }
    }

    #[test]
    fn norms_and_stats() {
        let m = Mat::from_vec(2, 2, vec![3.0, -4.0, 0.0, 0.0]);
        assert!((m.frob_norm() - 5.0).abs() < 1e-6);
        assert!((m.l1_norm() - 7.0).abs() < 1e-6);
        let cn = m.col_l2_norms();
        assert!((cn[0] - 3.0).abs() < 1e-6 && (cn[1] - 4.0).abs() < 1e-6);
        assert_eq!(m.row_l1_sums(), vec![7.0, 0.0]);
        assert_eq!(m.col_l1_sums(), vec![3.0, 4.0]);
    }

    #[test]
    fn dot_and_axpy() {
        let a: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..19).map(|i| (i * 2) as f32).collect();
        let want: f32 = (0..19).map(|i| (i * i * 2) as f32).sum();
        assert!((dot(&a, &b) - want).abs() < 1e-3);
        let mut y = vec![1.0f32; 5];
        axpy(&mut y, 2.0, &[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(y, vec![3.0, 5.0, 7.0, 9.0, 11.0]);
    }

    #[test]
    fn matvec_works() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(matvec(&a, &[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
    }
}
