//! Dense linear algebra for the OBC/GPTQ Hessian path: Cholesky
//! factorization, triangular solves, symmetric inverse, and the
//! "upper-Cholesky-of-inverse" helper that GPTQ/BiLLM/STBLLM all use
//! (`H^c = Cholesky((H + λI)^{-1})`, Algorithm 1 line 5).

use super::Mat;

/// Lower-triangular Cholesky factor L with A = L L^T.
/// Returns Err if A is not (numerically) positive definite.
pub fn cholesky(a: &Mat) -> Result<Mat, String> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // dot over the shared prefix of rows i and j
            let mut s = 0.0f64;
            for k in 0..j {
                s += l.data[i * n + k] as f64 * l.data[j * n + k] as f64;
            }
            let aij = a[(i, j)] as f64;
            if i == j {
                let d = aij - s;
                if d <= 0.0 || !d.is_finite() {
                    return Err(format!("not positive definite at pivot {i} (d={d})"));
                }
                l[(i, i)] = d.sqrt() as f32;
            } else {
                l[(i, j)] = ((aij - s) / l[(j, j)] as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Solve L y = b for lower-triangular L (forward substitution).
pub fn solve_lower(l: &Mat, b: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= l.data[i * n + k] as f64 * y[k] as f64;
        }
        y[i] = (s / l[(i, i)] as f64) as f32;
    }
    y
}

/// Solve L^T x = y for lower-triangular L (back substitution).
pub fn solve_lower_t(l: &Mat, y: &[f32]) -> Vec<f32> {
    let n = l.rows;
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = y[i] as f64;
        for k in i + 1..n {
            s -= l.data[k * n + i] as f64 * x[k] as f64;
        }
        x[i] = (s / l[(i, i)] as f64) as f32;
    }
    x
}

/// Symmetric positive-definite inverse via Cholesky:
/// A^{-1} column j = solve(L L^T, e_j).
pub fn spd_inverse(a: &Mat) -> Result<Mat, String> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Mat::zeros(n, n);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_lower_t(&l, &y);
        for i in 0..n {
            inv[(i, j)] = x[i];
        }
        e[j] = 0.0;
    }
    // symmetrize (kills accumulated asymmetry)
    for i in 0..n {
        for j in 0..i {
            let m = 0.5 * (inv[(i, j)] + inv[(j, i)]);
            inv[(i, j)] = m;
            inv[(j, i)] = m;
        }
    }
    Ok(inv)
}

/// GPTQ-style `H^c`: the UPPER Cholesky factor of `(H + λI)^{-1}`,
/// i.e. U with `inv = U^T U`... we follow torch's
/// `cholesky(cholesky_inverse(cholesky(H)), upper=True)` which returns U
/// such that inv = U U^T is FALSE — torch upper means inv = U^T U with U
/// upper-triangular. We return U = L^T where L = cholesky(inv).
///
/// Only the diagonal and the rows above/right of the current block are used
/// by the OBC update, and the unit tests pin the exact semantics.
pub fn hessian_chol_inv(h: &Mat, lambda: f32) -> Result<Mat, String> {
    let n = h.rows;
    let mut damped = h.clone();
    // damping: λ * mean(diag) * I, the standard GPTQ "percdamp" scheme
    let mean_diag: f32 = (0..n).map(|i| damped[(i, i)]).sum::<f32>() / n as f32;
    let eps = (lambda * mean_diag).max(1e-8);
    for i in 0..n {
        damped[(i, i)] += eps;
    }
    let inv = spd_inverse(&damped)?;
    let l = cholesky(&inv)?;
    Ok(l.transpose()) // upper factor
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{gram, matmul, matmul_bt};
    use crate::util::rng::Pcg32;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg32::seeded(seed);
        let x = Mat::random(n + 8, n, 1.0, &mut rng);
        let mut g = gram(&x);
        for i in 0..n {
            g[(i, i)] += 0.5;
        }
        g
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = random_spd(12, 1);
        let l = cholesky(&a).unwrap();
        let rec = matmul_bt(&l, &l); // L L^T
        for (x, y) in rec.data.iter().zip(&a.data) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = Mat::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn solves_invert_cholesky() {
        let a = random_spd(10, 2);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = (0..10).map(|i| (i as f32) - 4.5).collect();
        let y = solve_lower(&l, &b);
        let x = solve_lower_t(&l, &y);
        // A x should equal b
        let ax = crate::tensor::matvec(&a, &x);
        for (u, v) in ax.iter().zip(&b) {
            assert!((u - v).abs() < 1e-2, "{u} vs {v}");
        }
    }

    #[test]
    fn spd_inverse_is_inverse() {
        let a = random_spd(9, 3);
        let inv = spd_inverse(&a).unwrap();
        let prod = matmul(&a, &inv);
        for i in 0..9 {
            for j in 0..9 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - want).abs() < 1e-2, "({i},{j}) {}", prod[(i, j)]);
            }
        }
    }

    #[test]
    fn hessian_chol_inv_is_upper_and_reconstructs_inverse() {
        let h = random_spd(8, 4);
        let u = hessian_chol_inv(&h, 0.01).unwrap();
        // upper-triangular
        for i in 0..8 {
            for j in 0..i {
                assert_eq!(u[(i, j)], 0.0);
            }
        }
        // U^T U ≈ (H + λ mean_diag I)^{-1}
        let ut = u.transpose();
        let rec = matmul(&ut, &u);
        let mut damped = h.clone();
        let md: f32 = (0..8).map(|i| h[(i, i)]).sum::<f32>() / 8.0;
        for i in 0..8 {
            damped[(i, i)] += 0.01 * md;
        }
        let inv = spd_inverse(&damped).unwrap();
        for (x, y) in rec.data.iter().zip(&inv.data) {
            assert!((x - y).abs() < 2e-2, "{x} vs {y}");
        }
    }

    #[test]
    fn diag_positive() {
        let h = random_spd(16, 5);
        let u = hessian_chol_inv(&h, 0.01).unwrap();
        for i in 0..16 {
            assert!(u[(i, i)] > 0.0);
        }
    }
}
