//! N:M structured sparsity mask selection.
//!
//! Per output row, within every group of M consecutive input positions, keep
//! the N highest-scoring elements. This is exactly the layout Ampere sparse
//! tensor cores (and our `packed` simulator) consume.

use crate::tensor::Mat;

/// An N:M ratio (keep `n` of every `m`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NmRatio {
    pub n: usize,
    pub m: usize,
}

impl NmRatio {
    pub fn new(n: usize, m: usize) -> NmRatio {
        assert!(n >= 1 && n <= m, "invalid N:M {n}:{m}");
        NmRatio { n, m }
    }

    /// Parse "4:8" style strings.
    pub fn parse(s: &str) -> Option<NmRatio> {
        let (a, b) = s.split_once(':')?;
        let n = a.trim().parse().ok()?;
        let m = b.trim().parse().ok()?;
        (n >= 1 && n <= m).then(|| NmRatio::new(n, m))
    }

    pub fn density(&self) -> f64 {
        self.n as f64 / self.m as f64
    }

    pub fn label(&self) -> String {
        format!("{}:{}", self.n, self.m)
    }
}

/// Boolean keep-mask (row-major, same layout as `w`): within each row-group
/// of `m` columns keep the `n` largest scores. A trailing partial group
/// keeps `ceil(width * n/m)` elements so overall density is preserved.
pub fn nm_mask(scores: &Mat, nm: NmRatio) -> Vec<bool> {
    let (rows, cols) = (scores.rows, scores.cols);
    let mut mask = vec![false; rows * cols];
    let mut idx: Vec<usize> = Vec::with_capacity(nm.m);
    for i in 0..rows {
        let srow = scores.row(i);
        let mrow = &mut mask[i * cols..(i + 1) * cols];
        let mut g = 0;
        while g < cols {
            let width = nm.m.min(cols - g);
            let keep = if width == nm.m {
                nm.n
            } else {
                ((width * nm.n + nm.m - 1) / nm.m).max(1)
            };
            idx.clear();
            idx.extend(g..g + width);
            idx.sort_by(|&a, &b| srow[b].partial_cmp(&srow[a]).unwrap_or(std::cmp::Ordering::Equal));
            for &j in idx.iter().take(keep) {
                mrow[j] = true;
            }
            g += width;
        }
    }
    mask
}

/// Density of a mask (kept fraction).
pub fn mask_density(mask: &[bool]) -> f64 {
    mask.iter().filter(|&&b| b).count() as f64 / mask.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{gen_vec, prop_check};

    #[test]
    fn parse_ratio() {
        let r = NmRatio::parse("4:8").unwrap();
        assert_eq!((r.n, r.m), (4, 8));
        assert!(NmRatio::parse("9:8").is_none());
        assert!(NmRatio::parse("0:8").is_none());
        assert!(NmRatio::parse("48").is_none());
        assert_eq!(r.label(), "4:8");
        assert!((r.density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn keeps_exactly_n_per_group() {
        prop_check("nm keeps exactly n per full group", 60, |rng| {
            let m = [4usize, 8][rng.bounded(2) as usize];
            let n = 1 + rng.bounded(m as u32) as usize;
            let rows = 1 + rng.bounded(6) as usize;
            let cols = m * (1 + rng.bounded(8) as usize);
            let s = Mat::from_vec(rows, cols, gen_vec(rng, rows * cols, 1.0));
            let mask = nm_mask(&s, NmRatio::new(n, m));
            for i in 0..rows {
                for g in (0..cols).step_by(m) {
                    let cnt = (g..g + m).filter(|&j| mask[i * cols + j]).count();
                    prop_assert!(cnt == n, "row {i} group {g}: kept {cnt} != {n}");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn keeps_top_scores() {
        let s = Mat::from_vec(1, 8, vec![0.9, 0.1, 0.5, 0.3, 0.2, 0.8, 0.7, 0.6]);
        let mask = nm_mask(&s, NmRatio::new(2, 4));
        assert_eq!(mask, vec![true, false, true, false, false, true, true, false]);
    }

    #[test]
    fn partial_group_preserves_density() {
        let s = Mat::from_vec(1, 10, (0..10).map(|i| i as f32).collect());
        let mask = nm_mask(&s, NmRatio::new(4, 8));
        // full group keeps 4; trailing width-2 group keeps ceil(2*4/8)=1
        assert_eq!(mask.iter().filter(|&&b| b).count(), 5);
    }

    #[test]
    fn density_matches_ratio() {
        prop_check("density == n/m", 30, |rng| {
            let s = Mat::from_vec(4, 64, gen_vec(rng, 256, 1.0));
            for (n, m) in [(2, 4), (4, 8), (5, 8), (6, 8)] {
                let mask = nm_mask(&s, NmRatio::new(n, m));
                let d = mask_density(&mask);
                prop_assert!((d - n as f64 / m as f64).abs() < 1e-9, "{n}:{m} d={d}");
            }
            Ok(())
        });
    }
}
