//! Non-salient Aware Quantization (paper §3.4 + Algorithm 2).
//!
//! The non-salient weights are ~symmetric-Gaussian; a trisection search finds
//! break-points `p1* < p2*` splitting |w| into **dense** `[0, p1]`,
//! **intermediate** `(p1, p2]` and **sparse** `(p2, max]` regions, each
//! binarized with its own scale (Eq. 5–6). The O(N) search links
//! `p2 = σ·p1` (σ = 2) and scans p1 over linspace(0.1, 0.9, 160)·max|w|,
//! exactly as Algorithm 2 does.

use crate::quant::binarize::{binarize_masked, sgn};
use crate::tensor::Mat;

/// σ in `p2 = σ·p1` (paper Appendix A: "we set σ = 2 and it works well").
pub const SIGMA: f32 = 2.0;
/// Number of p1 candidates (paper: np.linspace(0.1, 0.9, 160)).
pub const N_CANDIDATES: usize = 160;

/// Result of the trisection search.
#[derive(Clone, Debug)]
pub struct Trisection {
    pub p1: f32,
    pub p2: f32,
    pub err: f32,
}

/// Region id per element (for packing/bit accounting): 0 = dense,
/// 1 = intermediate, 2 = sparse. Matches the 2-bit group marker of §3.4.
pub fn region_of(absw: f32, p1: f32, p2: f32) -> u8 {
    if absw > p2 {
        2
    } else if absw > p1 {
        1
    } else {
        0
    }
}

/// Reconstruction with three per-row scales, restricted to `mask`.
/// Each region r gets α_r = mean|w| over its members (per row — channel-wise
/// scaling consistent with Eq. 1) and reconstructs α_r · sign(w).
pub fn trisection_reconstruct(w: &Mat, mask: &[bool], p1: f32, p2: f32) -> Mat {
    let mut recon = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let row = w.row(i);
        let mrow = &mask[i * w.cols..(i + 1) * w.cols];
        let mut l1 = [0.0f32; 3];
        let mut cnt = [0usize; 3];
        for (&x, &m) in row.iter().zip(mrow) {
            if m {
                let r = region_of(x.abs(), p1, p2) as usize;
                l1[r] += x.abs();
                cnt[r] += 1;
            }
        }
        let alpha: Vec<f32> =
            (0..3).map(|r| if cnt[r] > 0 { l1[r] / cnt[r] as f32 } else { 0.0 }).collect();
        for ((o, &x), &m) in recon.row_mut(i).iter_mut().zip(row).zip(mrow) {
            if m {
                let r = region_of(x.abs(), p1, p2) as usize;
                *o = alpha[r] * sgn(x);
            }
        }
    }
    recon
}

/// O(N) trisection search (Algorithm 2 `NonSalientAwareQuant`): scan p1,
/// derive p2 = σ·p1, skip when p2 > 0.9·max|w|, keep the error minimizer.
/// Falls back to plain binarization break-points when the scan finds nothing
/// (e.g. all-zero input).
pub fn trisection_search(w: &Mat, mask: &[bool]) -> Trisection {
    let maxw = w
        .data
        .iter()
        .zip(mask)
        .filter(|(_, &m)| m)
        .map(|(x, _)| x.abs())
        .fold(0.0f32, f32::max);
    if maxw == 0.0 {
        return Trisection { p1: 0.0, p2: 0.0, err: 0.0 };
    }
    let mut best = Trisection { p1: f32::NAN, p2: f32::NAN, err: f32::INFINITY };
    for i in 0..N_CANDIDATES {
        let frac = 0.1 + 0.8 * i as f32 / (N_CANDIDATES - 1) as f32;
        let p1 = frac * maxw;
        let p2 = SIGMA * p1;
        if p2 > 0.9 * maxw {
            continue;
        }
        let recon = trisection_reconstruct(w, mask, p1, p2);
        let err = masked_err(w, &recon, mask);
        if err < best.err {
            best = Trisection { p1, p2, err };
        }
    }
    if !best.p1.is_finite() {
        // degenerate: no valid candidate (tiny max) — single region
        let (_, recon) = binarize_masked(w, mask);
        let err = masked_err(w, &recon, mask);
        return Trisection { p1: maxw, p2: maxw, err };
    }
    best
}

fn masked_err(w: &Mat, recon: &Mat, mask: &[bool]) -> f32 {
    let mut s = 0.0f32;
    for ((&a, &b), &m) in w.data.iter().zip(&recon.data).zip(mask) {
        if m {
            let d = a - b;
            s += d * d;
        }
    }
    s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{gen_normal_vec, prop_check};

    fn full(r: usize, c: usize) -> Vec<bool> {
        vec![true; r * c]
    }

    #[test]
    fn regions_partition() {
        prop_check("regions partition |w|", 50, |rng| {
            let p1 = 0.2 + rng.next_f32();
            let p2 = SIGMA * p1;
            for _ in 0..50 {
                let x = rng.range_f32(0.0, 3.0);
                let r = region_of(x, p1, p2);
                match r {
                    0 => prop_assert!(x <= p1),
                    1 => prop_assert!(x > p1 && x <= p2),
                    2 => prop_assert!(x > p2),
                    _ => return Err("bad region".into()),
                }
            }
            Ok(())
        });
    }

    #[test]
    fn trisection_beats_single_region_on_gaussian() {
        prop_check("trisection <= plain binarization error", 15, |rng| {
            let (r, c) = (16usize, 64usize);
            let w = Mat::from_vec(r, c, gen_normal_vec(rng, r * c, 1.0));
            let mask = full(r, c);
            let tri = trisection_search(&w, &mask);
            let (_, plain) = binarize_masked(&w, &mask);
            let ep = masked_err(&w, &plain, &mask);
            prop_assert!(tri.err <= ep + 1e-5, "tri={} plain={ep}", tri.err);
            prop_assert!(tri.p2 <= SIGMA * tri.p1 + 1e-5);
            Ok(())
        });
    }

    #[test]
    fn break_points_respect_sigma_link_and_cap() {
        let mut rng = crate::util::rng::Pcg32::seeded(4);
        let w = Mat::random(8, 40, 1.5, &mut rng);
        let mask = full(8, 40);
        let tri = trisection_search(&w, &mask);
        let maxw = w.data.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
        assert!((tri.p2 - SIGMA * tri.p1).abs() < 1e-5);
        assert!(tri.p2 <= 0.9 * maxw + 1e-5);
    }

    #[test]
    fn all_zero_input_is_handled() {
        let w = Mat::zeros(4, 8);
        let mask = full(4, 8);
        let tri = trisection_search(&w, &mask);
        assert_eq!(tri.err, 0.0);
        let recon = trisection_reconstruct(&w, &mask, tri.p1, tri.p2);
        assert!(recon.data.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn pruned_positions_stay_zero() {
        let mut rng = crate::util::rng::Pcg32::seeded(5);
        let w = Mat::random(6, 24, 1.0, &mut rng);
        let mask: Vec<bool> = (0..144).map(|i| i % 3 != 0).collect();
        let tri = trisection_search(&w, &mask);
        let recon = trisection_reconstruct(&w, &mask, tri.p1, tri.p2);
        for (idx, &m) in mask.iter().enumerate() {
            if !m {
                assert_eq!(recon.data[idx], 0.0);
            }
        }
    }
}
