//! Average-bit accounting (paper §3.4 "Average Bits" + Table 1).
//!
//! Value bits per kept weight: `N_param = 2·r_salient + 1·(1 − r_salient)`
//! (salient weights carry the residual pass ⇒ 2 bits). N:M pruning scales by
//! `N/M`; side information adds the 2-bit non-salient region marks (amortized
//! per group of `group` elements), the OBC block scale (`1/b_size`) and the
//! N:M mask index (`log2(C(M,N))/M` per position, the paper's uint16 meta
//! index in Appendix C).

use crate::quant::nm::NmRatio;

/// Paper Table 1's headline number: value bits/weight after N:M pruning.
pub fn param_bits(r_salient: f64, nm: NmRatio) -> f64 {
    let n_param = 2.0 * r_salient + (1.0 - r_salient);
    n_param * nm.density()
}

/// Storage side-info bits per weight (paper's `N_storing`, normalized per
/// weight rather than per block): 2 bits of region marks amortized over a
/// quantization group + block scale.
pub fn storing_bits(group_size: usize, b_size: usize) -> f64 {
    2.0 / group_size as f64 + 1.0 / b_size as f64
}

/// Mask-index bits per position for an N:M pattern: ceil(log2 C(M,N)) / M.
pub fn mask_index_bits(nm: NmRatio) -> f64 {
    let c = binomial(nm.m, nm.n) as f64;
    (c.log2().ceil()).max(0.0) / nm.m as f64
}

fn binomial(m: usize, n: usize) -> u64 {
    let n = n.min(m - n);
    let mut num = 1u64;
    let mut den = 1u64;
    for i in 0..n {
        num *= (m - i) as u64;
        den *= (i + 1) as u64;
    }
    num / den
}

/// Full effective bits/weight: values + marks + scales + mask index.
pub fn total_bits(r_salient: f64, nm: NmRatio, group_size: usize, b_size: usize) -> f64 {
    param_bits(r_salient, nm) + storing_bits(group_size, b_size) + mask_index_bits(nm)
}

/// The W-bits label the paper uses for a sparsity setting (e.g. "0.55 (4:8)").
pub fn paper_label(r_salient: f64, nm: NmRatio) -> String {
    format!("{:.2} ({})", param_bits(r_salient, nm), nm.label())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reference_points() {
        // Table 1: r_salient ≈ 0.10 gives BiLLM ≈ 1.10, 4:8 ≈ 0.55,
        // 5:8 ≈ 0.69, 6:8 ≈ 0.83 — the paper's LLaMA-1 row.
        let r = 0.10;
        assert!((param_bits(r, NmRatio::new(8, 8)) - 1.10).abs() < 0.01);
        assert!((param_bits(r, NmRatio::new(4, 8)) - 0.55).abs() < 0.01);
        assert!((param_bits(r, NmRatio::new(5, 8)) - 0.6875).abs() < 0.01);
        assert!((param_bits(r, NmRatio::new(6, 8)) - 0.825).abs() < 0.01);
    }

    #[test]
    fn more_salient_more_bits() {
        let nm = NmRatio::new(4, 8);
        assert!(param_bits(0.2, nm) > param_bits(0.05, nm));
    }

    #[test]
    fn binomials() {
        assert_eq!(binomial(4, 2), 6);
        assert_eq!(binomial(8, 4), 70);
        assert_eq!(binomial(8, 6), 28);
    }

    #[test]
    fn mask_bits_sane() {
        // 2:4 → log2(6)=2.58 → 3 bits / 4 = 0.75
        assert!((mask_index_bits(NmRatio::new(2, 4)) - 0.75).abs() < 1e-9);
        // 4:8 → log2(70)=6.13 → 7 bits / 8 = 0.875
        assert!((mask_index_bits(NmRatio::new(4, 8)) - 0.875).abs() < 1e-9);
        // dense 8:8 → 0 bits
        assert_eq!(mask_index_bits(NmRatio::new(8, 8)), 0.0);
    }

    #[test]
    fn total_is_monotone_in_components() {
        let nm = NmRatio::new(4, 8);
        assert!(total_bits(0.1, nm, 128, 128) > param_bits(0.1, nm));
        assert!(total_bits(0.1, nm, 64, 128) > total_bits(0.1, nm, 128, 128));
    }

    #[test]
    fn label_format() {
        assert_eq!(paper_label(0.10, NmRatio::new(4, 8)), "0.55 (4:8)");
    }
}
