//! Salient-column selection (paper Algorithm 2, `Salient`).
//!
//! The Hessian-based salience `S = W² / [H^c]²` (diag of the inverse-Cholesky
//! factor) ranks columns; the optimal salient-column *count* is found by
//! scanning candidate counts and measuring actual binarization error with
//! residual approximation on the salient group vs plain binarization on the
//! rest — exactly Algorithm 2's loop, with a capped/log-spaced scan (the
//! error curve is smooth in practice; BiLLM caps salient columns at ~1/10).

use crate::quant::binarize::{binarize_masked, residual_binarize_masked};
use crate::tensor::Mat;

/// Result of salient-column search.
#[derive(Clone, Debug)]
pub struct SalientSplit {
    /// column indices (into the block) deemed salient, best-first
    pub cols: Vec<usize>,
    /// fraction of weight *elements* that are salient (= cols/total)
    pub r_salient: f64,
}

/// Column salience scores: sum_i W_ij² / hc_diag_j².
pub fn column_salience(w: &Mat, hc_diag: &[f32]) -> Vec<f32> {
    assert_eq!(hc_diag.len(), w.cols);
    let mut s = vec![0.0f32; w.cols];
    for i in 0..w.rows {
        for (j, &x) in w.row(i).iter().enumerate() {
            s[j] += x * x;
        }
    }
    for (j, v) in s.iter_mut().enumerate() {
        let d = hc_diag[j] * hc_diag[j];
        *v /= d.max(1e-12);
    }
    s
}

/// Scan candidate salient-column counts (log-spaced up to `max_frac` of the
/// columns), choosing the count minimizing reconstruction error when salient
/// columns get residual approximation and the rest plain binarization.
/// `mask` restricts both to kept (N:M-surviving) positions.
pub fn select_salient(w: &Mat, hc_diag: &[f32], mask: &[bool], max_frac: f64) -> SalientSplit {
    let scores = column_salience(w, hc_diag);
    let mut order: Vec<usize> = (0..w.cols).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal));

    let max_cols = ((w.cols as f64 * max_frac).ceil() as usize).clamp(1, w.cols);
    // candidate counts: 0 plus log-spaced up to max_cols
    let mut cands = vec![0usize, 1];
    let mut c = 2usize;
    while c <= max_cols {
        cands.push(c);
        c = (c * 2).max(c + 1);
    }
    if *cands.last().unwrap() != max_cols {
        cands.push(max_cols);
    }

    let mut best = (f32::INFINITY, 0usize);
    for &cnt in &cands {
        let err = split_error(w, &order[..cnt], mask);
        if err < best.0 {
            best = (err, cnt);
        }
    }
    let cols = order[..best.1].to_vec();
    let r_salient = best.1 as f64 / w.cols as f64;
    SalientSplit { cols, r_salient }
}

/// Reconstruction error when `salient_cols` get residual approximation and
/// the remaining columns plain masked binarization.
fn split_error(w: &Mat, salient_cols: &[usize], mask: &[bool]) -> f32 {
    let recon = reconstruct_split(w, salient_cols, mask);
    w.sub(&recon).frob_norm()
}

/// Build the salient/non-salient reconstruction (used by the BiLLM baseline
/// and by the error scan above). Non-salient part: plain sign binarization.
pub fn reconstruct_split(w: &Mat, salient_cols: &[usize], mask: &[bool]) -> Mat {
    let mut is_sal = vec![false; w.cols];
    for &c in salient_cols {
        is_sal[c] = true;
    }
    // masks restricted to each group
    let mut m_sal = vec![false; w.rows * w.cols];
    let mut m_non = vec![false; w.rows * w.cols];
    for i in 0..w.rows {
        for j in 0..w.cols {
            let idx = i * w.cols + j;
            if mask[idx] {
                if is_sal[j] {
                    m_sal[idx] = true;
                } else {
                    m_non[idx] = true;
                }
            }
        }
    }
    let mut recon = residual_binarize_masked(w, &m_sal);
    let (_, non) = binarize_masked(w, &m_non);
    recon.add_assign(&non);
    recon
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{gen_normal_vec, prop_check};
    use crate::util::rng::Pcg32;

    fn full_mask(r: usize, c: usize) -> Vec<bool> {
        vec![true; r * c]
    }

    #[test]
    fn salience_prefers_large_columns_small_hc() {
        let w = Mat::from_vec(2, 3, vec![3.0, 0.1, 1.0, 3.0, 0.1, 1.0]);
        let hc = [1.0f32, 1.0, 10.0];
        let s = column_salience(&w, &hc);
        assert!(s[0] > s[1]); // bigger weights
        assert!(s[1] > s[2] || s[0] > s[2]); // large hc_diag suppresses
    }

    #[test]
    fn select_salient_reduces_error_vs_none() {
        prop_check("salient split never worse than no split", 20, |rng| {
            let (r, c) = (12usize, 32usize);
            let mut data = gen_normal_vec(rng, r * c, 1.0);
            // plant a few huge columns (outlier channels)
            for i in 0..r {
                data[i * c + 3] *= 8.0;
                data[i * c + 17] *= 6.0;
            }
            let w = Mat::from_vec(r, c, data);
            let hc: Vec<f32> = (0..c).map(|_| 0.5 + rng.next_f32()).collect();
            let mask = full_mask(r, c);
            let split = select_salient(&w, &hc, &mask, 0.25);
            let with = split_error(&w, &split.cols, &mask);
            let without = split_error(&w, &[], &mask);
            prop_assert!(with <= without + 1e-4, "with={with} without={without}");
            prop_assert!(split.r_salient <= 0.25 + 1e-9);
            Ok(())
        });
    }

    #[test]
    fn planted_outlier_columns_get_selected() {
        let mut rng = Pcg32::seeded(8);
        let (r, c) = (16usize, 24usize);
        let mut w = Mat::random(r, c, 0.3, &mut rng);
        for i in 0..r {
            w[(i, 5)] += 5.0;
        }
        let hc = vec![1.0f32; c];
        let split = select_salient(&w, &hc, &full_mask(r, c), 0.3);
        assert!(split.cols.contains(&5), "cols={:?}", split.cols);
    }

    #[test]
    fn reconstruct_respects_mask() {
        let mut rng = Pcg32::seeded(9);
        let w = Mat::random(4, 16, 1.0, &mut rng);
        let mask: Vec<bool> = (0..64).map(|i| i % 4 != 3).collect();
        let recon = reconstruct_split(&w, &[0, 1], &mask);
        for (idx, &m) in mask.iter().enumerate() {
            if !m {
                assert_eq!(recon.data[idx], 0.0);
            }
        }
    }
}
