//! Pruning/saliency metrics (paper §3.2 + Table 5 ablation).
//!
//! All metrics map a weight matrix (+ calibration statistics) to an
//! importance score per element; the N:M selector keeps the top-N per group.
//!
//! * `Magnitude`  — |w|
//! * `Wanda`      — |w| · ‖X_j‖₂                         (Sun et al. 2024)
//! * `SparseGpt`  — w² / diag(H⁻¹)_j²                    (Frantar & Alistarh 2023)
//! * `Si`         — the paper's Standardized Importance (Eq. 3):
//!                  σ(μ(|W|)) · ‖X_j‖₂ where μ is the sum of row- and
//!                  column-L1-normalized magnitude and σ standardizes over
//!                  the layer. Gradient-free, Hessian-free, outlier-robust.

use crate::tensor::Mat;

/// Which importance metric scores weights for N:M selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Magnitude,
    Wanda,
    SparseGpt,
    Si,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s.to_ascii_lowercase().as_str() {
            "magnitude" | "mag" => Some(Metric::Magnitude),
            "wanda" => Some(Metric::Wanda),
            "sparsegpt" => Some(Metric::SparseGpt),
            "si" | "ours" => Some(Metric::Si),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Metric::Magnitude => "Magnitude",
            Metric::Wanda => "Wanda",
            Metric::SparseGpt => "SparseGPT",
            Metric::Si => "SI",
        }
    }
}

/// Calibration statistics a metric may need. `x_col_norms[j] = ‖X_{:,j}‖₂`
/// over the calibration activations; `hinv_diag[j] = (H⁻¹)_{jj}`.
pub struct CalibStats<'a> {
    pub x_col_norms: Option<&'a [f32]>,
    pub hinv_diag: Option<&'a [f32]>,
}

impl<'a> CalibStats<'a> {
    pub fn none() -> CalibStats<'static> {
        CalibStats { x_col_norms: None, hinv_diag: None }
    }
}

/// Score every element of `w` under `metric`. Falls back gracefully when a
/// statistic is unavailable (norms default to 1) so the pipeline still runs
/// on weight-only paths; the ablation benches always supply real stats.
pub fn score(metric: Metric, w: &Mat, stats: &CalibStats) -> Mat {
    match metric {
        Metric::Magnitude => w.map(f32::abs),
        Metric::Wanda => {
            let mut s = w.map(f32::abs);
            if let Some(norms) = stats.x_col_norms {
                scale_cols(&mut s, norms);
            }
            s
        }
        Metric::SparseGpt => {
            let mut s = w.map(|x| x * x);
            if let Some(d) = stats.hinv_diag {
                for i in 0..s.rows {
                    for (v, dj) in s.row_mut(i).iter_mut().zip(d) {
                        let denom = dj * dj;
                        *v /= denom.max(1e-12);
                    }
                }
            }
            s
        }
        Metric::Si => si_score(w, stats.x_col_norms),
    }
}

/// Standardized Importance (Eq. 3).
pub fn si_score(w: &Mat, x_col_norms: Option<&[f32]>) -> Mat {
    let row_l1 = w.row_l1_sums();
    let col_l1 = w.col_l1_sums();
    // μ(|W|)_{ij} = |w_ij|/rowsum_i + |w_ij|/colsum_j
    let mut mu = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let rs = row_l1[i].max(1e-12);
        let wrow = w.row(i);
        for (j, (m, &x)) in mu.row_mut(i).iter_mut().zip(wrow).enumerate() {
            *m = x.abs() / rs + x.abs() / col_l1[j].max(1e-12);
        }
    }
    // standardize over the layer: (μ - mean) / std
    let mean = mu.mean();
    let std = mu.std().max(1e-12);
    let mut s = mu.map(|x| (x - mean) / std);
    // shift to non-negative so ranking is monotone in importance even after
    // multiplying by (non-negative) input norms
    let min = s.data.iter().copied().fold(f32::INFINITY, f32::min);
    s.data.iter_mut().for_each(|v| *v -= min);
    if let Some(norms) = x_col_norms {
        scale_cols(&mut s, norms);
    }
    s
}

fn scale_cols(m: &mut Mat, scales: &[f32]) {
    assert_eq!(m.cols, scales.len());
    for i in 0..m.rows {
        for (v, s) in m.row_mut(i).iter_mut().zip(scales) {
            *v *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{gen_normal_vec, prop_check};

    #[test]
    fn magnitude_is_abs() {
        let w = Mat::from_vec(1, 3, vec![-2.0, 0.5, -0.1]);
        let s = score(Metric::Magnitude, &w, &CalibStats::none());
        assert_eq!(s.data, vec![2.0, 0.5, 0.1]);
    }

    #[test]
    fn wanda_scales_by_input_norm() {
        let w = Mat::from_vec(2, 2, vec![1.0, 1.0, -1.0, 1.0]);
        let norms = [2.0f32, 0.5];
        let s = score(
            Metric::Wanda,
            &w,
            &CalibStats { x_col_norms: Some(&norms), hinv_diag: None },
        );
        assert_eq!(s.data, vec![2.0, 0.5, 2.0, 0.5]);
    }

    #[test]
    fn sparsegpt_downweights_well_conditioned() {
        let w = Mat::from_vec(1, 2, vec![1.0, 1.0]);
        let d = [1.0f32, 2.0]; // column 1 has larger (H^{-1})_jj ⇒ less important
        let s = score(Metric::SparseGpt, &w, &CalibStats { x_col_norms: None, hinv_diag: Some(&d) });
        assert!(s.data[0] > s.data[1]);
    }

    #[test]
    fn si_nonnegative_and_outlier_robust() {
        prop_check("si robust", 30, |rng| {
            let (r, c) = (8usize, 24usize);
            let mut data = gen_normal_vec(rng, r * c, 1.0);
            data[0] = 1e4; // extreme outlier
            let w = Mat::from_vec(r, c, data);
            let s = si_score(&w, None);
            prop_assert!(s.data.iter().all(|&v| v >= 0.0 && v.is_finite()));
            // the outlier must not dominate the entire layer: at most a
            // bounded share of total score mass
            let total: f32 = s.data.iter().sum();
            prop_assert!(s.data[0] / total < 0.5, "outlier share {}", s.data[0] / total);
            Ok(())
        });
    }

    #[test]
    fn si_ranks_bigger_weights_higher_within_row() {
        let w = Mat::from_vec(2, 4, vec![0.1, 0.2, 0.4, 0.8, 0.8, 0.4, 0.2, 0.1]);
        let s = si_score(&w, None);
        assert!(s[(0, 3)] > s[(0, 0)]);
        assert!(s[(1, 0)] > s[(1, 3)]);
    }

    #[test]
    fn metric_parse_roundtrip() {
        for m in [Metric::Magnitude, Metric::Wanda, Metric::SparseGpt, Metric::Si] {
            assert_eq!(Metric::parse(m.name()).unwrap(), m);
        }
        assert_eq!(Metric::parse("ours"), Some(Metric::Si));
        assert_eq!(Metric::parse("bogus"), None);
    }
}
