//! Channel rearrangement (paper §1 contributions: "channel rearrangement to
//! preserve salient weights").
//!
//! N:M selection operates on *aligned groups of M consecutive columns*; when
//! several salient channels land in the same group they compete for the N
//! slots and some are pruned. Permuting input channels so that high-salience
//! columns are spread across groups (round-robin over the salience ranking)
//! removes that collision. The same permutation must be applied to the
//! layer's input activations — for a linear layer this is exact:
//! `x @ (W P)^T` with `x P` — so we permute W, quantize, and permute back,
//! which keeps the *selection* benefit while leaving the layer interface
//! unchanged.

use crate::tensor::Mat;

/// Round-robin permutation from column scores: rank columns by descending
/// score, then deal them across the ⌈cols/m⌉ groups like cards so each group
/// receives one top channel before any group receives its second.
pub fn rearrangement(col_scores: &[f32], m: usize) -> Vec<usize> {
    let cols = col_scores.len();
    let n_groups = (cols + m - 1) / m;
    let mut order: Vec<usize> = (0..cols).collect();
    order.sort_by(|&a, &b| {
        col_scores[b].partial_cmp(&col_scores[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    // perm[new_position] = old_column
    let mut perm = vec![0usize; cols];
    for (rank, &col) in order.iter().enumerate() {
        let group = rank % n_groups;
        let slot = rank / n_groups;
        let pos = group * m + slot;
        if pos < cols {
            perm[pos] = col;
        }
    }
    // trailing positions for ranks that overflow the rectangular layout
    let mut used = vec![false; cols];
    for &p in &perm[..cols.min(perm.len())] {
        used[p] = true;
    }
    let mut missing: Vec<usize> = (0..cols).filter(|&c| !used[c]).collect();
    // positions that collided (duplicates) get the missing columns
    let mut seen = vec![false; cols];
    for slot in perm.iter_mut() {
        if seen[*slot] {
            *slot = missing.pop().unwrap();
        }
        seen[*slot] = true;
    }
    perm
}

/// Apply: out[:, i] = w[:, perm[i]].
pub fn permute_cols(w: &Mat, perm: &[usize]) -> Mat {
    assert_eq!(perm.len(), w.cols);
    let mut out = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let src = w.row(i);
        let dst = out.row_mut(i);
        for (new, &old) in perm.iter().enumerate() {
            dst[new] = src[old];
        }
    }
    out
}

/// Inverse permutation.
pub fn invert(perm: &[usize]) -> Vec<usize> {
    let mut inv = vec![0usize; perm.len()];
    for (new, &old) in perm.iter().enumerate() {
        inv[old] = new;
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::quant::nm::{nm_mask, NmRatio};
    use crate::util::prop::{gen_vec, prop_check};
    use crate::util::rng::Pcg32;

    #[test]
    fn rearrangement_is_permutation() {
        prop_check("rearrangement is a permutation", 40, |rng| {
            let cols = 8 * (1 + rng.bounded(8) as usize);
            let scores = gen_vec(rng, cols, 5.0);
            let perm = rearrangement(&scores, 8);
            let mut sorted = perm.clone();
            sorted.sort_unstable();
            prop_assert!(sorted == (0..cols).collect::<Vec<_>>(), "not a permutation");
            Ok(())
        });
    }

    #[test]
    fn spreads_top_channels_across_groups() {
        // 16 cols, m=4 ⇒ 4 groups; the 4 biggest scores must land in 4
        // distinct groups after rearrangement
        let mut scores = vec![0.1f32; 16];
        for &c in &[0usize, 1, 2, 3] {
            scores[c] = 10.0 + c as f32; // all top channels clustered in group 0
        }
        let perm = rearrangement(&scores, 4);
        let inv = invert(&perm);
        let groups: Vec<usize> = [0usize, 1, 2, 3].iter().map(|&c| inv[c] / 4).collect();
        let mut g = groups.clone();
        g.sort_unstable();
        g.dedup();
        assert_eq!(g.len(), 4, "top channels share groups: {groups:?}");
    }

    #[test]
    fn permute_roundtrip() {
        let mut rng = Pcg32::seeded(4);
        let w = Mat::random(6, 24, 1.0, &mut rng);
        let scores: Vec<f32> = (0..24).map(|_| rng.next_f32()).collect();
        let perm = rearrangement(&scores, 8);
        let back = permute_cols(&permute_cols(&w, &perm), &invert(&perm));
        assert_eq!(back.data, w.data);
    }

    #[test]
    fn rearrangement_preserves_more_salient_mass() {
        // clustered salient columns: N:M selection after rearrangement keeps
        // at least as much score mass as without
        let mut rng = Pcg32::seeded(5);
        let (rows, cols) = (16usize, 32usize);
        let mut w = Mat::random(rows, cols, 0.2, &mut rng);
        for i in 0..rows {
            for c in [0usize, 1, 2, 3, 4, 5] {
                w[(i, c)] += 3.0; // six salient channels all in the first groups
            }
        }
        let scores = w.map(f32::abs);
        let col_scores: Vec<f32> = (0..cols)
            .map(|j| (0..rows).map(|i| scores[(i, j)]).sum())
            .collect();
        let kept_mass = |m: &Mat| -> f32 {
            let sc = m.map(f32::abs);
            let mask = nm_mask(&sc, NmRatio::new(2, 8));
            sc.data.iter().zip(&mask).filter(|(_, &k)| k).map(|(v, _)| v).sum()
        };
        let perm = rearrangement(&col_scores, 8);
        let wp = permute_cols(&w, &perm);
        assert!(kept_mass(&wp) >= kept_mass(&w), "{} vs {}", kept_mass(&wp), kept_mass(&w));
    }
}
