//! PB-LLM baseline (Shang et al. 2024): partial binarization.
//!
//! The top `frac_salient` weights (by magnitude) stay at `hi_bits` precision
//! (8-bit RTN here, as in the reference "1.7 bit" configuration: 10% × 8 +
//! 90% × 1 ≈ 1.7 bits/weight); the remainder is binarized with the optimal
//! L1 scaling.

use crate::quant::binarize::binarize_masked;
use crate::tensor::Mat;

/// PB-LLM reconstruction + its effective bits/weight.
pub fn pbllm(w: &Mat, frac_salient: f64, hi_bits: u32) -> (Mat, f64) {
    let n = w.data.len();
    let keep = ((n as f64 * frac_salient).round() as usize).min(n);
    // global magnitude threshold
    let mut mags: Vec<f32> = w.data.iter().map(|x| x.abs()).collect();
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let thresh = if keep == 0 { f32::INFINITY } else { mags[keep - 1] };

    let salient_mask: Vec<bool> = w.data.iter().map(|x| x.abs() >= thresh).collect();
    let binary_mask: Vec<bool> = salient_mask.iter().map(|&m| !m).collect();

    // high-precision part: per-row absmax RTN at hi_bits over salient values
    let levels = ((1i32 << (hi_bits - 1)) - 1) as f32;
    let mut recon = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let row = w.row(i);
        let mrow = &salient_mask[i * w.cols..(i + 1) * w.cols];
        let s = row
            .iter()
            .zip(mrow)
            .filter(|(_, &m)| m)
            .map(|(x, _)| x.abs())
            .fold(0.0f32, f32::max);
        if s > 0.0 {
            for (j, (&x, &m)) in row.iter().zip(mrow).enumerate() {
                if m {
                    recon[(i, j)] = (x / s * levels).round().clamp(-levels, levels) / levels * s;
                }
            }
        }
    }
    // binarized remainder
    let (_, bin) = binarize_masked(w, &binary_mask);
    recon.add_assign(&bin);

    let bits = frac_salient * hi_bits as f64 + (1.0 - frac_salient);
    (recon, bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn bits_match_paper_configuration() {
        let mut rng = Pcg32::seeded(1);
        let w = Mat::random(8, 32, 1.0, &mut rng);
        let (_, bits) = pbllm(&w, 0.10, 8);
        assert!((bits - 1.7).abs() < 1e-9);
    }

    #[test]
    fn beats_plain_binarization() {
        let mut rng = Pcg32::seeded(2);
        let w = Mat::random(16, 64, 1.0, &mut rng);
        let (recon, _) = pbllm(&w, 0.10, 8);
        let (_, plain) = crate::quant::binarize::binarize(&w);
        assert!(w.sub(&recon).frob_norm() < w.sub(&plain).frob_norm());
    }

    #[test]
    fn salient_values_nearly_exact() {
        let mut rng = Pcg32::seeded(3);
        let mut w = Mat::random(4, 32, 0.3, &mut rng);
        w[(0, 0)] = 10.0; // guaranteed salient
        let (recon, _) = pbllm(&w, 0.10, 8);
        assert!((recon[(0, 0)] - 10.0).abs() / 10.0 < 0.02);
    }

    #[test]
    fn more_salient_lower_error() {
        let mut rng = Pcg32::seeded(4);
        let w = Mat::random(16, 64, 1.0, &mut rng);
        let (r1, _) = pbllm(&w, 0.05, 8);
        let (r2, _) = pbllm(&w, 0.30, 8);
        assert!(w.sub(&r2).frob_norm() < w.sub(&r1).frob_norm());
    }
}
