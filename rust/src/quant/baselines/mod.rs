//! Baseline PTQ methods the paper compares against: RTN, GPTQ, PB-LLM and
//! BiLLM. BiLLM shares the Algorithm-1 driver (it *is* STBLLM minus the SI
//! metric and trisection), so it is expressed as an `StbOpts` configuration.

pub mod awq;
pub mod gptq;
pub mod pbllm;
pub mod rtn;

use crate::quant::metrics::Metric;
use crate::quant::nm::NmRatio;
use crate::quant::pipeline::{NonSalientMode, StbOpts};

/// BiLLM (Huang et al. 2024) configuration: Hessian salient split + residual
/// approximation, bell-shaped (two-region) non-salient splitting, OBC
/// compensation. `nm = None` is vanilla ~1.09-bit BiLLM; `Some(r)` is the
/// paper's "BiLLM-N:M" sub-1-bit variant, which uses the Wanda metric for
/// mask selection (§4.1 Baseline: "We conduct the N:M sparsity using Wanda").
pub fn billm_opts(nm: Option<NmRatio>) -> StbOpts {
    let (structure, ratio) = match nm {
        Some(r) => (true, r),
        None => (false, NmRatio::new(8, 8)),
    };
    StbOpts {
        nm: ratio,
        block_size: 128,
        metric: Metric::Wanda,
        lambda: 0.01,
        salient_max_frac: 0.10,
        non_salient: NonSalientMode::BellShaped,
        structure,
        quantize: true,
        compensate: true,
        residual_salient: true,
        rearrange: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::pipeline::structured_binarize;
    use crate::quant::pipeline::LayerCalib;
    use crate::tensor::{gram, Mat};
    use crate::util::rng::Pcg32;

    #[test]
    fn billm_vanilla_is_dense_sub2bit() {
        let mut rng = Pcg32::seeded(1);
        let w = Mat::random(16, 64, 1.0, &mut rng);
        let x = Mat::random(128, 64, 1.0, &mut rng);
        let mut h = gram(&x);
        h.scale(2.0);
        let calib = LayerCalib { hessian: Some(h), x_col_norms: Some(x.col_l2_norms()) };
        let res = structured_binarize(&w, &calib, &billm_opts(None));
        assert!(res.mask.iter().all(|&m| m));
        assert!(res.avg_bits > 1.0 && res.avg_bits < 1.3, "bits={}", res.avg_bits);
    }

    #[test]
    fn stbllm_beats_billm_at_same_nm() {
        // the paper's core claim, at reconstruction-error level
        let mut rng = Pcg32::seeded(2);
        let w = Mat::random(32, 128, 1.0, &mut rng);
        let x = Mat::random(256, 128, 1.0, &mut rng);
        let mut h = gram(&x);
        h.scale(2.0);
        let calib = LayerCalib { hessian: Some(h), x_col_norms: Some(x.col_l2_norms()) };
        let nm = NmRatio::new(4, 8);
        let stb = structured_binarize(&w, &calib, &StbOpts::stbllm(nm));
        let billm = structured_binarize(&w, &calib, &billm_opts(Some(nm)));
        let es = w.sub(&stb.recon).frob_norm();
        let eb = w.sub(&billm.recon).frob_norm();
        assert!(es <= eb * 1.05, "stbllm={es} billm={eb}");
    }
}
