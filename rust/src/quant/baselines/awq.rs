//! AWQ-style baseline (Lin et al. 2024): activation-aware weight scaling
//! before quantization. Per input channel j, weights are scaled up by
//! `s_j = norm(X_j)^α` (protecting salient channels on the grid), quantized
//! with RTN, then the scale is folded back. Used for the Fig. 4(b) 2-bit
//! comparison row.

use crate::quant::baselines::rtn;
use crate::tensor::Mat;

/// AWQ quantization: returns the dequantized reconstruction. `alpha` is the
/// scale-exponent hyperparameter (reference implementation sweeps ~0.5).
pub fn awq(w: &Mat, x_col_norms: &[f32], bits: u32, alpha: f32, group: usize) -> Mat {
    assert_eq!(x_col_norms.len(), w.cols);
    // per-input-channel scales, normalized to mean 1 so grids stay centered
    let mut s: Vec<f32> = x_col_norms.iter().map(|n| n.max(1e-6).powf(alpha)).collect();
    let mean = s.iter().sum::<f32>() / s.len() as f32;
    s.iter_mut().for_each(|v| *v /= mean.max(1e-12));

    // scale up W columns, quantize, scale back down
    let mut scaled = w.clone();
    for i in 0..scaled.rows {
        for (v, sj) in scaled.row_mut(i).iter_mut().zip(&s) {
            *v *= sj;
        }
    }
    let mut q = rtn::rtn_grouped(&scaled, bits, group);
    for i in 0..q.rows {
        for (v, sj) in q.row_mut(i).iter_mut().zip(&s) {
            *v /= sj;
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul_bt, Mat};
    use crate::util::rng::Pcg32;

    fn setup(seed: u64) -> (Mat, Mat, Vec<f32>) {
        let mut rng = Pcg32::seeded(seed);
        let w = Mat::random(24, 64, 1.0, &mut rng);
        // activations with strong outlier channels — AWQ's motivating regime
        let mut x = Mat::random(128, 64, 1.0, &mut rng);
        for t in 0..x.rows {
            x[(t, 3)] *= 12.0;
            x[(t, 40)] *= 8.0;
        }
        let norms = x.col_l2_norms();
        (w, x, norms)
    }

    #[test]
    fn awq_beats_plain_rtn_on_output_error_with_outliers() {
        let (w, x, norms) = setup(1);
        let q_awq = awq(&w, &norms, 2, 0.5, 32);
        let q_rtn = rtn::rtn_grouped(&w, 2, 32);
        let err = |q: &Mat| {
            let y1 = matmul_bt(&x, &w);
            let y2 = matmul_bt(&x, q);
            y1.sub(&y2).frob_norm() / y1.frob_norm()
        };
        assert!(err(&q_awq) < err(&q_rtn), "awq={} rtn={}", err(&q_awq), err(&q_rtn));
    }

    #[test]
    fn alpha_zero_is_plain_rtn() {
        let (w, _, norms) = setup(2);
        let a = awq(&w, &norms, 3, 0.0, 32);
        let r = rtn::rtn_grouped(&w, 3, 32);
        for (x, y) in a.data.iter().zip(&r.data) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn reconstruction_finite_and_scaled_back() {
        let (w, _, norms) = setup(3);
        let q = awq(&w, &norms, 2, 0.5, 64);
        assert!(q.data.iter().all(|v| v.is_finite()));
        // coarse 2-bit grid zeroes much of the mass but the scale must stay
        // in the same decade (the per-channel scales fold back correctly)
        let ratio = q.l1_norm() / w.l1_norm();
        assert!(ratio > 0.2 && ratio < 2.0, "ratio={ratio}");
    }
}
