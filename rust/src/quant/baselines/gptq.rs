//! GPTQ baseline (Frantar et al. 2023): column-wise OBC quantization with
//! error propagation through the inverse-Hessian Cholesky factor. Used for
//! the Table 2 "GPTQ 1-bit" row and the 2-bit comparisons of Fig. 4b.

use crate::tensor::{linalg, Mat};

/// GPTQ at `bits` with per-row symmetric absmax grids (grid fixed from the
/// ORIGINAL weights, per the reference implementation), block size `beta`.
pub fn gptq(w: &Mat, hessian: Option<&Mat>, bits: u32, beta: usize, lambda: f32) -> Mat {
    let k = w.cols;
    let hc = match hessian {
        Some(h) => linalg::hessian_chol_inv(h, lambda).unwrap_or_else(|_| Mat::eye(k)),
        None => Mat::eye(k),
    };
    // fixed per-row grid scales from original W
    let scales: Vec<f32> = (0..w.rows)
        .map(|i| w.row(i).iter().map(|x| x.abs()).fold(0.0f32, f32::max))
        .collect();
    let levels = if bits <= 1 { 1 } else { (1i32 << (bits - 1)) - 1 } as f32;

    let mut work = w.clone();
    let mut out = Mat::zeros(w.rows, w.cols);
    let beta = beta.max(1).min(k);

    let mut b = 0usize;
    while b < k {
        let e = (b + beta).min(k);
        // error buffer for the block (rows × blockwidth)
        let mut err = Mat::zeros(w.rows, e - b);
        for j in b..e {
            let djj = hc[(j, j)].max(1e-12);
            for i in 0..w.rows {
                let x = work[(i, j)];
                let s = scales[i];
                let qv = if s == 0.0 {
                    0.0
                } else if bits == 1 {
                    if x >= 0.0 { s } else { -s }
                } else {
                    (x / s * levels).round().clamp(-levels, levels) / levels * s
                };
                out[(i, j)] = qv;
                let e_ij = (x - qv) / djj;
                err[(i, j - b)] = e_ij;
                // propagate inside the block
                for jj in j + 1..e {
                    work[(i, jj)] -= e_ij * hc[(j, jj)];
                }
            }
        }
        // propagate to the remaining columns
        if e < k {
            for i in 0..w.rows {
                for j in b..e {
                    let e_ij = err[(i, j - b)];
                    if e_ij != 0.0 {
                        let roww = work.row_mut(i);
                        for jj in e..k {
                            roww[jj] -= e_ij * hc[(j, jj)];
                        }
                    }
                }
            }
        }
        b = e;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{gram, matmul_bt};
    use crate::util::rng::Pcg32;

    fn setup(rows: usize, cols: usize, tokens: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Pcg32::seeded(seed);
        let w = Mat::random(rows, cols, 1.0, &mut rng);
        let x = Mat::random(tokens, cols, 1.0, &mut rng);
        let mut h = gram(&x);
        h.scale(2.0);
        (w, x, h)
    }

    fn out_err(w: &Mat, q: &Mat, x: &Mat) -> f32 {
        let y1 = matmul_bt(x, w);
        let y2 = matmul_bt(x, q);
        y1.sub(&y2).frob_norm() / y1.frob_norm()
    }

    #[test]
    fn gptq_beats_rtn_on_output_error() {
        let (w, x, h) = setup(16, 64, 256, 1);
        let g = gptq(&w, Some(&h), 2, 16, 0.01);
        let r = crate::quant::baselines::rtn::rtn(&w, 2);
        assert!(out_err(&w, &g, &x) < out_err(&w, &r, &x));
    }

    #[test]
    fn gptq_without_hessian_matches_rtn_grid() {
        let (w, _, _) = setup(4, 16, 32, 2);
        let g = gptq(&w, None, 4, 16, 0.01);
        let r = crate::quant::baselines::rtn::rtn(&w, 4);
        for (a, b) in g.data.iter().zip(&r.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn gptq_error_monotone_in_bits() {
        let (w, x, h) = setup(8, 32, 128, 3);
        let e2 = out_err(&w, &gptq(&w, Some(&h), 2, 8, 0.01), &x);
        let e4 = out_err(&w, &gptq(&w, Some(&h), 4, 8, 0.01), &x);
        assert!(e4 < e2);
    }

    #[test]
    fn gptq_1bit_catastrophic() {
        // reproduces the paper's observation: 1-bit GPTQ with absmax grids
        // still destroys the layer (Table 2 RTN/GPTQ rows)
        let (w, x, h) = setup(8, 64, 128, 4);
        let e1 = out_err(&w, &gptq(&w, Some(&h), 1, 16, 0.01), &x);
        assert!(e1 > 0.5, "e1={e1}");
    }
}
