//! RTN (round-to-nearest) baseline at arbitrary bit-width.
//!
//! Symmetric per-row (or per-group) absmax grids. At 1 bit RTN degenerates
//! to α·sign(w) with α = absmax (NOT the L1-optimal mean|w|), which is why
//! the paper's Table 2 shows RTN exploding at 1 bit — we reproduce that
//! behaviour faithfully.

use crate::tensor::Mat;

/// Quantize one value to a symmetric `bits`-wide grid with scale `s`
/// (s maps absmax to the top level).
#[inline]
fn q(x: f32, s: f32, bits: u32) -> f32 {
    if s == 0.0 {
        return 0.0;
    }
    let levels = (1i32 << (bits - 1)) - 1; // e.g. 2 bits → ±1, 4 bits → ±7
    let l = levels.max(1) as f32;
    (x / s * l).round().clamp(-l, l) / l * s
}

/// RTN quantization, per-row symmetric absmax grid.
pub fn rtn(w: &Mat, bits: u32) -> Mat {
    let mut out = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let row = w.row(i);
        if bits == 1 {
            // sign * absmax — the naive 1-bit RTN
            let s = row.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
            for (o, &x) in out.row_mut(i).iter_mut().zip(row) {
                *o = if x >= 0.0 { s } else { -s };
            }
        } else {
            let s = row.iter().map(|x| x.abs()).fold(0.0f32, f32::max);
            for (o, &x) in out.row_mut(i).iter_mut().zip(row) {
                *o = q(x, s, bits);
            }
        }
    }
    out
}

/// RTN with per-row column-group grids (group_size columns share a scale) —
/// the configuration 2-bit baselines (Fig. 4b) use.
pub fn rtn_grouped(w: &Mat, bits: u32, group_size: usize) -> Mat {
    let mut out = Mat::zeros(w.rows, w.cols);
    let g = group_size.max(1);
    for i in 0..w.rows {
        let row = w.row(i);
        let orow = out.row_mut(i);
        let mut c = 0;
        while c < row.len() {
            let e = (c + g).min(row.len());
            let s = row[c..e].iter().map(|x| x.abs()).fold(0.0f32, f32::max);
            if bits == 1 {
                for j in c..e {
                    orow[j] = if row[j] >= 0.0 { s } else { -s };
                }
            } else {
                for j in c..e {
                    orow[j] = q(row[j], s, bits);
                }
            }
            c = e;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn rtn_high_bits_near_exact() {
        let mut rng = Pcg32::seeded(1);
        let w = Mat::random(8, 32, 1.0, &mut rng);
        let r = rtn(&w, 8);
        let rel = w.sub(&r).frob_norm() / w.frob_norm();
        assert!(rel < 0.01, "rel={rel}");
    }

    #[test]
    fn rtn_error_monotone_in_bits() {
        let mut rng = Pcg32::seeded(2);
        let w = Mat::random(16, 64, 1.0, &mut rng);
        let errs: Vec<f32> = [1u32, 2, 3, 4]
            .iter()
            .map(|&b| w.sub(&rtn(&w, b)).frob_norm())
            .collect();
        assert!(errs[0] > errs[1] && errs[1] > errs[2] && errs[2] > errs[3], "{errs:?}");
    }

    #[test]
    fn rtn_1bit_worse_than_l1_binarization() {
        // absmax scaling is the wrong alpha for 1 bit — dynamic range blowup
        let mut rng = Pcg32::seeded(3);
        let w = Mat::random(8, 64, 1.0, &mut rng);
        let r = rtn(&w, 1);
        let (_, b) = crate::quant::binarize::binarize(&w);
        assert!(w.sub(&r).frob_norm() > w.sub(&b).frob_norm());
    }

    #[test]
    fn grouped_no_worse_than_rowwise() {
        let mut rng = Pcg32::seeded(4);
        let mut w = Mat::random(4, 128, 1.0, &mut rng);
        // inject a huge outlier in one group — grouped scales contain the blast
        w[(0, 5)] = 50.0;
        let rg = rtn_grouped(&w, 2, 32);
        let rr = rtn(&w, 2);
        assert!(w.sub(&rg).frob_norm() <= w.sub(&rr).frob_norm());
    }

    #[test]
    fn quantized_values_on_grid() {
        let w = Mat::from_vec(1, 4, vec![0.9, -0.4, 0.1, -1.0]);
        let r = rtn(&w, 2); // levels ±1, scale 1.0 ⇒ values in {-1, 0, 1}
        for v in r.data {
            assert!(v == 0.0 || (v.abs() - 1.0).abs() < 1e-6, "v={v}");
        }
    }
}
