//! Adaptive layer-wise N:M assignment (paper §3.3 + Table 6 ablation).
//!
//! Given a target ratio `R = N/M`, assign each layer its own `n_i:M` so the
//! *average* kept ratio meets the target:
//!
//! * `Uniform`  — every layer gets N.
//! * `SinShape` — density follows a sine wave over depth (early layers
//!   denser, late layers sparser), mean-preserving.
//! * `Ours`     — the paper's importance-proportional rule
//!   `r_i = α_i + (1 − α_i)·R` with `α_i = ω_i / ω_total` (per-layer weight
//!   L2 norm share), renormalized so the mean kept ratio equals R exactly.

use crate::quant::nm::NmRatio;

/// Allocation strategy for per-layer N:M ratios.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Allocation {
    Uniform,
    SinShape,
    Ours,
}

impl Allocation {
    pub fn parse(s: &str) -> Option<Allocation> {
        match s.to_ascii_lowercase().as_str() {
            "uniform" => Some(Allocation::Uniform),
            "sin" | "sinshape" | "sin-shape" => Some(Allocation::SinShape),
            "ours" | "adaptive" => Some(Allocation::Ours),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            Allocation::Uniform => "Uniform",
            Allocation::SinShape => "Sin-shape",
            Allocation::Ours => "Ours",
        }
    }
}

/// Compute per-layer N:M ratios. `importance[i]` is the L2 norm of layer i's
/// weights (only used by `Ours`). The result preserves the mean kept ratio:
/// `mean(n_i) == N` (exactly, via largest-remainder rounding on n_i).
pub fn assign_layer_ratios(
    strategy: Allocation,
    target: NmRatio,
    importance: &[f32],
) -> Vec<NmRatio> {
    let l = importance.len();
    assert!(l > 0);
    let m = target.m;
    let r_target = target.n as f64 / m as f64;

    let raw: Vec<f64> = match strategy {
        Allocation::Uniform => vec![r_target; l],
        Allocation::SinShape => {
            // density decreasing with depth: r_i = R + A·cos(π·i/(L−1));
            // cos averages ≈ 0 over [0, π] so the mean stays near R.
            let amp = (r_target - 1.0 / m as f64).min(1.0 - r_target) * 0.5;
            (0..l)
                .map(|i| {
                    let t = if l > 1 { i as f64 / (l - 1) as f64 } else { 0.5 };
                    r_target + amp * (std::f64::consts::PI * t).cos()
                })
                .collect()
        }
        Allocation::Ours => {
            let total: f64 = importance.iter().map(|&x| x as f64).sum::<f64>().max(1e-12);
            importance
                .iter()
                .map(|&w| {
                    let alpha = w as f64 / total;
                    alpha + (1.0 - alpha) * r_target
                })
                .collect()
        }
    };

    // Convert to integer n_i with exact mean preservation (largest-remainder).
    let budget = (target.n * l) as i64;
    let scaled: Vec<f64> = raw.iter().map(|r| r * m as f64).collect();
    let mut n: Vec<i64> = scaled.iter().map(|s| s.floor() as i64).collect();
    // clamp into [1, m]
    for v in n.iter_mut() {
        *v = (*v).clamp(1, m as i64);
    }
    let mut deficit = budget - n.iter().sum::<i64>();
    // largest-remainder: +1s go to layers with the largest fractional part,
    // −1s are taken from layers with the smallest fractional part
    let mut add_order: Vec<usize> = (0..l).collect();
    add_order.sort_by(|&a, &b| {
        let fa = scaled[a] - scaled[a].floor();
        let fb = scaled[b] - scaled[b].floor();
        fb.partial_cmp(&fa).unwrap()
    });
    let remove_order: Vec<usize> = add_order.iter().rev().copied().collect();
    let mut guard = 0;
    while deficit != 0 && guard < 10 * l as i64 {
        let order = if deficit > 0 { &add_order } else { &remove_order };
        for &i in order {
            if deficit > 0 && n[i] < m as i64 {
                n[i] += 1;
                deficit -= 1;
            } else if deficit < 0 && n[i] > 1 {
                n[i] -= 1;
                deficit += 1;
            }
            if deficit == 0 {
                break;
            }
        }
        guard += 1;
    }
    n.iter().map(|&ni| NmRatio::new(ni as usize, m)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;

    #[test]
    fn uniform_is_constant() {
        let r = assign_layer_ratios(Allocation::Uniform, NmRatio::new(4, 8), &[1.0; 6]);
        assert!(r.iter().all(|x| x.n == 4));
    }

    #[test]
    fn ours_gives_important_layers_more() {
        let imp = [10.0f32, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 30.0];
        let r = assign_layer_ratios(Allocation::Ours, NmRatio::new(4, 8), &imp);
        assert!(r[7].n >= r[1].n, "{:?}", r);
        assert!(r[0].n >= r[1].n, "{:?}", r);
    }

    #[test]
    fn sinshape_denser_early() {
        let r = assign_layer_ratios(Allocation::SinShape, NmRatio::new(4, 8), &[1.0; 10]);
        assert!(r[0].n >= r[9].n, "{:?}", r);
    }

    #[test]
    fn mean_ratio_preserved_all_strategies() {
        prop_check("allocation preserves mean n", 40, |rng| {
            let l = 2 + rng.bounded(14) as usize;
            let n = 2 + rng.bounded(5) as usize; // 2..6 of 8
            let imp: Vec<f32> = (0..l).map(|_| 0.1 + rng.next_f32() * 10.0).collect();
            for strat in [Allocation::Uniform, Allocation::SinShape, Allocation::Ours] {
                let rs = assign_layer_ratios(strat, NmRatio::new(n, 8), &imp);
                let total: usize = rs.iter().map(|r| r.n).sum();
                prop_assert!(total == n * l, "{strat:?}: total={total} want {}", n * l);
                prop_assert!(rs.iter().all(|r| r.n >= 1 && r.n <= 8));
            }
            Ok(())
        });
    }

    #[test]
    fn parse_names() {
        assert_eq!(Allocation::parse("sin-shape"), Some(Allocation::SinShape));
        assert_eq!(Allocation::parse("ours"), Some(Allocation::Ours));
        assert_eq!(Allocation::parse("nah"), None);
    }
}
