//! STBLLM layer quantization — the paper's Algorithm 1.
//!
//! For each β-column block of a weight matrix:
//!   1. score the block with the configured metric (SI by default, Eq. 3);
//!   2. select the N:M keep-mask from the scores;
//!   3. split kept columns into salient / non-salient via the OBC Hessian
//!      (Algorithm 2 `Salient`);
//!   4. reconstruct: residual approximation (Eq. 4) on salient columns,
//!      trisection non-salient-aware quantization (Eq. 5–6) on the rest;
//!   5. block-wise OBC error compensation: propagate the reconstruction
//!      error into the not-yet-quantized columns through the inverse-Hessian
//!      Cholesky factor (Algorithm 1 lines 16–17).
//!
//! The same driver also runs every ablated variant (Tables 5/6/8/9/10): each
//! stage can be toggled or swapped via `StbOpts`.

use crate::quant::binarize::{binarize_masked, residual_binarize_masked};
use crate::quant::bits;
use crate::quant::metrics::{score, CalibStats, Metric};
use crate::quant::nm::{nm_mask, NmRatio};
use crate::quant::salient::select_salient;
use crate::quant::trisection::{trisection_reconstruct, trisection_search};
use crate::tensor::{linalg, matmul, Mat};

/// Which quantizer handles non-salient kept weights (Table 8 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NonSalientMode {
    /// paper: trisection into sparse/intermediate/dense regions
    Trisection,
    /// BiLLM's bell-shaped splitting (one break-point, two groups)
    BellShaped,
    /// plain single-region binarization
    Plain,
}

/// Options for `structured_binarize`.
#[derive(Clone, Debug)]
pub struct StbOpts {
    pub nm: NmRatio,
    /// β — OBC block size (paper default 128; Table 9 sweeps it)
    pub block_size: usize,
    pub metric: Metric,
    /// Hessian damping λ (GPTQ percdamp)
    pub lambda: f32,
    /// cap on the salient-column fraction searched by Algorithm 2
    pub salient_max_frac: f64,
    pub non_salient: NonSalientMode,
    /// apply the N:M mask at all (false = "quant-only", Table 10)
    pub structure: bool,
    /// binarize at all (false = "structure-only", Table 10)
    pub quantize: bool,
    /// apply block-wise OBC error compensation
    pub compensate: bool,
    /// use residual approximation on salient columns
    pub residual_salient: bool,
    /// channel rearrangement: spread salient input channels across N:M
    /// groups before selection (§1 contributions), undone on output
    pub rearrange: bool,
}

impl StbOpts {
    /// Paper-default STBLLM configuration at a given N:M ratio.
    pub fn stbllm(nm: NmRatio) -> StbOpts {
        StbOpts {
            nm,
            block_size: 128,
            metric: Metric::Si,
            lambda: 0.01,
            salient_max_frac: 0.10,
            non_salient: NonSalientMode::Trisection,
            structure: true,
            quantize: true,
            compensate: true,
            residual_salient: true,
            rearrange: false,
        }
    }
}

/// Calibration inputs for one linear layer: the Hessian `H = 2 XᵀX` over the
/// layer's input activations and the per-column activation L2 norms.
#[derive(Clone, Debug)]
pub struct LayerCalib {
    pub hessian: Option<Mat>,
    pub x_col_norms: Option<Vec<f32>>,
}

impl LayerCalib {
    pub fn none() -> LayerCalib {
        LayerCalib { hessian: None, x_col_norms: None }
    }
}

/// Output of layer quantization.
#[derive(Clone, Debug)]
pub struct QuantResult {
    /// dense reconstruction (what the forward pass uses)
    pub recon: Mat,
    /// N:M keep-mask (all-true when structure is off)
    pub mask: Vec<bool>,
    /// measured salient fraction (kept-element weighted)
    pub r_salient: f64,
    /// value bits per weight (Table 1 accounting)
    pub avg_bits: f64,
    /// per-block trisection break-points (for diagnostics)
    pub break_points: Vec<(f32, f32)>,
}

/// Quantize one weight matrix (out × in) per Algorithm 1.
pub fn structured_binarize(w: &Mat, calib: &LayerCalib, opts: &StbOpts) -> QuantResult {
    if opts.rearrange && opts.structure {
        return rearranged_binarize(w, calib, opts);
    }
    structured_binarize_inner(w, calib, opts)
}

/// Channel rearrangement wrapper: permute input channels so high-salience
/// columns spread across N:M groups, quantize, permute back. The Hessian and
/// activation norms are permuted consistently so OBC compensation stays
/// exact under the reparameterization.
fn rearranged_binarize(w: &Mat, calib: &LayerCalib, opts: &StbOpts) -> QuantResult {
    use crate::quant::rearrange::{invert, permute_cols, rearrangement};
    let col_scores: Vec<f32> = match &calib.x_col_norms {
        Some(n) => {
            let l1 = w.col_l1_sums();
            l1.iter().zip(n).map(|(a, b)| a * b).collect()
        }
        None => w.col_l1_sums(),
    };
    let perm = rearrangement(&col_scores, opts.nm.m);
    let wp = permute_cols(w, &perm);
    let calib_p = LayerCalib {
        hessian: calib.hessian.as_ref().map(|h| {
            let mut hp = Mat::zeros(h.rows, h.cols);
            for i in 0..h.rows {
                for j in 0..h.cols {
                    hp[(i, j)] = h[(perm[i], perm[j])];
                }
            }
            hp
        }),
        x_col_norms: calib
            .x_col_norms
            .as_ref()
            .map(|n| perm.iter().map(|&c| n[c]).collect()),
    };
    let mut inner = opts.clone();
    inner.rearrange = false;
    let res = structured_binarize_inner(&wp, &calib_p, &inner);
    let inv = invert(&perm);
    let recon = permute_cols(&res.recon, &inv);
    let mut mask = vec![false; w.rows * w.cols];
    for i in 0..w.rows {
        for (new, &old) in inv.iter().enumerate() {
            mask[i * w.cols + new] = res.mask[i * w.cols + old];
        }
    }
    QuantResult { recon, mask, ..res }
}

fn structured_binarize_inner(w: &Mat, calib: &LayerCalib, opts: &StbOpts) -> QuantResult {
    let k = w.cols;
    let beta = opts.block_size.max(1).min(k);

    // H^c — upper Cholesky factor of (H + λI)^{-1}. Falls back to identity
    // (no compensation signal) when no Hessian is available.
    let hc = match (&calib.hessian, opts.compensate || true) {
        (Some(h), _) => linalg::hessian_chol_inv(h, opts.lambda).unwrap_or_else(|_| Mat::eye(k)),
        (None, _) => Mat::eye(k),
    };
    let hc_diag: Vec<f32> = (0..k).map(|j| hc[(j, j)]).collect();

    let mut work = w.clone();
    let mut recon = Mat::zeros(w.rows, w.cols);
    let mut mask_full = vec![true; w.rows * w.cols];
    let mut salient_kept = 0usize;
    let mut total_kept = 0usize;
    let mut break_points = Vec::new();

    let mut b = 0usize;
    while b < k {
        let e = (b + beta).min(k);
        let wb = work.slice_cols(b, e);

        // 1. importance scores on this block
        let norms_slice: Option<Vec<f32>> =
            calib.x_col_norms.as_ref().map(|n| n[b..e].to_vec());
        let stats = CalibStats {
            x_col_norms: norms_slice.as_deref(),
            hinv_diag: Some(&hc_diag[b..e]),
        };
        let scores = score(opts.metric, &wb, &stats);

        // 2. N:M keep-mask
        let mask_b: Vec<bool> = if opts.structure {
            nm_mask(&scores, opts.nm)
        } else {
            vec![true; wb.rows * wb.cols]
        };

        // 3–4. reconstruction
        let recon_b = if !opts.quantize {
            // structure-only: keep FP values where the mask keeps them
            let mut r = wb.clone();
            for (v, &m) in r.data.iter_mut().zip(&mask_b) {
                if !m {
                    *v = 0.0;
                }
            }
            r
        } else {
            let split = select_salient(&wb, &hc_diag[b..e], &mask_b, opts.salient_max_frac);
            let mut is_sal = vec![false; wb.cols];
            for &c in &split.cols {
                is_sal[c] = true;
            }
            let mut m_sal = vec![false; wb.rows * wb.cols];
            let mut m_non = vec![false; wb.rows * wb.cols];
            for i in 0..wb.rows {
                for j in 0..wb.cols {
                    let idx = i * wb.cols + j;
                    if mask_b[idx] {
                        if is_sal[j] {
                            m_sal[idx] = true;
                            salient_kept += 1;
                        } else {
                            m_non[idx] = true;
                        }
                        total_kept += 1;
                    }
                }
            }
            let mut r = if opts.residual_salient {
                residual_binarize_masked(&wb, &m_sal)
            } else {
                binarize_masked(&wb, &m_sal).1
            };
            let non = match opts.non_salient {
                NonSalientMode::Trisection => {
                    let tri = trisection_search(&wb, &m_non);
                    break_points.push((tri.p1, tri.p2));
                    trisection_reconstruct(&wb, &m_non, tri.p1, tri.p2)
                }
                NonSalientMode::BellShaped => bell_shaped_reconstruct(&wb, &m_non),
                NonSalientMode::Plain => binarize_masked(&wb, &m_non).1,
            };
            r.add_assign(&non);
            r
        };

        recon.set_cols(b, &recon_b);
        for i in 0..w.rows {
            for j in 0..wb.cols {
                mask_full[i * k + b + j] = mask_b[i * wb.cols + j];
            }
        }

        // 5. block-wise OBC compensation: W[:, e..] -= E · Hc[b..e, e..]
        if opts.compensate && e < k && calib.hessian.is_some() {
            let mut err = wb.sub(&recon_b); // (rows × β)
            for i in 0..err.rows {
                for (j, v) in err.row_mut(i).iter_mut().enumerate() {
                    *v /= hc_diag[b + j].max(1e-12);
                }
            }
            // Hc block rows b..e, cols e..k
            let mut hcb = Mat::zeros(e - b, k - e);
            for r_ in 0..e - b {
                for c_ in 0..k - e {
                    hcb[(r_, c_)] = hc[(b + r_, e + c_)];
                }
            }
            let delta = matmul(&err, &hcb); // (rows × k−e)
            for i in 0..work.rows {
                let roww = work.row_mut(i);
                for (c_, d) in delta.row(i).iter().enumerate() {
                    roww[e + c_] -= d;
                }
            }
        }

        b = e;
    }

    let r_salient = if total_kept > 0 {
        salient_kept as f64 / total_kept as f64
    } else {
        0.0
    };
    let avg_bits = if opts.quantize {
        bits::param_bits(r_salient, if opts.structure { opts.nm } else { NmRatio::new(opts.nm.m, opts.nm.m) })
    } else {
        32.0 * opts.nm.density()
    };
    QuantResult { recon, mask: mask_full, r_salient, avg_bits, break_points }
}

/// BiLLM's bell-shaped splitting of non-salient weights: a single searched
/// break-point p* divides |w| into two groups, each binarized on its own
/// (the paper's Table 8 "Bell-shaped" baseline).
pub fn bell_shaped_reconstruct(w: &Mat, mask: &[bool]) -> Mat {
    let maxw = w
        .data
        .iter()
        .zip(mask)
        .filter(|(_, &m)| m)
        .map(|(x, _)| x.abs())
        .fold(0.0f32, f32::max);
    if maxw == 0.0 {
        return Mat::zeros(w.rows, w.cols);
    }
    let mut best: Option<(f32, Mat)> = None;
    for i in 0..32 {
        let p = (0.1 + 0.8 * i as f32 / 31.0) * maxw;
        let recon = two_region_reconstruct(w, mask, p);
        let mut err = 0.0f32;
        for ((&a, &b), &m) in w.data.iter().zip(&recon.data).zip(mask) {
            if m {
                err += (a - b) * (a - b);
            }
        }
        if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
            best = Some((err, recon));
        }
    }
    best.unwrap().1
}

fn two_region_reconstruct(w: &Mat, mask: &[bool], p: f32) -> Mat {
    let mut recon = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let row = w.row(i);
        let mrow = &mask[i * w.cols..(i + 1) * w.cols];
        let mut l1 = [0.0f32; 2];
        let mut cnt = [0usize; 2];
        for (&x, &m) in row.iter().zip(mrow) {
            if m {
                let r = (x.abs() > p) as usize;
                l1[r] += x.abs();
                cnt[r] += 1;
            }
        }
        let alpha: Vec<f32> =
            (0..2).map(|r| if cnt[r] > 0 { l1[r] / cnt[r] as f32 } else { 0.0 }).collect();
        for ((o, &x), &m) in recon.row_mut(i).iter_mut().zip(row).zip(mrow) {
            if m {
                let r = (x.abs() > p) as usize;
                *o = alpha[r] * crate::quant::binarize::sgn(x);
            }
        }
    }
    recon
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gram;
    use crate::util::rng::Pcg32;

    fn calib_for(w_cols: usize, tokens: usize, seed: u64) -> (LayerCalib, Mat) {
        let mut rng = Pcg32::seeded(seed);
        let x = Mat::random(tokens, w_cols, 1.0, &mut rng);
        let mut h = gram(&x);
        h.scale(2.0);
        let norms = x.col_l2_norms();
        (LayerCalib { hessian: Some(h), x_col_norms: Some(norms) }, x)
    }

    fn recon_err(w: &Mat, r: &QuantResult) -> f32 {
        w.sub(&r.recon).frob_norm() / w.frob_norm()
    }

    /// task-level proxy error: how much the layer OUTPUT changes on calib data
    fn output_err(w: &Mat, recon: &Mat, x: &Mat) -> f32 {
        let y1 = crate::tensor::matmul_bt(x, w);
        let y2 = crate::tensor::matmul_bt(x, recon);
        y1.sub(&y2).frob_norm() / y1.frob_norm().max(1e-12)
    }

    #[test]
    fn respects_nm_mask() {
        let mut rng = Pcg32::seeded(1);
        let w = Mat::random(16, 64, 1.0, &mut rng);
        let (calib, _) = calib_for(64, 128, 2);
        let res = structured_binarize(&w, &calib, &StbOpts::stbllm(NmRatio::new(4, 8)));
        // exactly half the positions kept, zeros elsewhere
        let kept = res.mask.iter().filter(|&&m| m).count();
        assert_eq!(kept, 16 * 64 / 2);
        for (v, &m) in res.recon.data.iter().zip(&res.mask) {
            if !m {
                assert_eq!(*v, 0.0);
            }
        }
    }

    #[test]
    fn avg_bits_below_one() {
        let mut rng = Pcg32::seeded(2);
        let w = Mat::random(32, 128, 1.0, &mut rng);
        let (calib, _) = calib_for(128, 128, 3);
        for (n, want_max) in [(4usize, 0.62), (5, 0.78), (6, 0.93)] {
            let res = structured_binarize(&w, &calib, &StbOpts::stbllm(NmRatio::new(n, 8)));
            assert!(res.avg_bits < want_max, "{n}:8 bits={}", res.avg_bits);
            assert!(res.avg_bits > 0.3);
        }
    }

    #[test]
    fn compensation_improves_output_error() {
        let mut rng = Pcg32::seeded(3);
        let w = Mat::random(24, 96, 1.0, &mut rng);
        let (calib, x) = calib_for(96, 256, 4);
        let mut opts = StbOpts::stbllm(NmRatio::new(4, 8));
        opts.block_size = 32;
        let with = structured_binarize(&w, &calib, &opts);
        opts.compensate = false;
        let without = structured_binarize(&w, &calib, &opts);
        let ew = output_err(&w, &with.recon, &x);
        let eo = output_err(&w, &without.recon, &x);
        assert!(ew < eo, "with={ew} without={eo}");
    }

    #[test]
    fn trisection_beats_plain_nonsalient() {
        let mut rng = Pcg32::seeded(4);
        let w = Mat::random(32, 64, 1.0, &mut rng);
        let (calib, _) = calib_for(64, 128, 5);
        let mut opts = StbOpts::stbllm(NmRatio::new(6, 8));
        opts.compensate = false; // isolate the quantizer comparison
        let tri = structured_binarize(&w, &calib, &opts);
        opts.non_salient = NonSalientMode::Plain;
        let plain = structured_binarize(&w, &calib, &opts);
        assert!(recon_err(&w, &tri) <= recon_err(&w, &plain) + 1e-5);
    }

    #[test]
    fn structure_only_keeps_fp_values() {
        let mut rng = Pcg32::seeded(5);
        let w = Mat::random(8, 32, 1.0, &mut rng);
        let (calib, _) = calib_for(32, 64, 6);
        let mut opts = StbOpts::stbllm(NmRatio::new(4, 8));
        opts.quantize = false;
        let res = structured_binarize(&w, &calib, &opts);
        for ((&r, &orig), &m) in res.recon.data.iter().zip(&w.data).zip(&res.mask) {
            if m {
                // kept values are exact FP (up to compensation shifts on later blocks)
                // first block is untouched by compensation:
                let _ = (r, orig);
            } else {
                assert_eq!(r, 0.0);
            }
        }
        assert!(res.avg_bits > 10.0); // fp16/32-class, not binary
    }

    #[test]
    fn quant_only_keeps_all_positions() {
        let mut rng = Pcg32::seeded(6);
        let w = Mat::random(8, 32, 1.0, &mut rng);
        let (calib, _) = calib_for(32, 64, 7);
        let mut opts = StbOpts::stbllm(NmRatio::new(4, 8));
        opts.structure = false;
        let res = structured_binarize(&w, &calib, &opts);
        assert!(res.mask.iter().all(|&m| m));
        assert!(res.recon.data.iter().filter(|&&v| v != 0.0).count() > 8 * 32 / 2);
    }

    #[test]
    fn works_without_calibration() {
        let mut rng = Pcg32::seeded(7);
        let w = Mat::random(8, 24, 1.0, &mut rng);
        let res = structured_binarize(&w, &LayerCalib::none(), &StbOpts::stbllm(NmRatio::new(2, 4)));
        assert!(res.recon.data.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn bell_vs_trisection_table8_direction() {
        // trisection should match-or-beat bell-shaped in reconstruction error
        let mut rng = Pcg32::seeded(8);
        let w = Mat::random(48, 64, 1.0, &mut rng);
        let mask = vec![true; 48 * 64];
        let bell = bell_shaped_reconstruct(&w, &mask);
        let tri_res = trisection_search(&w, &mask);
        let tri = trisection_reconstruct(&w, &mask, tri_res.p1, tri_res.p2);
        let eb = w.sub(&bell).frob_norm();
        let et = w.sub(&tri).frob_norm();
        assert!(et <= eb + 1e-4, "tri={et} bell={eb}");
    }
}
