//! The paper's quantization algorithms (STBLLM Algorithm 1 + 2) and every
//! baseline it is compared against. All functions operate on a single
//! weight matrix + calibration statistics; `coordinator::quantizer` drives
//! them across a whole model.

pub mod allocate;
pub mod baselines;
pub mod binarize;
pub mod bits;
pub mod metrics;
pub mod nm;
pub mod pipeline;
pub mod rearrange;
pub mod salient;
pub mod trisection;

pub use allocate::Allocation;
pub use metrics::Metric;
pub use nm::NmRatio;
pub use pipeline::{structured_binarize, LayerCalib, NonSalientMode, QuantResult, StbOpts};
