//! Binarization primitives (paper Eq. 1–2, 4).
//!
//! Channel-wise (per-output-row) scaling throughout: `α = ||w_row||₁ / m`
//! with `sign(0) := +1` (Eq. 2). Masked variants compute α over the kept
//! elements only, so N:M-pruned rows are not diluted by their zeros.

use crate::tensor::Mat;

/// sign with sign(0) = +1, matching Eq. 2 and `kernels/ref.py`.
#[inline]
pub fn sgn(x: f32) -> f32 {
    if x >= 0.0 {
        1.0
    } else {
        -1.0
    }
}

/// Plain row-wise binarization: returns (alpha per row, reconstruction).
pub fn binarize(w: &Mat) -> (Vec<f32>, Mat) {
    let mut alphas = Vec::with_capacity(w.rows);
    let mut recon = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let row = w.row(i);
        let alpha = row.iter().map(|x| x.abs()).sum::<f32>() / w.cols as f32;
        for (o, &x) in recon.row_mut(i).iter_mut().zip(row) {
            *o = alpha * sgn(x);
        }
        alphas.push(alpha);
    }
    (alphas, recon)
}

/// Row-wise binarization restricted to `mask` (true = kept). Pruned
/// positions reconstruct to exactly 0; alpha averages over kept count.
pub fn binarize_masked(w: &Mat, mask: &[bool]) -> (Vec<f32>, Mat) {
    assert_eq!(mask.len(), w.rows * w.cols);
    let mut alphas = Vec::with_capacity(w.rows);
    let mut recon = Mat::zeros(w.rows, w.cols);
    for i in 0..w.rows {
        let row = w.row(i);
        let mrow = &mask[i * w.cols..(i + 1) * w.cols];
        let (mut l1, mut cnt) = (0.0f32, 0usize);
        for (x, &m) in row.iter().zip(mrow) {
            if m {
                l1 += x.abs();
                cnt += 1;
            }
        }
        let alpha = if cnt > 0 { l1 / cnt as f32 } else { 0.0 };
        for ((o, &x), &m) in recon.row_mut(i).iter_mut().zip(row).zip(mrow) {
            *o = if m { alpha * sgn(x) } else { 0.0 };
        }
        alphas.push(alpha);
    }
    (alphas, recon)
}

/// Residual approximation (Eq. 4): W ≈ α_o B_o + α_r B_r, row-wise,
/// restricted to `mask`. Returns the reconstruction.
pub fn residual_binarize_masked(w: &Mat, mask: &[bool]) -> Mat {
    let (_, first) = binarize_masked(w, mask);
    let resid = w.sub(&first);
    let (_, second) = binarize_masked(&resid, mask);
    let mut out = first;
    out.add_assign(&second);
    // re-zero pruned positions (binarize_masked already does, but keep exact)
    for (o, &m) in out.data.iter_mut().zip(mask) {
        if !m {
            *o = 0.0;
        }
    }
    out
}

/// Unmasked residual approximation (mirrors `kernels/residual.py`).
pub fn residual_binarize(w: &Mat) -> Mat {
    let mask = vec![true; w.rows * w.cols];
    residual_binarize_masked(w, &mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::{gen_normal_vec, prop_check};
    use crate::util::rng::Pcg32;

    #[test]
    fn binarize_known_values() {
        let w = Mat::from_vec(1, 4, vec![1.0, -2.0, 3.0, -4.0]);
        let (alphas, rec) = binarize(&w);
        assert!((alphas[0] - 2.5).abs() < 1e-6);
        assert_eq!(rec.data, vec![2.5, -2.5, 2.5, -2.5]);
    }

    #[test]
    fn masked_alpha_ignores_pruned() {
        let w = Mat::from_vec(1, 4, vec![1.0, -100.0, 3.0, 0.0]);
        let mask = vec![true, false, true, true];
        let (alphas, rec) = binarize_masked(&w, &mask);
        assert!((alphas[0] - 4.0 / 3.0).abs() < 1e-6);
        assert_eq!(rec.data[1], 0.0);
        assert!((rec.data[0] - 4.0 / 3.0).abs() < 1e-6);
        assert!(rec.data[3] > 0.0); // sign(0) = +1
    }

    #[test]
    fn residual_never_worse_than_plain() {
        prop_check("residual <= plain error", 40, |rng| {
            let (r, c) = (4 + rng.bounded(12) as usize, 8 + rng.bounded(40) as usize);
            let w = Mat::from_vec(r, c, gen_normal_vec(rng, r * c, 1.0));
            let (_, plain) = binarize(&w);
            let resid = residual_binarize(&w);
            let ep = w.sub(&plain).frob_norm();
            let er = w.sub(&resid).frob_norm();
            prop_assert!(er <= ep + 1e-5, "er={er} ep={ep}");
            Ok(())
        });
    }

    #[test]
    fn residual_masked_zeroes_pruned() {
        let mut rng = Pcg32::seeded(3);
        let w = Mat::random(6, 16, 1.0, &mut rng);
        let mask: Vec<bool> = (0..96).map(|i| i % 2 == 0).collect();
        let rec = residual_binarize_masked(&w, &mask);
        for (i, (&v, &m)) in rec.data.iter().zip(&mask).enumerate() {
            if !m {
                assert_eq!(v, 0.0, "elem {i}");
            }
        }
    }

    #[test]
    fn binarize_is_l1_optimal_scale() {
        // alpha = mean|w| minimizes ||w - a*sign(w)||² over a
        prop_check("alpha optimal", 25, |rng| {
            let w = Mat::from_vec(1, 32, gen_normal_vec(rng, 32, 2.0));
            let (alphas, rec) = binarize(&w);
            let base = w.sub(&rec).frob_norm();
            for da in [-0.05f32, 0.05] {
                let a = alphas[0] + da;
                let alt = w.map(|x| a * sgn(x));
                prop_assert!(w.sub(&alt).frob_norm() >= base - 1e-5);
            }
            Ok(())
        });
    }
}
