//! Named-metric registry with Prometheus text exposition.
//!
//! Registration (`counter`/`gauge`/`histogram`) takes a short lock on a
//! name→handle map and is meant to happen once, at wiring time; the
//! returned `Arc` handles record lock-free forever after. Metric names
//! follow `stbllm_<subsystem>_<metric>` (e.g. `stbllm_kv_evictions`,
//! `stbllm_server_decode_seconds`); counters are registered WITHOUT the
//! `_total` suffix — the renderer appends it per Prometheus convention —
//! and histogram names end in `_seconds` (all histograms here record
//! durations).
//!
//! A [`Registry::disabled`] registry mints no-op handles: every recording
//! call compiles to a branch on a constant-false flag. `serve --no-obs`
//! swaps one in so the recording overhead of the real registry can be
//! measured as a tok/s delta between two otherwise identical runs.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

use crate::obs::metrics::{Counter, Gauge, Histogram};

/// Process-scoped collection of named metrics.
///
/// Each gateway/server owns an `Arc<Registry>` (keeping tests isolated in
/// one process); [`Registry::global`] is the fallback for tools that
/// don't carry one.
#[derive(Debug)]
pub struct Registry {
    on: bool,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    hists: BTreeMap<String, Arc<Histogram>>,
    help: BTreeMap<String, String>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    /// An enabled registry: handles record, `render_prometheus` exposes.
    pub fn new() -> Self {
        Registry { on: true, inner: Mutex::new(Inner::default()) }
    }

    /// A disabled registry: every minted handle is a no-op and the
    /// exposition is empty. The baseline for overhead comparisons.
    pub fn disabled() -> Self {
        Registry { on: false, inner: Mutex::new(Inner::default()) }
    }

    /// Whether handles minted by this registry actually record.
    pub fn is_enabled(&self) -> bool {
        self.on
    }

    /// The process-wide registry, for call sites with no explicit one.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        // registration-only lock; a poisoned map is still a valid map
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Get-or-create the counter `name` (no `_total` suffix — the
    /// renderer appends it). Re-registration returns the same handle.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        debug_assert!(!name.ends_with("_total"), "register counters without _total: {name}");
        let on = self.on;
        let mut g = self.lock();
        g.help.entry(name.to_string()).or_insert_with(|| help.to_string());
        Arc::clone(g.counters.entry(name.to_string()).or_insert_with(|| Arc::new(Counter::new(on))))
    }

    /// Get-or-create the gauge `name`.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let on = self.on;
        let mut g = self.lock();
        g.help.entry(name.to_string()).or_insert_with(|| help.to_string());
        Arc::clone(g.gauges.entry(name.to_string()).or_insert_with(|| Arc::new(Gauge::new(on))))
    }

    /// Get-or-create the duration histogram `name` (by convention the
    /// name ends in `_seconds`).
    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        let on = self.on;
        let mut g = self.lock();
        g.help.entry(name.to_string()).or_insert_with(|| help.to_string());
        Arc::clone(g.hists.entry(name.to_string()).or_insert_with(|| Arc::new(Histogram::new(on))))
    }

    /// Render the whole registry as Prometheus text exposition (version
    /// 0.0.4): `# HELP`/`# TYPE` preamble per metric, counters suffixed
    /// `_total`, histograms as cumulative `_bucket{le=...}` series plus
    /// `_sum`/`_count`. Deterministic order (name-sorted per kind).
    pub fn render_prometheus(&self) -> String {
        let g = self.lock();
        let mut out = String::new();
        for (name, c) in &g.counters {
            let help = g.help.get(name).map(String::as_str).unwrap_or("");
            out.push_str(&format!("# HELP {name}_total {help}\n"));
            out.push_str(&format!("# TYPE {name}_total counter\n"));
            out.push_str(&format!("{name}_total {}\n", c.get()));
        }
        for (name, gauge) in &g.gauges {
            let help = g.help.get(name).map(String::as_str).unwrap_or("");
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {}\n", gauge.get()));
        }
        for (name, h) in &g.hists {
            let help = g.help.get(name).map(String::as_str).unwrap_or("");
            out.push_str(&format!("# HELP {name} {help}\n"));
            out.push_str(&format!("# TYPE {name} histogram\n"));
            let mut cum = 0u64;
            for (ub, count) in h.buckets() {
                cum += count;
                out.push_str(&format!("{name}_bucket{{le=\"{ub}\"}} {cum}\n"));
            }
            out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
            out.push_str(&format!("{name}_sum {}\n", h.sum_secs()));
            out.push_str(&format!("{name}_count {}\n", h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent() {
        let r = Registry::new();
        let a = r.counter("stbllm_test_events", "events");
        let b = r.counter("stbllm_test_events", "events");
        assert!(Arc::ptr_eq(&a, &b));
        a.inc();
        assert_eq!(b.get(), 1); // same underlying atomic
    }

    #[test]
    fn disabled_registry_mints_noop_handles() {
        let r = Registry::disabled();
        assert!(!r.is_enabled());
        let c = r.counter("stbllm_test_events", "events");
        c.add(10);
        assert_eq!(c.get(), 0);
        let h = r.histogram("stbllm_test_wait_seconds", "wait");
        h.record_secs(1.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn renders_prometheus_exposition() {
        let r = Registry::new();
        r.counter("stbllm_test_events", "total events").add(3);
        r.gauge("stbllm_test_level", "current level").set(-2);
        let h = r.histogram("stbllm_test_wait_seconds", "wait time");
        h.record_secs(1e-6);
        h.record_secs(1e-3);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE stbllm_test_events_total counter\n"));
        assert!(text.contains("stbllm_test_events_total 3\n"));
        assert!(text.contains("# TYPE stbllm_test_level gauge\n"));
        assert!(text.contains("stbllm_test_level -2\n"));
        assert!(text.contains("# TYPE stbllm_test_wait_seconds histogram\n"));
        assert!(text.contains("stbllm_test_wait_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("stbllm_test_wait_seconds_count 2\n"));
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let val = parts.next().unwrap_or("");
            assert!(val.parse::<f64>().is_ok(), "unparsable value in: {line}");
            assert!(parts.next().is_some(), "no name in: {line}");
        }
        // cumulative bucket counts are non-decreasing and end at _count
        let buckets: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("stbllm_test_wait_seconds_bucket"))
            .filter_map(|l| l.rsplit(' ').next())
            .filter_map(|v| v.parse().ok())
            .collect();
        assert!(buckets.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(buckets.last(), Some(&2));
    }

    #[test]
    fn global_registry_is_a_singleton() {
        let a = Registry::global();
        let b = Registry::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.is_enabled());
    }
}
