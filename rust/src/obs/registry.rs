//! Named-metric registry with Prometheus text exposition.
//!
//! Registration (`counter`/`gauge`/`histogram`) takes a short lock on a
//! name→handle map and is meant to happen once, at wiring time; the
//! returned `Arc` handles record lock-free forever after. Metric names
//! follow `stbllm_<subsystem>_<metric>` (e.g. `stbllm_kv_evictions`,
//! `stbllm_server_decode_seconds`); counters are registered WITHOUT the
//! `_total` suffix — the renderer appends it per Prometheus convention —
//! and histogram names end in `_seconds` (all histograms here record
//! durations).
//!
//! A [`Registry::disabled`] registry mints no-op handles: every recording
//! call compiles to a branch on a constant-false flag. `serve --no-obs`
//! swaps one in so the recording overhead of the real registry can be
//! measured as a tok/s delta between two otherwise identical runs.
//!
//! Series can carry a fixed label set (`counter_with`/`gauge_with`/
//! `histogram_with` with e.g. `replica="0"`): same-name series share one
//! `# HELP`/`# TYPE` preamble and render as `name{labels} value`. The
//! multi-replica gateway uses this to expose per-replica views of the
//! serving metrics next to the unlabeled aggregates.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};

use crate::obs::metrics::{Counter, Gauge, Histogram};

/// Process-scoped collection of named metrics.
///
/// Each gateway/server owns an `Arc<Registry>` (keeping tests isolated in
/// one process); [`Registry::global`] is the fallback for tools that
/// don't carry one.
#[derive(Debug)]
pub struct Registry {
    on: bool,
    inner: Mutex<Inner>,
}

/// One series = a metric name plus a (possibly empty) rendered label set
/// like `replica="0"`. BTreeMap ordering keeps all series of one name
/// adjacent (empty labels first), so the renderer emits the preamble once
/// per name.
type SeriesKey = (String, String);

#[derive(Debug, Default)]
struct Inner {
    counters: BTreeMap<SeriesKey, Arc<Counter>>,
    gauges: BTreeMap<SeriesKey, Arc<Gauge>>,
    hists: BTreeMap<SeriesKey, Arc<Histogram>>,
    help: BTreeMap<String, String>,
}

/// `name{labels}` (or just `name` for the unlabeled series).
fn series(name: &str, suffix: &str, labels: &str) -> String {
    if labels.is_empty() {
        format!("{name}{suffix}")
    } else {
        format!("{name}{suffix}{{{labels}}}")
    }
}

/// Bucket series name with `le` merged after any fixed labels.
fn bucket_series(name: &str, labels: &str, le: &str) -> String {
    if labels.is_empty() {
        format!("{name}_bucket{{le=\"{le}\"}}")
    } else {
        format!("{name}_bucket{{{labels},le=\"{le}\"}}")
    }
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    /// An enabled registry: handles record, `render_prometheus` exposes.
    pub fn new() -> Self {
        Registry { on: true, inner: Mutex::new(Inner::default()) }
    }

    /// A disabled registry: every minted handle is a no-op and the
    /// exposition is empty. The baseline for overhead comparisons.
    pub fn disabled() -> Self {
        Registry { on: false, inner: Mutex::new(Inner::default()) }
    }

    /// Whether handles minted by this registry actually record.
    pub fn is_enabled(&self) -> bool {
        self.on
    }

    /// The process-wide registry, for call sites with no explicit one.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    fn lock(&self) -> MutexGuard<'_, Inner> {
        // registration-only lock; a poisoned map is still a valid map
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Get-or-create the counter `name` (no `_total` suffix — the
    /// renderer appends it). Re-registration returns the same handle.
    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        self.counter_with(name, "", help)
    }

    /// [`Registry::counter`] with a fixed label set, e.g.
    /// `counter_with("stbllm_gateway_completed", "replica=\"0\"", ...)`.
    /// Each distinct `(name, labels)` pair is its own series.
    pub fn counter_with(&self, name: &str, labels: &str, help: &str) -> Arc<Counter> {
        debug_assert!(!name.ends_with("_total"), "register counters without _total: {name}");
        debug_assert!(!labels.contains(['{', '}', '\n']), "bad label set: {labels}");
        let on = self.on;
        let mut g = self.lock();
        g.help.entry(name.to_string()).or_insert_with(|| help.to_string());
        Arc::clone(
            g.counters
                .entry((name.to_string(), labels.to_string()))
                .or_insert_with(|| Arc::new(Counter::new(on))),
        )
    }

    /// Get-or-create the gauge `name`.
    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        self.gauge_with(name, "", help)
    }

    /// [`Registry::gauge`] with a fixed label set.
    pub fn gauge_with(&self, name: &str, labels: &str, help: &str) -> Arc<Gauge> {
        debug_assert!(!labels.contains(['{', '}', '\n']), "bad label set: {labels}");
        let on = self.on;
        let mut g = self.lock();
        g.help.entry(name.to_string()).or_insert_with(|| help.to_string());
        Arc::clone(
            g.gauges
                .entry((name.to_string(), labels.to_string()))
                .or_insert_with(|| Arc::new(Gauge::new(on))),
        )
    }

    /// Get-or-create the duration histogram `name` (by convention the
    /// name ends in `_seconds`).
    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        self.histogram_with(name, "", help)
    }

    /// [`Registry::histogram`] with a fixed label set (the `le` bucket
    /// label is appended after the fixed labels by the renderer).
    pub fn histogram_with(&self, name: &str, labels: &str, help: &str) -> Arc<Histogram> {
        debug_assert!(!labels.contains(['{', '}', '\n']), "bad label set: {labels}");
        let on = self.on;
        let mut g = self.lock();
        g.help.entry(name.to_string()).or_insert_with(|| help.to_string());
        Arc::clone(
            g.hists
                .entry((name.to_string(), labels.to_string()))
                .or_insert_with(|| Arc::new(Histogram::new(on))),
        )
    }

    /// Render the whole registry as Prometheus text exposition (version
    /// 0.0.4): `# HELP`/`# TYPE` preamble per metric name (shared by all
    /// its labeled series), counters suffixed `_total`, histograms as
    /// cumulative `_bucket{le=...}` series plus `_sum`/`_count`.
    /// Deterministic order (name-sorted per kind, unlabeled series first
    /// within a name).
    pub fn render_prometheus(&self) -> String {
        let g = self.lock();
        let mut out = String::new();
        let mut last = "";
        for ((name, labels), c) in &g.counters {
            if name != last {
                let help = g.help.get(name).map(String::as_str).unwrap_or("");
                out.push_str(&format!("# HELP {name}_total {help}\n"));
                out.push_str(&format!("# TYPE {name}_total counter\n"));
                last = name;
            }
            out.push_str(&format!("{} {}\n", series(name, "_total", labels), c.get()));
        }
        last = "";
        for ((name, labels), gauge) in &g.gauges {
            if name != last {
                let help = g.help.get(name).map(String::as_str).unwrap_or("");
                out.push_str(&format!("# HELP {name} {help}\n"));
                out.push_str(&format!("# TYPE {name} gauge\n"));
                last = name;
            }
            out.push_str(&format!("{} {}\n", series(name, "", labels), gauge.get()));
        }
        last = "";
        for ((name, labels), h) in &g.hists {
            if name != last {
                let help = g.help.get(name).map(String::as_str).unwrap_or("");
                out.push_str(&format!("# HELP {name} {help}\n"));
                out.push_str(&format!("# TYPE {name} histogram\n"));
                last = name;
            }
            let mut cum = 0u64;
            for (ub, count) in h.buckets() {
                cum += count;
                out.push_str(&format!("{} {cum}\n", bucket_series(name, labels, &ub.to_string())));
            }
            out.push_str(&format!("{} {}\n", bucket_series(name, labels, "+Inf"), h.count()));
            out.push_str(&format!("{} {}\n", series(name, "_sum", labels), h.sum_secs()));
            out.push_str(&format!("{} {}\n", series(name, "_count", labels), h.count()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent() {
        let r = Registry::new();
        let a = r.counter("stbllm_test_events", "events");
        let b = r.counter("stbllm_test_events", "events");
        assert!(Arc::ptr_eq(&a, &b));
        a.inc();
        assert_eq!(b.get(), 1); // same underlying atomic
    }

    #[test]
    fn disabled_registry_mints_noop_handles() {
        let r = Registry::disabled();
        assert!(!r.is_enabled());
        let c = r.counter("stbllm_test_events", "events");
        c.add(10);
        assert_eq!(c.get(), 0);
        let h = r.histogram("stbllm_test_wait_seconds", "wait");
        h.record_secs(1.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn renders_prometheus_exposition() {
        let r = Registry::new();
        r.counter("stbllm_test_events", "total events").add(3);
        r.gauge("stbllm_test_level", "current level").set(-2);
        let h = r.histogram("stbllm_test_wait_seconds", "wait time");
        h.record_secs(1e-6);
        h.record_secs(1e-3);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE stbllm_test_events_total counter\n"));
        assert!(text.contains("stbllm_test_events_total 3\n"));
        assert!(text.contains("# TYPE stbllm_test_level gauge\n"));
        assert!(text.contains("stbllm_test_level -2\n"));
        assert!(text.contains("# TYPE stbllm_test_wait_seconds histogram\n"));
        assert!(text.contains("stbllm_test_wait_seconds_bucket{le=\"+Inf\"} 2\n"));
        assert!(text.contains("stbllm_test_wait_seconds_count 2\n"));
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let val = parts.next().unwrap_or("");
            assert!(val.parse::<f64>().is_ok(), "unparsable value in: {line}");
            assert!(parts.next().is_some(), "no name in: {line}");
        }
        // cumulative bucket counts are non-decreasing and end at _count
        let buckets: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("stbllm_test_wait_seconds_bucket"))
            .filter_map(|l| l.rsplit(' ').next())
            .filter_map(|v| v.parse().ok())
            .collect();
        assert!(buckets.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(buckets.last(), Some(&2));
    }

    #[test]
    fn labeled_series_share_one_preamble() {
        let r = Registry::new();
        r.counter("stbllm_test_routed", "requests routed").add(9);
        r.counter_with("stbllm_test_routed", "replica=\"0\"", "requests routed").add(4);
        r.counter_with("stbllm_test_routed", "replica=\"1\"", "requests routed").add(5);
        r.gauge_with("stbllm_test_depth", "replica=\"0\"", "queue depth").set(3);
        r.histogram_with("stbllm_test_wait_seconds", "replica=\"1\"", "wait").record_secs(0.01);
        let text = r.render_prometheus();
        // one HELP/TYPE per metric name, shared by all its labeled series
        assert_eq!(text.matches("# TYPE stbllm_test_routed_total counter").count(), 1);
        assert!(text.contains("stbllm_test_routed_total 9\n"), "{text}");
        assert!(text.contains("stbllm_test_routed_total{replica=\"0\"} 4\n"), "{text}");
        assert!(text.contains("stbllm_test_routed_total{replica=\"1\"} 5\n"), "{text}");
        assert!(text.contains("stbllm_test_depth{replica=\"0\"} 3\n"), "{text}");
        // histogram labels merge before the le bucket label
        assert!(
            text.contains("stbllm_test_wait_seconds_bucket{replica=\"1\",le=\"+Inf\"} 1\n"),
            "{text}"
        );
        assert!(text.contains("stbllm_test_wait_seconds_count{replica=\"1\"} 1\n"), "{text}");
        // distinct label sets are distinct series
        let a = r.counter_with("stbllm_test_routed", "replica=\"0\"", "");
        let b = r.counter_with("stbllm_test_routed", "replica=\"1\"", "");
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(a.get(), 4);
    }

    #[test]
    fn global_registry_is_a_singleton() {
        let a = Registry::global();
        let b = Registry::global();
        assert!(std::ptr::eq(a, b));
        assert!(a.is_enabled());
    }
}
