//! Per-request trace spans: where did this token stream spend its time?
//!
//! A [`TraceSpan`] is born when a request is enqueued and rides with it
//! through the batch server: admission stamps queue-wait, every tick adds
//! its wall time to the prefill or decode stage (whichever phase the
//! session was in) and the packed-kernel share to `kernel`, the KV pool
//! contributes page counts and prefix-cache reuse. At retirement the span
//! collapses into a [`TraceSummary`] — a small `Copy` record that rides
//! on [`crate::coordinator::server::Response`], on the gateway's
//! streaming done-event (`"trace"`), and on the `x-stbllm-trace`
//! response trailer.
//!
//! Stage accounting is conservative by construction: tick wall-times are
//! disjoint intervals inside the span's lifetime, so
//! `queue + prefill + decode ≤ total` always holds (the smoke gate
//! asserts it per request).

use std::time::Instant;

use crate::util::json::{num, obj, Json};

/// Accumulating per-request span. Owned by the batch server's queue/active
/// entries; not thread-shared, so plain fields suffice.
#[derive(Debug, Clone)]
pub struct TraceSpan {
    enqueued: Instant,
    queue_s: f64,
    prefill_s: f64,
    decode_s: f64,
    kernel_s: f64,
    ttft_s: Option<f64>,
    pages: usize,
    prefix_hit_tokens: usize,
    prefill_tokens: usize,
    ticks: u32,
}

impl TraceSpan {
    /// Open a span at enqueue time.
    pub fn begin(now: Instant) -> Self {
        TraceSpan {
            enqueued: now,
            queue_s: 0.0,
            prefill_s: 0.0,
            decode_s: 0.0,
            kernel_s: 0.0,
            ttft_s: None,
            pages: 0,
            prefix_hit_tokens: 0,
            prefill_tokens: 0,
            ticks: 0,
        }
    }

    /// Stamp admission: everything from enqueue until now was queue wait.
    /// Returns the queue wait in seconds (for histogram recording).
    pub fn admitted(&mut self, now: Instant) -> f64 {
        self.queue_s = now.duration_since(self.enqueued).as_secs_f64();
        self.queue_s
    }

    /// Add `dt_s` of tick wall time to the prefill stage.
    pub fn add_prefill(&mut self, dt_s: f64) {
        self.prefill_s += dt_s;
        self.ticks += 1;
    }

    /// Add `dt_s` of tick wall time to the decode stage.
    pub fn add_decode(&mut self, dt_s: f64) {
        self.decode_s += dt_s;
        self.ticks += 1;
    }

    /// Add `dt_s` of time spent inside the backend's batched kernel call
    /// (the packed GEMV/GEMM itself, excluding scheduling and sampling).
    pub fn add_kernel(&mut self, dt_s: f64) {
        self.kernel_s += dt_s;
    }

    /// Stamp first-token time (from enqueue). Only the first call counts.
    pub fn first_token(&mut self, now: Instant) {
        if self.ttft_s.is_none() {
            self.ttft_s = Some(now.duration_since(self.enqueued).as_secs_f64());
        }
    }

    /// Record how many KV pages the request holds.
    pub fn set_pages(&mut self, pages: usize) {
        self.pages = pages;
    }

    /// Record prompt tokens served from the prefix cache instead of
    /// being prefilled.
    pub fn add_prefix_hit_tokens(&mut self, tokens: usize) {
        self.prefix_hit_tokens += tokens;
    }

    /// Record prompt tokens actually prefilled this tick (the chunked
    /// scheduler consumes up to `prefill_chunk` per tick; 1 per tick on
    /// the legacy path).
    pub fn add_prefill_tokens(&mut self, tokens: usize) {
        self.prefill_tokens += tokens;
    }

    /// Close the span and produce the summary that rides on the response.
    pub fn finish(&self, now: Instant) -> TraceSummary {
        let total_s = now.duration_since(self.enqueued).as_secs_f64();
        TraceSummary {
            total_ms: total_s * 1e3,
            queue_ms: self.queue_s * 1e3,
            prefill_ms: self.prefill_s * 1e3,
            decode_ms: self.decode_s * 1e3,
            kernel_ms: self.kernel_s * 1e3,
            ttft_ms: self.ttft_s.unwrap_or(total_s) * 1e3,
            pages: self.pages,
            prefix_hit_tokens: self.prefix_hit_tokens,
            prefill_tokens: self.prefill_tokens,
            ticks: self.ticks,
        }
    }
}

/// Closed span: the per-stage breakdown of one request, in milliseconds.
/// `Copy` so it can ride inside channel events (`DoneInfo`) for free.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TraceSummary {
    /// Enqueue → retirement wall time.
    pub total_ms: f64,
    /// Enqueue → admission (time spent waiting for batch/KV capacity).
    pub queue_ms: f64,
    /// Wall time of ticks spent prefilling the prompt.
    pub prefill_ms: f64,
    /// Wall time of ticks spent decoding new tokens.
    pub decode_ms: f64,
    /// Share of prefill+decode spent inside the backend kernel call.
    pub kernel_ms: f64,
    /// Enqueue → first emitted token.
    pub ttft_ms: f64,
    /// KV pages held at retirement.
    pub pages: usize,
    /// Prompt tokens served from the prefix cache.
    pub prefix_hit_tokens: usize,
    /// Prompt tokens actually prefilled (chunked prefill may consume many
    /// per tick; `prefix_hit_tokens + prefill_tokens` covers the prompt).
    pub prefill_tokens: usize,
    /// Number of scheduler ticks the request participated in.
    pub ticks: u32,
}

impl TraceSummary {
    /// JSON object used both in the stream's done-event (`"trace"` key)
    /// and as the `x-stbllm-trace` trailer value.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("total_ms", num(self.total_ms)),
            ("queue_ms", num(self.queue_ms)),
            ("prefill_ms", num(self.prefill_ms)),
            ("decode_ms", num(self.decode_ms)),
            ("kernel_ms", num(self.kernel_ms)),
            ("ttft_ms", num(self.ttft_ms)),
            ("pages", num(self.pages as f64)),
            ("prefix_hit_tokens", num(self.prefix_hit_tokens as f64)),
            ("prefill_tokens", num(self.prefill_tokens as f64)),
            ("ticks", num(f64::from(self.ticks))),
        ])
    }

    /// Compact single-line JSON for the `x-stbllm-trace` trailer.
    pub fn header_value(&self) -> String {
        self.to_json().dump()
    }

    /// The conservative-accounting invariant the smoke gate asserts:
    /// stage times are disjoint sub-intervals of the span, so their sum
    /// cannot exceed the total (modulo `eps_ms` of clock skew).
    pub fn stages_within_total(&self, eps_ms: f64) -> bool {
        self.queue_ms + self.prefill_ms + self.decode_ms <= self.total_ms + eps_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn span_stamps_queue_wait_and_stages() {
        let t0 = Instant::now();
        let mut span = TraceSpan::begin(t0);
        std::thread::sleep(Duration::from_millis(5));
        span.admitted(Instant::now());
        span.add_prefill(0.001);
        span.add_kernel(0.0008);
        span.first_token(Instant::now());
        span.add_decode(0.002);
        span.add_kernel(0.0015);
        let sum = span.finish(Instant::now());
        assert!(sum.queue_ms >= 4.0, "queue wait lost: {}", sum.queue_ms);
        assert!((sum.prefill_ms - 1.0).abs() < 1e-9);
        assert!((sum.decode_ms - 2.0).abs() < 1e-9);
        assert!((sum.kernel_ms - 2.3).abs() < 1e-9);
        assert_eq!(sum.ticks, 2);
        assert!(sum.total_ms >= sum.queue_ms);
        assert!(sum.ttft_ms <= sum.total_ms);
    }

    #[test]
    fn first_token_is_set_once() {
        let t0 = Instant::now();
        let mut span = TraceSpan::begin(t0);
        std::thread::sleep(Duration::from_millis(2));
        span.first_token(Instant::now());
        let first = span.finish(Instant::now()).ttft_ms;
        std::thread::sleep(Duration::from_millis(2));
        span.first_token(Instant::now()); // must not move the stamp
        let again = span.finish(Instant::now()).ttft_ms;
        assert_eq!(first, again);
    }

    #[test]
    fn summary_json_shape_and_invariant() {
        let sum = TraceSummary {
            total_ms: 10.0,
            queue_ms: 2.0,
            prefill_ms: 3.0,
            decode_ms: 4.0,
            kernel_ms: 5.0,
            ttft_ms: 6.0,
            pages: 3,
            prefix_hit_tokens: 8,
            prefill_tokens: 5,
            ticks: 7,
        };
        assert!(sum.stages_within_total(0.0)); // 2+3+4 <= 10
        let j = sum.to_json();
        assert_eq!(j.path(&["queue_ms"]).and_then(Json::as_f64), Some(2.0));
        assert_eq!(j.path(&["pages"]).and_then(Json::as_usize), Some(3));
        assert_eq!(j.path(&["prefill_tokens"]).and_then(Json::as_usize), Some(5));
        let parsed = Json::parse(&sum.header_value()).expect("trailer value parses");
        assert_eq!(parsed.get("ticks").and_then(Json::as_usize), Some(7));
        let busted = TraceSummary { queue_ms: 9.0, ..sum };
        assert!(!busted.stages_within_total(0.5)); // 9+3+4 > 10.5
    }
}
