//! The `Snapshot` trait and the versioned stats envelope (schema 2).
//!
//! The stats surfaces used to be five ad-hoc structs each hand-rolling
//! its own JSON at its own top level. Schema 2 re-homes them behind one
//! trait: a [`Snapshot`] names itself and serializes itself, and
//! [`envelope`] assembles any set of snapshots into
//! `{"schema": 2, "<name>": {...}, ...}`. `GET /stats`, `serve
//! --stats-json` and the drain report all emit this envelope; `loadgen`,
//! `chaos` and the smoke gates read it (`["gateway", "kv", ...]` paths
//! instead of the old flat top level).

use crate::util::json::{num, obj, Json};

/// Version stamped into every stats envelope. Bump when the shape of any
/// section changes incompatibly; readers assert on it.
pub const STATS_SCHEMA_VERSION: usize = 2;

/// A named, self-serializing view over observability state. Implemented
/// by `ServerStats`, `GatewayStats` snapshots and `KvPoolStats`.
pub trait Snapshot {
    /// The envelope key this snapshot lives under (e.g. `"server"`).
    fn name(&self) -> &'static str;
    /// The snapshot body (old flat fields, preserved verbatim).
    fn to_json(&self) -> Json;
}

/// Assemble snapshots into the versioned envelope:
/// `{"schema": 2, "<name>": {...}, ...}`.
pub fn envelope(parts: &[&dyn Snapshot]) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![("schema", num(STATS_SCHEMA_VERSION as f64))];
    for p in parts {
        fields.push((p.name(), p.to_json()));
    }
    obj(fields)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fake;
    impl Snapshot for Fake {
        fn name(&self) -> &'static str {
            "fake"
        }
        fn to_json(&self) -> Json {
            obj(vec![("answer", num(42.0))])
        }
    }

    #[test]
    fn envelope_wraps_named_sections_under_schema_2() {
        let doc = envelope(&[&Fake]);
        assert_eq!(doc.get("schema").and_then(Json::as_usize), Some(STATS_SCHEMA_VERSION));
        assert_eq!(doc.path(&["fake", "answer"]).and_then(Json::as_usize), Some(42));
        // round-trips through the serializer
        let parsed = Json::parse(&doc.dump()).expect("envelope serializes");
        assert_eq!(parsed.get("schema").and_then(Json::as_usize), Some(2));
    }
}
