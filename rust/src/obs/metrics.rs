//! Lock-free metric primitives: counters, gauges, log-scale histograms.
//!
//! Recording is a handful of `Relaxed` atomic operations — no locks, no
//! allocation — so these can sit on the per-token decode path. Every
//! handle carries an `on` flag fixed at mint time by its
//! [`crate::obs::Registry`]: a handle from a disabled registry skips the
//! atomics entirely, which is what makes the "no-op registry" baseline in
//! the recording-overhead comparison honest.
//!
//! The histogram uses one bucket per bit position of a nanosecond value
//! (64 buckets, ~2x resolution from 1 ns to centuries), so bucketing is a
//! `leading_zeros` — no search, no configuration, and any duration fits:
//! out-of-range values saturate into the last bucket instead of being
//! dropped.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// Number of logarithmic histogram buckets: one per bit position of a
/// nanosecond value. Bucket 0 holds exact zeros; bucket `i` holds values
/// in `[2^(i-1), 2^i)` ns; the last bucket also absorbs anything larger.
pub const HIST_BUCKETS: usize = 64;

/// Monotonically increasing event count (requests, tokens, evictions).
#[derive(Debug)]
pub struct Counter {
    v: AtomicU64,
    on: bool,
}

impl Counter {
    pub(crate) fn new(on: bool) -> Self {
        Counter { v: AtomicU64::new(0), on }
    }

    /// Add 1.
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n`. A handle minted by a disabled registry does nothing.
    pub fn add(&self, n: u64) {
        if self.on {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Instantaneous level (active sessions, queued requests, pages in use).
/// Signed so that a racy `sub` before `add` cannot wrap.
#[derive(Debug)]
pub struct Gauge {
    v: AtomicI64,
    on: bool,
}

impl Gauge {
    pub(crate) fn new(on: bool) -> Self {
        Gauge { v: AtomicI64::new(0), on }
    }

    /// Set the level outright.
    pub fn set(&self, n: i64) {
        if self.on {
            self.v.store(n, Ordering::Relaxed);
        }
    }

    /// Raise the level by `n`.
    pub fn add(&self, n: i64) {
        if self.on {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Lower the level by `n`.
    pub fn sub(&self, n: i64) {
        if self.on {
            self.v.fetch_sub(n, Ordering::Relaxed);
        }
    }

    /// Current level.
    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Fixed-bucket log2 latency histogram over nanoseconds.
///
/// Concurrent recording is loss-free: each sample is one `fetch_add` into
/// its bucket plus two more for the running sum/count. Quantiles are
/// nearest-rank at bucket granularity — the reported value is the
/// inclusive upper bound of the bucket containing the ranked sample, so
/// p50/p95/p99 are exact to within the ~2x bucket width and never
/// interpolate between samples that were not observed.
#[derive(Debug)]
pub struct Histogram {
    counts: [AtomicU64; HIST_BUCKETS],
    sum_nanos: AtomicU64,
    total: AtomicU64,
    on: bool,
}

impl Histogram {
    pub(crate) fn new(on: bool) -> Self {
        Histogram {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_nanos: AtomicU64::new(0),
            total: AtomicU64::new(0),
            on,
        }
    }

    /// Bucket index for a nanosecond value: its bit length, capped so
    /// out-of-range values saturate into the last bucket.
    fn bucket_of(nanos: u64) -> usize {
        ((64 - nanos.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i`, in seconds. The last bucket's
    /// bound is the largest representable nanosecond value (it is the
    /// saturation bucket).
    pub fn bucket_upper_secs(i: usize) -> f64 {
        let nanos = if i >= HIST_BUCKETS - 1 { u64::MAX } else { (1u64 << i) - 1 };
        nanos as f64 * 1e-9
    }

    /// Record one sample of `nanos` nanoseconds.
    pub fn record_nanos(&self, nanos: u64) {
        if !self.on {
            return;
        }
        self.counts[Self::bucket_of(nanos)].fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.total.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one sample of `secs` seconds. Negative, NaN and infinite
    /// inputs record as zero; durations beyond the u64-nanosecond range
    /// saturate (`as` casts from float clamp) into the last bucket.
    pub fn record_secs(&self, secs: f64) {
        let nanos = if secs.is_finite() && secs > 0.0 { (secs * 1e9) as u64 } else { 0 };
        self.record_nanos(nanos);
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }

    /// Sum of all recorded samples, in seconds.
    pub fn sum_secs(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Nearest-rank quantile (`p` in percent, e.g. 95.0) in seconds,
    /// exact at bucket granularity. Returns 0.0 with no samples.
    pub fn quantile(&self, p: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = (((p / 100.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            cum += c.load(Ordering::Relaxed);
            if cum >= rank {
                return Self::bucket_upper_secs(i);
            }
        }
        Self::bucket_upper_secs(HIST_BUCKETS - 1)
    }

    /// Per-bucket `(upper_bound_secs, count)` pairs, trimmed to the
    /// highest non-empty bucket (empty histogram renders no buckets).
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        let counts: Vec<u64> =
            self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        let last = match counts.iter().rposition(|&c| c > 0) {
            Some(i) => i,
            None => return Vec::new(),
        };
        counts[..=last]
            .iter()
            .enumerate()
            .map(|(i, &c)| (Self::bucket_upper_secs(i), c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new(true);
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new(true);
        g.add(3);
        g.sub(5);
        assert_eq!(g.get(), -2); // signed: transient underflow can't wrap
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn disabled_handles_are_noops() {
        let c = Counter::new(false);
        c.inc();
        assert_eq!(c.get(), 0);
        let g = Gauge::new(false);
        g.add(9);
        assert_eq!(g.get(), 0);
        let h = Histogram::new(false);
        h.record_secs(0.5);
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(50.0), 0.0);
    }

    #[test]
    fn histogram_zero_samples() {
        let h = Histogram::new(true);
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_secs(), 0.0);
        assert_eq!(h.quantile(50.0), 0.0);
        assert_eq!(h.quantile(99.0), 0.0);
        assert!(h.buckets().is_empty());
    }

    #[test]
    fn histogram_single_sample() {
        let h = Histogram::new(true);
        h.record_secs(1e-3); // 1ms = 1_000_000 ns, bit length 20
        assert_eq!(h.count(), 1);
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            let q = h.quantile(p);
            // every quantile of a single sample is that sample's bucket
            // upper bound: within one bucket width (2x) of the sample
            assert!(q >= 1e-3 && q <= 2e-3, "p{p} = {q}");
        }
        let b = h.buckets();
        assert_eq!(b.last().map(|&(_, c)| c), Some(1));
        assert_eq!(b.iter().map(|&(_, c)| c).sum::<u64>(), 1);
    }

    #[test]
    fn histogram_saturating_overflow() {
        let h = Histogram::new(true);
        h.record_secs(f64::MAX); // absurd duration: must clamp, not panic
        h.record_nanos(u64::MAX);
        assert_eq!(h.count(), 2);
        let b = h.buckets();
        assert_eq!(b.len(), HIST_BUCKETS); // landed in the last bucket
        assert_eq!(b.last().map(|&(_, c)| c), Some(2));
        let q = h.quantile(50.0);
        assert!(q.is_finite() && q > 0.0);
    }

    #[test]
    fn histogram_zero_and_negative_inputs_go_to_bucket_zero() {
        let h = Histogram::new(true);
        h.record_secs(0.0);
        h.record_secs(-1.0);
        h.record_secs(f64::NAN);
        assert_eq!(h.count(), 3);
        assert_eq!(h.buckets(), vec![(0.0, 3)]);
        assert_eq!(h.quantile(99.0), 0.0);
    }

    #[test]
    fn histogram_quantiles_are_nearest_rank_at_bucket_granularity() {
        let h = Histogram::new(true);
        // 90 fast samples (~1us) and 10 slow (~1s): p50 must report the
        // fast bucket, p95/p99 the slow one
        for _ in 0..90 {
            h.record_secs(1e-6);
        }
        for _ in 0..10 {
            h.record_secs(1.0);
        }
        assert!(h.quantile(50.0) < 1e-5);
        assert!(h.quantile(95.0) >= 1.0);
        assert!(h.quantile(99.0) >= 1.0);
        assert!((h.sum_secs() - (90.0 * 1e-6 + 10.0)).abs() < 1e-3);
    }

    #[test]
    fn concurrent_recording_is_loss_free() {
        const THREADS: usize = 4;
        const PER_THREAD: u64 = 10_000;
        let c = Counter::new(true);
        let h = Histogram::new(true);
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let (c, h) = (&c, &h);
                s.spawn(move || {
                    for i in 0..PER_THREAD {
                        c.inc();
                        // spread samples across several buckets
                        h.record_nanos((t as u64 + 1) * 1000 + i % 7);
                    }
                });
            }
        });
        assert_eq!(c.get(), THREADS as u64 * PER_THREAD);
        assert_eq!(h.count(), THREADS as u64 * PER_THREAD);
        let bucketed: u64 = h.buckets().iter().map(|&(_, n)| n).sum();
        assert_eq!(bucketed, THREADS as u64 * PER_THREAD);
    }
}
