//! Unified observability layer: metrics registry, trace spans, percentiles.
//!
//! Everything in here is dependency-free and cheap enough to leave on in
//! production serving:
//!
//! - [`metrics`] — lock-free [`Counter`]/[`Gauge`] and a fixed-bucket
//!   log-scale [`Histogram`] whose recording path is a couple of relaxed
//!   atomic ops. Handles minted by a disabled registry skip even those,
//!   so a no-op registry is genuinely free — that is the baseline the
//!   `loadgen` overhead comparison measures against.
//! - [`registry`] — a named-metric [`Registry`] (namespaced
//!   `stbllm_<subsystem>_<metric>` handles) that renders Prometheus text
//!   exposition for the gateway's `GET /metrics` endpoint.
//! - [`trace`] — per-request [`TraceSpan`]s stamping queue-wait, prefill,
//!   per-tick decode, packed-kernel time and KV page events, collapsed
//!   into a [`TraceSummary`] that rides on every HTTP response.
//! - [`percentile`] — the single nearest-rank percentile implementation
//!   shared by server stats, gateway stats and the load generator.
//! - [`snapshot`] — the [`Snapshot`] trait + versioned JSON [`envelope`]
//!   behind the schema-2 `GET /stats` redesign.
//!
//! The registry is plumbed explicitly (each server/gateway owns an
//! `Arc<Registry>`), keeping tests isolated; [`Registry::global`] exists
//! for process-wide tools that don't carry one.

pub mod metrics;
pub mod percentile;
pub mod registry;
pub mod snapshot;
pub mod trace;

pub use metrics::{Counter, Gauge, Histogram, HIST_BUCKETS};
pub use percentile::percentile;
pub use registry::Registry;
pub use snapshot::{envelope, Snapshot, STATS_SCHEMA_VERSION};
pub use trace::{TraceSpan, TraceSummary};
