//! The one percentile implementation, shared by every stats surface.
//!
//! `ServerStats`, `GatewayStats`, `BenchStats` and the load generator all
//! report latency percentiles; they used to disagree (nearest-rank here,
//! `round((p/100)·(n-1))` interpolation there, NaN vs 0.0 on empty).
//! This module pins ONE semantics — nearest-rank — and everything else
//! delegates: `crate::coordinator::server::percentile` re-exports this
//! function, and `BenchStats::percentile_s` calls it.

/// Nearest-rank percentile of an ascending-sorted slice: the smallest
/// value such that at least `p`% of the samples are ≤ it
/// (rank = ⌈p/100 · n⌉, 1-based). Empty input yields 0.0 — the JSON
/// sinks (`--stats-json`, `/stats`, BENCH_http.json) reject NaN/inf.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = ((p / 100.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Sort a sample vector ascending (NaN-tolerant) and return it — the
/// common prelude to [`percentile`] at every call site.
pub fn sorted(mut samples: Vec<f64>) -> Vec<f64> {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    samples
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The shared pin for nearest-rank semantics. Every consumer
    /// (`coordinator::server`, `net::stats`, `report::loadgen`,
    /// `util::timer`) resolves to this implementation, so this is the one
    /// place its contract is frozen.
    #[test]
    fn percentile_nearest_rank_pinned() {
        // known vector 1..=20: p50 = 10 (rank ⌈0.5·20⌉ = 10), p95 = 19,
        // p100 = 20, tiny p → min
        let v: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 10.0);
        assert_eq!(percentile(&v, 95.0), 19.0);
        assert_eq!(percentile(&v, 100.0), 20.0);
        assert_eq!(percentile(&v, 1.0), 1.0);
        // two samples: the median by nearest-rank is the FIRST, not the max
        assert_eq!(percentile(&[1.0, 2.0], 50.0), 1.0);
        assert_eq!(percentile(&[1.0, 2.0], 95.0), 2.0);
        // degenerate inputs
        assert_eq!(percentile(&[], 95.0), 0.0);
        assert_eq!(percentile(&[3.5], 95.0), 3.5);
    }

    #[test]
    fn sorted_orders_ascending() {
        assert_eq!(sorted(vec![3.0, 1.0, 2.0]), vec![1.0, 2.0, 3.0]);
        assert!(sorted(Vec::new()).is_empty());
    }
}
