//! Evaluation harnesses: perplexity, the 7-task zero-shot suite (Table 4),
//! and the sign-flip motivation study (Fig. 1).
//!
//! All scoring runs through the [`crate::engine::Backend`] seam — one
//! generic perplexity implementation serves the native, PJRT and packed
//! execution paths (the old `ppl_native` / `ppl_pjrt` pair remain as thin
//! wrappers). The usual entry point is the `Engine` facade
//! (`Engine::perplexity`, `Engine::zeroshot`, `Engine::flip_study`).

pub mod flip;
pub mod perplexity;
pub mod zeroshot;

pub use perplexity::{perplexity, perplexity_par, ppl_native, ppl_pjrt};
