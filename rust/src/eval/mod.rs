//! Evaluation harnesses: perplexity (native + PJRT paths), the 7-task
//! zero-shot suite (Table 4), and the sign-flip motivation study (Fig. 1).

pub mod flip;
pub mod perplexity;
pub mod zeroshot;

pub use perplexity::{ppl_native, ppl_pjrt};
