//! Zero-shot evaluation harness — 7 synthetic likelihood-ranked tasks
//! standing in for Winogrande / OBQA / Hellaswag / BoolQ / ARC-e / ARC-c /
//! RTE (Table 4). Each item gives the model a context and `n_choices`
//! candidate continuations; the model must rank the true continuation (the
//! actual corpus continuation) above distractors sampled per the task's
//! difficulty. Chance rates match the original benchmarks' option counts.
//!
//! The harness scores through any variable-length [`Backend`] (native or
//! packed); `Engine::zeroshot` picks the backend and handles the PJRT
//! fixed-window fallback.

use anyhow::Result;

use crate::engine::backend::Backend;
use crate::model::corpus::{self, Corpus};
use crate::util::rng::Pcg32;

/// How distractor continuations are produced (difficulty knob).
#[derive(Clone, Copy, Debug)]
pub enum Distractor {
    /// random slices from the same corpus (hard)
    InDomain,
    /// the true continuation with a few tokens perturbed (hardest)
    Perturbed,
    /// slices from a different corpus (easy)
    CrossCorpus,
}

/// A synthetic zero-shot task.
#[derive(Clone, Debug)]
pub struct Task {
    pub name: &'static str,
    pub corpus: &'static str,
    pub n_choices: usize,
    pub ctx_len: usize,
    pub cont_len: usize,
    pub n_items: usize,
    pub distractor: Distractor,
    pub seed: u64,
}

/// The 7-task suite (chance rates: 50/25/25/50/25/25/50 — as in Table 4).
pub fn tasks7() -> Vec<Task> {
    vec![
        Task { name: "Winogrande-s", corpus: "wikitext2s", n_choices: 2, ctx_len: 32, cont_len: 12, n_items: 60, distractor: Distractor::Perturbed, seed: 71 },
        Task { name: "OBQA-s", corpus: "c4s", n_choices: 4, ctx_len: 24, cont_len: 10, n_items: 50, distractor: Distractor::InDomain, seed: 72 },
        Task { name: "Hellaswag-s", corpus: "wikitext2s", n_choices: 4, ctx_len: 40, cont_len: 16, n_items: 50, distractor: Distractor::InDomain, seed: 73 },
        Task { name: "BoolQ-s", corpus: "ptbs", n_choices: 2, ctx_len: 24, cont_len: 8, n_items: 60, distractor: Distractor::InDomain, seed: 74 },
        Task { name: "ARC-e-s", corpus: "wikitext2s", n_choices: 4, ctx_len: 24, cont_len: 12, n_items: 50, distractor: Distractor::CrossCorpus, seed: 75 },
        Task { name: "ARC-c-s", corpus: "c4s", n_choices: 4, ctx_len: 32, cont_len: 14, n_items: 50, distractor: Distractor::Perturbed, seed: 76 },
        Task { name: "RTE-s", corpus: "ptbs", n_choices: 2, ctx_len: 28, cont_len: 10, n_items: 60, distractor: Distractor::InDomain, seed: 77 },
    ]
}

/// One evaluation item.
struct Item {
    ctx: Vec<u8>,
    cands: Vec<Vec<u8>>,
    correct: usize,
}

fn build_items(task: &Task) -> Vec<Item> {
    let spec = corpus::spec_by_name(task.corpus).unwrap();
    let corp = Corpus::new(spec);
    let other = Corpus::new(if task.corpus == "c4s" { corpus::WIKITEXT2S } else { corpus::C4S });
    let mut rng = Pcg32::new(task.seed, 29);
    let span = task.ctx_len + task.cont_len;
    let stream = corp.generate(task.n_items * span * 4, task.seed);
    let alt_stream = other.generate(task.n_items * span * 4, task.seed + 1);

    let mut items = Vec::with_capacity(task.n_items);
    for i in 0..task.n_items {
        let base = i * span * 3;
        let ctx = stream[base..base + task.ctx_len].to_vec();
        let truth = stream[base + task.ctx_len..base + span].to_vec();
        let mut cands = Vec::with_capacity(task.n_choices);
        let correct = rng.bounded(task.n_choices as u32) as usize;
        for c in 0..task.n_choices {
            if c == correct {
                cands.push(truth.clone());
                continue;
            }
            let d = match task.distractor {
                Distractor::InDomain => {
                    let off = (rng.bounded((stream.len() - span) as u32)) as usize;
                    stream[off..off + task.cont_len].to_vec()
                }
                Distractor::CrossCorpus => {
                    let off = (rng.bounded((alt_stream.len() - span) as u32)) as usize;
                    let alpha = spec.alphabet;
                    alt_stream[off..off + task.cont_len]
                        .iter()
                        .map(|&t| t % alpha as u8)
                        .collect()
                }
                Distractor::Perturbed => {
                    let mut t = truth.clone();
                    // flip ~1/3 of the tokens to random symbols
                    let flips = (task.cont_len / 3).max(1);
                    for _ in 0..flips {
                        let p = rng.bounded(task.cont_len as u32) as usize;
                        t[p] = rng.bounded(spec.alphabet) as u8;
                    }
                    t
                }
            };
            cands.push(d);
        }
        items.push(Item { ctx, cands, correct });
    }
    items
}

/// Log-likelihood of `cand` following `ctx` under the backend.
fn cand_loglik(backend: &dyn Backend, ctx: &[u8], cand: &[u8]) -> Result<f64> {
    let mut seq = ctx.to_vec();
    seq.extend_from_slice(cand);
    let logits = backend.forward(&seq[..seq.len() - 1])?;
    let mut ll = 0.0f64;
    for (k, &t) in cand.iter().enumerate() {
        let pos = ctx.len() - 1 + k;
        let row = logits.row(pos);
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        ll += (row[t as usize] - m - z.ln()) as f64;
    }
    Ok(ll)
}

/// Run one task through a backend; returns accuracy in percent.
pub fn run_task(backend: &dyn Backend, task: &Task) -> Result<f64> {
    let items = build_items(task);
    let mut correct = 0usize;
    for item in &items {
        let mut lls = Vec::with_capacity(item.cands.len());
        for c in &item.cands {
            lls.push(cand_loglik(backend, &item.ctx, c)?);
        }
        let pred = lls
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if pred == item.correct {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f64 / items.len() as f64)
}

/// Run all 7 tasks; returns (task name, accuracy) pairs + mean.
pub fn run_suite(backend: &dyn Backend) -> Result<(Vec<(&'static str, f64)>, f64)> {
    let mut out = Vec::new();
    for t in tasks7() {
        out.push((t.name, run_task(backend, &t)?));
    }
    let mean = out.iter().map(|(_, a)| a).sum::<f64>() / out.len() as f64;
    Ok((out, mean))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::native::NativeBackend;
    use crate::model::config::ModelConfig;
    use crate::model::ModelWeights;

    #[test]
    fn items_are_well_formed() {
        for t in tasks7() {
            let mut small = t.clone();
            small.n_items = 5;
            let items = build_items(&small);
            assert_eq!(items.len(), 5);
            for it in items {
                assert_eq!(it.ctx.len(), t.ctx_len);
                assert_eq!(it.cands.len(), t.n_choices);
                assert!(it.correct < t.n_choices);
                for c in &it.cands {
                    assert_eq!(c.len(), t.cont_len);
                }
            }
        }
    }

    #[test]
    fn items_deterministic() {
        let t = &tasks7()[0];
        let a = build_items(t);
        let b = build_items(t);
        assert_eq!(a[0].ctx, b[0].ctx);
        assert_eq!(a[0].correct, b[0].correct);
    }

    #[test]
    fn random_model_near_chance() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 3);
        let be = NativeBackend::borrowed(&cfg, &w);
        let mut t = tasks7()[0].clone(); // 2-choice
        t.n_items = 30;
        let acc = run_task(&be, &t).unwrap();
        assert!(acc > 15.0 && acc < 85.0, "acc={acc}");
    }
}
