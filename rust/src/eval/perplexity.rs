//! Perplexity evaluation over the synthetic corpora.
//!
//! ONE generic implementation ([`perplexity`]) windows the token stream at
//! `cfg.seq_len` and asks any [`Backend`] for full-sequence logits — the
//! former `ppl_native` / `ppl_pjrt` copy-paste is collapsed into thin
//! wrappers that stand a borrowed backend up. PJRT's fixed-window
//! constraint is satisfied by construction (windows are exactly `seq_len`
//! tokens), which is what the old hand-rolled PJRT loop did.
//!
//! Backends reporting [`Capabilities::chunked_prefill`] evaluate each
//! window through `DecodeSession::prefill` instead of `Backend::forward`:
//! one chunked decode-path pass per window, so eval rides the same
//! decode-amortized packed GEMM as serving. The two routes are pinned
//! against each other by test (`chunked_eval_bitmatches_token_by_token`).
//!
//! [`Capabilities::chunked_prefill`]: crate::engine::backend::Capabilities::chunked_prefill
//!
//! Perplexity is exp(mean NLL) of next-token prediction, matching
//! `python/compile/model.py::next_token_loss`.

use anyhow::Result;

use crate::engine::backend::Backend;
use crate::engine::native::NativeBackend;
use crate::engine::pjrt::PjrtBackend;
use crate::model::config::ModelConfig;
use crate::model::ModelWeights;
use crate::runtime::{Artifacts, Runtime};
use crate::tensor::Mat;

/// NLL of targets under a logits matrix (rows = positions).
fn nll_sum(logits: &Mat, targets: &[u8]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut total = 0.0f64;
    for (i, &t) in targets.iter().enumerate() {
        let row = logits.row(i);
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        total += (z.ln() + m - row[t as usize]) as f64;
    }
    total
}

/// Perplexity of `tokens` under any backend, over non-overlapping windows
/// of `cfg.seq_len` + 1 tokens (serial; see [`perplexity_par`]).
pub fn perplexity(backend: &dyn Backend, tokens: &[u8]) -> Result<f64> {
    perplexity_par(backend, tokens, 1)
}

/// Perplexity with the windows evaluated in parallel over
/// `coordinator::scheduler::run` (order-preserving). The per-window NLL
/// sums are reduced in window order, so the result is bit-identical to the
/// serial evaluation for any worker count. Unlike the old serial loop, a
/// failing window does NOT short-circuit the remaining windows (the pool
/// has no cancellation); the first error is returned after the pass.
pub fn perplexity_par(backend: &dyn Backend, tokens: &[u8], workers: usize) -> Result<f64> {
    let win = backend.cfg().seq_len;
    let mut starts = Vec::new();
    let mut i = 0usize;
    while i + win + 1 <= tokens.len() {
        starts.push(i);
        i += win;
    }
    let caps = backend.capabilities();
    let chunked = caps.chunked_prefill && caps.decode;
    let per_window = crate::coordinator::scheduler::run(starts, workers.max(1), |i| {
        let ctx = &tokens[i..i + win];
        let tgt = &tokens[i + 1..i + win + 1];
        if chunked {
            // decode-path window: one chunked prefill instead of a full
            // forward — the packed backend reads each weight word once per
            // window here rather than once per token
            backend
                .begin_decode(win)
                .and_then(|mut sess| sess.prefill(ctx, true))
                .map(|logits| nll_sum(&logits, tgt))
        } else {
            backend.forward(ctx).map(|logits| nll_sum(&logits, tgt))
        }
    });
    let mut total = 0.0f64;
    let mut count = 0usize;
    for w in per_window {
        total += w?;
        count += win;
    }
    Ok((total / count.max(1) as f64).exp())
}

/// Perplexity via the native Rust forward (infallible wrapper over
/// [`perplexity`] with a borrowed [`NativeBackend`]).
pub fn ppl_native(cfg: &ModelConfig, w: &ModelWeights, tokens: &[u8]) -> f64 {
    perplexity(&NativeBackend::borrowed(cfg, w), tokens)
        .expect("native backend forward is infallible")
}

/// Perplexity via the PJRT AOT path (wrapper over [`perplexity`] with a
/// borrowed [`PjrtBackend`] reusing `rt`'s executable cache).
pub fn ppl_pjrt(
    rt: &Runtime,
    arts: &Artifacts,
    model: &str,
    w: &ModelWeights,
    tokens: &[u8],
) -> Result<f64> {
    perplexity(&PjrtBackend::borrowed(rt, arts, model, w)?, tokens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::corpus;

    #[test]
    fn uniform_logits_give_vocab_ppl() {
        let logits = Mat::zeros(10, 256);
        let targets = vec![0u8; 10];
        let nll = nll_sum(&logits, &targets) / 10.0;
        assert!((nll.exp() - 256.0).abs() < 1e-3);
    }

    #[test]
    fn random_model_ppl_near_vocab_size() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 1);
        let toks = corpus::corpus_tokens("wikitext2s", 3 * 129, 7);
        let ppl = ppl_native(&cfg, &w, &toks);
        // untrained model ⇒ ppl in the vicinity of |alphabet|..|vocab|
        assert!(ppl > 30.0 && ppl < 1000.0, "ppl={ppl}");
    }

    #[test]
    fn zeroed_model_gives_exactly_uniform_ppl() {
        // with a zero embedding the logits are all equal ⇒ ppl == vocab size;
        // this pins the NLL math end-to-end
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let mut w = ModelWeights::synthetic(&cfg, 2);
        w.embed.scale(0.0);
        let toks = corpus::corpus_tokens("wikitext2s", 2 * 129, 3);
        let ppl = ppl_native(&cfg, &w, &toks);
        assert!((ppl - cfg.vocab as f64).abs() < 0.5, "ppl={ppl}");
    }

    #[test]
    fn generic_path_equals_native_wrapper() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 4);
        let toks = corpus::corpus_tokens("wikitext2s", 2 * 129, 11);
        let via_wrapper = ppl_native(&cfg, &w, &toks);
        let via_generic = perplexity(&NativeBackend::borrowed(&cfg, &w), &toks).unwrap();
        assert!((via_wrapper - via_generic).abs() < 1e-12);
    }

    /// The chunked-prefill eval route must bit-match evaluating the same
    /// windows one `step` at a time through a decode session.
    #[test]
    fn chunked_eval_bitmatches_token_by_token() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 8);
        let toks = corpus::corpus_tokens("wikitext2s", 3 * 129, 5);
        let be = NativeBackend::borrowed(&cfg, &w);
        assert!(be.capabilities().chunked_prefill);
        let got = perplexity(&be, &toks).unwrap();

        let win = cfg.seq_len;
        let (mut total, mut count) = (0.0f64, 0usize);
        let mut i = 0usize;
        while i + win + 1 <= toks.len() {
            let ctx = &toks[i..i + win];
            let tgt = &toks[i + 1..i + win + 1];
            let mut sess = be.begin_decode(win).unwrap();
            let mut logits = Mat::zeros(win, cfg.vocab);
            for (r, &t) in ctx.iter().enumerate() {
                logits.row_mut(r).copy_from_slice(&sess.step(t).unwrap());
            }
            total += nll_sum(&logits, tgt);
            count += win;
            i += win;
        }
        let want = (total / count.max(1) as f64).exp();
        assert!((got - want).abs() == 0.0, "{got} vs {want}");
    }

    /// Window-parallel evaluation reduces the per-window sums in window
    /// order, so any worker count reproduces the serial result exactly.
    #[test]
    fn parallel_eval_bitmatches_serial() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 6);
        let toks = corpus::corpus_tokens("c4s", 5 * 129, 21);
        let be = NativeBackend::borrowed(&cfg, &w);
        let serial = perplexity(&be, &toks).unwrap();
        for workers in [2usize, 3, 8] {
            let par = perplexity_par(&be, &toks, workers).unwrap();
            assert!(
                (serial - par).abs() == 0.0,
                "workers={workers}: {serial} vs {par}"
            );
        }
    }
}
