//! Perplexity evaluation over the synthetic corpora.
//!
//! Two execution paths:
//!  * `ppl_native` — the Rust transformer forward (any config, any length);
//!  * `ppl_pjrt`   — the AOT path: embedding in Rust, per-layer HLO
//!    executables + LM head through PJRT (fixed seq_len windows). This is
//!    the path that proves L1 (Pallas) ∘ L2 (JAX) ∘ L3 (Rust) compose.
//!
//! Perplexity is exp(mean NLL) of next-token prediction, matching
//! `python/compile/model.py::next_token_loss`.

use anyhow::Result;

use crate::model::config::{Family, ModelConfig};
use crate::model::transformer;
use crate::model::ModelWeights;
use crate::runtime::client::MatArg;
use crate::runtime::{Artifacts, Runtime};
use crate::tensor::Mat;

/// NLL of targets under a logits matrix (rows = positions).
fn nll_sum(logits: &Mat, targets: &[u8]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut total = 0.0f64;
    for (i, &t) in targets.iter().enumerate() {
        let row = logits.row(i);
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        total += (z.ln() + m - row[t as usize]) as f64;
    }
    total
}

/// Perplexity via the native Rust forward, over non-overlapping windows of
/// `cfg.seq_len`+1 tokens.
pub fn ppl_native(cfg: &ModelConfig, w: &ModelWeights, tokens: &[u8]) -> f64 {
    let win = cfg.seq_len;
    let mut total = 0.0f64;
    let mut count = 0usize;
    let mut i = 0usize;
    while i + win + 1 <= tokens.len() {
        let ctx = &tokens[i..i + win];
        let tgt = &tokens[i + 1..i + win + 1];
        let logits = transformer::model_fwd(cfg, w, ctx);
        total += nll_sum(&logits, tgt);
        count += win;
        i += win;
    }
    (total / count.max(1) as f64).exp()
}

/// Perplexity via the PJRT AOT path: layer_fwd_<model> is executed once per
/// layer per window; the LM head artifact produces logits.
pub fn ppl_pjrt(
    rt: &Runtime,
    arts: &Artifacts,
    model: &str,
    w: &ModelWeights,
    tokens: &[u8],
) -> Result<f64> {
    let ma = arts.models.get(model).ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;
    let cfg = &ma.config;
    let layer_exe = rt.load(&ma.layer_fwd)?;
    let head_exe = rt.load(&ma.lm_head)?;
    let names = cfg.layer_weight_names();

    let win = cfg.seq_len;
    let mut total = 0.0f64;
    let mut count = 0usize;
    let mut i = 0usize;
    while i + win + 1 <= tokens.len() {
        let ctx = &tokens[i..i + win];
        let tgt = &tokens[i + 1..i + win + 1];
        let mut x = transformer::embed(cfg, w, ctx);
        for lw in &w.layers {
            let mut args: Vec<MatArg> =
                vec![MatArg::M(&x), MatArg::V(&lw.ln1), MatArg::V(&lw.ln2)];
            for n in &names {
                args.push(MatArg::M(&lw.mats[*n]));
            }
            x = layer_exe.run(&args)?;
        }
        let logits =
            head_exe.run(&[MatArg::M(&x), MatArg::V(&w.ln_f), MatArg::M(&w.embed)])?;
        total += nll_sum(&logits, tgt);
        count += win;
        i += win;
    }
    if cfg.family == Family::Opt {
        // OPT shares the same artifact signature; nothing extra to do —
        // learned positions were added in `embed`.
    }
    Ok((total / count.max(1) as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::corpus;

    #[test]
    fn uniform_logits_give_vocab_ppl() {
        let logits = Mat::zeros(10, 256);
        let targets = vec![0u8; 10];
        let nll = nll_sum(&logits, &targets) / 10.0;
        assert!((nll.exp() - 256.0).abs() < 1e-3);
    }

    #[test]
    fn random_model_ppl_near_vocab_size() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 1);
        let toks = corpus::corpus_tokens("wikitext2s", 3 * 129, 7);
        let ppl = ppl_native(&cfg, &w, &toks);
        // untrained model ⇒ ppl in the vicinity of |alphabet|..|vocab|
        assert!(ppl > 30.0 && ppl < 1000.0, "ppl={ppl}");
    }

    #[test]
    fn zeroed_model_gives_exactly_uniform_ppl() {
        // with a zero embedding the logits are all equal ⇒ ppl == vocab size;
        // this pins the NLL math end-to-end
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let mut w = ModelWeights::synthetic(&cfg, 2);
        w.embed.scale(0.0);
        let toks = corpus::corpus_tokens("wikitext2s", 2 * 129, 3);
        let ppl = ppl_native(&cfg, &w, &toks);
        assert!((ppl - cfg.vocab as f64).abs() < 0.5, "ppl={ppl}");
    }
}
