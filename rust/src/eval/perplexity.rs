//! Perplexity evaluation over the synthetic corpora.
//!
//! ONE generic implementation ([`perplexity`]) windows the token stream at
//! `cfg.seq_len` and asks any [`Backend`] for full-sequence logits — the
//! former `ppl_native` / `ppl_pjrt` copy-paste is collapsed into thin
//! wrappers that stand a borrowed backend up. PJRT's fixed-window
//! constraint is satisfied by construction (windows are exactly `seq_len`
//! tokens), which is what the old hand-rolled PJRT loop did.
//!
//! Perplexity is exp(mean NLL) of next-token prediction, matching
//! `python/compile/model.py::next_token_loss`.

use anyhow::Result;

use crate::engine::backend::Backend;
use crate::engine::native::NativeBackend;
use crate::engine::pjrt::PjrtBackend;
use crate::model::config::ModelConfig;
use crate::model::ModelWeights;
use crate::runtime::{Artifacts, Runtime};
use crate::tensor::Mat;

/// NLL of targets under a logits matrix (rows = positions).
fn nll_sum(logits: &Mat, targets: &[u8]) -> f64 {
    assert_eq!(logits.rows, targets.len());
    let mut total = 0.0f64;
    for (i, &t) in targets.iter().enumerate() {
        let row = logits.row(i);
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
        total += (z.ln() + m - row[t as usize]) as f64;
    }
    total
}

/// Perplexity of `tokens` under any backend, over non-overlapping windows
/// of `cfg.seq_len` + 1 tokens.
pub fn perplexity(backend: &dyn Backend, tokens: &[u8]) -> Result<f64> {
    let win = backend.cfg().seq_len;
    let mut total = 0.0f64;
    let mut count = 0usize;
    let mut i = 0usize;
    while i + win + 1 <= tokens.len() {
        let ctx = &tokens[i..i + win];
        let tgt = &tokens[i + 1..i + win + 1];
        let logits = backend.forward(ctx)?;
        total += nll_sum(&logits, tgt);
        count += win;
        i += win;
    }
    Ok((total / count.max(1) as f64).exp())
}

/// Perplexity via the native Rust forward (infallible wrapper over
/// [`perplexity`] with a borrowed [`NativeBackend`]).
pub fn ppl_native(cfg: &ModelConfig, w: &ModelWeights, tokens: &[u8]) -> f64 {
    perplexity(&NativeBackend::borrowed(cfg, w), tokens)
        .expect("native backend forward is infallible")
}

/// Perplexity via the PJRT AOT path (wrapper over [`perplexity`] with a
/// borrowed [`PjrtBackend`] reusing `rt`'s executable cache).
pub fn ppl_pjrt(
    rt: &Runtime,
    arts: &Artifacts,
    model: &str,
    w: &ModelWeights,
    tokens: &[u8],
) -> Result<f64> {
    perplexity(&PjrtBackend::borrowed(rt, arts, model, w)?, tokens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::corpus;

    #[test]
    fn uniform_logits_give_vocab_ppl() {
        let logits = Mat::zeros(10, 256);
        let targets = vec![0u8; 10];
        let nll = nll_sum(&logits, &targets) / 10.0;
        assert!((nll.exp() - 256.0).abs() < 1e-3);
    }

    #[test]
    fn random_model_ppl_near_vocab_size() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 1);
        let toks = corpus::corpus_tokens("wikitext2s", 3 * 129, 7);
        let ppl = ppl_native(&cfg, &w, &toks);
        // untrained model ⇒ ppl in the vicinity of |alphabet|..|vocab|
        assert!(ppl > 30.0 && ppl < 1000.0, "ppl={ppl}");
    }

    #[test]
    fn zeroed_model_gives_exactly_uniform_ppl() {
        // with a zero embedding the logits are all equal ⇒ ppl == vocab size;
        // this pins the NLL math end-to-end
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let mut w = ModelWeights::synthetic(&cfg, 2);
        w.embed.scale(0.0);
        let toks = corpus::corpus_tokens("wikitext2s", 2 * 129, 3);
        let ppl = ppl_native(&cfg, &w, &toks);
        assert!((ppl - cfg.vocab as f64).abs() < 0.5, "ppl={ppl}");
    }

    #[test]
    fn generic_path_equals_native_wrapper() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 4);
        let toks = corpus::corpus_tokens("wikitext2s", 2 * 129, 11);
        let via_wrapper = ppl_native(&cfg, &w, &toks);
        let via_generic = perplexity(&NativeBackend::borrowed(&cfg, &w), &toks).unwrap();
        assert!((via_wrapper - via_generic).abs() < 1e-12);
    }
}
