//! Sign-flip motivation study (paper Fig. 1, Table 13, Algorithm 3).
//!
//! Flip the signs of a fraction of (binarized) weights — either randomly or
//! the least-significant ones under a score matrix — and measure perplexity.
//! The paper's observation: small flip ratios of non-salient weights barely
//! hurt, evidencing redundancy in 1-bit LLMs.

use crate::model::ModelWeights;
use crate::tensor::Mat;
use crate::util::rng::Pcg32;

/// Flip the signs of `ratio` of the elements of `w` (Algorithm 3).
/// When `scores` is given, the elements with the LOWEST scores are flipped
/// (the non-salient ones); otherwise a random subset.
pub fn flip_signs(w: &Mat, ratio: f64, scores: Option<&Mat>, rng: &mut Pcg32) -> Mat {
    let n = w.data.len();
    let k = ((n as f64) * ratio).round() as usize;
    let mut out = w.clone();
    if k == 0 {
        return out;
    }
    match scores {
        Some(c) => {
            assert_eq!(c.data.len(), n);
            let mut idx: Vec<usize> = (0..n).collect();
            idx.sort_by(|&a, &b| c.data[a].partial_cmp(&c.data[b]).unwrap_or(std::cmp::Ordering::Equal));
            for &i in idx.iter().take(k) {
                out.data[i] = -out.data[i];
            }
        }
        None => {
            for i in rng.choose_k(n, k) {
                out.data[i] = -out.data[i];
            }
        }
    }
    out
}

/// Flip signs across all quantizable matrices of a model.
pub fn flip_model(w: &ModelWeights, ratio: f64, salient_aware: bool, seed: u64) -> ModelWeights {
    let mut rng = Pcg32::seeded(seed);
    let mut out = w.clone();
    for layer in out.layers.iter_mut() {
        for m in layer.mats.values_mut() {
            let scores = salient_aware.then(|| m.map(f32::abs));
            *m = flip_signs(m, ratio, scores.as_ref(), &mut rng);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flips_exactly_k_elements() {
        let mut rng = Pcg32::seeded(1);
        let w = Mat::from_vec(4, 8, (0..32).map(|i| i as f32 + 1.0).collect());
        let f = flip_signs(&w, 0.25, None, &mut rng);
        let flipped = w.data.iter().zip(&f.data).filter(|(a, b)| a.signum() != b.signum()).count();
        assert_eq!(flipped, 8);
    }

    #[test]
    fn score_guided_flips_lowest() {
        let mut rng = Pcg32::seeded(2);
        let w = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let scores = Mat::from_vec(1, 4, vec![0.9, 0.1, 0.5, 0.7]);
        let f = flip_signs(&w, 0.5, Some(&scores), &mut rng);
        assert_eq!(f.data, vec![1.0, -2.0, -3.0, 4.0]);
    }

    #[test]
    fn zero_ratio_is_identity() {
        let mut rng = Pcg32::seeded(3);
        let w = Mat::from_vec(2, 2, vec![1.0, -2.0, 3.0, -4.0]);
        assert_eq!(flip_signs(&w, 0.0, None, &mut rng).data, w.data);
    }

    #[test]
    fn flip_model_touches_all_layers() {
        let cfg = crate::model::config::ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 4);
        let f = flip_model(&w, 0.1, false, 5);
        for (l0, l1) in w.layers.iter().zip(&f.layers) {
            let changed = l0.mats["wq"]
                .data
                .iter()
                .zip(&l1.mats["wq"].data)
                .filter(|(a, b)| a != b)
                .count();
            assert!(changed > 0);
        }
        // embeddings untouched
        assert_eq!(w.embed.data, f.embed.data);
    }
}
