//! `faults/` — the chaos harness: seeded fault injection against the
//! artifact loaders and the live HTTP gateway.
//!
//! The robustness claims this crate makes (typed rejection of corrupt
//! artifacts, panic supervision, load shedding, leak-free drains) are only
//! worth something if they hold under *injected* failure, not just happy
//! paths. This module turns each claim into a scripted fault:
//!
//! * [`plan`] — [`FaultPlan`](plan::FaultPlan): every fault parameter
//!   (which bits to flip, where to truncate, how long a client stalls)
//!   is derived from one seed through independent PCG streams, so a run
//!   is reproducible with `--seed N` and CI failures replay locally.
//! * [`chaos`] — [`run_chaos`](chaos::run_chaos): the two gauntlets.
//!   The *artifact* gauntlet corrupts `.stbp` / `.sbw2` containers
//!   (random bit flips, targeted payload flips, truncation, lying
//!   headers) and requires every corruption to be rejected with a typed
//!   [`ArtifactError`](crate::util::artifact::ArtifactError) — naming
//!   the corrupt entry where one exists — while v1 containers still
//!   load. The *serving* gauntlet stands a real gateway up and injects
//!   mid-stream disconnects, stalled clients, KV-pool exhaustion (the
//!   shed + retry path) and a decode-loop panic, requiring `/healthz`
//!   to answer after every fault and the final drain to leak zero KV
//!   pages.
//!
//! Entry point: `stbllm chaos [--smoke] [--seed N]` (the CI
//! `chaos-smoke` job); results land in `reports/CHAOS_report.json`.

pub mod chaos;
pub mod plan;

pub use chaos::{run_chaos, ChaosOpts, ChaosReport, FaultOutcome};
pub use plan::{flip_bit, FaultPlan};
