//! The chaos gauntlets: scripted fault injection with hard gates.
//!
//! [`run_chaos`] runs three gauntlets against the real implementations
//! (no mocks) and records one [`FaultOutcome`] per injected fault:
//!
//! 1. **Artifacts** — a tiny packed model is saved as a v2 `.stbp` and a
//!    `SBW2` weights file, then corrupted per the [`FaultPlan`]: seeded
//!    random bit flips, a targeted flip inside the first entry's payload,
//!    truncation, and a header lying about its sizes. Every corruption
//!    must be rejected with a typed
//!    [`ArtifactError`](crate::util::artifact::ArtifactError) (the
//!    targeted flip must *name* the corrupt entry), rejections must be
//!    byte-for-byte deterministic, and an untouched v1 container must
//!    still load.
//! 2. **Serving** — a real gateway (`serve_http` on `127.0.0.1:0`, small
//!    KV pool) survives, in order: a client vanishing mid-stream, a
//!    stalled half-written request, KV-pool exhaustion (at least one
//!    shed `503 + Retry-After`, then a backoff retry that completes),
//!    and a decode-loop panic injected through the bridge tick hook
//!    (supervisor restart + a fresh stream on the same channel).
//!    `/healthz` must answer 200 after every fault and the final drain
//!    must report zero leaked KV pages.
//! 3. **Replica death** — a second, two-replica gateway
//!    (`max_bridge_restarts = 0`) loses replica 0 to an armed panic
//!    while probe requests sit queued on its channel: the probes must
//!    migrate to the survivor and complete, `/healthz` must stay green,
//!    and the drain must again leak zero pages across both pools.
//!
//! The report always lands on disk (default
//! `reports/CHAOS_report.json`) before the pass/fail verdict, so CI can
//! upload it even when the gate fails.

use std::io::Write as _;
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::NativeBackend;
use crate::faults::plan::{flip_bit, FaultPlan};
use crate::model::config::ModelConfig;
use crate::model::weights::{parse_stbw, ModelWeights};
use crate::net::http::{read_response_head, BodyReader};
use crate::net::{serve_http, GatewayCtl, GenerateEvent, GenerateRequest, Router, ServeConfig};
use crate::packed::PackedModel;
use crate::util::artifact::ArtifactError;
use crate::util::json::{arr, num, obj, s, Json};

/// Configuration for [`run_chaos`].
#[derive(Clone, Debug)]
pub struct ChaosOpts {
    /// Seed for the [`FaultPlan`] (CI pins `7`).
    pub seed: u64,
    /// CI mode: same gauntlet, smoke-sized phrasing in the summary.
    pub smoke: bool,
    /// Report path override (default `reports/CHAOS_report.json`).
    pub out: Option<PathBuf>,
}

impl ChaosOpts {
    /// Defaults: seed 7, report under `reports/`.
    pub fn new(seed: u64) -> ChaosOpts {
        ChaosOpts { seed, smoke: false, out: None }
    }
}

/// One injected fault and whether the system held its guarantee.
#[derive(Clone, Debug)]
pub struct FaultOutcome {
    /// Stable fault id, e.g. `stbp-bit-flips`.
    pub name: String,
    /// Whether the gate held.
    pub ok: bool,
    /// Human-readable evidence (error text, counter values, timings).
    pub detail: String,
}

/// Everything one chaos run produced.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// The plan seed the run derived every fault from.
    pub seed: u64,
    /// Per-fault outcomes, in injection order.
    pub outcomes: Vec<FaultOutcome>,
    /// Whether every gate held.
    pub passed: bool,
    /// Where the JSON report was written.
    pub json_path: PathBuf,
}

impl ChaosReport {
    /// JSON form (what `reports/CHAOS_report.json` holds).
    pub fn to_json(&self) -> Json {
        let faults = self
            .outcomes
            .iter()
            .map(|o| {
                obj(vec![
                    ("name", s(&o.name)),
                    ("ok", Json::Bool(o.ok)),
                    ("detail", s(&o.detail)),
                ])
            })
            .collect();
        obj(vec![
            ("seed", num(self.seed as f64)),
            ("passed", Json::Bool(self.passed)),
            ("faults", arr(faults)),
        ])
    }
}

fn gate(outcomes: &mut Vec<FaultOutcome>, name: &str, ok: bool, detail: String) {
    eprintln!("[chaos] {} {name}: {detail}", if ok { "ok  " } else { "FAIL" });
    outcomes.push(FaultOutcome { name: name.to_string(), ok, detail });
}

/// Run both gauntlets and write the report. The returned report's
/// `passed` is the CI gate; infrastructure failures (bind errors, a
/// wedged gateway) surface as `Err` and fail the run the same way.
pub fn run_chaos(opts: &ChaosOpts) -> Result<ChaosReport> {
    let plan = FaultPlan::new(opts.seed);
    let mut outcomes = Vec::new();
    artifact_gauntlet(&plan, &mut outcomes)?;
    serving_gauntlet(&plan, &mut outcomes)?;
    replica_gauntlet(&mut outcomes)?;

    let passed = outcomes.iter().all(|o| o.ok);
    let json_path = opts
        .out
        .clone()
        .unwrap_or_else(|| crate::report::reports_dir().join("CHAOS_report.json"));
    if let Some(dir) = json_path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let report = ChaosReport { seed: opts.seed, outcomes, passed, json_path };
    std::fs::write(&report.json_path, report.to_json().dump())
        .with_context(|| format!("writing {}", report.json_path.display()))?;
    Ok(report)
}

/// Tiny model every fault is injected against (synthetic weights keyed by
/// the plan seed, so even the victim model is reproducible).
fn tiny_model(seed: u64) -> Result<(ModelConfig, ModelWeights)> {
    let cfg = ModelConfig::preset("llama1-7b")
        .context("preset llama1-7b missing from the model zoo")?;
    let w = ModelWeights::synthetic(&cfg, seed);
    Ok((cfg, w))
}

// ---------------------------------------------------------------------
// gauntlet 1: artifact corruption
// ---------------------------------------------------------------------

/// Number of seeded random bit flips thrown at each container.
const N_BIT_FLIPS: usize = 6;

/// Byte offset of the first byte *inside the first entry's payload* of an
/// encoded container, parsed from the wire bytes themselves (so the
/// harness needs no access to the store's private field order). `header`
/// is the fixed prefix before the first entry; the layout after the entry
/// name differs per container kind.
fn first_payload_offset(buf: &[u8], header: usize, kind_byte: bool) -> Option<usize> {
    let u32_at = |off: usize| -> Option<u32> {
        Some(u32::from_le_bytes(buf.get(off..off + 4)?.try_into().ok()?))
    };
    let mut off = header;
    let kind = if kind_byte {
        let k = *buf.get(off)?;
        off += 1;
        Some(k)
    } else {
        None
    };
    let name_len = u32_at(off)? as usize;
    off += 4 + name_len;
    match kind {
        // .stbp: packed24 (rows u32 | cols u32 | meta...) or f32 tensor
        Some(0) => Some(off + 8 + 2),
        // f32 tensor (both .stbp kind 1 and SBW2): ndim | dims | data
        _ => {
            let ndim = u32_at(off)? as usize;
            Some(off + 4 + 4 * ndim + 1)
        }
    }
}

/// Name of the first entry, parsed from the wire bytes.
fn first_entry_name(buf: &[u8], header: usize, kind_byte: bool) -> Option<String> {
    let off = header + usize::from(kind_byte);
    let name_len =
        u32::from_le_bytes(buf.get(off..off + 4)?.try_into().ok()?) as usize;
    let name = buf.get(off + 4..off + 4 + name_len)?;
    String::from_utf8(name.to_vec()).ok()
}

pub(crate) fn artifact_gauntlet(
    plan: &FaultPlan,
    outcomes: &mut Vec<FaultOutcome>,
) -> Result<()> {
    let dir = std::env::temp_dir().join(format!("stbllm-chaos-{}", std::process::id()));
    std::fs::create_dir_all(&dir).with_context(|| format!("mkdir {}", dir.display()))?;
    let result = artifact_gauntlet_in(plan, outcomes, &dir);
    std::fs::remove_dir_all(&dir).ok();
    result
}

fn artifact_gauntlet_in(
    plan: &FaultPlan,
    outcomes: &mut Vec<FaultOutcome>,
    dir: &std::path::Path,
) -> Result<()> {
    let (cfg, w) = tiny_model(plan.seed)?;
    let pm = PackedModel::from_weights(&cfg, &w)?;

    // clean v2 roundtrip is the baseline every corruption deviates from
    let stbp = dir.join("chaos.stbp");
    pm.save(&stbp)?;
    let clean = std::fs::read(&stbp)?;
    gate(
        outcomes,
        "stbp-roundtrip",
        PackedModel::load_bytes(&clean).is_ok(),
        format!("v2 container ({} bytes) reloads clean", clean.len()),
    );

    // seeded random bit flips: every one must be rejected with a typed
    // error, and rejections must be deterministic (same seed, same errors)
    let flips = plan.bit_flips(clean.len(), N_BIT_FLIPS);
    let reject = |bits: &[u64]| -> Vec<Option<String>> {
        bits.iter()
            .map(|&bit| {
                let mut bad = clean.clone();
                flip_bit(&mut bad, bit);
                PackedModel::load_bytes(&bad).err().map(|e| e.to_string())
            })
            .collect()
    };
    let first_pass = reject(&flips);
    let all_rejected = first_pass.iter().all(|e| e.is_some());
    gate(
        outcomes,
        "stbp-bit-flips",
        all_rejected,
        format!(
            "{}/{} seeded flips rejected (first: {})",
            first_pass.iter().filter(|e| e.is_some()).count(),
            flips.len(),
            first_pass[0].as_deref().unwrap_or("NOT REJECTED"),
        ),
    );
    gate(
        outcomes,
        "stbp-deterministic-rejection",
        reject(&flips) == first_pass,
        format!("two passes over {} flips produced identical errors", flips.len()),
    );

    // targeted payload flip: the error must NAME the corrupt entry
    let payload_off = first_payload_offset(&clean, 12, true)
        .context("could not locate the first .stbp entry payload")?;
    let victim = first_entry_name(&clean, 12, true)
        .context("could not parse the first .stbp entry name")?;
    let mut bad = clean.clone();
    flip_bit(&mut bad, payload_off as u64 * 8);
    let (named, detail) = match PackedModel::load_bytes(&bad) {
        Err(ArtifactError::EntryChecksum { entry, offset, .. }) => {
            (entry == victim, format!("entry {entry:?} @ offset {offset}"))
        }
        Err(other) => (false, format!("wrong error kind: {other}")),
        Ok(_) => (false, "corrupt payload ACCEPTED".to_string()),
    };
    gate(outcomes, "stbp-names-corrupt-entry", named, detail);

    // truncation: typed, never a panic or an OOM
    let cut = plan.truncate_to(clean.len());
    let truncated = PackedModel::load_bytes(&clean[..cut]);
    gate(
        outcomes,
        "stbp-truncation",
        truncated.is_err(),
        match truncated.err() {
            Some(e) => format!("cut to {cut}/{} bytes: {e}", clean.len()),
            None => "truncated container ACCEPTED".to_string(),
        },
    );

    // a header lying about its entry count must bound-check, not allocate
    let mut lying = clean.clone();
    lying[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    let lied = PackedModel::load_bytes(&lying);
    gate(
        outcomes,
        "stbp-lying-header",
        matches!(lied, Err(ArtifactError::BoundExceeded { .. })),
        match lied.err() {
            Some(e) => e.to_string(),
            None => "u32::MAX entry count ACCEPTED".to_string(),
        },
    );

    // v1 compatibility: yesterday's containers still load unchanged
    let v1 = dir.join("chaos_v1.stbp");
    pm.save_v1(&v1)?;
    let v1_bytes = std::fs::read(&v1)?;
    gate(
        outcomes,
        "stbp-v1-compat",
        v1_bytes[4..8] == 1u32.to_le_bytes() && PackedModel::load_bytes(&v1_bytes).is_ok(),
        format!("v1 container ({} bytes) loads without checksums", v1_bytes.len()),
    );

    // the weights container gets the same treatment
    let sbw = dir.join("chaos.sbw2");
    w.save(&sbw)?;
    let wclean = std::fs::read(&sbw)?;
    let woff = first_payload_offset(&wclean, 8, false)
        .context("could not locate the first SBW2 tensor payload")?;
    let wvictim = first_entry_name(&wclean, 8, false)
        .context("could not parse the first SBW2 tensor name")?;
    let mut wbad = wclean.clone();
    flip_bit(&mut wbad, woff as u64 * 8);
    let (wnamed, wdetail) = match parse_stbw(&wbad) {
        Err(ArtifactError::EntryChecksum { entry, offset, .. }) => {
            (entry == wvictim, format!("tensor {entry:?} @ offset {offset}"))
        }
        Err(other) => (false, format!("wrong error kind: {other}")),
        Ok(_) => (false, "corrupt tensor ACCEPTED".to_string()),
    };
    gate(
        outcomes,
        "sbw2-flip-rejected",
        parse_stbw(&wclean).is_ok() && wnamed,
        wdetail,
    );
    Ok(())
}

// ---------------------------------------------------------------------
// gauntlet 2: the live gateway
// ---------------------------------------------------------------------

/// Serving-side chaos sizing: a pool small enough to exhaust on purpose.
const CHAOS_KV_PAGES: usize = 16;
const CHAOS_PAGE_SIZE: usize = 4;
const CHAOS_MAX_BATCH: usize = 2;
/// Free-page watermark for the exhaustion fault: two saturating streams
/// (7 pages each) leave 2 free pages, below this, so the probe sheds.
const CHAOS_SHED_WATERMARK: usize = 4;
/// Per-fault patience (CI machines can be slow).
const WAIT: Duration = Duration::from_secs(60);

/// Shared fault-injection state behind the bridge tick hook: an optional
/// per-tick stall (keeps streams in flight while a fault needs them) and
/// a one-shot armed panic.
struct TickChaos {
    stall_ms: AtomicU64,
    panic_armed: AtomicBool,
}

fn connect(addr: SocketAddr) -> Result<TcpStream> {
    let s = TcpStream::connect(addr).context("connect to chaos gateway")?;
    s.set_read_timeout(Some(WAIT)).context("set read timeout")?;
    s.set_nodelay(true).ok();
    Ok(s)
}

/// One-shot request (`connection: close`); returns status, headers, body.
fn fetch(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let mut stream = connect(addr)?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nhost: chaos\r\nconnection: close\r\ncontent-length: {}\r\n\r\n{body}",
        body.len()
    )
    .context("send request")?;
    let head = read_response_head(&mut stream).context("read response head")?;
    let bytes = BodyReader::new(&head).read_all(&mut stream).context("read response body")?;
    Ok((head.status, head.headers, bytes))
}

fn healthz_ok(addr: SocketAddr) -> bool {
    matches!(fetch(addr, "GET", "/healthz", ""), Ok((200, _, _)))
}

/// Fetch `/stats` and return the whole document, asserting the schema-2
/// envelope on every read (the chaos run doubles as a gate on the stats
/// API contract).
fn stats_doc(addr: SocketAddr) -> Result<Json> {
    let (status, _, bytes) = fetch(addr, "GET", "/stats", "")?;
    if status != 200 {
        anyhow::bail!("/stats answered {status}");
    }
    let doc = Json::parse(&String::from_utf8_lossy(&bytes))
        .map_err(|e| anyhow::anyhow!("bad /stats json: {e}"))?;
    if doc.get("schema").and_then(Json::as_usize) != Some(2) {
        anyhow::bail!("/stats is not a schema-2 envelope: {}", doc.dump());
    }
    Ok(doc)
}

/// Fetch `/stats` and return its `"gateway"` section.
fn stats(addr: SocketAddr) -> Result<Json> {
    let doc = stats_doc(addr)?;
    doc.get("gateway")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("/stats envelope missing \"gateway\": {}", doc.dump()))
}

/// Poll `/stats` until `pred` holds (asynchronous retirement).
fn wait_stats(
    addr: SocketAddr,
    what: &str,
    pred: impl Fn(&Json) -> bool,
) -> Result<Json> {
    let deadline = Instant::now() + WAIT;
    loop {
        let doc = stats(addr)?;
        if pred(&doc) {
            return Ok(doc);
        }
        if Instant::now() >= deadline {
            anyhow::bail!("timed out waiting for {what}: {}", doc.dump());
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn generate_body(prompt: &[u8], max_new: usize) -> String {
    GenerateRequest::tokens(prompt.to_vec(), max_new).to_body()
}

/// Streamed `POST /generate` that completed: returns the token count once
/// the `done` line arrives.
fn run_stream(addr: SocketAddr, prompt: &[u8], max_new: usize) -> Result<usize> {
    let (status, _, bytes) =
        fetch(addr, "POST", "/generate", &generate_body(prompt, max_new))?;
    if status != 200 {
        anyhow::bail!("generate answered {status}: {}", String::from_utf8_lossy(&bytes));
    }
    let text = String::from_utf8_lossy(&bytes);
    let mut tokens = 0usize;
    let mut done = false;
    for line in text.lines() {
        match GenerateEvent::parse(line).map_err(|e| anyhow::anyhow!("bad stream line: {e}"))? {
            GenerateEvent::Token(_) => tokens += 1,
            GenerateEvent::Done(_) => done = true,
            GenerateEvent::Error(msg) => anyhow::bail!("stream error event: {msg}"),
        }
    }
    if !done {
        anyhow::bail!("stream ended without a done event");
    }
    Ok(tokens)
}

fn serving_gauntlet(plan: &FaultPlan, outcomes: &mut Vec<FaultOutcome>) -> Result<()> {
    let (cfg, w) = tiny_model(1)?;
    let ctl = GatewayCtl::new();
    let chaos_state =
        Arc::new(TickChaos { stall_ms: AtomicU64::new(0), panic_armed: AtomicBool::new(false) });
    {
        let cs = chaos_state.clone();
        ctl.set_tick_hook(Some(Arc::new(move |_replica, _tick| {
            if cs.panic_armed.swap(false, Ordering::SeqCst) {
                panic!("chaos: injected bridge panic");
            }
            let ms = cs.stall_ms.load(Ordering::Relaxed);
            if ms > 0 {
                std::thread::sleep(Duration::from_millis(ms));
            }
        })));
    }

    let ctl2 = ctl.clone();
    let handle = std::thread::spawn(move || {
        let be = NativeBackend::new(cfg, w);
        let mut opts = ServeConfig::new("127.0.0.1:0");
        opts.threads = 4;
        opts.max_batch = CHAOS_MAX_BATCH;
        opts.kv_pages = CHAOS_KV_PAGES;
        opts.page_size = CHAOS_PAGE_SIZE;
        opts.keepalive_ms = 50;
        opts.shed_watermark = CHAOS_SHED_WATERMARK;
        serve_http(&be, &opts, &ctl2)
    });
    let addr = ctl.wait_bound(WAIT).context("chaos gateway never bound")?;
    if !healthz_ok(addr) {
        anyhow::bail!("gateway unhealthy before any fault");
    }

    // ---- fault: client vanishes mid-stream -------------------------
    // slow the decode loop so the stream is provably in flight when the
    // client disconnects (otherwise a fast tiny model could complete
    // before the shutdown lands and the fault would test nothing)
    chaos_state.stall_ms.store(plan.decode_stall_ms(), Ordering::Relaxed);
    {
        let mut s = connect(addr)?;
        let body = generate_body(&[1, 2, 3], 24);
        write!(
            s,
            "POST /generate HTTP/1.1\r\nhost: chaos\r\ncontent-length: {}\r\n\r\n{body}",
            body.len()
        )?;
        let head = read_response_head(&mut s).context("disconnect victim head")?;
        if head.status != 200 {
            anyhow::bail!("victim stream answered {}", head.status);
        }
        let mut reader = BodyReader::new(&head);
        for _ in 0..plan.disconnect_after() {
            reader.next_piece(&mut s).context("victim stream chunk")?;
        }
        let _ = s.shutdown(std::net::Shutdown::Both);
    }
    let doc = wait_stats(addr, "disconnect cancellation", |d| {
        d.get("cancelled").and_then(Json::as_usize) >= Some(1)
            && d.path(&["kv", "pages_reserved"]).and_then(Json::as_usize) == Some(0)
    })?;
    chaos_state.stall_ms.store(0, Ordering::Relaxed);
    gate(
        outcomes,
        "client-disconnect",
        healthz_ok(addr),
        format!(
            "cancelled after {} chunks, pages recovered ({} cancelled total)",
            plan.disconnect_after(),
            doc.get("cancelled").and_then(Json::as_usize).unwrap_or(0)
        ),
    );

    // ---- fault: stalled client, half-written requests --------------
    let stall = plan.stall_ms();
    {
        // half a request head, then EOF
        let mut s = connect(addr)?;
        s.write_all(b"POST /generate HTTP/1.1\r\ncontent-le")?;
        std::thread::sleep(Duration::from_millis(stall));
        drop(s);
        // a body shorter than its content-length claims, then EOF
        let mut s = connect(addr)?;
        s.write_all(b"POST /generate HTTP/1.1\r\nhost: chaos\r\ncontent-length: 100\r\n\r\nshort")?;
        std::thread::sleep(Duration::from_millis(stall));
        drop(s);
    }
    gate(
        outcomes,
        "stalled-client",
        healthz_ok(addr) && run_stream(addr, &[4, 5], 2).is_ok(),
        format!("two half-written requests held {stall}ms; gateway still serves"),
    );

    // ---- fault: KV-pool exhaustion -> shed -> retry ----------------
    // two stalled streams reserve 14/16 pages; free (2) < watermark (4),
    // so the probe request must shed with 503 + Retry-After
    chaos_state.stall_ms.store(plan.decode_stall_ms(), Ordering::Relaxed);
    let saturators: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                // prompt 4 + max_new 24 = 28 tokens -> 7 pages each
                run_stream(addr, &[1, 2, 3, 4 + i], 24)
            })
        })
        .collect();
    wait_stats(addr, "pool saturation", |d| {
        d.path(&["kv", "pages_reserved"]).and_then(Json::as_usize) >= Some(14)
    })?;
    let (status, headers, _) =
        fetch(addr, "POST", "/generate", &generate_body(&[9, 9], 2))?;
    let shed_seen = status == 503
        && headers.iter().any(|(n, v)| n == "retry-after" && !v.is_empty());
    // lift the stall so the saturators finish, then retry with backoff
    chaos_state.stall_ms.store(0, Ordering::Relaxed);
    let mut retried_ok = false;
    let mut attempts = 0usize;
    let retry_deadline = Instant::now() + WAIT;
    while Instant::now() < retry_deadline {
        attempts += 1;
        match fetch(addr, "POST", "/generate", &generate_body(&[9, 9], 2))? {
            (200, _, _) => {
                retried_ok = true;
                break;
            }
            (503, _, _) => std::thread::sleep(Duration::from_millis(
                (25 * attempts as u64).min(500),
            )),
            (other, _, body) => anyhow::bail!(
                "retry answered {other}: {}",
                String::from_utf8_lossy(&body)
            ),
        }
    }
    for t in saturators {
        t.join()
            .map_err(|_| anyhow::anyhow!("saturator thread panicked"))?
            .context("saturating stream failed")?;
    }
    let shed_count =
        stats(addr)?.get("shed").and_then(Json::as_usize).unwrap_or(0);
    gate(
        outcomes,
        "kv-exhaustion-shed",
        shed_seen && retried_ok && shed_count >= 1 && healthz_ok(addr),
        format!(
            "probe shed with 503+Retry-After ({shed_count} sheds), \
             retry completed after {attempts} attempt(s)"
        ),
    );

    // ---- fault: decode-loop panic ----------------------------------
    chaos_state.panic_armed.store(true, Ordering::SeqCst);
    // the victim request trips the armed hook on its first tick; it may
    // see a 500 or a truncated stream — either is fine, a HANG is not
    let victim = fetch(addr, "POST", "/generate", &generate_body(&[1, 2], 8));
    let victim_note = match &victim {
        Ok((code, _, _)) => format!("victim answered {code}"),
        Err(e) => format!("victim stream cut: {e:#}"),
    };
    wait_stats(addr, "bridge restart", |d| {
        d.get("bridge_restarts").and_then(Json::as_usize) >= Some(1)
    })?;
    let revived = run_stream(addr, &[6, 7], 3).is_ok();
    let doc = stats(addr)?;
    gate(
        outcomes,
        "bridge-panic-restart",
        revived
            && healthz_ok(addr)
            && doc.get("bridge_panics").and_then(Json::as_usize) >= Some(1),
        format!(
            "{victim_note}; {} panic(s), {} restart(s), fresh stream completed",
            doc.get("bridge_panics").and_then(Json::as_usize).unwrap_or(0),
            doc.get("bridge_restarts").and_then(Json::as_usize).unwrap_or(0)
        ),
    );

    // ---- drain: zero leaked pages after all of the above -----------
    let (status, _, _) = fetch(addr, "POST", "/admin/drain", "")?;
    if status != 200 {
        anyhow::bail!("drain answered {status}");
    }
    let report = handle
        .join()
        .map_err(|_| anyhow::anyhow!("gateway thread panicked"))?
        .context("gateway errored")?;
    gate(
        outcomes,
        "drain-leak-free",
        report.leaked_pages == 0,
        format!(
            "{} completed, {} cancelled, {} leaked pages",
            report.completed, report.cancelled, report.leaked_pages
        ),
    );
    Ok(())
}

// ---------------------------------------------------------------------
// gauntlet 3: replica death and migration
// ---------------------------------------------------------------------

/// Queued probes that must migrate off the killed replica.
const MIGRATE_PROBES: usize = 2;

/// Fetch the `/metrics` Prometheus exposition.
fn fetch_metrics(addr: SocketAddr) -> Result<String> {
    let (status, _, bytes) = fetch(addr, "GET", "/metrics", "")?;
    if status != 200 {
        anyhow::bail!("/metrics answered {status}");
    }
    Ok(String::from_utf8_lossy(&bytes).into_owned())
}

/// Value of one series in a `/metrics` exposition, matched by its full
/// series name including any labels (`0.0` if absent).
fn metric_value(text: &str, series: &str) -> f64 {
    text.lines()
        .find_map(|l| {
            l.strip_prefix(series)
                .and_then(|rest| rest.strip_prefix(' '))
                .and_then(|rest| rest.trim().parse::<f64>().ok())
        })
        .unwrap_or(0.0)
}

/// Poll the `/stats` `"replicas"` section until `pred` holds.
fn wait_replicas(
    addr: SocketAddr,
    what: &str,
    pred: impl Fn(&[Json]) -> bool,
) -> Result<Json> {
    let deadline = Instant::now() + WAIT;
    loop {
        let doc = stats_doc(addr)?;
        let rows = doc
            .get("replicas")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("/stats missing \"replicas\": {}", doc.dump()))?;
        if pred(rows) {
            return Ok(doc);
        }
        if Instant::now() >= deadline {
            anyhow::bail!("timed out waiting for {what}: {}", doc.dump());
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// A two-replica gateway loses replica 0 for good (`max_bridge_restarts
/// = 0`). The victim stream dies with the decode loop — that is the
/// single-replica contract already gated above — but requests still
/// queued on the dead replica's channel must be re-dispatched to the
/// survivor and complete, `/healthz` must stay green throughout, and
/// the drain must still leak zero pages across both pools.
fn replica_gauntlet(outcomes: &mut Vec<FaultOutcome>) -> Result<()> {
    let (cfg, w) = tiny_model(1)?;
    let ctl = GatewayCtl::new();
    // replica 0's tick hook stalls in short armed-checking slices, so
    // the panic fires mid-tick — while later requests for replica 0
    // still sit in its channel rather than its scheduler queue
    let armed = Arc::new(AtomicBool::new(false));
    {
        let armed = armed.clone();
        ctl.set_tick_hook(Some(Arc::new(move |replica, _tick| {
            if replica != 0 {
                return;
            }
            for _ in 0..3000 {
                if armed.swap(false, Ordering::SeqCst) {
                    panic!("chaos: injected replica-0 panic");
                }
                std::thread::sleep(Duration::from_millis(10));
            }
        })));
    }

    let ctl2 = ctl.clone();
    let handle = std::thread::spawn(move || {
        let be = NativeBackend::new(cfg, w);
        let mut opts = ServeConfig::new("127.0.0.1:0");
        opts.threads = 4;
        opts.max_batch = CHAOS_MAX_BATCH;
        opts.kv_pages = CHAOS_KV_PAGES * 2;
        opts.page_size = CHAOS_PAGE_SIZE;
        opts.keepalive_ms = 50;
        opts.replicas = 2;
        opts.max_bridge_restarts = 0;
        serve_http(&be, &opts, &ctl2)
    });
    let addr = ctl.wait_bound(WAIT).context("replica gateway never bound")?;
    if !healthz_ok(addr) {
        anyhow::bail!("replica gateway unhealthy before any fault");
    }

    // prompts the router provably maps to replica 0
    let affine0: Vec<u8> = (0u8..=255)
        .filter(|&b| Router::affine_replica(&[b], 2) == 0)
        .take(MIGRATE_PROBES + 1)
        .collect();
    if affine0.len() < MIGRATE_PROBES + 1 {
        anyhow::bail!("could not find enough replica-0 affine prompts");
    }

    // ---- fault: replica 0 dies with requests queued on its channel --
    let victim = {
        let body = generate_body(&[affine0[0]], 8);
        std::thread::spawn(move || fetch(addr, "POST", "/generate", &body))
    };
    // once the victim is decoding, replica 0's bridge is inside its
    // stalled tick and everything dispatched next stays in the channel
    wait_replicas(addr, "victim active on replica 0", |rows| {
        rows.first().and_then(|r| r.get("active").and_then(Json::as_usize)) >= Some(1)
    })?;
    let probes: Vec<_> = affine0[1..=MIGRATE_PROBES]
        .iter()
        .map(|&b| std::thread::spawn(move || run_stream(addr, &[b], 3)))
        .collect();
    // the routed counter ticks at dispatch time, so it proves the
    // probes reached replica 0's channel before the panic is armed
    let routed_deadline = Instant::now() + WAIT;
    loop {
        let m = fetch_metrics(addr)?;
        if metric_value(&m, "stbllm_router_routed_total{replica=\"0\"}")
            >= (1 + MIGRATE_PROBES) as f64
        {
            break;
        }
        if Instant::now() >= routed_deadline {
            anyhow::bail!("probes never routed to replica 0");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    armed.store(true, Ordering::SeqCst);

    let mut probe_notes = Vec::new();
    let mut migrated_ok = 0usize;
    for p in probes {
        match p.join().map_err(|_| anyhow::anyhow!("probe thread panicked"))? {
            Ok(tokens) => {
                if tokens == 3 {
                    migrated_ok += 1;
                }
                probe_notes.push(format!("ok({tokens} tok)"));
            }
            Err(e) => probe_notes.push(format!("err({e:#})")),
        }
    }
    let victim_note = match victim.join().map_err(|_| anyhow::anyhow!("victim panicked"))? {
        Ok((code, _, _)) => format!("victim answered {code}"),
        Err(e) => format!("victim stream cut: {e:#}"),
    };
    let doc = wait_replicas(addr, "replica 0 marked dead", |rows| {
        rows.first().is_some_and(|r| {
            r.get("dead") == Some(&Json::Bool(true))
                && r.get("panics").and_then(Json::as_usize) >= Some(1)
        })
    })?;
    let panics = doc
        .get("replicas")
        .and_then(Json::as_arr)
        .and_then(|rows| rows.first())
        .and_then(|r| r.get("panics"))
        .and_then(Json::as_usize)
        .unwrap_or(0);
    let migrated = metric_value(&fetch_metrics(addr)?, "stbllm_router_migrated_total") as usize;
    // even replica-0-affine prompts must now route to the survivor
    let survivor_ok = run_stream(addr, &[affine0[0], 1], 3).is_ok();
    gate(
        outcomes,
        "replica-kill-migrate",
        migrated_ok == MIGRATE_PROBES
            && migrated >= MIGRATE_PROBES
            && survivor_ok
            && healthz_ok(addr),
        format!(
            "{victim_note}; probes [{}] after {migrated} migration(s), \
             replica 0 dead with {panics} panic(s), survivor serves",
            probe_notes.join(", ")
        ),
    );

    // ---- drain: both pools leak-free with one replica dead ---------
    let (status, _, _) = fetch(addr, "POST", "/admin/drain", "")?;
    if status != 200 {
        anyhow::bail!("drain answered {status}");
    }
    let report = handle
        .join()
        .map_err(|_| anyhow::anyhow!("replica gateway thread panicked"))?
        .context("replica gateway errored")?;
    gate(
        outcomes,
        "replica-drain-leak-free",
        report.leaked_pages == 0,
        format!(
            "{} completed, {} leaked pages across both replica pools",
            report.completed, report.leaked_pages
        ),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The artifact gauntlet must pass under the CI seed — this is the
    /// offline half of the `chaos-smoke` job, cheap enough for `cargo
    /// test`.
    #[test]
    fn artifact_gauntlet_passes_under_ci_seed() {
        let plan = FaultPlan::new(7);
        let mut outcomes = Vec::new();
        artifact_gauntlet(&plan, &mut outcomes).expect("gauntlet infrastructure");
        assert!(!outcomes.is_empty());
        for o in &outcomes {
            assert!(o.ok, "fault {} failed its gate: {}", o.name, o.detail);
        }
    }

    #[test]
    fn report_json_roundtrips() {
        let report = ChaosReport {
            seed: 7,
            outcomes: vec![FaultOutcome {
                name: "stbp-bit-flips".into(),
                ok: true,
                detail: "6/6 rejected".into(),
            }],
            passed: true,
            json_path: PathBuf::from("reports/CHAOS_report.json"),
        };
        let doc = Json::parse(&report.to_json().dump()).expect("parse");
        assert_eq!(doc.get("seed").and_then(Json::as_usize), Some(7));
        assert_eq!(doc.get("passed"), Some(&Json::Bool(true)));
        let faults = match doc.get("faults") {
            Some(Json::Arr(v)) => v,
            other => panic!("faults not an array: {other:?}"),
        };
        assert_eq!(faults[0].get("name").and_then(Json::as_str), Some("stbp-bit-flips"));
    }
}
