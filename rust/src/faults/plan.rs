//! Seeded fault plans — the deterministic random core of the chaos
//! harness.
//!
//! Every fault parameter is drawn from its own PCG stream keyed by the
//! plan seed, so adding a fault class (or reordering the gauntlet) never
//! shifts the draws of the existing ones: `--seed 7` means the same bit
//! flips, the same truncation point and the same stall durations on every
//! machine, every run.

use crate::util::rng::Pcg32;

/// PCG stream ids, one per fault class (see module doc for why each class
/// gets its own stream).
mod stream {
    pub const BIT_FLIPS: u64 = 0xb17;
    pub const TRUNCATE: u64 = 0x7c4;
    pub const CLIENT: u64 = 0xc11;
    pub const STALL: u64 = 0x57a;
    pub const DECODE: u64 = 0xdec;
}

/// A deterministic fault plan derived from one seed.
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    /// The seed every draw derives from (reported in CHAOS_report.json).
    pub seed: u64,
}

impl FaultPlan {
    /// Plan keyed by `seed`.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed }
    }

    fn rng(&self, stream: u64) -> Pcg32 {
        Pcg32::new(self.seed, stream)
    }

    /// `n` distinct bit positions to flip in a `len`-byte artifact.
    pub fn bit_flips(&self, len: usize, n: usize) -> Vec<u64> {
        let total_bits = (len as u64) * 8;
        let mut rng = self.rng(stream::BIT_FLIPS);
        let mut out: Vec<u64> = Vec::with_capacity(n);
        while out.len() < n && (out.len() as u64) < total_bits {
            let bit = (rng.next_u32() as u64) % total_bits;
            if !out.contains(&bit) {
                out.push(bit);
            }
        }
        out
    }

    /// Where to truncate a `len`-byte artifact (always keeps the magic so
    /// the failure exercises the bounded entry readers, not just BadMagic).
    pub fn truncate_to(&self, len: usize) -> usize {
        if len <= 8 {
            return len.saturating_sub(1);
        }
        let span = (len - 8) as u32;
        8 + self.rng(stream::TRUNCATE).bounded(span) as usize
    }

    /// How many streamed token chunks a chaos client reads before
    /// vanishing mid-stream (1..=3).
    pub fn disconnect_after(&self) -> usize {
        1 + self.rng(stream::CLIENT).bounded(3) as usize
    }

    /// How long the stalled-client fault holds a half-written request
    /// open, in milliseconds (20..=100).
    pub fn stall_ms(&self) -> u64 {
        20 + self.rng(stream::STALL).bounded(81) as u64
    }

    /// Per-tick decode slowdown while a serving fault needs streams to
    /// stay in flight, in milliseconds (10..=40).
    pub fn decode_stall_ms(&self) -> u64 {
        10 + self.rng(stream::DECODE).bounded(31) as u64
    }
}

/// Flip one bit (global bit index, LSB-first within each byte) in `buf`.
pub fn flip_bit(buf: &mut [u8], bit: u64) {
    let byte = (bit / 8) as usize;
    if byte < buf.len() {
        buf[byte] ^= 1 << (bit % 8);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plans_are_deterministic_per_seed() {
        let a = FaultPlan::new(7);
        let b = FaultPlan::new(7);
        assert_eq!(a.bit_flips(1024, 6), b.bit_flips(1024, 6));
        assert_eq!(a.truncate_to(1024), b.truncate_to(1024));
        assert_eq!(a.stall_ms(), b.stall_ms());
        // a different seed draws a different gauntlet
        assert_ne!(a.bit_flips(1024, 6), FaultPlan::new(8).bit_flips(1024, 6));
    }

    #[test]
    fn draws_stay_in_range() {
        for seed in 0..32 {
            let p = FaultPlan::new(seed);
            let flips = p.bit_flips(100, 6);
            assert_eq!(flips.len(), 6);
            assert!(flips.iter().all(|&b| b < 800));
            // distinct positions: a duplicate would waste a flip
            let mut sorted = flips.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 6);
            let t = p.truncate_to(100);
            assert!((8..100).contains(&t), "truncate_to({seed}) = {t}");
            assert!((1..=3).contains(&p.disconnect_after()));
            assert!((20..=100).contains(&p.stall_ms()));
            assert!((10..=40).contains(&p.decode_stall_ms()));
        }
    }

    #[test]
    fn flip_bit_is_an_involution() {
        let mut buf = vec![0u8; 4];
        flip_bit(&mut buf, 9);
        assert_eq!(buf, vec![0, 2, 0, 0]);
        flip_bit(&mut buf, 9);
        assert_eq!(buf, vec![0, 0, 0, 0]);
        flip_bit(&mut buf, 1000); // out of range: no-op, no panic
        assert_eq!(buf, vec![0, 0, 0, 0]);
    }
}
