//! Sub-1-bit storage format for 2:4 structured-binary matrices —
//! the paper's Appendix C encoding, bit-for-bit:
//!
//! * every group of 4 consecutive weights holds exactly 2 non-zeros;
//! * per group: 4 **index** bits (two 2-bit positions of the non-zeros) and
//!   2 **sign** bits (1 → +1, 0 → −1) — 6 bits per 4 weights = 1.5 bits/weight;
//! * index nibbles are packed 4-per-`u16` ("Uint16 Meta Index", Fig. 5) and
//!   sign pairs 4-per-`u8` ("Uint8 Real Value", Fig. 6);
//! * one f32 scale per output channel (the binarization α).
//!
//! This beats the naive 2-bit {-1,0,+1} encoding by 25% (6 bits vs 8 per
//! group), which is exactly the memory-traffic advantage Appendix C claims.

use crate::tensor::Mat;

/// A 2:4 structured-binary matrix in packed form.
#[derive(Clone, Debug)]
pub struct Packed24 {
    pub rows: usize,
    pub cols: usize,
    /// 4 index-nibbles per u16; one nibble per 4-weight group, row-major
    pub meta: Vec<u16>,
    /// 4 sign-pairs per u8; bit 1 = +1, bit 0 = −1
    pub signs: Vec<u8>,
    /// per-output-row scale α
    pub alpha: Vec<f32>,
}

/// Groups of 4 weights per row (cols must be divisible by 4).
fn groups_per_row(cols: usize) -> usize {
    assert_eq!(cols % 4, 0, "2:4 packing requires cols % 4 == 0");
    cols / 4
}

impl Packed24 {
    /// Pack a structured-binary matrix. `sb` entries must be in {-1, 0, +1}
    /// with exactly 2 non-zeros per aligned group of 4 (use
    /// `enforce_24` first if the source is a general N:M reconstruction).
    pub fn pack(sb: &Mat, alpha: &[f32]) -> Result<Packed24, String> {
        let g = groups_per_row(sb.cols);
        assert_eq!(alpha.len(), sb.rows);
        let total_groups = sb.rows * g;
        let mut meta = vec![0u16; (total_groups + 3) / 4];
        let mut signs = vec![0u8; (total_groups + 3) / 4];
        let mut gi = 0usize; // global group index
        for i in 0..sb.rows {
            let row = sb.row(i);
            for gg in 0..g {
                let vals = &row[gg * 4..gg * 4 + 4];
                let mut pos = [0u8; 2];
                let mut sg = [false; 2];
                let mut cnt = 0;
                for (p, &v) in vals.iter().enumerate() {
                    if v != 0.0 {
                        if cnt >= 2 {
                            return Err(format!("row {i} group {gg}: >2 non-zeros"));
                        }
                        if v != 1.0 && v != -1.0 {
                            return Err(format!("row {i} group {gg}: value {v} not ±1"));
                        }
                        pos[cnt] = p as u8;
                        sg[cnt] = v > 0.0;
                        cnt += 1;
                    }
                }
                if cnt != 2 {
                    return Err(format!("row {i} group {gg}: {cnt} non-zeros (need 2)"));
                }
                let nibble = (pos[0] | (pos[1] << 2)) as u16;
                meta[gi / 4] |= nibble << (4 * (gi % 4));
                let spair = (sg[0] as u8) | ((sg[1] as u8) << 1);
                signs[gi / 4] |= spair << (2 * (gi % 4));
                gi += 1;
            }
        }
        Ok(Packed24 { rows: sb.rows, cols: sb.cols, meta, signs, alpha: alpha.to_vec() })
    }

    /// Decode group `gg` of row `i`: ((pos0, sign0), (pos1, sign1)).
    #[inline]
    pub fn group(&self, i: usize, gg: usize) -> ((usize, f32), (usize, f32)) {
        let g = self.cols / 4;
        let gi = i * g + gg;
        let nibble = (self.meta[gi / 4] >> (4 * (gi % 4))) & 0xf;
        let spair = (self.signs[gi / 4] >> (2 * (gi % 4))) & 0x3;
        let p0 = (nibble & 0x3) as usize;
        let p1 = ((nibble >> 2) & 0x3) as usize;
        let s0 = if spair & 1 != 0 { 1.0 } else { -1.0 };
        let s1 = if spair & 2 != 0 { 1.0 } else { -1.0 };
        ((p0, s0), (p1, s1))
    }

    /// Dense reconstruction (α·sign at kept positions, 0 elsewhere).
    pub fn unpack(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        let g = self.cols / 4;
        for i in 0..self.rows {
            let a = self.alpha[i];
            for gg in 0..g {
                let ((p0, s0), (p1, s1)) = self.group(i, gg);
                out[(i, gg * 4 + p0)] = a * s0;
                out[(i, gg * 4 + p1)] = a * s1;
            }
        }
        out
    }

    /// Packed size in bytes (meta + signs + alphas) — the Fig. 9 number.
    pub fn bytes(&self) -> usize {
        self.meta.len() * 2 + self.signs.len() + self.alpha.len() * 4
    }

    /// Effective bits per weight of the packed representation.
    pub fn bits_per_weight(&self) -> f64 {
        self.bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

/// Force a general reconstruction onto an exact 2:4 pattern: per aligned
/// group of 4, keep the 2 largest-|w| entries as sign(w) and drop the rest.
/// Returns (sb ∈ {-1,0,+1}, per-row α = mean|kept recon values|). This is
/// the "collapse" step that converts an STBLLM layer (multi-scale regions)
/// into the single-α form the hardware kernel consumes (§4.3).
pub fn enforce_24(recon: &Mat) -> (Mat, Vec<f32>) {
    let g = groups_per_row(recon.cols);
    let mut sb = Mat::zeros(recon.rows, recon.cols);
    let mut alpha = Vec::with_capacity(recon.rows);
    for i in 0..recon.rows {
        let row = recon.row(i);
        let (mut l1, mut cnt) = (0.0f32, 0usize);
        for gg in 0..g {
            let base = gg * 4;
            let mut idx: Vec<usize> = (0..4).collect();
            idx.sort_by(|&a, &b| {
                row[base + b].abs().partial_cmp(&row[base + a].abs()).unwrap()
            });
            for &p in idx.iter().take(2) {
                sb[(i, base + p)] = crate::quant::binarize::sgn(row[base + p]);
                l1 += row[base + p].abs();
                cnt += 2; // placeholder; fixed below
            }
        }
        let kept = 2 * g;
        let _ = cnt;
        alpha.push(if kept > 0 { l1 / kept as f32 } else { 0.0 });
    }
    (sb, alpha)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg32;

    /// random valid 2:4 sb matrix
    fn random_sb24(rows: usize, cols: usize, rng: &mut Pcg32) -> Mat {
        let mut sb = Mat::zeros(rows, cols);
        for i in 0..rows {
            for gg in 0..cols / 4 {
                let ks = rng.choose_k(4, 2);
                for &p in &ks {
                    sb[(i, gg * 4 + p)] = if rng.bounded(2) == 0 { 1.0 } else { -1.0 };
                }
            }
        }
        sb
    }

    #[test]
    fn pack_unpack_roundtrip() {
        prop_check("pack/unpack roundtrip", 30, |rng| {
            let rows = 1 + rng.bounded(8) as usize;
            let cols = 4 * (1 + rng.bounded(16) as usize);
            let sb = random_sb24(rows, cols, rng);
            let alpha: Vec<f32> = (0..rows).map(|_| 0.1 + rng.next_f32()).collect();
            let packed = Packed24::pack(&sb, &alpha).map_err(|e| e)?;
            let back = packed.unpack();
            for i in 0..rows {
                for j in 0..cols {
                    let want = sb[(i, j)] * alpha[i];
                    prop_assert!((back[(i, j)] - want).abs() < 1e-6, "({i},{j})");
                }
            }
            Ok(())
        });
    }

    #[test]
    fn rejects_invalid_patterns() {
        let mut sb = Mat::zeros(1, 4);
        sb[(0, 0)] = 1.0; // only one non-zero
        assert!(Packed24::pack(&sb, &[1.0]).is_err());
        sb[(0, 1)] = 1.0;
        sb[(0, 2)] = -1.0; // three non-zeros
        assert!(Packed24::pack(&sb, &[1.0]).is_err());
        let mut bad = Mat::zeros(1, 4);
        bad[(0, 0)] = 0.5; // not ±1
        bad[(0, 1)] = 1.0;
        assert!(Packed24::pack(&bad, &[1.0]).is_err());
    }

    #[test]
    fn six_bits_per_group() {
        let mut rng = Pcg32::seeded(3);
        let sb = random_sb24(64, 256, &mut rng);
        let alpha = vec![1.0f32; 64];
        let p = Packed24::pack(&sb, &alpha).unwrap();
        // 1.5 bits/weight + alpha overhead (32/cols per weight)
        let want = 1.5 + 32.0 / 256.0;
        assert!((p.bits_per_weight() - want).abs() < 0.01, "{}", p.bits_per_weight());
    }

    #[test]
    fn enforce_24_valid_and_keeps_largest() {
        let recon = Mat::from_vec(1, 8, vec![0.9, -0.1, 0.5, 0.2, 0.0, -0.8, 0.3, 0.1]);
        let (sb, alpha) = enforce_24(&recon);
        // group 0 keeps idx 0, 2; group 1 keeps idx 5, 6
        assert_eq!(sb.data[0], 1.0);
        assert_eq!(sb.data[1], 0.0);
        assert_eq!(sb.data[2], 1.0);
        assert_eq!(sb.data[5], -1.0);
        assert_eq!(sb.data[6], 1.0);
        assert!(Packed24::pack(&sb, &alpha).is_ok());
        assert!((alpha[0] - (0.9 + 0.5 + 0.8 + 0.3) / 4.0).abs() < 1e-6);
    }

    #[test]
    fn memory_beats_2bit_by_25pct() {
        // 6 bits per 2:4 group vs 8 bits for naive 2-bit — Appendix C's claim
        let mut rng = Pcg32::seeded(4);
        let sb = random_sb24(128, 512, &mut rng);
        let p = Packed24::pack(&sb, &vec![1.0; 128]).unwrap();
        let ours = (p.meta.len() * 2 + p.signs.len()) as f64; // value bytes only
        let naive_2bit = (128.0 * 512.0) * 2.0 / 8.0;
        assert!((ours / naive_2bit - 0.75).abs() < 0.01, "{}", ours / naive_2bit);
    }
}
