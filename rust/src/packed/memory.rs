//! Analytic weight-memory model (paper Fig. 9): bytes to store a model's
//! quantizable weights under each scheme. Norms/embeddings (FP) are counted
//! identically across schemes, matching the paper's whole-model bars.

use crate::model::config::ModelConfig;

/// Storage scheme for the memory comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    Fp16,
    /// CUTLASS-style int8 W8A16
    Int8,
    /// ABQ-LLM 2-bit (+ per-group fp16 scales, group 128)
    Abq2Bit,
    /// ours: 2:4 packed 1-bit (6 bits / 4 weights) + per-channel scales
    Stb24,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Fp16 => "FP16",
            Scheme::Int8 => "CUTLASS-INT8",
            Scheme::Abq2Bit => "ABQ-LLM-2bit",
            Scheme::Stb24 => "STBLLM-2:4-1bit",
        }
    }

    /// Bytes for one (out × in) weight matrix.
    pub fn matrix_bytes(&self, out: usize, inp: usize) -> u64 {
        let n = (out * inp) as u64;
        match self {
            Scheme::Fp16 => 2 * n,
            Scheme::Int8 => n + (out as u64) * 2, // + per-channel scale
            Scheme::Abq2Bit => {
                let groups = (out * ((inp + 127) / 128)) as u64;
                n / 4 + groups * 2 // 2 bits/weight + fp16 scale per group-128
            }
            Scheme::Stb24 => {
                let groups4 = (out * ((inp + 3) / 4)) as u64;
                // 6 bits per group of 4 (4 index + 2 sign) + fp32 channel scale
                (groups4 * 6 + 7) / 8 + (out as u64) * 4
            }
        }
    }

    /// Whole-model bytes: quantizable matrices under the scheme, the rest
    /// (embeddings, norms, positions) at fp16.
    pub fn model_bytes(&self, cfg: &ModelConfig) -> u64 {
        let mut total = 0u64;
        for _ in 0..cfg.n_layers {
            for nme in cfg.layer_weight_names() {
                let (o, i) = cfg.layer_weight_shape(nme);
                total += self.matrix_bytes(o, i);
            }
            total += 2 * (2 * cfg.dim) as u64; // norms fp16
        }
        total += 2 * (cfg.vocab * cfg.dim + cfg.dim) as u64;
        if cfg.family == crate::model::config::Family::Opt {
            total += 2 * (cfg.seq_len * cfg.dim) as u64;
        }
        total
    }
}

pub const ALL_SCHEMES: [Scheme; 4] = [Scheme::Fp16, Scheme::Int8, Scheme::Abq2Bit, Scheme::Stb24];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_fp16_int8_2bit_ours() {
        let cfg = ModelConfig::preset("llama1-30b").unwrap();
        let b: Vec<u64> = ALL_SCHEMES.iter().map(|s| s.model_bytes(&cfg)).collect();
        assert!(b[0] > b[1] && b[1] > b[2] && b[2] > b[3], "{b:?}");
    }

    #[test]
    fn ours_beats_abq_by_about_15pct_on_values() {
        // Appendix C.3: ~15% whole-matrix reduction vs ABQ (25% on value bits,
        // diluted by scales)
        let ours = Scheme::Stb24.matrix_bytes(4096, 4096) as f64;
        let abq = Scheme::Abq2Bit.matrix_bytes(4096, 4096) as f64;
        let ratio = ours / abq;
        assert!(ratio < 0.85 && ratio > 0.6, "ratio={ratio}");
    }

    #[test]
    fn fp16_matches_two_bytes_per_param() {
        assert_eq!(Scheme::Fp16.matrix_bytes(10, 20), 400);
    }

    #[test]
    fn compression_vs_fp16_exceeds_3x(){
        // paper: >3.1× gain over SmoothQuant-class int8; vs fp16 much larger
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let fp16 = Scheme::Fp16.model_bytes(&cfg) as f64;
        let ours = Scheme::Stb24.model_bytes(&cfg) as f64;
        assert!(fp16 / ours > 3.0, "{}", fp16 / ours);
    }
}
