//! Sparse-binary GEMM kernels — the CPU analogue of the paper's CUDA 2:4
//! sparse-tensor-core kernel (§4.3, Fig. 4a) plus the ABQ-LLM-style dense
//! 2-bit baseline it is compared against.
//!
//! The mechanism that produces the speedup is the same as on Ampere:
//! (a) half the multiply-accumulates are skipped via the 2:4 metadata, and
//! (b) the packed representation moves 6 bits per 4 weights instead of 8
//! (2-bit) or 64 (fp32), which dominates in the memory-bound decode regime.
//!
//! §Perf lineage (regenerate numbers with `stbllm bench-kernels`):
//!   * v1 — [`packed_gemm_onthefly`] / [`packed_gemv_onthefly`]: per-group
//!     decode (shift + mask + sign branch per 4 weights) inside the hot
//!     loop. Kept as the baseline and a second correctness witness.
//!   * v2 — [`packed_gemm_scratch`]: expands each weight row's metadata once
//!     into (index, sign) scratch and amortizes the decode over the batch —
//!     but still gathers scalar-at-a-time and allocates scratch per call.
//!   * v3 — [`packed_gemm`] / [`packed_gemv`] (gemv v2): word-level LUT
//!     decode. One `u16` meta word + one `u8` sign byte cover 4 groups
//!     (16 weights, 8 non-zeros); each 6-bit group code maps through the
//!     64-entry `GROUP_COEF` LUT to its dense ±1/0 coefficient quad, so
//!     the inner loop is 16 contiguous FMAs per word — branch-free and
//!     auto-vectorizable. The micro-kernel is register-blocked 4 output
//!     rows × K/2 (`packed_row_dot4`); `_into` variants write
//!     caller-owned buffers (zero allocations on the decode path); `_par`
//!     variants split output across the `coordinator::scheduler` pool above
//!     the [`PAR_MIN_MACS`] serial cutoff. Every variant funnels through
//!     ONE row kernel, so serial, parallel, GEMM and GEMV outputs are
//!     bit-identical per element — which is what lets the fused
//!     cross-session `decode_batch` path reproduce per-session decode
//!     token-for-token.
//!   * v4 — [`packed_gemm4`] (`packed_gemm` v4): multi-column prefill
//!     kernel. A 4-row × 4-column register tile ([`packed_row_dot4x4`])
//!     reads each `u16` meta word + `u8` sign byte ONCE and FMAs its LUT
//!     coefficient quads (`word_coefs` / `word_dot_c` — the exact
//!     arithmetic `word_dot` is composed from) into all 4 activation
//!     columns, raising arithmetic intensity ×chunk on the metadata
//!     stream: chunked prefill decodes each packed weight word once per
//!     4 prompt tokens instead of once per token. Per-element
//!     accumulation order is unchanged — the tile only changes which
//!     loads are shared — so v4 outputs are bit-identical to v3 (and
//!     remainder rows/columns literally run the v3 row kernel), which is
//!     what lets chunked prefill reproduce token-by-token decode
//!     stream-for-stream.

use super::format::Packed24;
use crate::tensor::Mat;

// ---------------------------------------------------------------------------
// Word-level LUT decode (v3)
// ---------------------------------------------------------------------------

/// 64-entry LUT: one 6-bit group code — 4 index bits (two 2-bit non-zero
/// positions) in the low nibble, 2 sign bits above — expands to the group's
/// dense ±1/0 coefficient quad. Indexing four of these per `u16` meta word
/// + `u8` sign byte decodes 16 weights at a time with no branches.
const GROUP_COEF: [[f32; 4]; 64] = build_group_coef();

const fn build_group_coef() -> [[f32; 4]; 64] {
    let mut lut = [[0.0f32; 4]; 64];
    let mut code = 0usize;
    while code < 64 {
        let nib = code & 0xf;
        let sp = code >> 4;
        let p0 = nib & 3;
        let p1 = (nib >> 2) & 3;
        lut[code][p0] = if sp & 1 != 0 { 1.0 } else { -1.0 };
        lut[code][p1] = if sp & 2 != 0 { 1.0 } else { -1.0 };
        code += 1;
    }
    lut
}

/// Decode one meta word + sign byte into its 4 LUT coefficient quads.
/// This is the load the v4 tile shares across activation columns: one
/// `word_coefs` feeds up to 4 [`word_dot_c`] applications.
#[inline(always)]
fn word_coefs(m: u16, s: u8) -> [&'static [f32; 4]; 4] {
    let m = m as usize;
    let s = s as usize;
    [
        &GROUP_COEF[(m & 0xf) | ((s & 0x3) << 4)],
        &GROUP_COEF[((m >> 4) & 0xf) | (((s >> 2) & 0x3) << 4)],
        &GROUP_COEF[((m >> 8) & 0xf) | (((s >> 4) & 0x3) << 4)],
        &GROUP_COEF[((m >> 12) & 0xf) | (((s >> 6) & 0x3) << 4)],
    ]
}

/// Apply pre-decoded word coefficients to a 16-wide activation block:
/// 16 FMAs + the fixed pairwise reduction `(a0 + a1) + (a2 + a3)`. The
/// ONE word-level arithmetic every LUT kernel (v3 and v4) runs, so
/// sharing the decode cannot change a single output bit.
#[inline(always)]
fn word_dot_c(c: &[&'static [f32; 4]; 4], xb: &[f32]) -> f32 {
    let a0 = c[0][0] * xb[0] + c[0][1] * xb[1] + c[0][2] * xb[2] + c[0][3] * xb[3];
    let a1 = c[1][0] * xb[4] + c[1][1] * xb[5] + c[1][2] * xb[6] + c[1][3] * xb[7];
    let a2 = c[2][0] * xb[8] + c[2][1] * xb[9] + c[2][2] * xb[10] + c[2][3] * xb[11];
    let a3 = c[3][0] * xb[12] + c[3][1] * xb[13] + c[3][2] * xb[14] + c[3][3] * xb[15];
    (a0 + a1) + (a2 + a3)
}

/// Dot of one meta word (4 groups = 16 weights) with a 16-wide activation
/// block. `xb` must have at least 16 elements.
#[inline(always)]
fn word_dot(m: u16, s: u8, xb: &[f32]) -> f32 {
    word_dot_c(&word_coefs(m, s), xb)
}

/// Scalar single-group dot (head/tail of word-unaligned rows). `gi` is the
/// global group index, `gg` the group's column position within the row.
#[inline(always)]
fn group_dot(meta: &[u16], signs: &[u8], gi: usize, gg: usize, xr: &[f32]) -> f32 {
    let nib = ((meta[gi / 4] >> (4 * (gi % 4))) & 0xf) as usize;
    let sp = ((signs[gi / 4] >> (2 * (gi % 4))) & 0x3) as usize;
    let c = &GROUP_COEF[nib | (sp << 4)];
    let xb = &xr[gg * 4..gg * 4 + 4];
    c[0] * xb[0] + c[1] * xb[1] + c[2] * xb[2] + c[3] * xb[3]
}

/// Unscaled dot of one packed row (groups `[gbase, gbase + g)`) with `xr`,
/// word-level where the global group index is aligned, scalar at the edges.
#[inline(always)]
fn packed_row_dot(meta: &[u16], signs: &[u8], gbase: usize, g: usize, xr: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    let mut gg = 0usize;
    while gg < g && (gbase + gg) % 4 != 0 {
        acc += group_dot(meta, signs, gbase + gg, gg, xr);
        gg += 1;
    }
    while gg + 4 <= g {
        let wi = (gbase + gg) / 4;
        acc += word_dot(meta[wi], signs[wi], &xr[gg * 4..gg * 4 + 16]);
        gg += 4;
    }
    while gg < g {
        acc += group_dot(meta, signs, gbase + gg, gg, xr);
        gg += 1;
    }
    acc
}

/// Register-blocked micro-kernel: 4 consecutive word-aligned rows against
/// one activation row — each 16-wide `x` block is loaded once and consumed
/// by all 4 accumulators. `w0` is the first row's word offset, `wpr` the
/// words per row (rows are contiguous: row r starts at `w0 + r * wpr`).
/// Per-row accumulation order is identical to [`packed_row_dot`].
#[inline(always)]
fn packed_row_dot4(meta: &[u16], signs: &[u8], w0: usize, wpr: usize, xr: &[f32]) -> [f32; 4] {
    let m0 = &meta[w0..w0 + wpr];
    let m1 = &meta[w0 + wpr..w0 + 2 * wpr];
    let m2 = &meta[w0 + 2 * wpr..w0 + 3 * wpr];
    let m3 = &meta[w0 + 3 * wpr..w0 + 4 * wpr];
    let s0 = &signs[w0..w0 + wpr];
    let s1 = &signs[w0 + wpr..w0 + 2 * wpr];
    let s2 = &signs[w0 + 2 * wpr..w0 + 3 * wpr];
    let s3 = &signs[w0 + 3 * wpr..w0 + 4 * wpr];
    let mut acc = [0.0f32; 4];
    for wi in 0..wpr {
        let xb = &xr[wi * 16..wi * 16 + 16];
        acc[0] += word_dot(m0[wi], s0[wi], xb);
        acc[1] += word_dot(m1[wi], s1[wi], xb);
        acc[2] += word_dot(m2[wi], s2[wi], xb);
        acc[3] += word_dot(m3[wi], s3[wi], xb);
    }
    acc
}

/// The v4 register tile: 4 consecutive word-aligned weight rows × 4
/// activation columns. Each meta word + sign byte is decoded ONCE per
/// `wi` ([`word_coefs`]) and its coefficient quads are FMAed into all 4
/// columns' accumulators — the decode-amortization chunked prefill is
/// built on. `acc[b][r]` accumulates `word_dot_c` over ascending `wi`,
/// exactly the order [`packed_row_dot4`] uses per column, so the tile is
/// bit-identical to running the v3 kernel on each column independently.
#[inline(always)]
fn packed_row_dot4x4(
    meta: &[u16],
    signs: &[u8],
    w0: usize,
    wpr: usize,
    xs: &[&[f32]; 4],
) -> [[f32; 4]; 4] {
    let m0 = &meta[w0..w0 + wpr];
    let m1 = &meta[w0 + wpr..w0 + 2 * wpr];
    let m2 = &meta[w0 + 2 * wpr..w0 + 3 * wpr];
    let m3 = &meta[w0 + 3 * wpr..w0 + 4 * wpr];
    let s0 = &signs[w0..w0 + wpr];
    let s1 = &signs[w0 + wpr..w0 + 2 * wpr];
    let s2 = &signs[w0 + 2 * wpr..w0 + 3 * wpr];
    let s3 = &signs[w0 + 3 * wpr..w0 + 4 * wpr];
    let mut acc = [[0.0f32; 4]; 4];
    for wi in 0..wpr {
        let c0 = word_coefs(m0[wi], s0[wi]);
        let c1 = word_coefs(m1[wi], s1[wi]);
        let c2 = word_coefs(m2[wi], s2[wi]);
        let c3 = word_coefs(m3[wi], s3[wi]);
        for (b, xcol) in xs.iter().enumerate() {
            let xb = &xcol[wi * 16..wi * 16 + 16];
            acc[b][0] += word_dot_c(&c0, xb);
            acc[b][1] += word_dot_c(&c1, xb);
            acc[b][2] += word_dot_c(&c2, xb);
            acc[b][3] += word_dot_c(&c3, xb);
        }
    }
    acc
}

/// The ONE row kernel every packed GEMM/GEMV entry point funnels through:
/// `yr[n] = α_n · (packed row n · xr)` for all rows. Single accumulation
/// order ⇒ all variants (serial/parallel, gemm/gemv) bit-match.
fn packed_rows_kernel(w: &Packed24, xr: &[f32], yr: &mut [f32]) {
    row_range_kernel(w, xr, 0, yr);
}

/// Below this many effective multiply-accumulates a parallel launch costs
/// more than it saves (scoped spawn + join ≈ tens of µs on the CI box, the
/// serial kernel moves ≈ 1 MAC/ns), so `_par` entry points fall back to the
/// serial kernel — small projections never pay spawn overhead.
pub const PAR_MIN_MACS: usize = 1 << 19;

// ---------------------------------------------------------------------------
// GEMV (serving decode hot path) — v2: word-level LUT, zero-alloc `_into`
// ---------------------------------------------------------------------------

/// y = W_packed @ x into caller-owned storage — the zero-allocation decode
/// hot path (`DecodeScratch` owns the output buffers).
pub fn packed_gemv_into(w: &Packed24, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), w.cols, "K mismatch");
    assert_eq!(y.len(), w.rows, "N mismatch");
    packed_rows_kernel(w, x, y);
}

/// y = W_packed @ x for a single activation vector (allocating wrapper over
/// [`packed_gemv_into`]).
pub fn packed_gemv(w: &Packed24, x: &[f32]) -> Vec<f32> {
    let mut y = vec![0.0f32; w.rows];
    packed_gemv_into(w, x, &mut y);
    y
}

/// Parallel gemv: output rows split in contiguous blocks across the
/// scheduler pool; serial below the [`PAR_MIN_MACS`] cutoff. Bit-identical
/// to [`packed_gemv_into`] (each output element is produced by the same
/// sequential row dot regardless of the partition).
pub fn packed_gemv_par_into(w: &Packed24, x: &[f32], y: &mut [f32], workers: usize) {
    assert_eq!(x.len(), w.cols, "K mismatch");
    assert_eq!(y.len(), w.rows, "N mismatch");
    if workers <= 1 || w.rows * (w.cols / 2) < PAR_MIN_MACS {
        return packed_rows_kernel(w, x, y);
    }
    let parts = workers.min(w.rows);
    let chunk = w.rows.div_ceil(parts);
    let mut jobs: Vec<(usize, &mut [f32])> = Vec::with_capacity(parts);
    let mut n0 = 0usize;
    for seg in y.chunks_mut(chunk) {
        let len = seg.len();
        jobs.push((n0, seg));
        n0 += len;
    }
    crate::coordinator::scheduler::run(jobs, parts, |(n0, yseg)| {
        row_range_kernel(w, x, n0, yseg);
    });
}

/// Allocating wrapper over [`packed_gemv_par_into`].
pub fn packed_gemv_par(w: &Packed24, x: &[f32], workers: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; w.rows];
    packed_gemv_par_into(w, x, &mut y, workers);
    y
}

/// Rows `[n0, n0 + yseg.len())` of the row kernel — same per-element order
/// as [`packed_rows_kernel`], partitioned for the parallel entry points.
fn row_range_kernel(w: &Packed24, xr: &[f32], n0: usize, yseg: &mut [f32]) {
    let g = w.cols / 4;
    let n1 = n0 + yseg.len();
    if g % 4 == 0 && g > 0 {
        let wpr = g / 4;
        let mut n = n0;
        while n + 4 <= n1 {
            let acc = packed_row_dot4(&w.meta, &w.signs, n * wpr, wpr, xr);
            yseg[n - n0] = acc[0] * w.alpha[n];
            yseg[n + 1 - n0] = acc[1] * w.alpha[n + 1];
            yseg[n + 2 - n0] = acc[2] * w.alpha[n + 2];
            yseg[n + 3 - n0] = acc[3] * w.alpha[n + 3];
            n += 4;
        }
        while n < n1 {
            yseg[n - n0] = packed_row_dot(&w.meta, &w.signs, n * g, g, xr) * w.alpha[n];
            n += 1;
        }
    } else {
        for n in n0..n1 {
            yseg[n - n0] = packed_row_dot(&w.meta, &w.signs, n * g, g, xr) * w.alpha[n];
        }
    }
}

// ---------------------------------------------------------------------------
// GEMM — v3: word-level LUT + 4-row register blocking, `_into` / `_par`
// ---------------------------------------------------------------------------

/// y = x @ W_packed^T into a caller-owned output matrix (zero allocations).
pub fn packed_gemm_into(x: &Mat, w: &Packed24, y: &mut Mat) {
    assert_eq!(x.cols, w.cols, "K mismatch");
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "output shape mismatch");
    for b in 0..x.rows {
        packed_rows_kernel(w, x.row(b), y.row_mut(b));
    }
}

/// y = x @ W_packed^T — the v3 word-level LUT kernel (allocating wrapper
/// over [`packed_gemm_into`]).
pub fn packed_gemm(x: &Mat, w: &Packed24) -> Mat {
    let mut y = Mat::zeros(x.rows, w.rows);
    packed_gemm_into(x, w, &mut y);
    y
}

/// Parallel GEMM: batch rows split in contiguous blocks across the
/// scheduler pool (a single activation row degrades to the row-partitioned
/// [`packed_gemv_par_into`]); serial below the [`PAR_MIN_MACS`] cutoff.
/// Bit-identical to the serial kernel.
pub fn packed_gemm_par_into(x: &Mat, w: &Packed24, y: &mut Mat, workers: usize) {
    assert_eq!(x.cols, w.cols, "K mismatch");
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "output shape mismatch");
    let macs = x.rows * w.rows * (w.cols / 2);
    if workers <= 1 || macs < PAR_MIN_MACS {
        return packed_gemm_into(x, w, y);
    }
    if x.rows == 1 {
        return packed_gemv_par_into(w, x.row(0), y.row_mut(0), workers);
    }
    let parts = workers.min(x.rows);
    let chunk = x.rows.div_ceil(parts);
    let n = w.rows;
    let mut jobs: Vec<(usize, &mut [f32])> = Vec::with_capacity(parts);
    let mut b0 = 0usize;
    for seg in y.data.chunks_mut(chunk * n) {
        let nb = seg.len() / n;
        jobs.push((b0, seg));
        b0 += nb;
    }
    crate::coordinator::scheduler::run(jobs, parts, |(b0, yseg)| {
        let nb = yseg.len() / n;
        for bi in 0..nb {
            packed_rows_kernel(w, x.row(b0 + bi), &mut yseg[bi * n..(bi + 1) * n]);
        }
    });
}

/// Allocating wrapper over [`packed_gemm_par_into`].
pub fn packed_gemm_par(x: &Mat, w: &Packed24, workers: usize) -> Mat {
    let mut y = Mat::zeros(x.rows, w.rows);
    packed_gemm_par_into(x, w, &mut y, workers);
    y
}

// ---------------------------------------------------------------------------
// GEMM — v4: 4-row × 4-column tile, each meta word decoded once per tile
// ---------------------------------------------------------------------------

/// Batch rows `[b0, b0 + yseg.len() / w.rows)` of the v4 tile kernel.
/// Word-aligned weight rows run the [`packed_row_dot4x4`] tile; remainder
/// output rows, remainder batch columns and word-unaligned shapes fall
/// back to the v3 row kernel — every path produces the same per-element
/// accumulation, so v4 is bit-identical to v3 at any partition.
fn gemm4_batch_range(x: &Mat, w: &Packed24, b0: usize, yseg: &mut [f32]) {
    let n_out = w.rows;
    let nb = yseg.len() / n_out;
    let g = w.cols / 4;
    let aligned = g % 4 == 0 && g > 0;
    let mut bi = 0usize;
    if aligned {
        let wpr = g / 4;
        while bi + 4 <= nb {
            let xs = [
                x.row(b0 + bi),
                x.row(b0 + bi + 1),
                x.row(b0 + bi + 2),
                x.row(b0 + bi + 3),
            ];
            let mut n = 0usize;
            while n + 4 <= n_out {
                let acc = packed_row_dot4x4(&w.meta, &w.signs, n * wpr, wpr, &xs);
                for (c, col) in acc.iter().enumerate() {
                    let yr = &mut yseg[(bi + c) * n_out..(bi + c + 1) * n_out];
                    yr[n] = col[0] * w.alpha[n];
                    yr[n + 1] = col[1] * w.alpha[n + 1];
                    yr[n + 2] = col[2] * w.alpha[n + 2];
                    yr[n + 3] = col[3] * w.alpha[n + 3];
                }
                n += 4;
            }
            while n < n_out {
                for (c, xr) in xs.iter().enumerate() {
                    yseg[(bi + c) * n_out + n] =
                        packed_row_dot(&w.meta, &w.signs, n * g, g, xr) * w.alpha[n];
                }
                n += 1;
            }
            bi += 4;
        }
    }
    while bi < nb {
        packed_rows_kernel(w, x.row(b0 + bi), &mut yseg[bi * n_out..(bi + 1) * n_out]);
        bi += 1;
    }
}

/// y = x @ W_packed^T through the v4 4×4 tile into a caller-owned output
/// matrix (zero allocations). Bit-identical to [`packed_gemm_into`].
pub fn packed_gemm4_into(x: &Mat, w: &Packed24, y: &mut Mat) {
    assert_eq!(x.cols, w.cols, "K mismatch");
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "output shape mismatch");
    gemm4_batch_range(x, w, 0, &mut y.data);
}

/// y = x @ W_packed^T — the v4 multi-column tile kernel (allocating
/// wrapper over [`packed_gemm4_into`]).
pub fn packed_gemm4(x: &Mat, w: &Packed24) -> Mat {
    let mut y = Mat::zeros(x.rows, w.rows);
    packed_gemm4_into(x, w, &mut y);
    y
}

/// Parallel v4 GEMM: batch rows split across the scheduler pool in
/// multiples of 4 so every worker keeps full 4-column tiles (the tail
/// worker takes the remainder); a single activation row degrades to
/// [`packed_gemv_par_into`]; serial below the [`PAR_MIN_MACS`] cutoff.
/// Bit-identical to serial v4 (and so to v3) at any worker count —
/// partitioning only changes which columns share a tile's decode, never
/// any element's accumulation order.
pub fn packed_gemm4_par_into(x: &Mat, w: &Packed24, y: &mut Mat, workers: usize) {
    assert_eq!(x.cols, w.cols, "K mismatch");
    assert_eq!((y.rows, y.cols), (x.rows, w.rows), "output shape mismatch");
    let macs = x.rows * w.rows * (w.cols / 2);
    if workers <= 1 || macs < PAR_MIN_MACS {
        return packed_gemm4_into(x, w, y);
    }
    if x.rows == 1 {
        return packed_gemv_par_into(w, x.row(0), y.row_mut(0), workers);
    }
    let parts = workers.min(x.rows.div_ceil(4));
    let chunk = x.rows.div_ceil(parts).div_ceil(4) * 4;
    let n = w.rows;
    let mut jobs: Vec<(usize, &mut [f32])> = Vec::with_capacity(parts);
    let mut b0 = 0usize;
    for seg in y.data.chunks_mut(chunk * n) {
        let nb = seg.len() / n;
        jobs.push((b0, seg));
        b0 += nb;
    }
    crate::coordinator::scheduler::run(jobs, parts, |(b0, yseg)| {
        gemm4_batch_range(x, w, b0, yseg);
    });
}

/// Allocating wrapper over [`packed_gemm4_par_into`].
pub fn packed_gemm4_par(x: &Mat, w: &Packed24, workers: usize) -> Mat {
    let mut y = Mat::zeros(x.rows, w.rows);
    packed_gemm4_par_into(x, w, &mut y, workers);
    y
}

// ---------------------------------------------------------------------------
// Historical kernels (§Perf baselines + correctness witnesses)
// ---------------------------------------------------------------------------

/// v2 GEMM: the 6-bit metadata of each weight row is expanded ONCE into
/// (index, sign) scratch, then every batch row runs a K/2-long gather-MAC.
/// Kept as the §Perf v2 baseline (`stbllm bench-kernels` reports v3 vs v2).
pub fn packed_gemm_scratch(x: &Mat, w: &Packed24) -> Mat {
    assert_eq!(x.cols, w.cols, "K mismatch");
    let g = w.cols / 4;
    let nnz = 2 * g;
    let mut y = Mat::zeros(x.rows, w.rows);
    let mut idxbuf = vec![0u32; nnz];
    let mut sgnbuf = vec![0f32; nnz];
    for n in 0..w.rows {
        for gg in 0..g {
            let ((p0, s0), (p1, s1)) = w.group(n, gg);
            idxbuf[2 * gg] = (gg * 4 + p0) as u32;
            sgnbuf[2 * gg] = s0;
            idxbuf[2 * gg + 1] = (gg * 4 + p1) as u32;
            sgnbuf[2 * gg + 1] = s1;
        }
        let alpha = w.alpha[n];
        for b in 0..x.rows {
            let xr = x.row(b);
            // 4 accumulators over the gathered sparse pattern
            let chunks = nnz / 4;
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for c in 0..chunks {
                let t = c * 4;
                a0 += sgnbuf[t] * xr[idxbuf[t] as usize];
                a1 += sgnbuf[t + 1] * xr[idxbuf[t + 1] as usize];
                a2 += sgnbuf[t + 2] * xr[idxbuf[t + 2] as usize];
                a3 += sgnbuf[t + 3] * xr[idxbuf[t + 3] as usize];
            }
            let mut acc = a0 + a1 + a2 + a3;
            for t in chunks * 4..nnz {
                acc += sgnbuf[t] * xr[idxbuf[t] as usize];
            }
            y[(b, n)] = acc * alpha;
        }
    }
    y
}

/// v1 GEMV: decodes every group on the fly with per-group branches — the
/// baseline the v2 LUT gemv is measured against in `BENCH_kernels.json`.
pub fn packed_gemv_onthefly(w: &Packed24, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), w.cols, "K mismatch");
    let g = w.cols / 4;
    let mut y = vec![0.0f32; w.rows];
    for (n, out) in y.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for gg in 0..g {
            let ((p0, s0), (p1, s1)) = w.group(n, gg);
            acc += s0 * x[gg * 4 + p0] + s1 * x[gg * 4 + p1];
        }
        *out = acc * w.alpha[n];
    }
    y
}

/// v1 GEMM: decodes the metadata inside the (batch × row) loop — kept
/// BYTE-FOR-BYTE as it shipped (including its word-aligned branchless fast
/// path) so the v1-relative speedups in `BENCH_kernels.json` measure the
/// real before/after of this lineage, not a strawman.
pub fn packed_gemm_onthefly(x: &Mat, w: &Packed24) -> Mat {
    assert_eq!(x.cols, w.cols, "K mismatch");
    let g = w.cols / 4;
    let mut y = Mat::zeros(x.rows, w.rows);
    for b in 0..x.rows {
        let xr = x.row(b);
        let yr = y.row_mut(b);
        for n in 0..w.rows {
            let gbase = n * g;
            let mut acc = 0.0f32;
            // process 4 groups (one u16 meta word + one u8 sign byte) at a time
            let mut gg = 0;
            while gg + 4 <= g {
                let widx = (gbase + gg) / 4;
                // fast path only valid when the global group index is aligned
                if (gbase + gg) % 4 == 0 {
                    // branchless sign application: ±1 looked up from bits
                    const SGN: [f32; 2] = [-1.0, 1.0];
                    let meta = w.meta[widx];
                    let sgn = w.signs[widx];
                    let mut acc4 = 0.0f32;
                    for q in 0..4 {
                        let nib = (meta >> (4 * q)) & 0xf;
                        let sp = (sgn >> (2 * q)) & 0x3;
                        let base = (gg + q) * 4;
                        let x0 = xr[base + (nib & 3) as usize];
                        let x1 = xr[base + ((nib >> 2) & 3) as usize];
                        acc4 += SGN[(sp & 1) as usize] * x0 + SGN[(sp >> 1) as usize] * x1;
                    }
                    acc += acc4;
                    gg += 4;
                } else {
                    let ((p0, s0), (p1, s1)) = w.group(n, gg);
                    acc += s0 * xr[gg * 4 + p0] + s1 * xr[gg * 4 + p1];
                    gg += 1;
                }
            }
            while gg < g {
                let ((p0, s0), (p1, s1)) = w.group(n, gg);
                acc += s0 * xr[gg * 4 + p0] + s1 * xr[gg * 4 + p1];
                gg += 1;
            }
            yr[n] = acc * w.alpha[n];
        }
    }
    y
}

// ---------------------------------------------------------------------------
// Dense 2-bit baseline (ABQ-LLM stand-in)
// ---------------------------------------------------------------------------

/// Dense 2-bit weight matrix: 4 weights per byte, levels {-1, 0, +1} scaled
/// per row — the representation ABQ-LLM's W2A16 kernels stream.
#[derive(Clone, Debug)]
pub struct Dense2Bit {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>, // 2 bits per weight: 00=-1, 01=0, 10=+1
    pub alpha: Vec<f32>,
}

impl Dense2Bit {
    /// Quantize a dense matrix to 2-bit {-α, 0, +α} per row (absmax/2 dead-zone).
    pub fn quantize(w: &Mat) -> Dense2Bit {
        let mut data = vec![0u8; (w.rows * w.cols + 3) / 4];
        let mut alpha = Vec::with_capacity(w.rows);
        for i in 0..w.rows {
            let row = w.row(i);
            let a = row.iter().map(|x| x.abs()).sum::<f32>() / row.len() as f32;
            alpha.push(a);
            let thr = a * 0.5;
            for (j, &x) in row.iter().enumerate() {
                let code: u8 = if x > thr {
                    2
                } else if x < -thr {
                    0
                } else {
                    1
                };
                let idx = i * w.cols + j;
                data[idx / 4] |= code << (2 * (idx % 4));
            }
        }
        Dense2Bit { rows: w.rows, cols: w.cols, data, alpha }
    }

    #[inline]
    fn code(&self, i: usize, j: usize) -> i32 {
        let idx = i * self.cols + j;
        (((self.data[idx / 4] >> (2 * (idx % 4))) & 0x3) as i32) - 1
    }

    pub fn unpack(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(i, j)] = self.code(i, j) as f32 * self.alpha[i];
            }
        }
        out
    }

    pub fn bytes(&self) -> usize {
        self.data.len() + self.alpha.len() * 4
    }
}

/// 256-entry LUT: one code byte (4 weights) → its dense {-1, 0, +1}
/// coefficient quad. Keeps the 2-bit baseline honest: byte-at-a-time decode
/// with 4 contiguous FMAs per byte, the same decode style as the packed v3
/// kernel (code 0b11 is unused by `Dense2Bit::quantize`).
const CODE_COEF: [[f32; 4]; 256] = build_code_coef();

const fn build_code_coef() -> [[f32; 4]; 256] {
    let mut lut = [[0.0f32; 4]; 256];
    let mut b = 0usize;
    while b < 256 {
        let mut q = 0usize;
        while q < 4 {
            lut[b][q] = match (b >> (2 * q)) & 0x3 {
                0 => -1.0,
                1 => 0.0,
                _ => 1.0,
            };
            q += 1;
        }
        b += 1;
    }
    lut
}

/// y = x @ W_2bit^T: dense inner loop over all K, byte-at-a-time (4 codes
/// per byte through `CODE_COEF`, hoisted row base) — no sparsity skip.
pub fn gemm_2bit(x: &Mat, w: &Dense2Bit) -> Mat {
    assert_eq!(x.cols, w.cols);
    let mut y = Mat::zeros(x.rows, w.rows);
    for b in 0..x.rows {
        let xr = x.row(b);
        let yr = y.row_mut(b);
        for n in 0..w.rows {
            let base = n * w.cols;
            let mut acc = 0.0f32;
            let mut j = 0usize;
            // scalar head until the bit-stream is byte-aligned
            while j < w.cols && (base + j) % 4 != 0 {
                acc += w.code(n, j) as f32 * xr[j];
                j += 1;
            }
            while j + 4 <= w.cols {
                let c = &CODE_COEF[w.data[(base + j) / 4] as usize];
                let xb = &xr[j..j + 4];
                acc += c[0] * xb[0] + c[1] * xb[1] + c[2] * xb[2] + c[3] * xb[3];
                j += 4;
            }
            while j < w.cols {
                acc += w.code(n, j) as f32 * xr[j];
                j += 1;
            }
            yr[n] = acc * w.alpha[n];
        }
    }
    y
}

/// FP32 reference GEMM (`x @ w^T`) for correctness + the FP16-class roofline
/// baseline in Fig. 4a (fp16 and fp32 move 2×/4× the bytes of 2-bit).
pub fn gemm_f32(x: &Mat, w: &Mat) -> Mat {
    crate::tensor::matmul_bt(x, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packed::format::enforce_24;
    use crate::prop_assert;
    use crate::util::prop::prop_check;
    use crate::util::rng::Pcg32;

    fn random_sb24(rows: usize, cols: usize, rng: &mut Pcg32) -> (Packed24, Mat) {
        let dense = Mat::random(rows, cols, 1.0, rng);
        let (sb, alpha) = enforce_24(&dense);
        let packed = Packed24::pack(&sb, &alpha).unwrap();
        (packed, dense)
    }

    #[test]
    fn packed_gemm_variants_agree() {
        let mut rng = Pcg32::seeded(5);
        let (packed, _) = random_sb24(24, 64, &mut rng);
        let x = Mat::random(7, 64, 1.0, &mut rng);
        let v3 = packed_gemm(&x, &packed);
        let v2 = packed_gemm_scratch(&x, &packed);
        let v1 = packed_gemm_onthefly(&x, &packed);
        for ((a, b), c) in v3.data.iter().zip(&v2.data).zip(&v1.data) {
            assert!((a - b).abs() < 1e-4);
            assert!((a - c).abs() < 1e-4);
        }
    }

    #[test]
    fn packed_gemm_matches_dense_reference() {
        let mut rng = Pcg32::seeded(1);
        for (rows, cols, batch) in [(8usize, 16usize, 3usize), (24, 64, 7), (32, 128, 5)] {
            let (packed, _) = random_sb24(rows, cols, &mut rng);
            let x = Mat::random(batch, cols, 1.0, &mut rng);
            let got = packed_gemm(&x, &packed);
            let want = gemm_f32(&x, &packed.unpack());
            for (a, b) in got.data.iter().zip(&want.data) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b} ({rows}x{cols})");
            }
        }
    }

    /// Property test over word-UNALIGNED shapes (cols % 16 != 0 so rows are
    /// not meta-word aligned, rows % 4 != 0 so the 4-row micro-kernel has a
    /// remainder): the LUT kernels must agree with the v1 on-the-fly witness
    /// and the dense reference.
    #[test]
    fn lut_kernels_match_v1_and_dense_on_unaligned_shapes() {
        prop_check("LUT kernel parity on unaligned shapes", 25, |rng| {
            let rows = 1 + rng.bounded(13) as usize;
            let cols = 4 * (1 + rng.bounded(31) as usize); // frequently % 16 != 0
            let (packed, _) = random_sb24(rows, cols, rng);
            let batch = 1 + rng.bounded(5) as usize;
            let x = Mat::random(batch, cols, 1.0, rng);
            let v3 = packed_gemm(&x, &packed);
            let v1 = packed_gemm_onthefly(&x, &packed);
            let dense = gemm_f32(&x, &packed.unpack());
            for ((a, b), c) in v3.data.iter().zip(&v1.data).zip(&dense.data) {
                prop_assert!((a - b).abs() < 1e-4, "v3 vs v1: {a} vs {b} ({rows}x{cols})");
                prop_assert!((a - c).abs() < 1e-3, "v3 vs dense: {a} vs {c} ({rows}x{cols})");
            }
            let gv = packed_gemv(&packed, x.row(0));
            for (a, b) in gv.iter().zip(v3.row(0)) {
                prop_assert!(a == b, "gemv must bit-match gemm row 0: {a} vs {b}");
            }
            Ok(())
        });
    }

    #[test]
    fn packed_gemv_matches_gemm_single_row() {
        let mut rng = Pcg32::seeded(8);
        let (packed, _) = random_sb24(24, 64, &mut rng);
        let x = Mat::random(1, 64, 1.0, &mut rng);
        let want = packed_gemm(&x, &packed);
        let got = packed_gemv(&packed, x.row(0));
        assert_eq!(got.len(), 24);
        for (a, b) in got.iter().zip(want.row(0)) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn gemv_v2_matches_v1_witness() {
        let mut rng = Pcg32::seeded(9);
        for (rows, cols) in [(24usize, 64usize), (10, 84), (13, 20), (5, 176)] {
            let (packed, _) = random_sb24(rows, cols, &mut rng);
            let x: Vec<f32> = (0..cols).map(|i| ((i * 37 % 97) as f32 - 48.0) * 0.05).collect();
            let v2 = packed_gemv(&packed, &x);
            let v1 = packed_gemv_onthefly(&packed, &x);
            for (a, b) in v2.iter().zip(&v1) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b} ({rows}x{cols})");
            }
        }
    }

    /// Parallel GEMM/GEMV must bit-match serial: the row kernel is the same
    /// per output element regardless of the partition. Shapes are sized past
    /// PAR_MIN_MACS so the parallel path actually engages.
    #[test]
    fn parallel_kernels_bitmatch_serial() {
        let mut rng = Pcg32::seeded(10);
        let (packed, _) = random_sb24(256, 512, &mut rng);
        let x = Mat::random(8, 512, 1.0, &mut rng);
        assert!(x.rows * packed.rows * (packed.cols / 2) >= PAR_MIN_MACS);
        let serial = packed_gemm(&x, &packed);
        let par = packed_gemm_par(&x, &packed, 4);
        assert_eq!(serial.data, par.data, "parallel GEMM must bit-match serial");

        let (packed, _) = random_sb24(1024, 1024, &mut rng);
        let xv: Vec<f32> = (0..1024).map(|i| (i as f32 * 0.37).sin()).collect();
        assert!(packed.rows * (packed.cols / 2) >= PAR_MIN_MACS);
        let serial = packed_gemv(&packed, &xv);
        let par = packed_gemv_par(&packed, &xv, 4);
        assert_eq!(serial, par, "parallel GEMV must bit-match serial");
    }

    /// v4 (4×4 tile) must BIT-match v3 on every shape class: word-aligned
    /// and unaligned columns, 4-row remainders, and batch sizes spanning
    /// full tiles, remainders and single columns.
    #[test]
    fn gemm4_bitmatches_v3_across_shapes_and_batches() {
        prop_check("v4 tile bit-matches v3", 25, |rng| {
            let rows = 1 + rng.bounded(13) as usize;
            let cols = 4 * (1 + rng.bounded(31) as usize); // frequently % 16 != 0
            let (packed, _) = random_sb24(rows, cols, rng);
            for batch in [1usize, 3, 5, 8, 32] {
                let x = Mat::random(batch, cols, 1.0, rng);
                let v3 = packed_gemm(&x, &packed);
                let v4 = packed_gemm4(&x, &packed);
                prop_assert!(
                    v3.data == v4.data,
                    "v4 diverged from v3 on {rows}x{cols} batch {batch}"
                );
            }
            Ok(())
        });
    }

    /// Parallel v4 must bit-match serial v4 past the PAR_MIN_MACS cutoff,
    /// including a batch size that is not a multiple of the 4-column tile.
    #[test]
    fn gemm4_parallel_bitmatches_serial() {
        let mut rng = Pcg32::seeded(12);
        let (packed, _) = random_sb24(256, 512, &mut rng);
        for batch in [8usize, 10] {
            let x = Mat::random(batch, 512, 1.0, &mut rng);
            assert!(x.rows * packed.rows * (packed.cols / 2) >= PAR_MIN_MACS);
            let serial = packed_gemm4(&x, &packed);
            let par = packed_gemm4_par(&x, &packed, 4);
            assert_eq!(serial.data, par.data, "parallel v4 must bit-match serial (batch {batch})");
            let v3 = packed_gemm(&x, &packed);
            assert_eq!(serial.data, v3.data, "v4 must bit-match v3 (batch {batch})");
        }
    }

    #[test]
    fn gemm4_into_writes_in_place() {
        let mut rng = Pcg32::seeded(13);
        let (packed, _) = random_sb24(24, 64, &mut rng);
        let x = Mat::random(6, 64, 1.0, &mut rng);
        let want = packed_gemm(&x, &packed);
        let mut y = Mat::zeros(6, 24);
        packed_gemm4_into(&x, &packed, &mut y);
        assert_eq!(want.data, y.data);
    }

    #[test]
    fn into_variants_write_in_place() {
        let mut rng = Pcg32::seeded(11);
        let (packed, _) = random_sb24(24, 64, &mut rng);
        let x = Mat::random(3, 64, 1.0, &mut rng);
        let want = packed_gemm(&x, &packed);
        let mut y = Mat::zeros(3, 24);
        packed_gemm_into(&x, &packed, &mut y);
        assert_eq!(want.data, y.data);
        let mut yv = vec![0.0f32; 24];
        packed_gemv_into(&packed, x.row(1), &mut yv);
        assert_eq!(yv, want.row(1));
    }

    #[test]
    fn gemm_2bit_matches_its_unpack() {
        let mut rng = Pcg32::seeded(2);
        // includes cols % 4 != 0 (unaligned row starts in the bit stream)
        for (rows, cols, batch) in [(24usize, 64usize, 5usize), (5, 13, 2), (7, 31, 3)] {
            let w = Mat::random(rows, cols, 1.0, &mut rng);
            let q = Dense2Bit::quantize(&w);
            let x = Mat::random(batch, cols, 1.0, &mut rng);
            let got = gemm_2bit(&x, &q);
            let want = gemm_f32(&x, &q.unpack());
            for (a, b) in got.data.iter().zip(&want.data) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b} ({rows}x{cols})");
            }
        }
    }

    #[test]
    fn packed_is_smaller_than_2bit() {
        let mut rng = Pcg32::seeded(3);
        let (packed, dense) = random_sb24(64, 256, &mut rng);
        let two = Dense2Bit::quantize(&dense);
        assert!(packed.bytes() < two.bytes(), "{} vs {}", packed.bytes(), two.bytes());
    }

    #[test]
    fn two_bit_codes_in_range() {
        let mut rng = Pcg32::seeded(4);
        let w = Mat::random(4, 16, 1.0, &mut rng);
        let q = Dense2Bit::quantize(&w);
        let u = q.unpack();
        for i in 0..4 {
            for j in 0..16 {
                let v = u[(i, j)] / q.alpha[i].max(1e-12);
                assert!(v == 0.0 || (v.abs() - 1.0).abs() < 1e-5);
            }
        }
    }
}
