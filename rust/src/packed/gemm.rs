//! Sparse-binary GEMM kernels — the CPU analogue of the paper's CUDA 2:4
//! sparse-tensor-core kernel (§4.3, Fig. 4a) plus the ABQ-LLM-style dense
//! 2-bit baseline it is compared against.
//!
//! The mechanism that produces the speedup is the same as on Ampere:
//! (a) half the multiply-accumulates are skipped via the 2:4 metadata, and
//! (b) the packed representation moves 6 bits per 4 weights instead of 8
//! (2-bit) or 64 (fp32), which dominates in the memory-bound decode regime.

use super::format::Packed24;
use crate::tensor::Mat;

/// y = x @ W_packed^T with per-weight-row decode amortization: the 6-bit
/// metadata of row n is expanded ONCE into (index, sign) scratch, then every
/// batch row runs a K/2-long gather-MAC — half the multiply-accumulates of
/// the dense kernels, mirroring the sparse-tensor-core schedule. (§Perf L3:
/// this is v2; `packed_gemm_onthefly` below is the v1 baseline.)
pub fn packed_gemm(x: &Mat, w: &Packed24) -> Mat {
    assert_eq!(x.cols, w.cols, "K mismatch");
    let g = w.cols / 4;
    let nnz = 2 * g;
    let mut y = Mat::zeros(x.rows, w.rows);
    let mut idxbuf = vec![0u32; nnz];
    let mut sgnbuf = vec![0f32; nnz];
    for n in 0..w.rows {
        for gg in 0..g {
            let ((p0, s0), (p1, s1)) = w.group(n, gg);
            idxbuf[2 * gg] = (gg * 4 + p0) as u32;
            sgnbuf[2 * gg] = s0;
            idxbuf[2 * gg + 1] = (gg * 4 + p1) as u32;
            sgnbuf[2 * gg + 1] = s1;
        }
        let alpha = w.alpha[n];
        for b in 0..x.rows {
            let xr = x.row(b);
            // 4 accumulators over the gathered sparse pattern
            let chunks = nnz / 4;
            let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for c in 0..chunks {
                let t = c * 4;
                a0 += sgnbuf[t] * xr[idxbuf[t] as usize];
                a1 += sgnbuf[t + 1] * xr[idxbuf[t + 1] as usize];
                a2 += sgnbuf[t + 2] * xr[idxbuf[t + 2] as usize];
                a3 += sgnbuf[t + 3] * xr[idxbuf[t + 3] as usize];
            }
            let mut acc = a0 + a1 + a2 + a3;
            for t in chunks * 4..nnz {
                acc += sgnbuf[t] * xr[idxbuf[t] as usize];
            }
            y[(b, n)] = acc * alpha;
        }
    }
    y
}

/// y = W_packed @ x for a single activation vector — the serving decode hot
/// path (`engine::PackedBackend` routes every per-token projection here).
/// One output per packed row, K/2 gather-MACs each; the metadata is decoded
/// on the fly since each group is visited exactly once per call.
pub fn packed_gemv(w: &Packed24, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), w.cols, "K mismatch");
    let g = w.cols / 4;
    let mut y = vec![0.0f32; w.rows];
    for (n, out) in y.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for gg in 0..g {
            let ((p0, s0), (p1, s1)) = w.group(n, gg);
            acc += s0 * x[gg * 4 + p0] + s1 * x[gg * 4 + p1];
        }
        *out = acc * w.alpha[n];
    }
    y
}

/// v1 kernel: decodes the metadata inside the (batch × row) loop — kept as
/// the §Perf baseline and as a second correctness witness.
pub fn packed_gemm_onthefly(x: &Mat, w: &Packed24) -> Mat {
    assert_eq!(x.cols, w.cols, "K mismatch");
    let g = w.cols / 4;
    let mut y = Mat::zeros(x.rows, w.rows);
    for b in 0..x.rows {
        let xr = x.row(b);
        let yr = y.row_mut(b);
        for n in 0..w.rows {
            let gbase = n * g;
            let mut acc = 0.0f32;
            // process 4 groups (one u16 meta word + one u8 sign byte) at a time
            let mut gg = 0;
            while gg + 4 <= g {
                let widx = (gbase + gg) / 4;
                // fast path only valid when the global group index is aligned
                if (gbase + gg) % 4 == 0 {
                    // branchless sign application: ±1 looked up from bits
                    const SGN: [f32; 2] = [-1.0, 1.0];
                    let meta = w.meta[widx];
                    let sgn = w.signs[widx];
                    let mut acc4 = 0.0f32;
                    for q in 0..4 {
                        let nib = (meta >> (4 * q)) & 0xf;
                        let sp = (sgn >> (2 * q)) & 0x3;
                        let base = (gg + q) * 4;
                        let x0 = xr[base + (nib & 3) as usize];
                        let x1 = xr[base + ((nib >> 2) & 3) as usize];
                        acc4 += SGN[(sp & 1) as usize] * x0 + SGN[(sp >> 1) as usize] * x1;
                    }
                    acc += acc4;
                    gg += 4;
                } else {
                    let ((p0, s0), (p1, s1)) = w.group(n, gg);
                    acc += s0 * xr[gg * 4 + p0] + s1 * xr[gg * 4 + p1];
                    gg += 1;
                }
            }
            while gg < g {
                let ((p0, s0), (p1, s1)) = w.group(n, gg);
                acc += s0 * xr[gg * 4 + p0] + s1 * xr[gg * 4 + p1];
                gg += 1;
            }
            yr[n] = acc * w.alpha[n];
        }
    }
    y
}

// ---------------------------------------------------------------------------
// Dense 2-bit baseline (ABQ-LLM stand-in)
// ---------------------------------------------------------------------------

/// Dense 2-bit weight matrix: 4 weights per byte, levels {-1, 0, +1} scaled
/// per row — the representation ABQ-LLM's W2A16 kernels stream.
#[derive(Clone, Debug)]
pub struct Dense2Bit {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>, // 2 bits per weight: 00=-1, 01=0, 10=+1
    pub alpha: Vec<f32>,
}

impl Dense2Bit {
    /// Quantize a dense matrix to 2-bit {-α, 0, +α} per row (absmax/2 dead-zone).
    pub fn quantize(w: &Mat) -> Dense2Bit {
        let mut data = vec![0u8; (w.rows * w.cols + 3) / 4];
        let mut alpha = Vec::with_capacity(w.rows);
        for i in 0..w.rows {
            let row = w.row(i);
            let a = row.iter().map(|x| x.abs()).sum::<f32>() / row.len() as f32;
            alpha.push(a);
            let thr = a * 0.5;
            for (j, &x) in row.iter().enumerate() {
                let code: u8 = if x > thr {
                    2
                } else if x < -thr {
                    0
                } else {
                    1
                };
                let idx = i * w.cols + j;
                data[idx / 4] |= code << (2 * (idx % 4));
            }
        }
        Dense2Bit { rows: w.rows, cols: w.cols, data, alpha }
    }

    #[inline]
    fn code(&self, i: usize, j: usize) -> i32 {
        let idx = i * self.cols + j;
        (((self.data[idx / 4] >> (2 * (idx % 4))) & 0x3) as i32) - 1
    }

    pub fn unpack(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(i, j)] = self.code(i, j) as f32 * self.alpha[i];
            }
        }
        out
    }

    pub fn bytes(&self) -> usize {
        self.data.len() + self.alpha.len() * 4
    }
}

/// y = x @ W_2bit^T: dense inner loop over all K (no sparsity skip).
pub fn gemm_2bit(x: &Mat, w: &Dense2Bit) -> Mat {
    assert_eq!(x.cols, w.cols);
    let mut y = Mat::zeros(x.rows, w.rows);
    for b in 0..x.rows {
        let xr = x.row(b);
        let yr = y.row_mut(b);
        for n in 0..w.rows {
            let mut acc = 0.0f32;
            let base = n * w.cols;
            for j in 0..w.cols {
                let idx = base + j;
                let code = (((w.data[idx / 4] >> (2 * (idx % 4))) & 0x3) as i32) - 1;
                // branchless: code ∈ {-1,0,1}
                acc += code as f32 * xr[j];
            }
            yr[n] = acc * w.alpha[n];
        }
    }
    y
}

/// FP32 reference GEMM (`x @ w^T`) for correctness + the FP16-class roofline
/// baseline in Fig. 4a (fp16 and fp32 move 2×/4× the bytes of 2-bit).
pub fn gemm_f32(x: &Mat, w: &Mat) -> Mat {
    crate::tensor::matmul_bt(x, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::packed::format::enforce_24;
    use crate::util::rng::Pcg32;

    fn random_sb24(rows: usize, cols: usize, rng: &mut Pcg32) -> (Packed24, Mat) {
        let dense = Mat::random(rows, cols, 1.0, rng);
        let (sb, alpha) = enforce_24(&dense);
        let packed = Packed24::pack(&sb, &alpha).unwrap();
        (packed, dense)
    }

    #[test]
    fn packed_gemm_variants_agree() {
        let mut rng = Pcg32::seeded(5);
        let (packed, _) = random_sb24(24, 64, &mut rng);
        let x = Mat::random(7, 64, 1.0, &mut rng);
        let v2 = packed_gemm(&x, &packed);
        let v1 = packed_gemm_onthefly(&x, &packed);
        for (a, b) in v2.data.iter().zip(&v1.data) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn packed_gemm_matches_dense_reference() {
        let mut rng = Pcg32::seeded(1);
        for (rows, cols, batch) in [(8usize, 16usize, 3usize), (24, 64, 7), (32, 128, 5)] {
            let (packed, _) = random_sb24(rows, cols, &mut rng);
            let x = Mat::random(batch, cols, 1.0, &mut rng);
            let got = packed_gemm(&x, &packed);
            let want = gemm_f32(&x, &packed.unpack());
            for (a, b) in got.data.iter().zip(&want.data) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b} ({rows}x{cols})");
            }
        }
    }

    #[test]
    fn packed_gemv_matches_gemm_single_row() {
        let mut rng = Pcg32::seeded(8);
        let (packed, _) = random_sb24(24, 64, &mut rng);
        let x = Mat::random(1, 64, 1.0, &mut rng);
        let want = packed_gemm(&x, &packed);
        let got = packed_gemv(&packed, x.row(0));
        assert_eq!(got.len(), 24);
        for (a, b) in got.iter().zip(want.row(0)) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn gemm_2bit_matches_its_unpack() {
        let mut rng = Pcg32::seeded(2);
        let w = Mat::random(24, 64, 1.0, &mut rng);
        let q = Dense2Bit::quantize(&w);
        let x = Mat::random(5, 64, 1.0, &mut rng);
        let got = gemm_2bit(&x, &q);
        let want = gemm_f32(&x, &q.unpack());
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn packed_is_smaller_than_2bit() {
        let mut rng = Pcg32::seeded(3);
        let (packed, dense) = random_sb24(64, 256, &mut rng);
        let two = Dense2Bit::quantize(&dense);
        assert!(packed.bytes() < two.bytes(), "{} vs {}", packed.bytes(), two.bytes());
    }

    #[test]
    fn two_bit_codes_in_range() {
        let mut rng = Pcg32::seeded(4);
        let w = Mat::random(4, 16, 1.0, &mut rng);
        let q = Dense2Bit::quantize(&w);
        let u = q.unpack();
        for i in 0..4 {
            for j in 0..16 {
                let v = u[(i, j)] / q.alpha[i].max(1e-12);
                assert!(v == 0.0 || (v.abs() - 1.0).abs() < 1e-5);
            }
        }
    }
}
