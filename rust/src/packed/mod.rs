//! Sub-1-bit packed weight storage + the sparse-binary GEMM simulator
//! (paper §4.3 + Appendix C): the exact 6-bit 2:4 group encoding, the
//! dense 2-bit baseline, the analytic memory model (Fig. 9) and the
//! roofline model (Fig. 8).

pub mod format;
pub mod gemm;
pub mod memory;
pub mod roofline;
pub mod store;

pub use format::{enforce_24, Packed24};
pub use gemm::{
    gemm_2bit, gemm_f32, packed_gemm, packed_gemm4, packed_gemm4_into, packed_gemm4_par,
    packed_gemm4_par_into, packed_gemm_into, packed_gemm_onthefly, packed_gemm_par,
    packed_gemm_par_into, packed_gemm_scratch, packed_gemv, packed_gemv_into, packed_gemv_onthefly,
    packed_gemv_par, packed_gemv_par_into, Dense2Bit, PAR_MIN_MACS,
};
pub use store::PackedModel;
