//! Roofline model for sparse GEMM quantization (paper Fig. 8 / Appendix C.2).
//!
//! Attainable TFLOPS = min(peak_compute, arithmetic_intensity × bandwidth).
//! The GEMM is D = A(E)·B + C with A the (M × K) weight matrix, B the
//! (K × N) activations; N is batch×seq during prefill and batch during
//! decode. Bytes moved depend on the weight encoding; compute peak depends
//! on whether the sparse tensor core path (2× dense) applies.
//!
//! Default machine constants model the paper's RTX 4090 (f16 tensor core
//! peak ≈ 165 TFLOPS dense / 330 sparse, ~1 TB/s HBM); they are parameters
//! so the same model can be pointed at any device.

/// Device model for the roofline.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub peak_dense_tflops: f64,
    pub peak_sparse_tflops: f64,
    pub bandwidth_gbs: f64,
}

pub const RTX4090: Device =
    Device { peak_dense_tflops: 165.0, peak_sparse_tflops: 330.0, bandwidth_gbs: 1008.0 };

/// GEMM kernel variants compared in Fig. 8.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    Fp16,
    Int2,
    /// ours: 1-bit 2:4 sparse
    Sparse1Bit24,
}

impl Kernel {
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Fp16 => "FP16 GEMM",
            Kernel::Int2 => "2-bit GEMM",
            Kernel::Sparse1Bit24 => "1-bit 2:4 GEMM (ours)",
        }
    }

    /// Weight bits per element moved from memory.
    pub fn weight_bits(&self) -> f64 {
        match self {
            Kernel::Fp16 => 16.0,
            Kernel::Int2 => 2.0,
            Kernel::Sparse1Bit24 => 1.5, // 6 bits per 2:4 group of 4
        }
    }

    /// Effective FLOPs for an (M,K)×(K,N) GEMM: the sparse kernel skips the
    /// zero half of the MACs.
    pub fn flops(&self, m: u64, k: u64, n: u64) -> f64 {
        let dense = 2.0 * m as f64 * k as f64 * n as f64;
        match self {
            Kernel::Sparse1Bit24 => dense, // counts *useful* dense-equivalent work
            _ => dense,
        }
    }

    /// Bytes moved: weights (encoded) + activations/outputs at fp16.
    pub fn bytes(&self, m: u64, k: u64, n: u64) -> f64 {
        let w = m as f64 * k as f64 * self.weight_bits() / 8.0;
        let act = (k as f64 * n as f64 + m as f64 * n as f64) * 2.0;
        w + act
    }

    /// Arithmetic intensity (FLOPs/byte).
    pub fn intensity(&self, m: u64, k: u64, n: u64) -> f64 {
        self.flops(m, k, n) / self.bytes(m, k, n)
    }

    /// Compute ceiling on `dev` (sparse tensor cores for ours).
    pub fn compute_peak(&self, dev: &Device) -> f64 {
        match self {
            Kernel::Sparse1Bit24 => dev.peak_sparse_tflops,
            _ => dev.peak_dense_tflops,
        }
    }

    /// Attainable TFLOPS under the roofline.
    pub fn attainable_tflops(&self, dev: &Device, m: u64, k: u64, n: u64) -> f64 {
        let ai = self.intensity(m, k, n);
        let mem_bound = ai * dev.bandwidth_gbs * 1e9 / 1e12;
        mem_bound.min(self.compute_peak(dev))
    }
}

pub const ALL_KERNELS: [Kernel; 3] = [Kernel::Fp16, Kernel::Int2, Kernel::Sparse1Bit24];

/// Predicted speedup of ours over a baseline kernel at a given GEMM shape
/// (runtime ratio = flops/attainable ratio; flops are equal so it is the
/// attainable-TFLOPS ratio).
pub fn predicted_speedup(baseline: Kernel, dev: &Device, m: u64, k: u64, n: u64) -> f64 {
    Kernel::Sparse1Bit24.attainable_tflops(dev, m, k, n)
        / baseline.attainable_tflops(dev, m, k, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_regime_is_memory_bound_and_ours_wins() {
        // decode: N = 8 (batch), typical LLaMA-7B shape
        let (m, k, n) = (4096u64, 4096u64, 8u64);
        for kern in ALL_KERNELS {
            let at = kern.attainable_tflops(&RTX4090, m, k, n);
            assert!(at < kern.compute_peak(&RTX4090), "{:?} not memory bound", kern);
        }
        let s_fp16 = predicted_speedup(Kernel::Fp16, &RTX4090, m, k, n);
        let s_2bit = predicted_speedup(Kernel::Int2, &RTX4090, m, k, n);
        assert!(s_fp16 > 8.0, "vs fp16 {s_fp16}");
        assert!(s_2bit > 1.2 && s_2bit < 1.5, "vs 2bit {s_2bit}"); // ~1.33 (Appendix C)
    }

    #[test]
    fn prefill_regime_hits_compute_ceiling() {
        let (m, k, n) = (4096u64, 4096u64, 16384u64);
        let ours = Kernel::Sparse1Bit24.attainable_tflops(&RTX4090, m, k, n);
        assert!((ours - RTX4090.peak_sparse_tflops).abs() < 1e-6);
        // 2x over dense-peak kernels in the compute-bound limit
        let s = predicted_speedup(Kernel::Fp16, &RTX4090, m, k, n);
        assert!((s - 2.0).abs() < 0.2, "s={s}");
    }

    #[test]
    fn intensity_increases_with_n() {
        let k = Kernel::Sparse1Bit24;
        assert!(k.intensity(4096, 4096, 64) > k.intensity(4096, 4096, 4));
    }

    #[test]
    fn paper_headline_84pct_of_sparse_peak_is_reachable() {
        // paper: 263.45 TFLOPS = 79.74% of sparse peak at seq 8192
        let at = Kernel::Sparse1Bit24.attainable_tflops(&RTX4090, 4096, 4096, 8192);
        assert!(at / RTX4090.peak_sparse_tflops > 0.79, "{}", at / RTX4090.peak_sparse_tflops);
    }
}
