//! Packed-model container (.stbp): the deployment artifact for a quantized
//! model — every projection stored in the 6-bit 2:4 format plus FP sidecar
//! tensors (norms, embeddings). A serve process loads this instead of FP32
//! weights: ~19× smaller on disk and mmap-friendly (flat little-endian
//! layout).
//!
//! Layout: magic "STBP" | u32 version | u32 n_entries | per entry:
//!   u8 kind (0 = packed24, 1 = f32 tensor)
//!   u32 name_len | name
//!   packed24: u32 rows | u32 cols | meta u16[] | signs u8[] | alpha f32[]
//!   f32:      u32 ndim | dims | data

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::config::ModelConfig;
use crate::model::{ModelWeights};
use crate::packed::format::{enforce_24, Packed24};
use crate::tensor::Mat;

/// A deployable packed model.
pub struct PackedModel {
    pub packed: BTreeMap<String, Packed24>,
    pub fp: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl PackedModel {
    /// Collapse a quantized model's reconstructions onto exact 2:4 packed
    /// form (the serving representation of §4.3).
    pub fn from_weights(cfg: &ModelConfig, w: &ModelWeights) -> Result<PackedModel> {
        let mut packed = BTreeMap::new();
        let mut fp = BTreeMap::new();
        fp.insert("embed".into(), (vec![w.embed.rows, w.embed.cols], w.embed.data.clone()));
        fp.insert("ln_f".into(), (vec![w.ln_f.len()], w.ln_f.clone()));
        if let Some(p) = &w.pos {
            fp.insert("pos".into(), (vec![p.rows, p.cols], p.data.clone()));
        }
        for (i, l) in w.layers.iter().enumerate() {
            fp.insert(format!("layers.{i}.ln1"), (vec![l.ln1.len()], l.ln1.clone()));
            fp.insert(format!("layers.{i}.ln2"), (vec![l.ln2.len()], l.ln2.clone()));
            for n in cfg.layer_weight_names() {
                let m = &l.mats[n];
                let (sb, alpha) = enforce_24(m);
                let p = Packed24::pack(&sb, &alpha).map_err(anyhow::Error::msg)?;
                packed.insert(format!("layers.{i}.{n}"), p);
            }
        }
        Ok(PackedModel { packed, fp })
    }

    /// Expand back into dense ModelWeights (for the generic forward).
    pub fn to_weights(&self, cfg: &ModelConfig) -> Result<ModelWeights> {
        let get_fp = |name: &str| -> Result<&(Vec<usize>, Vec<f32>)> {
            self.fp.get(name).with_context(|| format!("missing fp tensor {name}"))
        };
        let embed = {
            let (d, v) = get_fp("embed")?;
            Mat::from_vec(d[0], d[1], v.clone())
        };
        let ln_f = get_fp("ln_f")?.1.clone();
        let pos = if self.fp.contains_key("pos") {
            let (d, v) = get_fp("pos")?;
            Some(Mat::from_vec(d[0], d[1], v.clone()))
        } else {
            None
        };
        let mut layers = Vec::new();
        for i in 0..cfg.n_layers {
            let mut mats = BTreeMap::new();
            for n in cfg.layer_weight_names() {
                let p = self
                    .packed
                    .get(&format!("layers.{i}.{n}"))
                    .with_context(|| format!("missing packed layers.{i}.{n}"))?;
                mats.insert(n.to_string(), p.unpack());
            }
            layers.push(crate::model::LayerWeights {
                ln1: get_fp(&format!("layers.{i}.ln1"))?.1.clone(),
                ln2: get_fp(&format!("layers.{i}.ln2"))?.1.clone(),
                mats,
            });
        }
        Ok(ModelWeights { embed, ln_f, pos, layers })
    }

    pub fn total_bytes(&self) -> usize {
        let p: usize = self.packed.values().map(|p| p.bytes()).sum();
        let f: usize = self.fp.values().map(|(_, v)| v.len() * 4).sum();
        p + f
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"STBP")?;
        f.write_all(&1u32.to_le_bytes())?;
        f.write_all(&((self.packed.len() + self.fp.len()) as u32).to_le_bytes())?;
        for (name, p) in &self.packed {
            f.write_all(&[0u8])?;
            write_name(&mut f, name)?;
            f.write_all(&(p.rows as u32).to_le_bytes())?;
            f.write_all(&(p.cols as u32).to_le_bytes())?;
            for m in &p.meta {
                f.write_all(&m.to_le_bytes())?;
            }
            f.write_all(&p.signs)?;
            for a in &p.alpha {
                f.write_all(&a.to_le_bytes())?;
            }
        }
        for (name, (dims, data)) in &self.fp {
            f.write_all(&[1u8])?;
            write_name(&mut f, name)?;
            f.write_all(&(dims.len() as u32).to_le_bytes())?;
            for d in dims {
                f.write_all(&(*d as u32).to_le_bytes())?;
            }
            for v in data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PackedModel> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        let mut p = 0usize;
        let take = |p: &mut usize, n: usize| -> Result<&[u8]> {
            if *p + n > buf.len() {
                bail!("truncated STBP");
            }
            let s = &buf[*p..*p + n];
            *p += n;
            Ok(s)
        };
        let u32r = |p: &mut usize| -> Result<u32> {
            let b = take(p, 4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        };
        if take(&mut p, 4)? != b"STBP" {
            bail!("bad magic");
        }
        let ver = u32r(&mut p)?;
        if ver != 1 {
            bail!("unsupported STBP version {ver}");
        }
        let n = u32r(&mut p)? as usize;
        let mut packed = BTreeMap::new();
        let mut fp = BTreeMap::new();
        for _ in 0..n {
            let kind = take(&mut p, 1)?[0];
            let nl = u32r(&mut p)? as usize;
            let name = String::from_utf8(take(&mut p, nl)?.to_vec())?;
            match kind {
                0 => {
                    let rows = u32r(&mut p)? as usize;
                    let cols = u32r(&mut p)? as usize;
                    let total_groups = rows * (cols / 4);
                    let n_words = (total_groups + 3) / 4;
                    let meta: Vec<u16> = take(&mut p, 2 * n_words)?
                        .chunks_exact(2)
                        .map(|c| u16::from_le_bytes([c[0], c[1]]))
                        .collect();
                    let signs = take(&mut p, n_words)?.to_vec();
                    let alpha: Vec<f32> = take(&mut p, 4 * rows)?
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    packed.insert(name, Packed24 { rows, cols, meta, signs, alpha });
                }
                1 => {
                    let ndim = u32r(&mut p)? as usize;
                    let mut dims = Vec::with_capacity(ndim);
                    for _ in 0..ndim {
                        dims.push(u32r(&mut p)? as usize);
                    }
                    let count: usize = dims.iter().product::<usize>().max(1);
                    let data: Vec<f32> = take(&mut p, 4 * count)?
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    fp.insert(name, (dims, data));
                }
                k => bail!("unknown entry kind {k}"),
            }
        }
        Ok(PackedModel { packed, fp })
    }
}

fn write_name<W: Write>(f: &mut W, name: &str) -> Result<()> {
    f.write_all(&(name.len() as u32).to_le_bytes())?;
    f.write_all(name.as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("stbp_{}_{}.stbp", tag, std::process::id()))
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 1);
        let pm = PackedModel::from_weights(&cfg, &w).unwrap();
        let path = tmpfile("rt");
        pm.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.packed.len(), pm.packed.len());
        let a = pm.to_weights(&cfg).unwrap();
        let b = back.to_weights(&cfg).unwrap();
        assert_eq!(a.layers[0].mats["wq"].data, b.layers[0].mats["wq"].data);
        assert_eq!(a.embed.data, b.embed.data);
    }

    #[test]
    fn packed_model_much_smaller_than_fp32() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 2);
        let pm = PackedModel::from_weights(&cfg, &w).unwrap();
        // projections compress ~19x; embeddings stay fp so compare matrices only
        let proj_fp: usize = w
            .layers
            .iter()
            .flat_map(|l| l.mats.values())
            .map(|m| m.data.len() * 4)
            .sum();
        let proj_packed: usize = pm.packed.values().map(|p| p.bytes()).sum();
        assert!(proj_fp / proj_packed >= 15, "{proj_fp} / {proj_packed}");
    }

    #[test]
    fn expanded_weights_run_the_forward() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 3);
        let pm = PackedModel::from_weights(&cfg, &w).unwrap();
        let qw = pm.to_weights(&cfg).unwrap();
        let toks: Vec<u8> = (0..16).collect();
        let logits = crate::model::transformer::model_fwd(&cfg, &qw, &toks);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn load_rejects_garbage() {
        let path = tmpfile("bad");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(PackedModel::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
