//! Packed-model container (.stbp): the deployment artifact for a quantized
//! model — every projection stored in the 6-bit 2:4 format plus FP sidecar
//! tensors (norms, embeddings). A serve process loads this instead of FP32
//! weights: ~19× smaller on disk and mmap-friendly (flat little-endian
//! layout).
//!
//! v2 layout (what [`PackedModel::save`] writes):
//!   magic "STBP" | u32 version=2 | u32 n_entries | per entry:
//!     entry bytes:
//!       u8 kind (0 = packed24, 1 = f32 tensor)
//!       u32 name_len | name
//!       packed24: u32 rows | u32 cols | meta u16[] | signs u8[] | alpha f32[]
//!       f32:      u32 ndim | dims | data
//!     u32 crc32(entry bytes)
//!   u32 crc32(everything above)   — the whole-file trailer
//!
//! v1 is the same without any checksums; [`PackedModel::load`] still reads
//! it (deployed artifacts keep working). Saves are atomic (temp file +
//! fsync + rename via [`atomic_write`]) and every load validates untrusted
//! length fields against the remaining file size before allocating, so a
//! corrupt header is a typed [`ArtifactError`] naming the entry and byte
//! offset — never an OOM abort or silently wrong weights.

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{Context, Result};

use crate::model::config::ModelConfig;
use crate::model::ModelWeights;
use crate::packed::format::{enforce_24, Packed24};
use crate::tensor::Mat;
use crate::util::artifact::{atomic_write, crc32, ArtifactError, ByteReader};

/// Container version written by [`PackedModel::save`].
pub const STBP_VERSION: u32 = 2;

/// A deployable packed model.
pub struct PackedModel {
    pub packed: BTreeMap<String, Packed24>,
    pub fp: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

impl PackedModel {
    /// Collapse a quantized model's reconstructions onto exact 2:4 packed
    /// form (the serving representation of §4.3).
    pub fn from_weights(cfg: &ModelConfig, w: &ModelWeights) -> Result<PackedModel> {
        let mut packed = BTreeMap::new();
        let mut fp = BTreeMap::new();
        fp.insert("embed".into(), (vec![w.embed.rows, w.embed.cols], w.embed.data.clone()));
        fp.insert("ln_f".into(), (vec![w.ln_f.len()], w.ln_f.clone()));
        if let Some(p) = &w.pos {
            fp.insert("pos".into(), (vec![p.rows, p.cols], p.data.clone()));
        }
        for (i, l) in w.layers.iter().enumerate() {
            fp.insert(format!("layers.{i}.ln1"), (vec![l.ln1.len()], l.ln1.clone()));
            fp.insert(format!("layers.{i}.ln2"), (vec![l.ln2.len()], l.ln2.clone()));
            for n in cfg.layer_weight_names() {
                let m = &l.mats[n];
                let (sb, alpha) = enforce_24(m);
                let p = Packed24::pack(&sb, &alpha).map_err(anyhow::Error::msg)?;
                packed.insert(format!("layers.{i}.{n}"), p);
            }
        }
        Ok(PackedModel { packed, fp })
    }

    /// Expand back into dense ModelWeights (for the generic forward).
    pub fn to_weights(&self, cfg: &ModelConfig) -> Result<ModelWeights> {
        let get_fp = |name: &str| -> Result<&(Vec<usize>, Vec<f32>)> {
            self.fp.get(name).with_context(|| format!("missing fp tensor {name}"))
        };
        let embed = {
            let (d, v) = get_fp("embed")?;
            Mat::from_vec(d[0], d[1], v.clone())
        };
        let ln_f = get_fp("ln_f")?.1.clone();
        let pos = if self.fp.contains_key("pos") {
            let (d, v) = get_fp("pos")?;
            Some(Mat::from_vec(d[0], d[1], v.clone()))
        } else {
            None
        };
        let mut layers = Vec::new();
        for i in 0..cfg.n_layers {
            let mut mats = BTreeMap::new();
            for n in cfg.layer_weight_names() {
                let p = self
                    .packed
                    .get(&format!("layers.{i}.{n}"))
                    .with_context(|| format!("missing packed layers.{i}.{n}"))?;
                mats.insert(n.to_string(), p.unpack());
            }
            layers.push(crate::model::LayerWeights {
                ln1: get_fp(&format!("layers.{i}.ln1"))?.1.clone(),
                ln2: get_fp(&format!("layers.{i}.ln2"))?.1.clone(),
                mats,
            });
        }
        Ok(ModelWeights { embed, ln_f, pos, layers })
    }

    pub fn total_bytes(&self) -> usize {
        let p: usize = self.packed.values().map(|p| p.bytes()).sum();
        let f: usize = self.fp.values().map(|(_, v)| v.len() * 4).sum();
        p + f
    }

    /// One entry's bytes (kind | name | payload), shared by both writers.
    fn encode_entry(out: &mut Vec<u8>, kind: u8, name: &str, body: &dyn Fn(&mut Vec<u8>)) {
        out.push(kind);
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        body(out);
    }

    /// Serialize the container at `version` (1 = legacy, no checksums;
    /// 2 = per-entry CRC32 + whole-file trailer).
    fn encode(&self, version: u32) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.total_bytes() + 64);
        out.extend_from_slice(b"STBP");
        out.extend_from_slice(&version.to_le_bytes());
        out.extend_from_slice(&((self.packed.len() + self.fp.len()) as u32).to_le_bytes());
        let mut entry = Vec::new();
        let push_entry = |out: &mut Vec<u8>, entry: &mut Vec<u8>| {
            if version >= 2 {
                let crc = crc32(entry);
                entry.extend_from_slice(&crc.to_le_bytes());
            }
            out.extend_from_slice(entry);
            entry.clear();
        };
        for (name, p) in &self.packed {
            Self::encode_entry(&mut entry, 0, name, &|b| {
                b.extend_from_slice(&(p.rows as u32).to_le_bytes());
                b.extend_from_slice(&(p.cols as u32).to_le_bytes());
                for m in &p.meta {
                    b.extend_from_slice(&m.to_le_bytes());
                }
                b.extend_from_slice(&p.signs);
                for a in &p.alpha {
                    b.extend_from_slice(&a.to_le_bytes());
                }
            });
            push_entry(&mut out, &mut entry);
        }
        for (name, (dims, data)) in &self.fp {
            Self::encode_entry(&mut entry, 1, name, &|b| {
                b.extend_from_slice(&(dims.len() as u32).to_le_bytes());
                for d in dims {
                    b.extend_from_slice(&(*d as u32).to_le_bytes());
                }
                for v in data {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            });
            push_entry(&mut out, &mut entry);
        }
        if version >= 2 {
            let file_crc = crc32(&out);
            out.extend_from_slice(&file_crc.to_le_bytes());
        }
        out
    }

    /// Save the v2 checksummed container, atomically (temp + fsync +
    /// rename — a crash mid-save never leaves a torn artifact).
    pub fn save(&self, path: &Path) -> Result<()> {
        atomic_write(path, &self.encode(STBP_VERSION))
            .with_context(|| format!("save {}", path.display()))?;
        Ok(())
    }

    /// Save the legacy v1 container (no checksums) — kept so the
    /// version-compat contract ("a v1 `.stbp` still loads") stays testable
    /// against bytes this build actually wrote.
    pub fn save_v1(&self, path: &Path) -> Result<()> {
        atomic_write(path, &self.encode(1)).with_context(|| format!("save {}", path.display()))?;
        Ok(())
    }

    /// Load a `.stbp` file (v1 or v2).
    pub fn load(path: &Path) -> Result<PackedModel> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        let pm = Self::load_bytes(&buf).with_context(|| format!("load {}", path.display()))?;
        Ok(pm)
    }

    /// Parse a `.stbp` container from bytes, with typed corruption errors
    /// ([`ArtifactError`] names the entry and byte offset). v2 verifies
    /// per-entry CRC32s and the whole-file trailer; v1 parses without
    /// checksums. Both bound every length field before allocating.
    pub fn load_bytes(buf: &[u8]) -> Result<PackedModel, ArtifactError> {
        let mut r = ByteReader::new(buf);
        let magic = r.take(4)?;
        if magic != b"STBP" {
            return Err(ArtifactError::BadMagic { found: magic.to_vec(), expected: "STBP" });
        }
        let ver = r.u32()?;
        if ver != 1 && ver != 2 {
            return Err(ArtifactError::UnsupportedVersion { version: ver });
        }
        let raw_n = r.u32()?;
        let n = r.bounded_count(raw_n as u64, 5, "entry count")?; // kind + name_len floor
        let mut packed = BTreeMap::new();
        let mut fp = BTreeMap::new();
        for _ in 0..n {
            let entry_start = r.pos();
            let (name, kind) = read_entry_header(&mut r)?;
            match kind {
                0 => {
                    let p = read_packed24(&mut r)?;
                    packed.insert(name.clone(), p);
                }
                1 => {
                    let t = read_fp_tensor(&mut r)?;
                    fp.insert(name.clone(), t);
                }
                k => return Err(r.invalid(format!("unknown entry kind {k}"))),
            }
            if ver >= 2 {
                let computed = crc32(r.consumed_since(entry_start));
                let stored = r.u32()?;
                if stored != computed {
                    return Err(ArtifactError::EntryChecksum {
                        entry: name,
                        offset: entry_start,
                        stored,
                        computed,
                    });
                }
            }
            r.entry = None;
        }
        if ver >= 2 {
            let body = r.consumed_since(0);
            let computed = crc32(body);
            let stored = r.u32()?;
            if stored != computed {
                return Err(ArtifactError::FileChecksum { stored, computed });
            }
        }
        r.expect_end()?;
        Ok(PackedModel { packed, fp })
    }
}

/// Entry prefix: kind + bounded name. Sets `r.entry` so every later error
/// in this entry names it.
fn read_entry_header(r: &mut ByteReader<'_>) -> Result<(String, u8), ArtifactError> {
    let kind = r.u8()?;
    let raw_nl = r.u32()?;
    let nl = r.bounded_count(raw_nl as u64, 1, "name_len")?;
    let name = String::from_utf8(r.take(nl)?.to_vec())
        .map_err(|_| r.invalid("entry name is not utf-8"))?;
    r.entry = Some(name.clone());
    Ok((name, kind))
}

/// Packed24 payload: rows | cols | meta | signs | alpha, all bounded.
fn read_packed24(r: &mut ByteReader<'_>) -> Result<Packed24, ArtifactError> {
    let rows = r.u32()? as u64;
    let cols = r.u32()? as u64;
    if cols % 4 != 0 {
        return Err(r.invalid(format!("cols {cols} not divisible by 4 (2:4 packing)")));
    }
    let total_groups = rows * (cols / 4);
    let n_words = total_groups.div_ceil(4);
    let n_meta = r.bounded_count(n_words, 2, "meta words")?;
    let meta: Vec<u16> = r
        .take(2 * n_meta)?
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect();
    let n_signs = r.bounded_count(n_words, 1, "sign bytes")?;
    let signs = r.take(n_signs)?.to_vec();
    let n_alpha = r.bounded_count(rows, 4, "alpha scales")?;
    let alpha: Vec<f32> = r
        .take(4 * n_alpha)?
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(Packed24 { rows: rows as usize, cols: cols as usize, meta, signs, alpha })
}

/// FP tensor payload: ndim | dims | f32 data, all bounded.
fn read_fp_tensor(r: &mut ByteReader<'_>) -> Result<(Vec<usize>, Vec<f32>), ArtifactError> {
    let raw_ndim = r.u32()?;
    let ndim = r.bounded_count(raw_ndim as u64, 4, "ndim")?;
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(r.u32()? as usize);
    }
    let count: u64 = dims.iter().map(|&d| d as u64).fold(1u64, u64::saturating_mul).max(1);
    let n = r.bounded_count(count, 4, "tensor data")?;
    let data: Vec<f32> = r
        .take(4 * n)?
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok((dims, data))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;

    fn tmpfile(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("stbp_{}_{}.stbp", tag, std::process::id()))
    }

    fn tiny_model() -> (ModelConfig, PackedModel) {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 1);
        let pm = PackedModel::from_weights(&cfg, &w).unwrap();
        (cfg, pm)
    }

    #[test]
    fn save_load_roundtrip() {
        let (cfg, pm) = tiny_model();
        let path = tmpfile("rt");
        pm.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(back.packed.len(), pm.packed.len());
        let a = pm.to_weights(&cfg).unwrap();
        let b = back.to_weights(&cfg).unwrap();
        assert_eq!(a.layers[0].mats["wq"].data, b.layers[0].mats["wq"].data);
        assert_eq!(a.embed.data, b.embed.data);
    }

    #[test]
    fn v1_container_still_loads() {
        let (cfg, pm) = tiny_model();
        let path = tmpfile("v1");
        pm.save_v1(&path).unwrap();
        let raw = std::fs::read(&path).unwrap();
        assert_eq!(&raw[4..8], &1u32.to_le_bytes(), "save_v1 must write version 1");
        let back = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let a = pm.to_weights(&cfg).unwrap();
        let b = back.to_weights(&cfg).unwrap();
        assert_eq!(a.layers[0].mats["wq"].data, b.layers[0].mats["wq"].data);
    }

    #[test]
    fn packed_model_much_smaller_than_fp32() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 2);
        let pm = PackedModel::from_weights(&cfg, &w).unwrap();
        // projections compress ~19x; embeddings stay fp so compare matrices only
        let proj_fp: usize = w
            .layers
            .iter()
            .flat_map(|l| l.mats.values())
            .map(|m| m.data.len() * 4)
            .sum();
        let proj_packed: usize = pm.packed.values().map(|p| p.bytes()).sum();
        assert!(proj_fp / proj_packed >= 15, "{proj_fp} / {proj_packed}");
    }

    #[test]
    fn expanded_weights_run_the_forward() {
        let cfg = ModelConfig::preset("llama1-7b").unwrap();
        let w = ModelWeights::synthetic(&cfg, 3);
        let pm = PackedModel::from_weights(&cfg, &w).unwrap();
        let qw = pm.to_weights(&cfg).unwrap();
        let toks: Vec<u8> = (0..16).collect();
        let logits = crate::model::transformer::model_fwd(&cfg, &qw, &toks);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn load_rejects_garbage() {
        let path = tmpfile("bad");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(PackedModel::load(&path).is_err());
        std::fs::remove_file(&path).ok();
        match PackedModel::load_bytes(b"NOPExxxx") {
            Err(ArtifactError::BadMagic { expected, .. }) => assert_eq!(expected, "STBP"),
            other => panic!("expected BadMagic, got {other:?}"),
        }
    }

    #[test]
    fn flipped_payload_bit_fails_entry_checksum_naming_the_entry() {
        let (_, pm) = tiny_model();
        let mut bytes = pm.encode(STBP_VERSION);
        // flip one bit inside the FIRST entry's meta words (past the 12-byte
        // header, kind, name_len, name, rows, cols — pure payload, so the
        // entry parses and only the checksum can catch it); entries are
        // BTreeMap-ordered so the first packed entry is deterministic
        let first_name = pm.packed.keys().next().unwrap().clone();
        let flip_at = 12 + 1 + 4 + first_name.len() + 8 + 2;
        bytes[flip_at] ^= 0x10;
        match PackedModel::load_bytes(&bytes) {
            Err(ArtifactError::EntryChecksum { entry, offset, .. }) => {
                assert_eq!(entry, first_name);
                assert_eq!(offset, 12, "first entry starts right after the header");
            }
            other => panic!("expected EntryChecksum naming {first_name}, got {other:?}"),
        }
    }

    #[test]
    fn truncated_v2_is_typed() {
        let (_, pm) = tiny_model();
        let bytes = pm.encode(STBP_VERSION);
        match PackedModel::load_bytes(&bytes[..bytes.len() - 9]) {
            Err(
                ArtifactError::Truncated { .. }
                | ArtifactError::EntryChecksum { .. }
                | ArtifactError::BoundExceeded { .. },
            ) => {}
            other => panic!("expected a typed corruption error, got {other:?}"),
        }
    }

    #[test]
    fn lying_header_lengths_rejected_without_alloc() {
        // v1 container claiming a huge name_len: must be BoundExceeded
        let mut buf = Vec::new();
        buf.extend_from_slice(b"STBP");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes()); // one entry
        buf.push(1u8); // fp tensor
        buf.extend_from_slice(&u32::MAX.to_le_bytes()); // name_len lie
        match PackedModel::load_bytes(&buf) {
            Err(ArtifactError::BoundExceeded { field, .. }) => assert_eq!(field, "name_len"),
            other => panic!("expected BoundExceeded, got {other:?}"),
        }
        // huge entry count with no entry bytes behind it
        let mut buf = Vec::new();
        buf.extend_from_slice(b"STBP");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        match PackedModel::load_bytes(&buf) {
            Err(ArtifactError::BoundExceeded { field, .. }) => assert_eq!(field, "entry count"),
            other => panic!("expected BoundExceeded, got {other:?}"),
        }
    }

    #[test]
    fn file_checksum_guards_the_header() {
        let (_, pm) = tiny_model();
        let mut bytes = pm.encode(STBP_VERSION);
        // corrupt the trailer itself: entries all verify, the file CRC must not
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        match PackedModel::load_bytes(&bytes) {
            Err(ArtifactError::FileChecksum { .. }) => {}
            other => panic!("expected FileChecksum, got {other:?}"),
        }
    }
}
