//! Result reporting for the bench harness: aligned text tables (the format
//! the paper's tables are regenerated in), CSV dumps, and a JSON results
//! sink under `reports/` for EXPERIMENTS.md bookkeeping.

pub mod bench;
pub mod kernels;
pub mod loadgen;

use std::io::Write;
use std::path::PathBuf;

use crate::util::json::Json;
use crate::util::render_table;

/// A named table being assembled by a bench.
pub struct Report {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Report {
    pub fn new(title: &str, headers: &[&str]) -> Report {
        Report {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    /// Render to stdout in the canonical format.
    pub fn print(&self) {
        let headers: Vec<&str> = self.headers.iter().map(|s| s.as_str()).collect();
        println!("\n=== {} ===", self.title);
        print!("{}", render_table(&headers, &self.rows));
    }

    /// Persist as CSV + JSON under `reports/` (best-effort).
    pub fn save(&self, slug: &str) {
        let dir = reports_dir();
        if std::fs::create_dir_all(&dir).is_err() {
            return;
        }
        // CSV
        if let Ok(mut f) = std::fs::File::create(dir.join(format!("{slug}.csv"))) {
            let _ = writeln!(f, "{}", self.headers.join(","));
            for r in &self.rows {
                let _ = writeln!(f, "{}", r.join(","));
            }
        }
        // JSON
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
            .collect();
        let j = crate::util::json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            ("headers", Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect())),
            ("rows", Json::Arr(rows)),
        ]);
        let _ = std::fs::write(dir.join(format!("{slug}.json")), j.dump());
    }
}

/// `$STBLLM_REPORTS` or `<repo>/reports`.
pub fn reports_dir() -> PathBuf {
    if let Ok(p) = std::env::var("STBLLM_REPORTS") {
        return PathBuf::from(p);
    }
    let base = std::env::var("CARGO_MANIFEST_DIR").unwrap_or_else(|_| ".".into());
    PathBuf::from(base).join("reports")
}

/// Format a perplexity the way the paper's tables do (2 decimals, scientific
/// for the blow-ups).
pub fn fmt_ppl(p: f64) -> String {
    if !p.is_finite() {
        "inf".to_string()
    } else if p >= 1e4 {
        format!("{:.1e}", p)
    } else {
        format!("{:.2}", p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_and_saves() {
        let mut r = Report::new("Table X", &["a", "b"]);
        r.row(vec!["1".into(), "2".into()]);
        let dir = std::env::temp_dir().join(format!("stbllm_rep_{}", std::process::id()));
        std::env::set_var("STBLLM_REPORTS", dir.to_str().unwrap());
        r.save("t");
        assert!(dir.join("t.csv").exists());
        assert!(dir.join("t.json").exists());
        std::env::remove_var("STBLLM_REPORTS");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn ppl_formatting() {
        assert_eq!(fmt_ppl(31.724), "31.72");
        assert_eq!(fmt_ppl(170000.0), "1.7e5");
        assert_eq!(fmt_ppl(f64::INFINITY), "inf");
    }
}
