//! `stbllm loadgen` — a concurrent streaming load generator for the HTTP
//! gateway.
//!
//! Drives N keep-alive connections against `POST /generate`, measuring
//! time-to-first-token and end-to-end latency per request from the
//! client's side of the socket (the numbers the serving trajectory in
//! EXPERIMENTS.md tracks), then snapshots `GET /stats` (schema-2
//! envelope) for the server-side prefix-cache counters and writes
//! `reports/BENCH_http.json`.
//!
//! With `--metrics-check` the run also scrapes `GET /metrics` before and
//! after the workload and gates on the observability contract: the
//! exposition parses, counters are monotone, the server-side token count
//! matches the client-observed total, the per-stage histograms are
//! populated, and every per-request trace obeys
//! `queue + prefill + decode ≤ total`. The scraped exposition is saved
//! next to the bench JSON as `metrics.prom`.
//!
//! Built on the same `net::http` client helpers the integration tests
//! use — real sockets, no mocks.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::server::percentile;
use crate::net::api::{split_lines, GenerateEvent, GenerateRequest};
use crate::net::http::{read_response_head, BodyReader};
use crate::util::json::{num, obj, Json};
use crate::util::rng::Pcg32;

/// Configuration for [`run_loadgen`].
#[derive(Clone, Debug)]
pub struct LoadgenOpts {
    /// Gateway address, `host:port`.
    pub target: String,
    /// Concurrent keep-alive connections.
    pub connections: usize,
    /// Total requests across all connections.
    pub requests: usize,
    /// Prompt length in tokens.
    pub prompt_len: usize,
    /// Tokens to generate per request.
    pub max_new: usize,
    /// Send the SAME prompt on every request (exercises the server's
    /// prefix cache — the `--smoke` gate requires hits > 0).
    pub shared_prompt: bool,
    /// `POST /admin/drain` after the workload (the CI job uses this to
    /// shut the server down and collect its drain report).
    pub drain: bool,
    /// Scrape `GET /metrics` before/after the run and gate on the
    /// observability contract (see module docs); errors fail the run.
    pub metrics_check: bool,
    /// Where to write `BENCH_http.json`; `None` = `reports/`.
    pub out: Option<PathBuf>,
}

impl LoadgenOpts {
    /// The `--smoke` workload: 4 connections × 2 requests each, shared
    /// 10-token prompt, 8 new tokens — small enough for CI, shared enough
    /// to hit the prefix cache.
    pub fn smoke(target: &str) -> LoadgenOpts {
        LoadgenOpts {
            target: target.to_string(),
            connections: 4,
            requests: 8,
            prompt_len: 10,
            max_new: 8,
            shared_prompt: true,
            drain: false,
            metrics_check: false,
            out: None,
        }
    }
}

/// Client-side results of one loadgen run.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    /// Requests that streamed to a `done` event.
    pub completed: usize,
    /// Requests that failed (connect, non-200, protocol, truncation).
    pub errors: usize,
    /// Shed (`503 + Retry-After`) attempts that were retried with jittered
    /// exponential backoff before completing or giving up.
    pub retries: usize,
    /// Tokens received across all streams.
    pub generated_tokens: usize,
    /// Wall-clock seconds for the whole workload.
    pub wall_s: f64,
    /// Aggregate client-observed throughput (finite; 0.0 on empty runs).
    pub tok_s: f64,
    /// Client-observed time-to-first-token percentiles (seconds).
    pub ttft_p50_s: f64,
    /// 95th-percentile TTFT.
    pub ttft_p95_s: f64,
    /// Client-observed end-to-end latency percentiles (seconds).
    pub latency_p50_s: f64,
    /// 95th-percentile latency.
    pub latency_p95_s: f64,
    /// Server-side prefix-cache hits, merged across replicas (from
    /// `GET /stats` after the run).
    pub prefix_hits: usize,
    /// Decode replicas the server reported (1 when the stats document
    /// carries no `replicas` section).
    pub replicas: usize,
    /// Max prefix hits held by a single replica's pool — with a shared
    /// prompt, the affinity router should concentrate (almost) all hits
    /// on one replica, so this is what the smoke gate checks.
    pub affine_prefix_hits: usize,
    /// Where `BENCH_http.json` was written.
    pub json_path: PathBuf,
}

struct Sample {
    ttft_s: f64,
    latency_s: f64,
    tokens: usize,
    /// Parsed `x-stbllm-trace` trailer (per-request span breakdown).
    trace: Option<Json>,
}

/// Deterministic prompt for request index `i` (all-same when shared).
fn prompt_tokens(opts: &LoadgenOpts, i: usize) -> Vec<u8> {
    let salt = if opts.shared_prompt { 0 } else { i };
    (0..opts.prompt_len).map(|k| ((k * 7 + salt * 13) % 31) as u8).collect()
}

fn body_for(opts: &LoadgenOpts, i: usize) -> String {
    GenerateRequest::tokens(prompt_tokens(opts, i), opts.max_new).to_body()
}

/// Outcome of one wire attempt of a `/generate` request.
enum Attempt {
    /// Streamed to a `done` event.
    Done(Sample),
    /// The gateway shed the admit (`503 + Retry-After`): back off and
    /// retry. `keep_alive` says whether the connection is still usable.
    Shed { keep_alive: bool },
}

/// One `POST /generate` on an open connection; returns the stream sample
/// or a shed signal.
fn run_request(stream: &mut TcpStream, body: &str) -> Result<Attempt> {
    let t0 = Instant::now();
    write!(
        stream,
        "POST /generate HTTP/1.1\r\nhost: stbllm\r\ncontent-type: application/json\r\ncontent-length: {}\r\n\r\n{}",
        body.len(),
        body
    )?;
    stream.flush()?;
    let head = read_response_head(stream).map_err(|e| anyhow!("response head: {e}"))?;
    let mut reader = BodyReader::new(&head);
    if head.status == 503 && head.header("retry-after").is_some() {
        // consume the body so a keep-alive connection stays framed
        let _ = reader.read_all(stream);
        let keep_alive = head
            .header("connection")
            .map(|c| c.eq_ignore_ascii_case("keep-alive"))
            .unwrap_or(false);
        return Ok(Attempt::Shed { keep_alive });
    }
    if head.status != 200 {
        let detail = reader.read_all(stream).unwrap_or_default();
        return Err(anyhow!(
            "status {} from /generate: {}",
            head.status,
            String::from_utf8_lossy(&detail)
        ));
    }
    let mut ttft = None;
    let mut tokens = 0usize;
    let mut done = false;
    // chunk boundaries need not align with event lines: buffer the tail
    // and parse only complete lines through the typed schema
    let mut buf = String::new();
    while let Some(piece) = reader.next_piece(stream).map_err(|e| anyhow!("stream: {e}"))? {
        buf.push_str(&String::from_utf8_lossy(&piece));
        let (events, rest) = {
            let (lines, tail) = split_lines(&buf);
            let mut evs = Vec::new();
            for line in lines {
                if line.trim().is_empty() {
                    continue;
                }
                evs.push(GenerateEvent::parse(line).map_err(|e| anyhow!("event line: {e}"))?);
            }
            (evs, tail.to_string())
        };
        buf = rest;
        for ev in events {
            match ev {
                GenerateEvent::Token(_) => {
                    tokens += 1;
                    if ttft.is_none() {
                        ttft = Some(t0.elapsed().as_secs_f64());
                    }
                }
                GenerateEvent::Done(_) => done = true,
                GenerateEvent::Error(msg) => return Err(anyhow!("stream error event: {msg}")),
            }
        }
    }
    if !done {
        return Err(anyhow!("stream ended without a done event ({tokens} tokens in)"));
    }
    let latency_s = t0.elapsed().as_secs_f64();
    let trace = reader.trailer("x-stbllm-trace").and_then(|t| Json::parse(t).ok());
    Ok(Attempt::Done(Sample { ttft_s: ttft.unwrap_or(latency_s), latency_s, tokens, trace }))
}

/// Max wire attempts per request (first try + shed retries).
const MAX_ATTEMPTS: usize = 8;

/// Jittered exponential backoff delay before shed retry `attempt`
/// (1-based): `10ms · 2^(attempt-1) · U[0.5, 1.0)`, capped at 2s. The
/// jitter comes from a seeded PCG stream, so a fixed-seed chaos run backs
/// off identically every time.
fn backoff_delay(attempt: usize, rng: &mut Pcg32) -> Duration {
    let exp = (1u64 << (attempt - 1).min(6)) as f64;
    let jitter = 0.5 + 0.5 * rng.next_f32() as f64;
    Duration::from_secs_f64((0.010 * exp * jitter).min(2.0))
}

fn connect(target: &str) -> std::io::Result<TcpStream> {
    let stream = TcpStream::connect(target)?;
    let _ = stream.set_read_timeout(Some(Duration::from_secs(120)));
    let _ = stream.set_nodelay(true);
    Ok(stream)
}

/// Simple GET returning the body (used for `/stats`) or POST with an
/// empty body (used for `/admin/drain`).
fn simple_request(target: &str, method: &str, path: &str) -> Result<Vec<u8>> {
    let mut stream = TcpStream::connect(target)
        .with_context(|| format!("connect {target}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nhost: stbllm\r\nconnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    let head = read_response_head(&mut stream).map_err(|e| anyhow!("{path}: {e}"))?;
    let body = BodyReader::new(&head)
        .read_all(&mut stream)
        .map_err(|e| anyhow!("{path} body: {e}"))?;
    if head.status != 200 {
        return Err(anyhow!("status {} from {path}", head.status));
    }
    Ok(body)
}

/// Parse a Prometheus text exposition into `series name → value`. The
/// series name keeps its label part (`..._bucket{le="..."}`), so every
/// sample line maps to a unique key. Errors on any malformed line — this
/// is the `--metrics-check` "exposition parses" gate.
fn parse_exposition(text: &str) -> Result<BTreeMap<String, f64>> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) =
            line.rsplit_once(' ').ok_or_else(|| anyhow!("bad exposition line {line:?}"))?;
        if name.is_empty() {
            bail!("bad exposition line {line:?}");
        }
        let v: f64 =
            value.parse().map_err(|_| anyhow!("bad value in exposition line {line:?}"))?;
        out.insert(name.to_string(), v);
    }
    Ok(out)
}

/// The `--metrics-check` gates, run against the before/after scrapes and
/// the per-request traces. Any violation is an error (CI fails the job).
fn check_metrics(
    before: &BTreeMap<String, f64>,
    after: &BTreeMap<String, f64>,
    samples: &[Sample],
    client_tokens: usize,
) -> Result<()> {
    // counters (and histogram counts) never go backwards
    for (name, b) in before {
        if !(name.ends_with("_total") || name.ends_with("_count")) {
            continue;
        }
        let a = after
            .get(name)
            .ok_or_else(|| anyhow!("counter {name} vanished between scrapes"))?;
        if a < b {
            bail!("counter {name} went backwards: {b} -> {a}");
        }
    }
    // server-side token accounting matches what the clients saw
    let tokens = "stbllm_gateway_generated_tokens_total";
    let delta = after.get(tokens).copied().unwrap_or(0.0)
        - before.get(tokens).copied().unwrap_or(0.0);
    if delta != client_tokens as f64 {
        bail!("{tokens} grew by {delta} but clients observed {client_tokens} tokens");
    }
    // the per-stage histograms actually saw the workload
    for stage in ["queue", "prefill", "decode", "kernel"] {
        let name = format!("stbllm_server_{stage}_seconds_count");
        let n = after.get(&name).copied().unwrap_or(0.0);
        if n <= 0.0 {
            bail!("stage histogram {name} is empty after the workload");
        }
    }
    // every stream carried a trace obeying conservative stage accounting
    for (i, s) in samples.iter().enumerate() {
        let t = s
            .trace
            .as_ref()
            .ok_or_else(|| anyhow!("request {i}: no x-stbllm-trace trailer"))?;
        let get = |k: &str| {
            t.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("request {i}: trace missing {k}: {}", t.dump()))
        };
        let (total, queue, prefill, decode) =
            (get("total_ms")?, get("queue_ms")?, get("prefill_ms")?, get("decode_ms")?);
        if queue + prefill + decode > total + 0.5 {
            bail!(
                "request {i}: stages exceed total ({queue} + {prefill} + {decode} > {total})"
            );
        }
    }
    Ok(())
}

/// Run the workload, snapshot `/stats`, write `BENCH_http.json`.
pub fn run_loadgen(opts: &LoadgenOpts) -> Result<LoadgenReport> {
    let connections = opts.connections.max(1);
    let requests = opts.requests.max(1);
    let metrics_before = if opts.metrics_check {
        let body = simple_request(&opts.target, "GET", "/metrics")
            .context("pre-run /metrics scrape")?;
        Some(parse_exposition(&String::from_utf8_lossy(&body)).context("pre-run exposition")?)
    } else {
        None
    };
    let samples: Mutex<Vec<Sample>> = Mutex::new(Vec::with_capacity(requests));
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let retries = AtomicUsize::new(0);
    let wall0 = Instant::now();
    std::thread::scope(|scope| {
        for c in 0..connections {
            let samples = &samples;
            let errors = &errors;
            let retries = &retries;
            scope.spawn(move || {
                // one keep-alive connection per worker, requests
                // round-robined by index; deterministic per-worker jitter
                let mut rng = Pcg32::new(0x6c6f_6164, c as u64);
                let mut stream = match connect(&opts.target) {
                    Ok(s) => s,
                    Err(e) => {
                        let mut errs = errors.lock().unwrap();
                        for i in (c..requests).step_by(connections) {
                            errs.push(format!("req {i}: connect: {e}"));
                        }
                        return;
                    }
                };
                for i in (c..requests).step_by(connections) {
                    let body = body_for(opts, i);
                    let mut attempt = 1usize;
                    loop {
                        match run_request(&mut stream, &body) {
                            Ok(Attempt::Done(sample)) => {
                                samples.lock().unwrap().push(sample);
                                break;
                            }
                            Ok(Attempt::Shed { keep_alive }) => {
                                if attempt >= MAX_ATTEMPTS {
                                    errors.lock().unwrap().push(format!(
                                        "req {i}: still shed after {attempt} attempts"
                                    ));
                                    break;
                                }
                                retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(backoff_delay(attempt, &mut rng));
                                attempt += 1;
                                if !keep_alive {
                                    match connect(&opts.target) {
                                        Ok(s) => stream = s,
                                        Err(e) => {
                                            errors
                                                .lock()
                                                .unwrap()
                                                .push(format!("req {i}: reconnect: {e}"));
                                            break;
                                        }
                                    }
                                }
                            }
                            Err(e) => {
                                errors.lock().unwrap().push(format!("req {i}: {e:#}"));
                                break;
                            }
                        }
                    }
                }
            });
        }
    });
    let wall_s = wall0.elapsed().as_secs_f64();
    let samples = samples.into_inner().unwrap();
    let errors = errors.into_inner().unwrap();
    let retries = retries.into_inner();
    for e in &errors {
        eprintln!("[loadgen] {e}");
    }

    // server-side counters AFTER the workload so prefix hits are visible
    // (schema-2 envelope: the merged kv counters nest under "gateway",
    // per-replica rows under "replicas")
    let stats_doc = match simple_request(&opts.target, "GET", "/stats") {
        Ok(body) => Json::parse(&String::from_utf8_lossy(&body)).ok(),
        Err(e) => {
            eprintln!("[loadgen] stats fetch failed: {e:#}");
            None
        }
    };
    let prefix_hits = stats_doc
        .as_ref()
        .and_then(|j| j.path(&["gateway", "kv", "prefix_hits"]).and_then(Json::as_usize))
        .unwrap_or(0);
    // with a shared prompt, affinity routes every stream to ONE replica —
    // its pool should hold (almost) all the hits, so the per-replica MAX
    // is the gate value (equals the aggregate on single-replica servers)
    let (replicas, affine_prefix_hits) = stats_doc
        .as_ref()
        .and_then(|j| j.get("replicas"))
        .and_then(Json::as_arr)
        .map(|rows| {
            let best = rows
                .iter()
                .filter_map(|r| r.path(&["kv", "prefix_hits"]).and_then(Json::as_usize))
                .max()
                .unwrap_or(prefix_hits);
            (rows.len().max(1), best)
        })
        .unwrap_or((1, prefix_hits));
    let generated_tokens: usize = samples.iter().map(|s| s.tokens).sum();
    if let Some(before) = &metrics_before {
        let raw = simple_request(&opts.target, "GET", "/metrics")
            .context("post-run /metrics scrape")?;
        let text = String::from_utf8_lossy(&raw).into_owned();
        let after = parse_exposition(&text).context("post-run exposition")?;
        check_metrics(before, &after, &samples, generated_tokens)?;
        // a multi-replica server must expose per-replica labeled series
        if replicas > 1 && !after.keys().any(|k| k.contains("replica=\"")) {
            bail!("{replicas} replicas served but no replica=\"N\"-labeled series in /metrics");
        }
        let prom_path = match &opts.out {
            Some(p) => p.with_file_name("metrics.prom"),
            None => crate::report::reports_dir().join("metrics.prom"),
        };
        if let Some(dir) = prom_path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(&prom_path, &text)
            .with_context(|| format!("write {}", prom_path.display()))?;
        eprintln!(
            "[loadgen] metrics check passed ({} series); exposition saved to {}",
            after.len(),
            prom_path.display()
        );
    }
    if opts.drain {
        simple_request(&opts.target, "POST", "/admin/drain").context("drain request")?;
    }

    let mut ttfts: Vec<f64> = samples.iter().map(|s| s.ttft_s).collect();
    let mut lats: Vec<f64> = samples.iter().map(|s| s.latency_s).collect();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let tok_s = if generated_tokens == 0 || wall_s <= 0.0 {
        0.0
    } else {
        generated_tokens as f64 / wall_s
    };
    let report = LoadgenReport {
        completed: samples.len(),
        errors: errors.len(),
        retries,
        generated_tokens,
        wall_s,
        tok_s,
        ttft_p50_s: percentile(&ttfts, 50.0),
        ttft_p95_s: percentile(&ttfts, 95.0),
        latency_p50_s: percentile(&lats, 50.0),
        latency_p95_s: percentile(&lats, 95.0),
        prefix_hits,
        replicas,
        affine_prefix_hits,
        json_path: PathBuf::new(),
    };

    let json_path = match &opts.out {
        Some(p) => p.clone(),
        None => crate::report::reports_dir().join("BENCH_http.json"),
    };
    if let Some(dir) = json_path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let doc = obj(vec![
        ("target", crate::util::json::s(&opts.target)),
        ("connections", num(connections as f64)),
        ("requests", num(requests as f64)),
        ("prompt_len", num(opts.prompt_len as f64)),
        ("max_new", num(opts.max_new as f64)),
        ("shared_prompt", Json::Bool(opts.shared_prompt)),
        ("completed", num(report.completed as f64)),
        ("errors", num(report.errors as f64)),
        ("retries", num(report.retries as f64)),
        ("generated_tokens", num(generated_tokens as f64)),
        ("wall_s", num(wall_s)),
        ("tok_s", num(tok_s)),
        ("ttft_p50_s", num(report.ttft_p50_s)),
        ("ttft_p95_s", num(report.ttft_p95_s)),
        ("latency_p50_s", num(report.latency_p50_s)),
        ("latency_p95_s", num(report.latency_p95_s)),
        ("prefix_hits", num(prefix_hits as f64)),
        ("replicas", num(replicas as f64)),
        ("affine_prefix_hits", num(affine_prefix_hits as f64)),
        ("metrics_check", Json::Bool(opts.metrics_check)),
    ]);
    std::fs::write(&json_path, doc.dump())
        .with_context(|| format!("write {}", json_path.display()))?;
    Ok(LoadgenReport { json_path, ..report })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially_with_bounded_jitter() {
        let mut rng = Pcg32::new(0x6c6f_6164, 0);
        let d1 = backoff_delay(1, &mut rng);
        assert!(d1 >= Duration::from_millis(5) && d1 <= Duration::from_millis(10), "{d1:?}");
        let d4 = backoff_delay(4, &mut rng);
        assert!(d4 >= Duration::from_millis(40) && d4 <= Duration::from_millis(80), "{d4:?}");
        // capped: huge attempt numbers cannot sleep forever
        assert!(backoff_delay(60, &mut rng) <= Duration::from_secs(2));
        // deterministic under a fixed seed
        let mut a = Pcg32::new(1, 7);
        let mut b = Pcg32::new(1, 7);
        assert_eq!(backoff_delay(3, &mut a), backoff_delay(3, &mut b));
    }

    #[test]
    fn shared_prompts_are_identical_and_salted_ones_differ() {
        let shared = LoadgenOpts { shared_prompt: true, ..LoadgenOpts::smoke("x") };
        assert_eq!(prompt_tokens(&shared, 0), prompt_tokens(&shared, 5));
        let distinct = LoadgenOpts { shared_prompt: false, ..LoadgenOpts::smoke("x") };
        assert_ne!(prompt_tokens(&distinct, 0), prompt_tokens(&distinct, 5));
        assert!(prompt_tokens(&shared, 0).iter().all(|&t| t < 31));
    }

    #[test]
    fn request_body_is_valid_json() {
        let opts = LoadgenOpts::smoke("x");
        let body = body_for(&opts, 3);
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("prompt").unwrap().as_arr().unwrap().len(), 10);
        assert_eq!(doc.get("max_new").unwrap().as_usize().unwrap(), 8);
    }

    #[test]
    fn exposition_parser_accepts_real_lines_and_rejects_garbage() {
        let text = "# HELP stbllm_x_total things\n# TYPE stbllm_x_total counter\n\
                    stbllm_x_total 5\n\
                    stbllm_h_seconds_bucket{le=\"0.001\"} 2\n\
                    stbllm_h_seconds_sum 0.004\n\
                    stbllm_h_seconds_count 2\n";
        let m = parse_exposition(text).unwrap();
        assert_eq!(m.get("stbllm_x_total"), Some(&5.0));
        assert_eq!(m.get("stbllm_h_seconds_bucket{le=\"0.001\"}"), Some(&2.0));
        assert_eq!(m.len(), 4);
        assert!(parse_exposition("not a metric line").is_err());
        assert!(parse_exposition("stbllm_x_total five").is_err());
    }

    fn sample_with_trace(total: f64, queue: f64, prefill: f64, decode: f64) -> Sample {
        let trace = format!(
            "{{\"total_ms\":{total},\"queue_ms\":{queue},\"prefill_ms\":{prefill},\"decode_ms\":{decode}}}"
        );
        Sample { ttft_s: 0.01, latency_s: 0.02, tokens: 4, trace: Json::parse(&trace).ok() }
    }

    #[test]
    fn metrics_check_gates_fire() {
        let mut before = BTreeMap::new();
        before.insert("stbllm_gateway_generated_tokens_total".to_string(), 0.0);
        let mut after = before.clone();
        after.insert("stbllm_gateway_generated_tokens_total".to_string(), 4.0);
        for stage in ["queue", "prefill", "decode", "kernel"] {
            after.insert(format!("stbllm_server_{stage}_seconds_count"), 1.0);
        }
        let good = vec![sample_with_trace(10.0, 1.0, 2.0, 3.0)];
        check_metrics(&before, &after, &good, 4).unwrap();

        // token mismatch
        assert!(check_metrics(&before, &after, &good, 5).is_err());
        // counter regression
        let mut shrunk = after.clone();
        shrunk.insert("stbllm_gateway_generated_tokens_total".to_string(), -1.0);
        assert!(check_metrics(&before, &shrunk, &good, 4).is_err());
        // empty stage histogram
        let mut hollow = after.clone();
        hollow.insert("stbllm_server_decode_seconds_count".to_string(), 0.0);
        assert!(check_metrics(&before, &hollow, &good, 4).is_err());
        // stage times exceeding the total
        let bad = vec![sample_with_trace(5.0, 4.0, 4.0, 4.0)];
        assert!(check_metrics(&before, &after, &bad, 4).is_err());
        // missing trace trailer
        let untraced = vec![Sample { trace: None, ..sample_with_trace(1.0, 0.0, 0.0, 0.0) }];
        assert!(check_metrics(&before, &after, &untraced, 4).is_err());
    }
}
