//! Shared support for the bench harness (`benches/*.rs`, `harness = false`).
//!
//! Centralizes artifact loading, calibration/weight caching, quantize+eval
//! plumbing and the fast/full switch so each bench file reads like the table
//! it regenerates.
//!
//! Environment knobs:
//!   STBLLM_FULL=1          — evaluate the full model zoo (default: a small
//!                            representative subset so `cargo bench` stays
//!                            tractable on one core)
//!   STBLLM_CALIB_TOKENS=N  — calibration token budget (default 512)
//!   STBLLM_EVAL_TOKENS=N   — perplexity token budget (default 1161 ≈ 9 windows)
//!   STBLLM_NATIVE_EVAL=1   — force the native forward instead of PJRT

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

use crate::coordinator::calib::{calibrate, ModelCalib};
use crate::coordinator::quantizer::{quantize_model, Method, QuantizedModel};
use crate::engine::{NativeBackend, PjrtBackend};
use crate::eval::perplexity::perplexity;
use crate::model::config::ModelConfig;
use crate::model::corpus;
use crate::model::ModelWeights;
use crate::runtime::{Artifacts, Runtime};

pub struct BenchCtx {
    pub arts: Artifacts,
    rt: Option<Runtime>,
    weights: HashMap<String, Rc<ModelWeights>>,
    calibs: HashMap<(String, String), Rc<ModelCalib>>,
    pub calib_tokens: usize,
    pub eval_tokens: usize,
    pub full: bool,
    native_eval: bool,
}

impl BenchCtx {
    pub fn new() -> Result<BenchCtx> {
        let arts = Artifacts::load_default()?;
        let native_eval = std::env::var("STBLLM_NATIVE_EVAL").is_ok();
        let rt = if native_eval {
            None
        } else {
            match Runtime::cpu(&arts.root) {
                Ok(rt) => Some(rt),
                Err(e) => {
                    eprintln!("[bench] PJRT unavailable ({e:#}); using native eval");
                    None
                }
            }
        };
        Ok(BenchCtx {
            arts,
            rt,
            weights: HashMap::new(),
            calibs: HashMap::new(),
            calib_tokens: env_usize("STBLLM_CALIB_TOKENS", 512),
            eval_tokens: env_usize("STBLLM_EVAL_TOKENS", 1161),
            full: std::env::var("STBLLM_FULL").is_ok(),
            native_eval,
        })
    }

    pub fn config(&self, model: &str) -> ModelConfig {
        self.arts.models[model].config.clone()
    }

    /// A model is usable when its manifest entry AND trained weights exist
    /// (the artifact build may have trained only a subset of the zoo).
    pub fn has_model(&self, model: &str) -> bool {
        match self.arts.models.get(model) {
            Some(ma) => self.arts.root.join(&ma.weights).exists(),
            None => false,
        }
    }

    /// Pick the evaluated subset of `all` (full zoo under STBLLM_FULL).
    pub fn subset<'a>(&self, all: &[&'a str], fast: &[&'a str]) -> Vec<&'a str> {
        let pick: Vec<&str> = if self.full { all.to_vec() } else { fast.to_vec() };
        pick.into_iter().filter(|m| self.has_model(m)).collect()
    }

    pub fn weights(&mut self, model: &str) -> Rc<ModelWeights> {
        if let Some(w) = self.weights.get(model) {
            return w.clone();
        }
        let w = Rc::new(self.arts.load_weights(model).expect("load weights"));
        self.weights.insert(model.to_string(), w.clone());
        w
    }

    pub fn calib(&mut self, model: &str, corpus_name: &str) -> Rc<ModelCalib> {
        let key = (model.to_string(), corpus_name.to_string());
        if let Some(c) = self.calibs.get(&key) {
            return c.clone();
        }
        let cfg = self.config(model);
        let w = self.weights(model);
        let c = Rc::new(calibrate(&cfg, &w, corpus_name, self.calib_tokens, 1234));
        self.calibs.insert(key, c.clone());
        c
    }

    /// Quantize `model` with `method`, calibrating on `calib_corpus`.
    pub fn quantize(&mut self, model: &str, method: &Method, calib_corpus: &str) -> QuantizedModel {
        let cfg = self.config(model);
        let w = self.weights(model);
        let needs_calib = !matches!(method, Method::FullPrecision | Method::Rtn { .. });
        let calib = needs_calib.then(|| self.calib(model, calib_corpus));
        quantize_model(&cfg, &w, method, calib.as_deref(), 1)
    }

    /// Perplexity of the given weights on `eval_corpus` — one generic
    /// evaluation over the `Backend` seam: a borrowed `PjrtBackend` (reusing
    /// this context's compiled-executable cache) when the runtime is up,
    /// else a borrowed `NativeBackend`.
    pub fn ppl(&mut self, model: &str, w: &ModelWeights, eval_corpus: &str) -> f64 {
        let cfg = self.config(model);
        let toks = corpus::corpus_tokens(eval_corpus, self.eval_tokens, 999);
        if !self.native_eval {
            if let Some(rt) = &self.rt {
                let via_pjrt = PjrtBackend::borrowed(rt, &self.arts, model, w)
                    .and_then(|be| perplexity(&be, &toks));
                match via_pjrt {
                    Ok(p) => return p,
                    Err(e) => eprintln!("[bench] PJRT eval failed ({e:#}); native fallback"),
                }
            }
        }
        perplexity(&NativeBackend::borrowed(&cfg, w), &toks).expect("native eval")
    }

    /// quantize + eval in one call — the cell of most tables.
    pub fn cell(&mut self, model: &str, method: &Method, calib_c: &str, eval_c: &str) -> f64 {
        let q = self.quantize(model, method, calib_c);
        self.ppl(model, &q.weights, eval_c)
    }

    pub fn runtime(&self) -> Option<&Runtime> {
        self.rt.as_ref()
    }
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The standard method lineup of Table 2 (labels match the paper rows).
pub fn table2_methods() -> Vec<Method> {
    use crate::quant::NmRatio;
    vec![
        Method::FullPrecision,
        Method::Rtn { bits: 1 },
        Method::Gptq { bits: 1, block: 128 },
        Method::PbLlm { frac_salient: 0.10, hi_bits: 8 },
        Method::BiLlm { nm: None },
        Method::BiLlm { nm: Some(NmRatio::new(6, 8)) },
        Method::BiLlm { nm: Some(NmRatio::new(5, 8)) },
        Method::BiLlm { nm: Some(NmRatio::new(4, 8)) },
        Method::stbllm(NmRatio::new(6, 8)),
        Method::stbllm(NmRatio::new(5, 8)),
        Method::stbllm(NmRatio::new(4, 8)),
    ]
}
